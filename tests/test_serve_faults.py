"""Fault-injection tests for the serving loop's failure paths: injector
matching/budget semantics, retry-then-recover on transient solve and
verify failures, quarantine of a poison request after bounded retries
WITHOUT stalling other fleets, and exact poison isolation (the folded
prefix of a partially-failed request group still serves).
"""

import numpy as np
import pytest

from repro.serve import (
    FaultInjector,
    FaultSpec,
    Request,
    RightsizingService,
    ServiceConfig,
)
from repro.workload.gct import gct_like_instance


def _admit(fleet, n=8, m=3, seed=0):
    p = gct_like_instance(n=n, m=m, seed=seed)
    return Request(fleet=fleet, kind="admit", dem=p.dem, start=p.start,
                   end=p.end, node_types=p.node_types, T=p.T)


def _service(faults=None, **cfg):
    cfg.setdefault("shape_quantum", 4)
    return RightsizingService(config=ServiceConfig(**cfg), faults=faults)


class TestInjector:
    def test_spec_validates_kind_and_budget(self):
        with pytest.raises(ValueError, match="fault kind must be one of"):
            FaultSpec(kind="oom")
        with pytest.raises(ValueError, match="times must be >= 1"):
            FaultSpec(kind="nonconverge", times=0)

    def test_matching_respects_fleet_tick_and_budget(self):
        inj = FaultInjector([
            FaultSpec(kind="verify-fail", fleet="a", tick=2, times=1)])
        assert not inj.fire("verify-fail", fleet="b", tick=2)  # fleet
        assert not inj.fire("verify-fail", fleet="a", tick=3)  # tick
        assert not inj.fire("nonconverge", fleet="a", tick=2)  # kind
        assert inj.fire("verify-fail", fleet="a", tick=2)
        assert not inj.fire("verify-fail", fleet="a", tick=2)  # spent
        assert inj.fired == [{"kind": "verify-fail", "fleet": "a",
                              "tick": 2, "spec": 0}]

    def test_unlimited_budget(self):
        inj = FaultInjector([FaultSpec(kind="nonconverge", times=None)])
        assert all(inj.fire("nonconverge", fleet="x", tick=t)
                   for t in range(5))


class TestRetryThenRecover:
    @pytest.mark.parametrize("kind", ["nonconverge", "verify-fail"])
    def test_transient_lane_failure_retries_and_serves(self, kind):
        # a one-shot fault: the first attempt fails (no commit, warm
        # state dropped), the requeued retry succeeds cold
        svc = _service(faults=FaultInjector([
            FaultSpec(kind=kind, fleet="gpu", tick=1, times=1)]))
        svc.submit(_admit("gpu", seed=1))
        svc.tick()
        plan_before = svc.fleet("gpu").plan
        svc.submit(Request(fleet="gpu", kind="burst", ids=(0, 1),
                           factor=1.5))
        failed = svc.tick()
        assert failed.fleets == () and failed.retried == 1
        # the failed tick adopted nothing
        np.testing.assert_array_equal(svc.fleet("gpu").plan, plan_before)
        recovered = svc.tick()
        assert recovered.fleets == ("gpu",)
        assert recovered.warm_lanes == 0  # warm state was dropped
        assert svc.queue.pending == 0 and not svc.quarantined
        assert svc.report()["retries"] == 1

    def test_apply_raise_transient_retries(self):
        svc = _service(faults=FaultInjector([
            FaultSpec(kind="apply-raise", fleet="gpu", times=1)]))
        svc.submit(_admit("gpu", seed=1))
        svc.drain()
        assert svc.fleets == ("gpu",) and not svc.quarantined
        assert svc.report()["retries"] == 1


class TestQuarantine:
    def test_poison_quarantines_without_stalling_other_fleets(self):
        # fleet 'bad' fails every attempt; fleet 'ok' shares the queue
        # and must keep serving while 'bad' burns its retry budget
        svc = _service(max_request_retries=2, faults=FaultInjector([
            FaultSpec(kind="apply-raise", fleet="bad", times=None)]))
        svc.submit(_admit("bad", seed=1))
        svc.submit(_admit("ok", seed=2))
        ticks = svc.drain()
        assert svc.queue.pending == 0 and ticks < 10
        assert svc.fleets == ("ok",)
        assert svc.fleet("ok").plan_cost > 0
        assert len(svc.quarantined) == 1
        q = svc.quarantined[0]
        assert (q.fleet, q.kind, q.attempts) == ("bad", "admit", 3)
        assert q.error.startswith("InjectedFault")
        assert svc.report()["quarantined"] == 1

    def test_zero_retries_quarantines_first_failure(self):
        svc = _service(max_request_retries=0, faults=FaultInjector([
            FaultSpec(kind="nonconverge", fleet="gpu", times=None)]))
        svc.submit(_admit("gpu", seed=1))
        svc.drain()
        assert svc.quarantined[0].attempts == 1
        assert "gpu" not in svc.fleets  # the admit never committed

    def test_poison_isolation_serves_folded_prefix(self):
        # [arrive, invalid depart, arrive] against one fleet: both
        # arrivals land, only the depart quarantines
        svc = _service(max_request_retries=0)
        svc.submit(_admit("gpu", n=8, seed=1))
        svc.tick()
        p = gct_like_instance(n=2, m=3, seed=9)
        arrive = Request(fleet="gpu", kind="arrive", dem=p.dem,
                         start=p.start, end=p.end)
        svc.submit(arrive)
        svc.submit(Request(fleet="gpu", kind="depart", ids=(500,)))
        svc.submit(arrive)
        svc.drain()
        assert svc.fleet("gpu").n_tasks == 12
        assert [q.kind for q in svc.quarantined] == ["depart"]
        assert "unknown task ids [500]" in svc.quarantined[0].error
