"""Constraint-aware planning tests (``repro.core.constraints`` +
``repro.core.checker``).

Covers, in order:

  * the Amdahl width/duration law (anchored at width 1, monotone,
    exact at the serial_frac extremes, floored at one slot);
  * ``TaskConstraints`` construction/validation, ``from_groups``,
    and the serving-loop row surgery (``take``/``extend``/
    ``constrain``);
  * lowering semantics — vacuous identity fast path, affinity merge
    with peak-over-hull demand, virtual exclusivity/anti-affinity
    dimensions, minimal-width deadline resolution, and
    ``expand_solution`` round-trips;
  * lowering errors — out-of-window deadlines, unmeetable deadlines,
    affinity/anti-affinity contradictions, merged or widened rows
    that fit no node-type;
  * the ``require_lowered`` gates on ``trim_timeline``/``two_phase``/
    ``pack_problems``/``solve_lp``;
  * the independent feasibility oracle flagging deliberately broken
    plans (capacity, affinity split, anti-affinity co-tenancy,
    exclusivity, deadline misses, width bounds);
  * seeded end-to-end properties — random instances with random
    constraint sets solved by ``rightsize`` pass the oracle, ALL
    THREE placement engines (looped ``two_phase``, numpy lockstep
    ``place_many``, compiled stepper) stay bit-identical under active
    constraints, and vacuous constraints are bit-stable against the
    unconstrained path;
  * the same properties as a hypothesis suite when the 'test' extra
    is installed.
"""

import dataclasses

import numpy as np
import pytest

try:  # the property suite needs the 'test' extra; the rest runs without
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (  # noqa: E402
    FeasibilityError,
    FleetEngine,
    NodeTypes,
    Problem,
    Solution,
    TaskConstraints,
    assert_feasible,
    check_plan,
    expand_solution,
    lower_constraints,
    pack_problems,
    penalty_map,
    place_many,
    rightsize,
    solve_lp,
    trim_timeline,
    two_phase,
    width_duration,
)
from repro.workload import SyntheticSpec, synthetic_instance  # noqa: E402


def _tiny(n=2, D=1, cap=((4.0,),), cost=(1.0,), dem=None, start=None,
          end=None, T=4, constraints=None):
    """A hand-sized instance for exact semantic checks."""
    nt = NodeTypes(cap=np.array(cap), cost=np.array(cost))
    return Problem(
        dem=np.ones((n, D)) if dem is None else np.array(dem, float),
        start=np.zeros(n, np.int64) if start is None else
        np.array(start, np.int64),
        end=np.full(n, T - 1, np.int64) if end is None else
        np.array(end, np.int64),
        node_types=nt, T=T, constraints=constraints)


def _constrained_instance(seed):
    """A random synthetic instance plus a random, guaranteed-lowerable
    constraint set.  Candidate sets are tried strongest-first and
    weakened (drop affinity merges, then widths) whenever lowering
    rejects them — the last resort, a single exclusive task, always
    lowers.  Returns ``(problem, lowering)``."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 30))
    spec = SyntheticSpec(n=n, m=int(rng.integers(2, 5)),
                         D=int(rng.integers(1, 4)),
                         T=int(rng.integers(6, 16)),
                         seed=int(rng.integers(0, 2**31 - 1)))
    p = synthetic_instance(spec)
    T = p.T
    pool = list(rng.permutation(n))

    def pop(k):
        return [int(pool.pop()) for _ in range(min(k, len(pool)))]

    # deadlines at or after the natural finish are always meetable
    deadlines = {u: int(rng.integers(int(p.end[u]), T))
                 for u in pop(int(rng.integers(1, 4)))}
    widths = {}
    for u in pop(int(rng.integers(0, 3))):
        w, f = int(rng.integers(2, 5)), float(rng.uniform(0.0, 0.6))
        widths[u] = (w, f)
        dur0 = int(p.end[u] - p.start[u] + 1)
        fastest = int(p.start[u]) + int(width_duration(dur0, w, f)) - 1
        # a deadline between the fastest and the natural finish makes
        # the resolver actually pick a width
        deadlines[u] = int(rng.integers(fastest, int(p.end[u]) + 1))
    affinity = {"aff0": pop(2)} if rng.random() < 0.7 else {}
    anti = {"anti0": pop(int(rng.integers(2, 4)))} \
        if rng.random() < 0.7 else {}
    exclusive = pop(int(rng.integers(0, 3)))

    candidates = [
        dict(deadlines=deadlines, affinity=affinity, anti_affinity=anti,
             exclusive=exclusive, widths=widths),
        dict(deadlines=deadlines, anti_affinity=anti,
             exclusive=exclusive, widths=widths),
        dict(deadlines={u: d for u, d in deadlines.items()
                        if u not in widths},
             anti_affinity=anti, exclusive=exclusive),
        dict(exclusive=[0]),
    ]
    for cand in candidates:
        c = TaskConstraints.from_groups(n, **cand)
        q = dataclasses.replace(p, constraints=c)
        try:
            return q, lower_constraints(q)
        except ValueError:
            continue
    raise AssertionError("exclusive-only fallback must always lower")


class TestWidthDurationLaw:
    def test_anchored_at_width_one(self):
        for dur0 in (1, 3, 7, 20):
            for f in (0.0, 0.3, 1.0):
                assert int(width_duration(dur0, 1, f)) == dur0

    def test_monotone_nonincreasing_in_width(self):
        for f in (0.0, 0.25, 0.5, 1.0):
            durs = [int(width_duration(12, w, f)) for w in range(1, 9)]
            assert durs == sorted(durs, reverse=True)

    def test_extremes_exact(self):
        # fully parallel: ceil(dur0 / w); fully serial: constant
        assert int(width_duration(10, 4, 0.0)) == 3
        assert int(width_duration(10, 4, 1.0)) == 10

    def test_floored_at_one_slot(self):
        assert int(width_duration(1, 8, 0.0)) == 1

    def test_vectorised(self):
        out = width_duration(np.array([6, 6]), np.array([1, 2]), 0.5)
        assert out.tolist() == [6, 5]


class TestTaskConstraintsAPI:
    def test_vacuous_is_vacuous(self):
        c = TaskConstraints.vacuous(5)
        assert c.n == 5 and c.is_vacuous()

    @pytest.mark.parametrize("field,bad,msg", [
        ("deadline", -2, "deadline must be >= 0"),
        ("affinity", -3, "group ids must be >= 0"),
        ("max_width", 0, "max_width must be >= 1"),
        ("serial_frac", 1.5, r"serial_frac must lie in \[0, 1\]"),
    ])
    def test_field_validation(self, field, bad, msg):
        kw = dataclasses.asdict(TaskConstraints.vacuous(3))
        kw[field] = np.array([bad] * 3, type(np.asarray(kw[field])[0]))
        with pytest.raises(ValueError, match=msg):
            TaskConstraints(**kw)

    def test_shape_mismatch_names_the_field(self):
        kw = dataclasses.asdict(TaskConstraints.vacuous(3))
        kw["exclusive"] = np.zeros(4, bool)
        with pytest.raises(ValueError, match="exclusive is"):
            TaskConstraints(**kw)

    def test_from_groups_round_trip(self):
        c = TaskConstraints.from_groups(
            6, deadlines={1: 3}, affinity={"tower": (0, 1)},
            anti_affinity={"spread": (2, 3)}, exclusive=(4,),
            widths={5: (4, 0.25)})
        assert c.affinity_names == ("tower",)
        assert c.anti_names == ("spread",)
        assert c.deadline[1] == 3 and c.deadline[0] == -1
        assert c.affinity.tolist() == [0, 0, -1, -1, -1, -1]
        assert c.anti_affinity.tolist() == [-1, -1, 0, 0, -1, -1]
        assert bool(c.exclusive[4]) and not c.exclusive[:4].any()
        assert c.max_width[5] == 4 and c.serial_frac[5] == 0.25
        assert not c.is_vacuous()

    def test_from_groups_rejects_double_membership(self):
        with pytest.raises(ValueError, match="belongs to two groups"):
            TaskConstraints.from_groups(
                4, affinity={"a": (0, 1), "b": (1, 2)})

    def test_take_extend_constrain(self):
        c = TaskConstraints.from_groups(4, affinity={"g": (0, 1)},
                                        exclusive=(3,))
        sub = c.take(np.array([0, 3]))
        assert sub.n == 2 and sub.affinity.tolist() == [0, -1]
        assert bool(sub.exclusive[1])
        ext = c.extend(2)
        assert ext.n == 6 and not ext.exclusive[4:].any()
        # named groups are created on first use and joined thereafter
        c2 = c.constrain(np.array([2]), affinity="g", deadline=3)
        assert c2.affinity.tolist() == [0, 0, 0, -1]
        assert c2.deadline[2] == 3
        c3 = c2.constrain(np.array([3]), anti_affinity="fresh")
        assert c3.anti_names == ("fresh",)
        assert c3.anti_affinity[3] == 0

    def test_problem_rejects_wrong_arity(self):
        with pytest.raises(ValueError, match="constraints cover"):
            _tiny(n=2, constraints=TaskConstraints.vacuous(3))


class TestLoweringSemantics:
    def test_no_constraints_is_identity_object(self):
        p = _tiny()
        low = lower_constraints(p)
        assert low.identity and low.lowered is p

    def test_vacuous_constraints_identity_arrays(self):
        p = _tiny(constraints=TaskConstraints.vacuous(2))
        low = lower_constraints(p)
        assert low.identity
        assert low.lowered.constraints is None
        assert low.lowered.dem is p.dem  # shared, not copied

    def test_affinity_merge_is_peak_over_hull(self):
        c = TaskConstraints.from_groups(2, affinity={"g": (0, 1)})
        p = _tiny(dem=[[2.0], [3.0]], start=[0, 1], end=[2, 3],
                  cap=((6.0,),), T=4, constraints=c)
        low = lower_constraints(p)
        assert low.lowered.n == 1
        assert low.row_of.tolist() == [0, 0]
        # hull window [0, 3]; summed demand peaks at 5 on slots 1-2
        assert int(low.lowered.start[0]) == 0
        assert int(low.lowered.end[0]) == 3
        assert float(low.lowered.dem[0, 0]) == 5.0

    def test_exclusive_adds_shared_unit_dimension(self):
        c = TaskConstraints.from_groups(3, exclusive=(1,))
        p = _tiny(n=3, constraints=c)
        low = lower_constraints(p)
        assert low.lowered.D == p.D + 1
        np.testing.assert_array_equal(
            low.lowered.node_types.cap[:, -1], 1.0)
        col = low.lowered.dem[:, -1]
        assert col[1] == 1.0          # the exclusive task fills it
        assert 0 < col[0] < 1e-5      # others leave only crumbs

    def test_anti_affinity_adds_one_dim_per_group(self):
        c = TaskConstraints.from_groups(4, anti_affinity={"s": (0, 2)})
        p = _tiny(n=4, constraints=c)
        low = lower_constraints(p)
        assert low.lowered.D == p.D + 1
        assert low.lowered.dem[:, -1].tolist() == [1.0, 0.0, 1.0, 0.0]

    def test_deadline_resolves_minimal_width(self):
        # dur0=4, fully parallel, deadline slot 1 -> needs dur <= 2,
        # the minimal width is 2 (not the maximal 4)
        c = TaskConstraints.from_groups(
            1, deadlines={0: 1}, widths={0: (4, 0.0)})
        p = _tiny(n=1, dem=[[1.0]], start=[0], end=[3], T=4,
                  cap=((4.0,),), constraints=c)
        low = lower_constraints(p)
        assert low.widths.tolist() == [2]
        assert low.end_eff.tolist() == [1]
        assert float(low.lowered.dem[0, 0]) == 2.0  # demand scales by w
        assert int(low.lowered.end[0]) == 1

    def test_expand_solution_round_trip(self):
        c = TaskConstraints.from_groups(3, affinity={"g": (0, 2)})
        p = _tiny(n=3, dem=[[1.0], [1.0], [1.0]], cap=((4.0,),),
                  constraints=c)
        low = lower_constraints(p)
        sol = rightsize(low.lowered)
        out = expand_solution(low, sol)
        assert out.assign.shape == (3,)
        assert out.assign[0] == out.assign[2]  # merged pair co-located
        assert out.meta["constrained"] is True
        assert out.meta["widths"].tolist() == [1, 1, 1]
        assert check_plan(p, out) == []


class TestLoweringErrors:
    def test_deadline_beyond_horizon(self):
        c = TaskConstraints.from_groups(1, deadlines={0: 9})
        with pytest.raises(ValueError, match="beyond the horizon"):
            lower_constraints(_tiny(n=1, T=4, end=[3], constraints=c))

    def test_deadline_before_start(self):
        c = TaskConstraints.from_groups(1, deadlines={0: 0})
        with pytest.raises(ValueError, match="precedes its start"):
            lower_constraints(
                _tiny(n=1, start=[2], end=[3], constraints=c))

    def test_unmeetable_deadline_names_remedies(self):
        # dur0=4 fully serial: no width helps
        c = TaskConstraints.from_groups(
            1, deadlines={0: 1}, widths={0: (8, 1.0)})
        with pytest.raises(ValueError, match="cannot meet deadline"):
            lower_constraints(
                _tiny(n=1, start=[0], end=[3], constraints=c))

    def test_affinity_anti_contradiction(self):
        c = TaskConstraints.from_groups(
            2, affinity={"g": (0, 1)}, anti_affinity={"s": (0, 1)})
        with pytest.raises(ValueError, match="AND anti-affinity"):
            lower_constraints(_tiny(n=2, constraints=c))

    def test_merged_group_fits_no_node_type(self):
        c = TaskConstraints.from_groups(2, affinity={"g": (0, 1)})
        p = _tiny(dem=[[3.0], [3.0]], cap=((4.0,),), constraints=c)
        with pytest.raises(ValueError, match="fits no node-type"):
            lower_constraints(p)

    def test_widened_task_fits_no_node_type(self):
        c = TaskConstraints.from_groups(
            1, deadlines={0: 1}, widths={0: (4, 0.0)})
        p = _tiny(n=1, dem=[[3.0]], start=[0], end=[3], T=4,
                  cap=((4.0,),), constraints=c)
        with pytest.raises(ValueError, match="fits no node-type"):
            lower_constraints(p)


class TestSolverGatesRequireLowering:
    def _active(self):
        c = TaskConstraints.from_groups(2, exclusive=(0,))
        return _tiny(constraints=c)

    def test_trim_timeline_gate(self):
        with pytest.raises(ValueError, match="active constraints"):
            trim_timeline(self._active())

    def test_two_phase_gate(self):
        with pytest.raises(ValueError, match="active constraints"):
            two_phase(self._active(), np.zeros(2, np.int64))

    def test_pack_problems_gate(self):
        with pytest.raises(ValueError, match="active constraints"):
            pack_problems([self._active()])

    def test_solve_lp_gate(self):
        with pytest.raises(ValueError, match="active constraints"):
            solve_lp(self._active())

    def test_vacuous_passes_every_gate(self):
        p = _tiny(constraints=TaskConstraints.vacuous(2))
        t, _ = trim_timeline(p)
        pack_problems([t], assume_trimmed=True)
        two_phase(t, np.zeros(2, np.int64))


class TestCheckerCatchesViolations:
    def test_capacity_violation(self):
        p = _tiny(dem=[[1.5], [1.5]], cap=((2.0,),), T=2, end=[1, 1])
        sol = Solution(node_type=np.array([0]), assign=np.array([0, 0]))
        out = check_plan(p, sol)
        assert len(out) == 2  # both slots overflow
        assert "over capacity at slot 0 dim 0" in out[0]

    def test_assignment_out_of_range_short_circuits(self):
        p = _tiny()
        sol = Solution(node_type=np.array([0]), assign=np.array([0, 5]))
        out = check_plan(p, sol)
        assert out == ["task 1 assigned to node 5 outside 0..0"]

    def test_affinity_split_flagged(self):
        c = TaskConstraints.from_groups(2, affinity={"g": (0, 1)})
        p = _tiny(constraints=c)
        sol = Solution(node_type=np.array([0, 0]),
                       assign=np.array([0, 1]))
        assert any("affinity group 'g' split" in v
                   for v in check_plan(p, sol))

    def test_anti_affinity_needs_temporal_overlap(self):
        c = TaskConstraints.from_groups(2, anti_affinity={"s": (0, 1)})
        bad = _tiny(start=[0, 1], end=[2, 3], constraints=c)
        sol = Solution(node_type=np.array([0]), assign=np.array([0, 0]))
        assert any("share node 0 with overlapping windows" in v
                   for v in check_plan(bad, sol))
        # disjoint windows on one node are legal
        ok = _tiny(start=[0, 2], end=[1, 3], constraints=c)
        assert check_plan(ok, sol) == []

    def test_exclusive_no_cotenancy(self):
        c = TaskConstraints.from_groups(2, exclusive=(0,))
        p = _tiny(constraints=c)
        sol = Solution(node_type=np.array([0]), assign=np.array([0, 0]))
        assert any("exclusive task 0 shares node 0" in v
                   for v in check_plan(p, sol))

    def test_exclusive_exempts_own_affinity_group(self):
        c = TaskConstraints.from_groups(2, affinity={"g": (0, 1)},
                                        exclusive=(0,))
        p = _tiny(constraints=c)
        sol = Solution(node_type=np.array([0]), assign=np.array([0, 0]))
        assert check_plan(p, sol) == []

    def test_deadline_miss_flagged(self):
        c = TaskConstraints.from_groups(1, deadlines={0: 3})
        p = _tiny(n=1, start=[0], end=[3], T=4, constraints=c)
        sol = Solution(node_type=np.array([0]), assign=np.array([0]))
        assert check_plan(p, sol) == []
        tight = dataclasses.replace(
            p, constraints=TaskConstraints.from_groups(
                1, deadlines={0: 2}))
        assert any("misses its deadline" in v
                   for v in check_plan(tight, sol))

    def test_width_out_of_bounds(self):
        p = _tiny(n=1, dem=[[1.0]], start=[0], end=[3], T=4)
        sol = Solution(node_type=np.array([0]), assign=np.array([0]))
        out = check_plan(p, sol, widths=[3])  # rigid task, max_width 1
        assert any("width 3 outside 1..1" in v for v in out)

    def test_assert_feasible_raises_with_violations(self):
        p = _tiny(dem=[[1.5], [1.5]], cap=((2.0,),))
        sol = Solution(node_type=np.array([0]), assign=np.array([0, 0]))
        with pytest.raises(FeasibilityError, match="over capacity"):
            assert_feasible(p, sol)
        try:
            assert_feasible(p, sol)
        except FeasibilityError as e:
            assert isinstance(e, AssertionError)  # serve's catch net
            assert len(e.violations) >= 1


def _check_seed(seed):
    """The end-to-end property bundle for one random constrained
    instance — shared by the seeded loop and the hypothesis suite."""
    p, low = _constrained_instance(seed)
    # rightsize end-to-end: lowered solve, expanded plan, oracle-clean
    sol = rightsize(p)
    assert check_plan(p, sol) == []
    # all three engines bit-identical on the lowered instance
    t, _ = trim_timeline(low.lowered)
    mp = penalty_map(t, "avg")
    want = two_phase(t, mp)
    batch = pack_problems([t], assume_trimmed=True)
    for placement in ("lockstep", "compiled"):
        got = place_many(batch, [mp], placement=placement)[0]
        np.testing.assert_array_equal(got.node_type, want.node_type)
        np.testing.assert_array_equal(got.assign, want.assign)
    # every engine's plan survives the oracle after expansion
    assert check_plan(p, expand_solution(low, want)) == []
    return low


class TestEndToEndSeeded:
    """Deterministic fallback for the property suite: the same bundle,
    seeded, so CI exercises it without the 'test' extra."""

    def test_random_constraint_sets_pass_oracle_and_agree(self):
        active = 0
        for seed in range(14):
            low = _check_seed(seed)
            active += not low.identity
        # the generator must actually produce constrained instances,
        # not fall through to vacuity
        assert active >= 10

    def test_vacuous_constraints_bit_stable_vs_unconstrained(self):
        for seed in (0, 1, 2):
            p = synthetic_instance(SyntheticSpec(n=24, m=3, D=2, T=10,
                                                 seed=seed))
            q = dataclasses.replace(
                p, constraints=TaskConstraints.vacuous(p.n))
            a, b = rightsize(p), rightsize(q)
            np.testing.assert_array_equal(a.node_type, b.node_type)
            np.testing.assert_array_equal(a.assign, b.assign)
            assert a.cost(p) == b.cost(p)

    def test_fleet_engine_place_expands_constrained_plans(self):
        p, low = _constrained_instance(3)
        assert not low.identity
        eng = FleetEngine()
        mp = penalty_map(trim_timeline(low.lowered)[0], "avg")
        sol = eng.place([p], [mp])[0]
        assert sol.assign.shape == (p.n,)
        assert sol.meta.get("constrained") is True
        assert_feasible(p, sol)


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="install the 'test' extra")
class TestConstraintProperty:
    if HAVE_HYPOTHESIS:
        # example budget comes from the active profile (conftest.py)
        @given(st.integers(0, 2**31 - 1))
        def test_random_constraints_checked_and_engines_agree(self, seed):
            """Random ragged instances x random constraint sets:
            checker-verified plans, three engines bit-identical."""
            _check_seed(seed)
