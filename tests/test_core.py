"""Unit tests for the TL-Rightsizing core (paper §II-§V)."""

import numpy as np
import pytest

from repro.core import (
    NodeTypes,
    Problem,
    Solution,
    active_mask,
    congestion_lowerbound,
    evaluate,
    feasible_types,
    lp_lowerbound,
    no_timeline_lowerbound,
    penalty_map,
    penalty_matrix,
    relative_demand,
    rightsize,
    solve_lp,
    trim_timeline,
    two_phase,
    verify,
)
from repro.workload import SyntheticSpec, gct_like_instance, synthetic_instance


def small_problem():
    """A Figure-1-style instance: D=2, n=3, m=2.

    Node-type 1: cap (4, 8) cost $10; node-type 2: cap (2, 2) cost $6.
    Tasks: t1 dem (2, 3) span [0, 1]; t2 dem (2, 4) span [2, 3];
           t3 dem (1, 2) span [0, 3].
    A timeline-aware solution fits all three in ONE type-1 node ($10: t1
    and t2 never overlap); a timeline-agnostic packing needs at least $16
    (t1+t2 flat in type 1, t3 in type 2) — the paper's Fig. 1 phenomenon.
    """
    nt = NodeTypes(cap=np.array([[4.0, 8.0], [2.0, 2.0]]),
                   cost=np.array([10.0, 6.0]))
    return Problem(
        dem=np.array([[2.0, 3.0], [2.0, 4.0], [1.0, 2.0]]),
        start=np.array([0, 2, 0]),
        end=np.array([1, 3, 3]),
        node_types=nt,
        T=4,
    )


class TestProblem:
    def test_fig1_instance_valid(self):
        p = small_problem()
        assert p.n == 3 and p.m == 2 and p.D == 2

    def test_active_mask(self):
        p = small_problem()
        act = active_mask(p)
        assert act.shape == (3, 4)
        np.testing.assert_array_equal(act[0], [True, True, False, False])
        np.testing.assert_array_equal(act[2], [True, True, True, True])

    def test_trim_preserves_overlap_structure(self):
        p = small_problem()
        t, kept = trim_timeline(p)
        assert t.T == 2  # starts are {0, 2}
        # overlap pairs must be preserved by trimming
        a_full = active_mask(p)
        a_trim = active_mask(t)
        ov_full = (a_full @ a_full.T) > 0
        ov_trim = (a_trim @ a_trim.T) > 0
        np.testing.assert_array_equal(ov_full, ov_trim)

    def test_trim_idempotent(self):
        p = small_problem()
        t1, _ = trim_timeline(p)
        t2, _ = trim_timeline(t1)
        assert t1.T == t2.T
        np.testing.assert_array_equal(t1.start, t2.start)
        np.testing.assert_array_equal(t1.end, t2.end)

    def test_validation(self):
        nt = NodeTypes(cap=np.ones((1, 2)), cost=np.ones(1))
        with pytest.raises(ValueError):
            Problem(dem=np.ones((1, 2)), start=np.array([3]),
                    end=np.array([1]), node_types=nt, T=4)
        with pytest.raises(ValueError):
            NodeTypes(cap=np.zeros((1, 2)), cost=np.ones(1))

    def test_feasible_types_masks_oversize(self):
        nt = NodeTypes(cap=np.array([[1.0, 1.0], [0.1, 1.0]]),
                       cost=np.array([2.0, 1.1]))
        p = Problem(dem=np.array([[0.5, 0.5]]), start=np.array([0]),
                    end=np.array([0]), node_types=nt, T=1)
        ft = feasible_types(p)
        np.testing.assert_array_equal(ft, [[True, False]])
        # penalty mapping must avoid the infeasible (cheaper-looking) type
        assert penalty_map(p, "avg")[0] == 0

    def test_infeasible_instance_raises(self):
        nt = NodeTypes(cap=np.array([[0.1, 0.1]]), cost=np.array([1.0]))
        p = Problem(dem=np.array([[0.5, 0.5]]), start=np.array([0]),
                    end=np.array([0]), node_types=nt, T=1)
        with pytest.raises(ValueError, match="infeasible"):
            feasible_types(p)


class TestPenalty:
    def test_relative_demand_formulas(self):
        p = small_problem()
        h_avg = relative_demand(p, "avg")
        h_max = relative_demand(p, "max")
        # task 0 on type 0: (2/4 + 3/8)/2 = 0.4375 ; max = 0.5
        assert h_avg[0, 0] == pytest.approx(0.4375)
        assert h_max[0, 0] == pytest.approx(0.5)

    def test_penalty_cost_weighting(self):
        p = small_problem()
        pen = penalty_matrix(p, "avg")
        np.testing.assert_allclose(
            pen, relative_demand(p, "avg") * p.node_types.cost[None, :]
        )


class TestPlacement:
    def test_fig1_packs_single_node(self):
        """The paper's Figure 1(a): time-sharing fits everything in one
        type-1 node for $10."""
        p = small_problem()
        sol = rightsize(p, "penalty-map")
        assert sol.cost(p) == pytest.approx(10.0)
        assert sol.num_nodes == 1

    def test_no_timeline_needs_more(self):
        """Figure 1(b): with all tasks perpetually active, $10 no longer
        suffices."""
        p = small_problem()
        flat = Problem(dem=p.dem, start=np.zeros(3, np.int64),
                       end=np.zeros(3, np.int64), node_types=p.node_types,
                       T=1)
        sol = rightsize(flat, "penalty-map")
        assert sol.cost(flat) >= 16.0 - 1e-9

    def test_first_fit_prefers_earliest(self):
        nt = NodeTypes(cap=np.array([[1.0]]), cost=np.array([1.0]))
        # two nodes forced open by parallel tasks; third task fits both ->
        # must go to node 0
        p = Problem(dem=np.array([[0.6], [0.6], [0.3]]),
                    start=np.array([0, 0, 1]), end=np.array([0, 0, 1]),
                    node_types=nt, T=2)
        sol = two_phase(p, np.zeros(3, np.int64), fit="first")
        assert sol.num_nodes == 2
        assert sol.assign[2] == sol.assign[0] == 0

    def test_similarity_fit_picks_best_match(self):
        nt = NodeTypes(cap=np.array([[1.0, 1.0]]), cost=np.array([1.0]))
        # t0 (0.6,0.1) and t1 (0.5,0.6) cannot share a node (cpu 1.1 > 1),
        # so two nodes open with remainders (0.4,0.9) and (0.5,0.4).  The
        # cpu-heavy t2 (0.35,0.05) fits both; first-fit takes node 0,
        # cosine similarity prefers the cpu-heavy remainder of node 1.
        p = Problem(
            dem=np.array([[0.6, 0.1], [0.5, 0.6], [0.35, 0.05]]),
            start=np.array([0, 0, 0]),
            end=np.array([1, 1, 1]),
            node_types=nt, T=2,
        )
        solF = two_phase(p, np.zeros(3, np.int64), fit="first")
        solS = two_phase(p, np.zeros(3, np.int64), fit="similarity")
        assert solF.assign[2] == 0          # first-fit: earliest feasible
        assert solS.assign[2] == 1          # similarity: ratio match
        verify(p, solF), verify(p, solS)

    def test_all_tasks_placed_and_feasible(self):
        p = synthetic_instance(SyntheticSpec(n=150, m=6, D=4, seed=3))
        t, _ = trim_timeline(p)
        for fit in ("first", "similarity"):
            for filling in (False, True):
                sol = two_phase(t, penalty_map(t), fit=fit, filling=filling)
                verify(t, sol)


class TestLP:
    def test_lp_lower_bounds_solutions(self):
        p = synthetic_instance(SyntheticSpec(n=100, m=4, D=3, seed=5))
        t, _ = trim_timeline(p)
        lb = lp_lowerbound(t)
        for algo in ("penalty-map", "lp-map", "lp-map-f", "penalty-map-f"):
            sol = rightsize(t, algo)
            assert sol.cost(t) >= lb - 1e-6, algo

    def test_lp_mapping_sums_to_one(self):
        p = synthetic_instance(SyntheticSpec(n=60, m=4, D=2, seed=6))
        res = solve_lp(p)
        np.testing.assert_allclose(res.x.sum(axis=1), 1.0, atol=1e-6)
        assert (res.x >= -1e-9).all()

    def test_lp_alpha_matches_max_congestion(self):
        """alpha_B must equal the max fractional congestion of type B."""
        p = synthetic_instance(SyntheticSpec(n=60, m=3, D=2, seed=8))
        t, _ = trim_timeline(p)
        res = solve_lp(t)
        act = active_mask(t)  # (n, T')
        for B in range(t.m):
            w = t.dem / t.node_types.cap[B][None, :]  # (n, D)
            cong = np.einsum("nt,nd->td", act * res.x[:, B : B + 1], w)
            assert cong.max() <= res.alpha[B] + 1e-6

    def test_congestion_lb_below_lp_lb(self):
        p = synthetic_instance(SyntheticSpec(n=80, m=5, D=3, seed=9))
        t, _ = trim_timeline(p)
        assert congestion_lowerbound(t) <= lp_lowerbound(t) + 1e-6

    def test_lp_subsampled_is_relaxation(self):
        g = gct_like_instance(n=200, m=6, seed=4)
        t, _ = trim_timeline(g)
        full = solve_lp(t).objective
        sub = solve_lp(t, max_slots=50).objective
        assert sub <= full + 1e-6


class TestFilling:
    def test_filling_never_hurts(self):
        """Cross-fill only reuses already-purchased capacity: cost must be
        <= the unfilled variant on every seed."""
        for seed in range(4):
            p = synthetic_instance(SyntheticSpec(n=120, m=5, D=3, seed=seed))
            t, _ = trim_timeline(p)
            mp = penalty_map(t)
            base = two_phase(t, mp, fit="first", filling=False).cost(t)
            filled = two_phase(t, mp, fit="first", filling=True).cost(t)
            assert filled <= base + 1e-9

    def test_paper_protocol_ordering(self):
        """Paper §VI headline: LP-map-F is the best algorithm on synthetic
        instances (Fig. 7)."""
        p = synthetic_instance(SyntheticSpec(n=300, m=8, D=5, seed=11))
        res = evaluate(p)
        assert res["normalized"]["lp-map-f"] <= res["normalized"]["penalty-map"] + 1e-9
        assert res["normalized"]["lp-map-f"] <= 1.35  # paper: within ~20%


class TestNoTimeline:
    def test_no_timeline_lb_dominates(self):
        """§VI-F: treating tasks as always-active can only raise the bound."""
        p = synthetic_instance(SyntheticSpec(n=100, m=5, D=3, seed=12))
        t, _ = trim_timeline(p)
        assert no_timeline_lowerbound(t) >= lp_lowerbound(t) - 1e-6


class TestVerify:
    def test_verify_catches_violation(self):
        p = small_problem()
        bad = Solution(node_type=np.array([1]), assign=np.zeros(3, np.int64))
        with pytest.raises(AssertionError):
            verify(p, bad)
