"""Validate the trip-count-aware HLO cost accounting.

FLOP assertions are stated as analytically derived *bounds and ratios*
rather than exact constants: XLA's optimized HLO legitimately drifts
across versions (fusion choices, extra elementwise ops, rematerialized
transposes), so each test pins (a) the analytic dot-product FLOPs as a
hard lower bound and (b) trip-count structure via ratios between two
programs whose per-iteration bodies are identical — per-op accounting
constants cancel in the ratio, leaving only the trip-count multiplier
this module exists to recover.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _cost(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze(compiled.as_text())


def _scan_matmul(n_iters):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n_iters)
        return y
    return f


class TestFlops:
    def test_single_matmul_bounds(self):
        c = _cost(lambda a, b: a @ b, (128, 256), (256, 64))
        analytic = 2 * 128 * 256 * 64
        # at least the dot's FLOPs, at most a modest fusion overhead
        assert analytic <= c.flops <= 2.0 * analytic, (c.flops, analytic)

    def test_scan_multiplies_by_trip_count(self):
        """flops ratio of two scans over the SAME body == trip ratio."""
        long, short = 17, 5
        c_long = _cost(_scan_matmul(long), (64, 64), (64, 64))
        c_short = _cost(_scan_matmul(short), (64, 64), (64, 64))
        body_analytic = 2 * 64 ** 3
        assert c_long.flops >= long * body_analytic
        assert c_short.flops >= short * body_analytic
        # per-op accounting constants cancel in the ratio
        assert c_long.flops / c_short.flops == pytest.approx(
            long / short, rel=0.15)

    def test_nested_scan_matches_flat_scan(self):
        """5 x 3 nested trips cost what one 15-trip scan costs."""
        def nested(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, None, length=3)
                return ci, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y

        def flat(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=15)
            return y

        c_nested = _cost(nested, (32, 32), (32, 32))
        c_flat = _cost(flat, (32, 32), (32, 32))
        assert c_nested.flops >= 15 * 2 * 32 ** 3
        assert c_nested.flops == pytest.approx(c_flat.flops, rel=0.15)

    def test_batched_dot_scales_with_batch(self):
        c8 = _cost(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                   (8, 32, 64), (8, 64, 16))
        c2 = _cost(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                   (2, 32, 64), (2, 64, 16))
        assert c8.flops >= 2 * 8 * 32 * 64 * 16
        assert c8.flops / c2.flops == pytest.approx(4.0, rel=0.15)


class TestCollectives:
    def test_psum_bytes_counted(self):
        # env-gated skip (audited): a multi-device run needs
        # XLA_FLAGS=--xla_force_host_platform_device_count set BEFORE
        # jax initializes, which the shared test session cannot do
        # retroactively; the dryrun CLI path (which sets 512) covers
        # the multi-device parse, and test_sharded_matmul_has_
        # collectives below exercises the parser robustly at 1 device
        if jax.device_count() < 2:
            pytest.skip("needs >1 device (dryrun sets 512)")

    def test_sharded_matmul_has_collectives(self):
        # run under whatever device count the test session has; with one
        # device there are no collectives — assert the parser is robust
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        devs = np.array(jax.devices())
        mesh = Mesh(devs[:1].reshape(1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P(None, None))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=sh)
        compiled = jax.jit(lambda a: a @ a).lower(x).compile()
        c = analyze(compiled.as_text())
        assert c.flops > 0
        assert all(v >= 0 for v in c.collective_bytes.values())


class TestTraffic:
    def test_traffic_at_least_io(self):
        c = _cost(lambda a, b: a @ b, (256, 256), (256, 256))
        io_bytes = 3 * 256 * 256 * 4
        assert c.traffic_bytes >= io_bytes * 0.9
