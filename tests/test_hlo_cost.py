"""Validate the trip-count-aware HLO cost accounting against analytic
FLOP counts on jitted programs with known structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _cost(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze(compiled.as_text())


class TestFlops:
    def test_single_matmul(self):
        c = _cost(lambda a, b: a @ b, (128, 256), (256, 64))
        want = 2 * 128 * 256 * 64
        assert abs(c.flops - want) / want < 0.05, (c.flops, want)

    def test_scan_multiplies_by_trip_count(self):
        n_iters = 17

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=n_iters)
            return y

        c = _cost(f, (64, 64), (64, 64))
        want = n_iters * 2 * 64 ** 3
        assert abs(c.flops - want) / want < 0.1, (c.flops, want)

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, None, length=3)
                return ci, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y

        c = _cost(f, (32, 32), (32, 32))
        want = 15 * 2 * 32 ** 3
        assert abs(c.flops - want) / want < 0.15, (c.flops, want)

    def test_batched_dot(self):
        c = _cost(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                  (8, 32, 64), (8, 64, 16))
        want = 2 * 8 * 32 * 64 * 16
        assert abs(c.flops - want) / want < 0.05, (c.flops, want)


class TestCollectives:
    def test_psum_bytes_counted(self):
        if jax.device_count() < 2:
            pytest.skip("needs >1 device (dryrun sets 512)")

    def test_sharded_matmul_has_collectives(self):
        # run under whatever device count the test session has; with one
        # device there are no collectives — assert the parser is robust
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        devs = np.array(jax.devices())
        mesh = Mesh(devs[:1].reshape(1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P(None, None))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=sh)
        compiled = jax.jit(lambda a: a @ a).lower(x).compile()
        c = analyze(compiled.as_text())
        assert c.flops > 0
        assert all(v >= 0 for v in c.collective_bytes.values())


class TestTraffic:
    def test_traffic_at_least_io(self):
        c = _cost(lambda a, b: a @ b, (256, 256), (256, 256))
        io_bytes = 3 * 256 * 256 * 4
        assert c.traffic_bytes >= io_bytes * 0.9
