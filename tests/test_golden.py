"""Golden-table regression: ``evaluate_many`` on a fixed-seed synthetic
grid must reproduce the committed snapshot under ``results/golden/``.

The snapshot pins the §VI protocol numbers (per-instance costs, LP lower
bounds and normalized ratios) end-to-end through the batched LP solve
AND the batched lockstep placement, so future solver or placement
refactors cannot silently shift paper-table numbers: an intentional
change must regenerate the snapshot (see the module-level docstring of
the generating grid inside the JSON) and justify the diff.
"""

import json
import pathlib

import pytest

from repro.core import evaluate_many
from repro.workload import SyntheticSpec, sweep_specs, synthetic_batch

GOLDEN = pathlib.Path(__file__).resolve().parent.parent \
    / "results" / "golden" / "evaluate_many.json"

# penalty-map costs are pure float64 numpy (bitwise stable); the LP-side
# numbers ride on fp32 XLA reductions, so allow library-level drift —
# any real regression (a flipped mapping / placement) moves costs by a
# whole node price, orders of magnitude above this tolerance.
REL = 1e-5


def _grid():
    specs = sweep_specs(SyntheticSpec(n=60, m=4, D=3, T=16), seeds=2,
                        n=(40, 60, 80))
    return synthetic_batch(specs)


class TestGoldenEvaluateMany:
    def test_matches_snapshot(self):
        want = json.loads(GOLDEN.read_text())
        entries = evaluate_many(_grid(), lp_iters=want["lp_iters"])
        assert len(entries) == len(want["entries"])
        for got, ref in zip(entries, want["entries"]):
            assert got["lb"] == pytest.approx(ref["lb"], rel=REL)
            assert set(got["costs"]) == set(ref["costs"])
            for algo, cost in ref["costs"].items():
                assert got["costs"][algo] == pytest.approx(
                    cost, rel=REL), algo
                assert got["normalized"][algo] == pytest.approx(
                    ref["normalized"][algo], rel=REL), algo

    def test_snapshot_sanity(self):
        """The committed snapshot itself is internally consistent."""
        want = json.loads(GOLDEN.read_text())
        for ref in want["entries"]:
            for algo, cost in ref["costs"].items():
                assert ref["normalized"][algo] == pytest.approx(
                    cost / ref["lb"], rel=1e-9)
            # filling never hurts; the LP map beats PenaltyMap here
            assert ref["costs"]["lp-map-f"] <= ref["costs"]["lp-map"]
            assert ref["costs"]["penalty-map-f"] \
                <= ref["costs"]["penalty-map"]
