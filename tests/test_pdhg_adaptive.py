"""Adaptive restarted PDHG engine battery (PDLP-style tol mode).

Covers: tolerance-stopped convergence with a certified duality-gap
certificate, per-lane independence of the batched adaptive state,
adaptive+restart dominating fixed-step vanilla (equal-or-fewer
iterations at no-worse objective), warm starts (same-batch re-solve,
neighbor solves matching cold-start costs, shape re-alignment), the
warm-started sweep acceptance gate (>=2x fewer total iterations than
vanilla at identical protocol costs), telemetry plumbing through
``evaluate_many``, and the CI convergence-regression gate logic.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core import (
    FIT_POLICIES,
    evaluate_many,
    pack_problems,
    solve_lp_many,
    solve_lp_pdhg,
    solve_lp_sweep,
    trim_timeline,
    two_phase,
)
from repro.core.batch import DEFAULT_CHECK_EVERY, DEFAULT_TOL
from repro.workload import SyntheticSpec, synthetic_batch, \
    synthetic_instance

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # the 'test' extra is not installed; suites skip
    _HAVE_HYPOTHESIS = False

TOL = DEFAULT_TOL
CAP = 8000        # worst-case iteration cap; tol stops far earlier
CHECK = DEFAULT_CHECK_EVERY  # iteration counts quantize to this

GOLDEN_STATS = pathlib.Path(__file__).resolve().parent.parent \
    / "results" / "golden" / "solver_stats.json"


def _inst(seed=0, n=60, m=5, D=3, T=14):
    p = synthetic_instance(SyntheticSpec(n=n, m=m, D=D, T=T, seed=seed))
    return trim_timeline(p)[0]


def _proto_cost(t, mapping):
    """The §VI lp-map-f protocol entry: best fit policy, with filling."""
    return min(two_phase(t, mapping, fit=f, filling=True).cost(t)
               for f in FIT_POLICIES)


def _gap_slack(res):
    """The provable objective slack of a tol-converged solve: both primal
    and dual are kept feasible, so objective - optimum <= tol * (1 +
    |primal| + |dual|)."""
    return TOL * (1.0 + abs(res.objective) + abs(res.lower_bound))


class TestToleranceStopping:
    def test_converges_with_certificate(self):
        res, stats = solve_lp_many([_inst(0)], iters=CAP, tol=TOL,
                                   full_output=True)
        r = res[0]
        assert r.converged
        assert r.kkt <= TOL
        assert r.lower_bound <= r.objective  # weak duality certificate
        assert 0 < r.iters < CAP
        assert stats.iterations[0] == r.iters
        assert stats.tol == TOL

    @pytest.mark.parametrize("cap", [2 * CHECK, CHECK + 10, 10])
    def test_cap_reported_honestly(self, cap):
        """An unreachable tolerance must come back converged=False with
        iters == the cap — exactly, even when the cap is not a multiple
        of the check interval (the final chunk shrinks)."""
        res, stats = solve_lp_many([_inst(0)], iters=cap, tol=1e-12,
                                   full_output=True)
        assert not res[0].converged
        assert res[0].iters == cap
        assert not stats.converged.any()

    def test_legacy_fixed_path_fields(self):
        r = solve_lp_pdhg(_inst(0), iters=200)
        assert r.iters == 200
        assert r.converged and r.restarts == 0

    @pytest.mark.parametrize("seed", range(4))
    def test_adaptive_dominates_vanilla(self, seed):
        """Adaptive+restart reaches tolerance in equal-or-fewer
        iterations than fixed-step vanilla, at a no-worse objective
        (within the provable tol slack)."""
        t = _inst(seed)
        res_v, st_v = solve_lp_many([t], iters=CAP, tol=TOL,
                                    adaptive=False, restart=False,
                                    full_output=True)
        res_a, st_a = solve_lp_many([t], iters=CAP, tol=TOL,
                                    full_output=True)
        assert st_a.iterations[0] <= st_v.iterations[0]
        assert res_a[0].objective \
            <= res_v[0].objective + _gap_slack(res_a[0])

    def test_single_type_lane_stays_finite(self):
        """m=1 pins x completely, so the ratio test's interaction term
        is identically zero — the step size must fall through to pure
        growth (never inf * 0 = NaN) and the lane must still converge,
        with a finite warm-startable eta."""
        from repro.core import NodeTypes, Problem

        rng = np.random.default_rng(0)
        n, D, T = 30, 3, 10
        a, b = rng.integers(0, T, n), rng.integers(0, T, n)
        p = Problem(dem=rng.uniform(0.01, 0.1, (n, D)),
                    start=np.minimum(a, b), end=np.maximum(a, b),
                    node_types=NodeTypes(cap=rng.uniform(0.5, 1.0, (1, D)),
                                         cost=np.array([1.0])), T=T)
        res, stats = solve_lp_many([p], iters=500, tol=TOL,
                                   full_output=True)
        assert res[0].converged
        assert np.isfinite(stats.state.eta).all()
        # and the degenerate lane warm-starts cleanly
        res2, stats2 = solve_lp_sweep([[p], [p]], tol=TOL, iters=500)
        assert all(s.converged.all() for s in stats2)

    def test_lanes_adapt_independently(self):
        """Per-lane step/restart/convergence state: each instance's
        telemetry in a ragged batch matches its solo solve (converged
        lanes freeze while stragglers keep iterating)."""
        probs = [_inst(s, n=40 + 25 * s, T=10 + 4 * s) for s in range(3)]
        _, st_b = solve_lp_many(probs, iters=CAP, tol=TOL,
                                full_output=True)
        assert st_b.converged.all()
        for i, p in enumerate(probs):
            _, st_s = solve_lp_many([p], iters=CAP, tol=TOL,
                                    full_output=True)
            # identical up to one check interval of padding float noise
            assert abs(int(st_s.iterations[0])
                       - int(st_b.iterations[i])) <= CHECK
            assert st_s.kkt[0] <= TOL and st_b.kkt[i] <= TOL


class TestWarmStart:
    def test_resolve_same_batch_converges_immediately(self):
        probs = [_inst(s) for s in range(3)]
        _, st = solve_lp_many(probs, iters=CAP, tol=TOL, full_output=True)
        res2, st2 = solve_lp_many(probs, iters=CAP, tol=TOL,
                                  init=st.state, full_output=True)
        assert st2.converged.all()
        assert (st2.iterations <= CHECK).all()  # one check interval

    def test_warm_neighbor_matches_cold_costs(self):
        """Warm-starting a neighboring sweep point (larger n and T, so
        the state is re-aligned across padded shapes) must converge in
        no more total iterations and certify the same LP costs within
        the provable tolerance slack."""
        a = [_inst(s, n=60, T=14) for s in range(3)]
        b = [_inst(s, n=72, T=16) for s in range(3)]
        _, st_a = solve_lp_many(a, iters=CAP, tol=TOL, full_output=True)
        cold, st_c = solve_lp_many(b, iters=CAP, tol=TOL,
                                   full_output=True)
        warm, st_w = solve_lp_many(b, iters=CAP, tol=TOL,
                                   init=st_a.state, full_output=True)
        assert st_w.converged.all()
        assert int(st_w.iterations.sum()) <= int(st_c.iterations.sum())
        for rc, rw in zip(cold, warm):
            assert abs(rw.objective - rc.objective) \
                <= _gap_slack(rc) + _gap_slack(rw)

    def test_warm_start_requires_matching_batch_size(self):
        probs = [_inst(s) for s in range(3)]
        _, stats = solve_lp_many(probs, iters=CAP, tol=TOL,
                                 full_output=True)
        with pytest.raises(ValueError, match="batch size"):
            solve_lp_many(probs[:2], iters=CAP, tol=TOL, init=stats.state)

    def test_evaluate_many_warm_start_needs_tol(self):
        with pytest.raises(ValueError, match="warm_start"):
            evaluate_many([_inst(0)], warm_start=1)


class TestSweepAcceptance:
    """The PR acceptance gate: on a quick fleet_sweep-style grid, the
    adaptive restarted engine with warm-started grid-adjacent sweep
    ordering reaches the default tolerance in >=2x fewer total
    iterations than fixed-step vanilla PDHG, at protocol-cost parity —
    certified LP objectives within the provable tol slack on every
    instance, total protocol cost within 1.5% (per-instance cost is
    two-sided rounding noise on degenerate instances: either engine can
    land on a different epsilon-optimal vertex, so identity is pinned in
    aggregate, like the benchmark gate does)."""

    SHAPES, SEEDS = 6, 3

    def _grid(self):
        specs = [SyntheticSpec(n=40 + 12 * i, m=5, D=4, T=12 + i, seed=s)
                 for i in range(self.SHAPES) for s in range(self.SEEDS)]
        problems = [trim_timeline(p)[0] for p in synthetic_batch(specs)]
        groups = [problems[i * self.SEEDS : (i + 1) * self.SEEDS]
                  for i in range(self.SHAPES)]
        return problems, groups

    def test_warm_sweep_2x_fewer_iters_at_cost_parity(self):
        problems, groups = self._grid()
        res_v, st_v = solve_lp_many(problems, iters=CAP, tol=TOL,
                                    adaptive=False, restart=False,
                                    full_output=True)
        res_w, stats_w = solve_lp_sweep(groups, tol=TOL, iters=CAP)
        assert st_v.converged.all()
        assert all(s.converged.all() for s in stats_w)
        total_v = int(st_v.iterations.sum())
        total_w = sum(int(s.iterations.sum()) for s in stats_w)
        assert total_v >= 2 * total_w, (
            f"warm-started adaptive sweep took {total_w} total iterations "
            f"vs vanilla's {total_v} (< 2x reduction)")
        # certified LP cost parity (provable given both converged)
        for rv, rw in zip(res_v, res_w):
            assert abs(rw.objective - rv.objective) \
                <= _gap_slack(rv) + _gap_slack(rw)
        # aggregate protocol-cost parity
        cost_v = [_proto_cost(t, r.mapping)
                  for t, r in zip(problems, res_v)]
        cost_w = [_proto_cost(t, r.mapping)
                  for t, r in zip(problems, res_w)]
        drift = abs(sum(cost_w) - sum(cost_v)) / sum(cost_v)
        assert drift <= 0.015, (
            f"total protocol cost drifted {100 * drift:.2f}% between "
            f"vanilla and warm-started adaptive solves")

    def test_committed_telemetry_baseline_passes_its_own_gate(self):
        """The CI convergence gate must run green on the committed
        baseline (the acceptance numbers are pinned in-repo)."""
        from benchmarks.check_convergence import check

        base = json.loads(GOLDEN_STATS.read_text())
        assert base["iter_reduction_vs_vanilla"] >= 2.0
        assert base["lp_obj_within_slack"]
        # two-sided 2% budget: the canonical rounding's cheapest-vertex
        # rule makes tol mode slightly cheaper than vanilla's argmax
        assert abs(base["cost_drift_pct"]) <= 2.0
        assert base["warm"]["converged_frac"] == 1.0
        assert check(base, base, 0.25, 2.0, 2.0) == []


class TestConvergenceGate:
    def _stats(self, median_iters=100.0, median_kkt=2e-3, max_kkt=4e-3,
               converged=1.0, reduction=3.0, slack=True, drift=0.2,
               total_iters=None):
        blk = {"median_iters": median_iters, "median_kkt": median_kkt,
               "max_kkt": max_kkt, "converged_frac": converged,
               "total_iters": (int(median_iters * 10)
                               if total_iters is None else total_iters)}
        return {"tol": TOL, "check_every": CHECK, "warm": blk,
                "iter_reduction_vs_vanilla": reduction,
                "lp_obj_within_slack": slack,
                "cost_drift_pct": drift}

    def test_pass_and_fail_modes(self):
        from benchmarks.check_convergence import check

        base = self._stats()
        assert check(self._stats(), base, 0.25, 2.0, 2.0) == []
        # within the 25% budget (+ one check-interval quantum of slack
        # on the median, so a single quantized shift never trips it)
        assert check(self._stats(median_iters=150.0,
                                 total_iters=1250), base,
                     0.25, 2.0, 2.0) == []
        # median beyond budget + quantum
        assert check(self._stats(median_iters=160.0), base,
                     0.25, 2.0, 2.0)
        # total iterations regressed even though the median held
        assert check(self._stats(total_iters=2000), base,
                     0.25, 2.0, 2.0)
        # KKT above tolerance
        assert check(self._stats(max_kkt=2 * TOL), base, 0.25, 2.0, 2.0)
        # lost the 2x advantage
        assert check(self._stats(reduction=1.5), base, 0.25, 2.0, 2.0)
        # certified objectives outside the provable slack
        assert check(self._stats(slack=False), base, 0.25, 2.0, 2.0)
        # protocol-cost drift beyond the parity budget
        assert check(self._stats(drift=-2.7), base, 0.25, 2.0, 2.0)
        # ...but the cheapest-vertex rounding's slight cost advantage
        # stays inside the two-sided 2% budget
        assert check(self._stats(drift=-1.7), base, 0.25, 2.0, 2.0) == []
        # a lane stopped converging
        assert check(self._stats(converged=0.9), base, 0.25, 2.0, 2.0)


class TestTelemetryPlumbing:
    def test_evaluate_many_entries_carry_solver_stats(self):
        probs = [_inst(s, n=40) for s in range(4)]
        entries, stats = evaluate_many(
            probs, algos=("lp-map-f",), lp_iters=CAP, lp_tol=TOL,
            warm_start=2, return_stats=True)
        assert len(entries) == 4
        assert len(stats) == 2  # one SolveStats per warm-started group
        for e in entries:
            s = e["solver"]
            assert s["converged"] and s["kkt"] <= TOL and s["iters"] > 0
        merged = np.concatenate([s.iterations for s in stats])
        assert [e["solver"]["iters"] for e in entries] \
            == [int(i) for i in merged]

    def test_legacy_entries_have_no_solver_block(self):
        entries = evaluate_many([_inst(0)], algos=("lp-map",),
                                lp_iters=150)
        assert "solver" not in entries[0]

    def test_stats_summary_shape(self):
        _, stats = solve_lp_many([_inst(s) for s in range(3)], iters=CAP,
                                 tol=TOL, full_output=True)
        s = stats.summary()
        assert s["converged_frac"] == 1.0
        assert s["total_iters"] >= s["max_iters"] >= s["median_iters"]
        assert s["max_kkt"] <= TOL
        assert stats.state.x.shape[0] == 3


if _HAVE_HYPOTHESIS:
    # fixed padded shapes (pack_problems pad_to) so every example reuses
    # one compiled solve per engine instead of recompiling per draw
    _PAD = (48, 4, 3, 12)

    def _rand_inst(seed):
        return pack_problems(
            [synthetic_instance(SyntheticSpec(n=48, m=4, D=3, T=12,
                                              seed=seed))],
            pad_to=_PAD)

    class TestRandomInstanceProperties:
        @given(seed=st.integers(0, 2**31 - 1))
        @settings(deadline=None)
        def test_adaptive_dominates_vanilla_everywhere(self, seed):
            batch = _rand_inst(seed)
            res_v, st_v = solve_lp_many(batch, iters=CAP, tol=TOL,
                                        adaptive=False, restart=False,
                                        full_output=True)
            res_a, st_a = solve_lp_many(batch, iters=CAP, tol=TOL,
                                        full_output=True)
            assert st_a.converged.all() and st_v.converged.all()
            assert st_a.iterations[0] <= st_v.iterations[0]
            assert res_a[0].objective \
                <= res_v[0].objective + _gap_slack(res_a[0])

        @given(seed=st.integers(0, 2**31 - 1))
        @settings(deadline=None)
        def test_warm_start_matches_cold_within_tolerance(self, seed):
            batch = _rand_inst(seed)
            neighbor = pack_problems(
                [synthetic_instance(SyntheticSpec(n=44, m=4, D=3, T=12,
                                                  seed=seed + 1))],
                pad_to=_PAD)
            _, st0 = solve_lp_many(batch, iters=CAP, tol=TOL,
                                   full_output=True)
            cold, st_c = solve_lp_many(neighbor, iters=CAP, tol=TOL,
                                       full_output=True)
            warm, st_w = solve_lp_many(neighbor, iters=CAP, tol=TOL,
                                       init=st0.state, full_output=True)
            assert st_w.converged.all()
            assert abs(warm[0].objective - cold[0].objective) \
                <= _gap_slack(cold[0]) + _gap_slack(warm[0])
