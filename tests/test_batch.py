"""Batched fleet-sweep engine tests: pad-and-stack exactness, the
batch-dim-aware Pallas congestion kernel vs its oracle, batched-vs-looped
LP parity on ragged grids, and the benchmark-smoke acceptance gate
(identical costs + >=5x LP-phase wall-clock on a B=32 quick-scale grid).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    evaluate,
    evaluate_many,
    pack_problems,
    solve_lp,
    solve_lp_many,
    solve_lp_pdhg,
    trim_timeline,
    two_phase,
    verify,
)
from repro.core.batch import _make_operators
from repro.kernels import ops
from repro.workload import SyntheticSpec, sweep_specs, synthetic_batch, \
    synthetic_instance

RNG = np.random.default_rng(7)


def _ragged_problems():
    """Mixed (n, m, D, T) instances — the ragged-batch fixture."""
    shapes = [(50, 3, 2, 12), (80, 5, 4, 24), (30, 2, 3, 8),
              (120, 6, 5, 30), (64, 4, 2, 16)]
    return [synthetic_instance(SyntheticSpec(n=n, m=m, D=D, T=T, seed=s))
            for s, (n, m, D, T) in enumerate(shapes)]


class TestPack:
    def test_padding_invariants(self):
        problems = _ragged_problems()
        batch = pack_problems(problems)
        trimmed = [trim_timeline(p)[0] for p in problems]
        assert batch.B == len(problems)
        assert batch.n == max(t.n for t in trimmed)
        assert batch.m == max(t.m for t in trimmed)
        assert batch.D == max(t.D for t in trimmed)
        assert batch.Tp == max(t.T for t in trimmed)
        w = batch.weights()
        for b, t in enumerate(trimmed):
            # real coordinates survive verbatim
            np.testing.assert_array_equal(batch.dem[b, : t.n, : t.D], t.dem)
            np.testing.assert_array_equal(batch.start[b, : t.n], t.start)
            np.testing.assert_array_equal(batch.end[b, : t.n], t.end)
            np.testing.assert_array_equal(
                batch.cap[b, : t.m, : t.D], t.node_types.cap)
            # padded tasks/types/dims carry zero operator weight
            assert (w[b, t.n :, :, :] == 0).all()
            assert (w[b, :, t.m :, :] == 0).all()
            assert (w[b, :, :, t.D :] == 0).all()
            # padded types are never feasible, padded tasks always are
            assert not batch.feas[b, :, t.m :].any()
            assert batch.feas[b, t.n :, : t.m].all()
            assert batch.feas[b].any(axis=1).all()

    def test_rejects_empty(self):
        with pytest.raises(ValueError):
            pack_problems([])

    def test_pad_to_minimum_dims_is_exact(self):
        """Padding past the natural max dims (the warm-started sweep's
        common-shape packing) must not move any LP result."""
        problems = _ragged_problems()[:3]
        batch = pack_problems(problems, pad_to=(200, 8, 6, 40))
        assert (batch.n, batch.m, batch.D, batch.Tp) == (200, 8, 6, 40)
        tight = solve_lp_many(problems, iters=200)
        padded = solve_lp_many(batch, iters=200)
        for a, b in zip(tight, padded):
            np.testing.assert_array_equal(a.mapping, b.mapping)
            assert b.objective == pytest.approx(a.objective, rel=1e-5)
            assert b.lower_bound == pytest.approx(a.lower_bound, rel=1e-5)


class TestBatchedCongestionKernel:
    @pytest.mark.parametrize("G,n,K,T", [
        (1, 1, 1, 1),
        (3, 7, 3, 24),        # sub-block everything
        (2, 128, 128, 128),   # exact block boundary
        (5, 300, 10, 130),    # off-block, many instances
    ])
    def test_matches_ref(self, G, n, K, T):
        start = RNG.integers(0, T, (G, n))
        end = np.minimum(start + RNG.integers(0, max(T // 2, 1), (G, n)),
                         T - 1)
        w = RNG.random((G, n, K)).astype(np.float32)
        out = np.asarray(ops.congestion_many(start, end, w, T))
        want = np.asarray(ops.congestion_many(start, end, w, T,
                                              use_ref=True))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_small_block_sizes(self):
        from repro.kernels.congestion import congestion_many_pallas
        from repro.kernels.ref import congestion_many_ref

        G, n, K, T = 3, 40, 6, 50
        start = RNG.integers(0, T, (G, n)).astype(np.int32)
        end = np.minimum(start + RNG.integers(0, 20, (G, n)),
                         T - 1).astype(np.int32)
        w = RNG.random((G, n, K)).astype(np.float32)
        out = np.asarray(congestion_many_pallas(
            jnp.asarray(start), jnp.asarray(end), jnp.asarray(w), T,
            block_t=8, block_n=16, block_k=8, interpret=True))
        want = np.asarray(congestion_many_ref(
            jnp.asarray(start), jnp.asarray(end), jnp.asarray(w), T))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_groups_are_independent(self):
        """Each grid-over-B group must see only its own instance."""
        n, K, T = 20, 4, 16
        start = RNG.integers(0, T, (1, n))
        end = np.minimum(start + RNG.integers(0, 8, (1, n)), T - 1)
        w = RNG.random((1, n, K)).astype(np.float32)
        alone = np.asarray(ops.congestion_many(start, end, w, T))
        # stack with a decoy instance on either side
        start3 = np.concatenate([start + 1, start, start], 0)
        end3 = np.concatenate([end, end, np.minimum(end + 3, T - 1)], 0)
        w3 = np.concatenate([w * 2, w, w + 1], 0)
        stacked = np.asarray(ops.congestion_many(start3, end3, w3, T))
        np.testing.assert_allclose(stacked[1], alone[0], rtol=1e-6,
                                   atol=1e-6)


class TestOperatorForms:
    def test_adjointness_all_forms(self):
        """<fwd(x), y> == <x, adj(y)> for dense, cumsum and pallas forms."""
        batch = pack_problems(_ragged_problems()[:3])
        w = jnp.asarray(batch.weights(), jnp.float32)
        start, end = jnp.asarray(batch.start), jnp.asarray(batch.end)
        B, n, m, D = w.shape
        x = jnp.asarray(RNG.random((B, n, m)), jnp.float32)
        y = jnp.asarray(RNG.random((B, batch.Tp, m, D)), jnp.float32)
        vals = {}
        for op in ("dense", "cumsum", "pallas"):
            fwd, adj = _make_operators(w, start, end, batch.Tp, op)
            lhs = float(jnp.sum(fwd(x) * y))
            rhs = float(jnp.sum(x * adj(y)))
            assert abs(lhs - rhs) / max(abs(lhs), 1e-9) < 1e-4, (op, lhs, rhs)
            vals[op] = lhs
        assert abs(vals["dense"] - vals["cumsum"]) < 1e-3 * abs(vals["dense"])
        assert abs(vals["dense"] - vals["pallas"]) < 1e-3 * abs(vals["dense"])


class TestSolveLPMany:
    def test_identical_copies_match_single(self):
        t, _ = trim_timeline(synthetic_instance(
            SyntheticSpec(n=80, m=4, D=3, seed=3)))
        single = solve_lp_pdhg(t, iters=400)
        for res in solve_lp_many([t, t, t], iters=400):
            np.testing.assert_array_equal(res.mapping, single.mapping)
            assert res.objective == pytest.approx(single.objective, rel=1e-6)
            assert res.lower_bound == pytest.approx(single.lower_bound,
                                                    rel=1e-6)

    def test_ragged_matches_per_instance_loop(self):
        problems = _ragged_problems()
        batched = solve_lp_many(problems, iters=600)
        for p, res in zip(problems, batched):
            t, _ = trim_timeline(p)
            ref = solve_lp_pdhg(t, iters=600)
            np.testing.assert_array_equal(res.mapping, ref.mapping)
            assert res.objective == pytest.approx(ref.objective, rel=1e-5)
            assert res.lower_bound == pytest.approx(ref.lower_bound,
                                                    rel=1e-5)
            assert res.x.shape == (t.n, t.m)

    def test_operator_forms_agree_end_to_end(self):
        problems = _ragged_problems()[:3]
        by_op = {op: solve_lp_many(problems, iters=120, operator=op)
                 for op in ("dense", "cumsum", "pallas")}
        for a, b, c in zip(*by_op.values()):
            assert a.objective == pytest.approx(b.objective, rel=1e-4)
            assert a.objective == pytest.approx(c.objective, rel=1e-4)
            np.testing.assert_array_equal(a.mapping, b.mapping)
            np.testing.assert_array_equal(a.mapping, c.mapping)

    def test_bounds_bracket_exact_lp(self):
        """Dual stays below, primal above, the HiGHS optimum; gap small."""
        problems = [synthetic_instance(SyntheticSpec(n=100, m=4, D=3,
                                                     seed=s))
                    for s in range(3)]
        batched = solve_lp_many(problems, iters=2500)
        for p, res in zip(problems, batched):
            t, _ = trim_timeline(p)
            exact = solve_lp(t).objective
            assert res.lower_bound <= exact * (1 + 1e-3)
            assert res.objective >= exact * (1 - 1e-3)
            assert (res.objective - res.lower_bound) < 0.08 * exact

    def test_mappings_are_placeable(self):
        problems = _ragged_problems()
        for p, res in zip(problems, solve_lp_many(problems, iters=300)):
            t, _ = trim_timeline(p)
            sol = two_phase(t, res.mapping, fit="first")
            verify(t, sol)


class TestEvaluateMany:
    def test_matches_looped_evaluate_on_ragged_grid(self):
        """Batched protocol == per-instance loop: costs identical."""
        specs = sweep_specs(SyntheticSpec(n=60, m=4, D=3, T=16), seeds=2,
                            n=(40, 60), D=(2, 3))
        problems = synthetic_batch(specs) + _ragged_problems()[:2]
        algos = ("lp-map", "lp-map-f")
        many = evaluate_many(problems, algos=algos, lp_iters=400)
        for p, got in zip(problems, many):
            want = evaluate(p, algos=algos, lp_solver="pdhg", lp_iters=400)
            assert got["costs"] == want["costs"]
            assert got["lb"] == pytest.approx(want["lb"], rel=1e-5)
            for a in algos:
                assert got["normalized"][a] == pytest.approx(
                    want["normalized"][a], rel=1e-5)

    def test_sweep_specs_grid(self):
        specs = sweep_specs(SyntheticSpec(n=10), seeds=2, D=(2, 3),
                            m=(4, 5))
        assert len(specs) == 8
        assert [(s.D, s.m, s.seed) for s in specs[:4]] == [
            (2, 4, 0), (2, 4, 1), (2, 5, 0), (2, 5, 1)]
        with pytest.raises(ValueError):
            sweep_specs(SyntheticSpec(), seeds=1, bogus=(1, 2))


class TestBenchmarkSmoke:
    """The acceptance gate: a B=32 quick-scale synthetic sweep grid must
    cost-match the per-instance loop and beat it >=5x on LP wall-clock.

    The grid is ragged (12 distinct (n, T) shapes x 2-3 seeds), exactly
    like the paper's Table-I sweeps — the per-instance loop pays a fresh
    JIT compile per distinct shape, the batched engine compiles its one
    padded shape; both are timed cold (caches cleared) on the same grid.
    """

    def _grid(self):
        specs = [SyntheticSpec(n=50 + 15 * i, m=5, D=4, T=12 + i, seed=s)
                 for i in range(12) for s in range(3)][:32]
        problems = [trim_timeline(p)[0] for p in synthetic_batch(specs)]
        assert len(problems) == 32
        return problems

    def test_costs_identical_and_lp_5x_faster(self):
        problems = self._grid()
        iters = 300

        jax.clear_caches()
        t0 = time.perf_counter()
        batched = solve_lp_many(problems, iters=iters)
        t_batch = time.perf_counter() - t0

        jax.clear_caches()
        t0 = time.perf_counter()
        looped = [solve_lp_pdhg(p, iters=iters) for p in problems]
        t_loop = time.perf_counter() - t0

        # identical LP mappings -> identical placements -> identical costs
        for p, rb, rl in zip(problems, batched, looped):
            np.testing.assert_array_equal(rb.mapping, rl.mapping)
            cb = two_phase(p, rb.mapping, fit="first",
                           filling=True).cost(p)
            cl = two_phase(p, rl.mapping, fit="first",
                           filling=True).cost(p)
            assert cb == cl

        speedup = t_loop / max(t_batch, 1e-9)
        assert speedup >= 5.0, (
            f"batched {t_batch:.2f}s vs looped {t_loop:.2f}s "
            f"-> {speedup:.1f}x (< 5x)")
