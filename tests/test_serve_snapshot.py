"""Checkpoint/recovery tests: exact snapshot round-trips (fleets,
plans, costs, warm ``PDHGState`` chains, queue, telemetry), identical
next-tick behavior after restore, crash-and-recover replay parity with
an uninterrupted run, loud failures on corrupt/missing/mismatched
snapshots, the ``serve --checkpoint/--restore`` CLI loop, and a
Hypothesis property over random ragged fleets (skipped where
``hypothesis`` is not installed, like ``tests/test_properties.py``).
"""

import json
import os

import numpy as np
import pytest

from repro.serve import (
    Request,
    RightsizingService,
    ServiceConfig,
    SnapshotError,
    TraceSpec,
    corrupt_snapshot,
    gct_trace,
    replay,
    replay_with_crash,
)
from repro.workload.gct import gct_like_instance


def _admit(fleet, n=8, m=3, seed=0):
    p = gct_like_instance(n=n, m=m, seed=seed)
    return Request(fleet=fleet, kind="admit", dem=p.dem, start=p.start,
                   end=p.end, node_types=p.node_types, T=p.T)


def _busy_service():
    """A service with live warm state, pending work, and telemetry."""
    svc = RightsizingService(config=ServiceConfig(shape_quantum=4))
    svc.submit(_admit("a", n=8, seed=1))
    svc.submit(_admit("b", n=11, seed=2))
    svc.tick()
    svc.tick()
    svc.submit(Request(fleet="a", kind="replan"))
    svc.tick()
    svc.submit(Request(fleet="b", kind="burst", ids=(0, 1), factor=1.4))
    svc.submit(Request(fleet="a", kind="replan", deadline_s=60.0))
    return svc


def _assert_equal_state(a: RightsizingService, b: RightsizingService):
    assert a.fleets == b.fleets
    assert a._tick == b._tick
    assert a.queue.pending == b.queue.pending
    for name in a.fleets:
        fa, fb = a._fleets[name], b._fleets[name]
        np.testing.assert_array_equal(fa.problem.dem, fb.problem.dem)
        np.testing.assert_array_equal(fa.ids, fb.ids)
        assert fa.next_id == fb.next_id
        np.testing.assert_array_equal(fa.plan, fb.plan)
        assert fa.plan_cost == fb.plan_cost          # exact, not approx
        assert (fa.warm is None) == (fb.warm is None)
        if fa.warm is not None:
            np.testing.assert_array_equal(fa.warm.x, fb.warm.x)
            np.testing.assert_array_equal(fa.warm.y, fb.warm.y)
            assert fa.warm.eta == fb.warm.eta
            assert fa.warm.omega == fb.warm.omega
            np.testing.assert_array_equal(fa.warm.ids, fb.warm.ids)
            np.testing.assert_array_equal(fa.warm.kept, fb.warm.kept)
    ra, rb = a.report(), b.report()
    for key in ("ticks", "requests", "total_cost", "proposed_cost_total",
                "warm_lanes", "cold_lanes", "events", "shed",
                "retries", "quarantined"):
        assert ra[key] == rb[key], key


class TestRoundTrip:
    def test_snapshot_restore_is_exact(self, tmp_path):
        svc = _busy_service()
        manifest = svc.snapshot(str(tmp_path / "snap"))
        assert manifest["version"] == 1
        restored = RightsizingService.restore(str(tmp_path / "snap"))
        _assert_equal_state(svc, restored)

    def test_next_tick_identical_after_restore(self, tmp_path):
        svc = _busy_service()
        svc.snapshot(str(tmp_path / "snap"))
        restored = RightsizingService.restore(str(tmp_path / "snap"))
        ra, rb = svc.tick(), restored.tick()
        # the warm lane re-enters PDHG from bit-identical state: same
        # modes, same iteration counts, same adopted costs
        assert ra.warm_lanes == rb.warm_lanes
        assert ra.iters == rb.iters
        assert ra.fleets == rb.fleets
        _assert_equal_state(svc, restored)

    def test_snapshot_is_rewritable_and_config_overridable(self, tmp_path):
        svc = _busy_service()
        path = str(tmp_path / "snap")
        svc.snapshot(path)
        svc.tick()
        svc.snapshot(path)                    # overwrite in place
        restored = RightsizingService.restore(
            path, config=ServiceConfig(shape_quantum=4, warm_start=False))
        assert not restored.config.warm_start
        _assert_equal_state(svc, restored)


class TestCrashRecoverReplay:
    def test_interrupted_replay_matches_uninterrupted(self, tmp_path):
        spec = TraceSpec(fleets=2, requests=30, n0=16, m=4, seed=5)
        trace = gct_trace(spec)
        base = replay(RightsizingService(), list(trace), push_per_tick=6)
        rec, crashed = replay_with_crash(
            RightsizingService(), list(trace),
            crash_after_ticks=max(1, base["ticks"] // 2),
            snapshot_dir=str(tmp_path / "snap"), push_per_tick=6)
        assert crashed
        for key in ("ticks", "requests", "total_cost",
                    "proposed_cost_total", "warm_lanes", "cold_lanes",
                    "events"):
            assert base[key] == rec[key], key


class TestCorruptionAndVersioning:
    def test_corrupt_blob_raises_snapshot_error(self, tmp_path):
        svc = _busy_service()
        path = str(tmp_path / "snap")
        svc.snapshot(path)
        corrupt_snapshot(path)
        with pytest.raises(SnapshotError, match="corrupt"):
            RightsizingService.restore(path)

    def test_missing_blob_raises_snapshot_error(self, tmp_path):
        svc = _busy_service()
        path = str(tmp_path / "snap")
        svc.snapshot(path)
        os.remove(os.path.join(path, "arrays.npz"))
        with pytest.raises(SnapshotError, match="missing arrays.npz"):
            RightsizingService.restore(path)

    def test_corrupt_manifest_raises_snapshot_error(self, tmp_path):
        svc = _busy_service()
        path = str(tmp_path / "snap")
        svc.snapshot(path)
        with open(os.path.join(path, "manifest.json"), "w") as f:
            f.write('{"version": 1, "trunca')   # torn write
        with pytest.raises(SnapshotError, match="not valid JSON"):
            RightsizingService.restore(path)

    def test_version_mismatch_raises(self, tmp_path):
        svc = _busy_service()
        path = str(tmp_path / "snap")
        svc.snapshot(path)
        mpath = os.path.join(path, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["version"] = 99
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(SnapshotError, match="version 99"):
            RightsizingService.restore(path)

    def test_missing_directory_raises(self, tmp_path):
        with pytest.raises(SnapshotError, match="missing"):
            RightsizingService.restore(str(tmp_path / "nope"))


class TestServeCli:
    def test_checkpoint_then_restore_round_trip(self, tmp_path, capsys):
        from repro.launch.rightsize import run

        snap = str(tmp_path / "snap")
        run(["serve", "--requests", "8", "--fleets", "2", "--seed", "3",
             "--checkpoint", snap])
        assert os.path.exists(os.path.join(snap, "manifest.json"))
        capsys.readouterr()
        run(["serve", "--requests", "8", "--fleets", "2", "--seed", "4",
             "--restore", snap])
        out = capsys.readouterr().out
        assert "restored service from" in out
        assert "2 fleet(s)" in out


# -- Hypothesis property: restore(snapshot(s)) == s on random fleets --
# guarded per-test (not module-level importorskip, which would skip
# every test above it too), matching tests/test_properties.py's env

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _property_body(tmp_path_factory, data):
    n_fleets = data.draw(st.integers(1, 3), label="fleets")
    svc = RightsizingService(config=ServiceConfig(shape_quantum=4))
    for i in range(n_fleets):
        n = data.draw(st.integers(4, 14), label=f"n{i}")
        m = data.draw(st.integers(2, 4), label=f"m{i}")
        seed = data.draw(st.integers(0, 10**6), label=f"seed{i}")
        svc.submit(_admit(f"f{i}", n=n, m=m, seed=seed))
    svc.drain()
    if data.draw(st.booleans(), label="replan"):
        svc.submit(Request(fleet="f0", kind="replan"))
        svc.tick()
    path = str(tmp_path_factory.mktemp("prop") / "snap")
    svc.snapshot(path)
    restored = RightsizingService.restore(path)
    _assert_equal_state(svc, restored)
    svc.submit(Request(fleet="f0", kind="replan"))
    restored.submit(Request(fleet="f0", kind="replan"))
    ra, rb = svc.tick(), restored.tick()
    assert ra.iters == rb.iters and ra.warm_lanes == rb.warm_lanes
    _assert_equal_state(svc, restored)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(data=st.data())
    def test_property_round_trip_random_ragged_fleets(
            tmp_path_factory, data):
        _property_body(tmp_path_factory, data)
else:
    @pytest.mark.skip(
        reason="hypothesis not installed in this environment")
    def test_property_round_trip_random_ragged_fleets():
        pass
