"""Batched greedy placement engine tests.

Covers the contracts of ``repro.core.place_batch`` and the compiled
on-device stepper ``repro.core.place_step``:

  * hypothesis property suite — on random ragged instance grids (mixed
    n, T, D, m) with random feasible mappings, ALL THREE engines agree
    exactly: ``place_many`` (numpy lockstep), ``place_many(placement=
    'compiled')`` (on-device stepper), and the looped ``two_phase``
    (same node purchases, same ``assign``, same cost) for all four
    {fit} x {filling} combos, and ``verify`` holds on every solution;
  * kernel oracle sweep — ``fit_scores_many`` vs its numpy/jnp
    reference across shapes, padded-dim masks, span edges (s == e,
    full-timeline tasks) and interpret-mode CPU execution, mirroring
    the ``congestion_many_pallas`` oracle tests;
  * protocol parity — ``evaluate_many(placement='batched')`` produces
    the same costs as the per-instance placement loop;
  * stepper dispatch — unknown ``place_many(placement=...)`` values
    raise a ``ValueError`` naming the valid stepper set, telemetry
    reports the stepper actually used, and oversized pools fall back
    to the numpy engine with identical placements;
  * the acceptance gates — identical placements on a ragged B>=16 grid
    with the similarity-fit placement phase of a cold fleet sweep
    >=3x faster than the per-instance loop (numpy lockstep), and the
    compiled stepper bit-identical on a B>=64 quick fleet grid with
    its (warm) similarity phase >=2x faster than the per-instance
    loop, dispatching once per phase boundary instead of per step.
"""

import time

import numpy as np
import pytest

try:  # the property suite needs the 'test' extra; the rest runs without
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (  # noqa: E402
    assert_feasible,
    evaluate_many,
    pack_problems,
    penalty_map,
    place_many,
    solve_lp_many,
    trim_timeline,
    two_phase,
    verify,
)
from repro.core.placement import FIT_POLICIES  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.fit import fit_scores_many_pallas  # noqa: E402
from repro.workload import SyntheticSpec, synthetic_batch, \
    synthetic_instance  # noqa: E402

RNG = np.random.default_rng(11)

ALL_COMBOS = [(fit, filling) for fit in FIT_POLICIES
              for filling in (False, True)]


def _ragged_problems(extra=()):
    """Mixed (n, m, D, T) instances — the ragged-batch fixture."""
    shapes = [(50, 3, 2, 12), (80, 5, 4, 24), (30, 2, 3, 8),
              (120, 6, 5, 30), (64, 4, 2, 16), (25, 3, 3, 10),
              *extra]
    return [synthetic_instance(SyntheticSpec(n=n, m=m, D=D, T=T, seed=s))
            for s, (n, m, D, T) in enumerate(shapes)]


def _assert_equal_solutions(got, want):
    np.testing.assert_array_equal(got.node_type, want.node_type)
    np.testing.assert_array_equal(got.assign, want.assign)


def _random_grid(seed):
    """A small ragged batch of instances plus random feasible mappings."""
    rng = np.random.default_rng(seed)
    problems, mappings = [], []
    for _ in range(int(rng.integers(2, 6))):
        n = int(rng.integers(1, 35))
        m = int(rng.integers(1, 5))
        D = int(rng.integers(1, 4))
        T = int(rng.integers(1, 14))
        spec = SyntheticSpec(n=n, m=m, D=D, T=T,
                             seed=int(rng.integers(0, 2**31 - 1)))
        p = synthetic_instance(spec)
        t, _ = trim_timeline(p)
        problems.append(t)
        # a random feasible node-type per task (demands in Table-I
        # ranges fit every type, but pick via the feasibility mask to
        # stay honest on degenerate draws)
        from repro.core.problem import feasible_types

        feas = feasible_types(t)
        pick = np.array([rng.choice(np.flatnonzero(row)) for row in feas])
        mappings.append(pick.astype(np.int64))
    return problems, mappings


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="install the 'test' extra")
class TestPlaceManyProperty:
    if HAVE_HYPOTHESIS:
        # example budget comes from the active profile (conftest.py)
        @given(st.integers(0, 2**31 - 1))
        def test_matches_looped_two_phase_exactly(self, seed):
            """All three engines (loop, numpy lockstep, compiled
            stepper) place bit-identically on random ragged grids."""
            problems, mappings = _random_grid(seed)
            batch = pack_problems(problems)
            for fit, filling in ALL_COMBOS:
                sols = place_many(batch, mappings, fit=fit,
                                  filling=filling)
                comp = place_many(batch, mappings, fit=fit,
                                  filling=filling,
                                  placement="compiled")
                for t, mp, got, got_c in zip(batch.problems, mappings,
                                             sols, comp):
                    want = two_phase(t, mp, fit=fit, filling=filling)
                    _assert_equal_solutions(got, want)
                    _assert_equal_solutions(got_c, want)
                    assert got.cost(t) == want.cost(t)
                    verify(t, got)
                    # independent oracle (repro.core.checker): shares
                    # no code with verify() or the engines
                    assert_feasible(t, got)


class TestPlaceManyFixtures:
    def test_ragged_grid_all_combos_and_mappings(self):
        """B>=16 ragged grid: every combo x {penalty-avg, penalty-max,
        LP} mapping family is bit-identical to the loop."""
        problems = _ragged_problems(
            extra=[(40 + 7 * i, 2 + i % 4, 1 + i % 5, 6 + i)
                   for i in range(12)])
        assert len(problems) >= 16
        batch = pack_problems(problems)
        mapsets = [[penalty_map(t, kind) for t in batch.problems]
                   for kind in ("avg", "max")]
        mapsets.append([r.mapping for r in
                        solve_lp_many(batch, iters=150)])
        for maps in mapsets:
            for fit, filling in ALL_COMBOS:
                sols = place_many(batch, maps, fit=fit, filling=filling)
                for t, mp, got in zip(batch.problems, maps, sols):
                    want = two_phase(t, mp, fit=fit, filling=filling)
                    _assert_equal_solutions(got, want)
                    verify(t, got)
                    assert_feasible(t, got)  # independent oracle

    def test_mapping_validation(self):
        t, _ = trim_timeline(synthetic_instance(SyntheticSpec(
            n=10, m=2, D=2, T=6, seed=0)))
        with pytest.raises(ValueError):
            place_many([t], [np.zeros(t.n, np.int64)], fit="worst")
        with pytest.raises(ValueError):
            place_many([t], [])

    def test_rejects_unknown_stepper(self):
        """Unknown placement= values raise a ValueError that names the
        valid stepper set (not just unknown backends)."""
        from repro.core.place_batch import PLACEMENT_STEPPERS

        t, _ = trim_timeline(synthetic_instance(SyntheticSpec(
            n=10, m=2, D=2, T=6, seed=0)))
        mp = [np.zeros(t.n, np.int64)]
        with pytest.raises(ValueError, match="lockstep.*compiled"):
            place_many([t], mp, placement="warp")
        for name in PLACEMENT_STEPPERS:  # every advertised name works
            place_many([t], mp, placement=name)

    def test_stepper_telemetry_and_fallback(self):
        """telemetry= reports the stepper actually used; a pool-cell
        budget of zero forces the compiled path back onto the numpy
        engine with identical placements."""
        from repro.core import place_step

        problems = _ragged_problems()[:3]
        batch = pack_problems(problems)
        maps = [penalty_map(t, "avg") for t in batch.problems]
        tel = {}
        sols_l = place_many(batch, maps, telemetry=tel)
        assert tel["engine"] == "lockstep" and tel["waves"] >= 1
        tel = {}
        sols_c = place_many(batch, maps, placement="compiled",
                            telemetry=tel)
        assert tel["engine"] == "compiled"
        assert tel["dispatches"] >= 1
        for a, b in zip(sols_l, sols_c):
            _assert_equal_solutions(a, b)
        old = place_step.MAX_POOL_CELLS
        try:
            place_step.MAX_POOL_CELLS = 0
            tel = {}
            sols_f = place_many(batch, maps, placement="compiled",
                                telemetry=tel)
        finally:
            place_step.MAX_POOL_CELLS = old
        assert tel["engine"] == "lockstep-fallback"
        assert "fallback" in tel
        for a, b in zip(sols_l, sols_f):
            _assert_equal_solutions(a, b)

    def test_infeasible_mapping_raises(self):
        """A mapping that sends a task to a type it cannot fit raises
        exactly like two_phase."""
        from repro.core import NodeTypes, Problem

        t = Problem(dem=np.array([[0.9], [0.4]]),
                    start=np.array([0, 0]), end=np.array([1, 1]),
                    node_types=NodeTypes(cap=np.array([[1.0], [0.5]]),
                                         cost=np.array([1.0, 0.4])),
                    T=2)
        bad = np.array([1, 1])  # task 0 (0.9) cannot fit type 1 (0.5)
        with pytest.raises(RuntimeError):
            two_phase(t, bad)
        with pytest.raises(RuntimeError):
            place_many([t], [bad])

    @pytest.mark.slow
    def test_kernel_backend_parity(self):
        """backend='kernel' (fp32 Pallas scoring, interpret on CPU)
        places identically to the numpy loop."""
        problems = [synthetic_instance(SyntheticSpec(n=n, m=m, D=D, T=T,
                                                     seed=s))
                    for s, (n, m, D, T) in enumerate(
                        [(25, 3, 2, 10), (30, 2, 3, 8), (20, 4, 2, 12)])]
        batch = pack_problems(problems)
        maps = [penalty_map(t, "avg") for t in batch.problems]
        for fit, filling in ALL_COMBOS:
            sols = place_many(batch, maps, fit=fit, filling=filling,
                              backend="kernel")
            for t, mp, got in zip(batch.problems, maps, sols):
                want = two_phase(t, mp, fit=fit, filling=filling)
                _assert_equal_solutions(got, want)


class TestFitScoresManyKernel:
    """Oracle sweep for the batch-dim-aware Pallas fit kernel, mirroring
    the congestion_many_pallas tests (interpret-mode CPU execution)."""

    @pytest.mark.parametrize("B,N,T,D", [
        (1, 1, 1, 1),
        (3, 7, 24, 2),       # sub-block everything
        (2, 16, 40, 5),
        (4, 30, 13, 3),
        (2, 130, 20, 2),     # over the 128-lane node block edge
    ])
    def test_matches_ref(self, B, N, T, D):
        rem = RNG.random((B, N, T, D)).astype(np.float32)
        dem = (RNG.random((B, D)) * 0.2).astype(np.float32)
        inv = (1.0 / (0.5 + RNG.random((B, D)))).astype(np.float32)
        s = RNG.integers(0, T, B)
        e = np.array([RNG.integers(lo, T) for lo in s])
        fk, ck = ops.fit_scores_many(rem, dem, s, e, inv, scored=True)
        fr, cr = ops.fit_scores_many(rem, dem, s, e, inv, scored=True,
                                     use_ref=True)
        np.testing.assert_array_equal(fk, fr)
        np.testing.assert_allclose(ck, cr, rtol=1e-4, atol=1e-5)

    def test_span_edges(self):
        """Point spans (s == e) and full-timeline tasks."""
        B, N, T, D = 3, 9, 12, 3
        rem = RNG.random((B, N, T, D)).astype(np.float32)
        dem = (RNG.random((B, D)) * 0.2).astype(np.float32)
        inv = np.ones((B, D), np.float32)
        for s, e in [(np.array([0, 5, T - 1]), np.array([0, 5, T - 1])),
                     (np.zeros(B, int), np.full(B, T - 1))]:
            fk, ck = ops.fit_scores_many(rem, dem, s, e, inv, scored=True)
            fr, cr = ops.fit_scores_many(rem, dem, s, e, inv,
                                         scored=True, use_ref=True)
            np.testing.assert_array_equal(fk, fr)
            np.testing.assert_allclose(ck, cr, rtol=1e-4, atol=1e-5)

    def test_padded_dims_are_neutral(self):
        """inv_cap=0 marks padded dims: they contribute nothing to the
        similarity reductions, and zero demand there keeps feasibility
        neutral — exactly the engine's padding contract."""
        B, N, T = 2, 6, 10
        rem3 = RNG.random((B, N, T, 3)).astype(np.float32)
        rem4 = np.concatenate(
            [rem3, np.ones((B, N, T, 1), np.float32)], axis=3)
        dem3 = (RNG.random((B, 3)) * 0.2).astype(np.float32)
        dem4 = np.concatenate([dem3, np.zeros((B, 1), np.float32)], 1)
        inv3 = np.ones((B, 3), np.float32)
        inv4 = np.concatenate([inv3, np.zeros((B, 1), np.float32)], 1)
        s = np.array([2, 0])
        e = np.array([7, T - 1])
        f3, c3 = ops.fit_scores_many(rem3, dem3, s, e, inv3, scored=True)
        f4, c4 = ops.fit_scores_many(rem4, dem4, s, e, inv4, scored=True)
        np.testing.assert_array_equal(f3, f4)
        np.testing.assert_allclose(c3, c4, rtol=1e-5, atol=1e-6)

    def test_instances_are_independent(self):
        """Each grid-over-B group must see only its own instance."""
        N, T, D = 8, 14, 2
        rem = RNG.random((1, N, T, D)).astype(np.float32)
        dem = (RNG.random((1, D)) * 0.3).astype(np.float32)
        inv = np.ones((1, D), np.float32)
        s, e = np.array([3]), np.array([9])
        alone_f, alone_c = ops.fit_scores_many(rem, dem, s, e, inv,
                                               scored=True)
        rem3 = np.concatenate([rem * 0.5, rem, rem + 1], 0)
        dem3 = np.concatenate([dem * 2, dem, dem * 0.1], 0)
        inv3 = np.concatenate([inv, inv, inv * 0.7], 0)
        s3 = np.array([0, 3, 5])
        e3 = np.array([T - 1, 9, 6])
        f3, c3 = ops.fit_scores_many(rem3, dem3, s3, e3, inv3,
                                     scored=True)
        np.testing.assert_array_equal(f3[1], alone_f[0])
        np.testing.assert_allclose(c3[1], alone_c[0], rtol=1e-6,
                                   atol=1e-6)

    def test_small_block_sizes(self):
        """Multi-step grids with tiny blocks, raw kernel vs raw oracle."""
        B, N, T, D = 3, 20, 40, 3
        rem = RNG.random((B, N, T, D)).astype(np.float32)
        dem = (RNG.random((B, D)) * 0.1).astype(np.float32)
        inv = np.ones((B, D), np.float32)
        mask = np.zeros((B, T), np.float32)
        mask[0, 5:30] = 1.0
        mask[1, 0:1] = 1.0
        mask[2, :] = 1.0
        got = fit_scores_many_pallas(
            np.ascontiguousarray(rem.transpose(0, 2, 3, 1)), dem, mask,
            inv, block_n=8, block_t=8, interpret=True)
        want = ref.fit_scores_many_ref(rem, dem, mask, inv)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-5)


class TestEvaluateManyPlacement:
    def test_batched_placement_matches_loop(self):
        problems = _ragged_problems()[:4]
        got = evaluate_many(problems, lp_iters=250)
        want = evaluate_many(problems, lp_iters=250, placement="loop")
        for g, w in zip(got, want):
            assert g["costs"] == w["costs"]
            assert g["lb"] == w["lb"]
            for a in g["normalized"]:
                assert g["normalized"][a] == pytest.approx(
                    w["normalized"][a], rel=1e-12)
            assert set(g["wall_s"]) == set(w["wall_s"])

    def test_rejects_unknown_placement(self):
        with pytest.raises(ValueError):
            evaluate_many(_ragged_problems()[:1], placement="bogus")


class TestCompiledPlacementAcceptance:
    """ISSUE 5 acceptance: on a B>=64 quick fleet grid the compiled
    stepper places bit-identically to BOTH the numpy lockstep engine
    and ``two_phase`` on every {fit} x {filling} combo, dispatches to
    the device once per node-type phase boundary (once per CALL in the
    type-parallel non-filling plan) instead of once per placement
    step, and its warm similarity-fit phase runs >=2x faster than the
    per-instance loop.  (Against the numpy lockstep engine, CPU hosts
    sit near parity — XLA's elementwise kernels are ~2x slower per
    element than numpy's — so the 2x gate pins the per-step
    host-dispatch baseline; the lockstep ratio is benchmark telemetry,
    see docs/benchmarks.md.)"""

    def _fleet(self):
        rng = np.random.default_rng(5)
        specs = [SyntheticSpec(n=24 + 4 * i, m=4, D=3, T=8, seed=s)
                 for i in range(2) for s in range(32)]   # B = 64
        problems = [trim_timeline(p)[0] for p in synthetic_batch(specs)]
        batch = pack_problems(problems)
        from repro.core.problem import feasible_types

        maps = [np.array([rng.choice(np.flatnonzero(row))
                          for row in feasible_types(t)], np.int64)
                for t in batch.problems]
        return batch, maps

    def test_bit_identical_all_combos_b64(self):
        batch, maps = self._fleet()
        assert batch.B >= 64
        for fit, filling in ALL_COMBOS:
            lock = place_many(batch, maps, fit=fit, filling=filling)
            comp = place_many(batch, maps, fit=fit, filling=filling,
                              placement="compiled")
            for a, b in zip(lock, comp):
                _assert_equal_solutions(b, a)
            for b_i in range(0, batch.B, 16):  # spot-check the loop
                want = two_phase(batch.problems[b_i], maps[b_i],
                                 fit=fit, filling=filling)
                _assert_equal_solutions(comp[b_i], want)

    def _ratio(self, batch, maps, rounds=3):
        t_loop = t_comp = float("inf")
        for _ in range(rounds):  # interleaved: both sides share load
            t0 = time.perf_counter()
            looped = [two_phase(t, mp, fit="similarity")
                      for t, mp in zip(batch.problems, maps)]
            t_loop = min(t_loop, time.perf_counter() - t0)
            t0 = time.perf_counter()
            sols = place_many(batch, maps, fit="similarity",
                              placement="compiled")
            t_comp = min(t_comp, time.perf_counter() - t0)
        for got, want in zip(sols, looped):
            _assert_equal_solutions(got, want)
        return t_loop / max(t_comp, 1e-9)

    def test_one_dispatch_and_similarity_phase_2x(self):
        batch, maps = self._fleet()
        tel = {}
        place_many(batch, maps, fit="similarity", placement="compiled",
                   telemetry=tel)  # warmup: pay the XLA compiles here
        assert tel["engine"] == "compiled"
        # the whole non-filling placement is ONE device dispatch (the
        # type-parallel plan); the numpy engine re-enters Python every
        # step, i.e. ~max-tasks-per-type times per wave
        assert tel["mode"] == "type-parallel"
        assert tel["dispatches"] == 1
        tel_f = {}
        place_many(batch, maps, fit="similarity", filling=True,
                   placement="compiled", telemetry=tel_f)
        assert tel_f["mode"] == "wave-sequential"
        assert tel_f["dispatches"] <= 2 * tel_f["waves"]
        ratio = self._ratio(batch, maps)
        if ratio < 2.0:  # one retry: CI boxes share noisy cores
            ratio = max(ratio, self._ratio(batch, maps))
        assert ratio >= 2.0, (
            f"compiled similarity placement speedup {ratio:.1f}x < 2x "
            f"vs the per-instance loop")


class TestPlacementAcceptance:
    """The acceptance gate, analogous to PR 1's LP speedup smoke: on a
    seed-replicated fleet grid the lockstep engine must place exactly
    like the loop, and the similarity-fit scoring phase (the engine's
    dot-product/best-fit hot loop) must run >=3x faster cold.
    """

    def _fleet(self):
        specs = [SyntheticSpec(n=28 + 4 * i, m=3, D=4, T=10, seed=s)
                 for i in range(4) for s in range(64)]
        problems = [trim_timeline(p)[0] for p in synthetic_batch(specs)]
        batch = pack_problems(problems)
        maps = [penalty_map(t, "avg") for t in batch.problems]
        return batch, maps

    def _ratio(self, batch, maps, rounds=3):
        t_loop = t_batch = float("inf")
        for _ in range(rounds):  # interleaved: both sides share load
            t0 = time.perf_counter()
            looped = [two_phase(t, mp, fit="similarity")
                      for t, mp in zip(batch.problems, maps)]
            t_loop = min(t_loop, time.perf_counter() - t0)
            t0 = time.perf_counter()
            sols = place_many(batch, maps, fit="similarity")
            t_batch = min(t_batch, time.perf_counter() - t0)
        for got, want in zip(sols, looped):
            _assert_equal_solutions(got, want)
        return t_loop / max(t_batch, 1e-9)

    def test_identical_and_similarity_phase_3x(self):
        batch, maps = self._fleet()
        # all four combos place identically on the fleet grid
        for fit, filling in ALL_COMBOS:
            sols = place_many(batch, maps, fit=fit, filling=filling)
            spot = list(range(0, batch.B, 16))  # full loop is the slow
            # comparator; spot-check here, timing below re-checks all
            for b in spot:
                want = two_phase(batch.problems[b], maps[b], fit=fit,
                                 filling=filling)
                _assert_equal_solutions(sols[b], want)
        ratio = self._ratio(batch, maps)
        if ratio < 3.0:  # one retry: CI boxes share noisy cores
            ratio = max(ratio, self._ratio(batch, maps))
        assert ratio >= 3.0, (
            f"similarity placement phase speedup {ratio:.1f}x < 3x")
