"""Per-architecture smoke tests: reduced same-family configs run a forward
/train step and a decode step on CPU; shapes + finiteness asserted.
The full configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, cells, get_config, smoke_config
from repro.models import (
    build_segments,
    decode_step,
    init_decode_state,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.model import _run_encoder

# full-arch forward/decode smoke runs take minutes on CPU: tier-1 runs the
# core solver suite; select these with `-m slow` (or `-m ""` for everything)
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
B, S = 2, 12


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_seq:
        batch["vision"] = jax.random.normal(
            KEY, (B, cfg.vision_seq, cfg.d_model))
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None, :], (3, B, S))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_train_step_finite(self, arch):
        cfg = smoke_config(arch)
        params = init_params(KEY, cfg)
        batch = _batch(cfg)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), arch
        gnorm = jax.tree.reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
            grads, jnp.zeros(()))
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, arch

    def test_decode_step_shapes(self, arch):
        cfg = smoke_config(arch)
        params = init_params(KEY, cfg)
        batch = _batch(cfg)
        enc_out = (_run_encoder(batch["frames"].astype(jnp.float32),
                                params, cfg)
                   if cfg.encoder_layers else None)
        state = init_decode_state(params, cfg, B, 16, enc_out=enc_out)
        logits, state = decode_step(params, cfg, state, batch["tokens"][:, 0])
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), arch
        assert int(state["pos"]) == 1

    def test_segments_cover_all_layers(self, arch):
        cfg = get_config(arch)
        segs = build_segments(cfg)
        assert sum(s.layers for s in segs) == cfg.num_layers


@pytest.mark.parametrize(
    "arch", ["gemma3-1b", "gemma2-9b", "recurrentgemma-9b", "rwkv6-7b",
             "whisper-small"])
def test_prefill_decode_parity(arch):
    """Prefill then single-step decode must agree with pure decode-from-
    scratch: exercises ring-buffer caches, recurrent state extraction and
    cross-attention K/V precompute."""
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    logits_p, _state = prefill(params, cfg, batch, max_len=16)
    enc_out = (_run_encoder(batch["frames"].astype(jnp.float32), params, cfg)
               if cfg.encoder_layers else None)
    state = init_decode_state(params, cfg, B, 16, enc_out=enc_out)
    lg = None
    for t in range(S):
        lg, state = decode_step(params, cfg, state, batch["tokens"][:, t])
    err = float(jnp.max(jnp.abs(lg - logits_p)))
    assert err < 5e-3, (arch, err)


class TestCellGrid:
    def test_40_cells(self):
        assert len(cells(include_skipped=True)) == 40

    def test_long500k_eligibility(self):
        eligible = {a for a, s, ok, _w in cells(include_skipped=True)
                    if s == "long_500k" and ok}
        assert eligible == {"gemma3-1b", "recurrentgemma-9b", "rwkv6-7b"}

    def test_param_counts_match_names(self):
        """Sanity: billions in the name ~ the config's param count."""
        expect = {
            "gemma3-1b": (0.7, 1.6), "gemma2-9b": (8, 11),
            "qwen2.5-3b": (2.5, 4), "granite-34b": (30, 38),
            "recurrentgemma-9b": (7.5, 11), "olmoe-1b-7b": (6, 8),
            "kimi-k2-1t-a32b": (900, 1150), "whisper-small": (0.2, 0.45),
            "qwen2-vl-2b": (1.2, 2.3), "rwkv6-7b": (6, 8.5),
        }
        for arch, (lo, hi) in expect.items():
            pc = get_config(arch).param_count() / 1e9
            assert lo <= pc <= hi, (arch, pc)

    def test_kimi_active_params(self):
        cfg = get_config("kimi-k2-1t-a32b")
        act = cfg.active_param_count() / 1e9
        assert 25 <= act <= 40, act
