"""Pallas kernel tests: shape/dtype sweeps vs. the pure-jnp oracles, plus
placement-engine parity between the numpy and kernel backends."""

import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.congestion import congestion_pallas
from repro.kernels.fit import fit_scores_pallas


RNG = np.random.default_rng(42)


class TestCongestionKernel:
    @pytest.mark.parametrize("n,K,T", [
        (1, 1, 1),
        (7, 3, 24),          # sub-block everything
        (128, 128, 128),     # exact block boundary
        (300, 10, 200),
        (1000, 26, 995),     # GCT-like trimmed timeline
        (513, 129, 130),     # off-by-one over block edges
    ])
    def test_matches_ref(self, n, K, T):
        start = RNG.integers(0, T, n)
        end = np.minimum(start + RNG.integers(0, max(T // 2, 1), n), T - 1)
        w = RNG.random((n, K)).astype(np.float32)
        out = np.asarray(ops.congestion(start, end, w, T))
        want = np.asarray(ops.congestion(start, end, w, T, use_ref=True))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, dtype):
        n, K, T = 50, 4, 30
        start = RNG.integers(0, T, n)
        end = np.minimum(start + RNG.integers(0, 10, n), T - 1)
        w = RNG.random((n, K)).astype(dtype)
        out = np.asarray(ops.congestion(start, end, w, T))
        want = np.asarray(ref.congestion_ref(
            np.asarray(start, np.int32), np.asarray(end, np.int32),
            w.astype(np.float32), T))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_point_tasks(self):
        """start == end tasks contribute to exactly one slot."""
        start = np.array([3, 3, 5])
        end = np.array([3, 3, 5])
        w = np.ones((3, 1), np.float32)
        out = np.asarray(ops.congestion(start, end, w, 8))
        np.testing.assert_allclose(out[:, 0], [0, 0, 0, 2, 0, 1, 0, 0])

    def test_small_block_sizes(self):
        """Exercise multi-step grids with tiny blocks."""
        n, K, T = 40, 6, 50
        start = RNG.integers(0, T, n)
        end = np.minimum(start + RNG.integers(0, 20, n), T - 1)
        w = RNG.random((n, K)).astype(np.float32)
        out = np.asarray(congestion_pallas(
            np.asarray(start, np.int32), np.asarray(end, np.int32),
            np.asarray(w), T, block_t=8, block_n=16, block_k=8,
            interpret=True))
        want = np.asarray(ref.congestion_ref(
            np.asarray(start, np.int32), np.asarray(end, np.int32), w, T))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


class TestFitKernel:
    @pytest.mark.parametrize("N,T,D", [
        (1, 1, 1),
        (3, 24, 2),
        (128, 256, 5),       # exact blocks
        (130, 300, 7),       # padding on both axes
        (64, 1000, 2),
    ])
    def test_matches_ref(self, N, T, D):
        rem = RNG.random((N, T, D)).astype(np.float32)
        dem = (RNG.random(D) * 0.2).astype(np.float32)
        cap = (0.5 + RNG.random(D)).astype(np.float32)
        s = int(RNG.integers(0, T))
        e = int(RNG.integers(s, T))
        feas_k, cos_k = ops.fit_scores(rem, dem, s, e, cap, scored=True)
        feas_r, cos_r = ops.fit_scores(rem, dem, s, e, cap, scored=True,
                                       use_ref=True)
        np.testing.assert_array_equal(feas_k, feas_r)
        np.testing.assert_allclose(cos_k, cos_r, rtol=1e-4, atol=1e-5)

    def test_feasibility_boundary(self):
        """A node with exactly the demand remaining is feasible; one with
        epsilon less is not."""
        T, D = 10, 2
        dem = np.array([0.5, 0.5], np.float32)
        rem = np.stack([
            np.full((T, D), 0.5, np.float32),          # exact fit
            np.full((T, D), 0.5 - 1e-3, np.float32),   # just misses
        ])
        feas, _ = ops.fit_scores(rem, dem, 0, T - 1, np.ones(D, np.float32))
        assert feas[0] and not feas[1]

    def test_span_masking(self):
        """Capacity shortfalls outside the span must not matter."""
        T, D = 12, 1
        rem = np.full((1, T, D), 1.0, np.float32)
        rem[0, 8:, 0] = 0.0  # empty outside span
        dem = np.array([0.9], np.float32)
        feas, _ = ops.fit_scores(rem, dem, 0, 7, np.ones(1, np.float32))
        assert feas[0]
        feas, _ = ops.fit_scores(rem, dem, 0, 8, np.ones(1, np.float32))
        assert not feas[0]

    def test_small_blocks(self):
        N, T, D = 20, 40, 3
        rem = RNG.random((N, T, D)).astype(np.float32)
        dem = (RNG.random(D) * 0.1).astype(np.float32)
        cap = np.ones(D, np.float32)
        mask = np.zeros(T, np.float32)
        mask[5:30] = 1.0
        got = fit_scores_pallas(
            np.ascontiguousarray(rem.transpose(1, 2, 0)), dem, mask,
            1.0 / cap, block_n=8, block_t=8, interpret=True)
        want = ref.fit_scores_ref(rem, dem, mask, 1.0 / cap)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-5)


class TestBackendParity:
    @pytest.mark.slow
    def test_placement_identical_across_backends(self):
        from repro.core import penalty_map, trim_timeline, two_phase, verify
        from repro.workload import SyntheticSpec, synthetic_instance

        p = synthetic_instance(SyntheticSpec(n=120, m=4, D=3, seed=7))
        t, _ = trim_timeline(p)
        mp = penalty_map(t, "avg")
        for fit in ("first", "similarity"):
            s_np = two_phase(t, mp, fit=fit, backend="numpy")
            s_k = two_phase(t, mp, fit=fit, backend="kernel")
            verify(t, s_np)
            verify(t, s_k)
            np.testing.assert_array_equal(s_np.assign, s_k.assign)
            np.testing.assert_array_equal(s_np.node_type, s_k.node_type)
