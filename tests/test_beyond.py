"""Beyond-paper components: PDHG LP solver, concentration rounding,
node-elimination local search."""

import numpy as np
import pytest

from repro.core import (
    concentration_rounding,
    eliminate_nodes,
    lp_lowerbound,
    rightsize,
    solve_lp,
    solve_lp_pdhg,
    trim_timeline,
    two_phase,
    verify,
)
from repro.workload import SyntheticSpec, gct_like_instance, \
    synthetic_instance


class TestPDHG:
    def test_converges_to_highs_objective(self):
        p = synthetic_instance(SyntheticSpec(n=150, m=5, D=3, seed=1))
        t, _ = trim_timeline(p)
        exact = solve_lp(t).objective
        res = solve_lp_pdhg(t, iters=3000)
        # primal upper-bounds, dual lower-bounds the LP optimum
        assert res.lower_bound <= exact + 1e-3 * exact
        assert res.objective >= exact - 1e-3 * exact
        gap = (res.objective - res.lower_bound) / exact
        assert gap < 0.08, (res.objective, res.lower_bound, exact)

    def test_dual_is_valid_lower_bound_on_opt(self):
        p = synthetic_instance(SyntheticSpec(n=100, m=4, D=2, seed=2))
        t, _ = trim_timeline(p)
        res = solve_lp_pdhg(t, iters=1500)
        cost = rightsize(t, "lp-map-f").cost(t)
        assert res.lower_bound <= cost + 1e-6

    def test_mapping_is_placeable(self):
        p = synthetic_instance(SyntheticSpec(n=120, m=4, D=3, seed=3))
        t, _ = trim_timeline(p)
        res = solve_lp_pdhg(t, iters=800)
        sol = two_phase(t, res.mapping, fit="first")
        verify(t, sol)

    def test_cumsum_operator_matches_dense(self):
        """The O(n+T) difference-array operators must produce the same
        iterates as the dense mask-matmul form."""
        p = synthetic_instance(SyntheticSpec(n=90, m=4, D=3, seed=4))
        t, _ = trim_timeline(p)
        a = solve_lp_pdhg(t, iters=400, operator="cumsum")
        b = solve_lp_pdhg(t, iters=400, operator="dense")
        np.testing.assert_allclose(a.objective, b.objective,
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(a.x, b.x, rtol=1e-3, atol=1e-4)

    def test_cumsum_fwd_adjoint_consistency(self):
        """<fwd(x), y> == <x, adj(y)> (adjointness) on random tensors."""
        import jax.numpy as jnp

        from repro.core.lp_pdhg import (
            _congestion_adj_cumsum,
            _congestion_fwd_cumsum,
        )

        rng = np.random.default_rng(0)
        n, Tp, D = 40, 25, 3
        start = jnp.asarray(rng.integers(0, Tp, n), jnp.int32)
        end = jnp.asarray(
            np.minimum(np.asarray(start) + rng.integers(0, 10, n), Tp - 1),
            jnp.int32)
        w = jnp.asarray(rng.random((n, D)), jnp.float32)
        x = jnp.asarray(rng.random(n), jnp.float32)
        y = jnp.asarray(rng.random((Tp, D)), jnp.float32)
        lhs = float(jnp.sum(_congestion_fwd_cumsum(x, w, start, end, Tp)
                            * y))
        rhs = float(jnp.sum(x * _congestion_adj_cumsum(y, w, start, end)))
        assert abs(lhs - rhs) / max(abs(lhs), 1e-9) < 1e-5


class TestConcentrationRounding:
    def test_produces_feasible_mapping(self):
        g = gct_like_instance(n=300, m=8, seed=5)
        t, _ = trim_timeline(g)
        lp = solve_lp(t)
        mapping = concentration_rounding(t, lp.x)
        sol = two_phase(t, mapping, fit="first", filling=True)
        verify(t, sol)

    def test_comparable_to_argmax(self):
        """Measured honestly: concentration rounding is a wash vs argmax +
        filling on the GCT emulation (within 10% either way, wins some
        seeds); the consistent beyond-paper win is the local search
        (TestLocalSearch.test_consistent_gain)."""
        ratios = []
        for seed in range(3):
            g = gct_like_instance(n=300, m=8, seed=seed)
            t, _ = trim_timeline(g)
            lp = solve_lp(t)
            argmax_sol = two_phase(t, lp.mapping, fit="first", filling=True)
            conc_sol = two_phase(
                t, concentration_rounding(t, lp.x), fit="first",
                filling=True)
            ratios.append(conc_sol.cost(t) / argmax_sol.cost(t))
        assert np.mean(ratios) < 1.10, ratios

    def test_local_search_consistent_gain_over_lp_map_f(self):
        """argmax + filling + node elimination: the measured 12-16% gain
        (EXPERIMENTS.md §Perf beyond-paper)."""
        gains = []
        for seed in range(3):
            g = gct_like_instance(n=300, m=8, seed=seed)
            t, _ = trim_timeline(g)
            lp = solve_lp(t)
            base = two_phase(t, lp.mapping, fit="first", filling=True)
            ls = eliminate_nodes(t, base)
            verify(t, ls)
            gains.append(1.0 - ls.cost(t) / base.cost(t))
        assert np.mean(gains) >= 0.05, gains


class TestLocalSearch:
    def test_never_increases_cost_and_stays_feasible(self):
        for seed in range(3):
            p = synthetic_instance(SyntheticSpec(n=150, m=5, D=3,
                                                 seed=seed))
            t, _ = trim_timeline(p)
            sol = rightsize(t, "penalty-map")
            improved = eliminate_nodes(t, sol)
            verify(t, improved)
            assert improved.cost(t) <= sol.cost(t) + 1e-9

    def test_eliminates_obviously_wasteful_node(self):
        """Two tiny tasks forced onto two nodes by a bad mapping; local
        search must merge them."""
        import numpy as np

        from repro.core import NodeTypes, Problem, Solution

        nt = NodeTypes(cap=np.array([[1.0]]), cost=np.array([1.0]))
        p = Problem(dem=np.array([[0.3], [0.3]]), start=np.array([0, 0]),
                    end=np.array([0, 0]), node_types=nt, T=1)
        bad = Solution(node_type=np.array([0, 0]),
                       assign=np.array([0, 1]))
        verify(p, bad)
        fixed = eliminate_nodes(p, bad)
        verify(p, fixed)
        assert fixed.num_nodes == 1
        assert fixed.cost(p) == pytest.approx(1.0)

    @pytest.mark.slow
    def test_improves_lp_map_on_gct(self):
        g = gct_like_instance(n=400, m=10, seed=7)
        t, _ = trim_timeline(g)
        sol = rightsize(t, "lp-map")
        ls = eliminate_nodes(t, sol)
        verify(t, ls)
        lb = lp_lowerbound(t)
        assert ls.cost(t) <= sol.cost(t)
        # report-style sanity: normalized cost must stay sane
        assert ls.cost(t) / lb < 2.0
