"""PR 8 speed-layer battery: Ruiz scaling invariance, primal-weight
(omega) balancing, mixed-precision certificate parity, the compiled
one-dispatch sweep pipeline, the redesigned ``SolverConfig`` /
``SweepConfig`` surface, the degeneracy-insensitive canonical rounding,
and the ``solve_lp_sweep`` deprecation shim.
"""

import warnings

import numpy as np
import pytest

from repro.core import (
    FIT_POLICIES,
    FleetEngine,
    SolverConfig,
    SweepConfig,
    solve_lp_many,
    solve_lp_sweep,
    trim_timeline,
    two_phase,
)
from repro.core.batch import (
    CANONICAL_MARGIN,
    DEFAULT_TOL,
    PRECISIONS,
    SCALINGS,
    _canonical_mapping,
    dispatch_count,
)
from repro.workload import SyntheticSpec, synthetic_instance

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # the 'test' extra is not installed; suites skip
    _HAVE_HYPOTHESIS = False

TOL = DEFAULT_TOL
CAP = 8000


def _inst(seed=0, n=40, m=5, D=3, T=12, **kw):
    p = synthetic_instance(SyntheticSpec(n=n, m=m, D=D, T=T, seed=seed,
                                         **kw))
    return trim_timeline(p)[0]


def _hetero_fleet(B=6):
    """Heterogeneous-cost, wide-capacity instances — the ill-conditioned
    regime the scaling layer targets."""
    return [_inst(seed=s, n=30, m=6, cost_model="heterogeneous",
                  capacity=(0.1, 8.0)) for s in range(B)]


def _objective_slack(a, b, tol=TOL):
    """Provable objective gap between two tol-converged solves of the
    same LP: each is within tol * (1 + |primal| + |dual|) of optimum."""
    return tol * (2.0 + a.objective + a.lower_bound
                  + b.objective + b.lower_bound)


# --- config surface --------------------------------------------------------

class TestConfigSurface:
    def test_scaling_validated_naming_the_set(self):
        with pytest.raises(ValueError, match=r"\('none', 'ruiz'\)"):
            SolverConfig(scaling="log")

    def test_precision_validated_naming_the_set(self):
        with pytest.raises(ValueError, match=r"\('f64', 'mixed'\)"):
            SolverConfig(precision="f16")

    def test_solve_lp_many_validates_too(self):
        with pytest.raises(ValueError, match=r"\('none', 'ruiz'\)"):
            solve_lp_many([_inst()], tol=TOL, scaling="bogus")
        with pytest.raises(ValueError, match=r"\('f64', 'mixed'\)"):
            solve_lp_many([_inst()], tol=TOL, precision="f128")

    def test_defaults_are_the_speed_layer(self):
        cfg = SolverConfig()
        assert cfg.scaling == "ruiz" and cfg.scaling in SCALINGS
        assert cfg.precision == "mixed" and cfg.precision in PRECISIONS
        assert cfg.omega is True

    def test_pipeline_requires_warm_start(self):
        with pytest.raises(ValueError, match="warm_start"):
            SweepConfig(pipeline=True)

    def test_devices_requires_pipeline(self):
        with pytest.raises(ValueError, match="pipeline"):
            SweepConfig(devices=2)

    def test_with_overrides_routes_new_fields(self):
        eng = FleetEngine(solver=SolverConfig(tol=TOL),
                          sweep=SweepConfig(warm_start=2))
        eng2 = eng.with_overrides(scaling="none", precision="f64",
                                  omega=False, pipeline=True)
        assert (eng2.solver.scaling, eng2.solver.precision,
                eng2.solver.omega) == ("none", "f64", False)
        assert eng2.sweep.pipeline and not eng.sweep.pipeline


# --- Ruiz scaling invariance ----------------------------------------------

class TestScalingInvariance:
    def test_ruiz_objectives_match_unscaled_within_slack(self):
        fleet = _hetero_fleet()
        res_n = solve_lp_many(fleet, tol=TOL, iters=CAP, scaling="none",
                              omega=False)
        res_r = solve_lp_many(fleet, tol=TOL, iters=CAP, scaling="ruiz")
        for a, b in zip(res_n, res_r):
            assert abs(a.objective - b.objective) <= _objective_slack(a, b)
            # certified bounds bracket a common optimum
            assert b.lower_bound <= a.objective + _objective_slack(a, b)
            assert a.lower_bound <= b.objective + _objective_slack(a, b)

    def test_ruiz_certificates_still_valid(self):
        fleet = _hetero_fleet(B=4)
        _, stats = solve_lp_many(fleet, tol=TOL, iters=CAP,
                                 scaling="ruiz", full_output=True)
        assert stats.converged.all()
        # the KKT certificate is evaluated in ORIGINAL coordinates
        assert (stats.kkt <= TOL).all()

    if _HAVE_HYPOTHESIS:
        @settings(max_examples=8, deadline=None)
        @given(st.lists(
            st.tuples(st.integers(6, 18), st.integers(2, 4),
                      st.integers(1, 3), st.integers(4, 10),
                      st.integers(0, 10**6)),
            min_size=1, max_size=3))
        def test_scaling_invariance_random_ragged(self, dims):
            fleet = [_inst(seed=s, n=n, m=m, D=D, T=T)
                     for n, m, D, T, s in dims]
            res_n = solve_lp_many(fleet, tol=TOL, iters=CAP,
                                  scaling="none", omega=False)
            res_r = solve_lp_many(fleet, tol=TOL, iters=CAP,
                                  scaling="ruiz")
            for a, b in zip(res_n, res_r):
                assert abs(a.objective - b.objective) \
                    <= _objective_slack(a, b)


# --- mixed precision -------------------------------------------------------

class TestMixedPrecision:
    def test_certificate_parity_vs_f64(self):
        fleet = _hetero_fleet(B=4)
        res_m, st_m = solve_lp_many(fleet, tol=TOL, iters=CAP,
                                    precision="mixed", full_output=True)
        res_f, st_f = solve_lp_many(fleet, tol=TOL, iters=CAP,
                                    precision="f64", full_output=True)
        assert st_m.converged.all() and st_f.converged.all()
        assert (st_m.kkt <= TOL).all() and (st_f.kkt <= TOL).all()
        for a, b in zip(res_m, res_f):
            assert abs(a.objective - b.objective) <= _objective_slack(a, b)

    def test_mixed_iterate_stays_f32_in_state(self):
        fleet = _hetero_fleet(B=2)
        _, stats = solve_lp_many(fleet, tol=TOL, iters=CAP,
                                 precision="mixed", full_output=True)
        state = stats.state
        assert state.x.dtype == np.float32
        assert state.y.dtype == np.float32
        assert state.omega is not None and state.omega.dtype == np.float32


# --- one-dispatch sweep pipeline ------------------------------------------

class TestPipeline:
    def _fleet(self):
        return [_inst(seed=s, n=24, m=4, T=10) for s in range(8)]

    def test_pipeline_matches_sequential_at_one_dispatch(self):
        fleet = self._fleet()
        seq = FleetEngine(solver=SolverConfig(tol=TOL, iters=CAP),
                          sweep=SweepConfig(warm_start=4))
        res_s, st_s = seq.solve(fleet)
        d0 = dispatch_count()
        res_p, st_p = seq.with_overrides(pipeline=True).solve(fleet)
        assert dispatch_count() - d0 == 1  # the whole chain, one dispatch
        # protocol cost identity with the sequential warm chain
        for t, a, b in zip(fleet, res_s, res_p):
            ca = min(two_phase(t, a.mapping, fit=f, filling=True).cost(t)
                     for f in FIT_POLICIES)
            cb = min(two_phase(t, b.mapping, fit=f, filling=True).cost(t)
                     for f in FIT_POLICIES)
            assert ca == cb
            assert abs(a.objective - b.objective) <= _objective_slack(a, b)
        assert all(s.converged.all() for s in st_p)

    def test_pipeline_carries_final_state_only(self):
        fleet = self._fleet()
        eng = FleetEngine(solver=SolverConfig(tol=TOL, iters=CAP),
                          sweep=SweepConfig(warm_start=4, pipeline=True))
        _, stats = eng.solve(fleet)
        assert [s.state is None for s in stats] == [True, False]
        final = stats[-1].state
        assert final.eta is not None and final.omega is not None

    def test_pipeline_rejects_ragged_groups(self):
        eng = FleetEngine(solver=SolverConfig(tol=TOL),
                          sweep=SweepConfig(warm_start=4, pipeline=True))
        with pytest.raises(ValueError, match="divide"):
            eng.solve(self._fleet()[:6])


# --- canonical rounding ----------------------------------------------------

class TestCanonicalRounding:
    def test_epsilon_perturbation_invariant(self):
        # rows either have a clear winner (runner-up gap >> margin) or
        # a solver-noise tie (gap << margin); the guarantee is for
        # masses away from the margin boundary, so build them that way
        rng = np.random.default_rng(0)
        feas = np.ones((12, 4), bool)
        cost = np.array([3.0, 1.0, 2.0, 4.0])
        rows = []
        for i in range(12):
            row = np.full(4, 0.05)
            if i % 2:                       # clear winner at type i%4
                row[i % 4] = 0.85
            else:                           # near-tie between two types
                row[i % 4] = 0.42
                row[(i + 1) % 4] = 0.42 + 0.01 * (-1) ** (i // 2)
            rows.append(row)
        x = np.array(rows)
        base = _canonical_mapping(x, feas, cost)
        for _ in range(10):
            noise = rng.uniform(-CANONICAL_MARGIN / 4,
                                CANONICAL_MARGIN / 4, size=x.shape)
            assert np.array_equal(
                _canonical_mapping(x + noise, feas, cost), base)

    def test_degenerate_tie_resolves_to_cheapest(self):
        # two types carry (near-)equal mass: the cheaper one wins, for
        # ANY tie order the trajectory happened to produce
        feas = np.ones((1, 3), bool)
        cost = np.array([2.0, 1.0, 3.0])
        for eps in (0.0, 0.01, -0.01):
            x = np.array([[0.5 + eps, 0.5 - eps, 0.0]])
            assert _canonical_mapping(x, feas, cost)[0] == 1

    def test_infeasible_types_never_picked(self):
        feas = np.array([[False, True, True]])
        cost = np.array([0.1, 5.0, 4.0])  # cheapest type infeasible
        x = np.array([[0.9, 0.55, 0.5]])
        assert _canonical_mapping(x, feas, cost)[0] == 2


# --- deprecation shim ------------------------------------------------------

class TestSweepShim:
    def test_solve_lp_sweep_warns_naming_the_config(self):
        groups = [[_inst(seed=0, n=16, m=3, T=8)]]
        with pytest.warns(DeprecationWarning, match="SweepConfig"):
            solve_lp_sweep(groups, tol=TOL, iters=2000)

    def test_shim_matches_engine_path(self):
        fleet = [_inst(seed=s, n=16, m=3, T=8) for s in range(4)]
        groups = [fleet[:2], fleet[2:]]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            res_shim, _ = solve_lp_sweep(groups, tol=TOL, iters=CAP)
        eng = FleetEngine(solver=SolverConfig(tol=TOL, iters=CAP),
                          sweep=SweepConfig(warm_start=2))
        res_eng, _ = eng.solve(fleet)
        for a, b in zip(res_shim, res_eng):
            assert np.array_equal(a.mapping, b.mapping)
            assert a.objective == b.objective
