"""Docs-suite health: intra-repo links resolve and the API names the
docs lean on stay exported.

The heavyweight half of the docs gate (doctest execution + README
snippet runs) lives in ``benchmarks/check_docs.py`` and runs as its
own CI step; this tier-1 suite covers the fast invariants so a broken
link or a renamed public symbol fails locally too.
"""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks import check_docs  # noqa: E402

import repro.core  # noqa: E402


# Every public name the docs/ suite and README reference by symbol; the
# docstring audit keeps these in repro.core.__all__ (docs must not name
# things users cannot import).
DOC_NAMES = {
    "FleetEngine", "SolverConfig", "PlacementConfig", "SweepConfig",
    "FleetResult", "PackPlan", "plan_buckets",
    "evaluate_many", "evaluate", "rightsize",
    "place_many", "two_phase", "TypePool",
    "pack_problems", "ProblemBatch", "solve_lp_many", "solve_lp_sweep",
    "solve_lp_pdhg", "solve_lp", "SolveStats", "PDHGResult",
    "Problem", "NodeTypes", "Solution", "verify", "trim_timeline",
    "penalty_map", "lp_map", "FIT_POLICIES",
}


class TestDocsSuite:
    def test_docs_files_exist(self):
        for name in ("architecture.md", "solver.md", "bucketing.md",
                     "service.md", "benchmarks.md"):
            assert (REPO / "docs" / name).is_file(), f"docs/{name} missing"

    def test_intra_repo_links_resolve(self):
        assert check_docs.check_links() == 0

    def test_doc_names_are_exported(self):
        missing = DOC_NAMES - set(repro.core.__all__)
        assert not missing, (
            f"docs reference unexported repro.core names: {sorted(missing)}")

    def test_audited_modules_importable(self):
        import importlib

        for name in check_docs.AUDITED_MODULES:
            importlib.import_module(name)

    def test_slugs_match_github_rules(self):
        slug = check_docs._slug
        assert slug("### 3. Greedy placement — three engines, "
                    "identical placements".lstrip("# ")) == \
            "3-greedy-placement--three-engines-identical-placements"
        assert slug("Migrating from the legacy `evaluate_many` "
                    "kwargs") == \
            "migrating-from-the-legacy-evaluate_many-kwargs"

    def test_link_checker_catches_breakage(self, tmp_path,
                                           monkeypatch):
        bad = tmp_path / "docs"
        bad.mkdir()
        (bad / "a.md").write_text("see [b](missing.md) and "
                                  "[c](a.md#no-such-heading)\n# Title\n")
        monkeypatch.setattr(check_docs, "REPO", tmp_path)
        monkeypatch.setattr(check_docs, "LINK_FILES", ("docs",))
        assert check_docs.check_links() == 2
