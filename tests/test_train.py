"""Training substrate tests: optimizer, microbatching, compression,
checkpoint/restart determinism, fault injection, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# env-gated skip (audited): zstandard/msgpack are optional 'train'
# extras deliberately absent from the minimal CI image; the suite runs
# wherever the extra is installed, so this stays a skip, not a test gap
pytest.importorskip("zstandard", reason="install the 'train' extra")
pytest.importorskip("msgpack", reason="install the 'train' extra")

from repro.configs import smoke_config  # noqa: E402
from repro.models import init_params
from repro.train import (
    AdamWConfig,
    DataConfig,
    TrainConfig,
    checkpoint,
    compression,
    init_train_state,
    make_batch,
    make_train_step,
)
from repro.train.fault import FaultInjector, LoopConfig, run_with_restarts, \
    train_loop

KEY = jax.random.PRNGKey(0)


def setup_tiny(microbatch=1, compress=False):
    cfg = smoke_config("qwen2.5-3b")
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=5),
        remat=True, microbatch=microbatch, loss_chunk=64,
        compress_grads=compress)
    params = init_params(KEY, cfg)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(cfg, tc))
    dc = DataConfig(batch=4, seq_len=32)
    return cfg, tc, params, state, step, dc


class TestTrainStep:
    def test_loss_decreases(self):
        cfg, tc, params, state, step, dc = setup_tiny()
        losses = []
        for i in range(30):
            params, state, m = step(params, state, make_batch(cfg, dc, i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses

    def test_microbatch_matches_full_batch_grads(self):
        """Gradient accumulation must reproduce the full-batch update."""
        cfg, tc1, params, state1, step1, dc = setup_tiny(microbatch=1)
        tc4 = TrainConfig(optimizer=tc1.optimizer, remat=True, microbatch=4,
                          loss_chunk=64)
        step4 = jax.jit(make_train_step(cfg, tc4))
        state4 = init_train_state(params, tc4)
        batch = make_batch(cfg, dc, 0)
        p1, _s, m1 = step1(params, state1, batch)
        p4, _s, m4 = step4(params, state4, batch)
        diff = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
        worst = max(jax.tree.leaves(diff))
        assert worst < 5e-5, worst

    def test_compression_roundtrip_error_feedback(self):
        g = jax.random.normal(KEY, (1000,)) * 0.01
        err = jnp.zeros((1000,))
        # single round trip loses precision...
        g1, err1 = compression.compress_decompress(g, err)
        assert float(jnp.max(jnp.abs(g1 - g))) > 0
        # ...but accumulated updates converge: sum of g_hat ~ sum of g
        total_hat = jnp.zeros_like(g)
        e = jnp.zeros_like(g)
        for _ in range(50):
            gh, e = compression.compress_decompress(g, e)
            total_hat += gh
        rel = float(jnp.linalg.norm(total_hat - 50 * g)
                    / jnp.linalg.norm(50 * g))
        assert rel < 1e-2, rel

    def test_compressed_training_still_learns(self):
        cfg, tc, params, state, step, dc = setup_tiny(compress=True)
        losses = []
        for i in range(30):
            params, state, m = step(params, state, make_batch(cfg, dc, i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cfg, tc, params, state, step, dc = setup_tiny()
        checkpoint.save(str(tmp_path), (params, state), step=7)
        (p2, s2), got = checkpoint.restore(str(tmp_path), (params, state))
        assert got == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k_gc(self, tmp_path):
        cfg, tc, params, state, step, dc = setup_tiny()
        ck = checkpoint.Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save_async(params, s)
        ck.close()
        steps = sorted(int(f.split("_")[1].split(".")[0])
                       for f in os.listdir(tmp_path))
        assert steps == [3, 4]

    def test_elastic_restore_with_new_sharding(self, tmp_path):
        """Restore onto a different (logical) mesh: the elastic path."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        cfg, tc, params, state, step, dc = setup_tiny()
        checkpoint.save(str(tmp_path), params, step=1)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        shardings = jax.tree.map(
            lambda p: NamedSharding(mesh, P()), params)
        p2, _ = checkpoint.restore(str(tmp_path), params,
                                   shardings=shardings)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_restart_resumes_exact_trajectory(self, tmp_path):
        """Crash mid-run; supervised restarts must converge to the exact
        same final params as an uninterrupted run."""
        def make_args():
            cfg, tc, params, state, step, dc = setup_tiny()
            return step, params, state, (
                lambda s: make_batch(cfg, dc, s))

        lc_a = LoopConfig(total_steps=20, ckpt_dir=str(tmp_path / "a"),
                          ckpt_every=5)
        p_clean, _s, hist_clean = run_with_restarts(
            make_args, lc_a, FaultInjector(()))

        lc_b = LoopConfig(total_steps=20, ckpt_dir=str(tmp_path / "b"),
                          ckpt_every=5)
        p_crashy, _s, hist = run_with_restarts(
            make_args, lc_b, FaultInjector((7, 13)))
        for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_crashy)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_straggler_detection(self, tmp_path):
        import time as time_mod

        cfg, tc, params, state, step, dc = setup_tiny()
        slow = {17}

        def batch_at(s):
            if s in slow:
                time_mod.sleep(1.0)
            return make_batch(cfg, dc, s)

        lc = LoopConfig(total_steps=20, ckpt_dir=str(tmp_path),
                        ckpt_every=50, straggler_factor=3.0)
        _p, _s, hist = train_loop(step, params, state, batch_at, lc)
        assert hist["straggler"][17], hist["straggler"]
