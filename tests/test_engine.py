"""FleetEngine tests: typed-config validation, the shape-bucket packing
planner, bucketed-vs-single-bucket protocol parity (a Hypothesis
property plus the B=32 acceptance gate: exact cost equality at >= 30%
padded-cell waste reduction), bucket-merge ordering round-trips,
structured ``FleetResult`` output, and the legacy ``evaluate_many`` shim
semantics (warm_start validation, trailing-group behavior).
"""

import dataclasses
import json
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (
    FleetEngine,
    PlacementConfig,
    SolverConfig,
    SweepConfig,
    assert_feasible,
    evaluate_many,
    pack_problems,
    place_many,
    plan_buckets,
    trim_timeline,
)
from repro.core.batch import DEFAULT_TOL
from repro.workload import SyntheticSpec, synthetic_batch

try:
    from hypothesis import given, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - the 'test' extra not installed
    _HAVE_HYPOTHESIS = False


def _shape(n, m, D, T):
    """A duck-typed trimmed instance for planner unit tests."""
    return SimpleNamespace(n=n, m=m, D=D, T=T)


def _ragged_grid(shapes=8, seeds=4):
    """The acceptance fixture: a B = shapes x seeds ragged sweep grid."""
    specs = [SyntheticSpec(n=30 + 6 * i, m=5, D=4, T=8 + 2 * i, seed=s)
             for i in range(shapes) for s in range(seeds)]
    return synthetic_batch(specs)


class TestConfigValidation:
    def test_defaults_construct(self):
        FleetEngine()  # every config default must be self-consistent

    def test_configs_are_frozen(self):
        for cfg in (SolverConfig(), PlacementConfig(), SweepConfig()):
            with pytest.raises(dataclasses.FrozenInstanceError):
                cfg.iters = 1  # type: ignore[misc]

    @pytest.mark.parametrize("kw", [
        {"tol": 0.0}, {"tol": -1e-3}, {"iters": 0},
        {"operator": "bogus"}, {"step_scale": 0.0}, {"check_every": 0},
    ])
    def test_solver_config_rejects(self, kw):
        with pytest.raises(ValueError):
            SolverConfig(**kw)

    @pytest.mark.parametrize("kw", [
        {"engine": "bogus"}, {"fit": "bogus"}, {"backend": "bogus"},
    ])
    def test_placement_config_rejects(self, kw):
        with pytest.raises(ValueError):
            PlacementConfig(**kw)

    def test_placement_fits_scan(self):
        from repro.core import FIT_POLICIES

        assert PlacementConfig().fits == FIT_POLICIES
        assert PlacementConfig(fit="first").fits == ("first",)

    @pytest.mark.parametrize("kw", [
        {"warm_start": 0}, {"warm_start": -3}, {"shard_size": 0},
        {"max_buckets": 0}, {"bucket_overhead": -0.1},
    ])
    def test_sweep_config_rejects(self, kw):
        with pytest.raises(ValueError):
            SweepConfig(**kw)

    def test_warm_start_excludes_bucketing(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            SweepConfig(warm_start=2, max_buckets=3)

    def test_warm_start_excludes_sharding(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            SweepConfig(warm_start=2, shard_size=4)

    def test_engine_warm_start_requires_tol(self):
        with pytest.raises(ValueError, match="warm_start"):
            FleetEngine(sweep=SweepConfig(warm_start=2))

    def test_engine_loop_rejects_fit_narrowing(self):
        with pytest.raises(ValueError, match="loop"):
            FleetEngine(placement=PlacementConfig(engine="loop",
                                                  fit="first"))


class TestPlanner:
    def test_single_bucket_when_capped(self):
        probs = [_shape(10 * (i + 1), 3, 2, 8) for i in range(5)]
        assert plan_buckets(probs, max_buckets=1) == [[0, 1, 2, 3, 4]]

    def test_uniform_shapes_stay_one_bucket(self):
        """Splitting identical shapes saves nothing — the overhead term
        (and the exact-tie preference for fewer buckets) keeps them
        together."""
        probs = [_shape(40, 4, 3, 12) for _ in range(6)]
        assert plan_buckets(probs, max_buckets=4) == [[0, 1, 2, 3, 4, 5]]
        assert plan_buckets(probs, max_buckets=4,
                            overhead=0.0) == [[0, 1, 2, 3, 4, 5]]

    def test_two_clusters_split_apart(self):
        """Interleaved small/large shapes must land in separate buckets
        (the planner sorts by footprint, so submission interleaving
        never defeats it)."""
        small = _shape(10, 2, 2, 4)
        large = _shape(100, 4, 4, 30)
        probs = [small, large, small, large, small, large]
        parts = plan_buckets(probs, max_buckets=4)
        assert sorted(map(tuple, parts)) == [(0, 2, 4), (1, 3, 5)]

    def test_partition_is_a_permutation(self):
        rng = np.random.default_rng(0)
        probs = [_shape(int(rng.integers(5, 200)), int(rng.integers(2, 8)),
                        int(rng.integers(1, 6)), int(rng.integers(4, 40)))
                 for _ in range(23)]
        for k in (1, 2, 3, 7):
            parts = plan_buckets(probs, max_buckets=k)
            assert len(parts) <= k
            flat = sorted(i for p in parts for i in p)
            assert flat == list(range(23))

    def test_more_buckets_never_pad_more(self):
        rng = np.random.default_rng(1)
        probs = [_shape(int(rng.integers(5, 200)), 4, 3,
                        int(rng.integers(4, 40))) for _ in range(17)]

        def packed_cells(parts):
            dims = np.array([(t.n, t.m, t.D, t.T) for t in probs])
            return sum(len(p) * dims[list(p)].max(axis=0).prod()
                       for p in parts)

        cells = [packed_cells(plan_buckets(probs, max_buckets=k,
                                           overhead=0.0))
                 for k in (1, 2, 4, 8)]
        assert all(a >= b for a, b in zip(cells, cells[1:]))

    def test_rejects_empty(self):
        with pytest.raises(ValueError):
            plan_buckets([])


class TestPackPlan:
    def test_round_trip_and_waste_metrics(self):
        problems = _ragged_grid(shapes=6, seeds=2)
        engine = FleetEngine(sweep=SweepConfig(max_buckets=3))
        plan = engine.pack(problems)
        flat = sorted(i for b in plan.buckets for i in b.indices)
        assert flat == list(range(len(problems)))
        trimmed = [trim_timeline(p)[0] for p in problems]
        n, m = max(t.n for t in trimmed), max(t.m for t in trimmed)
        D, T = max(t.D for t in trimmed), max(t.T for t in trimmed)
        assert plan.cells_single == len(problems) * n * m * D * T
        assert plan.cells_own == sum(t.n * t.m * t.D * t.T
                                     for t in trimmed)
        assert plan.cells_packed <= plan.cells_single
        assert 0.0 <= plan.waste_packed <= plan.waste_single < 1.0
        assert 0.0 <= plan.waste_reduction <= 1.0
        # bucket batches really are packed to their own maxima
        for bucket in plan.buckets:
            own = [trimmed[i] for i in bucket.indices]
            assert bucket.shape == (max(t.n for t in own),
                                    max(t.m for t in own),
                                    max(t.D for t in own),
                                    max(t.T for t in own))
        summary = plan.summary()
        assert summary["buckets"] == plan.n_buckets
        assert sum(summary["bucket_sizes"]) == len(problems)

    def test_prepacked_batch_passes_through(self):
        problems = _ragged_grid(shapes=3, seeds=1)
        batch = pack_problems(problems)
        plan = FleetEngine(sweep=SweepConfig(max_buckets=4)).pack(batch)
        assert plan.n_buckets == 1
        assert plan.buckets[0].batch is batch
        assert plan.waste_reduction == 0.0


class TestBucketedParity:
    """Bucketed FleetEngine.evaluate == single-bucket evaluate_many,
    cost-exactly, on ragged grids (the acceptance property)."""

    ALGOS = ("lp-map", "lp-map-f")
    ITERS = 300

    def test_acceptance_b32_exact_costs_and_waste_cut(self):
        """The PR gate: on a ragged B=32 grid the bucketed engine keeps
        every protocol cost exactly equal to single-bucket packing while
        eliminating >= 30% of the padded-cell waste."""
        problems = _ragged_grid(shapes=8, seeds=4)
        assert len(problems) == 32
        engine = FleetEngine(solver=SolverConfig(iters=self.ITERS),
                             sweep=SweepConfig(max_buckets=4),
                             algos=self.ALGOS)
        result = engine.evaluate(problems)
        legacy = evaluate_many(problems, algos=self.ALGOS,
                               lp_iters=self.ITERS)
        assert result.plan.n_buckets >= 2
        assert result.plan.waste_reduction >= 0.30, (
            f"bucketing eliminated only "
            f"{result.plan.waste_reduction:.1%} of the padded-cell "
            f"waste (< 30%)")
        assert len(result.entries) == len(legacy)
        for got, want in zip(result.entries, legacy):
            assert got["costs"] == want["costs"]  # EXACT, per instance
            assert got["lb"] == pytest.approx(want["lb"], rel=1e-5)

    def test_merge_restores_submission_order(self):
        """Instances are distinct per index, so any merge scramble
        would move a cost to the wrong entry."""
        problems = _ragged_grid(shapes=5, seeds=1)[::-1]  # descending
        engine = FleetEngine(solver=SolverConfig(iters=150),
                             sweep=SweepConfig(max_buckets=3,
                                               bucket_overhead=0.0),
                             algos=("lp-map",))
        result = engine.evaluate(problems)
        # planner must have reordered (ascending footprint) internally
        assert result.plan.n_buckets >= 2
        assert list(result.plan.buckets[0].indices) != [0]
        for i, p in enumerate(problems):
            want = evaluate_many([p], algos=("lp-map",), lp_iters=150)[0]
            assert result.entries[i]["costs"] == want["costs"]


if _HAVE_HYPOTHESIS:
    # shapes come from a small menu so padded bucket shapes repeat and
    # the JIT cache amortizes across examples
    _MENU = [(15, 6), (25, 12), (40, 6), (40, 12)]

    class TestBucketedParityProperty:
        @given(st.lists(
            st.tuples(st.sampled_from(_MENU), st.integers(0, 3)),
            min_size=3, max_size=8))
        def test_bucketed_costs_match_single_bucket(self, draws):
            problems = [synthetic_batch(
                [SyntheticSpec(n=n, m=4, D=3, T=T, seed=seed)])[0]
                for (n, T), seed in draws]
            engine = FleetEngine(
                solver=SolverConfig(iters=120),
                sweep=SweepConfig(max_buckets=3, bucket_overhead=0.0),
                algos=("lp-map-f",))
            result = engine.evaluate(problems)
            legacy = evaluate_many(problems, algos=("lp-map-f",),
                                   lp_iters=120)
            flat = sorted(i for b in result.plan.buckets
                          for i in b.indices)
            assert flat == list(range(len(problems)))
            for got, want in zip(result.entries, legacy):
                assert got["costs"] == want["costs"]


class TestShardedSolve:
    def test_shard_dispatch_keeps_costs_exact(self):
        problems = _ragged_grid(shapes=5, seeds=1)
        algos = ("lp-map",)
        whole = FleetEngine(solver=SolverConfig(iters=200),
                            algos=algos).evaluate(problems)
        sharded = FleetEngine(solver=SolverConfig(iters=200),
                              sweep=SweepConfig(shard_size=2),
                              algos=algos).evaluate(problems)
        for a, b in zip(whole.entries, sharded.entries):
            assert a["costs"] == b["costs"]

    def test_shard_stats_one_per_dispatch(self):
        problems = _ragged_grid(shapes=5, seeds=1)
        engine = FleetEngine(
            solver=SolverConfig(tol=DEFAULT_TOL, iters=4000),
            sweep=SweepConfig(shard_size=2), algos=("lp-map",))
        result = engine.evaluate(problems)
        assert len(result.stats) == 3  # ceil(5 / 2) dispatches
        assert all(s.converged.all() for s in result.stats)


class TestWarmStartShim:
    def _instances(self, k=5):
        return synthetic_batch([SyntheticSpec(n=30, m=4, D=3, T=8, seed=s)
                                for s in range(k)])

    def test_zero_warm_start_is_an_error_not_off(self):
        with pytest.raises(ValueError, match="warm_start"):
            evaluate_many(self._instances(1), warm_start=0,
                          lp_tol=DEFAULT_TOL)

    def test_negative_warm_start_rejected(self):
        with pytest.raises(ValueError, match="warm_start"):
            evaluate_many(self._instances(1), warm_start=-2,
                          lp_tol=DEFAULT_TOL)

    def test_warm_start_still_requires_tol(self):
        with pytest.raises(ValueError, match="warm_start"):
            evaluate_many(self._instances(1), warm_start=1)

    def test_trailing_group_smaller_and_cold_started(self):
        """warm_start=2 over B=5: groups of 2/2/1 — the trailing group
        is smaller, cold-starts, and everything still converges with
        entries in submission order."""
        problems = self._instances(5)
        entries, stats = evaluate_many(
            problems, algos=("lp-map",), lp_tol=DEFAULT_TOL,
            lp_iters=4000, warm_start=2, return_stats=True)
        assert len(entries) == 5
        assert len(stats) == 3
        assert [s.iterations.shape[0] for s in stats] == [2, 2, 1]
        for e in entries:
            assert e["solver"]["converged"]
        # entries stay in submission order: each entry's per-instance
        # iteration telemetry lines up with the concatenated group stats
        # (cost identity with an unchained solve is NOT asserted — at
        # tol, different trajectories may round degenerate instances to
        # different epsilon-optimal vertices; see README)
        merged = np.concatenate([s.iterations for s in stats])
        assert [e["solver"]["iters"] for e in entries] \
            == [int(i) for i in merged]


class TestPlaceAndBackends:
    def test_engine_place_matches_loop_engine(self):
        problems = _ragged_grid(shapes=4, seeds=1)
        lp, _ = FleetEngine(solver=SolverConfig(iters=200)).solve(problems)
        maps = [r.mapping for r in lp]
        batched = FleetEngine().place(problems, maps, fit="similarity",
                                      filling=True)
        looped = FleetEngine(
            placement=PlacementConfig(engine="loop")).place(
                problems, maps, fit="similarity", filling=True)
        for p, a, b in zip(problems, batched, looped):
            np.testing.assert_array_equal(a.assign, b.assign)
            np.testing.assert_array_equal(a.node_type, b.node_type)
            # independent oracle on the ORIGINAL (untrimmed) timeline:
            # assignments are time-coordinate-free, so the checker's
            # slot-by-slot capacity audit holds there too
            assert_feasible(p, a)

    def test_place_many_rejects_unknown_backend(self):
        problems = _ragged_grid(shapes=2, seeds=1)
        lp, _ = FleetEngine(solver=SolverConfig(iters=120)).solve(problems)
        with pytest.raises(ValueError, match="backend"):
            place_many(problems, [r.mapping for r in lp],
                       backend="bogus")


class TestCompiledPlacementEngine:
    """PlacementConfig(engine='compiled') routes the protocol through
    the on-device stepper: identical costs, telemetry in timings."""

    def test_unknown_engine_names_valid_set(self):
        with pytest.raises(ValueError,
                           match=r"batched.*compiled.*loop"):
            PlacementConfig(engine="warp")

    def test_unknown_stepper_names_valid_set(self):
        problems = [trim_timeline(p)[0]
                    for p in _ragged_grid(shapes=1, seeds=1)]
        maps = [np.zeros(t.n, np.int64) for t in problems]
        with pytest.raises(ValueError, match=r"lockstep.*compiled"):
            place_many(problems, maps, placement="warp")

    def test_engine_place_compiled_matches_loop(self):
        problems = _ragged_grid(shapes=3, seeds=1)
        lp, _ = FleetEngine(solver=SolverConfig(iters=150)).solve(problems)
        maps = [r.mapping for r in lp]
        comp = FleetEngine(
            placement=PlacementConfig(engine="compiled")).place(
                problems, maps, fit="similarity", filling=True)
        looped = FleetEngine(
            placement=PlacementConfig(engine="loop")).place(
                problems, maps, fit="similarity", filling=True)
        for a, b in zip(comp, looped):
            np.testing.assert_array_equal(a.assign, b.assign)
            np.testing.assert_array_equal(a.node_type, b.node_type)

    def test_compiled_protocol_costs_and_telemetry(self):
        problems = _ragged_grid(shapes=2, seeds=2)
        algos = ("lp-map", "lp-map-f")
        base = FleetEngine(solver=SolverConfig(iters=150),
                           algos=algos).evaluate(problems)
        comp = FleetEngine(solver=SolverConfig(iters=150), algos=algos,
                           placement=PlacementConfig(engine="compiled")
                           ).evaluate(problems)
        for a, b in zip(base.entries, comp.entries):
            assert a["costs"] == b["costs"]
        tel_b = base.timings["placement"]
        assert tel_b["engine"] == "batched" and tel_b["calls"] >= 1
        tel_c = comp.timings["placement"]
        assert tel_c["engine"] == "compiled"
        assert tel_c["dispatches"] >= 1
        assert tel_c["fallbacks"] == 0
        assert set(tel_c["modes"]) <= {"type-parallel",
                                       "wave-sequential"}
        json.dumps(comp.timings)  # telemetry must stay JSON-clean


class TestFleetResult:
    def test_structured_output(self):
        problems = _ragged_grid(shapes=3, seeds=1)
        engine = FleetEngine(
            solver=SolverConfig(tol=DEFAULT_TOL, iters=4000),
            sweep=SweepConfig(max_buckets=2), algos=("lp-map",))
        result = engine.evaluate(problems)
        assert len(result) == 3
        assert result.algos == ("lp-map",)
        assert result.costs("lp-map") == [
            e["costs"]["lp-map"] for e in result.entries]
        # telemetry attached per entry in tol mode
        for e in result.entries:
            assert e["solver"]["iters"] > 0
        rows = result.to_rows()
        assert [r["instance"] for r in rows] == [0, 1, 2]
        for row in rows:
            assert {"lb", "cost[lp-map]", "normalized[lp-map]",
                    "wall_s[lp-map]", "solver.iters",
                    "solver.converged"} <= set(row)
        t = result.timings
        assert {"pack_s", "lp_s", "place_s", "total_s",
                "bucket_lp_s", "bucket_place_s"} <= set(t)
        assert len(t["bucket_lp_s"]) == result.plan.n_buckets
        blob = json.loads(result.to_json())
        assert blob["plan"]["buckets"] == result.plan.n_buckets
        assert len(blob["entries"]) == 3
        assert len(blob["solver"]) == len(result.stats)

    def test_warm_path_has_no_plan(self):
        problems = synthetic_batch(
            [SyntheticSpec(n=30, m=4, D=3, T=8, seed=s)
             for s in range(4)])
        engine = FleetEngine(
            solver=SolverConfig(tol=DEFAULT_TOL, iters=4000),
            sweep=SweepConfig(warm_start=2), algos=("lp-map",))
        result = engine.evaluate(problems)
        assert result.plan is None
        assert len(result.stats) == 2
        blob = json.loads(result.to_json())
        assert blob["plan"] is None


class TestSweepConfigMessages:
    """The mutual-exclusivity errors must name the config fields AND
    point at the serving-loop alternative (the API that DOES combine
    warm starts with per-tick shape bucketing)."""

    def test_bucketing_conflict_names_fields_and_alternative(self):
        with pytest.raises(ValueError) as ei:
            SweepConfig(warm_start=2, max_buckets=3)
        msg = str(ei.value)
        assert "SweepConfig.warm_start" in msg
        assert "SweepConfig.max_buckets" in msg
        assert "mutually exclusive" in msg
        assert "repro.serve.RightsizingService" in msg

    def test_sharding_conflict_names_fields_and_alternative(self):
        with pytest.raises(ValueError) as ei:
            SweepConfig(warm_start=2, shard_size=4)
        msg = str(ei.value)
        assert "SweepConfig.warm_start" in msg
        assert "SweepConfig.shard_size" in msg
        assert "mutually exclusive" in msg
        assert "repro.serve.RightsizingService" in msg


class TestWithOverrides:
    def test_routes_fields_across_config_family(self):
        eng = FleetEngine(solver=SolverConfig(tol=5e-3, iters=900))
        eng2 = eng.with_overrides(tol=1e-2, fit="first", max_buckets=3,
                                  algos=("lp-map",))
        assert eng2.solver.tol == 1e-2
        assert eng2.solver.iters == 900        # untouched field survives
        assert eng2.placement.fits == ("first",)
        assert eng2.sweep.max_buckets == 3
        assert eng2.algos == ("lp-map",)
        # the base engine is immutable
        assert eng.solver.tol == 5e-3 and eng.sweep.max_buckets == 1

    def test_whole_config_replacement(self):
        eng = FleetEngine(solver=SolverConfig(tol=5e-3))
        eng2 = eng.with_overrides(sweep=SweepConfig(max_buckets=4))
        assert eng2.sweep.max_buckets == 4
        assert eng2.solver.tol == 5e-3

    def test_whole_config_plus_field_override_composes(self):
        eng = FleetEngine()
        eng2 = eng.with_overrides(solver=SolverConfig(tol=5e-3),
                                  iters=1234)
        assert eng2.solver.tol == 5e-3 and eng2.solver.iters == 1234

    def test_unknown_field_names_the_known_set(self):
        with pytest.raises(ValueError) as ei:
            FleetEngine().with_overrides(fuel="ion")
        msg = str(ei.value)
        assert "unknown field 'fuel'" in msg
        assert "solver=/placement=/sweep=/algos=" in msg
        assert "tol" in msg and "max_buckets" in msg

    def test_derived_engine_revalidates(self):
        eng = FleetEngine(solver=SolverConfig(tol=5e-3))
        with pytest.raises(ValueError, match="mutually exclusive"):
            eng.with_overrides(warm_start=2, max_buckets=3)


class TestSolveInitGuards:
    def test_init_conflicts_with_warm_started_sweep(self):
        eng = FleetEngine(solver=SolverConfig(tol=DEFAULT_TOL, iters=500),
                          sweep=SweepConfig(warm_start=2),
                          algos=("lp-map",))
        problems = synthetic_batch(
            [SyntheticSpec(n=20, m=3, D=2, T=6, seed=s) for s in range(2)])
        _, (st,) = FleetEngine(
            solver=SolverConfig(tol=DEFAULT_TOL, iters=500)).solve(
                problems[:1])
        with pytest.raises(ValueError, match="SweepConfig.warm_start"):
            eng.solve(problems, init=st.state)

    def test_init_needs_single_bucket_plan(self):
        small = synthetic_batch([SyntheticSpec(n=8, m=2, D=2, T=4,
                                               seed=0)])
        large = synthetic_batch([SyntheticSpec(n=120, m=5, D=4, T=30,
                                               seed=1)])
        eng = FleetEngine(solver=SolverConfig(tol=DEFAULT_TOL, iters=500),
                          sweep=SweepConfig(max_buckets=4))
        _, (st,) = FleetEngine(
            solver=SolverConfig(tol=DEFAULT_TOL, iters=500)).solve(small)
        plan = eng.pack(small + large)
        assert plan.n_buckets > 1
        with pytest.raises(ValueError, match="single-bucket plan"):
            eng.solve(plan, init=st.state)

    def test_init_warm_resolve_matches_cold_cost(self):
        problems = synthetic_batch(
            [SyntheticSpec(n=24, m=4, D=3, T=8, seed=s) for s in range(3)])
        eng = FleetEngine(solver=SolverConfig(tol=DEFAULT_TOL, iters=4000))
        cold, cold_stats = eng.solve(problems)
        warm, warm_stats = eng.solve(problems,
                                     init=cold_stats[-1].state)
        for c, w in zip(cold, warm):
            assert w.converged
            # same tolerance contract either way
            assert abs(w.objective - c.objective) <= \
                2 * DEFAULT_TOL * max(1.0, abs(c.objective))
        # re-solving the SAME batch from its own solution exits early
        assert sum(int(i) for s in warm_stats for i in s.iterations) <= \
            sum(int(i) for s in cold_stats for i in s.iterations)


class TestEvaluateManyDeprecation:
    def _one(self):
        return synthetic_batch([SyntheticSpec(n=16, m=3, D=2, T=6,
                                              seed=0)])

    def test_default_call_emits_no_warning(self):
        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("error", DeprecationWarning)
            evaluate_many(self._one(), algos=("penalty-map-f",))

    def test_legacy_kwarg_warns_with_config_equivalent(self):
        with pytest.warns(DeprecationWarning,
                          match=r"lp_iters -> SolverConfig\(iters=\.\.\.\)"):
            evaluate_many(self._one(), algos=("penalty-map-f",), lp_iters=300)

    def test_warning_joins_every_passed_kwarg(self):
        with pytest.warns(DeprecationWarning) as rec:
            evaluate_many(self._one(), algos=("penalty-map-f",),
                          placement="loop", backend="numpy")
        msg = str(rec[0].message)
        assert "placement -> PlacementConfig(engine=...)" in msg
        assert "backend -> PlacementConfig(backend=...)" in msg
        assert "FleetEngine" in msg

    def test_shim_is_bit_stable_vs_engine(self):
        problems = self._one()
        with pytest.warns(DeprecationWarning):
            entries = evaluate_many(problems, algos=("lp-map",),
                                    lp_iters=400)
        engine = FleetEngine(solver=SolverConfig(iters=400),
                             algos=("lp-map",))
        result = engine.evaluate(problems)
        assert entries[0]["costs"] == result.entries[0]["costs"]
        assert entries[0]["lb"] == result.entries[0]["lb"]
