"""Hypothesis property-based tests on the system's invariants."""

import numpy as np
import pytest

# env-gated skip (audited): hypothesis is an optional 'test' extra
# absent from the minimal CI image; every module here is property-based
# so a module-level importorskip is correct (mixed modules guard
# per-test instead — see tests/test_serve_snapshot.py)
pytest.importorskip("hypothesis", reason="install the 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    NodeTypes,
    Problem,
    active_mask,
    congestion_lowerbound,
    lp_lowerbound,
    penalty_map,
    rightsize,
    trim_timeline,
    two_phase,
    verify,
)


@st.composite
def problems(draw, max_n=40, max_m=4, max_d=3, max_t=20):
    n = draw(st.integers(1, max_n))
    m = draw(st.integers(1, max_m))
    D = draw(st.integers(1, max_d))
    T = draw(st.integers(1, max_t))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    cap = rng.uniform(0.3, 1.0, size=(m, D))
    cost = rng.uniform(0.2, 2.0, size=m)
    # demands bounded by the *min* capacity so every task fits every type
    dem = rng.uniform(0.0, cap.min(axis=0) * 0.9, size=(n, D))
    a = rng.integers(0, T, n)
    b = rng.integers(0, T, n)
    return Problem(
        dem=dem, start=np.minimum(a, b), end=np.maximum(a, b),
        node_types=NodeTypes(cap=cap, cost=cost), T=T,
    )


@settings(max_examples=25, deadline=None)
@given(problems())
def test_every_algorithm_produces_feasible_solutions(p):
    """THE invariant: no capacity violated at any (node, slot, dim), every
    task placed — across all four algorithms on arbitrary instances."""
    t, _ = trim_timeline(p)
    for algo in ("penalty-map", "penalty-map-f", "lp-map", "lp-map-f"):
        sol = rightsize(t, algo, check=False)
        verify(t, sol)


@settings(max_examples=25, deadline=None)
@given(problems())
def test_lowerbounds_sandwich(p):
    """congestion LB <= LP LB <= any algorithm's cost."""
    t, _ = trim_timeline(p)
    clb = congestion_lowerbound(t)
    llb = lp_lowerbound(t)
    assert clb <= llb + 1e-6
    cost = rightsize(t, "lp-map-f").cost(t)
    assert llb <= cost + 1e-6


@settings(max_examples=25, deadline=None)
@given(problems())
def test_trimming_preserves_costs(p):
    """Solving on the trimmed timeline gives a solution whose un-trimmed
    expansion is feasible: trimming does not change the feasible set."""
    t, kept = trim_timeline(p)
    mp = penalty_map(t)
    sol = two_phase(t, mp, fit="first")
    verify(t, sol)
    # expand assignment back to the original timeline and re-verify there
    verify_full = np.zeros((sol.num_nodes, p.T, p.D))
    for u in range(p.n):
        verify_full[sol.assign[u], p.start[u]: p.end[u] + 1] += p.dem[u]
    cap = p.node_types.cap[sol.node_type]
    assert (verify_full <= cap[:, None, :] + 1e-7).all()


@settings(max_examples=25, deadline=None)
@given(problems())
def test_filling_never_increases_cost(p):
    t, _ = trim_timeline(p)
    mp = penalty_map(t)
    plain = two_phase(t, mp, fit="first", filling=False).cost(t)
    filled = two_phase(t, mp, fit="first", filling=True).cost(t)
    assert filled <= plain + 1e-9


@settings(max_examples=20, deadline=None)
@given(problems(max_n=25))
def test_congestion_kernel_matches_mask_matmul(p):
    from repro.kernels import ops

    t, _ = trim_timeline(p)
    w = (t.dem / t.node_types.cap[0][None, :]).astype(np.float32)
    out = np.asarray(ops.congestion(t.start, t.end, w, t.T))
    act = active_mask(t).astype(np.float32)  # (n, T')
    want = act.T @ w
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
