"""Nightly B=10^4 pipeline-sweep smoke (slow): the compiled warm chain
must eat a 10,000-lane fleet sweep in ONE dispatch, converge, and
return telemetry for every lane.  This is the fleet scale the ROADMAP
targets ("make B=10^4-10^5 sweeps routine"); the tier-1 suite covers
the same path at toy scale in ``tests/test_solver_speed.py``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FleetEngine, SolverConfig, SweepConfig
from repro.core.batch import DEFAULT_TOL, dispatch_count
from repro.workload import SyntheticSpec, synthetic_instance

pytestmark = pytest.mark.slow


def test_pipeline_sweep_b10k_one_dispatch():
    B, group = 10_000, 500
    # a demand-scaled sweep chain: 20 grid-adjacent groups of 500 lanes
    # sharing one tiny shape (the pipeline packs them into one scan)
    base = [synthetic_instance(SyntheticSpec(n=12, m=3, D=2, T=8,
                                             seed=s))
            for s in range(group)]
    # clamp per-task demand to each instance's largest SKU so every
    # scaled scenario stays feasible (the `fleet` CLI's scenario clamp)
    fleet = []
    for g in range(B // group):
        f = 1.0 + 0.02 * g
        fleet.extend(
            dataclasses.replace(
                p, dem=np.minimum(p.dem * f,
                                  p.node_types.cap.max(axis=0)))
            for p in base)
    eng = FleetEngine(
        solver=SolverConfig(tol=DEFAULT_TOL, iters=4000),
        sweep=SweepConfig(warm_start=group, pipeline=True))
    d0 = dispatch_count()
    results, stats = eng.solve(fleet)
    assert dispatch_count() - d0 == 1
    assert len(results) == B
    iters = np.concatenate([s.iterations for s in stats])
    conv = np.concatenate([s.converged for s in stats])
    assert iters.shape == (B,)
    # the high-scale groups clamp tasks EXACTLY at SKU capacity, and a
    # few of those boundary-degenerate lanes exhaust the iteration cap
    # (~2% at f>=1.12); the smoke gates bulk health, not the tail
    assert conv.mean() >= 0.95
    assert np.median(iters) <= 200
    assert all(r.lower_bound <= r.objective + 10 * DEFAULT_TOL
               for r in results[:100])
