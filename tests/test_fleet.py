"""Fleet-planning integration (paper x framework) + partitioning units."""

import numpy as np
import pytest

from repro.core import rightsize, trim_timeline, verify
from repro.workload import DEFAULT_SCHEDULE, TPU_SKUS, fleet_problem


class TestFleetPlanning:
    def test_problem_builds_and_solves(self, tmp_path):
        # no dry-run artifacts -> builtin demand table
        problem, tasks = fleet_problem(DEFAULT_SCHEDULE,
                                       dryrun_dir=str(tmp_path))
        assert problem.n >= len(DEFAULT_SCHEDULE)
        assert problem.m == TPU_SKUS.m
        sol = rightsize(problem, "lp-map-f")
        t, _ = trim_timeline(problem)
        verify(t, sol)
        assert sol.cost(t) > 0

    def test_measured_demands_used_when_present(self, tmp_path):
        # synthesize the 16x16 dry-run artifact (the schema
        # workload.jobs._dryrun_bytes reads) instead of skipping when
        # the real results/dryrun tree is absent
        import json

        artifact = {
            "arch": "gemma2-9b", "shape": "train_4k", "devices": 256,
            "argument_size_in_bytes": 4_000_000_000,
            "temp_size_in_bytes": 1_500_000_000,
            "output_size_in_bytes": 500_000_000,
        }
        with open(tmp_path / "gemma2-9b__train_4k__16x16.json", "w") as f:
            json.dump(artifact, f)
        problem, tasks = fleet_problem(DEFAULT_SCHEDULE,
                                       dryrun_dir=str(tmp_path))
        by_name = {t["name"].split("/")[0]: t for t in tasks}
        assert by_name["nightly-train-gemma2"]["source"] == "dryrun"
        assert by_name["nightly-train-olmoe"]["source"] == "builtin"
        # 6 GB/device x 256 devices = 1536 GB total footprint
        measured = [t for t in tasks
                    if t["name"].startswith("nightly-train-gemma2")]
        assert sum(t["dem"][0] for t in measured) > 0
        assert problem.n >= len(DEFAULT_SCHEDULE)

    def test_volume_discount_ordering(self):
        # bigger slices cheaper per chip (e = 0.92)
        per_chip = TPU_SKUS.cost / TPU_SKUS.cap[:, 0]
        assert (np.diff(per_chip) < 0).all()


class TestPartitioning:
    def test_param_specs_cover_all_leaves(self):
        import jax
        from jax.sharding import Mesh

        from repro.configs import smoke_config
        from repro.models import init_params
        from repro.sharding import param_specs

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        for arch in ("gemma2-9b", "olmoe-1b-7b", "recurrentgemma-9b",
                     "rwkv6-7b", "whisper-small"):
            cfg = smoke_config(arch)
            params = init_params(jax.random.PRNGKey(0), cfg)
            specs = param_specs(params, cfg, mesh)
            p_leaves = jax.tree.leaves(params)
            s_leaves = jax.tree.leaves(
                specs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
                or x.__class__.__name__ == "PartitionSpec")
            assert len(p_leaves) == len(s_leaves), arch
            for p, s in zip(p_leaves, s_leaves):
                assert len(s) <= p.ndim, (arch, p.shape, s)

    def test_constrain_noop_without_mesh(self):
        import jax.numpy as jnp

        from repro.sharding.ctx import constrain, hints_enabled

        assert not hints_enabled()
        x = jnp.ones((4, 8))
        y = constrain(x, "batch", "model")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_constrain_axis_count_checked(self):
        import jax.numpy as jnp

        from repro.sharding.ctx import constrain, use_mesh
        from repro.launch.mesh import make_host_mesh

        with use_mesh(make_host_mesh()):
            with pytest.raises(ValueError):
                constrain(jnp.ones((4, 8)), "batch")
