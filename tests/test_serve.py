"""RightsizingService tests: admission-queue FIFO/coalescing semantics,
request validation (non-finite payloads, unknown task ids), the
overload shed policy and its never-drop guarantee, retry/quarantine of
poison requests, queue-drain determinism (same trace => same fleets),
warm-vs-cold re-solve parity within the documented aggregate-drift
bound, the shape-drift cold fallback, cooldown/flag transitions of the
scale decision loop, and the replayed-trace acceptance gate (>= 200
requests end-to-end, ONE FleetEngine dispatch per tick, warm re-solves
cheaper than the cold control's).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import FleetEngine, SolverConfig, assert_feasible
from repro.serve import (
    NEVER_SHED_KINDS,
    AdmissionQueue,
    Request,
    RightsizingService,
    ServiceConfig,
    ShedEvent,
    TraceSpec,
    evaluate_scale,
    gct_trace,
    replay,
)
from repro.workload.gct import gct_like_instance


def _admit_request(fleet, n=12, m=3, seed=0):
    p = gct_like_instance(n=n, m=m, seed=seed)
    return p, Request(fleet=fleet, kind="admit", dem=p.dem, start=p.start,
                      end=p.end, node_types=p.node_types, T=p.T)


def _service(**cfg):
    return RightsizingService(config=ServiceConfig(**cfg))


class TestRequestValidation:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="request kind must be one of"):
            Request(fleet="a", kind="shrink")

    def test_admit_needs_catalogue(self):
        with pytest.raises(ValueError,
                           match="admit requests need node_types and T"):
            Request(fleet="a", kind="admit", dem=np.ones((2, 2)),
                    start=np.zeros(2), end=np.ones(2))

    def test_depart_needs_ids(self):
        with pytest.raises(ValueError, match="non-empty ids tuple"):
            Request(fleet="a", kind="depart")

    def test_burst_needs_factor(self):
        with pytest.raises(ValueError, match="ids and factor"):
            Request(fleet="a", kind="burst", ids=(1,))

    def test_constrain_needs_ids(self):
        with pytest.raises(ValueError, match="non-empty ids tuple"):
            Request(fleet="a", kind="constrain", exclusive=True)

    def test_constrain_needs_a_constraint_field(self):
        with pytest.raises(ValueError,
                           match="at least one of affinity"):
            Request(fleet="a", kind="constrain", ids=(0,))

    def test_constrain_deadline_must_be_nonnegative(self):
        with pytest.raises(ValueError,
                           match="deadline must be a slot index >= 0"):
            Request(fleet="a", kind="constrain", ids=(0,), deadline=-1)

    @pytest.mark.parametrize("factor", [float("inf"), float("nan"),
                                        0.0, -2.0])
    def test_factor_must_be_positive_and_finite(self, factor):
        # 'not inf > 0' is False: a bare positivity test lets inf
        # through, and _fit_demands then silently zeroes the demands
        with pytest.raises(ValueError,
                           match="factor must be positive and finite"):
            Request(fleet="a", kind="burst", ids=(1,), factor=factor)

    @pytest.mark.parametrize("field", ["dem", "start", "end"])
    def test_nonfinite_payload_rejected(self, field):
        payload = dict(dem=np.ones((2, 2)), start=np.zeros(2),
                       end=np.ones(2))
        payload[field] = np.asarray(payload[field], dtype=float)
        payload[field].flat[0] = np.nan
        with pytest.raises(ValueError, match=f"{field} must be finite"):
            Request(fleet="a", kind="arrive", **payload)

    @pytest.mark.parametrize("deadline", [0.0, -1.0, float("inf")])
    def test_deadline_must_be_positive_and_finite(self, deadline):
        with pytest.raises(ValueError, match="deadline_s must be"):
            Request(fleet="a", kind="replan", deadline_s=deadline)


class TestAdmissionQueue:
    def test_fifo_take_and_front_requeue(self):
        q = AdmissionQueue()
        reqs = [Request(fleet=f, kind="replan") for f in "abcd"]
        items = [q.push(r, now_s=float(i)) for i, r in enumerate(reqs)]
        first = q.take(2)
        assert [p.seq for p in first] == [items[0].seq, items[1].seq]
        q.requeue(first)                 # deferred work goes back in front
        again = q.take(4)
        assert [p.request.fleet for p in again] == list("abcd")

    def test_coalesce_groups_by_fleet_in_arrival_order(self):
        q = AdmissionQueue()
        fleets = ["b", "a", "b", "c", "a"]
        items = [q.push(Request(fleet=f, kind="replan"), now_s=0.0)
                 for f in fleets]
        groups = AdmissionQueue.coalesce(items)
        assert list(groups) == ["b", "a", "c"]
        assert [p.request.fleet for p in groups["b"]] == ["b", "b"]


class TestShedPolicy:
    def test_shed_events_refuse_state_changing_kinds(self):
        # the never-drop guarantee is structural: the event type itself
        # cannot name an admit/arrive/depart/burst
        for kind in NEVER_SHED_KINDS:
            with pytest.raises(ValueError, match="only ever name"):
                ShedEvent(tick=0, seq=0, fleet="a", kind=kind,
                          reason="pressure", waited_s=0.0)

    def test_state_changing_backlog_is_never_shed(self):
        q = AdmissionQueue()
        for i in range(6):
            q.push(Request(fleet=f"f{i}", kind="burst", ids=(0,),
                           factor=1.5), now_s=0.0)
        events = q.shed(now_s=100.0, max_pending=2, tick=0)
        assert events == [] and q.pending == 6

    def test_expired_replans_shed_regardless_of_pressure(self):
        q = AdmissionQueue()
        q.push(Request(fleet="a", kind="replan", deadline_s=1.0),
               now_s=0.0)
        q.push(Request(fleet="b", kind="replan"), now_s=0.0)
        events = q.shed(now_s=50.0, max_pending=10, tick=3)
        assert [e.reason for e in events] == ["deadline"]
        assert events[0].fleet == "a" and q.pending == 1

    def test_coalesced_wave_prefers_redundant_replans(self):
        q = AdmissionQueue()
        q.push(Request(fleet="a", kind="replan"), now_s=0.0)
        q.push(Request(fleet="a", kind="burst", ids=(0,), factor=1.5),
               now_s=0.0)
        q.push(Request(fleet="b", kind="replan"), now_s=0.0)
        events = q.shed(now_s=1.0, max_pending=2, tick=0)
        # fleet a's replan is redundant (its burst forces the
        # re-solve); fleet b's lone replan survives
        assert [(e.fleet, e.reason) for e in events] == \
            [("a", "coalesced")]
        assert q.pending == 2

    def test_pressure_wave_drops_stalest_first(self):
        q = AdmissionQueue()
        for i in range(4):
            q.push(Request(fleet=f"f{i}", kind="replan"), now_s=float(i))
        events = q.shed(now_s=10.0, max_pending=2, tick=0)
        assert [e.reason for e in events] == ["pressure", "pressure"]
        assert [e.fleet for e in events] == ["f0", "f1"]  # oldest first
        assert q.pending == 2

    def test_shed_event_round_trips_json(self):
        e = ShedEvent(tick=2, seq=7, fleet="a", kind="replan",
                      reason="pressure", waited_s=1.25)
        assert ShedEvent.from_dict(e.to_dict()) == e


class TestScaleFlags:
    cost = np.array([1.0, 3.0])

    def _cfg(self, **kw):
        base = dict(scale_in_cooldown=3, min_scale_in_savings=0.02,
                    payback_ticks=12, reconfig_weight=0.5)
        base.update(kw)
        return ServiceConfig(**base)

    def test_fresh_fleet_admits(self):
        d = evaluate_scale(None, np.array([2, 1]), self.cost, tick=0,
                           last_scale_in_tick=-10, cfg=self._cfg())
        assert d.scope == "admit" and d.cost == pytest.approx(5.0)

    def test_growth_is_never_gated(self):
        d = evaluate_scale(np.array([1, 1]), np.array([3, 1]), self.cost,
                           tick=0, last_scale_in_tick=0, cfg=self._cfg())
        assert d.scope == "scale-out"
        assert d.adopted.tolist() == [3, 1] and not d.checks

    def test_cooldown_blocks_then_releases(self):
        cfg = self._cfg(scale_in_cooldown=3)
        args = (np.array([4, 2]), np.array([2, 2]), self.cost)
        held = evaluate_scale(*args, tick=5, last_scale_in_tick=3, cfg=cfg)
        assert held.scope == "hold-release"
        assert held.adopted.tolist() == [4, 2]  # superset stays feasible
        flags = {c.name: c for c in held.checks}
        assert not flags["cooldown"].flag
        assert "2 tick(s) since last scale-in" in flags["cooldown"].message
        ok = evaluate_scale(*args, tick=6, last_scale_in_tick=3, cfg=cfg)
        assert ok.scope == "scale-in" and ok.adopted.tolist() == [2, 2]

    def test_savings_threshold_flag(self):
        cfg = self._cfg(min_scale_in_savings=0.5)
        d = evaluate_scale(np.array([4, 2]), np.array([3, 2]), self.cost,
                           tick=20, last_scale_in_tick=0, cfg=cfg)
        assert d.scope == "hold-release"
        flags = {c.name: c.flag for c in d.checks}
        assert flags["cooldown"] and not flags["savings"]

    def test_payback_flag_rejects_thrash(self):
        cfg = self._cfg(payback_ticks=1, reconfig_weight=10.0)
        d = evaluate_scale(np.array([4, 2]), np.array([3, 2]), self.cost,
                           tick=20, last_scale_in_tick=0, cfg=cfg)
        flags = {c.name: c.flag for c in d.checks}
        assert not flags["payback"] and d.scope == "hold-release"
        assert "reconfiguration cost" in d.checks[2].message

    def test_event_log_is_json_ready(self):
        d = evaluate_scale(np.array([4, 2]), np.array([2, 2]), self.cost,
                           tick=9, last_scale_in_tick=0, cfg=self._cfg())
        assert d.scaled_in
        from repro.serve import ScaleEvent
        blob = ScaleEvent(tick=9, fleet="f", scope=d.scope,
                          cost_before=10.0, cost_after=d.cost,
                          checks=d.checks).to_dict()
        assert blob["scope"] == "scale-in"
        assert all(set(c) == {"name", "flag", "message"}
                   for c in blob["checks"])


class TestServiceLifecycle:
    def test_needs_tolerance_stopped_solver(self):
        eng = FleetEngine(solver=SolverConfig(iters=100),
                          algos=("lp-map-f",))
        with pytest.raises(ValueError, match="tolerance-stopped solver"):
            RightsizingService(engine=eng)

    def test_admit_then_warm_replan(self):
        svc = _service(shape_quantum=4)
        _, admit = _admit_request("gpu", n=12, m=3, seed=1)
        svc.submit(admit)
        rec = svc.tick()
        assert svc.fleets == ("gpu",)
        assert rec.cold_lanes == 1 and rec.warm_lanes == 0
        view = svc.fleet("gpu")
        assert view.n_tasks == 12 and view.plan_cost > 0
        assert view.plan.sum() > 0
        svc.submit(Request(fleet="gpu", kind="replan"))
        rec = svc.tick()
        assert rec.warm_lanes == 1 and rec.drift_fallbacks == 0

    def test_warm_start_off_cold_resolves(self):
        svc = _service(warm_start=False, shape_quantum=4)
        _, admit = _admit_request("gpu", n=12, m=3, seed=1)
        svc.submit(admit)
        svc.tick()
        svc.submit(Request(fleet="gpu", kind="replan"))
        rec = svc.tick()
        assert rec.warm_lanes == 0 and rec.cold_lanes == 1

    def test_shape_drift_falls_back_cold(self):
        svc = _service(max_shape_drift=0.5, shape_quantum=4)
        p, admit = _admit_request("gpu", n=12, m=3, seed=1)
        svc.submit(admit)
        svc.tick()
        # 16 fresh arrivals against 12 stored rows: the stored state
        # covers only 12/28 < 50% of the new task set -> cold fallback
        svc.submit(Request(fleet="gpu", kind="arrive",
                           dem=np.tile(p.dem, (2, 1))[:16],
                           start=np.tile(p.start, 2)[:16],
                           end=np.tile(p.end, 2)[:16]))
        rec = svc.tick()
        assert rec.drift_fallbacks == 1 and rec.warm_lanes == 0
        assert svc.fleet("gpu").n_tasks == 28

    def test_depart_to_empty_quarantines_after_retries(self):
        svc = _service(shape_quantum=4, max_request_retries=1)
        _, admit = _admit_request("gpu", n=4, m=3, seed=2)
        svc.submit(admit)
        svc.tick()
        svc.submit(Request(fleet="gpu", kind="depart", ids=(0, 1, 2, 3)))
        svc.drain()
        # the invalid depart never applies: one retry, then quarantine
        # with the validation error, fleet state untouched
        assert svc.queue.pending == 0
        assert len(svc.quarantined) == 1
        q = svc.quarantined[0]
        assert q.kind == "depart" and q.attempts == 2
        assert "depart would empty fleet" in q.error
        assert svc.fleet("gpu").n_tasks == 4
        assert svc.report()["retries"] == 1

    @pytest.mark.parametrize("kind,extra", [
        ("depart", {}), ("burst", {"factor": 1.5}),
        ("constrain", {"exclusive": True})])
    def test_unknown_ids_raise_instead_of_silent_noop(self, kind, extra):
        # np.isin against ids the fleet never had matches nothing: a
        # client typo must surface as an error, not a no-op re-solve
        svc = _service(shape_quantum=4, max_request_retries=0)
        _, admit = _admit_request("gpu", n=4, m=3, seed=2)
        svc.submit(admit)
        svc.tick()
        svc.submit(Request(fleet="gpu", kind=kind, ids=(2, 99), **extra))
        svc.drain()
        assert len(svc.quarantined) == 1
        assert "unknown task ids [99]" in svc.quarantined[0].error
        assert svc.fleet("gpu").n_tasks == 4

    def test_constrain_applies_and_plan_passes_oracle(self):
        svc = _service(shape_quantum=4)
        _, admit = _admit_request("gpu", n=8, m=3, seed=2)
        svc.submit(admit)
        svc.tick()
        svc.submit(Request(fleet="gpu", kind="constrain", ids=(0, 1),
                           affinity="tower"))
        svc.submit(Request(fleet="gpu", kind="constrain", ids=(2,),
                           exclusive=True))
        svc.drain()
        assert not svc.quarantined
        st = svc._fleets["gpu"]
        c = st.problem.constraints
        assert c is not None and "tower" in c.affinity_names
        assert bool(c.exclusive[2])
        # the adopted plan reflects the constraints and survives the
        # independent oracle: the pair shares a node, task 2 is alone
        sol = st.solution
        assert sol.meta.get("constrained") is True
        assert sol.node_type[0] == sol.node_type[1]
        assert sol.assign[0] == sol.assign[1]
        assert_feasible(st.problem, sol)

    def test_service_sheds_under_pressure_and_reports(self):
        svc = _service(shape_quantum=4, max_pending=2,
                       max_requests_per_tick=2)
        _, admit = _admit_request("gpu", n=8, m=3, seed=3)
        svc.submit(admit)
        svc.tick()
        for _ in range(5):
            svc.submit(Request(fleet="gpu", kind="replan"))
        svc.submit(Request(fleet="gpu", kind="burst", ids=(0,),
                           factor=1.3))
        svc.drain()
        rep = svc.report()
        assert rep["shed"] >= 3
        assert all(e.kind == "replan" for e in svc.shed_events)
        assert sum(rep["shed_reasons"].values()) == rep["shed"]
        # the burst always survives shedding and was applied
        assert any(e.reason == "coalesced" for e in svc.shed_events)

    def test_deadline_misses_counted(self):
        svc = _service(shape_quantum=4)
        _, admit = _admit_request("gpu", n=8, m=3, seed=3)
        svc.submit(admit)
        svc.tick()
        # an SLO no real solve can meet: served late -> counted miss
        svc.submit(Request(fleet="gpu", kind="replan", deadline_s=1e-9))
        svc.tick()
        assert svc.report()["deadline_misses"] == 1

    def test_dispatch_count_is_truthful(self):
        svc = _service(shape_quantum=4, max_request_retries=0)
        _, admit = _admit_request("gpu", n=8, m=3, seed=3)
        svc.submit(admit)
        assert svc.tick().dispatches == 1
        # a tick whose only request fails runs no solve at all
        svc.submit(Request(fleet="gpu", kind="depart", ids=(123,)))
        rec = svc.tick()
        assert rec.dispatches == 0 and rec.quarantined == 1


class TestQueueDrainDeterminism:
    def test_same_trace_same_fleets(self):
        spec = TraceSpec(fleets=2, requests=60, n0=20, m=4, seed=7)
        trace = gct_trace(spec)
        reports, plans = [], []
        for _ in range(2):
            svc = _service()
            reports.append(replay(svc, list(trace), push_per_tick=8))
            plans.append({f: svc.fleet(f).plan for f in svc.fleets})
        assert reports[0]["ticks"] == reports[1]["ticks"]
        assert reports[0]["total_cost"] == reports[1]["total_cost"]
        assert plans[0].keys() == plans[1].keys()
        for f in plans[0]:
            np.testing.assert_array_equal(plans[0][f], plans[1][f])


@pytest.fixture(scope="module")
def paired_replay():
    """ONE >=200-request trace replayed warm (production) and cold
    (control) — shared by the acceptance and parity tests."""
    spec = TraceSpec(fleets=3, requests=200, n0=28, m=5, seed=0)
    trace = gct_trace(spec)
    out = {}
    for label, warm in [("warm", True), ("cold", False)]:
        svc = RightsizingService(config=ServiceConfig(warm_start=warm))
        out[label] = replay(svc, list(trace), push_per_tick=12)
        out[label + "_svc"] = svc
    return out


class TestReplayAcceptance:
    def test_one_dispatch_per_tick_end_to_end(self, paired_replay):
        for label in ("warm", "cold"):
            rep = paired_replay[label]
            assert rep["requests"] >= 200
            assert rep["dispatches_per_tick"] == 1
            assert rep["converged_frac"] == 1.0

    def test_replay_plans_pass_independent_oracle(self, paired_replay):
        # second opinion from repro.core.checker: every adopted fleet
        # plan must satisfy the brute-force feasibility oracle, which
        # shares no code with the placement engines.  Assignments are
        # time-coordinate-free, so the audit runs on the ORIGINAL
        # (untrimmed) fleet problem.
        for label in ("warm", "cold"):
            svc = paired_replay[label + "_svc"]
            assert svc.fleets
            for name in svc.fleets:
                st = svc._fleets[name]
                assert st.solution is not None
                assert_feasible(st.problem, st.solution)

    def test_sustained_throughput_and_latency_reported(self, paired_replay):
        rep = paired_replay["warm"]
        assert rep["requests_per_s"] > 0.5
        assert 0 < rep["p50_replan_s"] <= rep["p99_replan_s"]
        assert rep["events"]           # decision loop logged transitions

    def test_warm_resolves_cheaper_than_cold_control(self, paired_replay):
        warm = paired_replay["warm"]["median_iters_warm"]
        cold = paired_replay["cold"]["median_iters_cold"]
        assert warm is not None and cold is not None
        assert warm < cold

    def test_warm_cold_parity_within_documented_bound(self, paired_replay):
        w = paired_replay["warm"]["proposed_cost_total"]
        c = paired_replay["cold"]["proposed_cost_total"]
        drift_pct = abs(w - c) / c * 100.0
        assert drift_pct <= ServiceConfig().cost_drift_bound_pct

    def test_burst_trace_exercises_every_request_kind(self):
        spec = TraceSpec(fleets=3, requests=200, n0=28, m=5, seed=0)
        kinds = {r.kind for r in gct_trace(spec)}
        assert kinds == {"admit", "arrive", "depart", "burst"}


class TestServiceConfigValidation:
    def test_messages_name_the_field(self):
        with pytest.raises(ValueError,
                           match=r"max_requests_per_tick must be >= 1"):
            ServiceConfig(max_requests_per_tick=0)
        with pytest.raises(ValueError,
                           match=r"max_shape_drift must be in \[0, 1\]"):
            ServiceConfig(max_shape_drift=1.5)
        with pytest.raises(ValueError, match=r"payback_ticks must be >= 1"):
            ServiceConfig(payback_ticks=0)

    def test_frozen_and_replaceable(self):
        cfg = ServiceConfig()
        with pytest.raises(dataclasses.FrozenInstanceError):
            cfg.warm_start = False
        assert not dataclasses.replace(cfg, warm_start=False).warm_start
