"""Stochastic rightsizing: forecast, fan-out, and CVaR selection.

The load-bearing invariants of ``repro.stochastic``:

  * degeneracy — a zero-variance forecast at K=1 IS the paper's
    deterministic protocol, cost-exact against ``FleetEngine.evaluate``;
  * determinism — same seed twice gives bit-identical scenarios, and
    growing K appends scenarios without moving the first ones;
  * CVaR — monotone in alpha, mean at alpha=0, max as alpha -> 1, and
    the frontier's chosen fleet cost is non-decreasing in alpha on the
    fixed-seed grid;
  * batching — K same-shape scenarios solve in ONE compiled dispatch
    (``FleetEngine.solve_scenarios``), and ragged groups are rejected
    with a pointed error.
"""

import dataclasses

import numpy as np
import pytest

# guarded per-test (not module-level importorskip — most tests here
# are plain), matching tests/test_serve_snapshot.py's env
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed in this environment")

from repro.core import FleetEngine, SolverConfig, SweepConfig
from repro.core.batch import dispatch_count
from repro.stochastic import (
    DemandForecast,
    ScenarioSet,
    StochasticConfig,
    candidate_fleets,
    cvar,
    fan_out,
    fit_forecast,
    gct_forecast,
    overload_costs,
    plan_stochastic,
)
from repro.workload import SyntheticSpec, synthetic_instance
from repro.workload.gct import gct_like_instance


def _forecast(seed: int, n: int = 12, **channels) -> DemandForecast:
    base = synthetic_instance(SyntheticSpec(n=n, m=3, D=2, T=10,
                                            seed=seed))
    return DemandForecast(base=base, **channels)


# -- degeneracy: zero variance at K=1 is the deterministic protocol ----

def _k1_zero_variance_body(seed):
    """A deterministic forecast's single scenario must price EXACTLY
    like ``FleetEngine.evaluate`` on the base instance — stochastic
    planning degenerates to the paper's point-forecast plan."""
    fc = _forecast(seed, load_sigma=0.0, diurnal_amp=0.0,
                   burst_prob=0.0)
    engine = FleetEngine(solver=SolverConfig(iters=600),
                         algos=("lp-map-f",))
    res = plan_stochastic(fc, StochasticConfig(scenarios=1, quantiles=2),
                          engine=engine)
    point = engine.evaluate([fc.base]).entries[0]["costs"]["lp-map-f"]
    assert res.scenario_costs[0] == point
    assert res.worst_overload == 0.0  # one scenario, fully covered


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_k1_zero_variance_reproduces_deterministic_protocol(seed):
        _k1_zero_variance_body(seed)
else:
    def test_k1_zero_variance_reproduces_deterministic_protocol():
        _k1_zero_variance_body(0)


def test_deterministic_fan_out_is_bitwise_base():
    fc = _forecast(3, load_sigma=0.0, diurnal_amp=0.0, burst_prob=0.0)
    ss = fan_out(fc, K=4, seed=11)
    assert (ss.factors == 1.0).all()
    for p in ss.problems:
        assert (p.dem == fc.base.dem).all()
        assert p is not fc.base or True  # replaced instance, same data


# -- determinism: seeded streams ---------------------------------------

def test_fan_out_same_seed_twice_is_identical():
    fc = _forecast(0, burst_prob=0.3)
    a, b = fan_out(fc, K=5, seed=9), fan_out(fc, K=5, seed=9)
    assert (a.factors == b.factors).all()
    for pa, pb in zip(a.problems, b.problems):
        assert (pa.dem == pb.dem).all()


def test_fan_out_k_prefix_stability():
    """Growing K appends scenarios; the first ones do not move."""
    fc = _forecast(1, burst_prob=0.2)
    small, big = fan_out(fc, K=3, seed=4), fan_out(fc, K=7, seed=4)
    assert (big.factors[:3] == small.factors).all()
    for ps, pb in zip(small.problems, big.problems):
        assert (ps.dem == pb.dem).all()


def test_fan_out_scenarios_share_one_trimmed_shape():
    fc = _forecast(2, burst_prob=0.4)
    ss = fan_out(fc, K=6, seed=0)
    assert isinstance(ss, ScenarioSet) and ss.K == 6
    assert len(ss.shape) == 4  # the single (n, m, D, T') shape


def test_workload_generators_same_seed_twice():
    for make in (lambda: gct_like_instance(n=20, m=4, seed=5),
                 lambda: synthetic_instance(
                     SyntheticSpec(n=10, m=3, D=2, T=8, seed=5))):
        a, b = make(), make()
        assert (a.dem == b.dem).all()
        assert (a.start == b.start).all() and (a.end == b.end).all()
        assert (a.node_types.cap == b.node_types.cap).all()
        assert (a.node_types.cost == b.node_types.cost).all()


def test_workload_generators_explicit_rng_matches_seed():
    """``rng=default_rng(s)`` and ``seed=s`` are the same stream, and
    neither touches global numpy state."""
    np.random.seed(123)
    before = np.random.get_state()[1].copy()
    a = gct_like_instance(n=16, m=4, seed=7)
    b = gct_like_instance(n=16, m=4, rng=np.random.default_rng(7))
    assert (a.dem == b.dem).all()
    s = synthetic_instance(SyntheticSpec(n=8, m=2, D=2, T=6, seed=7))
    r = synthetic_instance(SyntheticSpec(n=8, m=2, D=2, T=6, seed=7),
                           rng=np.random.default_rng(7))
    assert (s.dem == r.dem).all()
    assert (np.random.get_state()[1] == before).all()


# -- CVaR ---------------------------------------------------------------

def _cvar_monotone_body(xs, a1, a2):
    x = np.asarray(xs)
    lo, hi = min(a1, a2), max(a1, a2)
    assert cvar(x, lo) <= cvar(x, hi) + 1e-9
    assert cvar(x, 0.0) == pytest.approx(float(x.mean()))
    assert cvar(x, 0.999) == pytest.approx(float(x.max()))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(0.0, 1e6), min_size=1, max_size=40),
           st.floats(0.0, 0.999), st.floats(0.0, 0.999))
    def test_cvar_monotone_in_alpha(xs, a1, a2):
        _cvar_monotone_body(xs, a1, a2)
else:
    def test_cvar_monotone_in_alpha():
        _cvar_monotone_body([0.0, 1.0, 5.0, 2.0], 0.3, 0.8)


def test_cvar_validation():
    with pytest.raises(ValueError, match="alpha"):
        cvar(np.array([1.0]), 1.0)
    with pytest.raises(ValueError, match="non-empty"):
        cvar(np.array([]), 0.5)


def test_frontier_fleet_cost_nondecreasing_in_alpha():
    """On the fixed-seed burst grid, raising the tail level never buys
    a cheaper fleet: the frontier's lambda>0 rows are sorted by alpha
    and their purchase costs must be non-decreasing."""
    fc = _forecast(0, n=30, burst_prob=0.25, burst_alpha=1.5)
    res = plan_stochastic(
        fc, StochasticConfig(scenarios=12, cvar_lambda=2.0,
                             quantiles=5))
    rows = res.frontier[1:]  # row 0 is the lambda=0 comparison
    assert all(rows[i]["alpha"] < rows[i + 1]["alpha"]
               for i in range(len(rows) - 1))
    costs = [r["fleet_cost"] for r in rows]
    assert all(a <= b + 1e-9 for a, b in zip(costs, costs[1:]))


# -- selection machinery ------------------------------------------------

def test_candidate_fleets_brackets_and_pairwise_unions():
    plans = np.array([[2, 0, 1], [0, 3, 0], [1, 1, 1]])
    fleets = candidate_fleets(plans, quantiles=3)
    rows = {tuple(f) for f in fleets}
    assert {(2, 0, 1), (0, 3, 0), (1, 1, 1)} <= rows  # the plans
    assert (2, 3, 1) in rows                          # elementwise max
    assert (2, 3, 1) == tuple(fleets[-1])             # sorted by size
    # pairwise unions that per-type quantiles cannot express
    assert (2, 3, 1) in rows and (2, 1, 1) in rows
    node_cost = np.array([1.0, 2.0, 4.0])
    ov = overload_costs(plans, fleets, node_cost)
    assert ov.shape == (3, len(fleets))
    assert (ov[:, -1] == 0).all()  # the max fleet covers everything


def test_stochastic_config_validation():
    with pytest.raises(ValueError, match="scenarios"):
        StochasticConfig(scenarios=0)
    with pytest.raises(ValueError, match="cvar_alpha"):
        StochasticConfig(cvar_alpha=1.0)
    with pytest.raises(ValueError, match="cvar_lambda"):
        StochasticConfig(cvar_lambda=-0.1)
    with pytest.raises(ValueError, match="quantiles"):
        StochasticConfig(quantiles=1)
    with pytest.raises(ValueError, match="algo"):
        StochasticConfig(algo="lp-map-f+ls")


def test_forecast_validation():
    base = synthetic_instance(SyntheticSpec(n=4, m=2, D=2, T=6))
    with pytest.raises(ValueError, match="load_sigma"):
        DemandForecast(base=base, load_sigma=-0.1)
    with pytest.raises(ValueError, match="diurnal_amp"):
        DemandForecast(base=base, diurnal_amp=1.0)
    with pytest.raises(ValueError, match="burst_prob"):
        DemandForecast(base=base, burst_prob=1.5)
    with pytest.raises(ValueError, match="burst_cap"):
        DemandForecast(base=base, burst_cap=0.5)
    with pytest.raises(ValueError, match="K"):
        fan_out(DemandForecast(base=base), K=0)


# -- batching: the one-dispatch contract -------------------------------

def test_plan_stochastic_one_dispatch_one_bucket():
    fc = gct_forecast(n=24, m=4, seed=1, burst_prob=0.1)
    d0 = dispatch_count()
    res = plan_stochastic(fc, StochasticConfig(scenarios=8, quantiles=3))
    assert res.lp_dispatches == 1
    assert res.buckets == 1
    assert dispatch_count() - d0 >= 1
    assert res.K == 8 and len(res.scenario_plans) == 8
    s = res.summary()
    assert s["converged_frac"] == 1.0
    # the cost bracket the CI gate pins on the golden grid
    assert s["mean_scenario_cost"] <= s["fleet_cost"] + 1e-9
    assert s["fleet_cost"] <= s["max_fleet_cost"] + 1e-9


def test_solve_scenarios_rejects_ragged_shapes():
    a = synthetic_instance(SyntheticSpec(n=6, m=2, D=2, T=8, seed=0))
    b = synthetic_instance(SyntheticSpec(n=7, m=2, D=2, T=8, seed=0))
    engine = FleetEngine(solver=SolverConfig(iters=100))
    with pytest.raises(ValueError, match="ONE \\(n, m, D, T'\\) shape"):
        engine.solve_scenarios([a, b])


def test_solve_scenarios_rejects_warm_started_sweeps():
    p = synthetic_instance(SyntheticSpec(n=6, m=2, D=2, T=8, seed=0))
    engine = FleetEngine(solver=SolverConfig(tol=5e-3, iters=200),
                         sweep=SweepConfig(warm_start=2))
    with pytest.raises(ValueError, match="warm_start"):
        engine.solve_scenarios([p, p])


def test_sweep_config_devices_validated_against_visible_devices():
    import jax

    too_many = jax.local_device_count() + 1
    with pytest.raises(ValueError, match="local JAX device"):
        SweepConfig(warm_start=2, pipeline=True, devices=too_many)


# -- trace fitting ------------------------------------------------------

@dataclasses.dataclass
class _Req:
    kind: str
    fleet: str = "f0"
    dem: np.ndarray | None = None
    start: np.ndarray | None = None
    end: np.ndarray | None = None
    ids: tuple = ()
    factor: float = 1.0


def test_fit_forecast_estimates_burst_channel():
    base = synthetic_instance(SyntheticSpec(n=6, m=2, D=2, T=8))
    dem = np.full((4, 2), 0.2)
    reqs = [
        _Req("admit", dem=dem),
        _Req("burst", ids=(0, 1), factor=2.5),
        _Req("arrive", dem=dem),
        _Req("burst", ids=(2,), factor=4.0),
        _Req("depart", ids=(3,)),
    ]
    fc = fit_forecast(reqs, base)
    assert fc.base is base
    assert 0.0 < fc.burst_prob <= 1.0
    assert fc.burst_prob == pytest.approx(3 / 8)  # 3 bursted / 8 admits
    assert fc.burst_alpha > 0
    assert fc.load_sigma > 0  # the ledger total moved across events
    assert fc.diurnal_amp == 0.0  # never estimated from traces
    # overrides pin channels instead of estimating them
    assert fit_forecast(reqs, base, burst_prob=0.5).burst_prob == 0.5


def test_fit_forecast_empty_trace_is_deterministic():
    base = synthetic_instance(SyntheticSpec(n=4, m=2, D=2, T=6))
    assert fit_forecast([], base).deterministic


# -- the serving hook ---------------------------------------------------

def test_service_preprovision_grows_plan_and_logs_event():
    from repro.serve import RightsizingService, TraceSpec, gct_trace, replay

    svc = RightsizingService()
    replay(svc, gct_trace(TraceSpec(fleets=1, requests=10, seed=0)),
           push_per_tick=8)
    name = svc.fleets[0]
    before = svc.fleet(name)
    res = svc.preprovision(
        name, config=StochasticConfig(scenarios=4, quantiles=3))
    after = svc.fleet(name)
    assert res.K == 4 and res.lp_dispatches == 1
    assert (after.plan >= before.plan).all()  # growth-only adoption
    ev = svc.events[-1]
    assert ev.scope == "preprovision" and ev.fleet == name
    assert ev.cost_after >= ev.cost_before
