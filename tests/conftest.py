"""Shared test configuration: bounded-examples Hypothesis profiles.

The fast tier (default) runs property suites with a small bounded
example count so `pytest -q` stays quick; the scheduled (cron) CI job
exports ``HYPOTHESIS_PROFILE=thorough`` for a deeper sweep.  Individual
``@settings(...)`` decorators still override the profile's defaults.
"""

import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # the 'test' extra is not installed; suites skip
    settings = None

if settings is not None:
    _COMMON = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )
    settings.register_profile("fast", max_examples=15, **_COMMON)
    settings.register_profile("thorough", max_examples=75, **_COMMON)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "fast"))
