"""Demand-forecast model: the distribution a scenario fan-out samples.

The paper plans a minimum-cost cluster for a *known* timeline; real
traffic is a distribution.  ``DemandForecast`` keeps that distribution
small and explicit: a **point-forecast base instance** (the expected
task set, spans, and node-type catalogue — any ``Problem``) plus three
multiplicative uncertainty channels applied per scenario:

  * **load** — one scenario-wide lognormal factor (mean 1,
    ``load_sigma``): "the whole day runs hot/cold";
  * **diurnal** — a phase-jittered sinusoid over each task's start
    slot (amplitude ``diurnal_amp``): "the peaks land earlier/later
    than forecast" (the shape mirrors ``workload.gct``'s diurnal
    arrival mix, which is where the default base comes from);
  * **bursts** — per-task Pareto-tail spikes (probability
    ``burst_prob``, tail index ``burst_alpha``, capped at
    ``burst_cap``): the heavy-tail channel CVaR selection exists for.

All channels are multiplicative on demands, so every scenario keeps
the base's spans and catalogue — after timeline trimming all K
scenarios share ONE ``(n, m, D, T')`` shape and the engine solves them
in one batched dispatch (``FleetEngine.solve_scenarios``).  A forecast
with all three channels at zero is *deterministic*: every scenario
equals the base bit-for-bit, so stochastic planning degenerates to the
paper's point-forecast plan exactly (pinned by a Hypothesis test).

``gct_forecast`` parameterizes a forecast from the GCT-2019-like
generator; ``fit_forecast`` estimates the channel parameters from a
replayed arrival trace (``repro.serve.trace``-shaped requests).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import Problem
from repro.workload.gct import gct_like_instance

__all__ = ["DemandForecast", "gct_forecast", "fit_forecast"]


@dataclasses.dataclass(frozen=True)
class DemandForecast:
    """A demand distribution around a point-forecast ``base`` instance.

    >>> from repro.workload import SyntheticSpec, synthetic_instance
    >>> base = synthetic_instance(SyntheticSpec(n=6, m=2, D=2, T=8))
    >>> DemandForecast(base=base).deterministic
    False
    >>> DemandForecast(base=base, load_sigma=0.0, diurnal_amp=0.0,
    ...                burst_prob=0.0).deterministic
    True
    >>> DemandForecast(base=base, burst_alpha=0.0)
    Traceback (most recent call last):
        ...
    ValueError: burst_alpha must be positive, got 0.0
    """

    base: Problem
    load_sigma: float = 0.15
    diurnal_amp: float = 0.10
    burst_prob: float = 0.05
    burst_alpha: float = 1.8
    burst_cap: float = 8.0

    def __post_init__(self):
        if not isinstance(self.base, Problem):
            raise ValueError(
                f"base must be a Problem (the point forecast), got "
                f"{type(self.base).__name__}")
        if self.base.n == 0:
            raise ValueError("base must have at least one task")
        if self.load_sigma < 0:
            raise ValueError(
                f"load_sigma must be >= 0, got {self.load_sigma!r}")
        if not 0.0 <= self.diurnal_amp < 1.0:
            raise ValueError(
                f"diurnal_amp must be in [0, 1), got {self.diurnal_amp!r}")
        if not 0.0 <= self.burst_prob <= 1.0:
            raise ValueError(
                f"burst_prob must be in [0, 1], got {self.burst_prob!r}")
        if not self.burst_alpha > 0:
            raise ValueError(
                f"burst_alpha must be positive, got {self.burst_alpha!r}")
        if self.burst_cap < 1.0:
            raise ValueError(
                f"burst_cap must be >= 1 (a burst only ever grows "
                f"demand), got {self.burst_cap!r}")

    @property
    def deterministic(self) -> bool:
        """True when every channel is off: all scenarios == base."""
        return (self.load_sigma == 0.0 and self.diurnal_amp == 0.0
                and self.burst_prob == 0.0)

    def factors(self, rng: np.random.Generator) -> np.ndarray:
        """One scenario's per-task demand multipliers, shape ``(n,)``.

        Draw order is fixed (load, phase, burst mask, burst tails) so
        a given generator state always yields the same scenario.  A
        deterministic forecast returns exactly 1.0 everywhere —
        multiplying by it is a bit-exact no-op.
        """
        base = self.base
        if self.deterministic:
            return np.ones(base.n, dtype=np.float64)
        load = math.exp(rng.normal(-0.5 * self.load_sigma**2,
                                   self.load_sigma)) \
            if self.load_sigma > 0 else 1.0
        phase = rng.uniform(0.0, 2.0 * math.pi)
        diurnal = 1.0 + self.diurnal_amp * np.sin(
            2.0 * math.pi * base.start / max(base.T, 1) - phase) \
            if self.diurnal_amp > 0 else np.ones(base.n)
        burst = np.ones(base.n)
        if self.burst_prob > 0:
            hit = rng.random(base.n) < self.burst_prob
            # Pareto(alpha) with x_m = 1: heavy right tail, so a few
            # tasks per scenario spike hard — the regime that separates
            # CVaR selection from expected-cost selection
            tail = (1.0 - rng.random(base.n)) ** (-1.0 / self.burst_alpha)
            burst = np.where(hit, np.minimum(tail, self.burst_cap), 1.0)
        return load * diurnal * burst


def gct_forecast(n: int = 200, m: int = 8, seed: int = 0,
                 cost_model: str = "gce", e: float = 1.0,
                 **channels) -> DemandForecast:
    """A forecast whose base is a GCT-2019-like paper-protocol instance
    (``workload.gct.gct_like_instance``); ``channels`` override the
    uncertainty parameters (``load_sigma``/``diurnal_amp``/
    ``burst_prob``/``burst_alpha``/``burst_cap``).

    >>> fc = gct_forecast(n=16, m=4, burst_prob=0.1)
    >>> (fc.base.n, fc.base.m, fc.burst_prob)
    (16, 4, 0.1)
    """
    base = gct_like_instance(n=n, m=m, seed=seed,
                             cost_model=cost_model, e=e)
    return DemandForecast(base=base, **channels)


def _pareto_mle(factors: np.ndarray) -> float:
    """Pareto tail-index MLE with x_m = 1: alpha = k / sum(log f)."""
    logs = np.log(np.maximum(factors, 1.0 + 1e-12))
    return float(len(logs) / max(logs.sum(), 1e-12))


def fit_forecast(requests, base: Problem, **overrides) -> DemandForecast:
    """Trace-fitted mode: estimate the uncertainty channels from a
    replayed arrival trace and return a ``DemandForecast`` around
    ``base``.

    ``requests`` is any sequence of ``repro.serve``-shaped request
    records (duck-typed on ``kind``/``fleet``/``dem``/``ids``/
    ``factor`` so this module never imports the serving layer):

      * ``burst_prob`` — bursted-task events over total live-task
        events (each burst request hits ``len(ids)`` tasks);
      * ``burst_alpha`` — Pareto tail-index MLE over the observed
        burst factors (x_m = 1);
      * ``load_sigma`` — the trace is re-applied fleet-by-fleet
        (admit/arrive grow the demand ledger, depart removes rows by
        id, burst multiplies them — mirroring the service's own id
        assignment) and the std of each fleet's log total-demand
        trajectory is pooled by median across fleets.

    Estimates are deterministic in the trace; keyword ``overrides``
    pin any channel instead of estimating it (``diurnal_amp`` is never
    estimated — traces carry no slot phase — so it defaults to 0
    unless overridden).

    >>> from repro.workload import SyntheticSpec, synthetic_instance
    >>> base = synthetic_instance(SyntheticSpec(n=6, m=2, D=2, T=8))
    >>> fit_forecast([], base).deterministic
    True
    """
    ledgers: dict[str, dict[int, float]] = {}
    next_id: dict[str, int] = {}
    totals: dict[str, list[float]] = {}
    burst_factors: list[float] = []
    bursted = 0
    task_events = 0
    for req in requests:
        name = req.fleet
        ledger = ledgers.setdefault(name, {})
        if req.kind in ("admit", "arrive"):
            rows = np.asarray(req.dem, dtype=np.float64).sum(axis=1)
            start = next_id.get(name, 0)
            for i, v in enumerate(rows):
                ledger[start + i] = float(v)
            next_id[name] = start + len(rows)
            task_events += len(rows)
        elif req.kind == "depart":
            for i in req.ids:
                ledger.pop(int(i), None)
        elif req.kind == "burst":
            for i in req.ids:
                if int(i) in ledger:
                    ledger[int(i)] *= float(req.factor)
            burst_factors.append(float(req.factor))
            bursted += len(req.ids)
        else:  # replan and friends carry no demand information
            continue
        total = sum(ledger.values())
        if total > 0:
            totals.setdefault(name, []).append(total)

    est: dict[str, float] = {"diurnal_amp": 0.0}
    est["burst_prob"] = (min(1.0, bursted / task_events)
                         if task_events else 0.0)
    est["burst_alpha"] = (_pareto_mle(np.asarray(burst_factors))
                          if burst_factors else DemandForecast.burst_alpha)
    sigmas = [float(np.std(np.log(np.asarray(t))))
              for t in totals.values() if len(t) >= 2]
    est["load_sigma"] = float(np.median(sigmas)) if sigmas else 0.0
    est.update(overrides)
    return DemandForecast(base=base, **est)
