"""Seeded scenario fan-out: forecast -> K same-shape ``Problem``s.

``fan_out`` samples K Monte-Carlo scenarios from a ``DemandForecast``.
Every scenario is the forecast's base instance with its demand vectors
multiplied by that scenario's sampled factors (load x diurnal x burst,
see ``forecast.py``), clamped per task to the headroom of its
best-fitting node-type so every scenario stays placeable — spans and
catalogue are untouched, so
all K trimmed instances share ONE ``(n, m, D, T')`` shape and
``FleetEngine.solve_scenarios`` solves them in a single batched
dispatch (the whole point of fanning out on the batched engine; a
fan-out that also perturbed arrival *counts* would fracture the shape
and pay one compile per scenario).

Determinism contract: scenario ``k`` of ``fan_out(fc, K, seed)`` is a
pure function of ``(forecast, seed, k)`` — each scenario draws from
its own ``np.random.default_rng([_FANOUT_TAG, seed, k])`` stream — so
draws are bit-reproducible, independent of K (growing K appends
scenarios without moving the first ones), and independent across
scenarios.  Tests pin same-seed-twice equality and the K-prefix
property.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Problem, trim_timeline

from .forecast import DemandForecast

__all__ = ["ScenarioSet", "fan_out"]

# namespaces the fan-out's seed streams away from every other
# default_rng(seed) user in the repo (workload generators, traces)
_FANOUT_TAG = 0x5C3A


@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """K sampled scenarios on a shared shape (``fan_out``'s output).

    problems: the K scenario instances (original timeline; the engine
        trims them on pack, and all K trim to one shape).
    factors: (K, n) sampled per-task demand multipliers *before* the
        feasibility clamp (the raw uncertainty, kept for telemetry).
    forecast / seed: provenance, enough to re-draw the set exactly.
    """

    forecast: DemandForecast
    problems: tuple[Problem, ...]
    factors: np.ndarray
    seed: int

    def __post_init__(self):
        if len(self.problems) != self.factors.shape[0]:
            raise ValueError(
                f"factors must have one row per scenario, got "
                f"{self.factors.shape[0]} rows for "
                f"{len(self.problems)} problems")

    @property
    def K(self) -> int:
        return len(self.problems)

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """The shared trimmed ``(n, m, D, T')`` shape."""
        t = trim_timeline(self.problems[0])[0]
        return (t.n, t.m, t.D, t.T)


def fan_out(forecast: DemandForecast, K: int, seed: int = 0) -> ScenarioSet:
    """Fan a forecast into K deterministic scenario instances.

    >>> from repro.workload import SyntheticSpec, synthetic_instance
    >>> base = synthetic_instance(SyntheticSpec(n=6, m=2, D=2, T=8))
    >>> fc = DemandForecast(base=base, burst_prob=0.2)
    >>> ss = fan_out(fc, K=4, seed=1)
    >>> ss.K, ss.factors.shape
    (4, (4, 6))
    >>> bool((fan_out(fc, K=4, seed=1).factors == ss.factors).all())
    True
    """
    if K < 1:
        raise ValueError(f"K must be >= 1, got {K!r}")
    base = forecast.base
    # per-task burst headroom: the largest factor under which the task
    # still fits SOME single node-type along every dimension (the same
    # best-fitting-SKU clamp the serving layer applies at admission —
    # clipping to the elementwise-max capacity would not do: max-cpu
    # and max-memory can live on different types).  A feasible base
    # has headroom >= 1, so a factor of exactly 1.0 survives the clamp
    # untouched and a zero-variance forecast reproduces the base
    # bit-for-bit.
    cap = base.node_types.cap
    with np.errstate(divide="ignore"):
        ratios = np.where(base.dem[:, None, :] > 0,
                          cap[None, :, :] / base.dem[:, None, :],
                          np.inf)
    headroom = ratios.min(axis=2).max(axis=1)  # (n,)
    problems: list[Problem] = []
    factors = np.empty((K, base.n), dtype=np.float64)
    for k in range(K):
        rng = np.random.default_rng([_FANOUT_TAG, seed, k])
        f = forecast.factors(rng)
        factors[k] = f
        dem = base.dem * np.minimum(f, headroom)[:, None]
        problems.append(dataclasses.replace(base, dem=dem))
    return ScenarioSet(forecast=forecast, problems=tuple(problems),
                       factors=factors, seed=seed)
