"""CVaR fleet selection over a solved scenario fan-out.

One batched solve gives every scenario its own minimum-cost fleet
``R_s`` (node counts per type).  A *robust* fleet ``F`` must then be
chosen once, before knowing which scenario arrives.  Selection works
on two cost channels:

  * **purchase** — ``fleet_cost(F) = sum_B F[B] * cost[B]``;
  * **overload** — ``ov(s, F) = sum_B max(0, R_s[B] - F[B]) * cost[B]``,
    the cost-weighted node shortfall of running scenario ``s`` on
    ``F``: the price of the on-demand capacity you would have to rent
    (or the demand you would shed) when the scenario outgrows the
    fleet — the rent-vs-own trade of Renting Servers for
    Multi-Parameter Jobs (arXiv 2404.15444) collapsed to its
    first-order term.

The objective per candidate fleet is

    E_s[cost] + lambda * CVaR_alpha(overload) + reconfiguration

where ``E_s[cost] = fleet_cost + premium * mean_s ov(s, F)`` (the
expected bill including top-ups), ``CVaR_alpha`` is the mean of the
worst ``ceil((1-alpha) * K)`` scenario overloads (tail risk — what
expected-cost selection is blind to), and the reconfiguration term is
Eva-style (arXiv 2503.07437): ``recfg_weight * sum_B |F[B] -
current[B]| * cost[B]`` prices node churn relative to a currently
deployed fleet, so re-planning under new forecasts does not thrash.

Candidates are the per-scenario fleets, their pairwise elementwise
maxes (unions covering two scenarios at once, which per-type
quantiles cannot express), the elementwise per-type quantile chain
across scenarios (q = 0..1, inclusive of the elementwise max, which
has zero overload by construction) and the current fleet — a small
menu whose extremes bracket the cost/risk frontier.

``plan_stochastic`` is the end-to-end entry: fan out, solve all K in
ONE batched dispatch (``FleetEngine.solve_scenarios``), place, select,
and emit a structured ``StochasticResult`` with the frontier rows the
CLI and benchmarks print.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time

import numpy as np

from repro.core import (FleetEngine, SolverConfig, pack_problems,
                        trim_timeline)
from repro.core.batch import dispatch_count
from repro.core.lp_pdhg import SolveStats
from repro.core.placement import FIT_POLICIES

from .forecast import DemandForecast
from .scenarios import ScenarioSet, fan_out

__all__ = ["StochasticConfig", "StochasticResult", "cvar",
           "candidate_fleets", "overload_costs", "plan_stochastic"]

_STOCHASTIC_ALGOS = ("lp-map", "lp-map-f", "penalty-map",
                     "penalty-map-f")


def cvar(x: np.ndarray, alpha: float) -> float:
    """Conditional value-at-risk of a discrete equal-weight sample:
    the mean of the worst ``ceil((1 - alpha) * K)`` values.

    Non-decreasing in ``alpha`` for fixed ``x`` (shrinking the
    averaged tail can only raise its mean): ``cvar(x, 0) == mean`` and
    ``cvar(x, alpha -> 1) == max``.

    >>> cvar(np.array([0.0, 1.0, 2.0, 3.0]), 0.0)
    1.5
    >>> cvar(np.array([0.0, 1.0, 2.0, 3.0]), 0.5)
    2.5
    >>> cvar(np.array([0.0, 1.0, 2.0, 3.0]), 0.9)
    3.0
    """
    x = np.asarray(x, dtype=np.float64)
    if x.ndim != 1 or len(x) == 0:
        raise ValueError(f"cvar needs a non-empty 1-D sample, got "
                         f"shape {x.shape}")
    if not 0.0 <= alpha < 1.0:
        raise ValueError(f"alpha must be in [0, 1), got {alpha!r}")
    k = max(1, math.ceil((1.0 - alpha) * len(x)))
    return float(np.mean(np.sort(x)[len(x) - k:]))


@dataclasses.dataclass(frozen=True)
class StochasticConfig:
    """Stochastic-rightsizing knobs (fan-out size + CVaR objective).

    scenarios: K, the Monte-Carlo fan-out size (one batched dispatch).
    seed: fan-out seed (scenario k is a pure function of (forecast,
        seed, k) — see ``scenarios.fan_out``).
    cvar_alpha: tail level of the CVaR term (0.9 = average of the
        worst 10% of scenarios).
    cvar_lambda: weight of the CVaR term; 0 recovers expected-cost-
        only selection (the comparison column every frontier prints).
    overload_premium: price multiplier of the expected shortfall in
        the E[cost] term (renting capacity on demand costs more than
        owning it).
    recfg_weight: Eva-style reconfiguration weight on |F - current|
        node churn (0 = plan from scratch).
    quantiles: resolution of the per-type quantile candidate chain.
    algo: which mapping algorithm prices the per-scenario fleets.

    >>> StochasticConfig().cvar_alpha
    0.9
    >>> StochasticConfig(cvar_alpha=1.0)
    Traceback (most recent call last):
        ...
    ValueError: cvar_alpha must be in [0, 1), got 1.0
    """

    scenarios: int = 64
    seed: int = 0
    cvar_alpha: float = 0.9
    cvar_lambda: float = 1.0
    overload_premium: float = 3.0
    recfg_weight: float = 0.0
    quantiles: int = 9
    algo: str = "lp-map-f"
    frontier_alphas: tuple[float, ...] = (0.5, 0.75, 0.9, 0.95, 0.99)

    def __post_init__(self):
        if self.scenarios < 1:
            raise ValueError(
                f"scenarios must be >= 1, got {self.scenarios!r}")
        if not 0.0 <= self.cvar_alpha < 1.0:
            raise ValueError(
                f"cvar_alpha must be in [0, 1), got {self.cvar_alpha!r}")
        if self.cvar_lambda < 0:
            raise ValueError(
                f"cvar_lambda must be >= 0, got {self.cvar_lambda!r}")
        if self.overload_premium < 0:
            raise ValueError(
                f"overload_premium must be >= 0, got "
                f"{self.overload_premium!r}")
        if self.recfg_weight < 0:
            raise ValueError(
                f"recfg_weight must be >= 0, got {self.recfg_weight!r}")
        if self.quantiles < 2:
            raise ValueError(
                f"quantiles must be >= 2 (the chain needs both "
                f"extremes), got {self.quantiles!r}")
        if self.algo not in _STOCHASTIC_ALGOS:
            raise ValueError(
                f"algo must be one of {_STOCHASTIC_ALGOS}, got "
                f"{self.algo!r}")
        if not all(0.0 <= a < 1.0 for a in self.frontier_alphas):
            raise ValueError(
                f"frontier_alphas must all be in [0, 1), got "
                f"{self.frontier_alphas!r}")


def candidate_fleets(plans: np.ndarray, quantiles: int = 9,
                     current: np.ndarray | None = None) -> np.ndarray:
    """The candidate menu: per-scenario fleets, their pairwise
    elementwise maxes (a fleet covering scenarios s AND t exactly —
    quantiles are per-type and miss such unions), the per-type
    quantile chain (q = 0..1, elementwise, so the chain is nested:
    higher q never buys fewer nodes of any type; q = 1 is the
    zero-overload elementwise max) and the current fleet, deduped and
    sorted by purchase footprint.

    >>> plans = np.array([[1, 0], [2, 1], [4, 1]])
    >>> candidate_fleets(plans, quantiles=3).tolist()
    [[1, 0], [2, 1], [4, 1]]
    >>> candidate_fleets(np.array([[2, 0], [0, 2]]), quantiles=2).tolist()
    [[0, 0], [0, 2], [2, 0], [2, 2]]
    """
    plans = np.asarray(plans, dtype=np.int64)
    qs = np.linspace(0.0, 1.0, quantiles)
    chain = np.quantile(plans, qs, axis=0, method="higher").astype(np.int64)
    uniq = np.unique(plans, axis=0)
    pairs = np.maximum(uniq[:, None, :], uniq[None, :, :]) \
        .reshape(-1, plans.shape[1])
    rows = [tuple(r) for r in pairs] + [tuple(r) for r in chain]
    if current is not None:
        rows.append(tuple(int(v) for v in current))
    menu = sorted(set(rows), key=lambda r: (sum(r), r))
    return np.asarray(menu, dtype=np.int64)


def overload_costs(plans: np.ndarray, fleets: np.ndarray,
                   node_cost: np.ndarray) -> np.ndarray:
    """(K, J) cost-weighted node shortfall of each scenario's required
    fleet ``plans[s]`` against each candidate ``fleets[j]``."""
    short = np.maximum(plans[:, None, :] - fleets[None, :, :], 0)
    return (short * node_cost[None, None, :]).sum(axis=2)


@dataclasses.dataclass
class StochasticResult:
    """Structured output of ``plan_stochastic``.

    fleet / fleet_cost: the CVaR-selected robust fleet (node counts
        per type) and its purchase cost.
    expected_fleet / expected_fleet_cost: the lambda=0 selection (same
        premium, no tail term) — the comparison every frontier prints.
    scenario_costs: (K,) each scenario's own optimal protocol cost.
    scenario_plans: (K, m) each scenario's required node counts.
    overload / expected_overload: (K,) per-scenario shortfall cost of
        the robust / expected-only fleet.
    max_fleet_cost: purchase cost of the elementwise-max fleet (zero
        overload by construction — the robust plan's upper bracket).
    frontier: rows over (lambda=0, then the alpha grid at the
        configured lambda); the row matching the configured alpha is
        the selection.
    stats: SolveStats of the batched scenario dispatch(es);
    lp_dispatches / buckets: how many compiled LP dispatches the K
        scenarios cost (== 1 without sharding) and the bucket count
        (== 1 by the shared-shape construction).
    """

    config: StochasticConfig
    fleet: np.ndarray
    fleet_cost: float
    expected_fleet: np.ndarray
    expected_fleet_cost: float
    scenario_costs: np.ndarray
    scenario_plans: np.ndarray
    overload: np.ndarray
    expected_overload: np.ndarray
    max_fleet_cost: float
    frontier: list[dict]
    stats: list[SolveStats]
    lp_dispatches: int
    buckets: int
    timings: dict

    @property
    def K(self) -> int:
        return len(self.scenario_costs)

    @property
    def worst_overload(self) -> float:
        return float(self.overload.max())

    @property
    def cvar_overload(self) -> float:
        return cvar(self.overload, self.config.cvar_alpha)

    def to_rows(self) -> list[dict]:
        """Flat per-scenario rows (JSON/CSV-ready)."""
        return [{
            "scenario": s,
            "cost": float(self.scenario_costs[s]),
            "plan": self.scenario_plans[s].tolist(),
            "overload_robust": float(self.overload[s]),
            "overload_expected": float(self.expected_overload[s]),
        } for s in range(self.K)]

    def summary(self) -> dict:
        """The benchmark/CI blob: deterministic numbers only (no wall
        clock), rounded to 6 decimals like the golden tables."""
        r6 = lambda v: round(float(v), 6)  # noqa: E731
        return {
            "K": self.K,
            "seed": self.config.seed,
            "cvar_alpha": self.config.cvar_alpha,
            "cvar_lambda": self.config.cvar_lambda,
            "overload_premium": self.config.overload_premium,
            "recfg_weight": self.config.recfg_weight,
            "algo": self.config.algo,
            "fleet": self.fleet.tolist(),
            "fleet_cost": r6(self.fleet_cost),
            "expected_fleet": self.expected_fleet.tolist(),
            "expected_fleet_cost": r6(self.expected_fleet_cost),
            "mean_scenario_cost": r6(self.scenario_costs.mean()),
            "worst_scenario_cost": r6(self.scenario_costs.max()),
            "max_fleet_cost": r6(self.max_fleet_cost),
            "mean_overload": r6(self.overload.mean()),
            "cvar_overload": r6(self.cvar_overload),
            "worst_overload": r6(self.worst_overload),
            "expected_fleet_worst_overload": r6(
                self.expected_overload.max()),
            "frontier": self.frontier,
            "lp_dispatches": self.lp_dispatches,
            "buckets": self.buckets,
            "converged_frac": r6(np.mean([
                float(np.mean(s.converged)) for s in self.stats])
                if self.stats else 1.0),
            "total_iters": int(sum(int(s.iterations.sum())
                                   for s in self.stats)),
        }

    def to_json(self, indent: int | None = None) -> str:
        blob = dict(self.summary())
        blob["scenarios"] = self.to_rows()
        blob["timings"] = self.timings
        return json.dumps(blob, indent=indent)


def _select(fleets: np.ndarray, ov: np.ndarray, node_cost: np.ndarray,
            alpha: float, lam: float, premium: float,
            recfg_weight: float, current: np.ndarray | None) -> int:
    """Index of the objective-minimizing candidate (deterministic
    tie-break: lower worst-case overload, then lower purchase cost,
    then lexicographic fleet)."""
    costs = (fleets * node_cost[None, :]).sum(axis=1)
    recfg = np.zeros(len(fleets))
    if current is not None and recfg_weight > 0:
        churn = np.abs(fleets - np.asarray(current)[None, :])
        recfg = recfg_weight * (churn * node_cost[None, :]).sum(axis=1)
    obj = costs + premium * ov.mean(axis=0) + recfg
    if lam > 0:
        obj = obj + lam * np.array(
            [cvar(ov[:, j], alpha) for j in range(len(fleets))])
    keys = [(float(obj[j]), float(ov[:, j].max()), float(costs[j]),
             tuple(fleets[j])) for j in range(len(fleets))]
    return min(range(len(fleets)), key=keys.__getitem__)


def plan_stochastic(forecast: DemandForecast | ScenarioSet,
                    config: StochasticConfig = StochasticConfig(),
                    engine: FleetEngine | None = None,
                    current_fleet: np.ndarray | None = None,
                    ) -> StochasticResult:
    """Forecast -> fan-out -> ONE batched solve -> CVaR selection.

    ``forecast`` may be a ``DemandForecast`` (fanned out here with
    ``config.scenarios``/``config.seed``) or a pre-built
    ``ScenarioSet`` (reuse one fan-out across configs).  ``engine``
    defaults to a tolerance-stopped ``FleetEngine``; a passed engine
    must not configure warm-started sweeps (``solve_scenarios``
    rejects that).  ``current_fleet`` activates the Eva-style
    reconfiguration term of ``config.recfg_weight``.
    """
    scenario_set = forecast if isinstance(forecast, ScenarioSet) \
        else fan_out(forecast, config.scenarios, config.seed)
    problems = list(scenario_set.problems)
    base = scenario_set.forecast.base
    node_cost = base.node_types.cost
    if engine is None:
        engine = FleetEngine(solver=SolverConfig(tol=5e-3, iters=4000),
                             algos=(config.algo,))

    t0 = time.perf_counter()
    d0 = dispatch_count()
    lp_results, stats = engine.solve_scenarios(problems)
    lp_dispatches = dispatch_count() - d0
    lp_s = time.perf_counter() - t0

    # one lockstep placement pass per fit policy over the shared-shape
    # batch; each scenario keeps its own cheapest feasible fleet
    t0 = time.perf_counter()
    filling = config.algo.endswith("-f")
    trimmed = [trim_timeline(p)[0] for p in problems]
    if config.algo.startswith("penalty-map"):
        from repro.core import penalty_map

        mapsets = [[penalty_map(t, kind) for t in trimmed]
                   for kind in ("avg", "max")]
    else:
        mapsets = [[r.mapping for r in lp_results]]
    batch = pack_problems(trimmed, assume_trimmed=True)
    K, m = len(problems), base.m
    best_cost = np.full(K, np.inf)
    plans = np.zeros((K, m), dtype=np.int64)
    for maps in mapsets:
        for fit in FIT_POLICIES:
            sols = engine.place(batch, maps, fit=fit, filling=filling)
            for s, (t, sol) in enumerate(zip(batch.problems, sols)):
                c = sol.cost(t)
                if c < best_cost[s]:
                    best_cost[s] = c
                    plans[s] = sol.nodes_per_type(t)
    place_s = time.perf_counter() - t0

    fleets = candidate_fleets(plans, quantiles=config.quantiles,
                              current=current_fleet)
    ov = overload_costs(plans, fleets, node_cost)
    fleet_costs = (fleets * node_cost[None, :]).sum(axis=1)

    def _row(alpha: float, lam: float, j: int) -> dict:
        r6 = lambda v: round(float(v), 6)  # noqa: E731
        return {
            "alpha": alpha, "lambda": lam,
            "fleet": fleets[j].tolist(),
            "fleet_cost": r6(fleet_costs[j]),
            "mean_overload": r6(ov[:, j].mean()),
            "cvar_overload": r6(cvar(ov[:, j], alpha)),
            "worst_overload": r6(ov[:, j].max()),
        }

    sel = dict(alpha=config.cvar_alpha, lam=config.cvar_lambda,
               premium=config.overload_premium,
               recfg_weight=config.recfg_weight, current=current_fleet)
    j_exp = _select(fleets, ov, node_cost, **{**sel, "lam": 0.0})
    frontier = [_row(config.cvar_alpha, 0.0, j_exp)]
    alphas = sorted(set(config.frontier_alphas) | {config.cvar_alpha})
    j_sel = j_exp
    for alpha in alphas:
        j = _select(fleets, ov, node_cost, **{**sel, "alpha": alpha})
        frontier.append(_row(alpha, config.cvar_lambda, j))
        if alpha == config.cvar_alpha:
            j_sel = j

    return StochasticResult(
        config=config,
        fleet=fleets[j_sel],
        fleet_cost=float(fleet_costs[j_sel]),
        expected_fleet=fleets[j_exp],
        expected_fleet_cost=float(fleet_costs[j_exp]),
        scenario_costs=best_cost,
        scenario_plans=plans,
        overload=ov[:, j_sel],
        expected_overload=ov[:, j_exp],
        max_fleet_cost=float(
            (plans.max(axis=0) * node_cost).sum()),
        frontier=frontier,
        stats=list(stats),
        lp_dispatches=int(lp_dispatches),
        buckets=1,
        timings={"lp_s": lp_s, "place_s": place_s},
    )
