"""Stochastic rightsizing: plan under demand uncertainty.

The paper buys a minimum-cost cluster for a *known* timeline; this
layer plans for a demand *distribution*: a ``DemandForecast`` (point-
forecast base instance + load/diurnal/burst uncertainty channels) is
fanned into K seeded Monte-Carlo scenario instances on ONE shared
trimmed shape (``fan_out``), all K mapping LPs solve in a single
batched dispatch (``FleetEngine.solve_scenarios`` — the shape the
batched engine was built for), and ``plan_stochastic`` selects the
fleet minimizing ``E[cost] + lambda * CVaR_alpha(overload)`` with an
Eva-style reconfiguration penalty against the currently deployed
fleet.  See docs/stochastic.md for the model, the objective, and a
frontier walkthrough.
"""

from .forecast import DemandForecast, fit_forecast, gct_forecast
from .scenarios import ScenarioSet, fan_out
from .select import (StochasticConfig, StochasticResult,
                     candidate_fleets, cvar, overload_costs,
                     plan_stochastic)

__all__ = [
    "DemandForecast", "fit_forecast", "gct_forecast",
    "ScenarioSet", "fan_out",
    "StochasticConfig", "StochasticResult", "candidate_fleets",
    "cvar", "overload_costs", "plan_stochastic",
]
