"""Replayable arrival traces + the replay driver.

A trace is a deterministic (seeded) list of ``Request``s: fleet
admissions followed by a mixed stream of task arrivals, departures and
demand bursts — the online regime of Dynamic Vector Bin Packing laid
over the paper's workloads.  ``gct_trace`` samples tasks from the
GCT-2019-like pool (``workload.gct``); ``jobs_trace`` perturbs the
LM-job fleet (``workload.jobs``) with job-shaped arrivals.  The
generator mirrors the service's id assignment exactly (admission ids
are row ranks, each arrival takes the next ids), so departures and
bursts always reference live tasks and the same trace replays to the
same fleets.

``replay`` pushes the trace into a ``RightsizingService`` in bounded
chunks and ticks until drained — the benchmark harness for sustained
requests/sec and p99 re-plan latency.  ``replay_with_crash`` is the
crash-and-recover variant: it snapshots mid-replay, throws the live
service away, restores from the checkpoint, and finishes the trace —
because the snapshot round-trips every float exactly, the recovered
run adopts the same plans at the same costs as an uninterrupted one.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.workload.gct import gct_like_instance, gct_pool
from repro.workload.jobs import (BUILTIN_DEMANDS, HBM_PER_CHIP_GB,
                                 HOST_PER_CHIP_GB, _SKU_CHIPS,
                                 fleet_problem)

from .queue import Request

__all__ = ["TraceSpec", "gct_trace", "jobs_trace", "replay",
           "replay_with_crash"]

_MIN_FLEET_TASKS = 8  # departures never shrink a fleet below this


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Shape of a generated arrival trace (``requests`` counts every
    request, admissions included)."""

    fleets: int = 4
    requests: int = 200
    seed: int = 0
    n0: int = 48                   # tasks per fleet at admission
    m: int = 6                     # node-types per fleet
    arrive_frac: float = 0.5
    depart_frac: float = 0.25
    burst_frac: float = 0.25
    max_batch: int = 6             # tasks per arrival/departure/burst
    burst_span: tuple[float, float] = (1.2, 1.8)
    cost_model: str = "gce"
    cost_e: float = 0.9

    def __post_init__(self):
        if self.fleets < 1 or self.requests < self.fleets:
            raise ValueError(
                f"need >= 1 fleet and requests >= fleets, got "
                f"fleets={self.fleets} requests={self.requests}")
        mix = self.arrive_frac + self.depart_frac + self.burst_frac
        if not math.isclose(mix, 1.0, abs_tol=1e-9):
            raise ValueError(
                f"arrive/depart/burst fractions must sum to 1, got {mix}")


def _perturbations(rng, live: dict, next_id: dict, pool_sample, spec,
                   count: int) -> list[Request]:
    """The shared post-admission stream: arrivals/departures/bursts
    against the tracked live-id sets (mirroring the service)."""
    names = list(live)
    kinds = np.array(["arrive", "depart", "burst"])
    probs = np.array([spec.arrive_frac, spec.depart_frac,
                      spec.burst_frac])
    out: list[Request] = []
    while len(out) < count:
        name = names[int(rng.integers(len(names)))]
        kind = str(rng.choice(kinds, p=probs))
        k = int(rng.integers(1, spec.max_batch + 1))
        if kind == "depart" and len(live[name]) - k < _MIN_FLEET_TASKS:
            kind = "arrive"  # keep fleets non-trivial
        if kind == "arrive":
            dem, start, end = pool_sample(rng, name, k)
            out.append(Request(fleet=name, kind="arrive", dem=dem,
                               start=start, end=end))
            live[name].extend(range(next_id[name], next_id[name] + k))
            next_id[name] += k
        elif kind == "depart":
            picked = sorted(
                rng.choice(live[name], size=k, replace=False).tolist())
            out.append(Request(fleet=name, kind="depart",
                               ids=tuple(int(i) for i in picked)))
            live[name] = [i for i in live[name] if i not in set(picked)]
        else:
            k = min(k, len(live[name]))
            picked = sorted(
                rng.choice(live[name], size=k, replace=False).tolist())
            factor = float(rng.uniform(*spec.burst_span))
            out.append(Request(fleet=name, kind="burst",
                               ids=tuple(int(i) for i in picked),
                               factor=factor))
    return out


def gct_trace(spec: TraceSpec = TraceSpec()) -> list[Request]:
    """GCT-pool trace: each fleet is a paper-protocol instance
    (``gct_like_instance``), arrivals draw fresh tasks from the pool."""
    rng = np.random.default_rng(spec.seed)
    pool = gct_pool()
    requests: list[Request] = []
    live: dict[str, list[int]] = {}
    next_id: dict[str, int] = {}
    for f in range(spec.fleets):
        name = f"gct-{f}"
        prob = gct_like_instance(n=spec.n0, m=spec.m,
                                 seed=spec.seed * 1009 + f,
                                 cost_model=spec.cost_model, e=spec.cost_e)
        requests.append(Request(
            fleet=name, kind="admit", dem=prob.dem, start=prob.start,
            end=prob.end, node_types=prob.node_types, T=prob.T))
        live[name] = list(range(prob.n))
        next_id[name] = prob.n

    def pool_sample(rng, name, k):
        rows = rng.integers(0, len(pool["dem"]), size=k)
        return (pool["dem"][rows], pool["start"][rows], pool["end"][rows])

    requests += _perturbations(rng, live, next_id, pool_sample, spec,
                               spec.requests - spec.fleets)
    return requests


def jobs_trace(spec: TraceSpec = TraceSpec(fleets=2, n0=0),
               dryrun_dir: str = "results/dryrun") -> list[Request]:
    """LM-job trace: fleets are demand-scaled variants of the job
    schedule's fleet problem; arrivals are job-shaped tasks sampled
    from the builtin (arch, shape) catalogue with random hour windows."""
    rng = np.random.default_rng(spec.seed)
    base, _ = fleet_problem(dryrun_dir=dryrun_dir)
    requests: list[Request] = []
    live: dict[str, list[int]] = {}
    next_id: dict[str, int] = {}
    for f in range(spec.fleets):
        name = f"jobs-{f}"
        scale = float(rng.uniform(0.7, 1.3))
        dem = np.clip(base.dem * scale, 0.0,
                      base.node_types.cap.max(axis=0))
        requests.append(Request(
            fleet=name, kind="admit", dem=dem, start=base.start,
            end=base.end, node_types=base.node_types, T=base.T))
        live[name] = list(range(base.n))
        next_id[name] = base.n

    menu = sorted(BUILTIN_DEMANDS.items())
    max_chips = max(_SKU_CHIPS)

    def job_sample(rng, name, k):
        dems, starts, ends = [], [], []
        for _ in range(k):
            _, total_gb = menu[int(rng.integers(len(menu)))]
            chips = min(max_chips,
                        max(1, math.ceil(total_gb / (HBM_PER_CHIP_GB
                                                     * 0.85))))
            dems.append([chips, chips * HBM_PER_CHIP_GB * 0.95,
                         chips * HOST_PER_CHIP_GB * 0.5])
            s = int(rng.integers(0, 20))
            ends.append(min(23, s + int(rng.integers(2, 9))))
            starts.append(s)
        return (np.asarray(dems, dtype=float),
                np.asarray(starts, dtype=np.int64),
                np.asarray(ends, dtype=np.int64))

    requests += _perturbations(rng, live, next_id, job_sample, spec,
                               spec.requests - spec.fleets)
    return requests


def replay(service, requests: list[Request],
           push_per_tick: int = 8) -> dict:
    """Feed a trace into a service in chunks of ``push_per_tick``
    (sustained queue pressure), tick until drained, and return the
    service ``report()``."""
    i = 0
    while i < len(requests) or service.queue.pending:
        chunk = requests[i:i + push_per_tick]
        for req in chunk:
            service.submit(req)
        i += len(chunk) if chunk else 0
        if service.tick() is None and i >= len(requests):
            break
    return service.report()


def replay_with_crash(service, requests: list[Request], *,
                      crash_after_ticks: int, snapshot_dir: str,
                      push_per_tick: int = 8,
                      engine=None) -> tuple[dict, bool]:
    """``replay``, interrupted: after ``crash_after_ticks`` ticks the
    service is checkpointed to ``snapshot_dir``, the live object is
    DISCARDED (simulating a process crash — only the snapshot
    survives), a fresh service is restored from disk, and the replay
    finishes from the same trace position with the restored service's
    recovered queue.

    Returns ``(report, crashed)`` — ``crashed`` is False when the
    trace drained in fewer than ``crash_after_ticks`` ticks, in which
    case the report is just an uninterrupted replay's.  Snapshots
    round-trip all plan/warm-state floats exactly, so a crashed-and-
    recovered replay reports the same ``total_cost`` and
    ``proposed_cost_total`` as an uninterrupted one (wall-clock
    telemetry differs; downtime is excluded from re-plan latency).
    """
    from .service import RightsizingService

    if crash_after_ticks < 1:
        raise ValueError(
            f"crash_after_ticks must be >= 1, got {crash_after_ticks!r}")
    svc = service
    i = ticks = 0
    crashed = False
    while i < len(requests) or svc.queue.pending:
        chunk = requests[i:i + push_per_tick]
        for req in chunk:
            svc.submit(req)
        i += len(chunk) if chunk else 0
        if svc.tick() is None and i >= len(requests):
            break
        ticks += 1
        if not crashed and ticks >= crash_after_ticks:
            svc.snapshot(snapshot_dir)
            restore_engine = engine if engine is not None else svc.engine
            faults = svc.faults
            del svc  # the crash: all in-memory state is gone
            svc = RightsizingService.restore(
                snapshot_dir, engine=restore_engine, faults=faults)
            crashed = True
    return svc.report(), crashed
