"""``ServiceConfig``: the serving-loop member of the typed-config family.

The engine configs (``SolverConfig`` / ``PlacementConfig`` /
``SweepConfig``) describe ONE fleet evaluation; ``ServiceConfig``
describes the loop around many of them — how aggressively the admission
queue coalesces requests into a tick's micro-batch, when a perturbed
fleet may re-enter PDHG warm versus falling back to a cold solve, and
how the scale decision loop trades savings against reconfiguration
churn.  Like its siblings it is frozen and validates eagerly, so a bad
service is impossible to construct rather than failing mid-stream.
"""

from __future__ import annotations

import dataclasses

from repro.core.engine import DEFAULT_BUCKET_OVERHEAD

__all__ = ["ServiceConfig"]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving-loop configuration for ``RightsizingService``.

    Admission / micro-batching
        ``max_requests_per_tick`` caps how many queued requests one tick
        drains; ``max_buckets`` / ``bucket_overhead`` feed the same
        ``plan_buckets`` planner the engine uses, here to partition the
        tick's *touched fleets* into shape buckets — the bucket holding
        the oldest pending request becomes the tick's single dispatch
        and the rest requeue at the front (FIFO fairness).
        ``shape_quantum`` rounds each tick's padded task/slot dims up
        to a multiple, so consecutive ticks whose fleets drift by a few
        tasks reuse one compiled solve instead of recompiling per
        shape (padding is exact, so costs are unaffected).

    Overload shedding / SLO admission
        ``max_pending`` bounds the backlog: when the queue exceeds it
        at a tick boundary, queued ``replan``s are shed with structured
        ``ShedEvent``s (expired-deadline first, then coalesced-away,
        then stalest) until the backlog fits again.  State-changing
        requests (``admit``/``arrive``/``depart``/``burst``) are NEVER
        shed.  ``None`` (the default) disables shedding.  Per-request
        ``deadline_s`` SLOs feed the deadline-miss telemetry whether or
        not shedding is on.

    Fault handling
        ``max_request_retries`` bounds how often a request whose
        application (or whose tick's solve/verify) fails is retried
        before it is quarantined with its error — a poison request
        costs a bounded number of ticks instead of wedging the queue.
        0 quarantines on the first failure.

    Warm starts
        ``warm_start`` re-enters PDHG from each fleet's previous
        ``PDHGState`` (task rows and trimmed time slots re-aligned by
        id).  ``max_shape_drift`` is the fallback knob: when more than
        this fraction of a fleet's task rows or kept time slots no
        longer match the stored state, that lane cold-starts instead.
        ``cost_drift_bound_pct`` documents the warm-vs-cold parity
        bound on the *proposed* placement-cost total
        (``report()['proposed_cost_total']``): both solves stop at the
        same tolerance, so replaying one trace warm and cold proposes
        near-identical aggregate costs — they differ only by which
        epsilon-optimal vertex each solve lands on.  The default budget
        (5%) covers the Ruiz-scaled solver: equilibration changes the
        trajectory, so warm and cold runs land on different degenerate
        vertices more often (measured ~3.8% on the acceptance trace,
        vs ~1.6% unscaled) while cutting warm re-solve iterations ~3x.
        The noise is two-sided — neither replay is systematically
        cheaper.  Tests and the CI gate hold this bound.  *Adopted*
        plan costs are NOT bounded
        this tightly: the flag-gated decision loop is path-dependent
        (a cooldown latched on one run but not the other compounds
        over subsequent ticks), so ``total_cost`` may drift several
        times further while every individual proposal stays in bound.

    Scale decision loop
        Scale-OUT is forced (holding a too-small fleet is infeasible).
        Scale-IN must pass every flag: a cooldown of
        ``scale_in_cooldown`` ticks since the fleet's last scale-in,
        a savings fraction of at least ``min_scale_in_savings``, and an
        Eva-style reconfiguration payback — projected savings over
        ``payback_ticks`` must exceed ``reconfig_weight`` x the node
        churn cost (each changed node is priced at that fraction of its
        hourly cost, standing in for drain/migration).  A rejected
        scale-in holds the superset ``max(current, required)`` so the
        proposed placement stays feasible without thrash.

    >>> ServiceConfig().warm_start
    True
    >>> ServiceConfig(max_requests_per_tick=0)
    Traceback (most recent call last):
        ...
    ValueError: max_requests_per_tick must be >= 1, got 0
    >>> ServiceConfig(max_shape_drift=1.5)
    Traceback (most recent call last):
        ...
    ValueError: max_shape_drift must be in [0, 1], got 1.5
    """

    max_requests_per_tick: int = 32
    max_buckets: int = 4
    bucket_overhead: float = DEFAULT_BUCKET_OVERHEAD
    warm_start: bool = True
    max_shape_drift: float = 0.5
    cost_drift_bound_pct: float = 5.0
    reconfig_weight: float = 0.5
    payback_ticks: int = 12
    scale_in_cooldown: int = 3
    min_scale_in_savings: float = 0.02
    filling: bool = True
    shape_quantum: int = 8
    max_pending: int | None = None
    max_request_retries: int = 2

    def __post_init__(self):
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1 (or None to disable "
                f"shedding), got {self.max_pending!r}")
        if self.max_request_retries < 0:
            raise ValueError(
                f"max_request_retries must be >= 0, got "
                f"{self.max_request_retries!r}")
        if self.max_requests_per_tick < 1:
            raise ValueError(
                f"max_requests_per_tick must be >= 1, got "
                f"{self.max_requests_per_tick!r}")
        if self.max_buckets < 1:
            raise ValueError(
                f"max_buckets must be >= 1, got {self.max_buckets!r}")
        if self.bucket_overhead < 0:
            raise ValueError(
                f"bucket_overhead must be >= 0, got "
                f"{self.bucket_overhead!r}")
        if not 0.0 <= self.max_shape_drift <= 1.0:
            raise ValueError(
                f"max_shape_drift must be in [0, 1], got "
                f"{self.max_shape_drift!r}")
        if self.cost_drift_bound_pct < 0:
            raise ValueError(
                f"cost_drift_bound_pct must be >= 0, got "
                f"{self.cost_drift_bound_pct!r}")
        if self.reconfig_weight < 0:
            raise ValueError(
                f"reconfig_weight must be >= 0, got "
                f"{self.reconfig_weight!r}")
        if self.payback_ticks < 1:
            raise ValueError(
                f"payback_ticks must be >= 1, got {self.payback_ticks!r}")
        if self.scale_in_cooldown < 0:
            raise ValueError(
                f"scale_in_cooldown must be >= 0, got "
                f"{self.scale_in_cooldown!r}")
        if not 0.0 <= self.min_scale_in_savings < 1.0:
            raise ValueError(
                f"min_scale_in_savings must be in [0, 1), got "
                f"{self.min_scale_in_savings!r}")
        if self.shape_quantum < 1:
            raise ValueError(
                f"shape_quantum must be >= 1, got {self.shape_quantum!r}")
