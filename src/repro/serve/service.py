"""``RightsizingService``: a long-lived serving loop over ``FleetEngine``.

The paper solves one cold-start rightsizing instance; this module keeps
MANY live fleets rightsized under a stream of perturbations.  One tick:

  1. **Drain + coalesce** — pop a bounded FIFO prefix off the admission
     queue and fold it per fleet, so a fleet hit by several requests
     re-solves once with all of them applied.
  2. **Micro-batch** — shape-bucket the touched fleets' trimmed
     problems with the engine's own ``plan_buckets`` planner; the
     bucket holding the *oldest* pending request becomes the tick's
     batch, everything else requeues at the front (FIFO fairness, one
     padded shape, ONE ``FleetEngine`` LP dispatch per tick).
  3. **Warm re-solve** — each batched lane re-enters PDHG from its
     fleet's previous ``PDHGState``, with task rows and trimmed time
     slots re-aligned by id; lanes whose shape drifted past
     ``ServiceConfig.max_shape_drift`` (or whose fleet is new) cold
     start automatically.
  4. **Place + decide** — one lockstep placement scan proposes node
     counts; the flag-gated decision loop (``serve.scale``) adopts or
     holds them, logging a structured ``ScaleEvent``.
  5. **Account** — per-request re-plan latency, per-lane iteration
     counts split warm/cold, dispatch counts, and wall-time phases all
     land in the tick record; ``report()`` aggregates them into the
     requests/sec + p99-latency telemetry the benchmarks gate.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.batch import pack_problems
from repro.core.engine import (FleetEngine, SolverConfig, SweepConfig,
                               plan_buckets)
from repro.core.lp_pdhg import PDHGState
from repro.core.problem import Problem, trim_timeline
from repro.core.solution import Solution, verify

from .config import ServiceConfig
from .queue import AdmissionQueue, PendingRequest, Request
from .scale import ScaleEvent, evaluate_scale

__all__ = ["RightsizingService", "TickRecord", "FleetView"]


@dataclasses.dataclass
class _LaneState:
    """One fleet's stored solver state, cropped to its own trimmed
    shape, plus the alignment keys (task ids, kept slot ids) the next
    warm start re-maps it with."""

    x: np.ndarray            # (n_f, m) float32, trimmed task rows
    y: np.ndarray            # (T'_f, m, D) float32, trimmed slots
    eta: float | None
    ids: np.ndarray          # (n_f,) task ids, ascending
    kept: np.ndarray         # (T'_f,) original slot ids, ascending


@dataclasses.dataclass
class _FleetState:
    problem: Problem          # current task set, original timeline
    ids: np.ndarray           # (n,) task ids, ascending
    next_id: int
    warm: _LaneState | None = None
    plan: np.ndarray | None = None       # adopted node counts (m,)
    plan_cost: float = 0.0
    last_scale_in_tick: int = -(10**9)
    solution: Solution | None = None


@dataclasses.dataclass(frozen=True)
class FleetView:
    """Read-only snapshot of one fleet (what ``fleet()`` returns)."""

    name: str
    n_tasks: int
    plan: np.ndarray
    plan_cost: float
    solution: Solution | None


@dataclasses.dataclass
class TickRecord:
    """Telemetry of one tick: who re-solved, how warm, how fast."""

    tick: int
    fleets: tuple[str, ...]
    requests: int
    deferred: int
    dispatches: int
    warm_lanes: int
    cold_lanes: int
    drift_fallbacks: int
    iters: tuple[int, ...]
    converged: int
    solve_s: float
    place_s: float
    total_s: float

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fleets"] = list(self.fleets)
        d["iters"] = list(self.iters)
        return d


class RightsizingService:
    """A long-lived rightsizing loop: ``submit`` requests, ``tick``
    until drained (or forever), read ``report()`` / ``events``.

    The service derives its per-tick engine from the one it is given
    with ``FleetEngine.with_overrides``: the sweep config is replaced
    outright because the admission queue owns micro-batching (bucketing
    per tick) and the per-fleet state chain owns warm starts — the
    engine-level ``SweepConfig(warm_start=..., max_buckets=...)`` knobs
    describe offline sweeps, not a serving loop.  The solver must be
    tolerance-stopped: warm starts only pay off when lanes may exit
    early.
    """

    def __init__(self, engine: FleetEngine | None = None,
                 config: ServiceConfig | None = None):
        self.config = config if config is not None else ServiceConfig()
        base = engine if engine is not None else FleetEngine(
            solver=SolverConfig(tol=5e-3, iters=4000),
            algos=("lp-map-f",))
        if base.solver.tol is None:
            raise ValueError(
                "RightsizingService needs a tolerance-stopped solver "
                "(warm-started re-solves only pay off when lanes can "
                "exit early); derive one with "
                "engine.with_overrides(tol=5e-3)")
        # the queue owns micro-batching; neutralize sweep-level knobs
        self.engine = base.with_overrides(sweep=SweepConfig())
        self.queue = AdmissionQueue()
        self.events: list[ScaleEvent] = []
        self.ticks: list[TickRecord] = []
        self._fleets: dict[str, _FleetState] = {}
        self._tick = 0
        self._latencies: list[float] = []
        self._iters: dict[str, list[int]] = {
            "warm": [], "cold": [], "drift": [], "admit": []}
        self._converged: list[bool] = []
        self._proposed_cost = 0.0  # pre-decision placement cost total

    # -- admission -----------------------------------------------------

    def submit(self, request: Request) -> PendingRequest:
        return self.queue.push(request, now_s=time.perf_counter())

    @property
    def fleets(self) -> tuple[str, ...]:
        return tuple(self._fleets)

    def fleet(self, name: str) -> FleetView:
        st = self._fleets[name]
        return FleetView(name=name, n_tasks=st.problem.n,
                         plan=st.plan.copy(), plan_cost=st.plan_cost,
                         solution=st.solution)

    # -- request application (pure w.r.t. stored fleet state) ----------

    @staticmethod
    def _fit_demands(dem: np.ndarray, cap: np.ndarray) -> np.ndarray:
        """Admission control: any task fitting NO node type is scaled
        down onto its best-fitting type (smallest max demand/capacity
        ratio), so perturbed fleets always stay feasible."""
        dem = np.asarray(dem, dtype=float)
        ratios = np.max(dem[:, None, :] / np.maximum(cap[None, :, :],
                                                     1e-12), axis=2)
        r = ratios.min(axis=1)
        over = r > 1.0
        if over.any():
            dem = dem.copy()
            dem[over] /= r[over, None] * (1.0 + 1e-9)
        return dem

    def _apply(self, st: _FleetState | None, items: list[PendingRequest]):
        """Fold a fleet's coalesced requests into (problem, ids,
        next_id) without mutating the stored state."""
        if st is None:
            problem, ids, next_id = None, None, 0
        else:
            problem, ids, next_id = st.problem, st.ids, st.next_id
        for item in items:
            req = item.request
            if req.kind == "admit":
                if problem is not None:
                    raise ValueError(
                        f"fleet {req.fleet!r} is already admitted")
                dem = self._fit_demands(req.dem, req.node_types.cap)
                problem = Problem(
                    dem=dem,
                    start=np.asarray(req.start, dtype=np.int64),
                    end=np.asarray(req.end, dtype=np.int64),
                    node_types=req.node_types, T=int(req.T))
                ids = np.arange(dem.shape[0], dtype=np.int64)
                next_id = dem.shape[0]
                continue
            if problem is None:
                raise ValueError(
                    f"fleet {req.fleet!r} got a {req.kind!r} request "
                    f"before being admitted")
            cap = problem.node_types.cap
            if req.kind == "arrive":
                dem = self._fit_demands(req.dem, cap)
                k = dem.shape[0]
                problem = Problem(
                    dem=np.concatenate([problem.dem, dem]),
                    start=np.concatenate([
                        problem.start,
                        np.asarray(req.start, dtype=np.int64)]),
                    end=np.concatenate([
                        problem.end,
                        np.asarray(req.end, dtype=np.int64)]),
                    node_types=problem.node_types, T=problem.T)
                ids = np.concatenate([
                    ids, np.arange(next_id, next_id + k, dtype=np.int64)])
                next_id += k
            elif req.kind == "depart":
                keep = ~np.isin(ids, np.asarray(req.ids, dtype=np.int64))
                if not keep.any():
                    raise ValueError(
                        f"depart would empty fleet {req.fleet!r}")
                problem = Problem(
                    dem=problem.dem[keep], start=problem.start[keep],
                    end=problem.end[keep],
                    node_types=problem.node_types, T=problem.T)
                ids = ids[keep]
            elif req.kind == "burst":
                hit = np.isin(ids, np.asarray(req.ids, dtype=np.int64))
                dem = problem.dem.copy()
                dem[hit] = self._fit_demands(dem[hit] * req.factor, cap)
                problem = Problem(
                    dem=dem, start=problem.start, end=problem.end,
                    node_types=problem.node_types, T=problem.T)
            # 'replan' applies no perturbation
        return problem, ids, next_id

    # -- warm-start assembly -------------------------------------------

    def _lane_init(self, st: _FleetState | None, ids, trimmed, kept,
                   x0, y0, lane: int):
        """Fill one lane of the batch init from the fleet's stored
        state, task rows and kept slots re-aligned by id.  Returns the
        lane mode and step size: ('warm', eta), or (mode, None) with
        mode 'admit' (fresh fleet), 'cold' (warm starts off), or
        'drift' (shape drifted past the fallback bound)."""
        if st is None:
            return "admit", None
        if not self.config.warm_start or st.warm is None:
            return "cold", None
        ws = st.warm
        if ws.x.shape[1] != trimmed.m or ws.y.shape[2] != trimmed.D:
            return "drift", None
        row_pos = np.searchsorted(ws.ids, ids)
        row_pos = np.clip(row_pos, 0, len(ws.ids) - 1)
        row_ok = ws.ids[row_pos] == ids
        slot_pos = np.searchsorted(ws.kept, kept)
        slot_pos = np.clip(slot_pos, 0, len(ws.kept) - 1)
        slot_ok = ws.kept[slot_pos] == kept
        overlap = min(row_ok.mean(), slot_ok.mean())
        if overlap < 1.0 - self.config.max_shape_drift:
            return "drift", None
        m, d = trimmed.m, trimmed.D
        x0[lane, np.flatnonzero(row_ok), :m] = ws.x[row_pos[row_ok]]
        y0[lane, np.flatnonzero(slot_ok), :m, :d] = ws.y[slot_pos[slot_ok]]
        return "warm", ws.eta

    # -- one tick ------------------------------------------------------

    def tick(self) -> TickRecord | None:
        """Process one micro-batch; returns its ``TickRecord``, or
        None when the queue is empty."""
        t_tick = time.perf_counter()
        taken = self.queue.take(self.config.max_requests_per_tick)
        if not taken:
            return None
        groups = AdmissionQueue.coalesce(taken)
        names = list(groups)

        proposals = {}
        for name in names:
            problem, ids, next_id = self._apply(
                self._fleets.get(name), groups[name])
            trimmed, kept = trim_timeline(problem)
            proposals[name] = (problem, ids, next_id, trimmed, kept)

        # shape-bucket the touched fleets; serve the oldest request's
        # bucket this tick, defer the rest with their order intact
        parts = plan_buckets([proposals[n][3] for n in names],
                             max_buckets=self.config.max_buckets,
                             overhead=self.config.bucket_overhead)
        chosen_idx = next(p for p in parts if 0 in p)
        chosen = [names[i] for i in chosen_idx]
        deferred = [item for i, n in enumerate(names) if i not in chosen_idx
                    for item in groups[n]]
        self.queue.requeue(deferred)

        # pad task/slot dims up to the shape quantum so consecutive
        # ticks reuse one compiled solve (padding is exact)
        chosen_trimmed = [proposals[n][3] for n in chosen]
        q = self.config.shape_quantum
        pad_to = (-(-max(t.n for t in chosen_trimmed) // q) * q,
                  max(t.m for t in chosen_trimmed),
                  max(t.D for t in chosen_trimmed),
                  -(-max(t.T for t in chosen_trimmed) // q) * q)
        batch = pack_problems(chosen_trimmed, pad_to=pad_to,
                              assume_trimmed=True)
        x0 = np.zeros((batch.B, batch.n, batch.m), np.float32)
        y0 = np.zeros((batch.B, batch.Tp, batch.m, batch.D), np.float32)
        modes, etas = [], []
        for lane, name in enumerate(chosen):
            _, ids, _, trimmed, kept = proposals[name]
            mode, eta = self._lane_init(self._fleets.get(name), ids,
                                        trimmed, kept, x0, y0, lane)
            modes.append(mode)
            etas.append(eta)
        init = None
        if any(m == "warm" for m in modes):
            eta_arr = None
            if all(e is not None for e in etas):
                eta_arr = np.asarray(etas, np.float32)
            init = PDHGState(x=x0, y=y0, eta=eta_arr)

        t0 = time.perf_counter()
        lp_results, stats = self.engine.solve(batch, init=init)
        solve_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        maps = [r.mapping for r in lp_results]
        best: list[Solution | None] = [None] * batch.B
        best_cost = [float("inf")] * batch.B
        for fit in self.engine.placement.fits:
            sols = self.engine.place(batch, maps, fit=fit,
                                     filling=self.config.filling)
            for lane, (t, s) in enumerate(zip(batch.problems, sols)):
                c = s.cost(t)
                if c < best_cost[lane]:
                    best_cost[lane], best[lane] = c, s
        place_s = time.perf_counter() - t0

        state = stats[-1].state if stats else None
        now = time.perf_counter()
        for lane, name in enumerate(chosen):
            problem, ids, next_id, trimmed, kept = proposals[name]
            st = self._fleets.get(name)
            sol = best[lane]
            if self.engine.placement.check:
                verify(trimmed, sol)
            required = sol.nodes_per_type(trimmed)
            self._proposed_cost += float(
                required @ trimmed.node_types.cost)
            decision = evaluate_scale(
                None if st is None else st.plan, required,
                trimmed.node_types.cost, tick=self._tick,
                last_scale_in_tick=(-(10**9) if st is None
                                    else st.last_scale_in_tick),
                cfg=self.config)
            cost_before = 0.0 if st is None else st.plan_cost
            if st is None:
                st = _FleetState(problem=problem, ids=ids,
                                 next_id=next_id)
                self._fleets[name] = st
            else:
                st.problem, st.ids, st.next_id = problem, ids, next_id
            if decision.scaled_in:
                st.last_scale_in_tick = self._tick
            st.plan, st.plan_cost = decision.adopted, decision.cost
            st.solution = sol
            if state is not None:
                st.warm = _LaneState(
                    x=np.array(state.x[lane, :trimmed.n, :trimmed.m]),
                    y=np.array(state.y[lane, :trimmed.T, :trimmed.m,
                                       :trimmed.D]),
                    eta=(None if state.eta is None
                         else float(state.eta[lane])),
                    ids=ids.copy(), kept=kept.copy())
            if decision.scope != "hold" or decision.checks:
                self.events.append(ScaleEvent(
                    tick=self._tick, fleet=name, scope=decision.scope,
                    cost_before=cost_before, cost_after=decision.cost,
                    checks=decision.checks))

        served = [item for n in chosen for item in groups[n]]
        for item in served:
            self._latencies.append(now - item.submitted_s)
        iters = []
        for lane, mode in enumerate(modes):
            lane_iters = int(stats[0].iterations[lane]) if stats else 0
            iters.append(lane_iters)
            self._iters[mode].append(lane_iters)
        if stats:
            self._converged.extend(bool(c) for c in stats[0].converged)

        record = TickRecord(
            tick=self._tick, fleets=tuple(chosen), requests=len(served),
            deferred=len(deferred), dispatches=max(1, len(stats)),
            warm_lanes=sum(m == "warm" for m in modes),
            cold_lanes=sum(m != "warm" for m in modes),
            drift_fallbacks=sum(m == "drift" for m in modes),
            iters=tuple(iters),
            converged=(int(stats[0].converged.sum()) if stats
                       else batch.B),
            solve_s=solve_s, place_s=place_s,
            total_s=time.perf_counter() - t_tick)
        self.ticks.append(record)
        self._tick += 1
        return record

    def drain(self, max_ticks: int = 10**6) -> int:
        """Tick until the queue is empty; returns ticks executed."""
        n = 0
        while self.queue.pending and n < max_ticks:
            if self.tick() is None:
                break
            n += 1
        return n

    # -- telemetry -----------------------------------------------------

    def report(self) -> dict:
        """Aggregate serving telemetry (JSON-ready): sustained
        requests/sec, re-plan latency percentiles, warm-vs-cold
        iteration medians, decision-loop event counts, and the
        deterministic total adopted plan cost."""
        lat = np.asarray(self._latencies, dtype=float)
        wall = sum(t.total_s for t in self.ticks)
        scopes: dict[str, int] = {}
        for e in self.events:
            scopes[e.scope] = scopes.get(e.scope, 0) + 1
        resolve_cold = self._iters["cold"] + self._iters["drift"]

        def _median(vals):
            return float(np.median(vals)) if vals else None

        return {
            "ticks": len(self.ticks),
            "fleets": len(self._fleets),
            "requests": int(lat.size),
            "wall_s": round(wall, 4),
            "requests_per_s": (round(float(lat.size) / wall, 3)
                               if wall > 0 else 0.0),
            "p50_replan_s": (round(float(np.percentile(lat, 50)), 4)
                             if lat.size else 0.0),
            "p99_replan_s": (round(float(np.percentile(lat, 99)), 4)
                             if lat.size else 0.0),
            "dispatches_per_tick": (max(t.dispatches for t in self.ticks)
                                    if self.ticks else 0),
            "warm_lanes": len(self._iters["warm"]),
            "cold_lanes": (len(resolve_cold) + len(self._iters["admit"])),
            "drift_fallbacks": sum(t.drift_fallbacks for t in self.ticks),
            "median_iters_warm": _median(self._iters["warm"]),
            "median_iters_cold": _median(resolve_cold),
            "median_iters_admit": _median(self._iters["admit"]),
            "converged_frac": (round(float(np.mean(self._converged)), 4)
                               if self._converged else 1.0),
            "events": scopes,
            "total_cost": round(sum(st.plan_cost
                                    for st in self._fleets.values()), 6),
            "proposed_cost_total": round(self._proposed_cost, 6),
        }
