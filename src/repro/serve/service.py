"""``RightsizingService``: a long-lived serving loop over ``FleetEngine``.

The paper solves one cold-start rightsizing instance; this module keeps
MANY live fleets rightsized under a stream of perturbations.  One tick:

  1. **Drain + coalesce** — pop a bounded FIFO prefix off the admission
     queue and fold it per fleet, so a fleet hit by several requests
     re-solves once with all of them applied.
  2. **Micro-batch** — shape-bucket the touched fleets' trimmed
     problems with the engine's own ``plan_buckets`` planner; the
     bucket holding the *oldest* pending request becomes the tick's
     batch, everything else requeues at the front (FIFO fairness, one
     padded shape, ONE ``FleetEngine`` LP dispatch per tick).
  3. **Warm re-solve** — each batched lane re-enters PDHG from its
     fleet's previous ``PDHGState``, with task rows and trimmed time
     slots re-aligned by id; lanes whose shape drifted past
     ``ServiceConfig.max_shape_drift`` (or whose fleet is new) cold
     start automatically.
  4. **Place + decide** — one lockstep placement scan proposes node
     counts; the flag-gated decision loop (``serve.scale``) adopts or
     holds them, logging a structured ``ScaleEvent``.
  5. **Account** — per-request re-plan latency, per-lane iteration
     counts split warm/cold, dispatch counts, and wall-time phases all
     land in the tick record; ``report()`` aggregates them into the
     requests/sec + p99-latency telemetry the benchmarks gate.

The loop is hardened for unattended operation:

  * **Shedding** — with ``ServiceConfig.max_pending`` set, each tick
    first sheds stale queued ``replan``s (never state-changing kinds)
    through ``AdmissionQueue.shed``, logging ``ShedEvent``s.
  * **Retry + quarantine** — a request whose application raises, or a
    lane whose solve/verify fails (for real or via ``serve.faults``
    injection), is retried up to ``max_request_retries`` times and then
    quarantined with its error (``service.quarantined``) instead of
    poisoning every subsequent tick; the rest of the tick's fleets are
    unaffected.  Requests are folded one at a time, so the poison item
    is identified exactly and already-folded prefixes still serve.
  * **Pre-provisioning** — ``preprovision(fleet)`` routes the fleet's
    current task set through the stochastic layer (K-scenario fan-out
    + CVaR selection, ``repro.stochastic``) and adopts growth-only
    burst headroom, logged as a ``scope='preprovision'`` ScaleEvent.
  * **Checkpointing** — ``snapshot(path)`` / ``restore(path, engine)``
    persist every fleet's state (including the warm ``PDHGState``
    chain), the pending queue, and the telemetry counters, so a
    restarted service resumes mid-trace with warm lanes intact
    (``serve.snapshot``).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.batch import pack_problems
from repro.core.checker import assert_feasible
from repro.core.constraints import (TaskConstraints, expand_solution,
                                    lower_constraints)
from repro.core.engine import (FleetEngine, SolverConfig, SweepConfig,
                               plan_buckets)
from repro.core.lp_pdhg import PDHGState
from repro.core.problem import Problem, trim_timeline
from repro.core.solution import Solution, verify

from .config import ServiceConfig
from .faults import FaultInjector, InjectedFault
from .queue import AdmissionQueue, PendingRequest, Request, ShedEvent
from .scale import ScaleEvent, evaluate_scale

__all__ = ["RightsizingService", "TickRecord", "FleetView",
           "QuarantineRecord"]


@dataclasses.dataclass
class _LaneState:
    """One fleet's stored solver state, cropped to its own trimmed
    shape, plus the alignment keys (task ids, kept slot ids) the next
    warm start re-maps it with."""

    x: np.ndarray            # (n_f, m) float32, trimmed task rows
    y: np.ndarray            # (T'_f, m, D) float32, trimmed slots
    eta: float | None
    omega: float | None      # adapted primal weight (None pre-PR 8)
    ids: np.ndarray          # (n_f,) task ids, ascending
    kept: np.ndarray         # (T'_f,) original slot ids, ascending


@dataclasses.dataclass
class _FleetState:
    problem: Problem          # current task set, original timeline
    ids: np.ndarray           # (n,) task ids, ascending
    next_id: int
    warm: _LaneState | None = None
    plan: np.ndarray | None = None       # adopted node counts (m,)
    plan_cost: float = 0.0
    last_scale_in_tick: int = -(10**9)
    solution: Solution | None = None


@dataclasses.dataclass(frozen=True)
class FleetView:
    """Read-only snapshot of one fleet (what ``fleet()`` returns)."""

    name: str
    n_tasks: int
    plan: np.ndarray
    plan_cost: float
    solution: Solution | None


@dataclasses.dataclass(frozen=True)
class QuarantineRecord:
    """One quarantined request: what failed, with which error, after
    how many attempts (JSON-ready via ``to_dict``)."""

    seq: int
    fleet: str
    kind: str
    tick: int
    attempts: int
    error: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "QuarantineRecord":
        return QuarantineRecord(
            seq=int(d["seq"]), fleet=d["fleet"], kind=d["kind"],
            tick=int(d["tick"]), attempts=int(d["attempts"]),
            error=d["error"])


@dataclasses.dataclass
class TickRecord:
    """Telemetry of one tick: who re-solved, how warm, how fast."""

    tick: int
    fleets: tuple[str, ...]
    requests: int
    deferred: int
    dispatches: int
    warm_lanes: int
    cold_lanes: int
    drift_fallbacks: int
    iters: tuple[int, ...]
    converged: int
    solve_s: float
    place_s: float
    total_s: float
    shed: int = 0
    retried: int = 0
    quarantined: int = 0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fleets"] = list(self.fleets)
        d["iters"] = list(self.iters)
        return d

    @staticmethod
    def from_dict(d: dict) -> "TickRecord":
        return TickRecord(
            tick=int(d["tick"]), fleets=tuple(d["fleets"]),
            requests=int(d["requests"]), deferred=int(d["deferred"]),
            dispatches=int(d["dispatches"]),
            warm_lanes=int(d["warm_lanes"]),
            cold_lanes=int(d["cold_lanes"]),
            drift_fallbacks=int(d["drift_fallbacks"]),
            iters=tuple(int(i) for i in d["iters"]),
            converged=int(d["converged"]), solve_s=float(d["solve_s"]),
            place_s=float(d["place_s"]), total_s=float(d["total_s"]),
            shed=int(d.get("shed", 0)), retried=int(d.get("retried", 0)),
            quarantined=int(d.get("quarantined", 0)))


class RightsizingService:
    """A long-lived rightsizing loop: ``submit`` requests, ``tick``
    until drained (or forever), read ``report()`` / ``events``.

    The service derives its per-tick engine from the one it is given
    with ``FleetEngine.with_overrides``: the sweep config is replaced
    outright because the admission queue owns micro-batching (bucketing
    per tick) and the per-fleet state chain owns warm starts — the
    engine-level ``SweepConfig(warm_start=..., max_buckets=...)`` knobs
    describe offline sweeps, not a serving loop.  The solver must be
    tolerance-stopped: warm starts only pay off when lanes may exit
    early.
    """

    def __init__(self, engine: FleetEngine | None = None,
                 config: ServiceConfig | None = None,
                 faults: FaultInjector | None = None):
        self.config = config if config is not None else ServiceConfig()
        base = engine if engine is not None else FleetEngine(
            solver=SolverConfig(tol=5e-3, iters=4000),
            algos=("lp-map-f",))
        if base.solver.tol is None:
            raise ValueError(
                "RightsizingService needs a tolerance-stopped solver "
                "(warm-started re-solves only pay off when lanes can "
                "exit early); derive one with "
                "engine.with_overrides(tol=5e-3)")
        # the queue owns micro-batching; neutralize sweep-level knobs
        self.engine = base.with_overrides(sweep=SweepConfig())
        self.faults = faults
        self.queue = AdmissionQueue()
        self.events: list[ScaleEvent] = []
        self.shed_events: list[ShedEvent] = []
        self.quarantined: list[QuarantineRecord] = []
        self.ticks: list[TickRecord] = []
        self._fleets: dict[str, _FleetState] = {}
        self._tick = 0
        self._latencies: list[float] = []
        self._iters: dict[str, list[int]] = {
            "warm": [], "cold": [], "drift": [], "admit": []}
        self._converged: list[bool] = []
        self._proposed_cost = 0.0  # pre-decision placement cost total
        self._attempts: dict[int, int] = {}  # seq -> failed attempts
        self._retries = 0
        self._deadline_misses = 0

    # -- admission -----------------------------------------------------

    def submit(self, request: Request) -> PendingRequest:
        return self.queue.push(request, now_s=time.perf_counter())

    @property
    def fleets(self) -> tuple[str, ...]:
        return tuple(self._fleets)

    def fleet(self, name: str) -> FleetView:
        st = self._fleets[name]
        return FleetView(name=name, n_tasks=st.problem.n,
                         plan=st.plan.copy(), plan_cost=st.plan_cost,
                         solution=st.solution)

    # -- request application (pure w.r.t. stored fleet state) ----------

    @staticmethod
    def _fit_demands(dem: np.ndarray, cap: np.ndarray) -> np.ndarray:
        """Admission control: any task fitting NO node type is scaled
        down onto its best-fitting type (smallest max demand/capacity
        ratio), so perturbed fleets always stay feasible."""
        dem = np.asarray(dem, dtype=float)
        ratios = np.max(dem[:, None, :] / np.maximum(cap[None, :, :],
                                                     1e-12), axis=2)
        r = ratios.min(axis=1)
        over = r > 1.0
        if over.any():
            dem = dem.copy()
            dem[over] /= r[over, None] * (1.0 + 1e-9)
        return dem

    @staticmethod
    def _known_ids(req: Request, ids: np.ndarray) -> np.ndarray:
        """The request's target ids as int64, or ValueError naming the
        unknown ones — an ``np.isin`` that silently matches nothing
        would turn a client typo into a silent no-op."""
        target = np.asarray(req.ids, dtype=np.int64)
        unknown = target[~np.isin(target, ids)]
        if unknown.size:
            raise ValueError(
                f"{req.kind} for fleet {req.fleet!r} references "
                f"unknown task ids {sorted(unknown.tolist())} "
                f"(live ids run 0..{int(ids.max())} minus departures)")
        return target

    def _apply_one(self, problem: Problem | None, ids, next_id: int,
                   req: Request):
        """Fold ONE request into (problem, ids, next_id); raises on an
        invalid request and never mutates its inputs."""
        if req.kind == "admit":
            if problem is not None:
                raise ValueError(
                    f"fleet {req.fleet!r} is already admitted")
            dem = self._fit_demands(req.dem, req.node_types.cap)
            problem = Problem(
                dem=dem,
                start=np.asarray(req.start, dtype=np.int64),
                end=np.asarray(req.end, dtype=np.int64),
                node_types=req.node_types, T=int(req.T))
            return problem, np.arange(dem.shape[0], dtype=np.int64), \
                dem.shape[0]
        if problem is None:
            raise ValueError(
                f"fleet {req.fleet!r} got a {req.kind!r} request "
                f"before being admitted")
        cap = problem.node_types.cap
        constraints = problem.constraints
        if req.kind == "arrive":
            dem = self._fit_demands(req.dem, cap)
            k = dem.shape[0]
            problem = Problem(
                dem=np.concatenate([problem.dem, dem]),
                start=np.concatenate([
                    problem.start,
                    np.asarray(req.start, dtype=np.int64)]),
                end=np.concatenate([
                    problem.end,
                    np.asarray(req.end, dtype=np.int64)]),
                node_types=problem.node_types, T=problem.T,
                constraints=(None if constraints is None
                             else constraints.extend(k)))
            ids = np.concatenate([
                ids, np.arange(next_id, next_id + k, dtype=np.int64)])
            next_id += k
        elif req.kind == "depart":
            keep = ~np.isin(ids, self._known_ids(req, ids))
            if not keep.any():
                raise ValueError(
                    f"depart would empty fleet {req.fleet!r}")
            problem = Problem(
                dem=problem.dem[keep], start=problem.start[keep],
                end=problem.end[keep],
                node_types=problem.node_types, T=problem.T,
                constraints=(None if constraints is None
                             else constraints.take(keep)))
            ids = ids[keep]
        elif req.kind == "burst":
            hit = np.isin(ids, self._known_ids(req, ids))
            dem = problem.dem.copy()
            dem[hit] = self._fit_demands(dem[hit] * req.factor, cap)
            problem = Problem(
                dem=dem, start=problem.start, end=problem.end,
                node_types=problem.node_types, T=problem.T,
                constraints=constraints)
        elif req.kind == "constrain":
            hit = np.isin(ids, self._known_ids(req, ids))
            c = (TaskConstraints.vacuous(problem.n)
                 if constraints is None else constraints)
            c = c.constrain(np.flatnonzero(hit), affinity=req.affinity,
                            anti_affinity=req.anti_affinity,
                            exclusive=req.exclusive,
                            deadline=req.deadline)
            problem = Problem(
                dem=problem.dem, start=problem.start, end=problem.end,
                node_types=problem.node_types, T=problem.T,
                constraints=c)
            # validate eagerly: an unmeetable deadline, a contradictory
            # group, or an unplaceable merged row fails HERE (poison
            # isolation path) instead of poisoning the whole tick solve
            lower_constraints(problem)
        # 'replan' applies no perturbation
        return problem, ids, next_id

    def _apply(self, st: _FleetState | None, items: list[PendingRequest]):
        """Fold a fleet's coalesced requests one at a time into
        (problem, ids, next_id) without mutating the stored state.

        Returns ``(problem, ids, next_id, applied, poison, rest)``:
        ``applied`` is the folded prefix, and when an item raises (a
        real validation error or an injected 'apply-raise' fault) it
        becomes ``poison = (item, error)`` with the unapplied tail in
        ``rest`` — the caller serves the prefix and routes the poison
        through retry/quarantine, so one bad request never blocks the
        stream behind it."""
        if st is None:
            problem, ids, next_id = None, None, 0
        else:
            problem, ids, next_id = st.problem, st.ids, st.next_id
        applied: list[PendingRequest] = []
        for pos, item in enumerate(items):
            req = item.request
            try:
                if self.faults is not None and self.faults.fire(
                        "apply-raise", fleet=req.fleet, tick=self._tick):
                    raise InjectedFault(
                        f"injected failure applying {req.kind!r} to "
                        f"fleet {req.fleet!r}")
                problem, ids, next_id = self._apply_one(
                    problem, ids, next_id, req)
            except Exception as error:
                return (problem, ids, next_id, applied, (item, error),
                        items[pos + 1:])
            applied.append(item)
        return problem, ids, next_id, applied, None, []

    def _note_failure(self, items: list[PendingRequest],
                      error: Exception):
        """Retry/quarantine bookkeeping for failed requests: each item
        is retried (requeued by the caller) until it has failed
        ``max_request_retries + 1`` times, then quarantined with its
        error.  Returns ``(retry_items, n_quarantined)``."""
        retry: list[PendingRequest] = []
        n_quarantined = 0
        for item in items:
            fails = self._attempts.get(item.seq, 0) + 1
            if fails > self.config.max_request_retries:
                self._attempts.pop(item.seq, None)
                self.quarantined.append(QuarantineRecord(
                    seq=item.seq, fleet=item.request.fleet,
                    kind=item.request.kind, tick=self._tick,
                    attempts=fails,
                    error=f"{type(error).__name__}: {error}"))
                n_quarantined += 1
            else:
                self._attempts[item.seq] = fails
                self._retries += 1
                retry.append(item)
        return retry, n_quarantined

    # -- warm-start assembly -------------------------------------------

    def _lane_init(self, st: _FleetState | None, ids, trimmed, kept,
                   x0, y0, lane: int):
        """Fill one lane of the batch init from the fleet's stored
        state, task rows and kept slots re-aligned by id.  Returns the
        lane mode, step size, and primal weight: ('warm', eta, omega),
        or (mode, None, None) with mode 'admit' (fresh fleet), 'cold'
        (warm starts off), or 'drift' (shape drifted past the fallback
        bound)."""
        if st is None:
            return "admit", None, None
        if not self.config.warm_start or st.warm is None:
            return "cold", None, None
        ws = st.warm
        if ws.x.shape[1] != trimmed.m or ws.y.shape[2] != trimmed.D:
            return "drift", None
        row_pos = np.searchsorted(ws.ids, ids)
        row_pos = np.clip(row_pos, 0, len(ws.ids) - 1)
        row_ok = ws.ids[row_pos] == ids
        slot_pos = np.searchsorted(ws.kept, kept)
        slot_pos = np.clip(slot_pos, 0, len(ws.kept) - 1)
        slot_ok = ws.kept[slot_pos] == kept
        overlap = min(row_ok.mean(), slot_ok.mean())
        if overlap < 1.0 - self.config.max_shape_drift:
            return "drift", None, None
        m, d = trimmed.m, trimmed.D
        x0[lane, np.flatnonzero(row_ok), :m] = ws.x[row_pos[row_ok]]
        y0[lane, np.flatnonzero(slot_ok), :m, :d] = ws.y[slot_pos[slot_ok]]
        return "warm", ws.eta, ws.omega

    # -- one tick ------------------------------------------------------

    @staticmethod
    def _aggregate_stats(stats):
        """Per-lane telemetry across ALL of the solve's stats entries.

        A sharded dispatch partitions the batch's lanes across several
        ``SolveStats`` in order, so reading ``stats[0]`` for iteration
        counts but ``stats[-1]`` for the warm state silently mixes
        lanes.  Returns ``(iters (B,), converged (B,), lane_state)``
        where ``lane_state[b]`` is ``(state, local_index)`` for lane
        ``b`` (or None), or ``None`` when there are no stats at all.
        """
        if not stats:
            return None
        iters = np.concatenate(
            [np.asarray(s.iterations).reshape(-1) for s in stats])
        conv = np.concatenate(
            [np.asarray(s.converged).reshape(-1) for s in stats])
        lane_state = []
        for s in stats:
            b = int(np.asarray(s.iterations).reshape(-1).shape[0])
            for j in range(b):
                lane_state.append(
                    None if s.state is None else (s.state, j))
        return iters, conv, lane_state

    def tick(self) -> TickRecord | None:
        """Process one micro-batch; returns its ``TickRecord``, or
        None when the queue is empty.

        A tick whose every drained request fails application still
        returns a (solve-free) record — returning None there would
        stall ``drain`` with poison retries left in the queue.
        """
        t_tick = time.perf_counter()
        n_shed = 0
        if self.config.max_pending is not None:
            shed = self.queue.shed(
                now_s=time.perf_counter(),
                max_pending=self.config.max_pending, tick=self._tick)
            self.shed_events.extend(shed)
            n_shed = len(shed)
        taken = self.queue.take(self.config.max_requests_per_tick)
        if not taken:
            return None
        groups = AdmissionQueue.coalesce(taken)

        proposals = {}
        served_items: dict[str, list[PendingRequest]] = {}
        n_retried = n_quarantined = 0
        for name in list(groups):
            st = self._fleets.get(name)
            problem, ids, next_id, applied, poison, rest = self._apply(
                st, groups[name])
            if poison is not None:
                item, error = poison
                retry, nq = self._note_failure([item], error)
                n_retried += len(retry)
                n_quarantined += nq
                self.queue.requeue(retry + rest)
            if problem is None or (not applied and st is not None):
                # nothing new to solve: the fleet's only requests this
                # tick failed (or a fresh fleet's admit did)
                continue
            low = lower_constraints(problem)
            trimmed, kept = trim_timeline(low.lowered)
            proposals[name] = (problem, ids, next_id, trimmed, kept, low)
            served_items[name] = applied
        names = list(proposals)
        if not names:
            record = TickRecord(
                tick=self._tick, fleets=(), requests=0, deferred=0,
                dispatches=0, warm_lanes=0, cold_lanes=0,
                drift_fallbacks=0, iters=(), converged=0, solve_s=0.0,
                place_s=0.0, total_s=time.perf_counter() - t_tick,
                shed=n_shed, retried=n_retried,
                quarantined=n_quarantined)
            self.ticks.append(record)
            self._tick += 1
            return record

        # shape-bucket the touched fleets; serve the oldest request's
        # bucket this tick, defer the rest with their order intact
        # (deferral requeues only the successfully-applied items — a
        # poisoned item was already routed through retry/quarantine)
        parts = plan_buckets([proposals[n][3] for n in names],
                             max_buckets=self.config.max_buckets,
                             overhead=self.config.bucket_overhead)
        chosen_idx = next(p for p in parts if 0 in p)
        chosen = [names[i] for i in chosen_idx]
        deferred = [item for i, n in enumerate(names) if i not in chosen_idx
                    for item in served_items[n]]
        self.queue.requeue(deferred)

        # pad task/slot dims up to the shape quantum so consecutive
        # ticks reuse one compiled solve (padding is exact)
        chosen_trimmed = [proposals[n][3] for n in chosen]
        q = self.config.shape_quantum
        pad_to = (-(-max(t.n for t in chosen_trimmed) // q) * q,
                  max(t.m for t in chosen_trimmed),
                  max(t.D for t in chosen_trimmed),
                  -(-max(t.T for t in chosen_trimmed) // q) * q)
        batch = pack_problems(chosen_trimmed, pad_to=pad_to,
                              assume_trimmed=True)
        x0 = np.zeros((batch.B, batch.n, batch.m), np.float32)
        y0 = np.zeros((batch.B, batch.Tp, batch.m, batch.D), np.float32)
        modes, etas, omegas = [], [], []
        for lane, name in enumerate(chosen):
            _, ids, _, trimmed, kept, low = proposals[name]
            st_l = self._fleets.get(name)
            if not low.identity:
                # constrained lanes always cold-start: the lowered rows
                # (merged groups, virtual dims) no longer align with the
                # per-task-id warm state
                mode, eta, om = (("admit" if st_l is None else "cold"),
                                 None, None)
            else:
                mode, eta, om = self._lane_init(st_l, ids, trimmed,
                                                kept, x0, y0, lane)
            modes.append(mode)
            etas.append(eta)
            omegas.append(om)
        init = None
        if any(m == "warm" for m in modes):
            eta_arr = None
            if all(e is not None for e in etas):
                eta_arr = np.asarray(etas, np.float32)
            omega_arr = None
            if all(o is not None for o in omegas):
                omega_arr = np.asarray(omegas, np.float32)
            init = PDHGState(x=x0, y=y0, eta=eta_arr, omega=omega_arr)

        t0 = time.perf_counter()
        lp_results, stats = self.engine.solve(batch, init=init)
        solve_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        maps = [r.mapping for r in lp_results]
        best: list[Solution | None] = [None] * batch.B
        best_cost = [float("inf")] * batch.B
        for fit in self.engine.placement.fits:
            sols = self.engine.place(batch, maps, fit=fit,
                                     filling=self.config.filling)
            for lane, (t, s) in enumerate(zip(batch.problems, sols)):
                c = s.cost(t)
                if c < best_cost[lane]:
                    best_cost[lane], best[lane] = c, s
        place_s = time.perf_counter() - t0

        agg = self._aggregate_stats(stats)
        lane_iters_all, lane_conv, lane_state = (
            agg if agg is not None else (None, None, None))
        now = time.perf_counter()
        served: list[PendingRequest] = []
        committed = [False] * len(chosen)
        for lane, name in enumerate(chosen):
            problem, ids, next_id, trimmed, kept, low = proposals[name]
            st = self._fleets.get(name)
            sol = best[lane]
            failure: Exception | None = None
            if self.faults is not None and self.faults.fire(
                    "nonconverge", fleet=name, tick=self._tick):
                failure = InjectedFault(
                    f"injected solver non-convergence for fleet "
                    f"{name!r}")
            elif self.faults is not None and self.faults.fire(
                    "verify-fail", fleet=name, tick=self._tick):
                failure = InjectedFault(
                    f"injected placement verify failure for fleet "
                    f"{name!r}")
            elif self.engine.placement.check:
                try:
                    verify(trimmed, sol)
                    if not low.identity:
                        # independent second opinion: the expanded plan
                        # against the ORIGINAL constraint semantics
                        assert_feasible(problem,
                                        expand_solution(low, sol))
                except AssertionError as e:
                    failure = e
            if failure is not None:
                # do NOT commit; drop the stored warm state so the
                # retry cold-starts with a fresh step size, and route
                # the lane's requests through retry/quarantine
                if st is not None:
                    st.warm = None
                retry, nq = self._note_failure(served_items[name],
                                               failure)
                n_retried += len(retry)
                n_quarantined += nq
                self.queue.requeue(retry)
                continue
            committed[lane] = True
            required = sol.nodes_per_type(trimmed)
            self._proposed_cost += float(
                required @ trimmed.node_types.cost)
            decision = evaluate_scale(
                None if st is None else st.plan, required,
                trimmed.node_types.cost, tick=self._tick,
                last_scale_in_tick=(-(10**9) if st is None
                                    else st.last_scale_in_tick),
                cfg=self.config)
            cost_before = 0.0 if st is None else st.plan_cost
            if st is None:
                st = _FleetState(problem=problem, ids=ids,
                                 next_id=next_id)
                self._fleets[name] = st
            else:
                st.problem, st.ids, st.next_id = problem, ids, next_id
            if decision.scaled_in:
                st.last_scale_in_tick = self._tick
            st.plan, st.plan_cost = decision.adopted, decision.cost
            st.solution = expand_solution(low, sol)
            if not low.identity:
                # lowered-row state would misalign with task ids on a
                # later (possibly unconstrained) tick — never store it
                st.warm = None
            elif lane_state is not None and lane_state[lane] is not None:
                state, local = lane_state[lane]
                st.warm = _LaneState(
                    x=np.array(state.x[local, :trimmed.n, :trimmed.m]),
                    y=np.array(state.y[local, :trimmed.T, :trimmed.m,
                                       :trimmed.D]),
                    eta=(None if state.eta is None
                         else float(state.eta[local])),
                    omega=(None if state.omega is None
                           else float(state.omega[local])),
                    ids=ids.copy(), kept=kept.copy())
            if decision.scope != "hold" or decision.checks:
                self.events.append(ScaleEvent(
                    tick=self._tick, fleet=name, scope=decision.scope,
                    cost_before=cost_before, cost_after=decision.cost,
                    checks=decision.checks))
            served.extend(served_items[name])

        for item in served:
            self._latencies.append(now - item.submitted_s)
            self._attempts.pop(item.seq, None)
            if item.expired(now):
                self._deadline_misses += 1
        iters = []
        for lane, mode in enumerate(modes):
            lane_iters = (int(lane_iters_all[lane])
                          if lane_iters_all is not None else 0)
            iters.append(lane_iters)
            if committed[lane]:
                self._iters[mode].append(lane_iters)
        if lane_conv is not None:
            self._converged.extend(
                bool(lane_conv[lane]) for lane in range(len(chosen))
                if committed[lane])

        record = TickRecord(
            tick=self._tick, fleets=tuple(
                n for lane, n in enumerate(chosen) if committed[lane]),
            requests=len(served),
            deferred=len(deferred), dispatches=len(stats),
            warm_lanes=sum(m == "warm" for lane, m in enumerate(modes)
                           if committed[lane]),
            cold_lanes=sum(m != "warm" for lane, m in enumerate(modes)
                           if committed[lane]),
            drift_fallbacks=sum(
                m == "drift" for lane, m in enumerate(modes)
                if committed[lane]),
            iters=tuple(iters),
            converged=(int(lane_conv.sum()) if lane_conv is not None
                       else 0),
            solve_s=solve_s, place_s=place_s,
            total_s=time.perf_counter() - t_tick,
            shed=n_shed, retried=n_retried,
            quarantined=n_quarantined)
        self.ticks.append(record)
        self._tick += 1
        return record

    def drain(self, max_ticks: int = 10**6) -> int:
        """Tick until the queue is empty; returns ticks executed."""
        n = 0
        while self.queue.pending and n < max_ticks:
            if self.tick() is None:
                break
            n += 1
        return n

    # -- stochastic pre-provisioning -----------------------------------

    def preprovision(self, fleet: str, forecast=None, config=None):
        """Buy burst headroom ahead of demand: fan the fleet's current
        task set (or a caller-supplied ``DemandForecast``) into K
        scenarios, CVaR-select a robust fleet (``repro.stochastic``,
        one batched dispatch), and adopt ``max(current plan, robust)``.

        Growth-only by design — releases stay owned by the flag-gated
        scale-in loop, so pre-provisioning can never fight the cooldown
        or payback checks.  The adoption is logged as a
        ``scope='preprovision'`` ScaleEvent; the full
        ``StochasticResult`` (frontier, per-scenario overloads) is
        returned for telemetry.  The fleet's *current plan* anchors the
        Eva-style reconfiguration term, so a ``config`` with
        ``recfg_weight > 0`` biases selection toward fleets near what
        is already deployed."""
        from repro.stochastic import (DemandForecast, StochasticConfig,
                                      plan_stochastic)

        st = self._fleets[fleet]
        if forecast is None:
            forecast = DemandForecast(base=st.problem)
        if config is None:
            config = StochasticConfig(scenarios=16)
        current = (st.plan if st.plan is not None
                   else np.zeros(st.problem.m, dtype=np.int64))
        res = plan_stochastic(forecast, config, engine=self.engine,
                              current_fleet=current)
        adopted = np.maximum(current, res.fleet)
        cost_before = st.plan_cost
        st.plan = adopted
        st.plan_cost = float(adopted @ st.problem.node_types.cost)
        self.events.append(ScaleEvent(
            tick=self._tick, fleet=fleet, scope="preprovision",
            cost_before=cost_before, cost_after=st.plan_cost,
            checks=()))
        return res

    # -- checkpoint / recovery -----------------------------------------

    def snapshot(self, path: str) -> dict:
        """Write a versioned checkpoint (JSON manifest + npz arrays) of
        every fleet's state — problem, task ids, adopted plan, the
        cropped warm ``PDHGState`` with its alignment keys — plus the
        pending queue and telemetry counters.  Returns the manifest.
        See ``serve.snapshot`` for the format."""
        from .snapshot import save_snapshot
        return save_snapshot(self, path)

    @classmethod
    def restore(cls, path: str, engine: FleetEngine | None = None,
                config: ServiceConfig | None = None,
                faults: FaultInjector | None = None
                ) -> "RightsizingService":
        """Rebuild a service from ``snapshot(path)`` and resume: warm
        lanes, adopted plans, queue contents, and report() counters all
        carry over.  ``engine`` defaults to the service default (the
        snapshot does not capture engine internals); ``config``
        overrides the snapshotted ``ServiceConfig``."""
        from .snapshot import restore_service
        return restore_service(path, engine=engine, config=config,
                               faults=faults)

    # -- telemetry -----------------------------------------------------

    def report(self) -> dict:
        """Aggregate serving telemetry (JSON-ready): sustained
        requests/sec, re-plan latency percentiles, warm-vs-cold
        iteration medians, decision-loop event counts, and the
        deterministic total adopted plan cost."""
        lat = np.asarray(self._latencies, dtype=float)
        wall = sum(t.total_s for t in self.ticks)
        scopes: dict[str, int] = {}
        for e in self.events:
            scopes[e.scope] = scopes.get(e.scope, 0) + 1
        shed_reasons: dict[str, int] = {}
        for s in self.shed_events:
            shed_reasons[s.reason] = shed_reasons.get(s.reason, 0) + 1
        resolve_cold = self._iters["cold"] + self._iters["drift"]

        def _median(vals):
            return float(np.median(vals)) if vals else None

        return {
            "ticks": len(self.ticks),
            "fleets": len(self._fleets),
            "requests": int(lat.size),
            "wall_s": round(wall, 4),
            "requests_per_s": (round(float(lat.size) / wall, 3)
                               if wall > 0 else 0.0),
            "p50_replan_s": (round(float(np.percentile(lat, 50)), 4)
                             if lat.size else 0.0),
            "p99_replan_s": (round(float(np.percentile(lat, 99)), 4)
                             if lat.size else 0.0),
            "dispatches_per_tick": (max(t.dispatches for t in self.ticks)
                                    if self.ticks else 0),
            "warm_lanes": len(self._iters["warm"]),
            "cold_lanes": (len(resolve_cold) + len(self._iters["admit"])),
            "drift_fallbacks": sum(t.drift_fallbacks for t in self.ticks),
            "median_iters_warm": _median(self._iters["warm"]),
            "median_iters_cold": _median(resolve_cold),
            "median_iters_admit": _median(self._iters["admit"]),
            "converged_frac": (round(float(np.mean(self._converged)), 4)
                               if self._converged else 1.0),
            "events": scopes,
            "shed": len(self.shed_events),
            "shed_reasons": shed_reasons,
            "retries": self._retries,
            "quarantined": len(self.quarantined),
            "deadline_misses": self._deadline_misses,
            "total_cost": round(sum(st.plan_cost
                                    for st in self._fleets.values()), 6),
            "proposed_cost_total": round(self._proposed_cost, 6),
        }
