"""Fault injection for the serving loop — break it on purpose, in CI.

A crash-safe daemon is only believable if its failure paths run under
test.  This module is the injection harness the hardening tests and the
crash-and-recover benchmark drive:

  * ``FaultSpec``/``FaultInjector`` — declarative "fire fault X at
    fleet Y on tick Z, N times" triggers the service consults at its
    three failure points: request application (a raising request),
    the solve (solver non-convergence), and placement verification;
  * ``InjectedFault`` — the exception injected faults raise, so tests
    can tell a deliberate failure from a real one;
  * ``corrupt_snapshot`` — flips bytes inside a written checkpoint so
    recovery tests exercise the ``SnapshotError`` path.

The injector is deliberately dumb: it matches, decrements a budget, and
logs.  All retry/quarantine POLICY lives in ``serve.service`` — the
same code paths real failures take, which is the point.
"""

from __future__ import annotations

import dataclasses
import os

__all__ = ["FAULT_KINDS", "FaultSpec", "FaultInjector", "InjectedFault",
           "corrupt_snapshot"]

FAULT_KINDS = ("apply-raise", "nonconverge", "verify-fail")


class InjectedFault(RuntimeError):
    """Raised (or recorded) by a matching ``FaultSpec`` — distinct from
    real failures so tests can assert provenance."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection trigger.

    kind: 'apply-raise' (the request application raises mid-fold),
        'nonconverge' (treat the fleet's solve lane as failed), or
        'verify-fail' (placement verification raises).
    fleet: only fire for this fleet (None = any fleet).
    tick: only fire at this service tick (None = any tick).
    times: total firing budget (None = unlimited).  A budget of 1
        models a transient blip the first retry clears; a generous
        budget outlasts ``max_request_retries`` and forces quarantine.

    >>> FaultSpec(kind="segfault")
    Traceback (most recent call last):
        ...
    ValueError: fault kind must be one of ('apply-raise', 'nonconverge', 'verify-fail'), got 'segfault'
    """

    kind: str
    fleet: str | None = None
    tick: int | None = None
    times: int | None = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got "
                f"{self.kind!r}")
        if self.times is not None and self.times < 1:
            raise ValueError(
                f"times must be >= 1 (or None for unlimited), got "
                f"{self.times!r}")


class FaultInjector:
    """Matches service failure points against a list of ``FaultSpec``s.

    ``fire(kind, fleet=..., tick=...)`` returns True (and decrements
    the matching spec's budget, logging to ``fired``) when a spec
    matches; the service turns that True into the corresponding
    failure.  One call consumes at most one spec.

    >>> inj = FaultInjector([FaultSpec(kind="nonconverge", fleet="a")])
    >>> inj.fire("nonconverge", fleet="a", tick=3)
    True
    >>> inj.fire("nonconverge", fleet="a", tick=4)   # budget spent
    False
    >>> inj.fired[0]["tick"]
    3
    """

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...]):
        self.specs = tuple(specs)
        self._remaining = [s.times for s in self.specs]
        self.fired: list[dict] = []

    def fire(self, kind: str, *, fleet: str, tick: int) -> bool:
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {kind!r}")
        for i, spec in enumerate(self.specs):
            if spec.kind != kind:
                continue
            if spec.fleet is not None and spec.fleet != fleet:
                continue
            if spec.tick is not None and spec.tick != tick:
                continue
            if self._remaining[i] is not None:
                if self._remaining[i] <= 0:
                    continue
                self._remaining[i] -= 1
            self.fired.append(
                {"kind": kind, "fleet": fleet, "tick": tick, "spec": i})
            return True
        return False


def corrupt_snapshot(path: str, nbytes: int = 16, seed: int = 0) -> str:
    """Flip ``nbytes`` bytes in the middle of a snapshot's array blob
    (falling back to the manifest if there is no blob), so restore hits
    the checksum/parse error path.  Returns the corrupted file's path.
    """
    target = os.path.join(path, "arrays.npz")
    if not os.path.exists(target):
        target = os.path.join(path, "manifest.json")
    with open(target, "rb") as f:
        blob = bytearray(f.read())
    if not blob:
        raise ValueError(f"snapshot file {target} is empty")
    # deterministic positions, clustered mid-file where npz payload
    # (not just zip framing) lives
    start = len(blob) // 2
    for k in range(nbytes):
        pos = (start + seed + k * 7919) % len(blob)
        blob[pos] ^= 0xFF
    with open(target, "wb") as f:
        f.write(bytes(blob))
    return target
