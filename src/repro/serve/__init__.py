"""Online rightsizing service: a serving loop over ``FleetEngine``.

``RightsizingService`` keeps many live fleets rightsized under a
stream of arrivals/departures/bursts: an admission queue coalesces
requests into shape-bucketed micro-batches (one LP dispatch per tick),
perturbed fleets re-enter PDHG warm from their previous state, and a
flag-gated decision loop adopts or holds the proposed scale changes.
The loop is hardened for unattended operation: versioned
checkpoint/recovery (``serve.snapshot``), SLO-aware overload shedding
(``AdmissionQueue.shed``), and bounded retry-with-quarantine driven
under test by fault injection (``serve.faults``).  See docs/service.md
for the tick lifecycle, telemetry, and recovery semantics.
"""

from .config import ServiceConfig
from .faults import (FaultInjector, FaultSpec, InjectedFault,
                     corrupt_snapshot)
from .queue import (AdmissionQueue, PendingRequest, Request, ShedEvent,
                    NEVER_SHED_KINDS)
from .scale import ScaleCheck, ScaleDecision, ScaleEvent, evaluate_scale
from .service import (FleetView, QuarantineRecord, RightsizingService,
                      TickRecord)
from .snapshot import SNAPSHOT_VERSION, SnapshotError
from .trace import (TraceSpec, gct_trace, jobs_trace, replay,
                    replay_with_crash)

__all__ = [
    "ServiceConfig", "AdmissionQueue", "PendingRequest", "Request",
    "ShedEvent", "NEVER_SHED_KINDS",
    "ScaleCheck", "ScaleDecision", "ScaleEvent", "evaluate_scale",
    "FleetView", "QuarantineRecord", "RightsizingService", "TickRecord",
    "FaultInjector", "FaultSpec", "InjectedFault", "corrupt_snapshot",
    "SNAPSHOT_VERSION", "SnapshotError",
    "TraceSpec", "gct_trace", "jobs_trace", "replay",
    "replay_with_crash",
]
