"""Online rightsizing service: a serving loop over ``FleetEngine``.

``RightsizingService`` keeps many live fleets rightsized under a
stream of arrivals/departures/bursts: an admission queue coalesces
requests into shape-bucketed micro-batches (one LP dispatch per tick),
perturbed fleets re-enter PDHG warm from their previous state, and a
flag-gated decision loop adopts or holds the proposed scale changes.
See docs/service.md for the tick lifecycle and telemetry walkthrough.
"""

from .config import ServiceConfig
from .queue import AdmissionQueue, PendingRequest, Request
from .scale import ScaleCheck, ScaleDecision, ScaleEvent, evaluate_scale
from .service import FleetView, RightsizingService, TickRecord
from .trace import TraceSpec, gct_trace, jobs_trace, replay

__all__ = [
    "ServiceConfig", "AdmissionQueue", "PendingRequest", "Request",
    "ScaleCheck", "ScaleDecision", "ScaleEvent", "evaluate_scale",
    "FleetView", "RightsizingService", "TickRecord",
    "TraceSpec", "gct_trace", "jobs_trace", "replay",
]
