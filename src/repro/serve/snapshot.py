"""Versioned checkpoint/recovery for ``RightsizingService``.

A snapshot is a directory holding two files:

  * ``manifest.json`` — version tag, the frozen ``ServiceConfig``, all
    scalar/structured state (tick counter, per-fleet plan costs and
    scale history, the event/shed/quarantine logs, tick records, retry
    bookkeeping, queue metadata), and a SHA-256 checksum of the array
    blob;
  * ``arrays.npz`` — every numpy array: per-fleet problems, task ids,
    adopted plans, the cropped warm ``PDHGState`` (x, y) with its
    id/slot alignment keys, solutions, pending-request payloads, and
    the telemetry vectors.

Floats that feed the parity gates (plan costs, the proposed-cost
accumulator, warm step sizes) ride in the JSON manifest, which
round-trips Python floats exactly (``repr`` precision); arrays ride in
npz losslessly.  A restored service therefore resumes **bit-identical**:
replaying the rest of a trace after ``restore`` adopts exactly the
plans the uninterrupted replay would have, and warm lanes stay warm
across the restart boundary — the crash-and-recover CI gate holds both.

Queue timestamps are rebased: ``time.perf_counter`` origins are
process-local, so each pending request's *age* is snapshotted and its
submission time is reconstructed against the restoring process's
clock.  Downtime is excluded from re-plan latency by construction.

Corruption (a torn write, bit rot) surfaces as ``SnapshotError`` at
restore time via the manifest checksum — never as silently-wrong fleet
state; ``serve.faults.corrupt_snapshot`` exercises that path in tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

import numpy as np

from repro.core.constraints import TaskConstraints
from repro.core.problem import NodeTypes, Problem
from repro.core.solution import Solution

from .config import ServiceConfig
from .queue import PendingRequest, Request, ShedEvent
from .scale import ScaleEvent

__all__ = ["SNAPSHOT_VERSION", "SnapshotError", "save_snapshot",
           "restore_service"]

SNAPSHOT_VERSION = 1

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


class SnapshotError(RuntimeError):
    """A snapshot could not be read back: missing files, version
    mismatch, checksum failure, or undecodable manifest."""


def _sanitize(obj):
    """JSON-safe copy of free-form metadata (numpy scalars -> python,
    everything else -> repr string)."""
    return json.loads(json.dumps(obj, default=str))


def _request_entry(req: Request, arrays: dict, prefix: str) -> dict:
    """Manifest entry + array blobs for one ``Request``."""
    entry = {"fleet": req.fleet, "kind": req.kind, "T": req.T,
             "ids": None if req.ids is None else [int(i) for i in req.ids],
             "factor": req.factor, "deadline_s": req.deadline_s,
             "affinity": req.affinity,
             "anti_affinity": req.anti_affinity,
             "exclusive": req.exclusive,
             "deadline": req.deadline,
             "has_arrays": req.dem is not None,
             "has_node_types": req.node_types is not None}
    if req.dem is not None:
        arrays[f"{prefix}/dem"] = np.asarray(req.dem)
        arrays[f"{prefix}/start"] = np.asarray(req.start)
        arrays[f"{prefix}/end"] = np.asarray(req.end)
    if req.node_types is not None:
        entry["node_names"] = list(req.node_types.names)
        arrays[f"{prefix}/cap"] = np.asarray(req.node_types.cap)
        arrays[f"{prefix}/cost"] = np.asarray(req.node_types.cost)
    return entry


def _request_from(entry: dict, arrays, prefix: str) -> Request:
    node_types = None
    if entry["has_node_types"]:
        node_types = NodeTypes(cap=arrays[f"{prefix}/cap"],
                               cost=arrays[f"{prefix}/cost"],
                               names=tuple(entry["node_names"]))
    return Request(
        fleet=entry["fleet"], kind=entry["kind"],
        dem=arrays[f"{prefix}/dem"] if entry["has_arrays"] else None,
        start=arrays[f"{prefix}/start"] if entry["has_arrays"] else None,
        end=arrays[f"{prefix}/end"] if entry["has_arrays"] else None,
        node_types=node_types,
        T=None if entry["T"] is None else int(entry["T"]),
        ids=None if entry["ids"] is None else tuple(entry["ids"]),
        factor=entry["factor"], deadline_s=entry["deadline_s"],
        # .get keeps pre-constraint snapshots restorable
        affinity=entry.get("affinity"),
        anti_affinity=entry.get("anti_affinity"),
        exclusive=entry.get("exclusive"),
        deadline=entry.get("deadline"))


def save_snapshot(service, path: str) -> dict:
    """Checkpoint ``service`` into directory ``path`` (created if
    needed); returns the manifest dict.  Writes are staged through
    temporary names so a crash mid-snapshot never leaves a manifest
    pointing at a half-written blob."""
    os.makedirs(path, exist_ok=True)
    now_s = time.perf_counter()
    arrays: dict[str, np.ndarray] = {}

    fleets = []
    for i, (name, st) in enumerate(service._fleets.items()):
        p = st.problem
        entry = {
            "name": name,
            "T": int(p.T),
            "node_names": list(p.node_types.names),
            "next_id": int(st.next_id),
            "plan_cost": float(st.plan_cost),
            "last_scale_in_tick": int(st.last_scale_in_tick),
            "has_plan": st.plan is not None,
            "has_warm": st.warm is not None,
            "has_solution": st.solution is not None,
            "has_constraints": p.constraints is not None,
        }
        arrays[f"f{i}/dem"] = p.dem
        arrays[f"f{i}/start"] = p.start
        arrays[f"f{i}/end"] = p.end
        if p.constraints is not None:
            c = p.constraints
            entry["affinity_names"] = list(c.affinity_names)
            entry["anti_names"] = list(c.anti_names)
            arrays[f"f{i}/c_deadline"] = c.deadline
            arrays[f"f{i}/c_affinity"] = c.affinity
            arrays[f"f{i}/c_anti"] = c.anti_affinity
            arrays[f"f{i}/c_exclusive"] = c.exclusive
            arrays[f"f{i}/c_max_width"] = c.max_width
            arrays[f"f{i}/c_serial_frac"] = c.serial_frac
        arrays[f"f{i}/cap"] = p.node_types.cap
        arrays[f"f{i}/cost"] = p.node_types.cost
        arrays[f"f{i}/ids"] = st.ids
        if st.plan is not None:
            arrays[f"f{i}/plan"] = np.asarray(st.plan)
        if st.warm is not None:
            entry["warm_eta"] = st.warm.eta  # None or exact float
            entry["warm_omega"] = st.warm.omega
            arrays[f"f{i}/warm_x"] = st.warm.x
            arrays[f"f{i}/warm_y"] = st.warm.y
            arrays[f"f{i}/warm_ids"] = st.warm.ids
            arrays[f"f{i}/warm_kept"] = st.warm.kept
        if st.solution is not None:
            entry["solution_meta"] = _sanitize(st.solution.meta)
            arrays[f"f{i}/sol_node_type"] = st.solution.node_type
            arrays[f"f{i}/sol_assign"] = st.solution.assign
        fleets.append(entry)

    seq, pending = service.queue.dump()
    queue_items = []
    for j, item in enumerate(pending):
        entry = _request_entry(item.request, arrays, f"q{j}")
        entry["seq"] = int(item.seq)
        # perf_counter origins are process-local: persist the age, not
        # the raw timestamp (restore rebases onto its own clock)
        entry["age_s"] = float(max(0.0, now_s - item.submitted_s))
        queue_items.append(entry)

    arrays["t/latencies"] = np.asarray(service._latencies, dtype=float)
    for mode, vals in service._iters.items():
        arrays[f"t/iters_{mode}"] = np.asarray(vals, dtype=np.int64)
    arrays["t/converged"] = np.asarray(service._converged, dtype=bool)

    blob_path = os.path.join(path, _ARRAYS)
    tmp = blob_path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **arrays)
    os.replace(tmp, blob_path)
    with open(blob_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    manifest = {
        "version": SNAPSHOT_VERSION,
        "arrays_sha256": digest,
        "config": dataclasses.asdict(service.config),
        "tick": service._tick,
        "proposed_cost": service._proposed_cost,
        "retries": service._retries,
        "deadline_misses": service._deadline_misses,
        "attempts": {str(k): int(v)
                     for k, v in service._attempts.items()},
        "fleets": fleets,
        "queue": {"seq": int(seq), "items": queue_items},
        "events": [e.to_dict() for e in service.events],
        "shed_events": [e.to_dict() for e in service.shed_events],
        "quarantined": [q.to_dict() for q in service.quarantined],
        "ticks": [t.to_dict() for t in service.ticks],
    }
    manifest_path = os.path.join(path, _MANIFEST)
    tmp = manifest_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, manifest_path)
    return manifest


def _load(path: str):
    """Read and integrity-check a snapshot directory; returns
    ``(manifest, arrays)`` or raises ``SnapshotError``."""
    manifest_path = os.path.join(path, _MANIFEST)
    blob_path = os.path.join(path, _ARRAYS)
    for p in (manifest_path, blob_path):
        if not os.path.exists(p):
            raise SnapshotError(
                f"snapshot at {path!r} is missing {os.path.basename(p)}")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise SnapshotError(
            f"snapshot manifest at {manifest_path!r} is not valid "
            f"JSON ({e}) — the checkpoint is corrupt") from e
    version = manifest.get("version")
    if version != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"snapshot version {version!r} is not supported (this "
            f"build reads version {SNAPSHOT_VERSION})")
    with open(blob_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest.get("arrays_sha256"):
        raise SnapshotError(
            f"snapshot array blob at {blob_path!r} fails its checksum "
            f"(manifest {manifest.get('arrays_sha256')!r} != blob "
            f"{digest!r}) — the checkpoint is corrupt")
    try:
        arrays = dict(np.load(blob_path, allow_pickle=False))
    except Exception as e:  # zipfile/npy format errors
        raise SnapshotError(
            f"snapshot array blob at {blob_path!r} failed to load "
            f"({e})") from e
    return manifest, arrays


def restore_service(path: str, engine=None, config=None, faults=None):
    """Rebuild a ``RightsizingService`` from ``save_snapshot`` output.

    ``engine`` defaults to the service's default engine (snapshots
    capture fleet/queue/telemetry state, not engine internals — pass
    the same engine configuration the crashed service ran for identical
    resumed behavior).  ``config`` overrides the snapshotted
    ``ServiceConfig``; ``faults`` re-attaches an injection harness.
    """
    from .service import (QuarantineRecord, RightsizingService,
                          TickRecord, _FleetState, _LaneState)

    manifest, arrays = _load(path)
    if config is None:
        config = ServiceConfig(**manifest["config"])
    svc = RightsizingService(engine=engine, config=config, faults=faults)

    for i, entry in enumerate(manifest["fleets"]):
        node_types = NodeTypes(cap=arrays[f"f{i}/cap"],
                               cost=arrays[f"f{i}/cost"],
                               names=tuple(entry["node_names"]))
        constraints = None
        if entry.get("has_constraints"):
            constraints = TaskConstraints(
                deadline=arrays[f"f{i}/c_deadline"],
                affinity=arrays[f"f{i}/c_affinity"],
                anti_affinity=arrays[f"f{i}/c_anti"],
                exclusive=arrays[f"f{i}/c_exclusive"],
                max_width=arrays[f"f{i}/c_max_width"],
                serial_frac=arrays[f"f{i}/c_serial_frac"],
                affinity_names=tuple(entry["affinity_names"]),
                anti_names=tuple(entry["anti_names"]))
        problem = Problem(dem=arrays[f"f{i}/dem"],
                          start=arrays[f"f{i}/start"],
                          end=arrays[f"f{i}/end"],
                          node_types=node_types, T=int(entry["T"]),
                          constraints=constraints)
        st = _FleetState(problem=problem, ids=arrays[f"f{i}/ids"],
                         next_id=int(entry["next_id"]))
        st.plan_cost = float(entry["plan_cost"])
        st.last_scale_in_tick = int(entry["last_scale_in_tick"])
        if entry["has_plan"]:
            st.plan = arrays[f"f{i}/plan"]
        if entry["has_warm"]:
            eta = entry["warm_eta"]
            # pre-PR 8 snapshots have no primal weight: .get keeps them
            # restorable (the lane just re-adapts omega from 1)
            om = entry.get("warm_omega")
            st.warm = _LaneState(
                x=arrays[f"f{i}/warm_x"], y=arrays[f"f{i}/warm_y"],
                eta=None if eta is None else float(eta),
                omega=None if om is None else float(om),
                ids=arrays[f"f{i}/warm_ids"],
                kept=arrays[f"f{i}/warm_kept"])
        if entry["has_solution"]:
            st.solution = Solution(
                node_type=arrays[f"f{i}/sol_node_type"],
                assign=arrays[f"f{i}/sol_assign"],
                meta=entry.get("solution_meta", {}))
        svc._fleets[entry["name"]] = st

    now_s = time.perf_counter()
    pending = []
    for j, entry in enumerate(manifest["queue"]["items"]):
        pending.append(PendingRequest(
            seq=int(entry["seq"]),
            submitted_s=now_s - float(entry["age_s"]),
            request=_request_from(entry, arrays, f"q{j}")))
    svc.queue.load(manifest["queue"]["seq"], pending)

    svc._tick = int(manifest["tick"])
    svc._proposed_cost = float(manifest["proposed_cost"])
    svc._retries = int(manifest["retries"])
    svc._deadline_misses = int(manifest["deadline_misses"])
    svc._attempts = {int(k): int(v)
                     for k, v in manifest["attempts"].items()}
    svc.events = [ScaleEvent.from_dict(d) for d in manifest["events"]]
    svc.shed_events = [ShedEvent.from_dict(d)
                       for d in manifest["shed_events"]]
    svc.quarantined = [QuarantineRecord.from_dict(d)
                       for d in manifest["quarantined"]]
    svc.ticks = [TickRecord.from_dict(d) for d in manifest["ticks"]]
    svc._latencies = [float(v) for v in arrays["t/latencies"]]
    svc._iters = {mode: [int(v) for v in arrays[f"t/iters_{mode}"]]
                  for mode in ("warm", "cold", "drift", "admit")}
    svc._converged = [bool(v) for v in arrays["t/converged"]]
    return svc
