"""Admission queue: rightsizing requests in, per-tick micro-batches out.

A ``Request`` is one fleet perturbation — admit a new fleet, a batch of
task arrivals, task departures, or a demand burst (the online
arrival/departure regime of Dynamic Vector Bin Packing).  The
``AdmissionQueue`` keeps them in strict FIFO order; each service tick
drains a bounded prefix, coalesces it per fleet (so one fleet hit by
five requests re-solves ONCE with all five applied), and whatever the
tick's shape bucket cannot carry is requeued at the front with its
original submission order — deferral never reorders a fleet's stream.

Under sustained overload the queue would otherwise grow without bound,
so ``shed`` implements the SLO admission policy: when the backlog
exceeds ``ServiceConfig.max_pending``, queued ``replan`` requests — and
ONLY ``replan``s, which carry no perturbation — are dropped with a
structured ``ShedEvent``.  State-changing kinds (``admit``/``arrive``/
``depart``/``burst``/``constrain``) are never shed: dropping one would
silently fork the client's view of the fleet from the service's.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque

import numpy as np

__all__ = ["Request", "PendingRequest", "AdmissionQueue", "ShedEvent",
           "KINDS", "NEVER_SHED_KINDS"]

KINDS = ("admit", "arrive", "depart", "burst", "constrain", "replan")

# state-changing kinds: shedding one would desynchronize the client's
# fleet view, so the shed policy may only ever drop 'replan's
NEVER_SHED_KINDS = ("admit", "arrive", "depart", "burst", "constrain")


@dataclasses.dataclass(frozen=True)
class Request:
    """One rightsizing request against one fleet.

    kind='admit'  — create the fleet: dem (k, D), start/end (k,),
        node_types, T (the fleet's node catalogue and horizon are fixed
        at admission; task ids 0..k-1 are assigned in row order).
    kind='arrive' — append k tasks: dem (k, D), start/end (k,); the
        service assigns the next k ids in order.
    kind='depart' — remove tasks by id (``ids``).
    kind='burst'  — scale the demands of tasks ``ids`` by ``factor``
        (clamped to the fleet's largest per-dimension capacity).
    kind='constrain' — attach hard constraints to tasks ``ids``: any of
        ``affinity``/``anti_affinity`` (named groups, created on first
        use), ``exclusive`` (whole-node isolation), ``deadline`` (an
        inclusive finish slot).  Constraint semantics live in
        ``repro.core.constraints``; ids must reference live tasks
        (unknown ids raise at apply time, like depart/burst).
    kind='replan' — no perturbation; force a re-solve.

    ``deadline_s`` is an optional per-request SLO: the seconds the
    client allows between submission and service.  Requests served past
    their deadline feed the deadline-miss telemetry, and an expired
    queued ``replan`` is the first thing the shed policy drops.

    >>> import numpy as np
    >>> Request(fleet="a", kind="arrive", dem=np.ones((2, 2)),
    ...         start=np.zeros(2), end=np.ones(2)).n_tasks
    2
    >>> Request(fleet="a", kind="burst", ids=(1, 2))
    Traceback (most recent call last):
        ...
    ValueError: burst requests need ids and factor, got factor=None
    >>> Request(fleet="a", kind="burst", ids=(1,), factor=float("inf"))
    Traceback (most recent call last):
        ...
    ValueError: factor must be positive and finite, got inf
    """

    fleet: str
    kind: str
    dem: np.ndarray | None = None
    start: np.ndarray | None = None
    end: np.ndarray | None = None
    node_types: object | None = None
    T: int | None = None
    ids: tuple[int, ...] | None = None
    factor: float | None = None
    deadline_s: float | None = None
    affinity: str | None = None
    anti_affinity: str | None = None
    exclusive: bool | None = None
    deadline: int | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"request kind must be one of {KINDS}, got {self.kind!r}")
        if self.kind in ("admit", "arrive"):
            if self.dem is None or self.start is None or self.end is None:
                raise ValueError(
                    f"{self.kind} requests need dem/start/end arrays")
            if self.kind == "admit" and (self.node_types is None
                                         or self.T is None):
                raise ValueError(
                    "admit requests need node_types and T (the fleet's "
                    "catalogue and horizon are fixed at admission)")
            # non-finite payloads would flow silently through
            # _fit_demands (inf demand scales to zero) — reject here
            for field in ("dem", "start", "end"):
                vals = np.asarray(getattr(self, field), dtype=float)
                if not np.isfinite(vals).all():
                    raise ValueError(
                        f"{self.kind} request {field} must be finite, "
                        f"got non-finite entries")
        if self.kind == "depart" and not self.ids:
            raise ValueError("depart requests need a non-empty ids tuple")
        if self.kind == "burst" and (not self.ids or self.factor is None):
            raise ValueError(
                f"burst requests need ids and factor, got "
                f"factor={self.factor!r}")
        if self.kind == "constrain":
            if not self.ids:
                raise ValueError(
                    "constrain requests need a non-empty ids tuple")
            if (self.affinity is None and self.anti_affinity is None
                    and self.exclusive is None and self.deadline is None):
                raise ValueError(
                    "constrain requests need at least one of affinity/"
                    "anti_affinity/exclusive/deadline")
        if self.deadline is not None and self.deadline < 0:
            raise ValueError(
                f"deadline must be a slot index >= 0, got {self.deadline}")
        # 'not inf > 0' is False, so a bare positivity test would let
        # factor=inf through and _fit_demands would zero the demands
        if self.factor is not None and not (
                math.isfinite(self.factor) and self.factor > 0):
            raise ValueError(
                f"factor must be positive and finite, got {self.factor!r}")
        if self.deadline_s is not None and not (
                math.isfinite(self.deadline_s) and self.deadline_s > 0):
            raise ValueError(
                f"deadline_s must be positive and finite, got "
                f"{self.deadline_s!r}")

    @property
    def n_tasks(self) -> int:
        """Tasks this request adds (0 for depart/burst/replan)."""
        return 0 if self.dem is None else int(np.asarray(self.dem).shape[0])


@dataclasses.dataclass(frozen=True)
class PendingRequest:
    """A queued request plus its admission bookkeeping: the global FIFO
    sequence number and the submission timestamp the per-request re-plan
    latency is measured from."""

    seq: int
    submitted_s: float
    request: Request

    def deadline_at(self) -> float | None:
        """Absolute deadline on the submission clock (None = no SLO)."""
        if self.request.deadline_s is None:
            return None
        return self.submitted_s + self.request.deadline_s

    def expired(self, now_s: float) -> bool:
        deadline = self.deadline_at()
        return deadline is not None and now_s > deadline


@dataclasses.dataclass(frozen=True)
class ShedEvent:
    """One structured shed-log entry: which queued request the overload
    policy dropped, and why (JSON-ready via ``to_dict``).

    reason: 'deadline' (a queued replan's SLO already expired),
        'coalesced' (the same fleet has another pending request that
        forces the re-solve anyway), or 'pressure' (backlog still above
        ``max_pending`` — stalest replans go first).
    """

    tick: int
    seq: int
    fleet: str
    kind: str
    reason: str
    waited_s: float

    def __post_init__(self):
        # the never-drop guarantee, enforced structurally: only
        # perturbation-free replans are sheddable
        if self.kind in NEVER_SHED_KINDS:
            raise ValueError(
                f"shed events may only ever name 'replan' requests, "
                f"got kind={self.kind!r} (dropping a state-changing "
                f"request would desynchronize the fleet)")

    def to_dict(self) -> dict:
        return {"tick": self.tick, "seq": self.seq, "fleet": self.fleet,
                "kind": self.kind, "reason": self.reason,
                "waited_s": round(float(self.waited_s), 6)}

    @staticmethod
    def from_dict(d: dict) -> "ShedEvent":
        return ShedEvent(tick=int(d["tick"]), seq=int(d["seq"]),
                         fleet=d["fleet"], kind=d["kind"],
                         reason=d["reason"],
                         waited_s=float(d["waited_s"]))


class AdmissionQueue:
    """Strict-FIFO request queue with front-requeue for deferrals.

    >>> q = AdmissionQueue()
    >>> for f in ("a", "b", "a"):
    ...     _ = q.push(Request(fleet=f, kind="replan"), now_s=0.0)
    >>> taken = q.take(2)
    >>> [p.request.fleet for p in taken], len(q)
    (['a', 'b'], 1)
    >>> groups = AdmissionQueue.coalesce(taken)
    >>> list(groups)
    ['a', 'b']
    >>> q.requeue(taken)          # deferred tick: back to the front
    >>> [p.request.fleet for p in q.take(3)]
    ['a', 'b', 'a']
    """

    def __init__(self):
        self._pending: deque[PendingRequest] = deque()
        self._seq = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> int:
        return len(self._pending)

    def push(self, request: Request, now_s: float) -> PendingRequest:
        item = PendingRequest(seq=self._seq, submitted_s=now_s,
                              request=request)
        self._seq += 1
        self._pending.append(item)
        return item

    def take(self, cap: int) -> list[PendingRequest]:
        """Pop the up-to-``cap`` oldest pending requests (FIFO)."""
        out = []
        while self._pending and len(out) < cap:
            out.append(self._pending.popleft())
        return out

    def requeue(self, items: list[PendingRequest]) -> None:
        """Push deferred requests back to the FRONT, preserving their
        original submission order (they stay the oldest work)."""
        for item in sorted(items, key=lambda p: p.seq, reverse=True):
            self._pending.appendleft(item)

    # -- overload shedding --------------------------------------------

    def shed(self, now_s: float, max_pending: int,
             tick: int) -> list[ShedEvent]:
        """SLO admission under queue pressure: drop sheddable queued
        requests until the backlog fits ``max_pending`` again.

        Only ``replan``s are ever dropped (they carry no perturbation —
        the fleet simply stays at its current adopted plan), in three
        escalating waves:

          1. 'deadline'  — queued replans whose SLO already expired are
             dead on arrival regardless of pressure;
          2. 'coalesced' — replans whose fleet has ANOTHER pending
             request (that request forces the re-solve anyway);
          3. 'pressure'  — stalest remaining replans, oldest first.

        Waves 2 and 3 only run while the backlog exceeds
        ``max_pending``; ``admit``/``arrive``/``depart``/``burst`` are
        never touched, so the backlog can legitimately stay above the
        bound when it is made of state-changing work.
        """
        events: list[ShedEvent] = []

        def _drop(item: PendingRequest, reason: str) -> ShedEvent:
            return ShedEvent(tick=tick, seq=item.seq,
                             fleet=item.request.fleet,
                             kind=item.request.kind, reason=reason,
                             waited_s=max(0.0, now_s - item.submitted_s))

        # wave 1: expired replans are useless whatever the pressure
        keep: deque[PendingRequest] = deque()
        for item in self._pending:
            if item.request.kind == "replan" and item.expired(now_s):
                events.append(_drop(item, "deadline"))
            else:
                keep.append(item)
        self._pending = keep
        if len(self._pending) <= max_pending:
            return events

        # wave 2: replans another same-fleet request makes redundant
        fleets_with_other = {
            item.request.fleet for item in self._pending
            if item.request.kind != "replan"}
        keep = deque()
        over = len(self._pending) - max_pending
        for item in self._pending:
            if (over > 0 and item.request.kind == "replan"
                    and item.request.fleet in fleets_with_other):
                events.append(_drop(item, "coalesced"))
                over -= 1
            else:
                keep.append(item)
        self._pending = keep
        if len(self._pending) <= max_pending:
            return events

        # wave 3: stalest remaining replans, oldest (front) first
        keep = deque()
        over = len(self._pending) - max_pending
        for item in self._pending:
            if over > 0 and item.request.kind == "replan":
                events.append(_drop(item, "pressure"))
                over -= 1
            else:
                keep.append(item)
        self._pending = keep
        return events

    # -- snapshot plumbing --------------------------------------------

    def dump(self) -> tuple[int, list[PendingRequest]]:
        """(next seq, pending items oldest-first) for checkpointing."""
        return self._seq, list(self._pending)

    def load(self, seq: int, items: list[PendingRequest]) -> None:
        """Restore a dumped queue (replaces any current contents)."""
        self._seq = int(seq)
        self._pending = deque(items)

    @staticmethod
    def coalesce(items: list[PendingRequest]) -> dict:
        """Group a drained prefix per fleet, preserving both the
        per-fleet request order and the fleets' oldest-first order."""
        groups: dict[str, list[PendingRequest]] = {}
        for item in items:
            groups.setdefault(item.request.fleet, []).append(item)
        return groups
