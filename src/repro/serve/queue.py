"""Admission queue: rightsizing requests in, per-tick micro-batches out.

A ``Request`` is one fleet perturbation — admit a new fleet, a batch of
task arrivals, task departures, or a demand burst (the online
arrival/departure regime of Dynamic Vector Bin Packing).  The
``AdmissionQueue`` keeps them in strict FIFO order; each service tick
drains a bounded prefix, coalesces it per fleet (so one fleet hit by
five requests re-solves ONCE with all five applied), and whatever the
tick's shape bucket cannot carry is requeued at the front with its
original submission order — deferral never reorders a fleet's stream.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

__all__ = ["Request", "PendingRequest", "AdmissionQueue", "KINDS"]

KINDS = ("admit", "arrive", "depart", "burst", "replan")


@dataclasses.dataclass(frozen=True)
class Request:
    """One rightsizing request against one fleet.

    kind='admit'  — create the fleet: dem (k, D), start/end (k,),
        node_types, T (the fleet's node catalogue and horizon are fixed
        at admission; task ids 0..k-1 are assigned in row order).
    kind='arrive' — append k tasks: dem (k, D), start/end (k,); the
        service assigns the next k ids in order.
    kind='depart' — remove tasks by id (``ids``).
    kind='burst'  — scale the demands of tasks ``ids`` by ``factor``
        (clamped to the fleet's largest per-dimension capacity).
    kind='replan' — no perturbation; force a re-solve.

    >>> import numpy as np
    >>> Request(fleet="a", kind="arrive", dem=np.ones((2, 2)),
    ...         start=np.zeros(2), end=np.ones(2)).n_tasks
    2
    >>> Request(fleet="a", kind="burst", ids=(1, 2))
    Traceback (most recent call last):
        ...
    ValueError: burst requests need ids and factor, got factor=None
    """

    fleet: str
    kind: str
    dem: np.ndarray | None = None
    start: np.ndarray | None = None
    end: np.ndarray | None = None
    node_types: object | None = None
    T: int | None = None
    ids: tuple[int, ...] | None = None
    factor: float | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"request kind must be one of {KINDS}, got {self.kind!r}")
        if self.kind in ("admit", "arrive"):
            if self.dem is None or self.start is None or self.end is None:
                raise ValueError(
                    f"{self.kind} requests need dem/start/end arrays")
            if self.kind == "admit" and (self.node_types is None
                                         or self.T is None):
                raise ValueError(
                    "admit requests need node_types and T (the fleet's "
                    "catalogue and horizon are fixed at admission)")
        if self.kind == "depart" and not self.ids:
            raise ValueError("depart requests need a non-empty ids tuple")
        if self.kind == "burst" and (not self.ids or self.factor is None):
            raise ValueError(
                f"burst requests need ids and factor, got "
                f"factor={self.factor!r}")
        if self.factor is not None and not self.factor > 0:
            raise ValueError(f"factor must be positive, got {self.factor!r}")

    @property
    def n_tasks(self) -> int:
        """Tasks this request adds (0 for depart/burst/replan)."""
        return 0 if self.dem is None else int(np.asarray(self.dem).shape[0])


@dataclasses.dataclass(frozen=True)
class PendingRequest:
    """A queued request plus its admission bookkeeping: the global FIFO
    sequence number and the submission timestamp the per-request re-plan
    latency is measured from."""

    seq: int
    submitted_s: float
    request: Request


class AdmissionQueue:
    """Strict-FIFO request queue with front-requeue for deferrals.

    >>> q = AdmissionQueue()
    >>> for f in ("a", "b", "a"):
    ...     _ = q.push(Request(fleet=f, kind="replan"), now_s=0.0)
    >>> taken = q.take(2)
    >>> [p.request.fleet for p in taken], len(q)
    (['a', 'b'], 1)
    >>> groups = AdmissionQueue.coalesce(taken)
    >>> list(groups)
    ['a', 'b']
    >>> q.requeue(taken)          # deferred tick: back to the front
    >>> [p.request.fleet for p in q.take(3)]
    ['a', 'b', 'a']
    """

    def __init__(self):
        self._pending: deque[PendingRequest] = deque()
        self._seq = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> int:
        return len(self._pending)

    def push(self, request: Request, now_s: float) -> PendingRequest:
        item = PendingRequest(seq=self._seq, submitted_s=now_s,
                              request=request)
        self._seq += 1
        self._pending.append(item)
        return item

    def take(self, cap: int) -> list[PendingRequest]:
        """Pop the up-to-``cap`` oldest pending requests (FIFO)."""
        out = []
        while self._pending and len(out) < cap:
            out.append(self._pending.popleft())
        return out

    def requeue(self, items: list[PendingRequest]) -> None:
        """Push deferred requests back to the FRONT, preserving their
        original submission order (they stay the oldest work)."""
        for item in sorted(items, key=lambda p: p.seq, reverse=True):
            self._pending.appendleft(item)

    @staticmethod
    def coalesce(items: list[PendingRequest]) -> dict:
        """Group a drained prefix per fleet, preserving both the
        per-fleet request order and the fleets' oldest-first order."""
        groups: dict[str, list[PendingRequest]] = {}
        for item in items:
            groups.setdefault(item.request.fleet, []).append(item)
        return groups
