"""The metrics -> flags -> scale decision loop.

Each tick's re-solve proposes ``required`` node counts per type for
every re-planned fleet.  This module decides what the fleet actually
adopts, the way managed autoscalers do it: every precondition of a
scale-in is evaluated as a named flag with a human-readable message,
the decision is the conjunction, and the whole evaluation is logged as
a structured event.  Growing is never gated — a fleet below its
required counts is infeasible — but releasing nodes must pass a
cooldown window, a minimum-savings threshold, and an Eva-style
reconfiguration payback test (projected savings over a payback horizon
must beat the churn cost of changing nodes), so the plan does not
thrash between epsilon-different placements.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ScaleCheck", "ScaleDecision", "ScaleEvent", "evaluate_scale"]


@dataclasses.dataclass(frozen=True)
class ScaleCheck:
    """One named scale-in precondition: flag=True means it passed."""

    name: str
    flag: bool
    message: str

    def to_dict(self) -> dict:
        return {"name": self.name, "flag": bool(self.flag),
                "message": self.message}

    @staticmethod
    def from_dict(d: dict) -> "ScaleCheck":
        return ScaleCheck(name=d["name"], flag=bool(d["flag"]),
                          message=d["message"])


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """The adopted plan of one fleet after flag evaluation.

    scope: 'admit' (first plan), 'hold' (no change), 'scale-out'
        (forced growth only), 'scale-in' (release adopted), or
        'hold-release' (a proposed release rejected by the flags —
        the fleet holds ``max(current, required)``).
    adopted: (m,) node counts the fleet runs with after this tick.
    cost: adopted counts priced at the node-type costs.
    checks: every evaluated flag (empty when no release was proposed).
    """

    scope: str
    adopted: np.ndarray
    cost: float
    checks: tuple[ScaleCheck, ...] = ()

    @property
    def scaled_in(self) -> bool:
        return self.scope == "scale-in"


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One structured decision-log entry (JSON-ready via ``to_dict``)."""

    tick: int
    fleet: str
    scope: str
    cost_before: float
    cost_after: float
    checks: tuple[ScaleCheck, ...]

    def to_dict(self) -> dict:
        return {
            "tick": self.tick, "fleet": self.fleet, "scope": self.scope,
            "cost_before": round(float(self.cost_before), 6),
            "cost_after": round(float(self.cost_after), 6),
            "checks": [c.to_dict() for c in self.checks],
        }

    @staticmethod
    def from_dict(d: dict) -> "ScaleEvent":
        return ScaleEvent(
            tick=int(d["tick"]), fleet=d["fleet"], scope=d["scope"],
            cost_before=float(d["cost_before"]),
            cost_after=float(d["cost_after"]),
            checks=tuple(ScaleCheck.from_dict(c) for c in d["checks"]))


def evaluate_scale(current: np.ndarray | None, required: np.ndarray,
                   node_cost: np.ndarray, *, tick: int,
                   last_scale_in_tick: int, cfg) -> ScaleDecision:
    """Flag-gated scale decision for one fleet.

    ``current`` is the fleet's adopted per-type node counts (None for a
    fresh fleet), ``required`` the counts the tick's placement needs,
    ``node_cost`` the per-type hourly cost, ``cfg`` a ``ServiceConfig``.

    >>> import numpy as np
    >>> from repro.serve.config import ServiceConfig
    >>> cfg = ServiceConfig(scale_in_cooldown=2, min_scale_in_savings=0.01)
    >>> cost = np.array([1.0, 2.0])
    >>> d = evaluate_scale(np.array([2, 1]), np.array([1, 1]), cost,
    ...                    tick=0, last_scale_in_tick=-10, cfg=cfg)
    >>> d.scope, d.adopted.tolist()
    ('scale-in', [1, 1])
    >>> d = evaluate_scale(np.array([2, 1]), np.array([1, 1]), cost,
    ...                    tick=1, last_scale_in_tick=0, cfg=cfg)
    >>> d.scope, d.adopted.tolist()        # cooldown holds the release
    ('hold-release', [2, 1])
    """
    required = np.asarray(required, dtype=np.int64)
    node_cost = np.asarray(node_cost, dtype=float)
    if current is None:
        return ScaleDecision(scope="admit", adopted=required,
                             cost=float(required @ node_cost))
    current = np.asarray(current, dtype=np.int64)
    hold = np.maximum(current, required)   # feasible without releases
    hold_cost = float(hold @ node_cost)
    required_cost = float(required @ node_cost)
    releases = hold - required
    if not releases.any():
        scope = "scale-out" if (required > current).any() else "hold"
        return ScaleDecision(scope=scope, adopted=hold, cost=hold_cost)

    savings = hold_cost - required_cost
    savings_frac = savings / max(hold_cost, 1e-12)
    churn = float(np.abs(required - current) @ node_cost)
    since = tick - last_scale_in_tick
    checks = (
        ScaleCheck(
            "cooldown", since >= cfg.scale_in_cooldown,
            f"{since} tick(s) since last scale-in "
            f"(need >= {cfg.scale_in_cooldown})"),
        ScaleCheck(
            "savings", savings_frac >= cfg.min_scale_in_savings,
            f"release saves {savings_frac:.2%} of the current plan "
            f"(need >= {cfg.min_scale_in_savings:.2%})"),
        ScaleCheck(
            "payback", savings * cfg.payback_ticks
            >= cfg.reconfig_weight * churn,
            f"savings over {cfg.payback_ticks} tick(s) = "
            f"{savings * cfg.payback_ticks:.3f} vs reconfiguration "
            f"cost {cfg.reconfig_weight:.2f} x {churn:.3f} node churn"),
    )
    if all(c.flag for c in checks):
        return ScaleDecision(scope="scale-in", adopted=required,
                             cost=required_cost, checks=checks)
    return ScaleDecision(scope="hold-release", adopted=hold,
                         cost=hold_cost, checks=checks)
