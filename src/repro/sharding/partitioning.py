"""Sharding rules: parameter, batch, and decode-state PartitionSpecs.

Mesh axes: ('data', 'model') single-pod; ('pod', 'data', 'model') multi-pod.

Policy (DESIGN.md §6):
  * Params: TP along 'model' (heads / ffn hidden / expert axis) + FSDP
    along 'data' (d_model or the complementary axis).  Params are
    *replicated* across 'pod' — the only cross-pod collective is the
    gradient all-reduce (the cheapest thing to put on DCN).
  * Activations: batch over ('pod', 'data') when divisible.
  * Decode caches: batch over 'data' when divisible; for global_batch=1
    long-context cells the cache length axis shards over 'data'
    (sequence-parallel KV) instead.
  * Head axes shard over 'model' only when divisible; otherwise head_dim
    takes the shard (KV-head counts of 1/2/8 vs model=16).
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import ModelConfig

__all__ = ["param_specs", "batch_specs", "decode_state_specs",
           "named", "tree_named"]


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _maybe(mesh, dim_size, axis):
    """Axis name if it divides the dim, else None."""
    return axis if _div(dim_size, _axis_size(mesh, axis)) else None


def param_specs(params, cfg: ModelConfig, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params``."""
    import jax

    model = "model"
    data = "data"
    msz = _axis_size(mesh, model)
    dsz = _axis_size(mesh, data)

    def spec_for(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path]
        name = names[-1]
        # leading stacked axes: segments -> (repeats, ...); encoder blocks
        stacked = ("segments" in names) or ("blocks" in names)
        lead = (None,) if stacked else ()
        shape = leaf.shape[1:] if stacked else leaf.shape

        def pspec(*rest):
            return P(*(lead + rest))

        if name == "embed":
            return P(_maybe(mesh, leaf.shape[0], model),
                     _maybe(mesh, leaf.shape[1], data))
        if name in ("wq", "wk", "wv"):       # (d, H, hd)
            d, H, hd = shape
            if _div(H, msz):
                return pspec(_maybe(mesh, d, data), model, None)
            return pspec(_maybe(mesh, d, data), None,
                         _maybe(mesh, hd, model))
        if name == "wo":                      # (H, hd, d)
            H, hd, d = shape
            if _div(H, msz):
                return pspec(model, None, _maybe(mesh, d, data))
            return pspec(None, _maybe(mesh, hd, model),
                         _maybe(mesh, d, data))
        if name in ("bq", "bk", "bv"):        # (H, hd)
            H, hd = shape
            if _div(H, msz):
                return pspec(model, None)
            return pspec(None, _maybe(mesh, hd, model))
        if name in ("w_gate", "w_up"):
            if len(shape) == 3:               # moe (E, d, ff): EP + FSDP(d)
                # (§Perf kimi iteration 3, REFUTED: replicating d across
                # 'data' left the combine all-reduce unchanged and grew
                # per-device argument bytes 16x — EP+FSDP stays.)
                E, d, ff = shape
                return pspec(_maybe(mesh, E, model),
                             _maybe(mesh, d, data), None)
            d, ff = shape                     # dense (d, ff)
            return pspec(_maybe(mesh, d, data), _maybe(mesh, ff, model))
        if name == "w_down":
            if len(shape) == 3:               # moe (E, ff, d)
                E, ff, d = shape
                return pspec(_maybe(mesh, E, model), None,
                             _maybe(mesh, d, data))
            ff, d = shape
            return pspec(_maybe(mesh, ff, model), _maybe(mesh, d, data))
        if name == "router":                  # (d, E)
            d, E = shape
            return pspec(_maybe(mesh, d, data), _maybe(mesh, E, model))
        if name in ("w_x",):                  # rglru (d, W)
            d, W = shape
            return pspec(_maybe(mesh, d, data), _maybe(mesh, W, model))
        if name in ("w_input_gate", "w_rec_gate"):  # (W, W)
            W1, W2 = shape
            return pspec(_maybe(mesh, W1, data), _maybe(mesh, W2, model))
        if name == "w_out":                   # (W|d, d)
            a, d = shape
            return pspec(_maybe(mesh, a, model), _maybe(mesh, d, data))
        if name == "conv_w":                  # (K, W)
            return pspec(None, _maybe(mesh, shape[1], model))
        if name == "lam":                     # (W,)
            return pspec(_maybe(mesh, shape[0], model))
        if name in ("w_r", "w_k", "w_v", "w_g", "w_decay"):  # rwkv (d, *)
            a, b = shape
            return pspec(_maybe(mesh, a, data), _maybe(mesh, b, model))
        # everything small: norms, mu_*, biases, u_bonus, ln_x, decay_bias
        return pspec(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def _batch_axes(mesh: Mesh, B: int):
    """Largest prefix of ('pod','data') whose product divides B."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    prod = 1
    chosen = []
    for a in axes:
        if _div(B, prod * mesh.shape[a]):
            chosen.append(a)
            prod *= mesh.shape[a]
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def batch_specs(batch, cfg: ModelConfig, mesh: Mesh):
    """Specs for a train/prefill batch dict keyed by field name."""
    specs = {}
    for k, v in batch.items():
        if k == "mrope_positions":            # (3, B, S)
            specs[k] = P(None, _batch_axes(mesh, v.shape[1]), None)
        elif v.ndim == 1:                     # (B,) decode tokens
            specs[k] = P(_batch_axes(mesh, v.shape[0]))
        elif v.ndim == 2:                     # (B, S)
            specs[k] = P(_batch_axes(mesh, v.shape[0]), None)
        else:                                 # (B, S, d) frames/vision
            specs[k] = P(_batch_axes(mesh, v.shape[0]), None, None)
    return specs


def decode_state_specs(state, cfg: ModelConfig, mesh: Mesh):
    """Specs for the decode-state pytree (stacked caches)."""
    import jax

    msz = _axis_size(mesh, "model")

    def spec_for(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path]
        name = names[-1]
        if name == "pos" or leaf.ndim == 0:
            return P()
        if name == "slot_pos":                # (repeats, CL)
            return P(None, None)
        B = leaf.shape[1]
        bax = _batch_axes(mesh, B)
        if name in ("k", "v"):                # (repeats, B, CL, KV, hd)
            _r, _b, CL, KV, hd = leaf.shape
            kv_ax = "model" if _div(KV, msz) else None
            hd_ax = None if kv_ax else _maybe(mesh, hd, "model")
            if bax is None:
                # long-context, batch=1: sequence-parallel cache
                return P(None, None, _maybe(mesh, CL, "data"), kv_ax, hd_ax)
            return P(None, bax, None, kv_ax, hd_ax)
        if name in ("xk", "xv"):              # (repeats, B, Se, KV, hd)
            _r, _b, Se, KV, hd = leaf.shape
            kv_ax = "model" if _div(KV, msz) else None
            hd_ax = None if kv_ax else _maybe(mesh, hd, "model")
            return P(None, bax, None, kv_ax, hd_ax)
        if name == "h":                       # (repeats, B, W)
            return P(None, bax, _maybe(mesh, leaf.shape[2], "model"))
        if name == "conv":                    # (repeats, B, K-1, W)
            return P(None, bax, None, _maybe(mesh, leaf.shape[3], "model"))
        if name == "S":                       # (repeats, B, H, N, N)
            return P(None, bax, _maybe(mesh, leaf.shape[2], "model"),
                     None, None)
        if name in ("x_tm", "x_cm"):          # (repeats, B, d)
            return P(None, bax, _maybe(mesh, leaf.shape[2], "model"))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, state)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, specs):
    import jax

    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
