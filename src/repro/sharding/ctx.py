"""Ambient sharding-hint context.

Model code is mesh-agnostic; launchers establish a mesh context and the
model's hot spots call ``constrain(x, 'batch', None, 'heads', None)`` with
*logical* axis names.  Without a context (smoke tests, CPU examples) the
calls are no-ops, so the same model code runs everywhere.

Logical axes:
  'batch'  -> the ('pod','data') prefix that divides the dim
  'model'  -> 'model' if it divides the dim
  'heads'  -> alias of 'model' (reads better at call sites)
  None     -> unsharded

This is the mechanism behind the §Perf hillclimb: explicit constraints at
attention/MoE/recurrence boundaries remove GSPMD's "involuntary full
rematerialization" reshards (verified to cut the gemma2-9b train step's
traffic and collective terms; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["use_mesh", "constrain", "current_mesh", "hints_enabled"]

_STATE = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


def hints_enabled() -> bool:
    return getattr(_STATE, "mesh", None) is not None


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    """Enable sharding hints under ``mesh`` (None = disable)."""
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def _resolve(axis, dim: int, mesh: Mesh):
    if axis is None:
        return None
    if axis == "batch":
        chosen = []
        prod = 1
        for a in ("pod", "data"):
            sz = mesh.shape.get(a, 0)
            if sz and dim % (prod * sz) == 0:
                chosen.append(a)
                prod *= sz
        if not chosen:
            return None
        return tuple(chosen) if len(chosen) > 1 else chosen[0]
    name = "model" if axis in ("model", "heads") else axis
    sz = mesh.shape.get(name, 0)
    return name if sz and dim % sz == 0 else None


def constrain(x, *axes):
    """with_sharding_constraint with logical axes; no-op without a mesh
    context or when an axis does not divide."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{x.ndim} array")
    spec = P(*(_resolve(a, d, mesh) for a, d in zip(axes, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
