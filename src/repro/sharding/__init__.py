"""Distribution: partitioning rules for params, batches and decode state."""

from .partitioning import (
    batch_specs,
    decode_state_specs,
    named,
    param_specs,
    tree_named,
)

__all__ = ["batch_specs", "decode_state_specs", "named", "param_specs",
           "tree_named"]
