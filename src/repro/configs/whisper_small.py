"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865 — enc-dec, conv frontend is a stub (input_specs
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""

from repro.models.config import GLOBAL_WINDOW, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    pattern=tuple((("xattn", GLOBAL_WINDOW, 10_000.0, False)
                   for _ in range(12))),
    encoder_layers=12,
    encoder_seq=1500,
)
