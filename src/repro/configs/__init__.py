"""Architecture registry: the 10 assigned configs, the shape grid, cell
eligibility, and reduced smoke-test variants."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import GLOBAL_WINDOW, ModelConfig

__all__ = [
    "ARCHS", "SHAPES", "get_config", "smoke_config", "cells",
    "cell_eligible", "Shape",
]

# arch id -> module (one file per assigned architecture)
ARCHS = {
    "gemma3-1b": "gemma3_1b",
    "gemma2-9b": "gemma2_9b",
    "qwen2.5-3b": "qwen25_3b",
    "granite-34b": "granite_34b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "whisper-small": "whisper_small",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "rwkv6-7b": "rwkv6_7b",
}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def cell_eligible(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """long_500k runs only for sub-quadratic archs (SSM / hybrid / mostly-
    local); pure full-attention archs skip it (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k skipped per spec"
    return True, ""


def cells(include_skipped: bool = False):
    """All (arch, shape) cells; 40 total, with eligibility annotations."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_eligible(cfg, shape)
            if ok or include_skipped:
                out.append((arch, shape.name, ok, why))
    return out


def smoke_config(arch: str) -> ModelConfig:
    """A reduced same-family config: same pattern structure (one scan unit
    + remainder), tiny dims — runs a forward/train step on CPU in seconds."""
    cfg = get_config(arch)
    unit = max(cfg.scan_unit, 1)
    # keep 2 units + the same remainder structure, so segments mirror the
    # full config
    rem = cfg.num_layers % unit
    n_layers = 2 * unit + rem
    pattern = tuple(
        (k, (64 if w not in (0, GLOBAL_WINDOW) else w), t, m)
        for (k, w, t, m) in (cfg.pattern[:2 * unit] + cfg.pattern[
            cfg.num_layers - rem:] if rem else cfg.pattern[:n_layers])
    )
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    d_model = 64
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        pattern=pattern,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2)
        if cfg.num_experts else 0,
        moe_d_ff=32 if cfg.num_experts else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 24),
        vision_seq=min(cfg.vision_seq, 8),
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else None,
        rnn_width=d_model if cfg.rnn_width else 0,
        rwkv_head_dim=16,
        dtype="float32",
    )
