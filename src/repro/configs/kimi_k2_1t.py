"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert
vocab=163840, MoE 384 experts top-8 — trillion-param MoE (paper-table
config; the real K2 uses MLA attention and one dense first layer, the
assigned table specifies GQA kv=8 and uniform MoE, which we follow).
[arXiv:2501.kimi2; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163_840,
    num_experts=384,
    num_experts_per_tok=8,
    moe_d_ff=2048,
    capacity_factor=1.25,
)
