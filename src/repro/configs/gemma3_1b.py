"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import GLOBAL_WINDOW, ModelConfig

WINDOW = 512
_UNIT = tuple(
    ("attn", WINDOW, 10_000.0, False) for _ in range(5)
) + (("attn", GLOBAL_WINDOW, 1_000_000.0, False),)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    pattern=_UNIT * 4 + _UNIT[:2],   # 26 = 6*4 + 2 (trailing locals)
    scan_unit=6,
    rope_theta=1_000_000.0,
    subquadratic=True,  # 5:1 local; global layers are decode-KV-bounded
)
