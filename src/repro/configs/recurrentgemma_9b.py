"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; unverified]"""

from repro.models.config import ModelConfig

WINDOW = 2048
_UNIT = (
    ("rglru", 0, 10_000.0, False),
    ("rglru", 0, 10_000.0, False),
    ("attn", WINDOW, 10_000.0, False),
)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    pattern=_UNIT * 12 + _UNIT[:2],  # 38 = 3*12 + 2 (trailing recurrents)
    scan_unit=3,
    rnn_width=4096,
    conv_width=4,
    subquadratic=True,
)
