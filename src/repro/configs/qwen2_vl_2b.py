"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution (patch frontend is a stub:
input_specs provides precomputed patch embeddings and 3-axis positions).
[arXiv:2409.12191; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    mrope_sections=(16, 24, 24),
    vision_seq=256,
    rope_theta=1_000_000.0,
)
