"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]"""

from repro.models.config import GLOBAL_WINDOW, ModelConfig

WINDOW = 4096
_UNIT = (
    ("attn", WINDOW, 10_000.0, False),
    ("attn", GLOBAL_WINDOW, 10_000.0, False),
)

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    pattern=_UNIT * 21,
    scan_unit=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
)
