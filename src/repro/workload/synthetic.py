"""Synthetic benchmark generator (paper §VI-A, Table I).

Each of the D components of demand and capacity is uniform i.i.d. in its
interval; each task's span [s, e] is uniform over [1, T] (we draw two
uniform slots and order them).  Defaults follow Table I:

    n=1000, m=10, T=24, D=5, capacity ~ U[0.2, 1.0], demand ~ U[0.01, 0.1].
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import NodeTypes, Problem
from .cost_models import heterogeneous_cost, homogeneous_cost

__all__ = ["SyntheticSpec", "synthetic_instance", "sweep_specs",
           "synthetic_batch"]


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    n: int = 1000
    m: int = 10
    D: int = 5
    T: int = 24
    demand: tuple[float, float] = (0.01, 0.1)
    capacity: tuple[float, float] = (0.2, 1.0)
    cost_model: str = "homogeneous"  # 'homogeneous' | 'heterogeneous'
    e: float = 1.0                   # heterogeneous cost exponent
    seed: int = 0


def synthetic_instance(spec: SyntheticSpec = SyntheticSpec(),
                       rng: np.random.Generator | None = None) -> Problem:
    """Draw one Table-I instance from ``spec``.

    Bit-reproducible seed plumbing: every draw (capacities, the
    heterogeneous cost coefficients, demands, spans) comes from ONE
    explicit generator — ``rng`` when given, else a fresh
    ``np.random.default_rng(spec.seed)`` — so the same spec always
    yields the same instance and global NumPy state is never touched.
    Pass ``rng`` to draw several instances from one stream.
    """
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    cap = rng.uniform(*spec.capacity, size=(spec.m, spec.D))
    if spec.cost_model == "homogeneous":
        cost = homogeneous_cost(cap)
    elif spec.cost_model == "heterogeneous":
        cost = heterogeneous_cost(cap, e=spec.e, rng=rng)
    else:
        raise ValueError(f"unknown cost model {spec.cost_model!r}")
    dem = rng.uniform(*spec.demand, size=(spec.n, spec.D))
    a = rng.integers(0, spec.T, size=spec.n)
    b = rng.integers(0, spec.T, size=spec.n)
    start, end = np.minimum(a, b), np.maximum(a, b)
    return Problem(
        dem=dem, start=start, end=end,
        node_types=NodeTypes(cap=cap, cost=cost), T=spec.T,
    )


def sweep_specs(base: SyntheticSpec = SyntheticSpec(), seeds: int = 1,
                **axes) -> list[SyntheticSpec]:
    """Cartesian sweep grid over spec fields x seeds (paper Table I).

    Each keyword names a ``SyntheticSpec`` field and gives the values to
    sweep; every combination is replicated over ``seeds`` consecutive
    seeds.  The grid order is row-major over the axes (in keyword order)
    with the seed innermost, e.g.::

        sweep_specs(SyntheticSpec(n=200), seeds=2, D=(2, 5, 7))

    yields 6 specs: (D=2, s=0), (D=2, s=1), (D=5, s=0), ...
    """
    for name in axes:
        if not any(f.name == name for f in dataclasses.fields(base)):
            raise ValueError(f"unknown SyntheticSpec field {name!r}")
    out = []
    for combo in itertools.product(*axes.values()):
        overrides = dict(zip(axes.keys(), combo))
        for s in range(seeds):
            out.append(dataclasses.replace(base, seed=s, **overrides))
    return out


def synthetic_batch(specs) -> list[Problem]:
    """Instantiate a sweep grid — the input to ``core.evaluate_many`` /
    ``core.solve_lp_many`` (one batched LP solve for the whole grid)."""
    return [synthetic_instance(spec) for spec in specs]
