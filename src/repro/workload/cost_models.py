"""Node-type cost models (paper §VI-A, Eq. 8):

    cost(B) = sum_d c_d * cap(B, d)^e

* homogeneous linear: c_d = 1, e = 1.
* heterogeneous: random c_d in [0.3, 1.0], exponent e in {1/3 .. 3}.
* GCE-like: per-dimension coefficients shaped like Google Compute Engine
  on-demand pricing (vCPU-hour dominates, memory-GB secondary), e = 1 —
  the paper's Fig. 10 setting [32].
"""

from __future__ import annotations

import numpy as np

__all__ = ["homogeneous_cost", "heterogeneous_cost", "gce_like_cost"]


def homogeneous_cost(cap: np.ndarray) -> np.ndarray:
    return cap.sum(axis=1)


def heterogeneous_cost(
    cap: np.ndarray,
    e: float = 1.0,
    rng: np.random.Generator | None = None,
    coeff_range: tuple[float, float] = (0.3, 1.0),
) -> np.ndarray:
    # deterministic default: no rng -> a fixed-seed generator, so the
    # coefficients are reproducible and global NumPy state is untouched
    # (callers that want per-instance coefficients pass their own rng,
    # e.g. synthetic_instance threads its spec-seeded generator here)
    rng = rng if rng is not None else np.random.default_rng(0)
    c = rng.uniform(*coeff_range, size=cap.shape[1])
    return (c[None, :] * cap**e).sum(axis=1)


# GCE n2 on-demand-like ratios: ~$0.031/vCPU-h vs ~$0.0042/GB-h, rescaled so
# a "full" (cap = 1.0 normalized) node costs O(1) like the synthetic model.
_GCE_COEFF_2D = np.array([0.88, 0.12])


def gce_like_cost(cap: np.ndarray, e: float = 1.0) -> np.ndarray:
    if cap.shape[1] != 2:
        raise ValueError("gce_like_cost expects D=2 (cpu, memory)")
    return (_GCE_COEFF_2D[None, :] * cap**e).sum(axis=1) * 2.0
