"""GCT-2019-like trace (paper §VI-A).

The paper samples ~13K collection events and the 13 machine-types of
cluster "a" of the Google Cluster Trace 2019 via BigQuery: demands and
capacities are 2-dimensional (CPU, memory) and normalized, task demands are
small relative to node capacities, and task intervals come from creation /
end events with second timestamps.  Offline, we emulate that distribution
statistically (and provide a CSV loader for the real trace when present):

* 13 machine shapes drawn from the public GCT-2019 machine-config table
  (normalized CPU/memory pairs).
* ~13K tasks with log-normal durations (median minutes, heavy hour tail),
  diurnal arrival mix, and small log-normal demands with CPU<->memory
  correlation, matching the trace's "demands are fixed and small compared
  to node-capacities" regime.

``gct_like_instance(n, m, seed)`` reproduces the paper's sampling protocol:
draw n tasks and m node-types from the fixed processed pool per instance.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core import NodeTypes, Problem
from .cost_models import gce_like_cost, homogeneous_cost

__all__ = ["gct_pool", "gct_like_instance", "load_trace_csv"]

# Normalized (cpu, memory) machine shapes — the 13 distinct configs of
# GCT-2019 cell "a" (normalized to the largest machine), per the public
# machine_events table.
_MACHINE_SHAPES = np.array([
    [1.000, 1.000],
    [1.000, 0.500],
    [0.500, 0.500],
    [0.500, 0.250],
    [0.500, 0.750],
    [0.500, 0.125],
    [0.250, 0.250],
    [0.708, 0.250],
    [0.500, 0.375],
    [1.000, 0.250],
    [0.250, 0.125],
    [0.708, 0.500],
    [0.958, 0.500],
])

_POOL_TASKS = 13_000
_HORIZON_S = 86_400  # one day, second resolution (paper converts to seconds)


@functools.lru_cache(maxsize=1)
def gct_pool() -> dict:
    """The fixed processed pool: ~13K tasks + 13 node-types."""
    rng = np.random.default_rng(20190501)
    # Diurnal arrival mix: 70% uniform over the day, 30% in two peaks.
    n = _POOL_TASKS
    u = rng.random(n)
    start = np.where(
        u < 0.7,
        rng.uniform(0, _HORIZON_S, n),
        np.where(
            u < 0.85,
            rng.normal(10 * 3600, 1.5 * 3600, n),  # morning peak
            rng.normal(20 * 3600, 1.5 * 3600, n),  # evening peak
        ),
    )
    start = np.clip(start, 0, _HORIZON_S - 2).astype(np.int64)
    # Durations: log-normal (median ~90 min, heavy tail) plus a 20%
    # long-running cohort spanning 6-24h, as in the real trace where many
    # collections live for most of the day.
    dur = np.exp(rng.normal(np.log(5400), 1.3, n))
    long_mask = rng.random(n) < 0.20
    dur = np.where(long_mask, rng.uniform(6 * 3600, 24 * 3600, n), dur)
    dur = np.clip(dur, 10, 24 * 3600).astype(np.int64)
    end = np.minimum(start + dur, _HORIZON_S - 1)
    # Demands: the real trace's requests are *discrete* (fixed request
    # sizes; "task demands are fixed and small compared to node-capacities",
    # paper §VI-A): a small catalogue of CPU sizes with a heavy-small
    # distribution, and memory set by a discrete mem:cpu ratio concentrated
    # near the machine shapes (Borg requests are cpu-dominant).
    cpu_sizes = np.array([0.005, 0.01, 0.02, 0.04, 0.08, 0.16])
    cpu_probs = np.array([0.10, 0.20, 0.25, 0.20, 0.15, 0.10])
    mem_ratio = np.array([0.25, 0.5, 1.0, 2.0])
    ratio_probs = np.array([0.15, 0.40, 0.35, 0.10])
    cpu = rng.choice(cpu_sizes, size=n, p=cpu_probs)
    mem = np.clip(cpu * rng.choice(mem_ratio, size=n, p=ratio_probs),
                  1e-4, 0.5)
    dem = np.stack([cpu, mem], axis=1)
    return {
        "dem": dem,
        "start": start,
        "end": end,
        "cap": _MACHINE_SHAPES.copy(),
        "horizon": _HORIZON_S,
    }


def _node_types(cap: np.ndarray, cost_model: str, e: float = 1.0) -> NodeTypes:
    if cost_model == "homogeneous":
        cost = homogeneous_cost(cap)
    elif cost_model == "gce":
        cost = gce_like_cost(cap, e=e)
    else:
        raise ValueError(f"unknown cost model {cost_model!r}")
    return NodeTypes(cap=cap, cost=cost)


def gct_like_instance(
    n: int = 1000,
    m: int = 10,
    seed: int = 0,
    cost_model: str = "homogeneous",
    e: float = 1.0,
    rng: np.random.Generator | None = None,
) -> Problem:
    """Paper protocol: sample n tasks and m node-types from the pool.

    Sampling is bit-reproducible: all randomness flows from ONE
    explicit source — ``rng`` when given, else a fresh
    ``np.random.default_rng(seed)`` — so the same seed always yields
    the same instance (the scenario fan-out in ``repro.stochastic``
    and the trace generators in ``repro.serve.trace`` rely on this).
    Passing ``rng`` advances the caller's generator in place (draw
    several distinct instances from one stream); passing ``seed``
    never touches global NumPy state.
    """
    pool = gct_pool()
    if rng is None:
        rng = np.random.default_rng(seed)
    ti = rng.choice(len(pool["dem"]), size=min(n, len(pool["dem"])),
                    replace=False)
    mi = rng.choice(len(pool["cap"]), size=min(m, len(pool["cap"])),
                    replace=False)
    return Problem(
        dem=pool["dem"][ti],
        start=pool["start"][ti],
        end=pool["end"][ti],
        node_types=_node_types(pool["cap"][mi], cost_model, e),
        T=pool["horizon"],
    )


def load_trace_csv(
    path: str,
    cap: np.ndarray,
    cost_model: str = "homogeneous",
    e: float = 1.0,
) -> Problem:
    """Load a processed real trace: CSV rows ``start,end,cpu,mem`` in
    seconds/normalized units; entries with missing fields are purged
    (paper §VI-A)."""
    raw = np.genfromtxt(path, delimiter=",", skip_header=1)
    raw = raw[~np.isnan(raw).any(axis=1)]
    start = raw[:, 0].astype(np.int64)
    end = raw[:, 1].astype(np.int64)
    keep = end >= start
    raw, start, end = raw[keep], start[keep], end[keep]
    return Problem(
        dem=raw[:, 2:4],
        start=start - start.min(),
        end=end - start.min(),
        node_types=_node_types(np.asarray(cap, dtype=float), cost_model, e),
        T=int(end.max() - start.min() + 1),
    )
