"""LM-job -> TL-task adapter: the operational link between the framework
and the paper's planner.

A *job* is "run (arch x shape) during a time window" — e.g. "train
gemma2-9b nightly 00-06", "serve qwen2.5-3b 08-18".  Its resource demand
vector is **measured from the multi-pod dry-run artifacts** (per-device
argument+temp bytes x devices), converted into (chips, HBM GB, host GB).
Jobs wider than the largest slice SKU are split into per-pod tasks with
identical windows (a data-parallel pod is the unit of placement, matching
how multi-pod meshes are scheduled in practice).

Node-types are TPU slice SKUs; cost uses a committed-use-style sublinear
per-chip rate (bigger slices are cheaper per chip) — the heterogeneous
cost model of paper §VI-C with e < 1.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import math
import os

import numpy as np

from repro.core import NodeTypes, Problem, TaskConstraints

__all__ = ["TPU_SKUS", "Job", "DEFAULT_SCHEDULE", "jobs_from_dryrun",
           "fleet_problem", "BUILTIN_DEMANDS"]

HBM_PER_CHIP_GB = 16.0
HOST_PER_CHIP_GB = 32.0
CHIP_HOUR_USD = 1.2

# (name, chips) — host/HBM follow the chip count; cost is sublinear in
# size (committed-use volume discount, exponent e=0.92)
_SKU_CHIPS = [8, 16, 32, 64, 128, 256]


def _mk_skus() -> NodeTypes:
    cap = np.array([[c, c * HBM_PER_CHIP_GB, c * HOST_PER_CHIP_GB]
                    for c in _SKU_CHIPS], dtype=float)
    cost = np.array([CHIP_HOUR_USD * (c ** 0.92) for c in _SKU_CHIPS])
    names = tuple(f"v5e-{c}" for c in _SKU_CHIPS)
    return NodeTypes(cap=cap, cost=cost, names=names)


TPU_SKUS = _mk_skus()


@dataclasses.dataclass(frozen=True)
class Job:
    """One scheduled workload; optional hard constraints ride along.

    ``deadline_h`` is an inclusive finish hour (train jobs that must
    complete before the business day); ``exclusive`` reserves whole
    slices (isolation-sensitive serving); ``affinity``/``anti_affinity``
    are named groups (co-locate a tower of services / spread replicas);
    ``max_width``/``serial_frac`` allow widening a deadlined job per the
    Amdahl law.  Defaults are all vacuous, keeping ``DEFAULT_SCHEDULE``
    problems byte-stable.
    """

    name: str
    arch: str
    shape: str
    start_h: int
    end_h: int          # inclusive hour slot
    deadline_h: int | None = None
    exclusive: bool = False
    affinity: str | None = None
    anti_affinity: str | None = None
    max_width: int = 1
    serial_frac: float = 1.0


# a plausible production day: nightly training, business-hours serving,
# evening batch inference, always-on light service
DEFAULT_SCHEDULE = (
    Job("nightly-train-gemma2", "gemma2-9b", "train_4k", 0, 5),
    Job("nightly-train-olmoe", "olmoe-1b-7b", "train_4k", 0, 5),
    Job("nightly-train-rwkv", "rwkv6-7b", "train_4k", 1, 6),
    Job("day-serve-qwen", "qwen2.5-3b", "decode_32k", 8, 17),
    Job("day-serve-gemma3", "gemma3-1b", "decode_32k", 8, 17),
    Job("day-serve-vl", "qwen2-vl-2b", "decode_32k", 9, 18),
    Job("eve-batch-whisper", "whisper-small", "prefill_32k", 18, 22),
    Job("eve-batch-granite", "granite-34b", "prefill_32k", 18, 23),
    Job("allday-recgemma", "recurrentgemma-9b", "long_500k", 0, 23),
    Job("peak-kimi-serve", "kimi-k2-1t-a32b", "decode_32k", 10, 15),
)

# fallback per-(arch, shape) total memory footprints (GB across the whole
# job) if dry-run artifacts are absent — same order of magnitude as the
# measured ones
BUILTIN_DEMANDS = {
    ("gemma2-9b", "train_4k"): 1600.0,
    ("olmoe-1b-7b", "train_4k"): 1100.0,
    ("rwkv6-7b", "train_4k"): 1200.0,
    ("qwen2.5-3b", "decode_32k"): 700.0,
    ("gemma3-1b", "decode_32k"): 300.0,
    ("qwen2-vl-2b", "decode_32k"): 500.0,
    ("whisper-small", "prefill_32k"): 150.0,
    ("granite-34b", "prefill_32k"): 900.0,
    ("recurrentgemma-9b", "long_500k"): 250.0,
    ("kimi-k2-1t-a32b", "decode_32k"): 4000.0,
}


def _dryrun_bytes(dryrun_dir: str) -> dict:
    """(arch, shape) -> total program bytes, from 16x16 artifacts."""
    out = {}
    for path in glob.glob(os.path.join(dryrun_dir, "*__16x16.json")):
        with open(path) as f:
            rec = json.load(f)
        per_dev = (rec.get("argument_size_in_bytes", 0)
                   + rec.get("temp_size_in_bytes", 0)
                   + rec.get("output_size_in_bytes", 0))
        out[(rec["arch"], rec["shape"])] = per_dev * rec["devices"]
    return out


def jobs_from_dryrun(schedule=DEFAULT_SCHEDULE,
                     dryrun_dir: str = "results/dryrun",
                     util: float = 0.85):
    """Expand jobs into TL tasks: demands (chips, HBM GB, host GB)."""
    measured = _dryrun_bytes(dryrun_dir)
    max_chips = max(_SKU_CHIPS)
    tasks = []
    for job in schedule:
        key = (job.arch, job.shape)
        if key in measured:
            total_gb = measured[key] / 1e9
            src = "dryrun"
        else:
            total_gb = BUILTIN_DEMANDS.get(key, 500.0)
            src = "builtin"
        chips = max(1, math.ceil(total_gb / (HBM_PER_CHIP_GB * util)))
        n_shards = max(1, math.ceil(chips / max_chips))
        per_shard = math.ceil(chips / n_shards)
        for s in range(n_shards):
            tasks.append({
                "name": f"{job.name}/{s}" if n_shards > 1 else job.name,
                "dem": np.array([
                    per_shard,
                    per_shard * HBM_PER_CHIP_GB * 0.95,
                    per_shard * HOST_PER_CHIP_GB * 0.5,
                ]),
                "start": job.start_h,
                "end": job.end_h,
                "source": src,
                # shards inherit the job's constraints verbatim (a job's
                # pods share its deadline, isolation, and groups)
                "deadline": job.deadline_h,
                "exclusive": job.exclusive,
                "affinity": job.affinity,
                "anti_affinity": job.anti_affinity,
                "max_width": job.max_width,
                "serial_frac": job.serial_frac,
            })
    return tasks


def _constraints_from_tasks(tasks) -> TaskConstraints | None:
    """``TaskConstraints`` for expanded task dicts, or None when every
    job carried only the vacuous defaults."""
    if all(t.get("deadline") is None and not t.get("exclusive")
           and t.get("affinity") is None and t.get("anti_affinity") is None
           and t.get("max_width", 1) == 1 for t in tasks):
        return None
    deadlines = {i: t["deadline"] for i, t in enumerate(tasks)
                 if t.get("deadline") is not None}
    affinity: dict[str, list[int]] = {}
    anti: dict[str, list[int]] = {}
    for i, t in enumerate(tasks):
        if t.get("affinity") is not None:
            affinity.setdefault(t["affinity"], []).append(i)
        if t.get("anti_affinity") is not None:
            anti.setdefault(t["anti_affinity"], []).append(i)
    widths = {i: (t["max_width"], t.get("serial_frac", 1.0))
              for i, t in enumerate(tasks) if t.get("max_width", 1) > 1}
    return TaskConstraints.from_groups(
        len(tasks), deadlines=deadlines, affinity=affinity,
        anti_affinity=anti,
        exclusive=[i for i, t in enumerate(tasks) if t.get("exclusive")],
        widths=widths)


def fleet_problem(schedule=DEFAULT_SCHEDULE,
                  dryrun_dir: str = "results/dryrun") -> tuple[Problem, list]:
    tasks = jobs_from_dryrun(schedule, dryrun_dir)
    dem = np.stack([t["dem"] for t in tasks])
    start = np.array([t["start"] for t in tasks])
    end = np.array([t["end"] for t in tasks])
    problem = Problem(dem=dem, start=start, end=end, node_types=TPU_SKUS,
                      T=24, constraints=_constraints_from_tasks(tasks))
    return problem, tasks
