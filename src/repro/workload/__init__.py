"""Workload substrate: trace generators, cost models, LM-job adapters."""

from .cost_models import homogeneous_cost, heterogeneous_cost, gce_like_cost
from .synthetic import (
    synthetic_instance,
    synthetic_batch,
    sweep_specs,
    SyntheticSpec,
)
from .gct import gct_like_instance, load_trace_csv
from .jobs import (
    DEFAULT_SCHEDULE,
    Job,
    TPU_SKUS,
    fleet_problem,
    jobs_from_dryrun,
)

__all__ = [
    "homogeneous_cost", "heterogeneous_cost", "gce_like_cost",
    "synthetic_instance", "synthetic_batch", "sweep_specs", "SyntheticSpec",
    "gct_like_instance", "load_trace_csv",
    "DEFAULT_SCHEDULE", "Job", "TPU_SKUS", "fleet_problem",
    "jobs_from_dryrun",
]
