"""GQA attention: streaming (flash-style) train/prefill path and KV-cache
decode path, with sliding-window and soft-cap support.

TPU adaptation: instead of materializing (S, S) score matrices, the
train/prefill path streams KV in chunks under ``lax.scan`` with an online
softmax (running max / normalizer), and maps over query chunks — the
standard flash decomposition expressed in pure JAX so XLA fuses it; memory
is O(S * chunk) instead of O(S^2).  Block-level causal/window masks are
generated from indices, never stored.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import softcap

__all__ = [
    "streaming_attention",
    "decode_attention",
    "init_cache_positions",
]

NEG_INF = -2.0e38


def _block_mask(q_pos, k_pos, window: int, causal: bool):
    """(Q, K) boolean mask from absolute positions; window < 0 = full."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return ok


def streaming_attention(
    q, k, v, *,
    window: int = -1,
    causal: bool = True,
    attn_softcap: float | None = None,
    q_offset: int = 0,
    kv_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H = KV * G.
    Returns (B, Sq, H, hd).  Positions are offsets + arange (contiguous).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    # pad to chunk multiples
    nq = -(-Sq // qc)
    nk = -(-Skv // kc)
    q_pad = nq * qc - Sq
    k_pad = nk * kc - Skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))

    qr = q.reshape(B, nq, qc, KV, G, hd)
    kr = k.reshape(B, nk, kc, KV, hd)
    vr = v.reshape(B, nk, kc, KV, hd)

    q_positions = q_offset + jnp.arange(nq * qc, dtype=jnp.int32)
    k_positions = kv_offset + jnp.arange(nk * kc, dtype=jnp.int32)
    k_valid = jnp.arange(nk * kc) < Skv  # mask KV padding

    def q_block(args):
        qb, qpos = args  # (B, qc, KV, G, hd), (qc,)

        def kv_step(carry, inp):
            acc, m, l = carry
            kb, vb, kpos, kval = inp  # (B, kc, KV, hd), ..., (kc,), (kc,)
            s = jnp.einsum("bqkgd,bckd->bqgkc", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, attn_softcap)
            mask = _block_mask(qpos, kpos, window, causal) & kval[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqgkc,bckd->bqkgd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None].transpose(0, 1, 3, 2, 4) + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, qc, KV, G, hd), jnp.float32)
        m0 = jnp.full((B, qc, G, KV), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, G, KV), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             k_positions.reshape(nk, kc), k_valid.reshape(nk, kc)),
        )
        norm = jnp.maximum(l, 1e-37)[..., None].transpose(0, 1, 3, 2, 4)
        return (acc / norm).astype(q.dtype)

    out = jax.lax.map(
        q_block,
        (qr.transpose(1, 0, 2, 3, 4, 5), q_positions.reshape(nq, qc)),
    )  # (nq, B, qc, KV, G, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, H, hd)
    return out[:, :Sq]


def init_cache_positions(cache_len: int) -> jax.Array:
    """Per-slot absolute positions; -1 marks an empty slot."""
    return jnp.full((cache_len,), -1, jnp.int32)


def decode_attention(
    q, k_cache, v_cache, slot_pos, pos, *,
    window: int = -1,
    attn_softcap: float | None = None,
):
    """One-token attention against a (ring-buffer) KV cache.

    q: (B, H, hd); k_cache, v_cache: (B, CL, KV, hd);
    slot_pos: (CL,) absolute position stored in each slot (-1 = empty);
    pos: scalar int32 — the current token's position (already written).
    """
    B, H, hd = q.shape
    _, CL, KV, _ = k_cache.shape
    G = H // KV
    scale = hd ** -0.5
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bckd->bgkc", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, attn_softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        valid &= pos - slot_pos < window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgkc,bckd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)
