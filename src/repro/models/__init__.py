"""LM model substrate: configs, blocks, assembly."""

from .config import GLOBAL_WINDOW, ModelConfig, Segment, SubBlock, \
    build_segments
from .model import (
    decode_step,
    forward_train,
    init_decode_state,
    init_params,
    loss_fn,
    prefill,
    sub_cache_len,
)

__all__ = [
    "GLOBAL_WINDOW", "ModelConfig", "Segment", "SubBlock", "build_segments",
    "decode_step", "forward_train", "init_decode_state", "init_params",
    "loss_fn", "prefill", "sub_cache_len",
]
