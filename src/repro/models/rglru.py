"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with input/recurrence gates is a *linear* recurrence in h, so the
train/prefill path runs in log-depth via ``lax.associative_scan`` — the
TPU-native formulation (the paper's GPU implementation uses a fused linear
scan kernel; associative_scan is the XLA equivalent).  Decode keeps O(1)
state per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

from .layers import init_dense

__all__ = ["init_rglru", "rglru_scan", "rglru_step", "temporal_conv",
           "conv_step"]

_C = 8.0  # RG-LRU soft clamp constant from the paper


def init_rglru(key, d_model: int, width: int, conv_width: int, dtype):
    ks = jax.random.split(key, 7)
    return {
        # gated branch input projections
        "w_x": init_dense(ks[0], (d_model, width), dtype),
        "w_gate": init_dense(ks[1], (d_model, width), dtype),
        "conv_w": init_dense(ks[2], (conv_width, width), dtype),
        # RG-LRU gates
        "w_input_gate": init_dense(ks[3], (width, width), dtype),
        "w_rec_gate": init_dense(ks[4], (width, width), dtype),
        # Lambda param: a = sigmoid(lam)^(c * r_t); init near 0.9..0.999
        "lam": jnp.linspace(2.0, 6.0, width).astype(jnp.float32),
        "w_out": init_dense(ks[5], (width, d_model), dtype),
    }


def temporal_conv(x, conv_w):
    """Depthwise causal conv along time: x (B, S, W), conv_w (K, W)."""
    K = conv_w.shape[0]
    pads = [x]
    for k in range(1, K):
        pads.append(jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]])
    stack = jnp.stack(pads, axis=0)  # (K, B, S, W) — k steps back
    return jnp.einsum("kbsw,kw->bsw", stack, conv_w.astype(x.dtype))


def conv_step(x_t, conv_state, conv_w):
    """Decode: x_t (B, W); conv_state (B, K-1, W) holds previous inputs."""
    K = conv_w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,W)
    out = jnp.einsum("bkw,kw->bw", full[:, ::-1], conv_w.astype(x_t.dtype))
    return out, full[:, 1:]


def _gates(x, params):
    """RG-LRU gate computation (fp32): returns (a, gated_input)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_input_gate"].astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["lam"])  # log a_t <= 0
    a = jnp.exp(log_a)
    gated_x = xf * i
    # sqrt(1 - a^2) input normalizer
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * gated_x


def rglru_scan(x, params):
    """Full-sequence RG-LRU: x (B, S, W) -> (out (B, S, W), h_final fp32).
    h_0 = 0; log-depth via associative_scan."""
    x = constrain(x, "batch", None, "model")
    a, b = _gates(x, params)  # both (B, S, W) fp32

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(x_t, h_prev, params):
    """Decode: x_t (B, W), h_prev (B, W) fp32 -> (out, h_new)."""
    a, b = _gates(x_t, params)
    h = a * h_prev + b
    return h.astype(x_t.dtype), h
