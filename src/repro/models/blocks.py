"""Transformer-family blocks: init + train-mode + decode-mode application.

Every block is a pure function over a *single layer's* params; the model
assembly (model.py) stacks layers and drives these under ``lax.scan``.

Block layout conventions (pre-norm residual throughout):
  attn   : x += Attn(norm(x));  x += MLP_or_MoE(norm(x))
  xattn  : x += SelfAttn(norm(x)); x += CrossAttn(norm(x)); x += MLP(norm(x))
  rglru  : x += RGLRU_mixer(norm(x)); x += MLP(norm(x))
  rwkv   : x += TimeMix(norm(x));  x += ChannelMix(norm(x))
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv as rwkv_mod
from .config import ModelConfig
from .layers import gated_mlp, init_dense, init_norm, mrope, rms_norm, rope

__all__ = ["init_block", "block_train", "block_decode", "init_block_cache"]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_attn_params(key, cfg: ModelConfig, dtype, cross: bool = False):
    """3-D attention weights (d, heads, head_dim): head/head_dim axes stay
    explicit so the partitioner can shard whichever divides the mesh."""
    ks = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    p = {
        "wq": init_dense(ks[0], (d, H * hd), dtype).reshape(d, H, hd),
        "wk": init_dense(ks[1], (d, KV * hd), dtype).reshape(d, KV, hd),
        "wv": init_dense(ks[2], (d, KV * hd), dtype).reshape(d, KV, hd),
        "wo": init_dense(ks[3], (H * hd, d), dtype).reshape(H, hd, d),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def _init_mlp(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    p = {
        "w_up": init_dense(ks[1], (d, ff), dtype),
        "w_down": init_dense(ks[2], (ff, d), dtype, scale=ff ** -0.5),
    }
    if cfg.gated_mlp:
        p["w_gate"] = init_dense(ks[0], (d, ff), dtype)
    return p


def init_block(key, cfg: ModelConfig, kind: str, moe: bool, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"ln1": init_norm((d,), dtype), "ln2": init_norm((d,), dtype)}
    if kind in ("attn", "xattn"):
        p["attn"] = _init_attn_params(ks[0], cfg, dtype)
        if kind == "xattn":
            p["xattn"] = _init_attn_params(ks[1], cfg, dtype, cross=True)
            p["ln_x"] = init_norm((d,), dtype)
        if moe:
            p["moe"] = moe_mod.init_moe(
                ks[2], d, cfg.moe_d_ff, cfg.num_experts, dtype)
        else:
            p["mlp"] = _init_mlp(ks[2], cfg, dtype)
    elif kind == "rglru":
        p["rec"] = rglru_mod.init_rglru(
            ks[0], d, cfg.rnn_width or d, cfg.conv_width, dtype)
        p["mlp"] = _init_mlp(ks[2], cfg, dtype)
    elif kind == "rwkv":
        p["tm"] = rwkv_mod.init_rwkv_timemix(ks[0], d, cfg.rwkv_head_dim,
                                             dtype)
        p["cm"] = rwkv_mod.init_rwkv_channelmix(ks[1], d, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p


# --------------------------------------------------------------------------
# train / prefill
# --------------------------------------------------------------------------

def _attention_tr(x, p, cfg: ModelConfig, window, theta, positions,
                  causal=True, mrope_positions=None):
    B, S, d = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    kv_cache = (k, v)
    # GQA -> MHA for the attention compute: repeating KV heads to the full
    # head count keeps one uniform head axis sharded over 'model'
    # end-to-end, eliminating GSPMD's involuntary reshard of the (KV, G)
    # grouped reshape (see EXPERIMENTS.md §Perf iteration 1).
    G = cfg.num_heads // cfg.num_kv_heads
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    out = attn_mod.streaming_attention(
        q, k, v, window=window, causal=causal,
        attn_softcap=cfg.attn_softcap,
    )
    out = constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), kv_cache


def _cross_attention_tr(x, p, cfg: ModelConfig, enc_out):
    """Cross-attention against the encoder output (B, Se, d); K/V are
    computed with this layer's projections."""
    B, S, d = x.shape
    k_enc = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v_enc = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    G = cfg.num_heads // cfg.num_kv_heads
    if G > 1:
        k_enc = jnp.repeat(k_enc, G, axis=2)
        v_enc = jnp.repeat(v_enc, G, axis=2)
    q = constrain(q, "batch", None, "heads", None)
    k_enc = constrain(k_enc, "batch", None, "heads", None)
    v_enc = constrain(v_enc, "batch", None, "heads", None)
    out = attn_mod.streaming_attention(
        q, k_enc, v_enc, window=-1, causal=False,
        attn_softcap=cfg.attn_softcap,
    )
    out = constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def block_train(x, p, cfg: ModelConfig, kind: str, moe: bool, *,
                window=-1, theta=10_000.0, positions=None,
                causal=True, enc_out=None, mrope_positions=None):
    """One layer, full-sequence.

    Returns (x, aux_loss, state) where state is the layer's end-of-sequence
    decode state: (k, v) full-sequence tensors for attention kinds, the
    recurrent state dict for rglru/rwkv.
    """
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "xattn"):
        h, kv = _attention_tr(rms_norm(x, p["ln1"]), p["attn"], cfg,
                              window, theta, positions, causal,
                              mrope_positions)
        x = x + h
        if kind == "xattn":
            x = x + _cross_attention_tr(rms_norm(x, p["ln_x"]), p["xattn"],
                                        cfg, enc_out)
        h_in = rms_norm(x, p["ln2"])
        if moe:
            h, aux = moe_mod.moe_mlp(
                h_in, p["moe"], top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.capacity_factor)
        else:
            h = gated_mlp(h_in, p["mlp"])
        return x + h, aux, kv
    if kind == "rglru":
        rec = p["rec"]
        K = cfg.conv_width
        xin = rms_norm(x, p["ln1"])
        gate = jax.nn.gelu(xin @ rec["w_gate"])
        u_raw = xin @ rec["w_x"]
        u = rglru_mod.temporal_conv(u_raw, rec["conv_w"])
        u, h_fin = rglru_mod.rglru_scan(u, rec)
        x = x + (gate * u) @ rec["w_out"]
        x = x + gated_mlp(rms_norm(x, p["ln2"]), p["mlp"])
        conv_tail = jnp.pad(u_raw, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):]
        return x, aux, {"h": h_fin, "conv": conv_tail}
    if kind == "rwkv":
        B, S, d = x.shape
        h, S_fin, x_tm = rwkv_mod.timemix_scan(
            rms_norm(x, p["ln1"]), jnp.zeros((B, d), x.dtype), p["tm"],
            cfg.rwkv_head_dim)
        x = x + h
        h, x_cm = rwkv_mod.channelmix(rms_norm(x, p["ln2"]),
                                      jnp.zeros((B, d), x.dtype), p["cm"])
        return x + h, aux, {"S": S_fin, "x_tm": x_tm, "x_cm": x_cm}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# decode (single token, stateful)
# --------------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, kind: str, batch: int,
                     cache_len: int, dtype):
    """Per-layer decode state (unstacked; model.py stacks across layers)."""
    hd, kv = cfg.head_dim, cfg.num_kv_heads
    if kind in ("attn", "xattn"):
        return {
            "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
            "slot_pos": attn_mod.init_cache_positions(cache_len),
        }
    if kind == "rglru":
        w = cfg.rnn_width or cfg.d_model
        return {
            "h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        }
    if kind == "rwkv":
        d = cfg.d_model
        H = d // cfg.rwkv_head_dim
        return {
            "S": jnp.zeros((batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                           jnp.float32),
            "x_tm": jnp.zeros((batch, d), dtype),
            "x_cm": jnp.zeros((batch, d), dtype),
        }
    raise ValueError(kind)


def block_decode(x, cache, p, cfg: ModelConfig, kind: str, moe: bool, *,
                 pos, window=-1, theta=10_000.0, enc_kv=None):
    """One layer, one token.  x: (B, d).  Returns (x, new_cache)."""
    hd = cfg.head_dim
    B, d = x.shape
    if kind in ("attn", "xattn"):
        xin = rms_norm(x, p["ln1"])
        q = jnp.einsum("bd,dhk->bhk", xin, p["attn"]["wq"])
        k = jnp.einsum("bd,dhk->bhk", xin, p["attn"]["wk"])
        v = jnp.einsum("bd,dhk->bhk", xin, p["attn"]["wv"])
        if cfg.qkv_bias:
            q, k, v = (q + p["attn"]["bq"], k + p["attn"]["bk"],
                       v + p["attn"]["bv"])
        posv = jnp.full((B, 1), pos, jnp.int32)
        q = rope(q[:, None], posv, theta)[:, 0]
        k = rope(k[:, None], posv, theta)[:, 0]
        CL = cache["k"].shape[1]
        slot = jnp.mod(pos, CL)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k[:, None].astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v[:, None].astype(cache["v"].dtype), slot, axis=1)
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
        out = attn_mod.decode_attention(
            q, k_cache, v_cache, slot_pos, pos, window=window,
            attn_softcap=cfg.attn_softcap)
        x = x + jnp.einsum("bhk,hkd->bd", out, p["attn"]["wo"])
        new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
        if kind == "xattn":
            xin = rms_norm(x, p["ln_x"])
            qx = jnp.einsum("bd,dhk->bhk", xin, p["xattn"]["wq"])[:, None]
            k_enc, v_enc = enc_kv
            out = attn_mod.streaming_attention(
                qx, k_enc, v_enc, window=-1, causal=False,
                attn_softcap=cfg.attn_softcap)
            x = x + jnp.einsum("bhk,hkd->bd", out[:, 0], p["xattn"]["wo"])
        h_in = rms_norm(x, p["ln2"])
        if moe:
            h, _aux = moe_mod.moe_mlp(
                h_in, p["moe"], top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.capacity_factor)
        else:
            h = gated_mlp(h_in, p["mlp"])
        return x + h, new_cache
    if kind == "rglru":
        rec = p["rec"]
        xin = rms_norm(x, p["ln1"])
        gate = jax.nn.gelu(xin @ rec["w_gate"])
        u = xin @ rec["w_x"]
        u, conv_state = rglru_mod.conv_step(u, cache["conv"], rec["conv_w"])
        u, h_state = rglru_mod.rglru_step(u, cache["h"], rec)
        x = x + (gate * u) @ rec["w_out"]
        x = x + gated_mlp(rms_norm(x, p["ln2"]), p["mlp"])
        return x, {"h": h_state, "conv": conv_state}
    if kind == "rwkv":
        h, (S_new, x_tm) = rwkv_mod.timemix_step(
            rms_norm(x, p["ln1"]), (cache["S"], cache["x_tm"]), p["tm"],
            cfg.rwkv_head_dim)
        x = x + h
        h, x_cm = rwkv_mod.channelmix_step(rms_norm(x, p["ln2"]),
                                           cache["x_cm"], p["cm"])
        return x + h, {"S": S_new, "x_tm": x_tm, "x_cm": x_cm}
    raise ValueError(kind)
