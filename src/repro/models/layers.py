"""Shared layer primitives: RMSNorm, RoPE / M-RoPE, gated MLP, softcap."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "rope", "mrope", "gated_mlp", "softcap", "init_dense",
    "init_norm", "dense",
]


def softcap(x, cap):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm in fp32, cast back to input dtype (gemma convention:
    weight is a residual offset from 1)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def _rope_angles(positions, dim: int, theta: float):
    """(..., dim/2) angles for the given positions."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    return positions[..., None].astype(jnp.float32) * freqs  # (..., dim/2)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary position embedding.

    x: (..., seq, heads, head_dim); positions: (..., seq) int32.
    """
    half = x.shape[-1] // 2
    ang = _rope_angles(positions, x.shape[-1], theta)  # (..., seq, half)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x, positions_thw, sections, theta: float = 10_000.0):
    """Multimodal RoPE (Qwen2-VL): the head_dim/2 frequency slots are split
    into (t, h, w) sections, each rotated by its own position stream.

    x: (batch, seq, heads, head_dim); positions_thw: (3, batch, seq).
    sections: per-axis *pair* counts summing to head_dim // 2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, 2 * half, 2, dtype=jnp.float32) / (2 * half))
    # build per-slot positions by section
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        pos = positions_thw[i].astype(jnp.float32)  # (batch, seq)
        parts.append(pos[..., None] * freqs[off: off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)  # (batch, seq, half)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_dense(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def init_norm(shape, dtype):
    return jnp.zeros(shape, dtype)  # residual-from-1 convention


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def gated_mlp(x, params):
    """SwiGLU MLP (gelu(x W_gate) * x W_up) W_down, or plain GELU MLP
    gelu(x W_up) W_down when no gate matrix is present."""
    up = x @ params["w_up"]
    if "w_gate" in params:
        return (jax.nn.gelu(x @ params["w_gate"]) * up) @ params["w_down"]
    return jax.nn.gelu(up) @ params["w_down"]
