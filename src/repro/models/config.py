"""Model configuration for the assigned architectures.

A model is a sequence of *segments*.  Each segment repeats a fixed **unit**
of sub-blocks (e.g. gemma2's ``[local, global]``, recurrentgemma's
``[rec, rec, attn]``) under one ``lax.scan``: per-sub-block params are
stacked along a leading ``repeats`` axis, so stacked params *and* decode
caches stay rectangular even when layer kinds alternate.  Layers left over
after whole units form a trailing repeats=1 segment.

Block kinds:
  'attn'   — GQA attention (+ dense MLP or MoE), global or sliding-window
  'rglru'  — RecurrentGemma RG-LRU recurrent block (+ dense MLP)
  'rwkv'   — RWKV-6 time-mix + channel-mix block
  'xattn'  — decoder block with self-attn + cross-attn (enc-dec models)
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "SubBlock", "Segment", "build_segments"]

GLOBAL_WINDOW = -1  # sentinel: full-context attention


@dataclasses.dataclass(frozen=True)
class SubBlock:
    kind: str                 # 'attn' | 'rglru' | 'rwkv' | 'xattn'
    window: int               # GLOBAL_WINDOW = full-context
    theta: float              # rope theta
    moe: bool = False         # MoE MLP instead of dense


@dataclasses.dataclass(frozen=True)
class Segment:
    unit: tuple[SubBlock, ...]
    repeats: int

    @property
    def layers(self) -> int:
        return len(self.unit) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    # per-layer structure: sequence of (kind, window, theta, moe)
    pattern: tuple[tuple, ...] = ()
    # attention details
    qkv_bias: bool = False
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0          # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    # encoder-decoder (audio): encoder is bidirectional full attention
    encoder_layers: int = 0
    encoder_seq: int = 1500    # precomputed frame embeddings (stub frontend)
    # vlm
    mrope_sections: tuple[int, int, int] | None = None
    vision_seq: int = 0        # precomputed patch embeddings (stub frontend)
    # ssm / hybrid
    rnn_width: int = 0         # RG-LRU state width (0 -> d_model)
    conv_width: int = 4
    rwkv_head_dim: int = 64
    # MLP style: SwiGLU (3 matrices) vs plain GELU (2 matrices)
    gated_mlp: bool = True
    # numerics
    dtype: str = "bfloat16"
    # sub-quadratic? (drives long_500k eligibility)
    subquadratic: bool = False
    # layers per lax.scan unit (the repeating pattern period)
    scan_unit: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if not self.pattern:
            object.__setattr__(
                self,
                "pattern",
                tuple(("attn", GLOBAL_WINDOW, self.rope_theta,
                       self.num_experts > 0)
                      for _ in range(self.num_layers)),
            )
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def kv_groups(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        total = self.vocab_size * d  # embed (tied lm head)
        for kind, _w, _t, moe in self.pattern:
            if kind in ("attn", "xattn"):
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
                if kind == "xattn":
                    attn *= 2
                nmat = 3 if self.gated_mlp else 2
                if moe:
                    mlp = self.num_experts * nmat * d * self.moe_d_ff \
                        + d * self.num_experts
                else:
                    mlp = nmat * d * ff
                total += attn + mlp + 2 * d
            elif kind == "rglru":
                w = self.rnn_width or d
                total += 2 * d * w + w * d + 3 * w + self.conv_width * w \
                    + 3 * d * ff + 2 * d
            elif kind == "rwkv":
                total += 6 * d * d + 2 * d * ff + 2 * d
        # encoder stack
        enc_attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * hd * d + 3 * d * ff + 2 * d
        total += self.encoder_layers * enc_attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        nmat = 3 if self.gated_mlp else 2
        total = self.param_count()
        moe_layers = sum(1 for k, _w, _t, moe in self.pattern if moe)
        full = self.num_experts * nmat * d * self.moe_d_ff
        act = self.num_experts_per_tok * nmat * d * self.moe_d_ff
        return total - moe_layers * (full - act)


def build_segments(cfg: ModelConfig) -> tuple[Segment, ...]:
    """Group the per-layer pattern into repeated-unit scan segments.

    The pattern is split into ``scan_unit``-sized units; every full unit
    must be identical (asserted) and becomes one scanned segment; leftover
    layers form a trailing repeats=1 segment.
    """
    entries = tuple(SubBlock(kind=k, window=w, theta=t, moe=m)
                    for (k, w, t, m) in cfg.pattern)
    k = max(cfg.scan_unit, 1)
    full = len(entries) // k
    segs: list[Segment] = []
    if full:
        unit = entries[:k]
        for i in range(full):
            got = entries[i * k: (i + 1) * k]
            assert got == unit, (
                f"pattern not periodic with scan_unit={k} at unit {i}: "
                f"{got} != {unit}"
            )
        segs.append(Segment(unit=unit, repeats=full))
    rem = entries[full * k:]
    if rem:
        segs.append(Segment(unit=rem, repeats=1))
    return tuple(segs)
