"""Mixture-of-Experts MLP with top-k routing and capacity-based,
sort-order dispatch (dropless up to the capacity factor).

TPU adaptation: instead of a dense (tokens, E, C) one-hot dispatch einsum
(O(tokens*E*C) memory), tokens are *sorted by expert id* and scattered into
a rectangular (E, C, d) buffer; expert matmuls are a single batched einsum
over that buffer and results scatter back weighted by router probabilities.
Under GSPMD the buffer's expert axis is sharded over the 'model' mesh axis
(expert parallelism) — the scatter lowers to the dispatch all-to-all.
Tokens beyond an expert's capacity are dropped (standard capacity-factor
semantics); combine weights renormalize over the surviving experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

__all__ = ["moe_mlp", "init_moe", "router_capacity"]


def router_capacity(num_tokens: int, num_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    cap = int(num_tokens * top_k / num_experts * capacity_factor)
    return max(cap, 4)


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype):
    from .layers import init_dense

    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d_model, num_experts), jnp.float32),
        "w_gate": init_dense(ks[1], (num_experts, d_model, d_ff), dtype),
        "w_up": init_dense(ks[2], (num_experts, d_model, d_ff), dtype),
        "w_down": init_dense(ks[3], (num_experts, d_ff, d_model), dtype,
                             scale=d_ff ** -0.5),
    }


def _dispatch_group(xt, probs, top_k: int, C: int, dtype):
    """Sort-order dispatch for ONE token group (vmapped over groups).

    xt: (N, d) tokens; probs: (N, E) router probabilities.
    Returns (buf (E, C, d), slot (N*k,), keep, order, flat stuff) needed
    for the combine.
    """
    N, d = xt.shape
    E = probs.shape[1]
    top_p, top_e = jax.lax.top_k(probs, top_k)            # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)                            # (N*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), top_k)
    order = jnp.argsort(flat_e, stable=True)              # group by expert
    e_sorted = flat_e[order]
    start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank = jnp.arange(N * top_k) - start[e_sorted]
    keep = rank < C
    slot = jnp.where(keep, e_sorted * C + rank, E * C)    # overflow -> dump
    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(xt[flat_tok[order]], mode="drop")
    return buf[: E * C].reshape(E, C, d), (slot, keep, order, flat_tok,
                                           flat_p, flat_e)


def _combine_group(out_flat, meta, N: int, d: int, dtype):
    slot, keep, order, flat_tok, flat_p, _flat_e = meta
    EC = out_flat.shape[0]
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.clip(slot, 0, EC - 1)], 0.0)
    weighted = gathered * flat_p[order][:, None].astype(dtype)
    return jnp.zeros((N, d), dtype).at[flat_tok[order]].add(weighted)


def moe_mlp(x, params, *, top_k: int, capacity_factor: float = 1.25):
    """x: (..., d) -> (..., d).

    Routing is *per group* (a group = one leading-axis row, i.e. one batch
    element): the argsort/dispatch bookkeeping is then local to the data
    shard — no cross-device sort — and only the (G, E, C, d) expert buffer
    crosses the mesh (the EP all-to-all), with its expert axis sharded over
    'model' and group axis over 'batch'.  (§Perf iteration 2: the flat
    global-sort dispatch forced GSPMD into replicated sorts.)

    Returns (out, aux) where aux is the load-balancing loss (Switch-style).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    if x.ndim >= 3:
        G = orig_shape[0]
        xg = x.reshape(G, -1, d)
    else:
        G = 1
        xg = x.reshape(1, -1, d)
    N = xg.shape[1]
    E = params["router"].shape[1]
    C = router_capacity(N, E, top_k, capacity_factor)

    logits = (xg.astype(jnp.float32) @ params["router"])  # (G, N, E)
    probs = jax.nn.softmax(logits, axis=-1)

    buf, meta = jax.vmap(
        lambda xt, pr: _dispatch_group(xt, pr, top_k, C, x.dtype)
    )(xg, probs)                                          # buf (G, E, C, d)
    buf = constrain(buf, "batch", "model", None, None)

    # ---- expert computation (batched einsum over experts) ---------------
    gate = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    out_buf = jnp.einsum("gecf,efd->gecd", gate * up, params["w_down"])
    out_buf = constrain(out_buf, "batch", "model", None, None)

    out = jax.vmap(
        lambda ob, m: _combine_group(ob.reshape(E * C, d), m, N, d, x.dtype)
    )(out_buf, meta)

    # Switch-style load-balance aux loss (over all groups)
    me = probs.reshape(-1, E).mean(axis=0)
    ce = jnp.zeros(E, jnp.float32).at[meta[5].reshape(-1)].add(1.0) \
        / (G * N * top_k)
    aux = E * jnp.sum(me * ce)
    return out.reshape(orig_shape), aux
