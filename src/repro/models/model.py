"""Model assembly: repeated-unit scan segments, train forward (chunked xent
loss), prefill, and single-token decode.

Layers are grouped into repeated-unit segments (config.build_segments);
each segment scans its unit of sub-blocks with parameters stacked along a
leading ``repeats`` axis — keeping HLO size O(#distinct sub-blocks), not
O(#layers), which is what makes 88-layer and 61-layer configs
lower/compile quickly for the multi-pod dry-run, while still supporting
alternating local/global and hybrid recurrent/attention patterns.

The loss never materializes (B, S, V) logits: cross-entropy is computed per
sequence chunk under a rematerialized scan (peak memory O(B * chunk * V)).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import blocks as blocks_mod
from .config import GLOBAL_WINDOW, ModelConfig, SubBlock, build_segments
from .layers import init_dense, init_norm, rms_norm, softcap

__all__ = [
    "init_params",
    "forward_train",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_decode_state",
    "sub_cache_len",
]


def sub_cache_len(sub: SubBlock, max_len: int) -> int:
    """KV-cache length of one sub-block: full context for global attention,
    the window for sliding-window layers, 1 slot (unused) for stateful
    recurrent kinds."""
    if sub.kind in ("attn", "xattn"):
        return max_len if sub.window == GLOBAL_WINDOW \
            else min(sub.window, max_len)
    return 1


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stack_init(key, n: int, init_one):
    """Initialize n copies of a block and stack each leaf: (n, ...)."""
    keys = jax.random.split(key, n)
    inits = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg)
    segs = build_segments(cfg)
    k_embed, k_seg, k_enc = jax.random.split(key, 3)
    params: dict[str, Any] = {
        # N(0, 1/sqrt(d)): input embeddings are re-scaled by sqrt(d) at
        # lookup; the tied unembedding then produces O(1) logits at init.
        "embed": init_dense(k_embed, (cfg.vocab_size, cfg.d_model), dtype,
                            scale=cfg.d_model ** -0.5),
        "final_norm": init_norm((cfg.d_model,), dtype),
    }
    seg_keys = jax.random.split(k_seg, max(len(segs), 1))
    segments = []
    for i, seg in enumerate(segs):
        sub_keys = jax.random.split(seg_keys[i], len(seg.unit))
        segments.append(tuple(
            _stack_init(
                sub_keys[j], seg.repeats,
                lambda k, sub=sub: blocks_mod.init_block(
                    k, cfg, sub.kind, sub.moe, dtype),
            )
            for j, sub in enumerate(seg.unit)
        ))
    params["segments"] = tuple(segments)
    if cfg.encoder_layers:
        enc_keys = jax.random.split(k_enc, 2)
        params["encoder"] = {
            "blocks": _stack_init(
                enc_keys[0], cfg.encoder_layers,
                lambda k: blocks_mod.init_block(k, cfg, "attn", False,
                                                dtype),
            ),
            "final_norm": init_norm((cfg.d_model,), dtype),
        }
    return params


# --------------------------------------------------------------------------
# train forward + loss
# --------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]  # (B, S, d) gather
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def _run_encoder(frames, params, cfg: ModelConfig):
    """Bidirectional encoder over precomputed frame embeddings (stub
    frontend): (B, Se, d) -> (B, Se, d)."""
    enc = params["encoder"]
    Se = frames.shape[1]
    positions = jnp.arange(Se, dtype=jnp.int32)[None, :]

    def body(xc, p):
        xc, _a, _st = blocks_mod.block_train(
            xc, p, cfg, "attn", False, window=GLOBAL_WINDOW,
            theta=cfg.rope_theta, positions=positions, causal=False)
        return xc, None

    x, _ = jax.lax.scan(body, frames, enc["blocks"])
    return rms_norm(x, enc["final_norm"])


def forward_train(params, cfg: ModelConfig, batch, remat: bool = False):
    """Returns (final hidden states (B, S, d), aux losses)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    if cfg.vision_seq and "vision" in batch:
        # stub multimodal frontend: precomputed patch embeddings replace
        # the first vision_seq positions
        v = batch["vision"].astype(x.dtype)
        x = jnp.concatenate([v, x[:, v.shape[1]:]], axis=1)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(batch["frames"].astype(x.dtype), params, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :],
                                 (B, S))
    mrope_positions = batch.get("mrope_positions")
    aux_total = jnp.zeros((), jnp.float32)
    for seg, seg_params in zip(build_segments(cfg), params["segments"]):

        def body(carry, inp, seg=seg):
            xc, aux = carry
            for j, sub in enumerate(seg.unit):
                xc, a, _st = blocks_mod.block_train(
                    xc, inp[j], cfg, sub.kind, sub.moe, window=sub.window,
                    theta=sub.theta, positions=positions, causal=True,
                    enc_out=enc_out, mrope_positions=mrope_positions)
                aux = aux + a
            return (xc, aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params)
    return rms_norm(x, params["final_norm"]), aux_total


def _xent_chunk(x, embed, labels, cfg: ModelConfig):
    """x: (B, C, d); labels: (B, C). Returns (sum_loss, count)."""
    logits = (x @ embed.T).astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None].astype(jnp.int32),
        axis=-1)[..., 0]
    valid = labels >= 0
    loss = jnp.where(valid, lse - ll, 0.0)
    return loss.sum(), valid.sum()


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = False,
            loss_chunk: int = 512, aux_weight: float = 0.01):
    """Scalar LM loss with chunked cross-entropy (never materializes the
    full (B, S, V) logits)."""
    x, aux = forward_train(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    B, S, d = x.shape
    C = min(loss_chunk, S)
    n_chunks = -(-S // C)
    pad = n_chunks * C - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, n_chunks, C, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, C).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(carry, inp):
        tot, cnt = carry
        xb, lb = inp
        s, c = _xent_chunk(xb, params["embed"], lb, cfg)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        chunk_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, lc))
    loss = tot / jnp.maximum(cnt, 1)
    return loss + aux_weight * aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_decode_state(params, cfg: ModelConfig, batch: int, max_len: int,
                      enc_out=None) -> dict:
    """Decode state: per-segment, per-sub-block stacked caches (+ cross
    K/V for enc-dec models)."""
    dtype = _dtype(cfg)
    segs = build_segments(cfg)
    caches = []
    for si, seg in enumerate(segs):
        sub_caches = []
        for j, sub in enumerate(seg.unit):
            cl = sub_cache_len(sub, max_len)
            one = lambda sub=sub, cl=cl: blocks_mod.init_block_cache(
                cfg, sub.kind, batch, cl, dtype)
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[one() for _ in range(seg.repeats)])
            if sub.kind == "xattn":
                hd, kv = cfg.head_dim, cfg.num_kv_heads
                Se = cfg.encoder_seq
                if enc_out is not None:
                    xk, xv = _cross_kv(params["segments"][si][j], cfg,
                                       enc_out)
                else:
                    xk = jnp.zeros((seg.repeats, batch, Se, kv, hd), dtype)
                    xv = jnp.zeros((seg.repeats, batch, Se, kv, hd), dtype)
                stacked = dict(stacked, xk=xk, xv=xv)
            sub_caches.append(stacked)
        caches.append(tuple(sub_caches))
    return {"caches": tuple(caches), "pos": jnp.zeros((), jnp.int32)}


def _cross_kv(stacked_params, cfg: ModelConfig, enc_out):
    """Per-layer cross-attention K/V from the encoder output.
    stacked_params: one sub-block's params with leading repeats axis."""
    B, Se, d = enc_out.shape

    def one_layer(p):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
        return k, v

    return jax.vmap(one_layer)(stacked_params)


def decode_step(params, cfg: ModelConfig, state, tokens):
    """One token for the whole batch.  tokens: (B,) int32.
    Returns (logits (B, V), new state)."""
    pos = state["pos"]
    x = _embed_tokens(params, cfg, tokens[:, None])[:, 0]  # (B, d)
    new_caches = []
    for seg, seg_params, cache in zip(build_segments(cfg),
                                      params["segments"], state["caches"]):

        def body(xc, inp, seg=seg):
            p_all, c_all = inp
            new_c = []
            for j, sub in enumerate(seg.unit):
                c_j = c_all[j]
                ekv = ((c_j["xk"], c_j["xv"]) if sub.kind == "xattn"
                       else None)
                core = {k: v for k, v in c_j.items()
                        if k not in ("xk", "xv")}
                xc, cn = blocks_mod.block_decode(
                    xc, core, p_all[j], cfg, sub.kind, sub.moe, pos=pos,
                    window=sub.window, theta=sub.theta, enc_kv=ekv)
                if sub.kind == "xattn":
                    cn = dict(cn, xk=c_j["xk"], xv=c_j["xv"])
                new_c.append(cn)
            return xc, tuple(new_c)

        x, cache_new = jax.lax.scan(body, x, (seg_params, cache))
        new_caches.append(cache_new)
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["embed"].T).astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    return logits, {"caches": tuple(new_caches), "pos": pos + 1}


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def _format_attn_cache(kv, sub: SubBlock, cfg: ModelConfig, S: int,
                       max_len: int, dtype):
    """Pack full-sequence K/V into ring-buffer cache layout: entry for
    position p lives at slot p % cache_len."""
    k_full, v_full = kv
    B = k_full.shape[0]
    cl = sub_cache_len(sub, max_len)
    take = min(S, cl)
    pos_tail = jnp.arange(S - take, S, dtype=jnp.int32)
    slots = jnp.mod(pos_tail, cl)
    kc = jnp.zeros((B, cl, cfg.num_kv_heads, cfg.head_dim), dtype)
    vc = jnp.zeros((B, cl, cfg.num_kv_heads, cfg.head_dim), dtype)
    kc = kc.at[:, slots].set(k_full[:, S - take:].astype(dtype))
    vc = vc.at[:, slots].set(v_full[:, S - take:].astype(dtype))
    sp = jnp.full((cl,), -1, jnp.int32).at[slots].set(pos_tail)
    return {"k": kc, "v": vc, "slot_pos": sp}


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """Full-sequence prefill: returns (last-token logits (B, V), state).

    Runs the train-mode forward (streaming attention) while extracting
    per-layer decode state: ring-buffer K/V for attention layers, final
    recurrent state for rglru/rwkv layers.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    if cfg.vision_seq and "vision" in batch:
        v = batch["vision"].astype(x.dtype)
        x = jnp.concatenate([v, x[:, v.shape[1]:]], axis=1)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(batch["frames"].astype(x.dtype), params, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :],
                                 (B, S))
    mrope_positions = batch.get("mrope_positions")
    dtype = _dtype(cfg)
    segs = build_segments(cfg)

    caches = []
    for si, (seg, seg_params) in enumerate(zip(segs, params["segments"])):

        def body(xc, inp, seg=seg):
            new_c = []
            for j, sub in enumerate(seg.unit):
                xc, _a, st = blocks_mod.block_train(
                    xc, inp[j], cfg, sub.kind, sub.moe, window=sub.window,
                    theta=sub.theta, positions=positions, causal=True,
                    enc_out=enc_out, mrope_positions=mrope_positions)
                if sub.kind in ("attn", "xattn"):
                    new_c.append(_format_attn_cache(st, sub, cfg, S,
                                                    max_len, dtype))
                else:
                    new_c.append(jax.tree.map(
                        lambda a: a.astype(a.dtype), st))
            return xc, tuple(new_c)

        x, cache = jax.lax.scan(body, x, seg_params)
        sub_caches = []
        for j, sub in enumerate(seg.unit):
            c = cache[j]
            if sub.kind == "xattn":
                xk, xv = _cross_kv(seg_params[j], cfg, enc_out)
                c = dict(c, xk=xk, xv=xv)
            sub_caches.append(c)
        caches.append(tuple(sub_caches))
    x = rms_norm(x, params["final_norm"])
    logits = (x[:, -1] @ params["embed"].T).astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    return logits, {"caches": tuple(caches),
                    "pos": jnp.asarray(S, jnp.int32)}
