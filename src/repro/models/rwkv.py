"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus squared-ReLU channel-mix.

State per head is an (N, N) outer-product accumulator:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with w_t = exp(-exp(wproj(x_t))) the data-dependent decay.  The recurrence
is not linear-associative in a small state (w_t varies per step), so the
train path runs a time ``lax.scan`` (the paper's CUDA kernel's sequential
semantics); decode carries O(1) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

from .layers import init_dense

__all__ = ["init_rwkv_timemix", "init_rwkv_channelmix", "timemix_scan",
           "timemix_step", "channelmix", "channelmix_step"]


def init_rwkv_timemix(key, d_model: int, head_dim: int, dtype):
    ks = jax.random.split(key, 8)
    H = d_model // head_dim
    return {
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_v": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_w": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_g": jnp.full((d_model,), 0.5, jnp.float32),
        "w_r": init_dense(ks[0], (d_model, d_model), dtype),
        "w_k": init_dense(ks[1], (d_model, d_model), dtype),
        "w_v": init_dense(ks[2], (d_model, d_model), dtype),
        "w_g": init_dense(ks[3], (d_model, d_model), dtype),
        "w_decay": init_dense(ks[4], (d_model, d_model), jnp.float32,
                              scale=0.01 * d_model ** -0.5),
        "decay_bias": jnp.full((d_model,), -4.0, jnp.float32),
        "u_bonus": jnp.zeros((H, head_dim), jnp.float32),
        "w_out": init_dense(ks[5], (d_model, d_model), dtype),
        "ln_x": jnp.ones((d_model,), jnp.float32),  # group-norm scale
    }


def init_rwkv_channelmix(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "w_k": init_dense(ks[0], (d_model, d_ff), dtype),
        "w_v": init_dense(ks[1], (d_ff, d_model), dtype),
        "w_r": init_dense(ks[2], (d_model, d_model), dtype),
    }


def _shift(x, x_prev):
    """Token shift: previous token's features (B, S, d); x_prev (B, d) is
    the last token of the previous segment (zeros at sequence start)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _projections(x, xs, p, head_dim: int):
    B, S, d = x.shape
    H = d // head_dim
    r = _mix(x, xs, p["mu_r"]) @ p["w_r"]
    k = _mix(x, xs, p["mu_k"]) @ p["w_k"]
    v = _mix(x, xs, p["mu_v"]) @ p["w_v"]
    g = _mix(x, xs, p["mu_g"]) @ p["w_g"]
    wx = _mix(x, xs, p["mu_w"]).astype(jnp.float32) @ p["w_decay"]
    w = jnp.exp(-jnp.exp(wx + p["decay_bias"]))  # (B, S, d) in (0, 1)
    shp = (B, S, H, head_dim)
    # keep the head axis sharded over 'model' through the recurrence
    return tuple(
        constrain(a.reshape(shp), "batch", None, "heads", None)
        for a in (r, k, v)
    ) + (g, constrain(w.reshape(shp), "batch", None, "heads", None))


def _group_norm(y, scale, head_dim):
    """Per-head layer norm of the wkv output (ln_x in RWKV)."""
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    yn = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    B, S, H, N = yn.shape
    return yn.reshape(B, S, H * N) * scale


def timemix_scan(x, x_prev, p, head_dim: int):
    """Full-sequence time-mix.  x: (B, S, d); x_prev: (B, d).
    Returns (out (B, S, d), S_final (B, H, N, N), x_last (B, d))."""
    B, S, d = x.shape
    H = d // head_dim
    xs = _shift(x, x_prev)
    r, k, v, g, w = _projections(x, xs, p, head_dim)
    u = p["u_bonus"]  # (H, N)

    def step(S_state, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, N) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       S_state + u[None, :, :, None] * kv)
        S_new = w_t.astype(jnp.float32)[..., None] * S_state + kv
        return S_new, y

    S0 = jnp.zeros((B, H, head_dim, head_dim), jnp.float32)
    S_fin, ys = jax.lax.scan(
        step, S0,
        (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)),
    )
    y = ys.transpose(1, 0, 2, 3)  # (B, S, H, N)
    y = _group_norm(y, p["ln_x"], head_dim).astype(x.dtype)
    out = (y * jax.nn.silu(g)) @ p["w_out"]
    return out, S_fin, x[:, -1, :]


def timemix_step(x_t, state, p, head_dim: int):
    """Decode: x_t (B, d); state = (S (B,H,N,N) fp32, x_prev (B, d))."""
    S_state, x_prev = state
    B, d = x_t.shape
    H = d // head_dim
    x3 = x_t[:, None, :]
    xs3 = x_prev[:, None, :]
    r, k, v, g, w = _projections(x3, xs3, p, head_dim)
    r_t, k_t, v_t, w_t = (a[:, 0] for a in (r, k, v, w))  # (B, H, N)
    u = p["u_bonus"]
    kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                    v_t.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                   S_state + u[None, :, :, None] * kv)
    S_new = w_t.astype(jnp.float32)[..., None] * S_state + kv
    y = _group_norm(y[:, None], p["ln_x"], head_dim)[:, 0].astype(x_t.dtype)
    out = (y * jax.nn.silu(g[:, 0])) @ p["w_out"]
    return out, (S_new, x_t)


def channelmix(x, x_prev, p):
    """x: (B, S, d); returns (out, x_last)."""
    xs = _shift(x, x_prev)
    k = _mix(x, xs, p["mu_k"]) @ p["w_k"]
    r = jax.nn.sigmoid(_mix(x, xs, p["mu_r"]) @ p["w_r"])
    out = r * (jnp.square(jax.nn.relu(k)) @ p["w_v"])
    return out, x[:, -1, :]


def channelmix_step(x_t, x_prev, p):
    """Decode: x_t (B, d), x_prev (B, d) -> (out (B, d), new x_prev)."""
    out, _ = channelmix(x_t[:, None, :], x_prev, p)
    return out[:, 0], x_t
