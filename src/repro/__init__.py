"""repro — TL-Rightsizing (IEEE CLOUD 2021) as the capacity-planning layer
of a multi-pod JAX training/serving framework."""

__version__ = "0.1.0"
