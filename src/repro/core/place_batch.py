"""Batched greedy placement engine: lockstep `two_phase` over a fleet.

PR 1 fused the mapping-LP phase of a fleet sweep into one batched PDHG
solve; this module does the same for the paper's phase-2 greedy
placement (§III first/similarity fit, §V-D cross-fill), the remaining
per-instance Python in ``evaluate_many``.  ``place_many`` advances all B
instances in lockstep over their task-event schedules, wave-synchronized
at node-type phase boundaries: every instance's open nodes live in ONE
padded ``(B, N_nodes, T', D)`` array, and each lockstep step scores the
pending task of every instance against all its candidate nodes in a
single batched feasibility + similarity pass (the dot-product/best-fit
hot loop of this whole family of vector bin-packing heuristics) instead
of B Python-level ``TypePool.find_fit`` calls.  All per-step bookkeeping
(schedule pointers, purchases, capacity updates) is vectorized across
instances, so a step costs O(1) numpy dispatches regardless of B.

Wave synchronization is the engine's load-bearing trick: instances are
independent, so inserting barriers between their (own-pack, cross-fill)
phase pairs changes nothing per instance — but since nodes are only
purchased during a type's own phase, every wave's candidate pool starts
*empty* and grows at the tail.  Each wave therefore runs on a compact
``(B, W, T', D)`` pool tensor with no gathers at all (W = widest pool in
the wave, typically ~N_nodes/m), and scatters its finished type-block
back into the master array once per wave.  Without the barriers, ragged
instances drift into different phases and the per-step candidate window
spans most of the node axis.

Exactness: placements are identical to looped ``two_phase`` — same node
purchases in the same order, same ``assign``, same cost.  Three
properties make that hold:

  * the *attempt schedule* (which (task, node-type, purchase?, policy)
    triples are tried, in what order) is precomputed per instance; the
    per-instance attempt order is exactly ``two_phase``'s, and attempts
    on already-placed tasks are skipped at run time.  Filtering a
    stably-sorted superset equals stably sorting the runtime subset
    (both tie-break on ascending task id), so the dynamic order matches.
  * node ids are purchase ranks and purchases only happen in a type's
    own phase, so each type's nodes form one contiguous id block:
    ``first``-fit's "earliest purchased" is the lowest pool-local index,
    and similarity's argmax tie-break (first maximum) matches pool-local
    argmax.
  * the batched numpy scoring computes the *same float64 expressions*
    as ``TypePool.find_fit``: feasibility as ``not any(rem < dem -
    EPS)`` over the span (a bool reduction of the identical
    comparisons, on identical remaining-capacity values — elementwise
    updates never reassociate), and similarity via einsums whose masked
    terms are exact zeros.  Similarity sums can still differ from the
    loop in the last ulp (numpy's einsum kernels vary with memory
    layout), so BOTH engines quantize scores to 9 decimals before the
    argmax — reassociation noise collapses onto identical values and
    the first-max tie-break picks the same node on every path.

``backend='kernel'`` routes the scoring pass through the batch-dim-aware
Pallas kernel ``fit_scores_many`` (grid over B; fp32, matching the
single-instance kernel backend), ``backend='numpy'`` uses the bit-exact
vectorized host path.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from . import penalty as penalty_mod
from .batch import ProblemBatch, pack_problems
from .placement import FIT_POLICIES
from .solution import EPS, Solution

__all__ = ["place_many", "PLACEMENT_STEPPERS"]

# The lockstep stepper implementations behind ``place_many(placement=)``:
# 'lockstep' is this module's vectorized-numpy engine (one host dispatch
# per placement step); 'compiled' is ``place_step``'s on-device stepper
# (one host dispatch per node-type phase boundary), which falls back to
# 'lockstep' when a wave's pool tensor would be oversized.
PLACEMENT_STEPPERS = ("lockstep", "compiled")


@dataclasses.dataclass
class _Phases:
    """One instance's precomputed phase structure, in two_phase order."""

    type_order: np.ndarray   # (n_phases,) node-type per wave
    own: list                # own-pack task lists, sorted (start, id)
    fill: list               # cross-fill candidate lists, sorted
                             # (h_avg(u|B), id); empty when not filling
    dem_norm: np.ndarray     # (n,) find_fit demand norms (1.0 unused)


def _phases(problem, mapping: np.ndarray, fit: str,
            filling: bool) -> _Phases:
    nt = problem.node_types
    if filling:
        type_order = np.argsort(-nt.capacity_per_cost(), kind="stable")
        h_avg = penalty_mod.relative_demand(problem, "avg")
        rank = np.empty(nt.m, np.int64)
        rank[type_order] = np.arange(nt.m)
        map_rank = rank[mapping]
    else:
        type_order = np.arange(nt.m)

    dn_all = np.ones(problem.n)
    if fit == "similarity":
        # find_fit's demand norm, cached per task (static given the
        # mapping).  The row-wise einsum may differ from find_fit's BLAS
        # np.linalg.norm in the last ulp; the norm is a per-task factor
        # common to every candidate node's score, so exactly-tied nodes
        # (identical remaining capacity) stay exactly tied and the
        # argmax tie-breaking is unaffected.
        dem_n_all = problem.dem / nt.cap[mapping]
        spans = problem.end - problem.start + 1
        dn_all = np.sqrt(
            np.einsum("nd,nd->n", dem_n_all, dem_n_all)) * np.sqrt(spans)

    own, fill = [], []
    for pos, B in enumerate(type_order):
        mine = np.flatnonzero(mapping == int(B))
        own.append(mine[np.lexsort((mine, problem.start[mine]))])
        if filling:
            rest = np.flatnonzero(map_rank > pos)
            fill.append(rest[np.argsort(h_avg[rest, B], kind="stable")])
        else:
            fill.append(np.zeros(0, np.int64))
    return _Phases(type_order=type_order, own=own, fill=fill,
                   dem_norm=dn_all)


def _batch_aux(batch: ProblemBatch, phases: list[_Phases]):
    """Scoring-side arrays shared by the lockstep stepper engines.

    Returns ``(dn, capx, span_all)``: per-task demand norms (B, n) padded
    with 1.0; per-(instance, type) capacity (B, m, D) with +inf on padded
    dims so ``rem / capx`` is bit-exact on real dims and 0 on padded
    ones; and every task's span mask (B, n, T') bool.
    """
    Bn = batch.B
    dn = np.stack([
        np.pad(ph.dem_norm, (0, batch.n - len(ph.dem_norm)),
               constant_values=1.0) for ph in phases])
    dim_mask = np.zeros((Bn, batch.D), bool)
    for b, t in enumerate(batch.problems):
        dim_mask[b, : t.D] = True
    capx = np.where(dim_mask[:, None, :], batch.cap, np.inf)
    t_ids = np.arange(batch.Tp)
    span_all = ((batch.start[:, :, None] <= t_ids)
                & (t_ids <= batch.end[:, :, None]))
    return dn, capx, span_all


class _Engine:
    """Shared lockstep state across the waves of one place_many call."""

    def __init__(self, batch: ProblemBatch, phases: list[_Phases],
                 backend: str):
        self.batch = batch
        self.phases = phases
        self.backend = backend
        Bn = batch.B
        self.n_cap = 8
        # the master open-node state: node id == purchase rank
        self.rem = np.zeros((Bn, self.n_cap, batch.Tp, batch.D))
        self.node_type = np.full((Bn, self.n_cap), -1, np.int64)
        self.counts = np.zeros(Bn, np.int64)
        self.placed = np.zeros((Bn, batch.n), bool)
        self.assign = np.full((Bn, batch.n), -1, np.int64)
        self.dn, self.capx_all, self.span_all = _batch_aux(batch, phases)

    def run_wave(self, k: int, fit: str, filling: bool) -> bool:
        """Own-pack + cross-fill sub-phases of every instance's k-th
        node-type, on a compact tail-growing pool tensor.  Returns
        False when no instance has a k-th phase."""
        wave = np.array([b for b, ph in enumerate(self.phases)
                         if k < len(ph.type_order)], np.int64)
        if len(wave) == 0:
            return False
        tau = np.array([self.phases[b].type_order[k] for b in wave],
                       np.int64)
        lo = self.counts[wave].copy()  # each type-block starts at the
        # current purchase rank: no node of type tau exists yet
        A = len(wave)
        pool = np.zeros((A, 8, self.batch.Tp, self.batch.D))
        w = np.zeros(A, np.int64)
        # drop already-placed tasks per sub-phase up front: a task only
        # becomes placed *between* sub-phases (each list holds distinct
        # tasks), so this is exactly two_phase's dynamic ~placed filter
        # and no skip checks are needed inside the lockstep loop
        own = [self._live(b, self.phases[b].own[k]) for b in wave]
        self._run_sub(wave, tau, pool, w, own, purchase=True,
                      similarity=fit == "similarity")
        pool = self._pool
        if filling:
            fill = [self._live(b, self.phases[b].fill[k]) for b in wave]
            self._run_sub(wave, tau, pool, w, fill, purchase=False,
                          similarity=False)
            pool = self._pool
        # scatter the finished type-block back into the master array
        hi = int((lo + w).max())
        while hi > self.n_cap:
            self.rem = np.concatenate(
                [self.rem, np.zeros_like(self.rem)], axis=1)
            self.node_type = np.concatenate(
                [self.node_type,
                 np.full_like(self.node_type, -1)], axis=1)
            self.n_cap *= 2
        for a, b in enumerate(wave):
            if w[a]:
                self.rem[b, lo[a]: lo[a] + w[a]] = pool[a, : w[a]]
                self.node_type[b, lo[a]: lo[a] + w[a]] = tau[a]
        return True

    def _live(self, b: int, tasks: np.ndarray) -> np.ndarray:
        """Order-preserving ~placed filter (two_phase's phase entry)."""
        return tasks[~self.placed[b, tasks]]

    def _run_sub(self, wave, tau, pool, w, lists, purchase: bool,
                 similarity: bool):
        """Lockstep one sub-phase: one attempt list per wave instance,
        scored against the wave's compact pool each step.

        Instances leave a sub-phase permanently (their list is
        exhausted); finished pool rows are written back into the wave's
        pool tensor as their instance leaves, and the working set is
        compacted to the live rows once enough have finished, so the
        batched ops stay sized to the instances that still have
        attempts.  Fill-only sub-phases drop node-less instances up
        front: with an empty pool every attempt is a guaranteed miss
        that mutates nothing, exactly as ``find_fit`` returns None on an
        empty TypePool.  All per-task data (demands, spans, norms,
        placement flags) is read straight from the engine's padded
        batch arrays through the live-row -> instance map, so dropping
        rows never copies them.
        """
        batch = self.batch
        if purchase:
            keep = np.flatnonzero(
                np.array([len(x) for x in lists]) > 0)
        else:
            keep = np.flatnonzero(
                (w > 0) & (np.array([len(x) for x in lists]) > 0))
        lists = [lists[a] for a in keep]
        A = len(keep)
        if A == 0:
            self._pool = pool
            return
        L = max(len(x) for x in lists)
        # live-row state; `keep` maps live rows back to wave rows and
        # `bsel_l` to instances
        u_pad = np.zeros((A, L), np.int64)
        lens = np.zeros(A, np.int64)
        for a, x in enumerate(lists):
            u_pad[a, : len(x)] = x
            lens[a] = len(x)
        ptr = np.zeros(A, np.int64)
        arows = np.arange(A)
        wl = w[keep].copy()
        pool_l = pool[keep]
        tau_l = tau[keep]
        bsel_l = wave[keep]
        capx = self.capx_all[bsel_l, tau_l]
        cap_rows = batch.cap[bsel_l, tau_l]      # (A, Dp), padded dims 1
        start_pad = batch.start.astype(np.int64)
        end_pad = batch.end.astype(np.int64)
        kernel = self.backend == "kernel"
        if kernel:
            from repro.kernels import ops as kops

            inv_cap = np.where(np.isfinite(capx), 1.0 / capx, 0.0)
        # pool_n caches pool / capx so similarity steps skip the big
        # division pass; one row is re-divided after each update, which
        # is bitwise what find_fit computes from the current rem
        pool_n = pool_l / capx[:, None, None, :] \
            if similarity and not kernel else None

        def write_back(rows):
            """Store finished live rows in the wave pool (grown if the
            live pool outgrew it) and the width array."""
            nonlocal pool
            if pool_l.shape[1] > pool.shape[1]:
                grown = np.zeros(
                    (len(wave),) + pool_l.shape[1:], pool.dtype)
                grown[:, : pool.shape[1]] = pool
                pool = grown
            pool[keep[rows]] = pool_l[rows]
            w[keep[rows]] = wl[rows]

        written = np.zeros(A, bool)  # finished rows already stored
        while True:
            # lists are pre-filtered (run_wave's _live), so the pending
            # attempt is always at the pointer — no skip checks needed
            done = ptr >= lens
            fresh = done & ~written
            if fresh.any():
                write_back(np.flatnonzero(fresh))
                written |= fresh
            n_done = int(done.sum())
            if n_done == A:
                break
            if n_done >= max(4, A // 4):  # compact to the live rows
                live = np.flatnonzero(~done)
                keep = keep[live]
                u_pad, lens, ptr = u_pad[live], lens[live], ptr[live]
                wl, pool_l = wl[live], pool_l[live]
                tau_l, bsel_l = tau_l[live], bsel_l[live]
                capx, cap_rows = capx[live], cap_rows[live]
                if kernel:
                    inv_cap = inv_cap[live]
                if pool_n is not None:
                    pool_n = pool_n[live]
                A = len(live)
                arows = np.arange(A)
                done = np.zeros(A, bool)
                written = np.zeros(A, bool)
            if wl.max() == pool_l.shape[1]:  # grow the pool tail
                pool_l = np.concatenate(
                    [pool_l, np.zeros_like(pool_l)], axis=1)
                if pool_n is not None:
                    pool_n = np.concatenate(
                        [pool_n, np.zeros_like(pool_n)], axis=1)

            alive = ~done
            u_cur = u_pad[arows, np.minimum(ptr, lens - 1)]
            dem = batch.dem[bsel_l, u_cur]               # (A, Dp)
            s_cur = start_pad[bsel_l, u_cur]
            e_cur = end_pad[bsel_l, u_cur]
            span = self.span_all[bsel_l, u_cur]          # (A, T')
            W = max(int(wl.max()), 1)
            node_ok = (np.arange(W)[None, :] < wl[:, None]) \
                & alive[:, None]

            if kernel:
                feas, score = kops.fit_scores_many(
                    pool_l[:, :W], dem, s_cur, e_cur, inv_cap,
                    scored=similarity)
                feas = feas & node_ok
            else:
                # not any(rem < dem - EPS) over the span == find_fit's
                # all(rem >= dem - EPS): the same comparisons on the
                # contiguous (T'*D)-flattened pool rows (numpy's
                # iterator is ~10x faster there than on 4-D broadcasts
                # with a tiny trailing axis)
                pool3 = pool_l[:, :W].reshape(A, W, -1)  # contig view
                thr_flat = np.tile(dem - EPS, (1, batch.Tp))
                span_flat = np.repeat(span, batch.D, axis=1)
                viol = ((pool3 < thr_flat[:, None, :])
                        & span_flat[:, None, :]).any(axis=2)
                feas = ~viol & node_ok
                if similarity:
                    # slice time to the live span union for the einsum
                    # reductions: dropped slots carry only exact-zero
                    # terms, so the accumulations are unchanged
                    t0 = int(s_cur[alive].min())
                    t1 = int(e_cur[alive].max()) + 1
                    rem_n = pool_n[:, :W, t0:t1]
                    dem_n = dem / capx
                    span_f = span[:, t0:t1].astype(np.float64)
                    dot = np.einsum("bntd,bd,bt->bn", rem_n, dem_n,
                                    span_f)
                    norm2 = np.einsum("bntd,bntd,bt->bn", rem_n, rem_n,
                                      span_f)
                    dem_norm = self.dn[bsel_l, u_cur]
                    score = dot / (dem_norm[:, None] * np.sqrt(norm2)
                                   + 1e-30)
            has = feas.any(axis=1)
            if similarity:
                # find_fit's quantized tie-break: digits beyond the 9th
                # are float reassociation noise across scoring layouts
                choice = np.where(feas, np.round(score, 9),
                                  -np.inf).argmax(axis=1)
            else:
                choice = feas.argmax(axis=1)  # lowest id == earliest

            place_a = np.flatnonzero(has)     # has implies alive
            j_all = choice[place_a]
            if purchase:
                buy_a = np.flatnonzero(~has & alive)
                if len(buy_a):
                    bad = (dem[buy_a] > cap_rows[buy_a] + EPS
                           ).any(axis=1)
                    if bad.any():
                        a0 = int(buy_a[int(np.flatnonzero(bad)[0])])
                        raise RuntimeError(
                            f"mapping assigned task {int(u_cur[a0])} "
                            f"to node-type {int(tau_l[a0])} it cannot "
                            f"fit")
                    j_new = wl[buy_a]
                    pool_l[buy_a, j_new] = cap_rows[buy_a][:, None]
                    wl[buy_a] += 1
                    self.counts[bsel_l[buy_a]] += 1
                    place_a = np.concatenate([place_a, buy_a])
                    j_all = np.concatenate([j_all, j_new])
            if len(place_a):
                sub = (dem[place_a][:, None, :]
                       * span[place_a].astype(np.float64)[:, :, None])
                pool_l[place_a, j_all] -= sub  # dem*1 / dem*0: exact
                if pool_n is not None:
                    pool_n[place_a, j_all] = (
                        pool_l[place_a, j_all]
                        / capx[place_a][:, None, :])
                u_sel = u_cur[place_a]
                b_sel = bsel_l[place_a]
                # global node id = block start + pool-local index
                self.assign[b_sel, u_sel] = \
                    self.counts[b_sel] - wl[place_a] + j_all
                self.placed[b_sel, u_sel] = True
            ptr += alive
        self._pool = pool

def place_many(problems, mappings, fit: str = "first",
               filling: bool = False, backend: str = "numpy",
               meta: dict | None = None, placement: str = "lockstep",
               telemetry: dict | None = None) -> list[Solution]:
    """Batched ``two_phase`` over B instances; placements are identical.

    ``problems`` is a sequence of ``Problem``s or an already-packed
    ``ProblemBatch`` (instances are timeline-trimmed either way, like
    every placement entry point); ``mappings[b]`` is instance b's
    task -> node-type mapping in trimmed coordinates.  Returns one
    ``Solution`` per instance, equal (node purchases, ``assign``, cost)
    to ``two_phase(batch.problems[b], mappings[b], fit, filling)``.

    ``placement`` picks the lockstep stepper: ``'lockstep'`` (default)
    is this module's vectorized-numpy engine, one host dispatch per
    placement step; ``'compiled'`` runs each node-type phase as one
    on-device ``lax.scan`` (``repro.core.place_step``) so the host
    dispatches only at phase boundaries — placements are bit-identical,
    and oversized pools fall back to the numpy engine automatically.
    ``backend`` routes the numpy stepper's scoring pass (``'kernel'`` =
    the batch-dim-aware Pallas fit kernel); the compiled stepper scores
    on-device and ignores it.  ``telemetry``, when a dict, is filled
    in place with the stepper actually used, wave count, per-wave
    seconds, and (compiled) device-dispatch counts.

    >>> import numpy as np
    >>> from repro.core import place_many, two_phase
    >>> from repro.workload import SyntheticSpec, synthetic_instance
    >>> ps = [synthetic_instance(SyntheticSpec(n=12, m=2, D=2, T=6,
    ...                                        seed=s)) for s in (0, 1)]
    >>> maps = [np.zeros(12, np.int64), np.ones(12, np.int64)]
    >>> sols = place_many(ps, maps, fit="similarity")
    >>> want = two_phase(ps[0], maps[0], fit="similarity")
    >>> bool(np.array_equal(sols[0].assign, want.assign))
    True
    """
    if fit not in FIT_POLICIES:
        raise ValueError(f"fit must be one of {FIT_POLICIES}")
    if backend not in ("numpy", "kernel"):
        raise ValueError(
            f"backend must be 'numpy'|'kernel', got {backend!r}")
    if placement not in PLACEMENT_STEPPERS:
        raise ValueError(
            f"placement must be one of {PLACEMENT_STEPPERS}, "
            f"got {placement!r}")
    batch = problems if isinstance(problems, ProblemBatch) \
        else pack_problems(problems)
    if len(mappings) != batch.B:
        raise ValueError("need exactly one mapping per instance")
    phases = [_phases(t, np.asarray(mp, np.int64), fit, filling)
              for t, mp in zip(batch.problems, mappings)]
    if placement == "compiled":
        from . import place_step

        sols = place_step.run_compiled(batch, phases, fit=fit,
                                       filling=filling, meta=meta,
                                       telemetry=telemetry)
        if sols is not None:
            return sols
        # oversized pool: place_step declined (and recorded why in
        # telemetry); fall through to the numpy lockstep engine
    eng = _Engine(batch, phases, backend)
    wave_s = []
    k = 0
    while True:
        t0 = time.perf_counter()
        if not eng.run_wave(k, fit, filling):
            break
        wave_s.append(time.perf_counter() - t0)
        k += 1
    if telemetry is not None:
        telemetry.setdefault("engine", "lockstep")
        telemetry["waves"] = len(wave_s)
        telemetry["wave_s"] = wave_s

    out = []
    for b, t in enumerate(batch.problems):
        assert eng.placed[b, : t.n].all(), \
            "place_many must place every task"
        out.append(Solution(
            node_type=eng.node_type[b, : eng.counts[b]].copy(),
            assign=eng.assign[b, : t.n].copy(),
            meta=dict(meta or {}, fit=fit, filling=filling),
        ))
    return out
