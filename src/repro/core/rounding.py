"""Congestion-aware concentration rounding (beyond-paper).

Plain argmax rounding pays ~half a node of ceiling waste per node-type
when the LP spreads mass (degenerate homogeneous pricing — see
EXPERIMENTS.md).  This rounding assigns tasks sequentially (descending
LP-confidence last, so confident tasks anchor first... empirically:
descending size first) to the type minimizing *marginal ceiling cost*:

    marginal(u, B) = cost(B) * (ceil(new_peak_B) - ceil(peak_B))
                     - lam * x_lp(u, B)

ties broken toward the LP's fractional preference.  The result feeds the
same placement phase as any other mapping.
"""

from __future__ import annotations

import numpy as np

from .problem import Problem, active_mask, feasible_types

__all__ = ["concentration_rounding"]


def concentration_rounding(problem: Problem, x_lp: np.ndarray,
                           lam: float = 0.05) -> np.ndarray:
    """(n,) mapping from the fractional LP solution x_lp (n, m)."""
    n, m, D = problem.n, problem.m, problem.D
    act = active_mask(problem)                      # (n, T')
    Tp = act.shape[1]
    w = problem.dem[:, None, :] / problem.node_types.cap[None, :, :]
    feas = feasible_types(problem)
    cost = problem.node_types.cost

    cong = np.zeros((m, Tp, D))
    peak = np.zeros(m)
    mapping = np.full(n, -1, np.int64)
    # big, long tasks first: they dominate the peaks
    size = w.mean(axis=(1, 2)) * (problem.end - problem.start + 1)
    order = np.argsort(-size)
    for u in order:
        span = act[u]                               # (T',)
        best, best_score = -1, np.inf
        for B in range(m):
            if not feas[u, B]:
                continue
            new = cong[B][span] + w[u, B][None, :]
            new_peak = max(peak[B], float(new.max()) if new.size else 0.0)
            marginal = cost[B] * (np.ceil(new_peak - 1e-9)
                                  - np.ceil(peak[B] - 1e-9))
            score = marginal - lam * cost[B] * x_lp[u, B]
            if score < best_score - 1e-12:
                best, best_score = B, score
        mapping[u] = best
        cong[best][span] += w[u, best][None, :]
        peak[best] = max(peak[best], float(cong[best][span].max())
                         if span.any() else peak[best])
    return mapping
