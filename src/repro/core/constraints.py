"""Hard task constraints and their lowering into plain TL instances.

The paper fixes every task's demand vector and active window; the
related work (Task Scheduling on the Cloud with Hard Constraints,
arXiv 1507.05470; Divide (CPU Load) and Conquer, arXiv 2206.05035)
generalizes both.  ``TaskConstraints`` carries four per-task hard
constraints on top of a ``Problem``:

  * **deadline** — the task must *finish* by an inclusive slot.  A
    task whose window already ends in time is untouched; one that
    would finish late may be widened (below) or is rejected.
  * **malleable width** — ``max_width``/``serial_frac`` define an
    Amdahl-style speedup law: at width ``w`` the task runs for
    ``dur(w) = max(1, ceil(dur0 * (f + (1 - f) / w)))`` slots with
    demand ``w * dem`` (the cluster-size-vs-time trade-off of the
    bpmn-parser cost model).  Widths are resolved at lowering time:
    the *minimal* width meeting the deadline wins.
  * **affinity** — tasks sharing a named affinity group must be
    placed on the SAME node.
  * **anti-affinity** — tasks sharing a named anti-affinity group
    must not share a node while their windows overlap in time
    (non-overlapping members may reuse a node: the separation
    constraint is physical co-tenancy, not node identity).
  * **exclusive** — the task tolerates no co-tenants at all while it
    runs (a whole-node / whole-slice reservation).

Rather than teaching every engine a constraint mask, this module
**lowers** a constrained instance into an ordinary ``Problem`` that the
existing (bit-identical) LP + placement stack solves unchanged:

  1. *Width resolve* — each deadlined task picks the minimal feasible
     width; its demand and duration are rewritten.
  2. *Affinity merge* — each affinity group collapses into one
     super-task row spanning the group's hull window, whose demand is
     the per-dimension PEAK of the summed member demands over the
     hull (a conservative reservation: members land on one node by
     construction).
  3. *Virtual dimensions* — one shared unit-capacity dimension
     encodes exclusivity (exclusive rows demand 1.0, everyone else a
     δ = 1e-6 sliver, so an exclusive tenant exhausts the node for
     all others and vice versa), and one unit-capacity dimension per
     anti-affinity group (members demand 1.0, so two overlapping
     members can never co-locate).

Vacuous constraints take an identity fast path: ``lower_constraints``
returns the *original problem object*, so unconstrained behavior —
including the committed golden tables — is bit-for-bit untouched.
The independent feasibility oracle for the ORIGINAL constraint
semantics lives in ``repro.core.checker`` and shares no code with this
lowering or the engines.

>>> import numpy as np
>>> from repro.core import NodeTypes, Problem
>>> nt = NodeTypes(cap=np.array([[4.0]]), cost=np.array([1.0]))
>>> c = TaskConstraints.from_groups(2, affinity={"pair": (0, 1)})
>>> p = Problem(dem=np.ones((2, 1)), start=np.array([0, 1]),
...             end=np.array([1, 2]), node_types=nt, T=3, constraints=c)
>>> low = lower_constraints(p)
>>> low.lowered.n, low.row_of.tolist()      # one merged super-task
(1, [0, 0])
>>> float(low.lowered.dem[0, 0])            # peak of summed demands
2.0
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .problem import NodeTypes, Problem

__all__ = [
    "TaskConstraints", "Lowering", "lower_constraints",
    "expand_solution", "width_duration", "DELTA",
]

# Virtual-dimension demand of a NON-exclusive task on the shared
# exclusivity dimension.  Must exceed the placement feasibility slack
# (solution.EPS = 1e-7): a node drained to 0.0 by an exclusive tenant
# must reject a δ-demand, and a node nibbled by any δ must reject an
# exclusive 1.0-demand.
DELTA = 1e-6


def width_duration(dur0, width, serial_frac):
    """Amdahl-style duration law: ``max(1, ceil(dur0 * (f + (1-f)/w)))``.

    ``width=1`` always returns ``dur0`` exactly (the law is anchored at
    the unwidened duration); a tiny pre-ceil epsilon absorbs float
    fuzz so exact integer products never round up spuriously.

    >>> int(width_duration(6, 1, 0.5)), int(width_duration(6, 2, 0.5))
    (6, 5)
    >>> int(width_duration(6, 100, 0.0))   # perfectly parallel
    1
    """
    dur0 = np.asarray(dur0, dtype=np.float64)
    w = np.asarray(width, dtype=np.float64)
    f = np.asarray(serial_frac, dtype=np.float64)
    dur = np.ceil(dur0 * (f + (1.0 - f) / w) - 1e-9).astype(np.int64)
    return np.maximum(1, dur)


def _names_for(ids: np.ndarray, names, label: str) -> tuple[str, ...]:
    """Validated (auto-generated if empty) group-name tuple."""
    n_groups = int(ids.max()) + 1 if ids.size and ids.max() >= 0 else 0
    if not names:
        return tuple(f"{label}{g}" for g in range(n_groups))
    names = tuple(str(s) for s in names)
    if len(names) < n_groups:
        raise ValueError(
            f"{label} group ids reference {n_groups} groups but only "
            f"{len(names)} names were given")
    return names


@dataclasses.dataclass(frozen=True)
class TaskConstraints:
    """Per-task hard constraints, aligned with a ``Problem``'s task rows.

    deadline:      (n,) int64, inclusive latest FINISH slot; -1 = none.
    affinity:      (n,) int64 group id into ``affinity_names``; -1 = none.
    anti_affinity: (n,) int64 group id into ``anti_names``; -1 = none.
    exclusive:     (n,) bool — no co-tenants while the task runs.
    max_width:     (n,) int64 >= 1 — malleable-width ceiling (1 = rigid).
    serial_frac:   (n,) float64 in [0, 1] — Amdahl serial fraction.

    >>> TaskConstraints.vacuous(3).is_vacuous()
    True
    >>> c = TaskConstraints.from_groups(3, exclusive=(2,),
    ...                                 deadlines={0: 5})
    >>> c.is_vacuous(), int(c.deadline[0]), bool(c.exclusive[2])
    (False, 5, True)
    """

    deadline: np.ndarray
    affinity: np.ndarray
    anti_affinity: np.ndarray
    exclusive: np.ndarray
    max_width: np.ndarray
    serial_frac: np.ndarray
    affinity_names: tuple[str, ...] = ()
    anti_names: tuple[str, ...] = ()

    def __post_init__(self):
        deadline = np.asarray(self.deadline, dtype=np.int64)
        affinity = np.asarray(self.affinity, dtype=np.int64)
        anti = np.asarray(self.anti_affinity, dtype=np.int64)
        exclusive = np.asarray(self.exclusive, dtype=bool)
        max_width = np.asarray(self.max_width, dtype=np.int64)
        serial = np.asarray(self.serial_frac, dtype=np.float64)
        n = deadline.shape[0]
        for name, arr in (("affinity", affinity),
                          ("anti_affinity", anti),
                          ("exclusive", exclusive),
                          ("max_width", max_width),
                          ("serial_frac", serial)):
            if arr.shape != (n,):
                raise ValueError(
                    f"constraint arrays must share one (n,) shape; "
                    f"{name} is {arr.shape}, deadline is {(n,)}")
        if (deadline < -1).any():
            raise ValueError("deadline must be >= 0, or -1 for none")
        if (affinity < -1).any() or (anti < -1).any():
            raise ValueError("group ids must be >= 0, or -1 for none")
        if (max_width < 1).any():
            raise ValueError("max_width must be >= 1 (1 = rigid task)")
        if ((serial < 0.0) | (serial > 1.0)).any():
            raise ValueError("serial_frac must lie in [0, 1]")
        object.__setattr__(self, "deadline", deadline)
        object.__setattr__(self, "affinity", affinity)
        object.__setattr__(self, "anti_affinity", anti)
        object.__setattr__(self, "exclusive", exclusive)
        object.__setattr__(self, "max_width", max_width)
        object.__setattr__(self, "serial_frac", serial)
        object.__setattr__(
            self, "affinity_names",
            _names_for(affinity, self.affinity_names, "aff"))
        object.__setattr__(
            self, "anti_names", _names_for(anti, self.anti_names, "anti"))

    # -- constructors --------------------------------------------------

    @classmethod
    def vacuous(cls, n: int) -> "TaskConstraints":
        """Constraints that constrain nothing (the identity element)."""
        return cls(
            deadline=np.full(n, -1, dtype=np.int64),
            affinity=np.full(n, -1, dtype=np.int64),
            anti_affinity=np.full(n, -1, dtype=np.int64),
            exclusive=np.zeros(n, dtype=bool),
            max_width=np.ones(n, dtype=np.int64),
            serial_frac=np.ones(n, dtype=np.float64))

    @classmethod
    def from_groups(cls, n: int, *, deadlines=None, affinity=None,
                    anti_affinity=None, exclusive=(),
                    widths=None) -> "TaskConstraints":
        """Build from named groups and per-task dicts.

        deadlines:     {task: inclusive finish slot}
        affinity:      {group name: iterable of task indices}
        anti_affinity: {group name: iterable of task indices}
        exclusive:     iterable of task indices
        widths:        {task: (max_width, serial_frac)}
        """
        c = cls.vacuous(n)
        dl, aff, anti = c.deadline, c.affinity, c.anti_affinity
        excl, mw, sf = c.exclusive, c.max_width, c.serial_frac
        for u, slot in (deadlines or {}).items():
            dl[u] = int(slot)
        aff_names, anti_names = [], []
        for names, ids_arr, groups in ((aff_names, aff, affinity),
                                       (anti_names, anti, anti_affinity)):
            for name, members in (groups or {}).items():
                gid = len(names)
                names.append(str(name))
                for u in members:
                    if ids_arr[u] >= 0:
                        raise ValueError(
                            f"task {u} belongs to two groups "
                            f"({names[ids_arr[u]]!r} and {name!r}); a "
                            f"task carries at most one group per kind")
                    ids_arr[u] = gid
        for u in exclusive:
            excl[u] = True
        for u, (w, f) in (widths or {}).items():
            mw[u], sf[u] = int(w), float(f)
        return cls(deadline=dl, affinity=aff, anti_affinity=anti,
                   exclusive=excl, max_width=mw, serial_frac=sf,
                   affinity_names=tuple(aff_names),
                   anti_names=tuple(anti_names))

    # -- queries -------------------------------------------------------

    @property
    def n(self) -> int:
        return self.deadline.shape[0]

    def is_vacuous(self) -> bool:
        """True when lowering would be the identity: no deadlines, no
        groups, no exclusivity, every task rigid."""
        return bool(
            (self.deadline < 0).all() and (self.affinity < 0).all()
            and (self.anti_affinity < 0).all()
            and not self.exclusive.any() and (self.max_width == 1).all())

    # -- row surgery (the serving loop's arrive/depart/constrain path) --

    def take(self, index) -> "TaskConstraints":
        """Constraints of a task subset (boolean mask or index array)."""
        return TaskConstraints(
            deadline=self.deadline[index],
            affinity=self.affinity[index],
            anti_affinity=self.anti_affinity[index],
            exclusive=self.exclusive[index],
            max_width=self.max_width[index],
            serial_frac=self.serial_frac[index],
            affinity_names=self.affinity_names,
            anti_names=self.anti_names)

    def extend(self, k: int) -> "TaskConstraints":
        """Append ``k`` unconstrained task rows."""
        fresh = TaskConstraints.vacuous(k)
        return TaskConstraints(
            deadline=np.concatenate([self.deadline, fresh.deadline]),
            affinity=np.concatenate([self.affinity, fresh.affinity]),
            anti_affinity=np.concatenate(
                [self.anti_affinity, fresh.anti_affinity]),
            exclusive=np.concatenate([self.exclusive, fresh.exclusive]),
            max_width=np.concatenate([self.max_width, fresh.max_width]),
            serial_frac=np.concatenate(
                [self.serial_frac, fresh.serial_frac]),
            affinity_names=self.affinity_names,
            anti_names=self.anti_names)

    def constrain(self, index, *, affinity: str | None = None,
                  anti_affinity: str | None = None,
                  exclusive: bool | None = None,
                  deadline: int | None = None) -> "TaskConstraints":
        """A copy with the given constraints applied to tasks ``index``
        (named groups are created on first use, joined thereafter)."""
        dl, aff, anti = (self.deadline.copy(), self.affinity.copy(),
                         self.anti_affinity.copy())
        excl = self.exclusive.copy()
        aff_names, anti_names = (list(self.affinity_names),
                                 list(self.anti_names))
        if deadline is not None:
            dl[index] = int(deadline)
        if affinity is not None:
            if affinity not in aff_names:
                aff_names.append(affinity)
            aff[index] = aff_names.index(affinity)
        if anti_affinity is not None:
            if anti_affinity not in anti_names:
                anti_names.append(anti_affinity)
            anti[index] = anti_names.index(anti_affinity)
        if exclusive is not None:
            excl[index] = bool(exclusive)
        return TaskConstraints(
            deadline=dl, affinity=aff, anti_affinity=anti,
            exclusive=excl, max_width=self.max_width.copy(),
            serial_frac=self.serial_frac.copy(),
            affinity_names=tuple(aff_names),
            anti_names=tuple(anti_names))


@dataclasses.dataclass(frozen=True)
class Lowering:
    """The result of ``lower_constraints``: the lowered instance plus
    everything needed to expand its solutions back to original tasks.

    original: the constrained input ``Problem`` (original task rows).
    lowered:  the plain ``Problem`` the engines solve (merged rows,
              possibly extra virtual dimensions; ``constraints=None``).
    row_of:   (n,) lowered row index of each original task.
    widths:   (n,) resolved widths (1 for rigid tasks).
    end_eff:  (n,) resolved inclusive finish slots on the ORIGINAL
              timeline (shrunk for widened tasks).
    identity: True when the lowering was a no-op (``lowered`` shares
              every array with — or IS — ``original``).
    """

    original: Problem
    lowered: Problem
    row_of: np.ndarray
    widths: np.ndarray
    end_eff: np.ndarray
    identity: bool


def _resolve_widths(problem: Problem, c: TaskConstraints):
    """(widths, end_eff): minimal width meeting each deadline, or
    ValueError when even ``max_width`` finishes late."""
    n = problem.n
    dur0 = problem.end - problem.start + 1
    widths = np.ones(n, dtype=np.int64)
    for u in np.flatnonzero(c.deadline >= 0):
        dl, s = int(c.deadline[u]), int(problem.start[u])
        if dl >= problem.T:
            raise ValueError(
                f"task {u} deadline {dl} lies beyond the horizon "
                f"T={problem.T} (slots are 0-based)")
        if dl < s:
            raise ValueError(
                f"task {u} deadline {dl} precedes its start slot {s}")
        cap_w = int(c.max_width[u])
        for w in range(1, cap_w + 1):
            fin = s + int(width_duration(dur0[u], w, c.serial_frac[u])) - 1
            if fin <= dl:
                widths[u] = w
                break
        else:
            fin = s + int(width_duration(dur0[u], cap_w,
                                         c.serial_frac[u])) - 1
            raise ValueError(
                f"task {u} cannot meet deadline {dl}: even at "
                f"max_width={cap_w} it finishes at slot {fin}; raise "
                f"max_width, lower serial_frac, or relax the deadline")
    end_eff = problem.start + width_duration(dur0, widths,
                                             c.serial_frac) - 1
    return widths, end_eff


def _check_contradictions(problem: Problem, c: TaskConstraints,
                          end_eff: np.ndarray) -> None:
    """Affinity ∩ anti-affinity with overlapping windows is
    unsatisfiable (must co-locate AND separate at once)."""
    for g in np.unique(c.affinity[c.affinity >= 0]):
        members = np.flatnonzero(c.affinity == g)
        for a in np.unique(c.anti_affinity[members]):
            if a < 0:
                continue
            both = members[c.anti_affinity[members] == a]
            for i, u in enumerate(both):
                for v in both[i + 1:]:
                    if (problem.start[u] <= end_eff[v]
                            and problem.start[v] <= end_eff[u]):
                        raise ValueError(
                            f"tasks {u} and {v} share affinity group "
                            f"{c.affinity_names[g]!r} AND anti-affinity "
                            f"group {c.anti_names[a]!r} with overlapping "
                            f"windows — they would have to co-locate "
                            f"and separate at once")


def lower_constraints(problem: Problem) -> Lowering:
    """Lower a (possibly constrained) instance to a plain ``Problem``.

    Vacuous or absent constraints take the identity fast path (the
    returned ``lowered`` IS the input, minus a dropped vacuous
    constraints field), which keeps unconstrained pipelines bit-stable.
    Active constraints produce a new instance per the module docstring;
    a merged super-task or widened task that no longer fits any
    node-type raises ``ValueError`` here with the group/task named,
    instead of a generic infeasibility later.
    """
    c = problem.constraints
    n = problem.n
    if c is None or n == 0 or c.is_vacuous():
        lowered = problem if c is None else dataclasses.replace(
            problem, constraints=None)
        return Lowering(
            original=problem, lowered=lowered,
            row_of=np.arange(n, dtype=np.int64),
            widths=np.ones(n, dtype=np.int64),
            end_eff=problem.end.copy(), identity=True)

    widths, end_eff = _resolve_widths(problem, c)
    _check_contradictions(problem, c, end_eff)
    dem_eff = problem.dem * widths[:, None].astype(np.float64)

    # affinity merge: one row per group (leader = lowest member index),
    # singleton rows for ungrouped tasks, rows ordered by leader
    row_of = np.empty(n, dtype=np.int64)
    row_members: list[list[int]] = []
    group_row: dict[int, int] = {}
    for u in range(n):
        g = int(c.affinity[u])
        if g >= 0 and g in group_row:
            row_of[u] = group_row[g]
            row_members[group_row[g]].append(u)
            continue
        row_of[u] = len(row_members)
        if g >= 0:
            group_row[g] = len(row_members)
        row_members.append([u])

    R, D = len(row_members), problem.D
    r_start = np.empty(R, dtype=np.int64)
    r_end = np.empty(R, dtype=np.int64)
    r_dem = np.zeros((R, D))
    r_excl = np.zeros(R, dtype=bool)
    anti_ids = np.unique(c.anti_affinity[c.anti_affinity >= 0])
    anti_col = {int(a): j for j, a in enumerate(anti_ids)}
    r_anti = np.zeros((R, len(anti_ids)))
    for r, members in enumerate(row_members):
        ms = np.asarray(members)
        s = int(problem.start[ms].min())
        e = int(end_eff[ms].max())
        acc = np.zeros((e - s + 1, D))
        for u in members:
            acc[problem.start[u] - s : end_eff[u] - s + 1] += dem_eff[u]
        r_start[r], r_end[r] = s, e
        r_dem[r] = acc.max(axis=0)  # peak-over-hull reservation
        r_excl[r] = bool(c.exclusive[ms].any())
        for u in members:
            a = int(c.anti_affinity[u])
            if a >= 0:
                r_anti[r, anti_col[a]] = 1.0

    # virtual unit-capacity dimensions: [exclusivity?] + one per anti
    # group, appended AFTER the merge so reservations never double-count
    nt = problem.node_types
    cols = [r_dem]
    vdims = 0
    if c.exclusive.any():
        cols.append(np.where(r_excl, 1.0, DELTA)[:, None])
        vdims += 1
    if len(anti_ids):
        cols.append(r_anti)
        vdims += len(anti_ids)
    new_dem = np.hstack(cols)
    new_cap = np.hstack([nt.cap, np.ones((nt.m, vdims))]) if vdims \
        else nt.cap
    new_nt = NodeTypes(cap=new_cap, cost=nt.cost, names=nt.names) \
        if vdims else nt

    fits = (new_dem[:, None, :] <= new_cap[None, :, :] + 1e-12
            ).all(axis=2).any(axis=1)
    for r in np.flatnonzero(~fits):
        members = row_members[r]
        if len(members) > 1:
            g = int(c.affinity[members[0]])
            raise ValueError(
                f"affinity group {c.affinity_names[g]!r} (tasks "
                f"{members}) reserves demand {r_dem[r].tolist()} at its "
                f"peak, which fits no node-type")
        u = members[0]
        raise ValueError(
            f"task {u} at resolved width {int(widths[u])} demands "
            f"{r_dem[r].tolist()}, which fits no node-type; its "
            f"deadline cannot be met by widening")

    lowered = Problem(dem=new_dem, start=r_start, end=r_end,
                      node_types=new_nt, T=problem.T)
    return Lowering(original=problem, lowered=lowered, row_of=row_of,
                    widths=widths, end_eff=end_eff, identity=False)


def expand_solution(lowering: Lowering, solution) -> "object":
    """Map a solution of ``lowering.lowered`` back to original tasks.

    Identity lowerings return the solution object unchanged (bit-stable
    unconstrained path).  Otherwise every original task inherits its
    merged row's node, and the resolved widths / effective finish slots
    ride in ``meta`` (the checker's inputs).  Works for solutions in
    trimmed coordinates too: trimming never reorders task rows, and
    node assignments are time-coordinate-free.
    """
    if lowering.identity:
        return solution
    from .solution import Solution

    return Solution(
        node_type=solution.node_type.copy(),
        assign=solution.assign[lowering.row_of],
        meta=dict(solution.meta, constrained=True,
                  widths=lowering.widths.copy(),
                  end_eff=lowering.end_eff.copy()))
