"""Lower bounds on cost(opt).

* ``lp_lowerbound``        — the LP objective (paper §V-B / §VI-A); the bound
                             all reported costs are normalized by.
* ``congestion_lowerbound``— Lemma 1: max_t sum_{u ~ t} p*(u); cheap,
                             solver-free, used for sanity cross-checks
                             (always <= the LP bound's quality, never above
                             cost(opt)).
* ``no_timeline_lowerbound``— the §VI-F comparator: the same LP bound after
                             making every task perpetually active (T = 1),
                             i.e. the cost floor a timeline-agnostic
                             rightsizer cannot beat.
"""

from __future__ import annotations

import numpy as np

from .problem import Problem
from .penalty import min_penalty
from .problem import active_mask, trim_timeline

__all__ = [
    "congestion_lowerbound",
    "lp_lowerbound",
    "no_timeline_lowerbound",
]


def congestion_lowerbound(problem: Problem) -> float:
    """Lemma 1: cost(opt) >= max_t sum_{u ~ t} p_avg*(u)."""
    if problem.n == 0:
        return 0.0
    p_star = min_penalty(problem, "avg")  # (n,)
    trimmed, _ = trim_timeline(problem)
    act = active_mask(trimmed)  # (n, T')
    per_slot = p_star @ act  # (T',)
    return float(per_slot.max())


def lp_lowerbound(problem: Problem) -> float:
    from .lp_map import solve_lp

    return solve_lp(problem).objective


def no_timeline_lowerbound(problem: Problem) -> float:
    """LP lower bound of the always-active (T=1) relaxation-to-Rightsizing."""
    flat = Problem(
        dem=problem.dem,
        start=np.zeros(problem.n, dtype=np.int64),
        end=np.zeros(problem.n, dtype=np.int64),
        node_types=problem.node_types,
        T=1,
    )
    return lp_lowerbound(flat)
