"""TL-Rightsizing problem definition (paper §II).

An instance consists of ``n`` tasks, each with a ``D``-dimensional demand
vector and an active interval ``[start, end]`` (inclusive, 0-based) on a
discrete timeline of ``T`` slots, plus ``m`` node-types with capacity
vectors and prices.  A feasible solution purchases nodes (replicas of
node-types) and places every task on a node such that at every timeslot and
along every dimension the aggregate demand of active co-located tasks does
not exceed the node capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "NodeTypes",
    "Problem",
    "trim_timeline",
    "active_mask",
    "feasible_types",
    "require_lowered",
]


@dataclasses.dataclass(frozen=True)
class NodeTypes:
    """The catalogue of purchasable node-types.

    cap:  (m, D) capacities, cap[B, d] > 0.
    cost: (m,)   prices, cost[B] > 0.
    names: optional display names.
    """

    cap: np.ndarray
    cost: np.ndarray
    names: tuple[str, ...] = ()

    def __post_init__(self):
        cap = np.asarray(self.cap, dtype=np.float64)
        cost = np.asarray(self.cost, dtype=np.float64)
        object.__setattr__(self, "cap", cap)
        object.__setattr__(self, "cost", cost)
        if cap.ndim != 2:
            raise ValueError(f"cap must be (m, D), got {cap.shape}")
        if cost.shape != (cap.shape[0],):
            raise ValueError(f"cost must be (m,), got {cost.shape}")
        if not (cap > 0).all():
            raise ValueError("all capacities must be positive")
        if not (cost > 0).all():
            raise ValueError("all costs must be positive")
        if not self.names:
            object.__setattr__(
                self, "names", tuple(f"type{i}" for i in range(cap.shape[0]))
            )

    @property
    def m(self) -> int:
        return self.cap.shape[0]

    @property
    def D(self) -> int:
        return self.cap.shape[1]

    def capacity_per_cost(self) -> np.ndarray:
        """sum_d cap(B, d) / cost(B) — the cross-fill ordering key (§V-D)."""
        return self.cap.sum(axis=1) / self.cost


@dataclasses.dataclass(frozen=True)
class Problem:
    """A TL-Rightsizing instance.

    dem:   (n, D) demands, dem[u, d] >= 0.
    start: (n,)   0-based inclusive start slots.
    end:   (n,)   0-based inclusive end slots, end >= start.
    node_types: the catalogue.
    T: number of timeslots (end < T).
    constraints: optional ``repro.core.constraints.TaskConstraints``
        (deadlines, affinity groups, exclusivity, malleable width).
        The LP/placement stack consumes only *lowered* instances —
        ``lower_constraints`` turns a constrained Problem into a plain
        one; ``require_lowered`` guards the solver entry points.
    """

    dem: np.ndarray
    start: np.ndarray
    end: np.ndarray
    node_types: NodeTypes
    T: int
    constraints: object | None = None

    def __post_init__(self):
        dem = np.asarray(self.dem, dtype=np.float64)
        start = np.asarray(self.start, dtype=np.int64)
        end = np.asarray(self.end, dtype=np.int64)
        object.__setattr__(self, "dem", dem)
        object.__setattr__(self, "start", start)
        object.__setattr__(self, "end", end)
        n = dem.shape[0]
        if dem.ndim != 2 or dem.shape[1] != self.node_types.D:
            raise ValueError(
                f"dem must be (n, {self.node_types.D}), got {dem.shape}"
            )
        if start.shape != (n,) or end.shape != (n,):
            raise ValueError("start/end must be (n,)")
        if n and ((start < 0).any() or (end >= self.T).any()):
            raise ValueError("spans must lie in [0, T)")
        if n and (end < start).any():
            raise ValueError("end must be >= start")
        if (dem < 0).any():
            raise ValueError("demands must be non-negative")
        if self.constraints is not None and self.constraints.n != n:
            raise ValueError(
                f"constraints cover {self.constraints.n} tasks but the "
                f"instance has {n}"
            )

    @property
    def n(self) -> int:
        return self.dem.shape[0]

    @property
    def m(self) -> int:
        return self.node_types.m

    @property
    def D(self) -> int:
        return self.node_types.D

    def spans(self) -> np.ndarray:
        return np.stack([self.start, self.end], axis=1)


def feasible_types(problem: Problem) -> np.ndarray:
    """(n, m) bool: task u fits an *empty* node of type B along every
    dimension.  Mappings must only use feasible pairs; an instance where
    some task fits no type at all has no feasible solution."""
    ok = (
        problem.dem[:, None, :] <= problem.node_types.cap[None, :, :] + 1e-12
    ).all(axis=2)
    bad = ~ok.any(axis=1)
    if bad.any():
        raise ValueError(
            f"infeasible instance: tasks {np.flatnonzero(bad)[:5]}... fit no node-type"
        )
    return ok


def active_mask(problem: Problem, slots: Sequence[int] | None = None) -> np.ndarray:
    """Boolean (n, |slots|) mask: task u active at slot t (paper's ``u ~ t``)."""
    t = np.arange(problem.T) if slots is None else np.asarray(slots)
    return (problem.start[:, None] <= t[None, :]) & (t[None, :] <= problem.end[:, None])


def require_lowered(problem: Problem, where: str) -> None:
    """Reject instances carrying *active* constraints.

    The LP and placement stack understands only plain instances; a
    constrained ``Problem`` must go through
    ``repro.core.constraints.lower_constraints`` first (the public
    entry points — ``rightsize``, ``evaluate``, ``FleetEngine``, the
    serving loop — all do).  Vacuous constraints are harmless and
    pass through.
    """
    c = problem.constraints
    if c is not None and not c.is_vacuous():
        raise ValueError(
            f"{where} received a Problem with active constraints; lower "
            f"it first with repro.core.constraints.lower_constraints "
            f"(the rightsize/evaluate/FleetEngine entry points do this "
            f"automatically)"
        )


def trim_timeline(problem: Problem) -> tuple[Problem, np.ndarray]:
    """Timeline trimming (paper §II): keep only task start slots.

    Congestion on a node can only increase at a task start, so checking
    capacity at start slots is equivalent to checking everywhere.  Returns
    the trimmed problem (T' <= n slots) and the array of original slot ids
    (one per trimmed slot) for mapping back.

    Task spans are remapped to trimmed coordinates: the new start is the
    rank of the old start (which is always a kept slot) and the new end is
    the rank of the last kept slot <= old end.

    Active constraints must be lowered before trimming (ValueError
    otherwise); vacuous constraints are silently dropped — the trimmed
    instance is plain either way.
    """
    require_lowered(problem, "trim_timeline")
    if problem.n == 0:
        return problem, np.zeros(0, dtype=np.int64)
    kept = np.unique(problem.start)
    # rank of each original start slot
    new_start = np.searchsorted(kept, problem.start)
    # last kept slot <= end  ->  searchsorted(side='right') - 1
    new_end = np.searchsorted(kept, problem.end, side="right") - 1
    # every task is active at its own start, so new_end >= new_start always
    trimmed = Problem(
        dem=problem.dem,
        start=new_start,
        end=new_end,
        node_types=problem.node_types,
        T=len(kept),
    )
    return trimmed, kept
