"""High-level TL-Rightsizing API.

``rightsize(problem, algo)`` runs one named algorithm; ``evaluate(problem)``
reproduces the paper's §VI protocol:

  * PenaltyMap    — min cost over {h_avg, h_max} x {first, similarity}
  * PenaltyMap-F  — same four combos with cross-node-type filling
  * LP-map        — LP mapping, min over {first, similarity}
  * LP-map-F      — LP mapping + filling, min over {first, similarity}

``evaluate_many(problems)`` runs the protocol over a whole instance grid
fully batched (the fleet-sweep path): the mapping LPs of all instances
are packed and solved together by ``core.batch.solve_lp_many`` —
tolerance-stopped by the adaptive restarted engine with ``lp_tol``, and
warm-started between grid-adjacent sweep groups with ``warm_start=k`` —
and the greedy placement phase advances all instances in lockstep
through ``core.place_batch.place_many`` (``placement='loop'`` restores
the per-instance placement loop; costs are identical either way).

All problems are timeline-trimmed internally; solutions are expressed (and
verified) in trimmed coordinates, which preserves feasibility and cost
exactly (paper §II).
"""

from __future__ import annotations

import time

from .problem import Problem, trim_timeline
from .penalty import penalty_map
from .placement import two_phase, FIT_POLICIES
from .solution import Solution, verify
from .lp_map import solve_lp as _solve_lp

__all__ = ["rightsize", "evaluate", "evaluate_many", "ALGORITHMS"]

ALGORITHMS = ("penalty-map", "penalty-map-f", "lp-map", "lp-map-f")
# beyond-paper: any algorithm + node-elimination local search ("+ls")
EXTENDED_ALGORITHMS = ALGORITHMS + ("lp-map-f+ls", "penalty-map-f+ls")


def _penalty_solutions(problem: Problem, filling: bool, backend: str):
    for kind in ("avg", "max"):
        mapping = penalty_map(problem, kind)
        for fit in FIT_POLICIES:
            yield two_phase(
                problem, mapping, fit=fit, filling=filling, backend=backend,
                meta={"algo": "penalty-map" + ("-f" if filling else ""),
                      "h": kind},
            )


def _lp_solutions(problem: Problem, filling: bool, backend: str,
                  lp_result=None):
    res = lp_result if lp_result is not None else _solve_lp(problem)
    for fit in FIT_POLICIES:
        sol = two_phase(
            problem, res.mapping, fit=fit, filling=filling, backend=backend,
            meta={"algo": "lp-map" + ("-f" if filling else ""),
                  "lp_objective": res.objective},
        )
        yield sol


def rightsize(
    problem: Problem,
    algo: str = "lp-map-f",
    backend: str = "numpy",
    check: bool = True,
    lp_result=None,
) -> Solution:
    """Solve one instance with one algorithm, taking the best fit policy
    (and, for PenaltyMap, the best relative-demand kind) per the paper."""
    trimmed, _ = trim_timeline(problem)
    t0 = time.perf_counter()
    local_search = algo.endswith("+ls")
    if local_search:
        algo = algo[: -len("+ls")]
    if algo == "penalty-map":
        sols = _penalty_solutions(trimmed, filling=False, backend=backend)
    elif algo == "penalty-map-f":
        sols = _penalty_solutions(trimmed, filling=True, backend=backend)
    elif algo == "lp-map":
        sols = _lp_solutions(trimmed, filling=False, backend=backend,
                             lp_result=lp_result)
    elif algo == "lp-map-f":
        sols = _lp_solutions(trimmed, filling=True, backend=backend,
                             lp_result=lp_result)
    else:
        raise ValueError(f"unknown algo {algo!r}; want one of {ALGORITHMS}")
    best = min(sols, key=lambda s: s.cost(trimmed))
    if local_search:
        from .local_search import eliminate_nodes

        best = eliminate_nodes(trimmed, best)
    best.meta["wall_s"] = time.perf_counter() - t0
    if check:
        verify(trimmed, best)
    return best


def _solve_lp_for(problem: Problem, lp_solver: str, lp_iters: int,
                  lp_tol: float | None = None):
    """(lp_result, certified lower bound) for one instance."""
    if lp_solver == "highs":
        res = _solve_lp(problem)
        return res, res.objective
    if lp_solver == "pdhg":
        from .lp_pdhg import solve_lp_pdhg

        res = solve_lp_pdhg(problem, iters=lp_iters, tol=lp_tol)
        return res, res.lower_bound
    raise ValueError(f"unknown lp_solver {lp_solver!r}; want 'highs'|'pdhg'")


def _protocol_entry(trimmed: Problem, lp_result, lb: float, algos,
                    backend: str) -> dict:
    out: dict = {"lb": lb, "costs": {}, "normalized": {}, "wall_s": {}}
    for algo in algos:
        sol = rightsize(trimmed, algo, backend=backend, lp_result=lp_result)
        cost = sol.cost(trimmed)
        out["costs"][algo] = cost
        out["normalized"][algo] = cost / max(lb, 1e-12)
        out["wall_s"][algo] = sol.meta["wall_s"]
    return out


def evaluate(problem: Problem, algos=ALGORITHMS, backend: str = "numpy",
             lp_solver: str = "highs", lp_iters: int = 2000,
             lp_tol: float | None = None) -> dict:
    """Paper §VI protocol: per-algorithm best cost + the LP lower bound.

    ``lp_solver='highs'`` solves the mapping LP exactly (the paper's
    setup); ``'pdhg'`` uses the accelerator-native solver, normalizing by
    its certified dual lower bound instead of the exact LP optimum.
    ``lp_tol`` switches the PDHG solve to tolerance-based stopping
    (adaptive restarted engine; ``lp_iters`` caps the worst case).

    Returns {algo: cost, ..., 'lb': lowerbound, 'normalized': {algo: cost/lb}}.
    """
    trimmed, _ = trim_timeline(problem)
    lp_result, lb = _solve_lp_for(trimmed, lp_solver, lp_iters, lp_tol)
    return _protocol_entry(trimmed, lp_result, lb, algos, backend)


def _protocol_many(batch, lp_results, algos, backend: str,
                   check: bool = True) -> list[dict]:
    """Batched placement protocol: every (mapping, fit, filling) combo of
    every algorithm runs as ONE lockstep ``place_many`` over the grid."""
    from .place_batch import place_many

    B = batch.B
    out = [{"lb": res.lower_bound, "costs": {}, "normalized": {},
            "wall_s": {}} for res in lp_results]
    for algo in algos:
        t0 = time.perf_counter()
        filling = algo.endswith("-f")
        if algo in ("penalty-map", "penalty-map-f"):
            mapsets = [[penalty_map(t, kind) for t in batch.problems]
                       for kind in ("avg", "max")]
        elif algo in ("lp-map", "lp-map-f"):
            mapsets = [[res.mapping for res in lp_results]]
        else:
            # extended algos (e.g. "+ls") keep the per-instance path
            for b, t in enumerate(batch.problems):
                sol = rightsize(t, algo, backend=backend,
                                lp_result=lp_results[b], check=check)
                out[b]["costs"][algo] = sol.cost(t)
                out[b]["wall_s"][algo] = sol.meta["wall_s"]
            continue
        best: list[Solution | None] = [None] * B
        best_cost = [float("inf")] * B
        for maps in mapsets:
            for fit in FIT_POLICIES:
                sols = place_many(batch, maps, fit=fit, filling=filling,
                                  backend=backend, meta={"algo": algo})
                for b, (t, s) in enumerate(zip(batch.problems, sols)):
                    c = s.cost(t)
                    if c < best_cost[b]:
                        best_cost[b], best[b] = c, s
        wall = (time.perf_counter() - t0) / B
        for b, t in enumerate(batch.problems):
            if check:
                verify(t, best[b])
            out[b]["costs"][algo] = best_cost[b]
            out[b]["wall_s"][algo] = wall
    for entry in out:
        lb = max(entry["lb"], 1e-12)
        entry["normalized"] = {a: c / lb
                               for a, c in entry["costs"].items()}
    return out


def evaluate_many(problems, algos=ALGORITHMS, backend: str = "numpy",
                  lp_iters: int = 2000, operator: str = "auto",
                  placement: str = "batched",
                  lp_tol: float | None = None,
                  lp_adaptive: bool = True, lp_restart: bool = True,
                  warm_start: int = 0,
                  return_stats: bool = False):
    """§VI protocol over a grid of instances, fully batched.

    Equivalent to ``[evaluate(p, algos, lp_solver='pdhg') for p in
    problems]`` — the batched engines pad ragged instances exactly, so
    costs match the per-instance loop — but the LP phase is a single
    compiled ``solve_lp_many`` call for the whole grid, and (with
    ``placement='batched'``, the default) the greedy placement phase
    advances all instances in lockstep through ``place_many``: one
    batched feasibility+similarity scoring pass per task event instead
    of B Python-level ``find_fit`` loops.  ``placement='loop'`` restores
    the per-instance placement loop; placements (and therefore costs)
    are identical either way.

    ``lp_tol=None`` (default) keeps the fixed-``lp_iters`` vanilla
    solve.  With ``lp_tol`` set the LP phase runs the adaptive restarted
    engine until every instance's normalized duality gap is below the
    tolerance (``lp_iters`` caps the worst case; ``lp_adaptive`` /
    ``lp_restart`` ablate the step-size and restart machinery), and each
    returned entry carries a ``'solver'`` telemetry dict — iterations-
    to-tolerance, restarts, final KKT residual, converged flag.

    ``warm_start=k`` treats ``problems`` as a sweep in grid-adjacent
    order (the order ``workload.sweep_specs`` emits) split into
    consecutive groups of k — one sweep point's seed replicas each — and
    solves the LP phase as a warm-started chain (``solve_lp_sweep``):
    every group starts from its predecessor's primal/dual solution.
    Requires ``lp_tol`` (warm starts only pay off with tolerance-based
    stopping).  ``return_stats=True`` additionally returns the
    ``SolveStats`` list (one per batched solve).
    """
    from .batch import (ProblemBatch, pack_problems, solve_lp_many,
                        solve_lp_sweep)

    if placement not in ("loop", "batched"):
        raise ValueError(
            f"placement must be 'loop'|'batched', got {placement!r}")
    if warm_start and lp_tol is None:
        raise ValueError("warm_start requires lp_tol (tolerance-stopped "
                         "solves); fixed-iteration solves gain nothing "
                         "from a warm start")
    batch = problems if isinstance(problems, ProblemBatch) \
        else pack_problems(problems)  # trims each instance once
    if warm_start:
        groups = [batch.problems[i : i + warm_start]
                  for i in range(0, batch.B, warm_start)]
        lp_results, stats = solve_lp_sweep(
            groups, tol=lp_tol, iters=lp_iters, operator=operator,
            adaptive=lp_adaptive, restart=lp_restart)
    elif lp_tol is not None:
        lp_results, st = solve_lp_many(
            batch, iters=lp_iters, operator=operator, tol=lp_tol,
            adaptive=lp_adaptive, restart=lp_restart, full_output=True)
        stats = [st]
    else:
        lp_results = solve_lp_many(batch, iters=lp_iters,
                                   operator=operator)
        stats = []
    if placement == "batched":
        entries = _protocol_many(batch, lp_results, algos, backend)
    else:
        entries = [
            _protocol_entry(t, res, res.lower_bound, algos, backend)
            for t, res in zip(batch.problems, lp_results)
        ]
    if lp_tol is not None:
        for entry, res in zip(entries, lp_results):
            entry["solver"] = {"iters": res.iters,
                               "restarts": res.restarts,
                               "kkt": res.kkt,
                               "converged": res.converged}
    if return_stats:
        return entries, stats
    return entries
