"""High-level TL-Rightsizing API (single-instance calls + legacy shims).

``rightsize(problem, algo)`` runs one named algorithm; ``evaluate(problem)``
reproduces the paper's §VI protocol:

  * PenaltyMap    — min cost over {h_avg, h_max} x {first, similarity}
  * PenaltyMap-F  — same four combos with cross-node-type filling
  * LP-map        — LP mapping, min over {first, similarity}
  * LP-map-F      — LP mapping + filling, min over {first, similarity}

The fleet-scale surface lives in ``core.engine``: a ``FleetEngine``
session configured by frozen dataclasses (``SolverConfig`` /
``PlacementConfig`` / ``SweepConfig``) packs a whole instance grid into
shape buckets, solves every mapping LP batched, advances every greedy
placement in lockstep, and returns a structured ``FleetResult``.
``evaluate_many`` in this module is kept as a thin kwarg shim over that
engine — it maps the legacy keyword arguments onto the typed configs
one-to-one, always runs single-bucket (so the committed golden tables
stay bit-identical), and returns the legacy list-of-entry-dicts.  New
code should construct a ``FleetEngine`` directly.

All problems are timeline-trimmed internally; solutions are expressed (and
verified) in trimmed coordinates, which preserves feasibility and cost
exactly (paper §II).
"""

from __future__ import annotations

import time
import warnings

from .constraints import expand_solution, lower_constraints
from .problem import Problem, trim_timeline
from .penalty import penalty_map
from .placement import two_phase, FIT_POLICIES
from .solution import Solution, verify
from .lp_map import solve_lp as _solve_lp

__all__ = ["rightsize", "evaluate", "evaluate_many", "ALGORITHMS"]

ALGORITHMS = ("penalty-map", "penalty-map-f", "lp-map", "lp-map-f")
# beyond-paper: any algorithm + node-elimination local search ("+ls")
EXTENDED_ALGORITHMS = ALGORITHMS + ("lp-map-f+ls", "penalty-map-f+ls")


def _penalty_solutions(problem: Problem, filling: bool, backend: str):
    for kind in ("avg", "max"):
        mapping = penalty_map(problem, kind)
        for fit in FIT_POLICIES:
            yield two_phase(
                problem, mapping, fit=fit, filling=filling, backend=backend,
                meta={"algo": "penalty-map" + ("-f" if filling else ""),
                      "h": kind},
            )


def _lp_solutions(problem: Problem, filling: bool, backend: str,
                  lp_result=None):
    res = lp_result if lp_result is not None else _solve_lp(problem)
    for fit in FIT_POLICIES:
        sol = two_phase(
            problem, res.mapping, fit=fit, filling=filling, backend=backend,
            meta={"algo": "lp-map" + ("-f" if filling else ""),
                  "lp_objective": res.objective},
        )
        yield sol


def rightsize(
    problem: Problem,
    algo: str = "lp-map-f",
    backend: str = "numpy",
    check: bool = True,
    lp_result=None,
) -> Solution:
    """Solve one instance with one algorithm, taking the best fit policy
    (and, for PenaltyMap, the best relative-demand kind) per the paper.

    Constrained instances (``problem.constraints``) are lowered first
    (``repro.core.constraints``); the returned solution is expanded
    back to original task rows, and under ``check=True`` it is also
    validated against the ORIGINAL constraint semantics by the
    independent ``repro.core.checker`` oracle."""
    low = lower_constraints(problem)
    trimmed, _ = trim_timeline(low.lowered)
    t0 = time.perf_counter()
    local_search = algo.endswith("+ls")
    if local_search:
        algo = algo[: -len("+ls")]
    if algo == "penalty-map":
        sols = _penalty_solutions(trimmed, filling=False, backend=backend)
    elif algo == "penalty-map-f":
        sols = _penalty_solutions(trimmed, filling=True, backend=backend)
    elif algo == "lp-map":
        sols = _lp_solutions(trimmed, filling=False, backend=backend,
                             lp_result=lp_result)
    elif algo == "lp-map-f":
        sols = _lp_solutions(trimmed, filling=True, backend=backend,
                             lp_result=lp_result)
    else:
        raise ValueError(f"unknown algo {algo!r}; want one of {ALGORITHMS}")
    best = min(sols, key=lambda s: s.cost(trimmed))
    if local_search:
        from .local_search import eliminate_nodes

        best = eliminate_nodes(trimmed, best)
    best.meta["wall_s"] = time.perf_counter() - t0
    if check:
        verify(trimmed, best)
    best = expand_solution(low, best)
    if check and not low.identity:
        from .checker import assert_feasible

        assert_feasible(problem, best)
    return best


def _solve_lp_for(problem: Problem, lp_solver: str, lp_iters: int,
                  lp_tol: float | None = None):
    """(lp_result, certified lower bound) for one instance."""
    if lp_solver == "highs":
        res = _solve_lp(problem)
        return res, res.objective
    if lp_solver == "pdhg":
        from .lp_pdhg import solve_lp_pdhg

        res = solve_lp_pdhg(problem, iters=lp_iters, tol=lp_tol)
        return res, res.lower_bound
    raise ValueError(f"unknown lp_solver {lp_solver!r}; want 'highs'|'pdhg'")


def _protocol_entry(trimmed: Problem, lp_result, lb: float, algos,
                    backend: str) -> dict:
    out: dict = {"lb": lb, "costs": {}, "normalized": {}, "wall_s": {}}
    for algo in algos:
        sol = rightsize(trimmed, algo, backend=backend, lp_result=lp_result)
        cost = sol.cost(trimmed)
        out["costs"][algo] = cost
        out["normalized"][algo] = cost / max(lb, 1e-12)
        out["wall_s"][algo] = sol.meta["wall_s"]
    return out


def evaluate(problem: Problem, algos=ALGORITHMS, backend: str = "numpy",
             lp_solver: str = "highs", lp_iters: int = 2000,
             lp_tol: float | None = None) -> dict:
    """Paper §VI protocol: per-algorithm best cost + the LP lower bound.

    ``lp_solver='highs'`` solves the mapping LP exactly (the paper's
    setup); ``'pdhg'`` uses the accelerator-native solver, normalizing by
    its certified dual lower bound instead of the exact LP optimum.
    ``lp_tol`` switches the PDHG solve to tolerance-based stopping
    (adaptive restarted engine; ``lp_iters`` caps the worst case).

    Returns {algo: cost, ..., 'lb': lowerbound, 'normalized': {algo: cost/lb}}.

    Constrained instances are lowered first; costs (and the lower
    bound) are those of the lowered instance, whose affinity rows
    reserve peak-over-hull demand — a conservative relaxation, so the
    reported ``lb`` may exceed the true constrained optimum's LP bound.
    """
    low = lower_constraints(problem)
    trimmed, _ = trim_timeline(low.lowered)
    lp_result, lb = _solve_lp_for(trimmed, lp_solver, lp_iters, lp_tol)
    return _protocol_entry(trimmed, lp_result, lb, algos, backend)


_UNSET = object()  # sentinel: distinguishes "kwarg passed" from default

# legacy kwarg -> the typed-config equivalent named in the deprecation
# warning (behavior is bit-stable either way; only the spelling moves)
_LEGACY_KWARGS = {
    "backend": "PlacementConfig(backend=...)",
    "lp_iters": "SolverConfig(iters=...)",
    "operator": "SolverConfig(operator=...)",
    "placement": "PlacementConfig(engine=...)",
    "lp_tol": "SolverConfig(tol=...)",
    "lp_adaptive": "SolverConfig(adaptive=...)",
    "lp_restart": "SolverConfig(restart=...)",
    "warm_start": "SweepConfig(warm_start=...)",
    "return_stats": "FleetEngine.evaluate(...).stats on the FleetResult",
}

_LEGACY_DEFAULTS = {
    "backend": "numpy", "lp_iters": 2000, "operator": "auto",
    "placement": "batched", "lp_tol": None, "lp_adaptive": True,
    "lp_restart": True, "warm_start": None, "return_stats": False,
}


def evaluate_many(problems, algos=ALGORITHMS, backend=_UNSET,
                  lp_iters=_UNSET, operator=_UNSET,
                  placement=_UNSET,
                  lp_tol=_UNSET,
                  lp_adaptive=_UNSET, lp_restart=_UNSET,
                  warm_start=_UNSET,
                  return_stats=_UNSET):
    """§VI protocol over a grid of instances, fully batched — the
    **legacy kwarg shim** over ``core.engine.FleetEngine``.

    .. deprecated::
        The kwarg surface is deprecated: passing any of the legacy
        keywords emits a ``DeprecationWarning`` naming its typed-config
        equivalent (``SolverConfig`` / ``PlacementConfig`` /
        ``SweepConfig``).  Behavior is bit-stable — only the spelling
        moves to ``FleetEngine``.

    Equivalent to ``[evaluate(p, algos, lp_solver='pdhg') for p in
    problems]`` — the batched engines pad ragged instances exactly, so
    costs match the per-instance loop — but the LP phase is a single
    compiled ``solve_lp_many`` call for the whole grid, and (with
    ``placement='batched'``, the default) the greedy placement phase
    advances all instances in lockstep through ``place_many``.
    ``placement='compiled'`` routes that lockstep through the on-device
    ``lax.scan`` stepper (``core.place_step``; one device dispatch per
    node-type phase instead of one numpy dispatch per step), and
    ``placement='loop'`` restores the per-instance placement loop;
    placements (and therefore costs) are identical all three ways.

    Every kwarg maps onto one typed-config field (see the README
    migration table): ``lp_iters/operator/lp_tol/lp_adaptive/lp_restart``
    -> ``SolverConfig``, ``placement/backend`` -> ``PlacementConfig``,
    ``warm_start`` -> ``SweepConfig``.  The shim always runs
    single-bucket (``SweepConfig(max_buckets=1)``) so the committed
    golden tables stay bit-identical; shape-bucketed packing of very
    ragged grids is a ``FleetEngine`` feature
    (``SweepConfig(max_buckets=k)``).

    ``lp_tol=None`` (default) keeps the fixed-``lp_iters`` vanilla
    solve.  With ``lp_tol`` set the LP phase runs the adaptive restarted
    engine until every instance's normalized duality gap is below the
    tolerance (``lp_iters`` caps the worst case; ``lp_adaptive`` /
    ``lp_restart`` ablate the step-size and restart machinery), and each
    returned entry carries a ``'solver'`` telemetry dict — iterations-
    to-tolerance, restarts, final KKT residual, converged flag.

    ``warm_start=k`` treats ``problems`` as a sweep in grid-adjacent
    order (the order ``workload.sweep_specs`` emits) split into
    consecutive groups of k — one sweep point's seed replicas each — and
    solves the LP phase as a warm-started chain (``solve_lp_sweep``):
    every group starts from its predecessor's primal/dual solution.
    Requires ``lp_tol`` (warm starts only pay off with tolerance-based
    stopping).  ``warm_start=None`` (default) disables chaining; a
    non-positive k raises ``ValueError`` rather than being treated as
    falsy "off".  When k does not divide the grid size the trailing
    group is smaller and cold-starts (its lanes no longer align with
    the predecessor state) — costs are unaffected, only that group's
    iteration telemetry loses the warm-start advantage.
    ``return_stats=True`` additionally returns the ``SolveStats`` list
    (one per batched solve / warm-started group).

    >>> from repro.workload import SyntheticSpec, synthetic_instance
    >>> grid = [synthetic_instance(SyntheticSpec(n=8, m=2, D=2, T=5,
    ...                                          seed=s))
    ...         for s in (0, 1)]
    >>> entries = evaluate_many(grid, algos=("penalty-map",),
    ...                         lp_iters=30)
    >>> sorted(entries[0])
    ['costs', 'lb', 'normalized', 'wall_s']
    >>> list(entries[1]["costs"])
    ['penalty-map']
    """
    from .engine import (FleetEngine, PlacementConfig, SolverConfig,
                         SweepConfig)

    passed = {name: val for name, val in [
        ("backend", backend), ("lp_iters", lp_iters),
        ("operator", operator), ("placement", placement),
        ("lp_tol", lp_tol), ("lp_adaptive", lp_adaptive),
        ("lp_restart", lp_restart), ("warm_start", warm_start),
        ("return_stats", return_stats)] if val is not _UNSET}
    if passed:
        hints = "; ".join(f"{k} -> {_LEGACY_KWARGS[k]}" for k in passed)
        warnings.warn(
            f"the evaluate_many kwarg surface is deprecated; build a "
            f"FleetEngine with the typed configs instead ({hints})",
            DeprecationWarning, stacklevel=2)
    resolved = dict(_LEGACY_DEFAULTS, **passed)
    backend, lp_iters, operator, placement, lp_tol, lp_adaptive, \
        lp_restart, warm_start, return_stats = (
            resolved[k] for k in ("backend", "lp_iters", "operator",
                                  "placement", "lp_tol", "lp_adaptive",
                                  "lp_restart", "warm_start",
                                  "return_stats"))

    sweep = SweepConfig(warm_start=warm_start)  # rejects warm_start <= 0
    if warm_start is not None and lp_tol is None:
        raise ValueError("warm_start requires lp_tol (tolerance-stopped "
                         "solves); fixed-iteration solves gain nothing "
                         "from a warm start")
    engine = FleetEngine(
        solver=SolverConfig(tol=lp_tol, iters=lp_iters,
                            adaptive=lp_adaptive, restart=lp_restart,
                            operator=operator),
        placement=PlacementConfig(engine=placement, backend=backend),
        sweep=sweep,
        algos=algos,
    )
    result = engine.evaluate(problems)
    if return_stats:
        return result.entries, result.stats
    return result.entries
