"""High-level TL-Rightsizing API.

``rightsize(problem, algo)`` runs one named algorithm; ``evaluate(problem)``
reproduces the paper's §VI protocol:

  * PenaltyMap    — min cost over {h_avg, h_max} x {first, similarity}
  * PenaltyMap-F  — same four combos with cross-node-type filling
  * LP-map        — LP mapping, min over {first, similarity}
  * LP-map-F      — LP mapping + filling, min over {first, similarity}

All problems are timeline-trimmed internally; solutions are expressed (and
verified) in trimmed coordinates, which preserves feasibility and cost
exactly (paper §II).
"""

from __future__ import annotations

import time

import numpy as np

from .problem import Problem, trim_timeline
from .penalty import penalty_map
from .placement import two_phase, FIT_POLICIES
from .solution import Solution, verify
from .lp_map import solve_lp as _solve_lp

__all__ = ["rightsize", "evaluate", "ALGORITHMS"]

ALGORITHMS = ("penalty-map", "penalty-map-f", "lp-map", "lp-map-f")
# beyond-paper: any algorithm + node-elimination local search ("+ls")
EXTENDED_ALGORITHMS = ALGORITHMS + ("lp-map-f+ls", "penalty-map-f+ls")


def _penalty_solutions(problem: Problem, filling: bool, backend: str):
    for kind in ("avg", "max"):
        mapping = penalty_map(problem, kind)
        for fit in FIT_POLICIES:
            yield two_phase(
                problem, mapping, fit=fit, filling=filling, backend=backend,
                meta={"algo": "penalty-map" + ("-f" if filling else ""),
                      "h": kind},
            )


def _lp_solutions(problem: Problem, filling: bool, backend: str,
                  lp_result=None):
    res = lp_result if lp_result is not None else _solve_lp(problem)
    for fit in FIT_POLICIES:
        sol = two_phase(
            problem, res.mapping, fit=fit, filling=filling, backend=backend,
            meta={"algo": "lp-map" + ("-f" if filling else ""),
                  "lp_objective": res.objective},
        )
        yield sol


def rightsize(
    problem: Problem,
    algo: str = "lp-map-f",
    backend: str = "numpy",
    check: bool = True,
    lp_result=None,
) -> Solution:
    """Solve one instance with one algorithm, taking the best fit policy
    (and, for PenaltyMap, the best relative-demand kind) per the paper."""
    trimmed, _ = trim_timeline(problem)
    t0 = time.perf_counter()
    local_search = algo.endswith("+ls")
    if local_search:
        algo = algo[: -len("+ls")]
    if algo == "penalty-map":
        sols = _penalty_solutions(trimmed, filling=False, backend=backend)
    elif algo == "penalty-map-f":
        sols = _penalty_solutions(trimmed, filling=True, backend=backend)
    elif algo == "lp-map":
        sols = _lp_solutions(trimmed, filling=False, backend=backend,
                             lp_result=lp_result)
    elif algo == "lp-map-f":
        sols = _lp_solutions(trimmed, filling=True, backend=backend,
                             lp_result=lp_result)
    else:
        raise ValueError(f"unknown algo {algo!r}; want one of {ALGORITHMS}")
    best = min(sols, key=lambda s: s.cost(trimmed))
    if local_search:
        from .local_search import eliminate_nodes

        best = eliminate_nodes(trimmed, best)
    best.meta["wall_s"] = time.perf_counter() - t0
    if check:
        verify(trimmed, best)
    return best


def evaluate(problem: Problem, algos=ALGORITHMS, backend: str = "numpy") -> dict:
    """Paper §VI protocol: per-algorithm best cost + the LP lower bound.

    Returns {algo: cost, ..., 'lb': lp_lowerbound, 'normalized': {algo: cost/lb}}.
    """
    trimmed, _ = trim_timeline(problem)
    # the LP is always solved: its objective is the normalizing lower bound
    lp_result = _solve_lp(trimmed)
    out: dict = {"lb": lp_result.objective, "costs": {}, "normalized": {},
                 "wall_s": {}}
    for algo in algos:
        sol = rightsize(trimmed, algo, backend=backend, lp_result=lp_result)
        cost = sol.cost(trimmed)
        out["costs"][algo] = cost
        out["normalized"][algo] = cost / max(out["lb"], 1e-12)
        out["wall_s"][algo] = sol.meta["wall_s"]
    return out
