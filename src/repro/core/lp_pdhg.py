"""Matrix-free PDHG (Chambolle–Pock) solver for the mapping LP — the
accelerator-native path (beyond-paper; mirrors how PDLP replaced
simplex/IPM at fleet scale).

After eliminating alpha (optimal alpha_B = max_{t,d} congestion), the LP
is the saddle problem

    min_{x in X} max_{y in Y}  sum_B <y_B, W_B(x_B)>

where
  * x is (n, m): per-task distributions over *feasible* node-types
    (X = product of masked simplices),
  * y is (m, T', D): Y = product of scaled simplices
    {y_B >= 0, sum y_B <= cost(B)}  (dual of cost_B * max_{t,d}),
  * W_B(x_B)[t, d] = sum_{u ~ t} x(u,B) dem(u,d)/cap(B,d) — the interval
    congestion operator: **the forward map is the Pallas congestion
    kernel's matmul**, and the adjoint is a cumulative-sum span lookup.

Both the primal objective F(x) (an upper bound on the LP optimum) and the
dual value G(y) = sum_u min_B (W^T y)(u, B) (a certified LOWER bound by
weak duality — hence still a valid lower bound on cost(opt)) are reported;
tests check the gap closes against HiGHS.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .problem import Problem, active_mask, feasible_types, trim_timeline

__all__ = ["PDHGResult", "solve_lp_pdhg"]


@dataclasses.dataclass
class PDHGResult:
    x: np.ndarray          # (n, m)
    objective: float       # primal F(x): upper bound on LP optimum
    lower_bound: float     # dual G(y): certified lower bound on LP optimum
    gap: float
    iters: int
    mapping: np.ndarray
    x_max: np.ndarray


def _congestion_fwd(x_col, w, act):
    """(T', D) congestion of one type: act (n, T'), w (n, D)."""
    return jnp.einsum("nt,nd->td", act * x_col[:, None], w)


def _congestion_adj(y, w, act):
    """adjoint: (n,) contribution  sum_{t in span, d} y[t,d] w[u,d]."""
    return jnp.einsum("td,nt,nd->n", y, act, w)


# --- O(n + T) difference-array formulation (beyond-paper optimization) ----
# forward: scatter +w at start, -w after end, prefix-sum over time:
#   cong[t] = cumsum_t( sum_u started(u,t) w_u - sum_u ended(u,t-1) w_u )
# adjoint: span sums via an exclusive cumsum of y:
#   (W^T y)_u = sum_d w[u,d] * (C[end_u + 1, d] - C[start_u, d])
# Both replace the (n x T) dense mask contraction — O(nT D) -> O((n+T) D).

def _congestion_fwd_cumsum(x_col, w, start, end, Tp):
    xw = w * x_col[:, None]                       # (n, D)
    D = w.shape[1]
    delta = jnp.zeros((Tp + 1, D), xw.dtype)
    delta = delta.at[start].add(xw)
    delta = delta.at[end + 1].add(-xw)
    return jnp.cumsum(delta[:Tp], axis=0)         # (T', D)


def _congestion_adj_cumsum(y, w, start, end):
    C = jnp.cumsum(y, axis=0)                     # inclusive (T', D)
    Cx = jnp.concatenate([jnp.zeros_like(C[:1]), C], axis=0)  # exclusive
    span = Cx[end + 1] - Cx[start]                # (n, D)
    return jnp.einsum("nd,nd->n", w, span)


def _project_simplex_masked(v, mask):
    """Project each row of v onto the simplex restricted to mask==True."""
    big = 1e30
    v = jnp.where(mask, v, -big)
    m = v.shape[-1]
    u = jnp.sort(v, axis=-1)[:, ::-1]
    css = jnp.cumsum(u, axis=-1)
    idx = jnp.arange(1, m + 1)
    cond = u * idx > (css - 1.0)
    rho = jnp.sum(cond, axis=-1)
    theta = (jnp.take_along_axis(css, (rho - 1)[:, None], axis=1)[:, 0]
             - 1.0) / rho
    out = jnp.maximum(v - theta[:, None], 0.0)
    return jnp.where(mask, out, 0.0)


def _project_capped_simplex(y, cap):
    """Project flat y onto {y >= 0, sum(y) <= cap}."""
    y = jnp.maximum(y, 0.0)
    total = y.sum()

    def shrink(yv):
        # project onto the simplex of size cap: sort-based threshold
        u = jnp.sort(yv)[::-1]
        css = jnp.cumsum(u)
        k = jnp.arange(1, yv.shape[0] + 1)
        cond = u * k > (css - cap)
        rho = jnp.sum(cond)
        theta = (css[rho - 1] - cap) / rho
        return jnp.maximum(yv - theta, 0.0)

    return jax.lax.cond(total <= cap, lambda v: v, shrink, y)


@functools.partial(jax.jit, static_argnames=("iters", "Tp", "operator"))
def _pdhg_run(w_all, act, start, end, feas, cost, tau, sigma, iters: int,
              Tp: int, operator: str = "cumsum"):
    n, m = feas.shape

    x = feas.astype(jnp.float32)
    x = x / x.sum(axis=1, keepdims=True)
    D = w_all.shape[2]
    y = jnp.zeros((m, Tp, D), jnp.float32)

    if operator == "cumsum":  # O((n+T)D) difference-array operators
        def fwd_all(xv):
            return jax.vmap(
                lambda xb, wb: _congestion_fwd_cumsum(xb, wb, start, end,
                                                      Tp),
                in_axes=(1, 0))(xv, w_all)  # (m, T', D)

        def adj_all(yv):
            return jax.vmap(
                lambda yb, wb: _congestion_adj_cumsum(yb, wb, start, end),
                in_axes=(0, 0))(yv, w_all).T  # (n, m)
    else:  # dense mask matmul (the Pallas congestion kernel's form)
        def fwd_all(xv):
            return jax.vmap(
                lambda xb, wb: _congestion_fwd(xb, wb, act),
                in_axes=(1, 0))(xv, w_all)

        def adj_all(yv):
            return jax.vmap(
                lambda yb, wb: _congestion_adj(yb, wb, act),
                in_axes=(0, 0))(yv, w_all).T

    def step(carry, _):
        x, y, x_prev = carry
        x_bar = 2.0 * x - x_prev
        y_new = y + sigma * fwd_all(x_bar)
        y_new = jax.vmap(
            lambda yb, cb: _project_capped_simplex(yb.reshape(-1), cb)
            .reshape(Tp, D))(y_new, cost)
        g = adj_all(y_new)
        x_new = _project_simplex_masked(x - tau * g, feas)
        return (x_new, y_new, x), None

    (x, y, _), _ = jax.lax.scan(step, (x, y, x), None, length=iters)

    cong = fwd_all(x)  # (m, T', D)
    primal = jnp.sum(cost * cong.reshape(m, -1).max(axis=1))
    # dual: G(y) = sum_u min_B (W^T y)(u, B) over feasible B
    wty = adj_all(y)
    wty = jnp.where(feas, wty, jnp.inf)
    dual = jnp.sum(wty.min(axis=1))
    return x, primal, dual


def solve_lp_pdhg(problem: Problem, iters: int = 2000,
                  step_scale: float = 0.9,
                  operator: str = "cumsum") -> PDHGResult:
    """operator='cumsum' uses the O((n+T)D) difference-array form of the
    congestion operator (beyond-paper; linear-time iterations); 'dense'
    uses the mask-matmul form matching the Pallas kernel."""
    trimmed, _ = trim_timeline(problem)
    n, m, D = trimmed.n, trimmed.m, trimmed.D
    Tp = trimmed.T
    act = jnp.asarray(active_mask(trimmed), jnp.float32)  # (n, T')
    start = jnp.asarray(trimmed.start, jnp.int32)
    end = jnp.asarray(trimmed.end, jnp.int32)
    w_all = jnp.asarray(
        trimmed.dem[None, :, :] / trimmed.node_types.cap[:, None, :],
        jnp.float32)  # (m, n, D)
    feas = jnp.asarray(feasible_types(trimmed))
    cost = jnp.asarray(trimmed.node_types.cost, jnp.float32)

    # ||A||_2 bound: power iteration on the stacked operator
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (n, m))
    for _ in range(12):
        u = jax.vmap(lambda xb, wb: _congestion_fwd(xb, wb, act),
                     in_axes=(1, 0))(v, w_all)
        v2 = jax.vmap(lambda yb, wb: _congestion_adj(yb, wb, act),
                      in_axes=(0, 0))(u, w_all).T
        norm = jnp.linalg.norm(v2)
        v = v2 / (norm + 1e-30)
    op_norm = jnp.sqrt(norm)
    tau = step_scale / op_norm
    sigma = step_scale / op_norm

    x, primal, dual = _pdhg_run(w_all, act, start, end, feas, cost,
                                jnp.float32(tau), jnp.float32(sigma),
                                iters, Tp, operator)
    x_np = np.asarray(x)
    mapping = np.where(
        np.asarray(feas).any(axis=1),
        np.asarray(jnp.where(feas, x, -1.0).argmax(axis=1)), 0)
    return PDHGResult(
        x=x_np,
        objective=float(primal),
        lower_bound=float(dual),
        gap=float(primal - dual),
        iters=iters,
        mapping=mapping.astype(np.int64),
        x_max=x_np.max(axis=1),
    )
