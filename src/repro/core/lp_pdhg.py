"""Matrix-free PDHG (Chambolle–Pock) solver for the mapping LP — the
accelerator-native path (beyond-paper; mirrors how PDLP replaced
simplex/IPM at fleet scale).

After eliminating alpha (optimal alpha_B = max_{t,d} congestion), the LP
is the saddle problem

    min_{x in X} max_{y in Y}  sum_B <y_B, W_B(x_B)>

where
  * x is (n, m): per-task distributions over *feasible* node-types
    (X = product of masked simplices),
  * y is (m, T', D): Y = product of scaled simplices
    {y_B >= 0, sum y_B <= cost(B)}  (dual of cost_B * max_{t,d}),
  * W_B(x_B)[t, d] = sum_{u ~ t} x(u,B) dem(u,d)/cap(B,d) — the interval
    congestion operator: **the forward map is the Pallas congestion
    kernel's matmul**, and the adjoint is a cumulative-sum span lookup.

Both the primal objective F(x) (an upper bound on the LP optimum) and the
dual value G(y) = sum_u min_B (W^T y)(u, B) (a certified LOWER bound by
weak duality — hence still a valid lower bound on cost(opt)) are reported;
tests check the gap closes against HiGHS.

The iteration itself lives in ``repro.core.batch``: the batched
fleet-sweep engine solves B instances in one fused scan, and this module's
``solve_lp_pdhg`` is its B=1 case.  This file keeps the problem
description, the result dataclass, and the difference-array operator
primitives.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .problem import Problem

__all__ = ["PDHGResult", "solve_lp_pdhg"]


@dataclasses.dataclass
class PDHGResult:
    x: np.ndarray          # (n, m)
    objective: float       # primal F(x): upper bound on LP optimum
    lower_bound: float     # dual G(y): certified lower bound on LP optimum
    gap: float
    iters: int
    mapping: np.ndarray
    x_max: np.ndarray


# --- O(n + T) difference-array formulation (beyond-paper optimization) ----
# forward: scatter +w at start, -w after end, prefix-sum over time:
#   cong[t] = cumsum_t( sum_u started(u,t) w_u - sum_u ended(u,t-1) w_u )
# adjoint: span sums via an exclusive cumsum of y:
#   (W^T y)_u = sum_d w[u,d] * (C[end_u + 1, d] - C[start_u, d])
# Both replace the (n x T) dense mask contraction — O(nT D) -> O((n+T) D).

def _congestion_fwd_cumsum(x_col, w, start, end, Tp):
    xw = w * x_col[:, None]                       # (n, D)
    D = w.shape[1]
    delta = jnp.zeros((Tp + 1, D), xw.dtype)
    delta = delta.at[start].add(xw)
    delta = delta.at[end + 1].add(-xw)
    return jnp.cumsum(delta[:Tp], axis=0)         # (T', D)


def _congestion_adj_cumsum(y, w, start, end):
    C = jnp.cumsum(y, axis=0)                     # inclusive (T', D)
    Cx = jnp.concatenate([jnp.zeros_like(C[:1]), C], axis=0)  # exclusive
    span = Cx[end + 1] - Cx[start]                # (n, D)
    return jnp.einsum("nd,nd->n", w, span)


def solve_lp_pdhg(problem: Problem, iters: int = 2000,
                  step_scale: float = 0.9,
                  operator: str = "auto") -> PDHGResult:
    """Single-instance PDHG solve — the B=1 case of the batched engine
    (``repro.core.batch.solve_lp_many``), so per-instance and fleet-sweep
    solves share one implementation.

    operator='cumsum' uses the O((n+T)D) difference-array form of the
    congestion operator (beyond-paper; linear-time iterations); 'dense'
    uses the mask-matmul form matching the Pallas kernel; 'pallas' routes
    the forward map through the batched Pallas congestion kernel itself;
    'auto' picks dense vs cumsum by memory footprint.
    """
    from .batch import solve_lp_many

    return solve_lp_many([problem], iters=iters, step_scale=step_scale,
                         operator=operator)[0]
