"""Matrix-free PDHG (Chambolle–Pock) solver for the mapping LP — the
accelerator-native path (beyond-paper; mirrors how PDLP replaced
simplex/IPM at fleet scale).

After eliminating alpha (optimal alpha_B = max_{t,d} congestion), the LP
is the saddle problem

    min_{x in X} max_{y in Y}  sum_B <y_B, W_B(x_B)>

where
  * x is (n, m): per-task distributions over *feasible* node-types
    (X = product of masked simplices),
  * y is (m, T', D): Y = product of scaled simplices
    {y_B >= 0, sum y_B <= cost(B)}  (dual of cost_B * max_{t,d}),
  * W_B(x_B)[t, d] = sum_{u ~ t} x(u,B) dem(u,d)/cap(B,d) — the interval
    congestion operator: **the forward map is the Pallas congestion
    kernel's matmul**, and the adjoint is a cumulative-sum span lookup.

Both the primal objective F(x) (an upper bound on the LP optimum) and the
dual value G(y) = sum_u min_B (W^T y)(u, B) (a certified LOWER bound by
weak duality — hence still a valid lower bound on cost(opt)) are reported;
tests check the gap closes against HiGHS.

The iteration itself lives in ``repro.core.batch``: the batched
fleet-sweep engine solves B instances in one fused solve, and this
module's ``solve_lp_pdhg`` is its B=1 case.  Two stopping regimes:

  * ``tol=None`` (legacy): fixed step, fixed ``iters`` — the vanilla
    Chambolle–Pock loop, kept bit-stable for the golden tables;
  * ``tol=<float>`` (PDLP-style): per-instance adaptive step sizes via
    the backtracking ratio test, average-iterate restarts on a
    normalized duality-gap criterion, and early exit once the
    normalized gap drops below ``tol`` — ``iters`` becomes a cap.

This file keeps the problem description, the result/telemetry
dataclasses, and the difference-array operator primitives.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .problem import Problem

__all__ = ["PDHGResult", "PDHGState", "SolveStats", "solve_lp_pdhg"]


@dataclasses.dataclass
class PDHGResult:
    x: np.ndarray          # (n, m)
    objective: float       # primal F(x): upper bound on LP optimum
    lower_bound: float     # dual G(y): certified lower bound on LP optimum
    gap: float
    iters: int             # iterations actually spent on this instance
    mapping: np.ndarray
    x_max: np.ndarray
    restarts: int = 0      # average-iterate restarts taken (tol mode)
    kkt: float = float("nan")  # final normalized duality gap (KKT proxy)
    converged: bool = True     # reached tol (always True in legacy mode)


@dataclasses.dataclass(frozen=True)
class PDHGState:
    """Final primal/dual iterates of a batched solve, in padded batch
    coordinates — the warm-start handle: pass as ``solve_lp_many(...,
    init=state)`` to start the next (neighboring) solve from here.
    Shapes are re-aligned (cropped / zero-padded per lane) when the next
    batch pads differently; lane b warm-starts lane b.  ``eta`` carries
    the adapted per-lane step size, so a warm-started neighbor skips the
    conservative power-iteration step and resumes at the tuned one;
    ``omega`` carries the adapted primal weight the same way (None when
    the solve ran with ``omega=False`` or in legacy mode).  Iterates are
    stored in original (unscaled) coordinates and in f32 regardless of
    the solve's ``precision``, so snapshots are format-stable."""

    x: np.ndarray  # (B, n, m) float32
    y: np.ndarray  # (B, T', m, D) float32
    eta: np.ndarray | None = None  # (B,) float32 adapted step sizes
    omega: np.ndarray | None = None  # (B,) float32 adapted primal weights

    @property
    def B(self) -> int:
        return self.x.shape[0]


@dataclasses.dataclass(frozen=True)
class SolveStats:
    """Per-instance solver telemetry for one batched solve.

    iterations: (B,) int — iterations-to-tolerance (== the cap when a
        lane did not converge; == the fixed count in legacy mode).
    restarts:   (B,) int — average-iterate restarts taken per lane.
    kkt:        (B,) float — final normalized duality gap
        (primal - dual) / (1 + |primal| + |dual|), the KKT-residual
        proxy both the restart criterion and the stop rule use.
    converged:  (B,) bool — lane reached ``tol``.
    tol:        the tolerance used (None in legacy fixed-iters mode).
    state:      final ``PDHGState`` for warm-starting a neighbor solve
        (None for all but the last group of a pipelined sweep — the
        compiled chain only carries the final dual iterate out).
    """

    iterations: np.ndarray
    restarts: np.ndarray
    kkt: np.ndarray
    converged: np.ndarray
    tol: float | None
    state: PDHGState | None

    def summary(self) -> dict:
        """JSON-ready aggregate row (the telemetry the benchmarks emit)."""
        return {
            "total_iters": int(self.iterations.sum()),
            "median_iters": float(np.median(self.iterations)),
            "max_iters": int(self.iterations.max()),
            "total_restarts": int(self.restarts.sum()),
            "median_kkt": float(np.median(self.kkt)),
            "max_kkt": float(np.max(self.kkt)),
            "converged_frac": float(np.mean(self.converged)),
            "tol": self.tol,
        }


def merge_stats(stats_list) -> dict:
    """Aggregate ``SolveStats.summary`` across a warm-started sweep's
    per-group solves into one flat telemetry dict (the concatenated
    batch's summary plus the per-instance lists)."""
    merged = SolveStats(
        iterations=np.concatenate([s.iterations for s in stats_list]),
        restarts=np.concatenate([s.restarts for s in stats_list]),
        kkt=np.concatenate([s.kkt for s in stats_list]),
        converged=np.concatenate([s.converged for s in stats_list]),
        tol=stats_list[0].tol, state=stats_list[0].state,
    )
    return {
        **merged.summary(),
        "iters": [int(i) for i in merged.iterations],
        "restarts": [int(r) for r in merged.restarts],
        "kkt": [float(k) for k in merged.kkt],
    }


# --- O(n + T) difference-array formulation (beyond-paper optimization) ----
# forward: scatter +w at start, -w after end, prefix-sum over time:
#   cong[t] = cumsum_t( sum_u started(u,t) w_u - sum_u ended(u,t-1) w_u )
# adjoint: span sums via an exclusive cumsum of y:
#   (W^T y)_u = sum_d w[u,d] * (C[end_u + 1, d] - C[start_u, d])
# Both replace the (n x T) dense mask contraction — O(nT D) -> O((n+T) D).

def _congestion_fwd_cumsum(x_col, w, start, end, Tp):
    xw = w * x_col[:, None]                       # (n, D)
    D = w.shape[1]
    delta = jnp.zeros((Tp + 1, D), xw.dtype)
    delta = delta.at[start].add(xw)
    delta = delta.at[end + 1].add(-xw)
    return jnp.cumsum(delta[:Tp], axis=0)         # (T', D)


def _congestion_adj_cumsum(y, w, start, end):
    C = jnp.cumsum(y, axis=0)                     # inclusive (T', D)
    Cx = jnp.concatenate([jnp.zeros_like(C[:1]), C], axis=0)  # exclusive
    span = Cx[end + 1] - Cx[start]                # (n, D)
    return jnp.einsum("nd,nd->n", w, span)


def solve_lp_pdhg(problem: Problem, iters: int = 2000,
                  step_scale: float = 0.9,
                  operator: str = "auto",
                  tol: float | None = None,
                  adaptive: bool = True,
                  restart: bool = True,
                  check_every: int | None = None,
                  init: PDHGState | None = None,
                  scaling: str = "ruiz", precision: str = "mixed",
                  omega: bool = True) -> PDHGResult:
    """Single-instance PDHG solve — the B=1 case of the batched engine
    (``repro.core.batch.solve_lp_many``), so per-instance and fleet-sweep
    solves share one implementation.

    With ``tol=None`` this is the legacy fixed-step, fixed-``iters``
    loop.  With ``tol`` set, the solve stops once the normalized duality
    gap drops below ``tol`` (``iters`` caps the worst case), using
    PDLP-style adaptive step sizes (``adaptive``) and average-iterate
    restarts (``restart``); ``init`` warm-starts from a previous solve's
    ``PDHGState``.  ``scaling``/``precision``/``omega`` are the tol-mode
    speed-layer knobs (see ``solve_lp_many``); legacy mode ignores them.

    operator='cumsum' uses the O((n+T)D) difference-array form of the
    congestion operator (beyond-paper; linear-time iterations); 'dense'
    uses the mask-matmul form matching the Pallas kernel; 'pallas' routes
    the forward map through the batched Pallas congestion kernel itself;
    'auto' picks dense vs cumsum by memory footprint.
    """
    from .batch import DEFAULT_CHECK_EVERY, solve_lp_many

    return solve_lp_many([problem], iters=iters, step_scale=step_scale,
                         operator=operator, tol=tol, adaptive=adaptive,
                         restart=restart,
                         check_every=(DEFAULT_CHECK_EVERY
                                      if check_every is None
                                      else check_every),
                         init=init, scaling=scaling, precision=precision,
                         omega=omega)[0]
