"""LP-based task -> node-type mapping (paper §V).

Variables: x(u,B) in [0,1] for every task/node-type pair, and alpha_B >= 0
per node-type.  The LP (relaxation of the paper's IP, Eq. 4-7):

    min  sum_B cost(B) * alpha_B
    s.t. sum_B x(u,B) = 1                                  (each task mapped)
         sum_{u ~ t} x(u,B) * dem(u,d)/cap(B,d) <= alpha_B  for all (B,t,d)
         0 <= x <= 1,  alpha >= 0

The optimal objective lower-bounds cost(opt) (§V-B); the solution is
near-integral (Lemma 4: at most n + mTD fractional variables at an extreme
point), so the rounding pi(u) = argmax_B x*(u,B) is effective.

The timeline is trimmed before constraint construction (congestion only
changes at task starts), so the constraint count is m * T' * D with
T' <= n.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
from scipy.optimize import linprog

from .problem import (Problem, active_mask, feasible_types,
                      require_lowered, trim_timeline)

__all__ = ["LPResult", "solve_lp", "lp_map"]


@dataclasses.dataclass
class LPResult:
    x: np.ndarray          # (n, m) fractional assignment
    alpha: np.ndarray      # (m,) per-type congestion
    objective: float       # lower bound on cost(opt)
    mapping: np.ndarray    # (n,) argmax-rounded node-type
    x_max: np.ndarray      # (n,) max_B x(u,B) — near-integrality diagnostic
    solver: str = "highs"


def _select_slots(problem: Problem, max_slots: int) -> np.ndarray:
    """Pick <= max_slots trimmed-slot indices: the top-congestion slots (by
    the cheap Lemma-1 penalty congestion) plus a uniform stride cover.

    Dropping constraint rows *relaxes* the LP, so the objective remains a
    valid lower bound on cost(opt); only the mapping gets coarser.
    """
    from .penalty import min_penalty

    act = active_mask(problem)  # (n, T')
    per_slot = min_penalty(problem, "avg") @ act
    top = np.argsort(-per_slot)[: max_slots // 2]
    stride = np.linspace(0, problem.T - 1, max_slots - len(top)).astype(int)
    return np.unique(np.concatenate([top, stride]))


def _build_constraints(problem: Problem, max_slots: int | None = None):
    """Sparse A_ub for the congestion constraints, plus A_eq.

    Column layout: [x(0,0..m-1), x(1,0..m-1), ..., x(n-1,0..m-1), alpha_0..m-1]
    Row layout for A_ub: for B in range(m) for t in range(T') for d in range(D).
    """
    n, m, D = problem.n, problem.m, problem.D
    trimmed, _ = trim_timeline(problem)
    if max_slots is not None and trimmed.T > max_slots:
        slots = _select_slots(trimmed, max_slots)
        act_full = active_mask(trimmed)[:, slots]
        Tp = len(slots)
        act = act_full
    else:
        Tp = trimmed.T
        act = active_mask(trimmed)  # (n, T')
    u_idx, t_idx = np.nonzero(act)  # active (task, slot) pairs
    nnz_per_bd = len(u_idx)

    # weight w(u, B, d) = dem(u, d) / cap(B, d)
    rows, cols, vals = [], [], []
    for B in range(m):
        w = problem.dem / problem.node_types.cap[B][None, :]  # (n, D)
        for d in range(D):
            r = (B * Tp + t_idx) * D + d
            rows.append(r)
            cols.append(u_idx * m + B)
            vals.append(w[u_idx, d])
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = np.concatenate(vals)
    # -alpha_B on each row
    arow = np.arange(m * Tp * D)
    acol = n * m + arow // (Tp * D)
    rows = np.concatenate([rows, arow])
    cols = np.concatenate([cols, acol])
    vals = np.concatenate([vals, -np.ones(m * Tp * D)])
    A_ub = sp.coo_matrix(
        (vals, (rows, cols)), shape=(m * Tp * D, n * m + m)
    ).tocsr()
    b_ub = np.zeros(m * Tp * D)

    # sum_B x(u, B) = 1
    erow = np.repeat(np.arange(n), m)
    ecol = np.arange(n * m)
    A_eq = sp.coo_matrix(
        (np.ones(n * m), (erow, ecol)), shape=(n, n * m + m)
    ).tocsr()
    b_eq = np.ones(n)
    return A_ub, b_ub, A_eq, b_eq, nnz_per_bd


def solve_lp(
    problem: Problem,
    method: str = "auto",
    max_slots: int | None = None,
) -> LPResult:
    """Solve the mapping LP exactly with HiGHS (paper used CBC via python-mip;
    HiGHS is the offline-available exact equivalent).

    method='auto' uses dual simplex for small LPs and interior-point (with
    crossover) for large ones — ~4x faster at GCT scale, measured.
    ``max_slots`` optionally subsamples constraint slots (sound relaxation).

    Constrained instances must be lowered first: lowered virtual
    dimensions become ordinary congestion rows here.
    """
    require_lowered(problem, "solve_lp")
    n, m = problem.n, problem.m
    if n == 0:
        return LPResult(
            x=np.zeros((0, m)), alpha=np.zeros(m), objective=0.0,
            mapping=np.zeros(0, dtype=np.int64), x_max=np.zeros(0),
        )
    A_ub, b_ub, A_eq, b_eq, _ = _build_constraints(problem, max_slots=max_slots)
    if method == "auto":
        method = "highs-ipm" if A_ub.shape[0] > 6000 else "highs"
    c = np.concatenate([np.zeros(n * m), problem.node_types.cost])
    # x(u,B) is pinned to 0 when u cannot fit an empty B node: opt can only
    # use feasible placements, so this keeps the LP a valid (tighter)
    # relaxation and keeps the rounded mapping placeable.
    feas = feasible_types(problem).reshape(-1)  # (n*m,)
    bounds = [(0.0, 1.0 if f else 0.0) for f in feas] + [(0.0, None)] * m
    res = linprog(
        c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq, bounds=bounds,
        method=method,
    )
    if res.status != 0:
        raise RuntimeError(f"LP solve failed: status={res.status} {res.message}")
    x = res.x[: n * m].reshape(n, m)
    alpha = res.x[n * m :]
    mapping = x.argmax(axis=1)
    return LPResult(
        x=x,
        alpha=alpha,
        objective=float(res.fun),
        mapping=mapping,
        x_max=x.max(axis=1),
        solver=f"linprog/{method}",
    )


def lp_map(problem: Problem, **kw) -> np.ndarray:
    """(n,) argmax-rounded LP mapping (paper §V-C)."""
    return solve_lp(problem, **kw).mapping
