"""Independent brute-force feasibility oracle for rightsizing plans.

This module is the repo's second opinion.  It validates a
``Solution`` against a ``Problem`` — including every hard constraint
in ``problem.constraints`` — by brute force over the ORIGINAL,
untrimmed timeline, and it deliberately shares **no code** with the
placement engines, the LP stack, or the constraint lowering:

  * capacity is re-accumulated slot by slot from scratch (no reuse of
    ``solution.verify``'s dense tensor or the engines' remaining-
    capacity bookkeeping);
  * the width/duration speedup law is re-derived with ``math.ceil``
    (not ``repro.core.constraints.width_duration``);
  * group semantics are checked directly on the original task rows
    (no affinity merge, no virtual dimensions).

An engine bug and an identical oracle bug would have to be written
twice, independently, to slip through.  ``check_plan`` returns a list
of human-readable violation strings (empty = feasible);
``assert_feasible`` raises ``FeasibilityError`` with all of them.

>>> import numpy as np
>>> from repro.core import NodeTypes, Problem, Solution
>>> nt = NodeTypes(cap=np.array([[2.0]]), cost=np.array([1.0]))
>>> p = Problem(dem=np.ones((2, 1)), start=np.zeros(2, dtype=int),
...             end=np.ones(2, dtype=int), node_types=nt, T=2)
>>> sol = Solution(node_type=np.array([0]), assign=np.array([0, 0]))
>>> check_plan(p, sol)
[]
>>> tight = Problem(dem=np.full((2, 1), 1.5), start=p.start, end=p.end,
...                 node_types=nt, T=2)
>>> check_plan(tight, sol)[0]
'node 0 (type type0) over capacity at slot 0 dim 0: used 3 > cap 2'
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["FeasibilityError", "check_plan", "assert_feasible"]

# Tolerance for capacity sums only (float accumulation); structural
# checks (windows, widths, group membership) are exact integer logic.
_CAP_EPS = 1e-7


class FeasibilityError(AssertionError):
    """A plan violates capacity or constraint semantics; ``.violations``
    holds every individual violation string."""

    def __init__(self, violations: list[str]):
        self.violations = list(violations)
        head = "\n  ".join(self.violations[:20])
        more = len(self.violations) - 20
        tail = f"\n  ... and {more} more" if more > 0 else ""
        super().__init__(
            f"{len(self.violations)} feasibility violation(s):\n  "
            f"{head}{tail}")


def _task_widths(problem, solution, widths):
    """Resolved per-task widths: explicit arg > solution.meta > all-1."""
    n = problem.n
    if widths is None:
        widths = solution.meta.get("widths") if solution.meta else None
    if widths is None:
        return [1] * n
    widths = [int(w) for w in np.asarray(widths).reshape(-1)]
    if len(widths) != n:
        return None  # reported by caller
    return widths


def check_plan(problem, solution, widths=None, eps=_CAP_EPS):
    """Return every violation of ``solution`` against ``problem``.

    Checks, in order: assignment validity, width bounds, the
    width/duration law vs deadlines and the horizon, per-node capacity
    at EVERY timeslot and dimension, affinity co-location,
    anti-affinity temporal separation, and exclusive no-co-tenancy.
    ``widths`` (per-task, default from ``solution.meta['widths']``,
    else all 1) scales demand and shrinks duration per the law.
    """
    violations: list[str] = []
    n, T = problem.n, problem.T
    nt = problem.node_types
    c = problem.constraints
    node_type = np.asarray(solution.node_type)
    assign = np.asarray(solution.assign)
    num_nodes = node_type.shape[0]

    # -- assignment validity ------------------------------------------
    if assign.shape[0] != n:
        return [f"assign has {assign.shape[0]} entries for {n} tasks"]
    for u in range(n):
        if not 0 <= int(assign[u]) < num_nodes:
            violations.append(
                f"task {u} assigned to node {int(assign[u])} outside "
                f"0..{num_nodes - 1}")
    for b in range(num_nodes):
        if not 0 <= int(node_type[b]) < nt.m:
            violations.append(
                f"node {b} has type {int(node_type[b])} outside "
                f"0..{nt.m - 1}")
    if violations:
        return violations  # later checks index by node

    # -- widths and the duration law ----------------------------------
    w = _task_widths(problem, solution, widths)
    if w is None:
        return [f"widths has wrong length (expected {n})"]
    finish = [0] * n
    for u in range(n):
        dur0 = int(problem.end[u]) - int(problem.start[u]) + 1
        max_w = int(c.max_width[u]) if c is not None else 1
        f = float(c.serial_frac[u]) if c is not None else 1.0
        if not 1 <= w[u] <= max_w:
            violations.append(
                f"task {u} width {w[u]} outside 1..{max_w}")
            w[u] = 1
        # independent re-derivation of the speedup law (math.ceil,
        # not repro.core.constraints.width_duration)
        dur = max(1, math.ceil(dur0 * (f + (1.0 - f) / w[u]) - 1e-9))
        finish[u] = int(problem.start[u]) + dur - 1
        if finish[u] >= T:
            violations.append(
                f"task {u} finishes at slot {finish[u]} beyond the "
                f"horizon T={T}")
        if c is not None and int(c.deadline[u]) >= 0 \
                and finish[u] > int(c.deadline[u]):
            violations.append(
                f"task {u} misses its deadline: finishes at slot "
                f"{finish[u]} > deadline {int(c.deadline[u])}")

    # -- capacity at every timeslot, accumulated from scratch ---------
    for b in range(num_nodes):
        cap = nt.cap[int(node_type[b])]
        tasks_on_b = [u for u in range(n) if int(assign[u]) == b]
        for t in range(T):
            used = [0.0] * problem.D
            for u in tasks_on_b:
                if int(problem.start[u]) <= t <= finish[u]:
                    for d in range(problem.D):
                        used[d] += w[u] * float(problem.dem[u, d])
            for d in range(problem.D):
                if used[d] > float(cap[d]) + eps:
                    violations.append(
                        f"node {b} (type {nt.names[int(node_type[b])]})"
                        f" over capacity at slot {t} dim {d}: used "
                        f"{used[d]:g} > cap {float(cap[d]):g}")

    if c is None:
        return violations

    # -- affinity: every group on ONE node ----------------------------
    for g in sorted(set(int(x) for x in c.affinity if x >= 0)):
        members = [u for u in range(n) if int(c.affinity[u]) == g]
        nodes = sorted(set(int(assign[u]) for u in members))
        if len(nodes) > 1:
            violations.append(
                f"affinity group {c.affinity_names[g]!r} split across "
                f"nodes {nodes} (tasks {members})")

    # -- anti-affinity: no two members co-tenant while overlapping ----
    for a in sorted(set(int(x) for x in c.anti_affinity if x >= 0)):
        members = [u for u in range(n) if int(c.anti_affinity[u]) == a]
        for i, u in enumerate(members):
            for v in members[i + 1:]:
                if int(assign[u]) != int(assign[v]):
                    continue
                if int(problem.start[u]) <= finish[v] \
                        and int(problem.start[v]) <= finish[u]:
                    violations.append(
                        f"anti-affinity group {c.anti_names[a]!r}: "
                        f"tasks {u} and {v} share node "
                        f"{int(assign[u])} with overlapping windows")

    # -- exclusivity: no co-tenant overlaps an exclusive task (its own
    # affinity-group members are exempt — the group reserves the node
    # together, and the whole group is exclusive to outsiders) --------
    for u in range(n):
        if not bool(c.exclusive[u]):
            continue
        for v in range(n):
            if v == u or int(assign[v]) != int(assign[u]):
                continue
            if int(c.affinity[u]) >= 0 \
                    and int(c.affinity[u]) == int(c.affinity[v]):
                continue
            if int(problem.start[u]) <= finish[v] \
                    and int(problem.start[v]) <= finish[u]:
                violations.append(
                    f"exclusive task {u} shares node {int(assign[u])} "
                    f"with task {v} during overlapping slots")
    return violations


def assert_feasible(problem, solution, widths=None, eps=_CAP_EPS):
    """Raise ``FeasibilityError`` listing every violation, if any."""
    violations = check_plan(problem, solution, widths=widths, eps=eps)
    if violations:
        raise FeasibilityError(violations)
