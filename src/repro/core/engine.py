"""FleetEngine: the typed-config session API of the fleet-sweep path.

PRs 1-3 grew the fleet evaluation surface one keyword argument at a
time: ``evaluate_many`` ended up with ten kwargs spanning three
orthogonal concerns (how to solve the mapping LPs, how to run the greedy
placement phase, how to batch/chain the sweep) and returned bare lists
of nested dicts.  This module redesigns that surface around a session
object:

  * ``SolverConfig``    — LP phase: stopping regime (tol/iters), the
                          adaptive/restart machinery, operator form.
  * ``PlacementConfig`` — greedy phase: numpy-lockstep vs compiled
                          on-device vs per-instance engine, fit-policy
                          scan, filling override, scoring backend.
  * ``SweepConfig``     — fleet shape: shape-bucketed packing (this
                          module's planner), warm-started sweep
                          chaining, shard size of the LP dispatch.
  * ``FleetEngine``     — one configured session: ``pack(problems)``,
                          ``solve(...)``, ``place(...)``,
                          ``evaluate(...)`` -> ``FleetResult``.

Shape-bucketed packing (ROADMAP follow-on, landed here): a very ragged
grid padded to ONE worst-case ``(n, m, D, T')`` shape wastes most of its
padded FLOPs on zeros — e.g. a sweep whose instances span n=30..130 and
T=8..30 pads every instance to (130, 30).  ``plan_buckets`` partitions
the instances into a small number of shape buckets chosen by a cost
model (padded cells minimized, with a per-extra-bucket overhead term
standing in for the extra XLA compile), each bucket is packed/solved/
placed on its own padded shape, and results are merged back into
submission order.  Exactness rides on the engine's padding invariant
(padding never perturbs real coordinates — pinned by
``tests/test_batch.py::TestPack::test_pad_to_minimum_dims_is_exact``),
so bucketed costs equal single-bucket costs exactly while the padded-
cell waste drops measurably.

``core.api.evaluate_many`` / ``evaluate`` remain as thin shims mapping
the legacy kwargs onto these configs (single-bucket, so golden tables
stay bit-stable).
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from .api import ALGORITHMS
from .batch import (DEFAULT_CHECK_EVERY, PRECISIONS, SCALINGS,
                    ProblemBatch, _sweep_impl, pack_problems,
                    solve_lp_many)
from .lp_pdhg import PDHGResult, PDHGState, SolveStats
from .penalty import penalty_map
from .place_batch import place_many
from .placement import FIT_POLICIES, two_phase
from .constraints import expand_solution, lower_constraints
from .problem import Problem, trim_timeline
from .solution import Solution, verify

__all__ = [
    "SolverConfig", "PlacementConfig", "SweepConfig", "FleetEngine",
    "FleetResult", "PackPlan", "Bucket", "plan_buckets",
    "DEFAULT_BUCKET_OVERHEAD",
]

_OPERATORS = ("auto", "dense", "cumsum", "pallas")
_PLACEMENT_ENGINES = ("batched", "compiled", "loop")
_PLACEMENT_BACKENDS = ("numpy", "kernel")
# PlacementConfig.engine -> place_many stepper name ('loop' bypasses
# place_many entirely)
_ENGINE_STEPPER = {"batched": "lockstep", "compiled": "compiled"}

# Planner cost of one extra shape bucket (one extra XLA compile of the
# fused stepper), expressed as a fraction of the single-bucket padded
# cell count: splitting must save at least this fraction of the whole
# grid's padded work per added bucket to pay for its compile.
DEFAULT_BUCKET_OVERHEAD = 0.03


# --- typed configs ---------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Mapping-LP phase configuration (``core.batch.solve_lp_many``).

    tol=None runs the legacy fixed-step, fixed-``iters`` solve (bit-
    stable; the golden tables pin it); tol=<float> runs the adaptive
    restarted engine until every lane's normalized duality gap is below
    tol, with ``iters`` demoted to the worst-case cap.  ``adaptive`` /
    ``restart`` ablate the PDLP machinery; ``operator`` picks the
    congestion-operator form; ``check_every`` is the tol-mode
    convergence-check cadence (iteration telemetry quantizes to it).

    The speed-layer knobs (tol mode only; legacy mode ignores them):
    ``scaling='ruiz'`` equilibrates the packed operator by a Ruiz-style
    change of variables (fewer iterations on ill-conditioned
    heterogeneous-cost instances; cost semantics stay exact because the
    extraction rescales back); ``precision='mixed'`` iterates in f32
    with an f64 KKT certificate and a final f64 polish pass ('f64'
    runs the whole iterate in f64); ``omega`` enables PDLP-style
    primal-weight balancing next to the adaptive step machinery.

    >>> SolverConfig().tol is None        # legacy fixed-iteration mode
    True
    >>> SolverConfig(scaling="log")
    Traceback (most recent call last):
        ...
    ValueError: scaling must be one of ('none', 'ruiz'), got 'log'
    >>> SolverConfig(tol=5e-3).check_every == DEFAULT_CHECK_EVERY
    True
    >>> SolverConfig(iters=0)
    Traceback (most recent call last):
        ...
    ValueError: iters must be >= 1, got 0
    """

    tol: float | None = None
    iters: int = 2000
    adaptive: bool = True
    restart: bool = True
    operator: str = "auto"
    step_scale: float = 0.9
    check_every: int = DEFAULT_CHECK_EVERY
    scaling: str = "ruiz"
    precision: str = "mixed"
    omega: bool = True

    def __post_init__(self):
        if self.tol is not None and not self.tol > 0:
            raise ValueError(f"tol must be positive or None, got {self.tol!r}")
        if self.iters < 1:
            raise ValueError(f"iters must be >= 1, got {self.iters!r}")
        if self.operator not in _OPERATORS:
            raise ValueError(
                f"operator must be one of {_OPERATORS}, got {self.operator!r}")
        if not self.step_scale > 0:
            raise ValueError(
                f"step_scale must be positive, got {self.step_scale!r}")
        if self.check_every < 1:
            raise ValueError(
                f"check_every must be >= 1, got {self.check_every!r}")
        if self.scaling not in SCALINGS:
            raise ValueError(
                f"scaling must be one of {SCALINGS}, got {self.scaling!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, "
                f"got {self.precision!r}")


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    """Greedy placement phase configuration.

    engine='batched' advances all instances in lockstep through the
    vectorized-numpy stepper (``place_many``); 'compiled' routes the
    same lockstep through the on-device ``lax.scan`` stepper
    (``place_step``) so the host dispatches once per node-type phase
    boundary — or once per *call* without filling — instead of once
    per placement step (oversized pools fall back to the numpy
    stepper); 'loop' restores the per-instance ``two_phase`` loop.
    Placements and costs are identical across all three engines.
    fit='best' scans every fit policy and keeps the per-instance
    minimum (the paper's §VI protocol); a concrete policy
    ('first'/'similarity') narrows the scan.  ``filling`` only applies
    to direct ``FleetEngine.place`` calls (the protocol derives
    filling from the algorithm name); ``backend`` routes the numpy
    stepper's scoring pass ('kernel' = the batch-dim-aware Pallas fit
    kernel; the compiled stepper always scores on-device).  ``check``
    verifies every returned placement against the instance
    constraints.

    >>> PlacementConfig().engine
    'batched'
    >>> PlacementConfig(engine="compiled", fit="similarity").fits
    ('similarity',)
    >>> PlacementConfig(engine="warp")
    Traceback (most recent call last):
        ...
    ValueError: placement engine must be one of ('batched', 'compiled', 'loop'), got 'warp'
    """

    engine: str = "batched"
    fit: str = "best"
    filling: bool = False
    backend: str = "numpy"
    check: bool = True

    def __post_init__(self):
        if self.engine not in _PLACEMENT_ENGINES:
            raise ValueError(
                f"placement engine must be one of {_PLACEMENT_ENGINES}, "
                f"got {self.engine!r}")
        if self.fit != "best" and self.fit not in FIT_POLICIES:
            raise ValueError(
                f"fit must be 'best' or one of {FIT_POLICIES}, "
                f"got {self.fit!r}")
        if self.backend not in _PLACEMENT_BACKENDS:
            raise ValueError(
                f"placement backend must be one of {_PLACEMENT_BACKENDS}, "
                f"got {self.backend!r}")

    @property
    def fits(self) -> tuple[str, ...]:
        return FIT_POLICIES if self.fit == "best" else (self.fit,)


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Fleet-shape configuration: bucketing, warm starts, sharding.

    max_buckets caps the shape-bucket partition of the packing planner
    (1 = legacy single-bucket packing); bucket_overhead is the planner's
    cost of one extra bucket (one extra compile), as a fraction of the
    single-bucket padded cell count.  warm_start=k treats the instances
    as a grid-adjacent sweep chained in consecutive groups of k (None =
    off; k <= 0 is an error, not "off" — and when k does not divide B
    the trailing group is smaller and COLD-starts, because its lanes no
    longer align with the predecessor state).  shard_size splits each
    bucket's LP solve into dispatches of at most that many instances
    (peak-memory knob; shards share the bucket's padded shape, so all
    equal-sized shards reuse one compile and results are unchanged).

    pipeline=True compiles the whole warm-started chain into ONE
    ``lax.scan`` over the groups — one host dispatch for the entire
    sweep instead of one per group (requires ``warm_start``, and the
    group size must divide the instance count so every scanned group
    stacks to one shape).  ``devices`` additionally shards the batch
    dim across that many local devices via ``shard_map`` (None = no
    sharding; the group size must divide by it, and the count is
    validated against ``jax.local_device_count()`` at config time —
    single-device CPU hosts fail HERE with a clear error instead of
    deep inside the shard_map dispatch).

    warm_start and max_buckets > 1 are mutually exclusive: the warm
    chain packs every group to one common shape so primal/dual states
    carry over lane-for-lane, which is the opposite trade of bucketing.

    >>> (SweepConfig(max_buckets=4).bucket_overhead
    ...  == DEFAULT_BUCKET_OVERHEAD)
    True
    >>> SweepConfig(warm_start=2, max_buckets=3)
    Traceback (most recent call last):
        ...
    ValueError: SweepConfig.warm_start and SweepConfig.max_buckets > 1 are mutually exclusive: ...
    """

    warm_start: int | None = None
    shard_size: int | None = None
    max_buckets: int = 1
    bucket_overhead: float = DEFAULT_BUCKET_OVERHEAD
    pipeline: bool = False
    devices: int | None = None

    def __post_init__(self):
        if self.warm_start is not None and self.warm_start <= 0:
            raise ValueError(
                f"warm_start must be a positive group size, got "
                f"{self.warm_start!r}; use warm_start=None to disable "
                f"warm-started sweep chaining")
        if self.shard_size is not None and self.shard_size <= 0:
            raise ValueError(
                f"shard_size must be a positive instance count, got "
                f"{self.shard_size!r}")
        if self.max_buckets < 1:
            raise ValueError(
                f"max_buckets must be >= 1, got {self.max_buckets!r}")
        if self.bucket_overhead < 0:
            raise ValueError(
                f"bucket_overhead must be >= 0, got {self.bucket_overhead!r}")
        if self.warm_start is not None and self.max_buckets > 1:
            raise ValueError(
                "SweepConfig.warm_start and SweepConfig.max_buckets > 1 "
                "are mutually exclusive: warm-started sweep chaining "
                "packs every group to one common shape (states must "
                "align lane-for-lane), while bucketing splits shapes "
                "apart.  To combine warm starts with shape-bucketed "
                "micro-batches online, use the serving loop "
                "(repro.serve.RightsizingService), which re-buckets per "
                "tick and carries per-fleet state across re-solves")
        if self.warm_start is not None and self.shard_size is not None:
            raise ValueError(
                "SweepConfig.warm_start and SweepConfig.shard_size are "
                "mutually exclusive: the warm chain already dispatches "
                "one group at a time (warm_start IS its shard size), so "
                "a separate shard size would be silently ignored.  For "
                "warm-started dispatches of bounded size, use the "
                "serving loop (repro.serve.RightsizingService), whose "
                "admission queue caps each tick's micro-batch")
        if self.pipeline and self.warm_start is None:
            raise ValueError(
                "SweepConfig.pipeline=True requires warm_start: the "
                "compiled pipeline IS the warm-started sweep chain "
                "fused into one lax.scan dispatch; set warm_start=<group "
                "size> to enable it")
        if self.devices is not None and not self.pipeline:
            raise ValueError(
                "SweepConfig.devices requires pipeline=True: the "
                "shard_map batch axis shards the compiled sweep "
                "pipeline's lanes; sequential dispatches don't shard")
        if self.devices is not None and self.devices < 1:
            raise ValueError(
                f"devices must be >= 1 or None, got {self.devices!r}")
        if self.devices is not None:
            import jax

            avail = jax.local_device_count()
            if self.devices > avail:
                raise ValueError(
                    f"SweepConfig(devices={self.devices}) but only "
                    f"{avail} local JAX device(s) are visible: the "
                    f"shard_map sweep pipeline places one batch shard "
                    f"per device, so the config would fail at dispatch "
                    f"time with a cryptic mesh error.  Use devices<="
                    f"{avail}, or (CPU hosts) set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N before "
                    f"importing jax to expose N host devices")


# --- shape-bucketed packing planner ----------------------------------------

def _own_cells(t: Problem) -> int:
    return t.n * t.m * t.D * t.T


def plan_buckets(problems, max_buckets: int = 1,
                 overhead: float = DEFAULT_BUCKET_OVERHEAD) -> list[list[int]]:
    """Partition (trimmed) instances into <= max_buckets shape buckets.

    Minimizes total padded cells ``sum_b B_b * n̂_b * m̂_b * D̂_b * T̂_b``
    (hats = per-bucket dimension maxima — the padded footprint every
    batched array and operator apply scales with) plus ``overhead *
    single_bucket_cells`` per bucket beyond the first (the extra-compile
    cost).  Instances are sorted by their own cell count and the DP
    finds the optimal contiguous partition of that order, which captures
    the ragged-sweep structure (shapes grow along sweep axes) without a
    4-D clustering pass.  Ties prefer fewer buckets; each returned
    bucket lists its instance indices in ascending submission order.
    """
    B = len(problems)
    if B == 0:
        raise ValueError("plan_buckets needs at least one instance")
    dims = np.array([(t.n, t.m, t.D, t.T) for t in problems], np.int64)
    if max_buckets <= 1 or B == 1:
        return [list(range(B))]
    cells = dims.prod(axis=1)
    order = sorted(range(B), key=lambda i: (int(cells[i]),
                                            tuple(dims[i]), i))
    sd = dims[order]  # (B, 4) in planning order
    single = float(B * sd.max(axis=0).prod())
    pay = overhead * single

    K = min(max_buckets, B)
    INF = float("inf")
    # dp[j] = min padded cells of the first j planned instances split
    # into exactly k buckets; the last bucket [i, j) has its per-dim
    # maxima accumulated by walking i downward, so one layer is O(B^2)
    dp_prev = [0.0] + [INF] * B  # k=0 layer: only 0 instances coverable
    best_cost, best_k = INF, 1
    cuts: list[list[int | None]] = []
    for k in range(1, K + 1):
        dp: list[float] = [INF] * (B + 1)
        cut: list[int | None] = [None] * (B + 1)
        for j in range(k, B + 1):
            mx = sd[j - 1].copy()
            for i in range(j - 1, k - 2, -1):
                np.maximum(mx, sd[i], out=mx)
                if dp_prev[i] == INF:
                    continue
                cand = dp_prev[i] + float((j - i) * mx.prod())
                if cand < dp[j]:
                    dp[j] = cand
                    cut[j] = i
        cuts.append(cut)
        total = dp[B] + pay * (k - 1)
        if total < best_cost:  # strict: exact ties keep fewer buckets
            best_cost, best_k = total, k
        dp_prev = dp

    # reconstruct the best_k-bucket partition
    segs = []
    j, k = B, best_k
    while j > 0:
        i = cuts[k - 1][j]
        segs.append((i, j))
        j, k = i, k - 1
    segs.reverse()
    return [sorted(order[i:j]) for i, j in segs]


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One shape bucket: submission-order indices + their packed batch."""

    indices: tuple[int, ...]
    batch: ProblemBatch

    @property
    def B(self) -> int:
        return self.batch.B

    @property
    def shape(self) -> tuple[int, int, int, int]:
        return self.batch.shape

    @property
    def cells(self) -> int:
        """Padded cells of this bucket's batched arrays."""
        b = self.batch
        return b.B * b.n * b.m * b.D * b.Tp

    @property
    def own_cells(self) -> int:
        return sum(_own_cells(t) for t in self.batch.problems)


@dataclasses.dataclass(frozen=True)
class PackPlan:
    """A bucketed packing of one fleet: the output of ``FleetEngine.pack``.

    ``buckets[b].indices`` are submission-order instance indices; their
    concatenation is a permutation of ``range(n_instances)`` (the merge
    key ``FleetEngine.evaluate`` uses to restore submission order).
    ``cells_single`` is the padded cell count of legacy single-bucket
    packing, the baseline every waste metric compares against.
    """

    buckets: tuple[Bucket, ...]
    n_instances: int
    cells_single: int

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def cells_packed(self) -> int:
        return sum(b.cells for b in self.buckets)

    @property
    def cells_own(self) -> int:
        return sum(b.own_cells for b in self.buckets)

    @property
    def waste_single(self) -> float:
        """Padded-cell waste fraction of single-bucket packing."""
        return 1.0 - self.cells_own / max(self.cells_single, 1)

    @property
    def waste_packed(self) -> float:
        """Padded-cell waste fraction of this bucketed packing."""
        return 1.0 - self.cells_own / max(self.cells_packed, 1)

    @property
    def waste_reduction(self) -> float:
        """Fraction of single-bucket WASTED cells this plan eliminates."""
        wasted_single = self.cells_single - self.cells_own
        wasted_packed = self.cells_packed - self.cells_own
        if wasted_single <= 0:
            return 0.0
        return 1.0 - wasted_packed / wasted_single

    def summary(self) -> dict:
        return {
            "buckets": self.n_buckets,
            "bucket_sizes": [b.B for b in self.buckets],
            "bucket_shapes": [list(b.shape) for b in self.buckets],
            "cells_single": int(self.cells_single),
            "cells_packed": int(self.cells_packed),
            "cells_own": int(self.cells_own),
            "waste_frac_single": round(self.waste_single, 4),
            "waste_frac_bucketed": round(self.waste_packed, 4),
            "waste_reduction": round(self.waste_reduction, 4),
        }


# --- structured results ----------------------------------------------------

@dataclasses.dataclass
class FleetResult:
    """Structured output of ``FleetEngine.evaluate``.

    entries: one §VI protocol dict per instance, in submission order —
        {'lb', 'costs': {algo: cost}, 'normalized': {algo: cost/lb},
        'wall_s': {algo: s}} plus a 'solver' telemetry block in tol
        mode (iters/restarts/kkt/converged per instance).
    stats: the ``SolveStats`` of each batched LP dispatch (one per
        bucket shard, or one per warm-started group); empty in legacy
        fixed-iters mode.
    plan: the bucketed ``PackPlan`` (None on the warm-sweep path, which
        packs to one common shape by construction).
    timings: phase breakdown — pack_s / lp_s / place_s / total_s plus
        per-bucket lists bucket_lp_s / bucket_place_s and a
        ``placement`` block (which placement engine ran, stepper calls
        and waves, summed per-wave seconds; for the compiled stepper
        also device-dispatch counts, execution modes, and fallbacks).

    >>> r = FleetResult(
    ...     entries=[{"lb": 1.0, "costs": {"lp-map": 2.0},
    ...               "normalized": {"lp-map": 2.0},
    ...               "wall_s": {"lp-map": 0.1}}],
    ...     stats=[], plan=None, timings={})
    >>> r.algos, r.costs("lp-map")
    (('lp-map',), [2.0])
    >>> r.to_rows()[0]["cost[lp-map]"]
    2.0
    """

    entries: list[dict]
    stats: list[SolveStats]
    plan: PackPlan | None
    timings: dict

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def algos(self) -> tuple[str, ...]:
        return tuple(self.entries[0]["costs"]) if self.entries else ()

    def costs(self, algo: str) -> list[float]:
        return [e["costs"][algo] for e in self.entries]

    def to_rows(self) -> list[dict]:
        """Flat benchmark rows, one per instance (JSON/CSV-ready)."""
        rows = []
        for i, e in enumerate(self.entries):
            row: dict = {"instance": i, "lb": e["lb"]}
            for algo in e["costs"]:
                row[f"cost[{algo}]"] = e["costs"][algo]
                row[f"normalized[{algo}]"] = e["normalized"][algo]
                row[f"wall_s[{algo}]"] = e["wall_s"][algo]
            for key, val in e.get("solver", {}).items():
                row[f"solver.{key}"] = val
            rows.append(row)
        return rows

    def to_json(self, indent: int | None = None) -> str:
        """Whole-result JSON: rows + plan summary + timings + solver
        aggregates (what the benchmark drivers persist)."""
        blob = {
            "entries": self.to_rows(),
            "timings": self.timings,
            "plan": self.plan.summary() if self.plan is not None else None,
            "solver": [s.summary() for s in self.stats],
        }
        return json.dumps(blob, indent=indent)


# --- the protocol engine ---------------------------------------------------

def _protocol_batched(batch: ProblemBatch, lp_results, algos, fits,
                      backend: str, check: bool = True,
                      stepper: str = "lockstep",
                      tels: list | None = None) -> list[dict]:
    """Batched placement protocol: every (mapping, fit, filling) combo of
    every algorithm runs as ONE lockstep ``place_many`` over the grid
    (through the ``stepper`` of the configured placement engine);
    per-call stepper telemetry is appended to ``tels``."""
    from .api import rightsize

    B = batch.B
    out = [{"lb": res.lower_bound, "costs": {}, "normalized": {},
            "wall_s": {}} for res in lp_results]
    for algo in algos:
        t0 = time.perf_counter()
        filling = algo.endswith("-f")
        if algo in ("penalty-map", "penalty-map-f"):
            mapsets = [[penalty_map(t, kind) for t in batch.problems]
                       for kind in ("avg", "max")]
        elif algo in ("lp-map", "lp-map-f"):
            mapsets = [[res.mapping for res in lp_results]]
        else:
            # extended algos (e.g. "+ls") keep the per-instance path
            for b, t in enumerate(batch.problems):
                sol = rightsize(t, algo, backend=backend,
                                lp_result=lp_results[b], check=check)
                out[b]["costs"][algo] = sol.cost(t)
                out[b]["wall_s"][algo] = sol.meta["wall_s"]
            continue
        best: list[Solution | None] = [None] * B
        best_cost = [float("inf")] * B
        for maps in mapsets:
            for fit in fits:
                tel: dict = {}
                sols = place_many(batch, maps, fit=fit, filling=filling,
                                  backend=backend, meta={"algo": algo},
                                  placement=stepper, telemetry=tel)
                if tels is not None:
                    tels.append(tel)
                for b, (t, s) in enumerate(zip(batch.problems, sols)):
                    c = s.cost(t)
                    if c < best_cost[b]:
                        best_cost[b], best[b] = c, s
        wall = (time.perf_counter() - t0) / B
        for b, t in enumerate(batch.problems):
            if check:
                verify(t, best[b])
            out[b]["costs"][algo] = best_cost[b]
            out[b]["wall_s"][algo] = wall
    for entry in out:
        lb = max(entry["lb"], 1e-12)
        entry["normalized"] = {a: c / lb
                               for a, c in entry["costs"].items()}
    return out


def _placement_telemetry(engine: str, tels: list) -> dict:
    """Aggregate per-call stepper telemetry into the ``FleetResult``
    timings block: which stepper actually ran, how many device
    dispatches the compiled stepper issued, how often it fell back,
    and the summed per-phase (wave) seconds."""
    out: dict = {"engine": engine, "calls": len(tels)}
    if engine == "loop" or not tels:
        return out
    out["waves"] = max((t.get("waves", 0) for t in tels), default=0)
    out["wave_s_total"] = sum(sum(t.get("wave_s", ())) for t in tels)
    if engine == "compiled":
        out["dispatches"] = sum(t.get("dispatches", 0) for t in tels)
        out["fallbacks"] = sum(1 for t in tels
                               if t.get("engine") != "compiled")
        out["modes"] = sorted({t["mode"] for t in tels if "mode" in t})
    return out


class FleetEngine:
    """One configured fleet-evaluation session (the §VI protocol at
    fleet scale): ``pack`` plans the shape buckets, ``solve`` runs the
    mapping-LP phase, ``place`` runs one greedy placement pass, and
    ``evaluate`` runs the whole protocol into a ``FleetResult``.

        engine = FleetEngine(
            solver=SolverConfig(tol=5e-3, iters=4000),
            sweep=SweepConfig(max_buckets=4),
        )
        result = engine.evaluate(problems)
        result.entries[0]["normalized"]       # cost / LP lower bound
        result.plan.summary()                 # bucket shapes + waste
        result.to_rows()                      # flat benchmark rows
        result.timings["placement"]           # stepper telemetry

    The legacy ``evaluate_many`` kwargs map onto the configs one-to-one
    (see docs/architecture.md for the migration table); with the
    default single-bucket ``SweepConfig`` the engine executes exactly
    the legacy code path, so golden tables are bit-stable under the
    shim.

    >>> from repro.core import FleetEngine, SolverConfig
    >>> from repro.workload import SyntheticSpec, synthetic_instance
    >>> fleet = [synthetic_instance(SyntheticSpec(n=10, m=2, D=2, T=6,
    ...                                           seed=s))
    ...          for s in (0, 1)]
    >>> engine = FleetEngine(solver=SolverConfig(iters=40),
    ...                      algos=("penalty-map",))
    >>> result = engine.evaluate(fleet)
    >>> len(result), result.algos
    (2, ('penalty-map',))
    >>> result.timings["placement"]["engine"]
    'batched'
    """

    def __init__(self, solver: SolverConfig | None = None,
                 placement: PlacementConfig | None = None,
                 sweep: SweepConfig | None = None,
                 algos=ALGORITHMS):
        self.solver = solver if solver is not None else SolverConfig()
        self.placement = placement if placement is not None \
            else PlacementConfig()
        self.sweep = sweep if sweep is not None else SweepConfig()
        self.algos = tuple(algos)
        if self.sweep.warm_start is not None and self.solver.tol is None:
            raise ValueError(
                "warm_start requires a tolerance-stopped solver "
                "(SolverConfig(tol=...)); fixed-iteration solves gain "
                "nothing from a warm start")
        if self.placement.engine == "loop" and self.placement.fit != "best":
            raise ValueError(
                "the per-instance 'loop' placement engine always scans "
                "every fit policy (the legacy protocol); narrowing "
                "PlacementConfig.fit requires engine='batched'")

    def with_overrides(self, **changes) -> "FleetEngine":
        """Derive a new engine with field-level changes routed across
        the config family (``dataclasses.replace`` under the hood).

        Accepts any field of ``SolverConfig`` / ``PlacementConfig`` /
        ``SweepConfig`` by name (the three families share no field
        names), whole replacement configs via ``solver=`` /
        ``placement=`` / ``sweep=``, and ``algos=``.  The derived
        engine re-validates, so invalid combinations fail exactly as
        they would at construction.  The base engine is untouched.

        >>> eng = FleetEngine(solver=SolverConfig(tol=5e-3))
        >>> eng2 = eng.with_overrides(tol=1e-2, fit="first")
        >>> (eng2.solver.tol, eng2.placement.fit, eng.solver.tol)
        (0.01, 'first', 0.005)
        >>> eng.with_overrides(fuel="ion")
        Traceback (most recent call last):
            ...
        ValueError: with_overrides got unknown field 'fuel'; ...
        """
        changes = dict(changes)
        parts = {
            "solver": changes.pop("solver", self.solver),
            "placement": changes.pop("placement", self.placement),
            "sweep": changes.pop("sweep", self.sweep),
        }
        algos = changes.pop("algos", self.algos)
        owner = {f.name: g for g, cfg in parts.items()
                 for f in dataclasses.fields(cfg)}
        grouped: dict[str, dict] = {g: {} for g in parts}
        for name, value in changes.items():
            if name not in owner:
                known = ", ".join(sorted(owner))
                raise ValueError(
                    f"with_overrides got unknown field {name!r}; "
                    f"expected solver=/placement=/sweep=/algos= or one "
                    f"of the config fields: {known}")
            grouped[owner[name]][name] = value
        return FleetEngine(
            solver=dataclasses.replace(parts["solver"],
                                       **grouped["solver"]),
            placement=dataclasses.replace(parts["placement"],
                                          **grouped["placement"]),
            sweep=dataclasses.replace(parts["sweep"], **grouped["sweep"]),
            algos=algos)

    # -- phase 0: pack -------------------------------------------------

    def pack(self, problems) -> PackPlan:
        """Trim, bucket (``plan_buckets``), and pad-and-stack a fleet.

        A pre-packed ``ProblemBatch`` passes through as one bucket (its
        padding is taken as-is, so bucketing never re-pads a batch the
        caller already laid out).  Constrained instances are lowered
        here (``repro.core.constraints``) before trimming, so every
        downstream phase sees plain instances."""
        if isinstance(problems, ProblemBatch):
            bucket = Bucket(indices=tuple(range(problems.B)),
                            batch=problems)
            return PackPlan(buckets=(bucket,), n_instances=problems.B,
                            cells_single=bucket.cells)
        trimmed = [trim_timeline(lower_constraints(p).lowered)[0]
                   for p in problems]
        if not trimmed:
            raise ValueError("FleetEngine.pack needs at least one instance")
        parts = plan_buckets(trimmed, max_buckets=self.sweep.max_buckets,
                             overhead=self.sweep.bucket_overhead)
        buckets = tuple(
            Bucket(indices=tuple(idx),
                   batch=pack_problems([trimmed[i] for i in idx],
                                       assume_trimmed=True))
            for idx in parts)
        n_hat = max(t.n for t in trimmed)
        m_hat = max(t.m for t in trimmed)
        d_hat = max(t.D for t in trimmed)
        t_hat = max(t.T for t in trimmed)
        return PackPlan(
            buckets=buckets, n_instances=len(trimmed),
            cells_single=len(trimmed) * n_hat * m_hat * d_hat * t_hat)

    # -- phase 1: the mapping-LP solve ---------------------------------

    def _solve_batch(self, batch: ProblemBatch, init=None):
        """One LP dispatch under ``self.solver`` -> (results, [stats])."""
        cfg = self.solver
        if cfg.tol is None:
            res = solve_lp_many(batch, iters=cfg.iters,
                                step_scale=cfg.step_scale,
                                operator=cfg.operator, init=init)
            return res, []
        res, st = solve_lp_many(
            batch, iters=cfg.iters, step_scale=cfg.step_scale,
            operator=cfg.operator, tol=cfg.tol, adaptive=cfg.adaptive,
            restart=cfg.restart, check_every=cfg.check_every, init=init,
            scaling=cfg.scaling, precision=cfg.precision, omega=cfg.omega,
            full_output=True)
        return res, [st]

    @staticmethod
    def _slice_state(state: PDHGState | None, lo: int, hi: int):
        if state is None:
            return None
        return PDHGState(
            x=state.x[lo:hi], y=state.y[lo:hi],
            eta=None if state.eta is None else state.eta[lo:hi],
            omega=None if state.omega is None else state.omega[lo:hi])

    def _solve_bucket(self, bucket: Bucket, init: PDHGState | None = None):
        """Solve one bucket, sharded to ``sweep.shard_size`` instances
        per dispatch (shards share the bucket's padded shape, so every
        full shard reuses one compile and results are unchanged); an
        ``init`` state is sliced lane-for-lane across the shards."""
        shard = self.sweep.shard_size
        batch = bucket.batch
        if shard is None or batch.B <= shard:
            return self._solve_batch(batch, init=init)
        shape = batch.shape
        results: list[PDHGResult] = []
        stats: list[SolveStats] = []
        for i in range(0, batch.B, shard):
            sub = pack_problems(batch.problems[i : i + shard],
                                pad_to=shape, assume_trimmed=True)
            res, st = self._solve_batch(
                sub, init=self._slice_state(init, i, i + shard))
            results.extend(res)
            stats.extend(st)
        return results, stats

    def solve(self, problems, init: PDHGState | None = None):
        """Mapping-LP phase only: ``(results, stats)`` with one
        ``PDHGResult`` per instance in submission order.  Accepts a
        problem sequence, a ``ProblemBatch``, or a ``PackPlan``.

        ``init`` warm-starts lane b of the dispatch from lane b of a
        previous solve's ``PDHGState`` (the serving loop's per-tick
        re-solve path).  It requires a single-bucket plan — the state's
        lanes align with ONE dispatch — and is rejected on the
        warm-started sweep path, which manages its own state chain."""
        if self.sweep.warm_start is not None:
            if init is not None:
                raise ValueError(
                    "solve(init=...) conflicts with "
                    "SweepConfig.warm_start: the warm-started sweep "
                    "chain seeds each group from its predecessor")
            trimmed = self._trimmed(problems)
            return self._solve_warm(trimmed)
        plan = problems if isinstance(problems, PackPlan) \
            else self.pack(problems)
        if init is not None and plan.n_buckets > 1:
            raise ValueError(
                f"solve(init=...) needs a single-bucket plan (state "
                f"lanes align with one dispatch), got {plan.n_buckets} "
                f"buckets; pack to one bucket or pass a ProblemBatch")
        results: list[PDHGResult | None] = [None] * plan.n_instances
        stats: list[SolveStats] = []
        for bucket in plan.buckets:
            res, st = self._solve_bucket(bucket, init=init)
            for i, r in zip(bucket.indices, res):
                results[i] = r
            stats.extend(st)
        return results, stats

    def solve_scenarios(self, problems, init: PDHGState | None = None):
        """Same-shape scenario group: ONE batched LP dispatch for K
        instances sharing one trimmed ``(n, m, D, T')`` shape.

        This is the Monte-Carlo fan-out entry (``repro.stochastic``):
        K scenario instances drawn from one demand forecast differ
        only in their demand vectors, so they already share a padded
        shape — the bucket planner has nothing to decide and every
        lane belongs in the same dispatch.  The shape is validated
        eagerly (a mixed-shape group raises, naming the shapes) and
        the planner is bypassed, so the K-lane solve issues exactly
        one compiled dispatch regardless of ``SweepConfig.max_buckets``
        (``shard_size`` still bounds the dispatch if set).  Returns
        ``(results, stats)`` like :meth:`solve`.

        >>> from repro.workload import SyntheticSpec, synthetic_instance
        >>> fleet = [synthetic_instance(SyntheticSpec(n=8, m=2, D=2,
        ...                                           T=6, seed=0))] * 2
        >>> eng = FleetEngine(solver=SolverConfig(tol=1e-2, iters=400))
        >>> results, stats = eng.solve_scenarios(fleet)
        >>> len(results), results[0].mapping.shape
        (2, (8,))
        """
        if self.sweep.warm_start is not None:
            raise ValueError(
                "solve_scenarios conflicts with SweepConfig.warm_start: "
                "a scenario group is one same-shape batch solved in a "
                "single dispatch, not a grid-adjacent sweep chain; use "
                "a SweepConfig without warm_start")
        trimmed = self._trimmed(problems)
        if not trimmed:
            raise ValueError("solve_scenarios needs at least one instance")
        shapes = {(t.n, t.m, t.D, t.T) for t in trimmed}
        if len(shapes) > 1:
            raise ValueError(
                f"solve_scenarios needs every trimmed instance on ONE "
                f"(n, m, D, T') shape (that is what makes the group a "
                f"single batched dispatch), got {sorted(shapes)}; fan "
                f"scenarios out of one forecast base "
                f"(repro.stochastic.fan_out) or pad them yourself")
        batch = problems if isinstance(problems, ProblemBatch) \
            else pack_problems(trimmed, assume_trimmed=True)
        bucket = Bucket(indices=tuple(range(batch.B)), batch=batch)
        return self._solve_bucket(bucket, init=init)

    def _trimmed(self, problems) -> list[Problem]:
        if isinstance(problems, ProblemBatch):
            return list(problems.problems)
        if isinstance(problems, PackPlan):
            raise ValueError(
                "warm-started sweeps take the problem sequence itself "
                "(grid-adjacent order), not a PackPlan")
        return [trim_timeline(lower_constraints(p).lowered)[0]
                for p in problems]

    def _solve_warm(self, trimmed: list[Problem]):
        """Warm-started sweep chain over consecutive groups of
        ``sweep.warm_start`` instances.  When the group size does not
        divide B the trailing group is smaller and cold-starts (its
        lanes no longer align with the predecessor state) — documented
        behavior on the sequential path, but an error under
        ``pipeline=True``, whose single ``lax.scan`` needs every group
        stacked to one shape."""
        cfg, k = self.solver, self.sweep.warm_start
        if self.sweep.pipeline and len(trimmed) % k:
            raise ValueError(
                f"SweepConfig(pipeline=True) needs warm_start "
                f"({k}) to divide the instance count ({len(trimmed)}): "
                f"the compiled sweep scans equal-shaped groups; pad the "
                f"fleet or adjust the group size")
        groups = [trimmed[i : i + k] for i in range(0, len(trimmed), k)]
        return _sweep_impl(
            groups, tol=cfg.tol, iters=cfg.iters,
            step_scale=cfg.step_scale, operator=cfg.operator,
            adaptive=cfg.adaptive, restart=cfg.restart,
            check_every=cfg.check_every, scaling=cfg.scaling,
            precision=cfg.precision, omega=cfg.omega,
            pipeline=self.sweep.pipeline, devices=self.sweep.devices)

    # -- phase 2: greedy placement -------------------------------------

    def place(self, problems, mappings, fit: str | None = None,
              filling: bool | None = None) -> list[Solution]:
        """One placement pass of given mappings under
        ``self.placement`` (fit/filling overridable per call; fit
        defaults to the config's policy, or 'first' under 'best').

        Constrained instances are lowered first and the returned
        solutions expanded back to original task rows (resolved widths
        ride ``meta['widths']``); ``mappings[b]`` must therefore align
        with the LOWERED rows — which is exactly what :meth:`solve`
        produces for the same problems."""
        if isinstance(problems, PackPlan):
            raise ValueError(
                "place() takes a problem sequence or a ProblemBatch "
                "(mappings align with submission order), not a PackPlan")
        cfg = self.placement
        fit = fit if fit is not None else (
            "first" if cfg.fit == "best" else cfg.fit)
        filling = cfg.filling if filling is None else filling
        lows = None
        if not isinstance(problems, ProblemBatch):
            lows = [lower_constraints(p) for p in problems]
            problems = [low.lowered for low in lows]
        if cfg.engine == "loop":
            trimmed = self._trimmed(problems)
            sols = [two_phase(t, mp, fit=fit, filling=filling,
                              backend=cfg.backend)
                    for t, mp in zip(trimmed, mappings)]
        else:
            batch = problems if isinstance(problems, ProblemBatch) \
                else pack_problems(self._trimmed(problems),
                                   assume_trimmed=True)
            sols = place_many(batch, mappings, fit=fit, filling=filling,
                              backend=cfg.backend,
                              placement=_ENGINE_STEPPER[cfg.engine])
        if lows is not None:
            sols = [expand_solution(low, s)
                    for low, s in zip(lows, sols)]
        return sols

    def _evaluate_bucket(self, batch: ProblemBatch, lp_results,
                         tels: list | None = None):
        """§VI protocol entries for one packed bucket."""
        cfg = self.placement
        if cfg.engine in _ENGINE_STEPPER:
            return _protocol_batched(batch, lp_results, self.algos,
                                     cfg.fits, cfg.backend,
                                     check=cfg.check,
                                     stepper=_ENGINE_STEPPER[cfg.engine],
                                     tels=tels)
        from .api import _protocol_entry

        return [_protocol_entry(t, res, res.lower_bound, self.algos,
                                cfg.backend)
                for t, res in zip(batch.problems, lp_results)]

    # -- the full protocol ---------------------------------------------

    def evaluate(self, problems) -> FleetResult:
        """§VI protocol over a fleet: bucketed pack -> per-bucket LP
        solve -> per-bucket lockstep placement -> entries merged back
        into submission order, as a ``FleetResult``."""
        t_start = time.perf_counter()
        if self.sweep.warm_start is not None:
            return self._evaluate_warm(problems, t_start)
        t0 = time.perf_counter()
        plan = problems if isinstance(problems, PackPlan) \
            else self.pack(problems)
        pack_s = time.perf_counter() - t0

        entries: list[dict | None] = [None] * plan.n_instances
        stats: list[SolveStats] = []
        bucket_lp_s, bucket_place_s = [], []
        tels: list[dict] = []
        for bucket in plan.buckets:
            t0 = time.perf_counter()
            lp_results, st = self._solve_bucket(bucket)
            bucket_lp_s.append(time.perf_counter() - t0)
            stats.extend(st)
            t0 = time.perf_counter()
            part = self._evaluate_bucket(bucket.batch, lp_results,
                                         tels=tels)
            bucket_place_s.append(time.perf_counter() - t0)
            if self.solver.tol is not None:
                self._attach_solver(part, lp_results)
            for i, entry in zip(bucket.indices, part):
                entries[i] = entry
        timings = {
            "pack_s": pack_s,
            "lp_s": sum(bucket_lp_s),
            "place_s": sum(bucket_place_s),
            "bucket_lp_s": bucket_lp_s,
            "bucket_place_s": bucket_place_s,
            "placement": _placement_telemetry(self.placement.engine,
                                              tels),
            "total_s": time.perf_counter() - t_start,
        }
        return FleetResult(entries=entries, stats=stats, plan=plan,
                           timings=timings)

    def _evaluate_warm(self, problems, t_start: float) -> FleetResult:
        """The warm-started sweep path: one chained LP solve, then one
        single-shape placement pass over the whole grid."""
        trimmed = self._trimmed(problems)
        t0 = time.perf_counter()
        lp_results, stats = self._solve_warm(trimmed)
        lp_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        batch = problems if isinstance(problems, ProblemBatch) \
            else pack_problems(trimmed, assume_trimmed=True)
        pack_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        tels: list[dict] = []
        entries = self._evaluate_bucket(batch, lp_results, tels=tels)
        place_s = time.perf_counter() - t0
        self._attach_solver(entries, lp_results)
        timings = {
            "pack_s": pack_s, "lp_s": lp_s, "place_s": place_s,
            "bucket_lp_s": [lp_s], "bucket_place_s": [place_s],
            "placement": _placement_telemetry(self.placement.engine,
                                              tels),
            "total_s": time.perf_counter() - t_start,
        }
        return FleetResult(entries=entries, stats=stats, plan=None,
                           timings=timings)

    @staticmethod
    def _attach_solver(entries, lp_results):
        for entry, res in zip(entries, lp_results):
            entry["solver"] = {"iters": res.iters,
                               "restarts": res.restarts,
                               "kkt": res.kkt,
                               "converged": res.converged}
