"""Solution representation, cost accounting and feasibility verification."""

from __future__ import annotations

import dataclasses

import numpy as np

from .problem import Problem

__all__ = ["Solution", "verify", "EPS"]

# Feasibility slack for float accumulation; demands/capacities are O(1).
EPS = 1e-7


@dataclasses.dataclass
class Solution:
    """A purchased cluster plus a placement of every task.

    node_type: (num_nodes,) node-type index of each purchased node, in
               purchase order (node ids are purchase ranks *within the whole
               solution*; first-fit's "earliest purchased" == lowest id).
    assign:    (n,) node id for each task.
    meta:      free-form provenance (algorithm, mapper, fit policy, ...).
    """

    node_type: np.ndarray
    assign: np.ndarray
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_nodes(self) -> int:
        return int(len(self.node_type))

    def cost(self, problem: Problem) -> float:
        return float(problem.node_types.cost[self.node_type].sum())

    def nodes_per_type(self, problem: Problem) -> np.ndarray:
        return np.bincount(self.node_type, minlength=problem.m)


def verify(problem: Problem, solution: Solution, eps: float = EPS) -> None:
    """Raise AssertionError unless the solution satisfies the capacity
    constraint at every (node, timeslot, dimension) and every task is placed.

    This is the ground-truth checker used by every test and benchmark; it is
    intentionally direct (dense usage tensor) rather than clever.
    """
    n, T, D = problem.n, problem.T, problem.D
    assert solution.assign.shape == (n,), "every task must be placed"
    if n == 0:
        return
    assert (solution.assign >= 0).all() and (
        solution.assign < solution.num_nodes
    ).all(), "assignments must reference purchased nodes"

    num_nodes = solution.num_nodes
    usage = np.zeros((num_nodes, T, D))
    for u in range(n):
        b = solution.assign[u]
        usage[b, problem.start[u] : problem.end[u] + 1, :] += problem.dem[u]
    cap = problem.node_types.cap[solution.node_type]  # (num_nodes, D)
    excess = usage - cap[:, None, :]
    worst = excess.max()
    assert worst <= eps, (
        f"capacity violated: max excess {worst:.3e} at "
        f"{np.unravel_index(excess.argmax(), excess.shape)}"
    )
