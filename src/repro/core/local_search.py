"""Node-elimination local search (beyond-paper post-pass).

The GCT diagnosis (EXPERIMENTS.md §Paper note) shows most of LP-map's gap
is per-type ceiling waste: many types own a nearly-empty last node.  This
pass tries to *empty* nodes — lowest utilization first — by relocating
their tasks into the remaining nodes (any type, feasibility-checked over
the full timeline); an emptied node is removed from the purchase.  Cost
never increases; applies to any algorithm's output.
"""

from __future__ import annotations

import numpy as np

from .problem import Problem
from .solution import EPS, Solution

__all__ = ["eliminate_nodes"]


def _usage(problem: Problem, solution: Solution) -> np.ndarray:
    """(num_nodes, T, D) aggregate demand."""
    usage = np.zeros((solution.num_nodes, problem.T, problem.D))
    for u in range(problem.n):
        usage[solution.assign[u],
              problem.start[u]: problem.end[u] + 1] += problem.dem[u]
    return usage


def eliminate_nodes(problem: Problem, solution: Solution,
                    passes: int = 2) -> Solution:
    """Returns a solution with cost <= the input's."""
    assign = solution.assign.copy()
    node_type = solution.node_type.copy()
    usage = _usage(problem, solution)
    cap = problem.node_types.cap[node_type]          # (nodes, D)
    cost = problem.node_types.cost[node_type]
    alive = np.ones(len(node_type), bool)

    tasks_of: list[list[int]] = [[] for _ in range(len(node_type))]
    for u in range(problem.n):
        tasks_of[assign[u]].append(u)

    for _ in range(passes):
        # utilization = peak fraction of capacity used (cost-weighted order)
        util = (usage / np.maximum(cap[:, None, :], 1e-12)).max(axis=(1, 2))
        order = np.argsort(util / np.maximum(cost, 1e-12))
        improved = False
        for b in order:
            if not alive[b] or not tasks_of[b]:
                if alive[b] and not tasks_of[b]:
                    alive[b] = False
                    improved = True
                continue
            # try to relocate every task of b elsewhere (largest first)
            moves: list[tuple[int, int]] = []
            trial_usage = usage.copy()
            ok = True
            tasks_sorted = sorted(
                tasks_of[b],
                key=lambda u: -float(problem.dem[u].max()))
            for u in tasks_sorted:
                s, e = problem.start[u], problem.end[u]
                dem = problem.dem[u]
                trial_usage[b, s:e + 1] -= dem
                placed = False
                for nb in range(len(node_type)):
                    if nb == b or not alive[nb]:
                        continue
                    fits = (
                        trial_usage[nb, s:e + 1] + dem[None, :]
                        <= problem.node_types.cap[node_type[nb]][None, :]
                        + EPS).all()
                    if fits:
                        trial_usage[nb, s:e + 1] += dem
                        moves.append((u, nb))
                        placed = True
                        break
                if not placed:
                    ok = False
                    break
            if ok:
                usage = trial_usage
                for u, nb in moves:
                    assign[u] = nb
                    tasks_of[nb].append(u)
                tasks_of[b] = []
                alive[b] = False
                improved = True
        if not improved:
            break

    # compact node ids
    remap = -np.ones(len(node_type), np.int64)
    remap[alive] = np.arange(int(alive.sum()))
    return Solution(
        node_type=node_type[alive],
        assign=remap[assign],
        meta=dict(solution.meta, local_search=True),
    )
