"""TL-Rightsizing core (the paper's contribution).

Public API (the surface docs/architecture.md documents):
    Problem, NodeTypes, Solution        — data model
    rightsize, evaluate                 — solve / paper-protocol evaluation
    FleetEngine, SolverConfig,
    PlacementConfig, SweepConfig        — typed-config fleet session API
    FleetResult, PackPlan, plan_buckets — structured results + bucketing
    evaluate_many                       — legacy kwarg shim over FleetEngine
    solve_lp_many, pack_problems        — batched fleet-sweep LP engine
    place_many                          — lockstep placement engine
                                          (placement='compiled' routes it
                                          through the on-device stepper,
                                          core.place_step)
    penalty_map, lp_map, solve_lp       — mapping strategies
    two_phase                           — per-instance placement engine
    lp_lowerbound, congestion_lowerbound, no_timeline_lowerbound
    TaskConstraints, lower_constraints,
    expand_solution, Lowering           — hard constraints + lowering
    check_plan, assert_feasible         — independent feasibility oracle
"""

from .problem import (
    Problem,
    NodeTypes,
    trim_timeline,
    active_mask,
    feasible_types,
    require_lowered,
)
from .constraints import (
    TaskConstraints,
    Lowering,
    lower_constraints,
    expand_solution,
    width_duration,
)
from .checker import FeasibilityError, assert_feasible, check_plan
from .solution import Solution, verify
from .penalty import (
    penalty_map,
    penalty_matrix,
    relative_demand,
    min_penalty,
)
from .placement import two_phase, TypePool, FIT_POLICIES
from .lp_map import solve_lp, lp_map, LPResult
from .lowerbound import (
    lp_lowerbound,
    congestion_lowerbound,
    no_timeline_lowerbound,
)
from .api import rightsize, evaluate, evaluate_many, ALGORITHMS
from .local_search import eliminate_nodes
from .rounding import concentration_rounding
from .lp_pdhg import solve_lp_pdhg, PDHGResult, PDHGState, SolveStats
from .batch import ProblemBatch, pack_problems, solve_lp_many, \
    solve_lp_sweep
from .place_batch import place_many
from .engine import (
    FleetEngine,
    FleetResult,
    PackPlan,
    PlacementConfig,
    SolverConfig,
    SweepConfig,
    plan_buckets,
)

__all__ = [
    "Problem", "NodeTypes", "Solution", "trim_timeline", "active_mask",
    "feasible_types",
    "verify", "penalty_map", "penalty_matrix", "relative_demand",
    "min_penalty", "two_phase", "TypePool", "FIT_POLICIES",
    "solve_lp", "lp_map", "LPResult",
    "lp_lowerbound", "congestion_lowerbound", "no_timeline_lowerbound",
    "rightsize", "evaluate", "evaluate_many", "ALGORITHMS",
    "eliminate_nodes", "concentration_rounding", "solve_lp_pdhg",
    "PDHGResult", "PDHGState", "SolveStats", "ProblemBatch",
    "pack_problems", "solve_lp_many", "solve_lp_sweep", "place_many",
    "FleetEngine", "FleetResult", "PackPlan", "PlacementConfig",
    "SolverConfig", "SweepConfig", "plan_buckets",
    "require_lowered", "TaskConstraints", "Lowering",
    "lower_constraints", "expand_solution", "width_duration",
    "FeasibilityError", "assert_feasible", "check_plan",
]
