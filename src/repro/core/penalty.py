"""Penalty-based task -> node-type mapping (paper §III, PenaltyMap phase 1).

Relative demand ("height") of task u w.r.t. node-type B:

    h_avg(u|B) = (1/D) sum_d dem(u,d) / cap(B,d)
    h_max(u|B) = max_d  dem(u,d) / cap(B,d)

Penalty p(u|B) = cost(B) * h(u|B); each task maps to argmin_B p(u|B).
"""

from __future__ import annotations

import numpy as np

from .problem import Problem, feasible_types

__all__ = [
    "relative_demand",
    "penalty_matrix",
    "penalty_map",
    "min_penalty",
]


def relative_demand(problem: Problem, kind: str = "avg") -> np.ndarray:
    """(n, m) matrix of h(u|B)."""
    # ratios: (n, m, D) = dem[u, d] / cap[B, d]
    ratios = problem.dem[:, None, :] / problem.node_types.cap[None, :, :]
    if kind == "avg":
        return ratios.mean(axis=2)
    if kind == "max":
        return ratios.max(axis=2)
    raise ValueError(f"unknown relative-demand kind {kind!r} (want 'avg'|'max')")


def penalty_matrix(problem: Problem, kind: str = "avg") -> np.ndarray:
    """(n, m) matrix of p(u|B) = cost(B) * h(u|B)."""
    return relative_demand(problem, kind) * problem.node_types.cost[None, :]


def _masked_penalty(problem: Problem, kind: str) -> np.ndarray:
    """Penalty matrix with +inf on (task, type) pairs the task cannot fit
    even on an empty node (the paper's traces have only small tasks, so it
    never states this guard; it is required for general instances)."""
    p = penalty_matrix(problem, kind)
    return np.where(feasible_types(problem), p, np.inf)


def penalty_map(problem: Problem, kind: str = "avg") -> np.ndarray:
    """(n,) array mapping each task to its least-penalty *feasible* node-type."""
    return _masked_penalty(problem, kind).argmin(axis=1)


def min_penalty(problem: Problem, kind: str = "avg") -> np.ndarray:
    """(n,) array of p*(u) = min_B p(u|B) over feasible types — used by the
    congestion lower bound (restricting to feasible types only tightens it:
    opt can only place u on feasible types)."""
    return _masked_penalty(problem, kind).min(axis=1)
