"""Compiled on-device lockstep placement stepper.

``place_batch.place_many`` already advances all B instances' greedy
placement (paper §III first/similarity fit, §V-D cross-fill) in
lockstep, but it re-enters Python between every placement step: one
step costs O(1) *numpy dispatches*, which ROADMAP lists as the
remaining bottleneck on small/medium batches.  This module makes the
same move for the placement phase that PR 3's PDLP-style engine made
for the LP phase — the inner loop compiles end-to-end, and the host
dispatches at *node-type phase boundaries* instead of once per step.

Execution model.  Two plans share one jitted sub-phase (``lax.scan``
over the attempt cursor — the numpy engine advances every live lane's
pointer each step, so the lockstep loop is exactly a scan over attempt
index with lanes masked by their list lengths):

  * **type-parallel** (``filling=False``): every (instance, node-type)
    phase is independent — types partition the tasks and pools never
    interact — so ALL phases run concurrently as scan lanes and the
    host dispatches ONCE for the whole placement.  Global node ids are
    reconstructed afterwards from the per-type node counts
    (``two_phase`` numbers each type's purchases as one contiguous
    block in type order).
  * **wave-sequential** (``filling=True``): cross-fill makes wave k+1's
    task lists depend on wave k's placements, so waves run in the numpy
    engine's order — one own-pack and one cross-fill dispatch per
    node-type phase boundary.

Each scan step scores the pending task of every lane against all its
candidate nodes in one batched feasibility + similarity pass
(``kernels.ops.fit_scores_step``, the in-loop callable form of
``fit_scores_many``) and picks nodes with the engines' shared argmax
tie-break; purchases and capacity updates are masked tensor updates
inside the scan.  The scan is split into static *chunks* replicating
the numpy engine's work-saving slices (see ``_plan_chunks``): the live
time window and node prefix of each chunk are statically known, so the
per-step tensors stay close to the work the numpy engine touches.

Exactness.  Placements are bit-identical to ``two_phase`` and the
numpy lockstep engine:

  * the whole sub-phase is traced under ``jax.experimental.enable_x64``
    so every elementwise expression (feasibility comparisons against
    ``dem - EPS``, capacity subtractions ``rem - dem`` over the span,
    the ``rem / capx`` normalizations) is the same float64 operation on
    the same values — elementwise ops never reassociate, so they match
    the numpy engines bit for bit;
  * similarity reduction sums (the dot/norm reductions) may differ from
    numpy's in the last ulp, exactly as the numpy engine's differ from
    ``find_fit``'s — all engines therefore quantize scores to 9
    decimals before the argmax.  The quantum is passed as a *runtime*
    operand so XLA cannot fold the division into a multiply-by-
    reciprocal (which is not bit-equal to ``np.round(score, 9)``);
  * ``jnp.argmax`` and ``np.argmax`` both take the first maximum, and
    node ids are purchase ranks in both engines, so tie-breaks agree.

Performance envelope.  The stepper eliminates the per-step host
round-trip: total dispatches drop from O(placement steps) to 1
(type-parallel) or O(phases) (wave-sequential).  On CPU hosts the win
is bounded by XLA's own elementwise kernels (~2x slower per element
than numpy's on small f64 tensors), so compiled ~matches the numpy
engine there and is pinned >=2x against the per-instance loop; on TPU
the same trace lowers to fused Mosaic kernels without the handicap.
A call whose pool tensor would exceed ``MAX_POOL_CELLS`` falls back to
the numpy lockstep engine (``run_compiled`` returns None and records
the reason in the telemetry dict).
"""

from __future__ import annotations

import functools
import time

import numpy as np

from .solution import EPS, Solution

__all__ = ["run_compiled", "MAX_POOL_CELLS"]

# Fall back to the numpy lockstep engine when a wave's padded pool
# tensor (B, N_cap, T', D) would exceed this many float64 elements: the
# scan materializes a few same-shaped temporaries per step, and past
# this size the compiled stepper's dispatch savings no longer pay for
# the padded arithmetic.
MAX_POOL_CELLS = 1 << 24

_QUANTUM = 1e9  # the engines' shared 9-decimal tie-break quantization


def _pow2(x: int, floor: int = 8) -> int:
    return max(floor, 1 << (int(x) - 1).bit_length()) if x else floor


def _pad4(x: int) -> int:
    return max(4, (int(x) + 3) & ~3)


def _make_sub_phase():
    """Build the jitted sub-phase scan (deferred so importing this
    module never imports jax eagerly on the fallback-only path)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import fit_scores_step

    @functools.partial(jax.jit,
                       static_argnames=("purchase", "similarity",
                                        "chunks"),
                       donate_argnums=(0,))
    def sub_phase(pool, w, lens, dem_seq, s_seq, e_seq, dn_seq, capx,
                  cap_rows, quantum, purchase: bool, similarity: bool,
                  chunks: tuple):
        """One lockstep sub-phase as a sequence of compiled scan chunks.

        pool:     (B, N_cap, K) f64 open-node remaining capacity with
                  the (T', D) slot axes flattened to K = T'*D (slot
                  k = t*D + d), the mat-vec-friendly scoring layout.
        w:        (B,) i32 open-node counts (pool widths).
        lens:     (B,) i32 attempt-list lengths (0 = lane idle).
        dem_seq:  (L, B, D) f64 per-attempt demands.
        s_seq:    (L, B) i32 per-attempt span starts (inclusive).
        e_seq:    (L, B) i32 per-attempt span ends (inclusive).
        dn_seq:   (L, B) f64 per-attempt demand norms.
        capx:     (B, D) f64 capacity, +inf on padded dims.
        cap_rows: (B, D) f64 capacity as opened-node rows (padded 1.0).
        quantum:  () f64 runtime tie-break quantum.
        chunks:   static ``(l0, l1, n_hi, t0, t1)`` tuples from
                  ``_plan_chunks``: attempt steps [l0, l1) only ever
                  see pool rows < n_hi and timeslots [t0, t1).

        The chunk plan replicates the numpy engine's two work-saving
        slices with *static* shapes: attempts are start-sorted, so a
        chunk's spans land in a narrow statically-known time window,
        and a lane's width grows by at most one node per step, so a
        chunk's live node prefix is statically bounded too.  Each chunk
        scans a static slice ``pool[:, :n_hi, t0*D:t1*D]`` — everything
        outside is provably untouched (spans inside the window, rows
        past ``n_hi`` masked infeasible) — and writes it back.  The
        pool arrives with EVERY row initialized to full capacity, so
        opening a node is just the width increment (a zero-initialized
        pool would leave an opened row blank outside the opening
        chunk's window); demand subtraction is a masked elementwise
        update rather than a scatter, which CPU backends lower to
        scalar loops as costly as a full pass.

        Returns (pool, w, bad, j_rec): ``bad`` is each lane's first
        attempt index whose task cannot fit the node-type (-1 = none),
        ``j_rec`` (L, B) the pool-local node index each attempt placed
        into (-1 = no placement).
        """
        B, n_cap, K = pool.shape
        D = dem_seq.shape[2]

        def flat_d(x_d, t_lo, t_hi):
            """(B, D) per-dim operand -> (B, Kw) window tiling."""
            return jnp.broadcast_to(
                x_d[:, None, :], (B, t_hi - t_lo, D)
            ).reshape(B, (t_hi - t_lo) * D)

        bad = jnp.full(w.shape, -1, jnp.int32)
        j_parts = []
        for (l0, l1, n_hi, t0, t1) in chunks:
            view = pool[:, :n_hi, t0 * D: t1 * D]
            capx_k = flat_d(capx, t0, t1)
            node_ids = jnp.arange(n_hi, dtype=jnp.int32)[None, :]
            t_ids = jnp.arange(t0, t1, dtype=jnp.int32)[None, :]

            def body(carry, xs, capx_k=capx_k, node_ids=node_ids,
                     t_ids=t_ids, t01=(t0, t1)):
                view, w, bad = carry
                dem, s, e, dn, step = xs
                active = step < lens                     # (B,)
                dem_k = flat_d(dem, *t01)
                span = (s[:, None] <= t_ids) & (t_ids <= e[:, None])
                span_k = jnp.broadcast_to(
                    span[:, :, None], (B, t01[1] - t01[0], D)
                ).reshape(B, (t01[1] - t01[0]) * D)
                feas, score = fit_scores_step(
                    view, dem_k, span_k, capx_k, dn,
                    scored=similarity, quantum=quantum, eps=EPS)
                feas = feas & (node_ids < w[:, None]) & active[:, None]
                has = feas.any(axis=1)
                if similarity:
                    choice = jnp.where(feas, score, -jnp.inf) \
                        .argmax(axis=1).astype(jnp.int32)
                else:
                    choice = jnp.argmax(feas, axis=1).astype(jnp.int32)
                if purchase:
                    buy = (~has) & active
                    bad_now = buy & (dem > cap_rows + EPS).any(axis=1)
                    bad = jnp.where(bad_now & (bad < 0), step, bad)
                    # the pool arrives cap-initialized on EVERY row
                    # (unopened rows are never read: node_ok masks
                    # them), so opening a node is just the width bump
                    j = jnp.where(has, choice, w)
                    placed = active
                    w = w + buy.astype(jnp.int32)
                else:
                    j = choice
                    placed = has
                # subtract the demand over the span from the chosen
                # row: a masked elementwise update (vectorized), not a
                # scatter — CPU/TPU backends lower scatters to scalar
                # loops that cost as much as a full pass here
                hit = placed[:, None] & (node_ids == j[:, None])
                view = view - jnp.where(
                    hit[:, :, None] & span_k[:, None, :],
                    dem_k[:, None, :], 0.0)
                j_rec = jnp.where(placed, j, -1)
                return (view, w, bad), j_rec

            steps = jnp.arange(l0, l1, dtype=jnp.int32)
            (view, w, bad), j_part = jax.lax.scan(
                body, (view, w, bad),
                (dem_seq[l0:l1], s_seq[l0:l1], e_seq[l0:l1],
                 dn_seq[l0:l1], steps))
            pool = pool.at[:, :n_hi, t0 * D: t1 * D].set(view)
            j_parts.append(j_part)
        if not j_parts:
            j_rec = jnp.full((0, B), -1, jnp.int32)
        else:
            j_rec = jnp.concatenate(j_parts, axis=0)
        return pool, w, bad, j_rec

    return sub_phase


_SUB_PHASE = None


def _sub_phase_fn():
    global _SUB_PHASE
    if _SUB_PHASE is None:
        _SUB_PHASE = _make_sub_phase()
    return _SUB_PHASE


def _pad_lists(lists, L: int):
    """(B, L) attempt-index padding + (B,) i32 lengths."""
    B = len(lists)
    u_pad = np.zeros((B, L), np.int64)
    lens = np.zeros(B, np.int32)
    for b, x in enumerate(lists):
        u_pad[b, : len(x)] = x
        lens[b] = len(x)
    return u_pad, lens


# Scan-chunk length of the compiled stepper: every CHUNK steps the
# node-prefix and time-window slices are re-tightened (smaller = less
# padded arithmetic, more unrolled scans to compile).
CHUNK = 8


def _plan_chunks(lens, s_seq, e_seq, n_cap: int, Tp: int,
                 w0_max: int, grows: bool, chunk: int = CHUNK) -> tuple:
    """Static per-chunk slice bounds for ``sub_phase``.

    Chunk c covers attempt steps [l0, l1).  Because attempt lists are
    start-sorted and a lane opens at most one node per step, the steps
    of one chunk provably touch only pool rows < ``w0_max + l1`` and
    the timeslots spanned by the chunk's live attempts; both bounds are
    known on the host, so each chunk scans a *static* slice.  Windows
    quantize to multiples of 4 slots and prefixes to powers of two so
    near-identical plans share compiled programs.
    """
    lens = np.asarray(lens)
    Lr = int(lens.max()) if len(lens) else 0
    steps = np.arange(Lr)[:, None]
    chunks = []
    for l0 in range(0, Lr, chunk):
        l1 = min(l0 + chunk, Lr)
        act = steps[l0:l1] < lens[None, :]
        if not act.any():
            break
        t0 = int(s_seq[l0:l1][act].min()) // 4 * 4
        t1 = min(Tp, (int(e_seq[l0:l1][act].max()) + 4) // 4 * 4)
        n_hi = min(n_cap, _pow2(w0_max + (l1 if grows else 0), floor=4))
        chunks.append((l0, l1, n_hi, t0, t1))
    return tuple(chunks)


class _Driver:
    """Shared host state of one ``run_compiled`` call."""

    def __init__(self, batch, phases, fit: str):
        from .place_batch import _batch_aux

        self.batch = batch
        self.phases = phases
        self.B, self.n = batch.B, batch.n
        self.Tpp = _pad4(batch.Tp)  # slot padding is cheap; nodes not
        self.K = self.Tpp * batch.D
        self.dn, self.capx_all, _ = _batch_aux(batch, phases)
        self.similarity = fit == "similarity"
        self.quantum = np.float64(_QUANTUM)
        self.counts = np.zeros(self.B, np.int64)
        self.placed = np.zeros((self.B, self.n), bool)
        self.assign = np.full((self.B, self.n), -1, np.int64)
        self.sub_phase = _sub_phase_fn()
        self.dispatches = 0

    def gather(self, lists, L, b_of, tau_of):
        """Per-attempt scan inputs for one sub-phase: lane a is
        instance ``b_of[a]`` packing node-type ``tau_of[a]``."""
        batch = self.batch
        u_pad, lens = _pad_lists(lists, L)
        lidx = b_of[:, None]
        dem_seq = np.ascontiguousarray(
            batch.dem[lidx, u_pad].transpose(1, 0, 2))
        s_seq = np.ascontiguousarray(
            batch.start[lidx, u_pad].T.astype(np.int32))
        e_seq = np.ascontiguousarray(
            batch.end[lidx, u_pad].T.astype(np.int32))
        dn_seq = np.ascontiguousarray(self.dn[lidx, u_pad].T)
        capx = self.capx_all[b_of, tau_of]
        cap_rows = batch.cap[b_of, tau_of]
        return u_pad, lens, dem_seq, s_seq, e_seq, dn_seq, capx, \
            cap_rows

    def cap_pool(self, cap_rows, n_cap: int):
        """Cap-initialized (A, n_cap, K) pool: every row starts at full
        capacity, so opening a node inside the scan is just the width
        increment (unopened rows are never read or written)."""
        cap_k = np.tile(cap_rows, (1, self.Tpp))         # (A, K)
        return np.ascontiguousarray(np.broadcast_to(
            cap_k[:, None, :], (len(cap_rows), n_cap, self.K)))

    def dispatch(self, pool, w, gathered, purchase: bool,
                 similarity: bool, w0_max: int):
        (_, lens, dem_seq, s_seq, e_seq, dn_seq, capx,
         cap_rows) = gathered
        chunks = _plan_chunks(lens, s_seq, e_seq, pool.shape[1],
                              self.Tpp, w0_max, grows=purchase)
        out = self.sub_phase(pool, w, lens, dem_seq, s_seq, e_seq,
                             dn_seq, capx, cap_rows, self.quantum,
                             purchase=purchase, similarity=similarity,
                             chunks=chunks)
        self.dispatches += 1
        return out

    def apply(self, j_rec, u_pad, b_of, base):
        """Fold one sub-phase's (L, Ap) node choices into assign:
        lane a's attempt l placed task ``u_pad[a, l]`` into global node
        ``base[a] + j_rec[l, a]``."""
        A = len(b_of)
        j_al = np.asarray(j_rec).T[:A]        # (A, L)
        a_hit, l_hit = np.nonzero(j_al >= 0)
        u_hit = u_pad[a_hit, l_hit]
        b_hit = b_of[a_hit]
        self.assign[b_hit, u_hit] = base[a_hit] + j_al[a_hit, l_hit]
        self.placed[b_hit, u_hit] = True

    def raise_bad(self, bad, u_pad, b_of, tau_of, phase_of=None):
        """Raise the sequential engines' infeasible-mapping error.

        ``phase_of`` orders lanes by type-phase position (type-parallel
        runs every phase at once, but the sequential engines hit the
        earliest (phase, step, lane) first, so the reported task must
        match theirs)."""
        bad = np.asarray(bad)[: len(b_of)]
        hit = np.flatnonzero(bad >= 0)
        if len(hit):
            if phase_of is None:
                a = int(hit[np.argmin(bad[hit])])
            else:
                a = int(min(hit, key=lambda i: (phase_of[i], bad[i], i)))
            u = int(u_pad[a, bad[a]])
            raise RuntimeError(
                f"mapping assigned task {u} to node-type "
                f"{int(tau_of[a])} it cannot fit")

    def solutions(self, node_type, meta, fit, filling):
        out = []
        for b, t in enumerate(self.batch.problems):
            assert self.placed[b, : t.n].all(), \
                "compiled stepper must place every task"
            out.append(Solution(
                node_type=node_type[b, : self.counts[b]].copy(),
                assign=self.assign[b, : t.n].copy(),
                meta=dict(meta or {}, fit=fit, filling=filling),
            ))
        return out


def _run_type_parallel(drv: _Driver, max_pool_cells: int):
    """filling=False: every (instance, node-type) phase is independent
    (types partition the tasks and pools never interact), so ALL waves
    run concurrently as one scan over (instance, type) lanes — a single
    device dispatch for the entire placement.  Global node ids are
    reconstructed afterwards: ``two_phase`` numbers each type's
    purchases as one contiguous block in type order, so the block
    offsets are the exclusive prefix sums of the per-type node counts.
    Returns None when the lane-pool tensor would be oversized."""
    phases, B = drv.phases, drv.B
    lanes = [(b, k) for b in range(B)
             for k in range(len(phases[b].type_order))
             if len(phases[b].own[k])]
    if not lanes:
        return [], np.full((B, 1), -1, np.int64)
    lists = [phases[b].own[k] for b, k in lanes]
    b_of = np.array([b for b, _ in lanes], np.int64)
    k_of = np.array([k for _, k in lanes], np.int64)
    tau_of = np.array([int(phases[b].type_order[k]) for b, k in lanes],
                      np.int64)
    L = max(len(x) for x in lists)
    if len(lanes) * L * drv.K > max_pool_cells:
        return None
    gathered = drv.gather(lists, L, b_of, tau_of)
    u_pad = gathered[0]
    pool0 = drv.cap_pool(gathered[-1], L)
    w0 = np.zeros(len(lanes), np.int32)
    _, w, bad, j_rec = drv.dispatch(pool0, w0, gathered, purchase=True,
                                    similarity=drv.similarity,
                                    w0_max=0)
    drv.raise_bad(bad, u_pad, b_of, tau_of, phase_of=k_of)
    w_np = np.asarray(w)[: len(lanes)].astype(np.int64)
    # per-instance node blocks in type order -> purchase-rank offsets
    m = drv.batch.m
    per_type = np.zeros((B, m), np.int64)
    per_type[b_of, tau_of] = w_np
    offsets = np.cumsum(per_type, axis=1) - per_type  # exclusive
    drv.counts = per_type.sum(axis=1)
    drv.apply(j_rec, u_pad, b_of, offsets[b_of, tau_of])
    node_type = np.full((B, max(1, int(drv.counts.max()))), -1,
                        np.int64)
    for (b, tau, cnt) in zip(b_of, tau_of, w_np):
        if cnt:
            off = offsets[b, tau]
            node_type[b, off: off + cnt] = tau
    return [1.0], node_type  # one fused "wave"


def _run_waves(drv: _Driver, filling: bool):
    """filling=True: wave-synchronized phases (the numpy engine's order)
    — cross-fill makes wave k+1's task lists depend on wave k's
    placements, so waves dispatch sequentially: one own-pack and one
    cross-fill scan per node-type phase boundary."""
    phases, B = drv.phases, drv.B
    node_cap = 8
    node_type = np.full((B, node_cap), -1, np.int64)
    wave_s: list[float] = []
    k = 0
    while True:
        wave = {b for b, ph in enumerate(phases)
                if k < len(ph.type_order)}
        if not wave:
            break
        t0 = time.perf_counter()
        tau = np.zeros(B, np.int64)
        for b in wave:
            tau[b] = phases[b].type_order[k]
        own = [phases[b].own[k][~drv.placed[b, phases[b].own[k]]]
               if b in wave else np.zeros(0, np.int64)
               for b in range(B)]
        lo = drv.counts.copy()
        b_all = np.arange(B)
        pool = w = None
        if any(len(x) for x in own):
            L = max(len(x) for x in own)
            gathered = drv.gather(own, L, b_all, tau)
            u_pad = gathered[0]
            pool0 = drv.cap_pool(gathered[-1], L)
            w0 = np.zeros(B, np.int32)
            pool, w, bad, j_rec = drv.dispatch(
                pool0, w0, gathered, purchase=True,
                similarity=drv.similarity, w0_max=0)
            drv.raise_bad(bad, u_pad, b_all, tau)
            w_np = np.asarray(w)[:B].astype(np.int64)
            drv.apply(j_rec, u_pad, b_all, lo)
            drv.counts += w_np
            while int(drv.counts.max()) > node_cap:
                node_type = np.concatenate(
                    [node_type, np.full_like(node_type, -1)], axis=1)
                node_cap *= 2
            for b in wave:
                if w_np[b]:
                    node_type[b, lo[b]: lo[b] + w_np[b]] = tau[b]
        if filling and pool is not None:
            w_host = np.asarray(w)[:B]
            fill = [phases[b].fill[k][~drv.placed[b, phases[b].fill[k]]]
                    if b in wave and w_host[b] > 0
                    else np.zeros(0, np.int64)
                    for b in range(B)]
            if any(len(x) for x in fill):
                L = max(len(x) for x in fill)
                gathered = drv.gather(fill, L, b_all, tau)
                pool, w, _, j_rec = drv.dispatch(
                    pool, w, gathered, purchase=False, similarity=False,
                    w0_max=int(w_host.max()))
                drv.apply(j_rec, gathered[0], b_all, lo)
        wave_s.append(time.perf_counter() - t0)
        k += 1
    return wave_s, node_type


def run_compiled(batch, phases, fit: str, filling: bool,
                 meta: dict | None = None,
                 telemetry: dict | None = None,
                 max_pool_cells: int | None = None):
    """Compiled-stepper body of ``place_many(placement='compiled')``.

    Takes the packed ``ProblemBatch`` and the per-instance ``_Phases``
    the caller already built; returns one ``Solution`` per instance
    (bit-identical to the numpy lockstep engine and ``two_phase``), or
    None when the padded pool tensor would exceed ``max_pool_cells``
    (the caller then runs the numpy engine on the same phases).

    filling=False runs the *type-parallel* plan: one device dispatch
    for the whole placement (every (instance, type) phase is an
    independent scan lane).  filling=True runs wave-synchronized, one
    own-pack + one cross-fill dispatch per node-type phase boundary.
    """
    from jax.experimental import enable_x64

    if max_pool_cells is None:
        max_pool_cells = MAX_POOL_CELLS
    drv = _Driver(batch, phases, fit)
    # wave-mode budget: the widest wave's padded pool
    max_own = max((len(ph.own[k]) for ph in phases
                   for k in range(len(ph.type_order))), default=0)
    if batch.B * max_own * drv.K > max_pool_cells:
        if telemetry is not None:
            telemetry["engine"] = "lockstep-fallback"
            telemetry["fallback"] = (
                "padded pool would exceed "
                f"{max_pool_cells} cells; using the numpy engine")
        return None

    t0 = time.perf_counter()
    with enable_x64():
        if filling:
            wave_s, node_type = _run_waves(drv, filling)
            mode = "wave-sequential"
        else:
            out = _run_type_parallel(drv, max_pool_cells)
            if out is None:  # lane pool oversized: waves fit the budget
                wave_s, node_type = _run_waves(drv, filling)
                mode = "wave-sequential"
            else:
                wave_s, node_type = out
                wave_s = [time.perf_counter() - t0] * len(wave_s)
                mode = "type-parallel"

    if telemetry is not None:
        telemetry["engine"] = "compiled"
        telemetry["mode"] = mode
        telemetry["waves"] = len(wave_s)
        telemetry["wave_s"] = wave_s
        telemetry["dispatches"] = drv.dispatches

    return drv.solutions(node_type, meta, fit, filling)
