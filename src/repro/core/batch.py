"""Batched many-instance LP engine: pad-and-stack + one fused PDHG solve.

The paper's §VI protocol (and every fleet-scale sweep in the related
work) evaluates algorithms over *grids* of instances.  This module packs
B ``Problem`` instances into ragged-safe ``(B, ...)`` arrays and runs the
matrix-free PDHG mapping LP for all of them in a single compiled solve —
the whole iteration (congestion operator, adjoint, both projections) is
batched, so one compiled stepper advances every instance at once instead
of B sequential solves.

Two stopping regimes share the packed operator machinery:

  * ``tol=None`` — the legacy fixed-step, fixed-``iters`` vanilla
    Chambolle–Pock ``lax.scan`` (bit-stable; the golden tables pin it);
  * ``tol=<float>`` — the PDLP-style engine: per-instance adaptive
    primal/dual step sizes via the backtracking ratio test (step-size
    state carried per batch lane, so each instance adapts independently
    inside the one fused solve), average-iterate restarts triggered by a
    per-instance normalized duality-gap criterion, a vectorized
    convergence mask that freezes converged lanes (masked updates) while
    stragglers keep iterating, and an early-exit ``lax.while_loop``
    outer stepper that stops as soon as the whole batch is converged.
    ``solve_lp_many(..., init=prev_state)`` warm-starts from a previous
    solve's primal/dual iterates, and ``solve_lp_sweep`` chains that
    across a grid-adjacent sequence of sweep groups so each sweep point
    starts from its neighbor's solution.  Per-instance telemetry
    (iterations-to-tolerance, restarts, final KKT residuals) comes back
    in a ``SolveStats``.

Padding scheme (exact — padded coordinates never perturb real ones):

  * tasks      — zero demand, span [0, 0]: zero operator weight, zero
                 congestion, zero dual contribution;
  * node-types — unit capacity but *zero operator weight* and an
                 effectively-infinite price (``PAD_COST``), masked
                 infeasible for every task so ``x`` never selects them;
  * dimensions — zero demand over unit capacity: zero weight;
  * timeline   — slots past an instance's trimmed T' have no active
                 task, so congestion and the (zero-initialized) dual
                 iterate stay identically zero there.

Both simplex projections are padding-exact as well: appended ``-inf``/
zero entries never enter the sorted-threshold count, so the projected
real coordinates match the unbatched projection bit-for-bit up to float
reassociation.  ``solve_lp_pdhg`` is the B=1 special case of this engine,
so the per-instance and batched paths share one implementation.

The forward map can run through the batch-dim-aware Pallas congestion
kernel (``operator='pallas'``, grid over B; see kernels/congestion.py),
the dense mask-matmul form it implements (``'dense'``), or the O((n+T)D)
difference-array form (``'cumsum'``, the default).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .lp_pdhg import PDHGResult, PDHGState, SolveStats
from .problem import Problem, feasible_types, trim_timeline

__all__ = ["ProblemBatch", "pack_problems", "solve_lp_many",
           "solve_lp_sweep", "PAD_COST", "DEFAULT_TOL",
           "DEFAULT_CHECK_EVERY"]

# Padded node-types carry this price: they never accrue congestion (their
# operator weight is zeroed), so they contribute exactly 0 to the primal,
# but any accidental use would be unmissable in the objective.
PAD_COST = 1e9

# Default normalized-duality-gap tolerance of the adaptive engine: a 0.5%
# certified relative gap.  Near-integrality (paper Fig 5) keeps the argmax
# mapping — and therefore the §VI protocol costs — stable at this gap, so
# tolerance-stopped solves place identically to converged ones.
DEFAULT_TOL = 5e-3

# Default convergence-check cadence of the tol-mode engine: iteration
# counts quantize to this interval, so telemetry consumers (the CI gate's
# quantum slack, test tolerances) must read it from here, not hardcode it.
DEFAULT_CHECK_EVERY = 25


@dataclasses.dataclass(frozen=True)
class ProblemBatch:
    """B timeline-trimmed instances padded to common (n, m, D, T') shapes.

    problems: the trimmed per-instance ``Problem``s (for unpacking).
    dem:   (B, n, D) float64, padded tasks/dims zero.
    start: (B, n) int32, padded tasks [0, 0].
    end:   (B, n) int32.
    cap:   (B, m, D) float64, padded types/dims one.
    cost:  (B, m) float64, padded types ``PAD_COST``.
    feas:  (B, n, m) bool — per-instance feasible pairs; padded tasks may
           use any *real* type (zero demand fits everywhere), padded
           types are feasible for no task.
    task_mask: (B, n) bool; type_mask: (B, m) bool.
    Tp: common (max) trimmed timeline length.
    """

    problems: tuple[Problem, ...]
    dem: np.ndarray
    start: np.ndarray
    end: np.ndarray
    cap: np.ndarray
    cost: np.ndarray
    feas: np.ndarray
    task_mask: np.ndarray
    type_mask: np.ndarray
    Tp: int

    @property
    def B(self) -> int:
        return self.dem.shape[0]

    @property
    def n(self) -> int:
        return self.dem.shape[1]

    @property
    def m(self) -> int:
        return self.cap.shape[1]

    @property
    def D(self) -> int:
        return self.dem.shape[2]

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """The common padded (n, m, D, T') every instance was packed to
        — the ``pad_to`` that reproduces this batch's layout (what the
        engine's shard dispatch passes so shards share one compile)."""
        return (self.n, self.m, self.D, self.Tp)

    def weights(self) -> np.ndarray:
        """(B, n, m, D) operator weights dem/cap, zeroed on padding."""
        w = self.dem[:, :, None, :] / self.cap[:, None, :, :]
        return w * self.type_mask[:, None, :, None]


def pack_problems(problems, pad_to=None,
                  assume_trimmed: bool = False) -> ProblemBatch:
    """Trim each instance's timeline, then pad-and-stack the batch.

    ``pad_to=(n, m, D, Tp)`` sets *minimum* padded dims — warm-started
    sweeps pack every group to one common shape so all groups share one
    compiled solve and states align lane-for-lane without re-padding.
    ``assume_trimmed`` skips the (idempotent) per-instance trim for
    callers that already hold trimmed instances — e.g. the FleetEngine,
    which trims once up front to plan its shape buckets.
    """
    problems = list(problems)
    if not problems:
        raise ValueError("pack_problems needs at least one instance")
    trimmed = []
    for p in problems:
        if p.n == 0:
            raise ValueError("cannot batch an empty instance")
        trimmed.append(p if assume_trimmed else trim_timeline(p)[0])
    n = max(t.n for t in trimmed)
    m = max(t.m for t in trimmed)
    D = max(t.D for t in trimmed)
    Tp = max(t.T for t in trimmed)
    if pad_to is not None:
        n, m, D, Tp = (max(n, pad_to[0]), max(m, pad_to[1]),
                       max(D, pad_to[2]), max(Tp, pad_to[3]))
    B = len(trimmed)

    dem = np.zeros((B, n, D))
    start = np.zeros((B, n), np.int32)
    end = np.zeros((B, n), np.int32)
    cap = np.ones((B, m, D))
    cost = np.full((B, m), PAD_COST)
    feas = np.zeros((B, n, m), bool)
    task_mask = np.zeros((B, n), bool)
    type_mask = np.zeros((B, m), bool)
    for b, t in enumerate(trimmed):
        dem[b, : t.n, : t.D] = t.dem
        start[b, : t.n] = t.start
        end[b, : t.n] = t.end
        cap[b, : t.m, : t.D] = t.node_types.cap
        cost[b, : t.m] = t.node_types.cost
        feas[b, : t.n, : t.m] = feasible_types(t)
        feas[b, t.n :, : t.m] = True  # zero-demand pad tasks fit anywhere
        task_mask[b, : t.n] = True
        type_mask[b, : t.m] = True
    return ProblemBatch(
        problems=tuple(trimmed), dem=dem, start=start, end=end, cap=cap,
        cost=cost, feas=feas, task_mask=task_mask, type_mask=type_mask,
        Tp=Tp,
    )


# --- projections -----------------------------------------------------------
# Water-filling thresholds found by Newton's method on the piecewise-linear
# residual instead of a sort: XLA's sort lowers to an element-serial
# comparator loop on CPU, which would put a batch-size-independent floor
# under every PDHG iteration, while Newton is pure element-wise arithmetic
# that vectorizes across everything the engine stacks.  Starting left of
# the root, the iteration is monotone; with <= m breakpoints it is *exact*
# for the task simplex after m steps.

_NEWTON_ITERS_Y = 12


def _project_simplex_masked(v, mask):
    """Project rows (last axis) of v onto the simplex over mask==True."""
    neg = jnp.finfo(v.dtype).min
    theta = jnp.where(mask, v, neg).max(axis=-1, keepdims=True) - 1.0
    # unrolled so XLA fuses the whole chain into a handful of kernels
    # (a fori_loop would re-dispatch ~6 tiny ops per Newton step)
    for _ in range(v.shape[-1] + 1):  # piecewise-linear: exact in m+1 steps
        r = jnp.sum(jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0),
                    axis=-1, keepdims=True)
        k = jnp.sum(jnp.where(mask, (v > theta), False), axis=-1,
                    keepdims=True)
        theta = theta + (r - 1.0) / jnp.maximum(k, 1)
    out = jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0)
    return out / (out.sum(axis=-1, keepdims=True) + 1e-30)


def _project_capped_simplex_td(y, cap):
    """Project y (B, T', m, D) onto {y >= 0, sum_{t,d} y <= cap} per (b, m).

    cap: (B, 1, m, 1).  Axis-aware so the dual iterate never needs a
    transpose inside the scan.
    """
    y = jnp.maximum(y, 0.0)
    total = jnp.sum(y, axis=(1, 3), keepdims=True)
    theta = jnp.zeros_like(total)
    for _ in range(_NEWTON_ITERS_Y):  # unrolled: see _project_simplex_masked
        r = jnp.sum(jnp.maximum(y - theta, 0.0), axis=(1, 3), keepdims=True)
        k = jnp.sum(y > theta, axis=(1, 3), keepdims=True)
        theta = theta + jnp.maximum(r - cap, 0.0) / jnp.maximum(k, 1)
    shrunk = jnp.maximum(y - theta, 0.0)
    # scale out any Newton residue: keeps sum <= cap exactly, so the dual
    # value G(y) stays a certified lower bound
    ssum = jnp.sum(shrunk, axis=(1, 3), keepdims=True)
    shrunk = shrunk * (cap / jnp.maximum(ssum, cap))
    return jnp.where(total <= cap, y, shrunk)


# --- congestion operator, three interchangeable forms ----------------------

def _make_operators(w_all, start, end, Tp: int, operator: str):
    """fwd_all: (B, n, m) -> (B, T', m, D); adj_all: its exact adjoint.

    All layouts are chosen so the scan body is transpose-free: the dual
    iterate lives as (B, T', m, D), weights as (B, n, m, D), and the two
    activity layouts are materialized once outside the scan.  Each form
    applies the whole batch in O(1) XLA ops — at sweep sizes per-op
    dispatch dominates, so the batch must live *inside* single ops for
    batching to pay off.
    """
    B, n, m, D = w_all.shape
    w_flat = w_all.reshape(B, n, m * D)

    if operator == "dense":
        t_ids = jnp.arange(Tp, dtype=jnp.int32)
        act_nt = ((start[:, :, None] <= t_ids[None, None, :])
                  & (t_ids[None, None, :] <= end[:, :, None])
                  ).astype(jnp.float32)  # (B, n, T')
        act_tn = act_nt.transpose(0, 2, 1)  # (B, T', n)

        def fwd_all(xv):
            xw = (xv[..., None] * w_all).reshape(B, n, m * D)
            return jnp.matmul(act_tn, xw).reshape(B, Tp, m, D)

        def adj_all(yv):
            z = jnp.matmul(act_nt, yv.reshape(B, Tp, m * D))
            return jnp.sum(z.reshape(B, n, m, D) * w_all, axis=3)
        return fwd_all, adj_all

    if operator == "cumsum":
        # O((n+T)D) difference-array form: scatter +xw at start, -xw past
        # end, prefix-sum over time; adjoint reads span sums off an
        # exclusive prefix-sum.  One batched scatter/gather per apply.
        def fwd_all(xv):
            xw = (xv[..., None] * w_all).reshape(B, n, m * D)

            def one(xw_b, s_b, e_b):
                delta = jnp.zeros((Tp + 1, m * D), xw_b.dtype)
                delta = delta.at[s_b].add(xw_b)
                delta = delta.at[e_b + 1].add(-xw_b)
                return jnp.cumsum(delta[:Tp], axis=0)

            return jax.vmap(one)(xw, start, end).reshape(B, Tp, m, D)

        def adj_all(yv):
            C = jnp.cumsum(yv.reshape(B, Tp, m * D), axis=1)
            Cx = jnp.concatenate([jnp.zeros_like(C[:, :1]), C], axis=1)

            def one(Cx_b, s_b, e_b):
                return Cx_b[e_b + 1] - Cx_b[s_b]  # (n, m*D) span sums

            span = jax.vmap(one)(Cx, start, end).reshape(B, n, m, D)
            return jnp.sum(span * w_all, axis=3)
        return fwd_all, adj_all

    if operator == "pallas":
        from repro.kernels import ops as kops

        # one (B*m)-group kernel launch per forward: group g = b*m + B
        start_g = jnp.repeat(start, m, axis=0)
        end_g = jnp.repeat(end, m, axis=0)
        w_g = w_all.transpose(0, 2, 1, 3).reshape(B * m, n, D)

        def fwd_all(xv):
            x_g = xv.transpose(0, 2, 1).reshape(B * m, n)
            cong = kops.congestion_many(start_g, end_g,
                                        w_g * x_g[:, :, None], Tp)
            return cong.reshape(B, m, Tp, D).transpose(0, 2, 1, 3)

        _, adj_cumsum = _make_operators(w_all, start, end, Tp, "cumsum")
        return fwd_all, adj_cumsum  # adjoint of the same linear map

    raise ValueError(f"unknown operator {operator!r}")


def _power_op_norm(fwd_all, adj_all, feas, power_iters: int):
    """||A||_2 per instance: power iteration on A^T A from the
    (nonnegative, deterministic, padding-invariant) feasibility pattern."""
    v = feas.astype(jnp.float32)
    norm = jnp.ones((feas.shape[0],), jnp.float32)
    for _ in range(power_iters):
        v2 = adj_all(fwd_all(v))
        norm = jnp.sqrt(jnp.sum(v2 * v2, axis=(1, 2)))
        v = v2 / (norm[:, None, None] + 1e-30)
    return jnp.sqrt(norm)


def _objectives(Ax, y, adj_all, cost, feas):
    """(primal, dual, normalized gap) per lane, from a cached forward
    apply.  The normalized gap is the KKT-residual proxy: both iterates
    are kept exactly feasible by their projections, so the duality gap is
    the full KKT error."""
    primal = jnp.sum(cost * Ax.max(axis=(1, 3)), axis=1)
    wty = jnp.where(feas, adj_all(y), jnp.inf)
    dual = jnp.sum(wty.min(axis=2), axis=1)
    rel = (primal - dual) / (1.0 + jnp.abs(primal) + jnp.abs(dual))
    return primal, dual, rel


# --- legacy fixed-step engine (tol=None; golden-table bit-stable) ----------

@functools.partial(jax.jit,
                   static_argnames=("iters", "Tp", "operator", "power_iters"))
def _pdhg_run_many(w_all, start, end, feas, cost, step_scale, iters: int,
                   Tp: int, operator: str = "cumsum", power_iters: int = 12,
                   x0=None, y0=None):
    B, n, m, D = w_all.shape
    fwd_all, adj_all = _make_operators(w_all, start, end, Tp, operator)

    op_norm = _power_op_norm(fwd_all, adj_all, feas, power_iters)
    tau = (step_scale / (op_norm + 1e-30))[:, None, None]        # vs (B,n,m)
    sigma = tau[..., None]                                    # vs (B,T',m,D)
    cap = cost[:, None, :, None]                              # vs (B,T',m,D)

    if x0 is None:
        x = feas.astype(jnp.float32)
        x = x / x.sum(axis=2, keepdims=True)
    else:
        x = _project_simplex_masked(x0, feas)
    if y0 is None:
        y = jnp.zeros((B, Tp, m, D), jnp.float32)
    else:
        y = _project_capped_simplex_td(y0, cap)

    def step(carry, _):
        x, y, x_prev = carry
        x_bar = 2.0 * x - x_prev
        y_new = _project_capped_simplex_td(y + sigma * fwd_all(x_bar), cap)
        x_new = _project_simplex_masked(x - tau * adj_all(y_new), feas)
        return (x_new, y_new, x), None

    (x, y, _), _ = jax.lax.scan(step, (x, y, x), None, length=iters)

    primal, dual, rel_gap = _objectives(fwd_all(x), y, adj_all, cost, feas)
    return x, y, primal, dual, rel_gap


# --- adaptive restarted engine (tol mode; PDLP-style) ----------------------
# Restart sufficient-decay factor: restart an epoch once the best of
# {current, average} iterate improves the normalized gap to below
# _RESTART_BETA x the gap at the last restart.
_RESTART_BETA = 0.5
# Adaptive step-size clip around the power-iteration baseline: the ratio
# test drives eta, these only stop a degenerate lane (zero interaction
# many checks in a row) from running eta to inf/0.
_ETA_CLIP = 1e4


class _TolCarry(NamedTuple):
    x: jnp.ndarray        # (B, n, m) primal iterate
    x_prev: jnp.ndarray   # momentum partner
    Ax: jnp.ndarray       # (B, T', m, D) cached forward apply of x
    Ax_prev: jnp.ndarray
    y: jnp.ndarray        # (B, T', m, D) dual iterate
    eta: jnp.ndarray      # (B,) per-lane step size (tau = sigma = eta)
    k: jnp.ndarray        # scalar: outer attempted-iteration count
    iters_b: jnp.ndarray  # (B,) per-lane iterations-to-tolerance
    conv: jnp.ndarray     # (B,) converged mask — frozen lanes
    restarts_b: jnp.ndarray  # (B,)
    gap_b: jnp.ndarray    # (B,) latest normalized gap per lane
    last_gap: jnp.ndarray  # (B,) gap at last restart (criterion anchor)
    sum_x: jnp.ndarray    # epoch average accumulators (restart mode)
    sum_y: jnp.ndarray
    sum_Ax: jnp.ndarray
    elen: jnp.ndarray     # (B,) epoch length


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "check_every", "Tp",
                                    "operator", "adaptive", "restart",
                                    "power_iters"))
def _pdhg_run_many_tol(w_all, start, end, feas, cost, step_scale, tol,
                       max_iters: int, check_every: int, Tp: int,
                       operator: str = "cumsum", adaptive: bool = True,
                       restart: bool = True, power_iters: int = 12,
                       x0=None, y0=None, eta_init=None):
    """Adaptive restarted PDHG with per-lane tolerance stopping.

    One fused stepper for the whole batch: ``check_every`` inner PDHG
    iterations (adaptive per-lane step sizes via the PDLP backtracking
    ratio test — a rejected attempt keeps the iterate and shrinks that
    lane's step below the ratio bound, so backtracking unrolls across
    the loop instead of nesting one), then a convergence/restart check,
    inside an early-exit ``lax.while_loop`` that runs until every lane's
    normalized duality gap is <= tol (or ``max_iters``).  Converged
    lanes freeze via masked updates but keep riding along until the
    whole batch is done — that is the batched analogue of PDLP's
    per-problem termination.
    """
    B, n, m, D = w_all.shape
    fwd_all, adj_all = _make_operators(w_all, start, end, Tp, operator)

    op_norm = _power_op_norm(fwd_all, adj_all, feas, power_iters)
    eta0 = step_scale / (op_norm + 1e-30)                     # (B,)
    cap = cost[:, None, :, None]

    if x0 is None:
        x = feas.astype(jnp.float32)
        x = x / x.sum(axis=2, keepdims=True)
    else:
        x = _project_simplex_masked(x0, feas)
    if y0 is None:
        y = jnp.zeros((B, Tp, m, D), jnp.float32)
    else:
        y = _project_capped_simplex_td(y0, cap)
    Ax = fwd_all(x)

    def inner(_, c: _TolCarry) -> _TolCarry:
        active = ~c.conv
        sig = c.eta[:, None, None, None]
        tau = c.eta[:, None, None]
        # candidate step; fwd(2x - x_prev) folded through linearity onto
        # the cached applies, so each attempt costs one fwd + one adj
        y_c = _project_capped_simplex_td(
            c.y + sig * (2.0 * c.Ax - c.Ax_prev), cap)
        x_c = _project_simplex_masked(c.x - tau * adj_all(y_c), feas)
        Ax_c = fwd_all(x_c)
        if adaptive:
            dx = x_c - c.x
            dy = y_c - c.y
            move = 0.5 * (jnp.sum(dx * dx, axis=(1, 2))
                          + jnp.sum(dy * dy, axis=(1, 2, 3)))
            inter = jnp.abs(jnp.sum(dy * (Ax_c - c.Ax), axis=(1, 2, 3)))
            eta_bar = jnp.where(inter > 1e-20,
                                move / jnp.maximum(inter, 1e-20), jnp.inf)
            accept = c.eta <= eta_bar
            # kk starts at 2 so the decay factor is never exactly 0 (a
            # k=0 reject would zero eta for good); a lane with no
            # interaction (eta_bar = inf, e.g. x pinned by single-type
            # feasibility) must fall through to the growth term, not
            # evaluate inf * factor (whose 0-factor case is NaN)
            kk = (c.k + 2).astype(jnp.float32)
            shrink = jnp.where(jnp.isfinite(eta_bar),
                               eta_bar * (1.0 - kk ** -0.3), jnp.inf)
            eta_next = jnp.minimum(shrink, c.eta * (1.0 + kk ** -0.6))
            eta_next = jnp.clip(eta_next, eta0 / _ETA_CLIP, eta0 * _ETA_CLIP)
        else:
            accept = jnp.ones((B,), bool)
            eta_next = c.eta
        upd = active & accept
        u3 = upd[:, None, None]
        u4 = upd[:, None, None, None]
        new = c._replace(
            x=jnp.where(u3, x_c, c.x),
            x_prev=jnp.where(u3, c.x, c.x_prev),
            Ax=jnp.where(u4, Ax_c, c.Ax),
            Ax_prev=jnp.where(u4, c.Ax, c.Ax_prev),
            y=jnp.where(u4, y_c, c.y),
            eta=jnp.where(active, eta_next, c.eta),
            k=c.k + 1,
            iters_b=c.iters_b + active.astype(jnp.int32),
        )
        if restart:
            new = new._replace(
                sum_x=c.sum_x + jnp.where(u3, x_c, 0.0),
                sum_y=c.sum_y + jnp.where(u4, y_c, 0.0),
                sum_Ax=c.sum_Ax + jnp.where(u4, Ax_c, 0.0),
                elen=c.elen + upd.astype(jnp.float32),
            )
        return new

    def body(c: _TolCarry) -> _TolCarry:
        # never overshoot the cap: the final chunk shrinks to the
        # remaining budget (traced bound -> dynamic fori length)
        c = jax.lax.fori_loop(0, jnp.minimum(check_every, max_iters - c.k),
                              inner, c)
        _, _, gap_cur = _objectives(c.Ax, c.y, adj_all, cost, feas)
        if restart:
            den = jnp.maximum(c.elen, 1.0)
            x_avg = c.sum_x / den[:, None, None]
            y_avg = c.sum_y / den[:, None, None, None]
            Ax_avg = c.sum_Ax / den[:, None, None, None]
            _, _, gap_avg = _objectives(Ax_avg, y_avg, adj_all, cost, feas)
            gap_avg = jnp.where(c.elen > 0, gap_avg, jnp.inf)
            use_avg = gap_avg < gap_cur
            cand = jnp.minimum(gap_avg, gap_cur)
            do_r = (~c.conv) & ((cand <= _RESTART_BETA * c.last_gap)
                                | (cand <= tol))
            a3 = (do_r & use_avg)[:, None, None]
            a4 = (do_r & use_avg)[:, None, None, None]
            x = jnp.where(a3, x_avg, c.x)
            y = jnp.where(a4, y_avg, c.y)
            Ax = jnp.where(a4, Ax_avg, c.Ax)
            r3 = do_r[:, None, None]
            r4 = do_r[:, None, None, None]
            c = c._replace(
                x=x, y=y, Ax=Ax,
                # restarts reset momentum and the epoch average
                x_prev=jnp.where(r3, x, c.x_prev),
                Ax_prev=jnp.where(r4, Ax, c.Ax_prev),
                restarts_b=c.restarts_b + do_r.astype(jnp.int32),
                last_gap=jnp.where(do_r, cand, c.last_gap),
                sum_x=jnp.where(r3, 0.0, c.sum_x),
                sum_y=jnp.where(r4, 0.0, c.sum_y),
                sum_Ax=jnp.where(r4, 0.0, c.sum_Ax),
                elen=jnp.where(do_r, 0.0, c.elen),
            )
            gap_new = jnp.where(do_r, cand, gap_cur)
        else:
            gap_new = gap_cur
        gap_b = jnp.where(c.conv, c.gap_b, gap_new)
        return c._replace(gap_b=gap_b, conv=c.conv | (gap_b <= tol))

    def cond(c: _TolCarry):
        return jnp.logical_and(~jnp.all(c.conv), c.k < max_iters)

    zeros_b = jnp.zeros((B,), jnp.float32)
    eta_start = eta0 if eta_init is None else jnp.clip(
        eta_init, eta0 / _ETA_CLIP, eta0 * _ETA_CLIP)
    c = _TolCarry(
        x=x, x_prev=x, Ax=Ax, Ax_prev=Ax, y=y,
        eta=eta_start, k=jnp.int32(0),
        iters_b=jnp.zeros((B,), jnp.int32),
        conv=jnp.zeros((B,), bool),
        restarts_b=jnp.zeros((B,), jnp.int32),
        gap_b=jnp.full((B,), jnp.inf, jnp.float32),
        # normalized gap starts < 1 (dual of y=0 is 0), so 1.0 anchors
        # the first sufficient-decay restart check
        last_gap=jnp.ones((B,), jnp.float32),
        sum_x=jnp.zeros_like(x), sum_y=jnp.zeros_like(y),
        sum_Ax=jnp.zeros_like(Ax), elen=zeros_b,
    )
    c = jax.lax.while_loop(cond, body, c)

    primal, dual, rel_gap = _objectives(c.Ax, c.y, adj_all, cost, feas)
    return (c.x, c.y, primal, dual, rel_gap, c.iters_b, c.restarts_b,
            c.conv, c.eta)


# 'auto' picks the dense one-dot-per-application operator while the
# activity matrix fits comfortably in memory, else the O((n+T)D) form.
_DENSE_ACT_BUDGET = 64 * 1024 * 1024  # elements of (B, n, T')


def _align_state(state: PDHGState, batch: ProblemBatch):
    """Crop / zero-pad a previous solve's iterates to this batch's padded
    shape.  Lane b warm-starts lane b; the projections inside the engine
    re-feasibilize whatever lands outside the new feasible sets (a fresh
    task row starts uniform over its feasible types, a fresh time slot's
    dual starts at zero)."""
    if state.B != batch.B:
        raise ValueError(
            f"warm start needs matching batch sizes, got state B={state.B} "
            f"vs batch B={batch.B}")
    x0 = np.zeros((batch.B, batch.n, batch.m), np.float32)
    n_c = min(state.x.shape[1], batch.n)
    m_c = min(state.x.shape[2], batch.m)
    x0[:, :n_c, :m_c] = state.x[:, :n_c, :m_c]
    y0 = np.zeros((batch.B, batch.Tp, batch.m, batch.D), np.float32)
    T_c = min(state.y.shape[1], batch.Tp)
    D_c = min(state.y.shape[3], batch.D)
    y0[:, :T_c, :m_c, :D_c] = state.y[:, :T_c, :m_c, :D_c]
    return x0, y0, state.eta


def solve_lp_many(problems, iters: int = 2000, step_scale: float = 0.9,
                  operator: str = "auto", tol: float | None = None,
                  adaptive: bool = True, restart: bool = True,
                  check_every: int = DEFAULT_CHECK_EVERY, init: PDHGState | None = None,
                  full_output: bool = False):
    """One fused PDHG solve of the mapping LP for B instances.

    ``problems`` is a sequence of ``Problem``s or an already-packed
    ``ProblemBatch``.  Returns one ``PDHGResult`` per instance, sliced
    back to its own (n, m) shapes: primal upper bound, certified dual
    lower bound, and the argmax-rounded mapping for the placement phase.

    ``tol=None`` runs the legacy fixed-step loop for exactly ``iters``
    iterations.  ``tol=<float>`` switches to the adaptive restarted
    engine: per-lane PDLP-style step sizes (``adaptive``), average-
    iterate restarts (``restart``), and early exit once every lane's
    normalized duality gap is <= tol — ``iters`` becomes the cap, and
    convergence is checked every ``check_every`` iterations.

    ``init`` warm-starts from a previous solve's ``PDHGState`` (shapes
    are re-aligned; lane b seeds lane b).  ``full_output=True`` returns
    ``(results, SolveStats)`` — per-instance telemetry plus the final
    state for warm-starting the next solve.
    """
    batch = problems if isinstance(problems, ProblemBatch) \
        else pack_problems(problems)
    if operator == "auto":
        operator = ("dense" if batch.B * batch.n * batch.Tp
                    <= _DENSE_ACT_BUDGET else "cumsum")
    x0 = y0 = eta_init = None
    if init is not None:
        x0, y0, eta_init = _align_state(init, batch)
        x0, y0 = jnp.asarray(x0), jnp.asarray(y0)
        if eta_init is not None:
            eta_init = jnp.asarray(eta_init, jnp.float32)
    args = (jnp.asarray(batch.weights(), jnp.float32),
            jnp.asarray(batch.start), jnp.asarray(batch.end),
            jnp.asarray(batch.feas),
            jnp.asarray(batch.cost, jnp.float32),
            jnp.float32(step_scale))
    if tol is None:
        x, y, primal, dual, rel_gap = _pdhg_run_many(
            *args, iters=iters, Tp=batch.Tp, operator=operator,
            x0=x0, y0=y0)
        iters_b = np.full(batch.B, iters, np.int64)
        restarts_b = np.zeros(batch.B, np.int64)
        conv = np.ones(batch.B, bool)
        eta_np = None
    else:
        (x, y, primal, dual, rel_gap, iters_b, restarts_b,
         conv, eta_out) = _pdhg_run_many_tol(
            *args, jnp.float32(tol), max_iters=iters,
            check_every=check_every, Tp=batch.Tp, operator=operator,
            adaptive=adaptive, restart=restart, x0=x0, y0=y0,
            eta_init=eta_init)
        iters_b = np.asarray(iters_b, np.int64)
        restarts_b = np.asarray(restarts_b, np.int64)
        conv = np.asarray(conv)
        eta_np = np.asarray(eta_out, np.float32)
    x = np.asarray(x)
    primal = np.asarray(primal)
    dual = np.asarray(dual)
    rel_gap = np.asarray(rel_gap)
    results = []
    for b, t in enumerate(batch.problems):
        x_b = x[b, : t.n, : t.m]
        feas_b = batch.feas[b, : t.n, : t.m]
        mapping = np.where(feas_b, x_b, -1.0).argmax(axis=1)
        results.append(PDHGResult(
            x=x_b,
            objective=float(primal[b]),
            lower_bound=float(dual[b]),
            gap=float(primal[b] - dual[b]),
            iters=int(iters_b[b]),
            mapping=mapping.astype(np.int64),
            x_max=x_b.max(axis=1),
            restarts=int(restarts_b[b]),
            kkt=float(rel_gap[b]),
            converged=bool(conv[b]),
        ))
    if not full_output:
        return results
    stats = SolveStats(
        iterations=iters_b, restarts=restarts_b, kkt=rel_gap,
        converged=conv, tol=tol,
        state=PDHGState(x=np.asarray(x, np.float32),
                        y=np.asarray(y, np.float32), eta=eta_np),
    )
    return results, stats


def solve_lp_sweep(groups, tol: float = DEFAULT_TOL, iters: int = 4000,
                   step_scale: float = 0.9, operator: str = "auto",
                   adaptive: bool = True, restart: bool = True,
                   check_every: int = DEFAULT_CHECK_EVERY, align_shapes: bool = True):
    """Warm-started fleet sweep: solve a grid-adjacent sequence of
    instance groups, seeding each group's primal/dual iterates from its
    predecessor's solution.

    ``groups[g]`` holds one sweep point's instances (e.g. the seed
    replicas of one grid cell), ordered so consecutive groups are
    neighbors on the sweep grid — exactly the row-major, seed-innermost
    order ``workload.sweep_specs`` emits.  Neighboring LP instances
    differ by one perturbed axis, so the previous optimum is deep inside
    the new problem's basin and the adaptive engine converges in a
    fraction of a cold start's iterations (the sweep analogue of Eva's
    incremental re-provisioning).

    With ``align_shapes`` every group is packed to one common padded
    shape, so the whole sweep reuses a single compiled solve and states
    carry over without re-alignment.  A group whose size differs from
    its predecessor's cold-starts (states match lane-for-lane only).

    Returns ``(results, stats)``: the flat per-instance ``PDHGResult``
    list (group order preserved) and one ``SolveStats`` per group.
    """
    groups = [list(g) for g in groups]
    if not groups or any(not g for g in groups):
        raise ValueError("solve_lp_sweep needs non-empty groups")
    pad_to = None
    if align_shapes:
        trimmed = [trim_timeline(p)[0] for g in groups for p in g]
        pad_to = (max(t.n for t in trimmed), max(t.m for t in trimmed),
                  max(t.D for t in trimmed), max(t.T for t in trimmed))
    results: list[PDHGResult] = []
    stats: list[SolveStats] = []
    state: PDHGState | None = None
    for g in groups:
        batch = pack_problems(g, pad_to=pad_to)
        if state is not None and state.B != batch.B:
            state = None
        res, st = solve_lp_many(
            batch, iters=iters, step_scale=step_scale, operator=operator,
            tol=tol, adaptive=adaptive, restart=restart,
            check_every=check_every, init=state, full_output=True)
        results.extend(res)
        stats.append(st)
        state = st.state
    return results, stats
