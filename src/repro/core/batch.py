"""Batched many-instance LP engine: pad-and-stack + one fused PDHG solve.

The paper's §VI protocol (and every fleet-scale sweep in the related
work) evaluates algorithms over *grids* of instances.  This module packs
B ``Problem`` instances into ragged-safe ``(B, ...)`` arrays and runs the
matrix-free PDHG mapping LP for all of them in a single compiled solve —
the whole iteration (congestion operator, adjoint, both projections) is
batched, so one ``lax.scan`` over iterations advances every instance at
once instead of B sequential solves.

Padding scheme (exact — padded coordinates never perturb real ones):

  * tasks      — zero demand, span [0, 0]: zero operator weight, zero
                 congestion, zero dual contribution;
  * node-types — unit capacity but *zero operator weight* and an
                 effectively-infinite price (``PAD_COST``), masked
                 infeasible for every task so ``x`` never selects them;
  * dimensions — zero demand over unit capacity: zero weight;
  * timeline   — slots past an instance's trimmed T' have no active
                 task, so congestion and the (zero-initialized) dual
                 iterate stay identically zero there.

Both simplex projections are padding-exact as well: appended ``-inf``/
zero entries never enter the sorted-threshold count, so the projected
real coordinates match the unbatched projection bit-for-bit up to float
reassociation.  ``solve_lp_pdhg`` is the B=1 special case of this engine,
so the per-instance and batched paths share one implementation.

The forward map can run through the batch-dim-aware Pallas congestion
kernel (``operator='pallas'``, grid over B; see kernels/congestion.py),
the dense mask-matmul form it implements (``'dense'``), or the O((n+T)D)
difference-array form (``'cumsum'``, the default).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .lp_pdhg import PDHGResult
from .problem import Problem, feasible_types, trim_timeline

__all__ = ["ProblemBatch", "pack_problems", "solve_lp_many", "PAD_COST"]

# Padded node-types carry this price: they never accrue congestion (their
# operator weight is zeroed), so they contribute exactly 0 to the primal,
# but any accidental use would be unmissable in the objective.
PAD_COST = 1e9


@dataclasses.dataclass(frozen=True)
class ProblemBatch:
    """B timeline-trimmed instances padded to common (n, m, D, T') shapes.

    problems: the trimmed per-instance ``Problem``s (for unpacking).
    dem:   (B, n, D) float64, padded tasks/dims zero.
    start: (B, n) int32, padded tasks [0, 0].
    end:   (B, n) int32.
    cap:   (B, m, D) float64, padded types/dims one.
    cost:  (B, m) float64, padded types ``PAD_COST``.
    feas:  (B, n, m) bool — per-instance feasible pairs; padded tasks may
           use any *real* type (zero demand fits everywhere), padded
           types are feasible for no task.
    task_mask: (B, n) bool; type_mask: (B, m) bool.
    Tp: common (max) trimmed timeline length.
    """

    problems: tuple[Problem, ...]
    dem: np.ndarray
    start: np.ndarray
    end: np.ndarray
    cap: np.ndarray
    cost: np.ndarray
    feas: np.ndarray
    task_mask: np.ndarray
    type_mask: np.ndarray
    Tp: int

    @property
    def B(self) -> int:
        return self.dem.shape[0]

    @property
    def n(self) -> int:
        return self.dem.shape[1]

    @property
    def m(self) -> int:
        return self.cap.shape[1]

    @property
    def D(self) -> int:
        return self.dem.shape[2]

    def weights(self) -> np.ndarray:
        """(B, n, m, D) operator weights dem/cap, zeroed on padding."""
        w = self.dem[:, :, None, :] / self.cap[:, None, :, :]
        return w * self.type_mask[:, None, :, None]


def pack_problems(problems) -> ProblemBatch:
    """Trim each instance's timeline, then pad-and-stack the batch."""
    problems = list(problems)
    if not problems:
        raise ValueError("pack_problems needs at least one instance")
    trimmed = []
    for p in problems:
        if p.n == 0:
            raise ValueError("cannot batch an empty instance")
        trimmed.append(trim_timeline(p)[0])
    n = max(t.n for t in trimmed)
    m = max(t.m for t in trimmed)
    D = max(t.D for t in trimmed)
    Tp = max(t.T for t in trimmed)
    B = len(trimmed)

    dem = np.zeros((B, n, D))
    start = np.zeros((B, n), np.int32)
    end = np.zeros((B, n), np.int32)
    cap = np.ones((B, m, D))
    cost = np.full((B, m), PAD_COST)
    feas = np.zeros((B, n, m), bool)
    task_mask = np.zeros((B, n), bool)
    type_mask = np.zeros((B, m), bool)
    for b, t in enumerate(trimmed):
        dem[b, : t.n, : t.D] = t.dem
        start[b, : t.n] = t.start
        end[b, : t.n] = t.end
        cap[b, : t.m, : t.D] = t.node_types.cap
        cost[b, : t.m] = t.node_types.cost
        feas[b, : t.n, : t.m] = feasible_types(t)
        feas[b, t.n :, : t.m] = True  # zero-demand pad tasks fit anywhere
        task_mask[b, : t.n] = True
        type_mask[b, : t.m] = True
    return ProblemBatch(
        problems=tuple(trimmed), dem=dem, start=start, end=end, cap=cap,
        cost=cost, feas=feas, task_mask=task_mask, type_mask=type_mask,
        Tp=Tp,
    )


# --- projections -----------------------------------------------------------
# Water-filling thresholds found by Newton's method on the piecewise-linear
# residual instead of a sort: XLA's sort lowers to an element-serial
# comparator loop on CPU, which would put a batch-size-independent floor
# under every PDHG iteration, while Newton is pure element-wise arithmetic
# that vectorizes across everything the engine stacks.  Starting left of
# the root, the iteration is monotone; with <= m breakpoints it is *exact*
# for the task simplex after m steps.

_NEWTON_ITERS_Y = 12


def _project_simplex_masked(v, mask):
    """Project rows (last axis) of v onto the simplex over mask==True."""
    neg = jnp.finfo(v.dtype).min
    theta = jnp.where(mask, v, neg).max(axis=-1, keepdims=True) - 1.0
    # unrolled so XLA fuses the whole chain into a handful of kernels
    # (a fori_loop would re-dispatch ~6 tiny ops per Newton step)
    for _ in range(v.shape[-1] + 1):  # piecewise-linear: exact in m+1 steps
        r = jnp.sum(jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0),
                    axis=-1, keepdims=True)
        k = jnp.sum(jnp.where(mask, (v > theta), False), axis=-1,
                    keepdims=True)
        theta = theta + (r - 1.0) / jnp.maximum(k, 1)
    out = jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0)
    return out / (out.sum(axis=-1, keepdims=True) + 1e-30)


def _project_capped_simplex_td(y, cap):
    """Project y (B, T', m, D) onto {y >= 0, sum_{t,d} y <= cap} per (b, m).

    cap: (B, 1, m, 1).  Axis-aware so the dual iterate never needs a
    transpose inside the scan.
    """
    y = jnp.maximum(y, 0.0)
    total = jnp.sum(y, axis=(1, 3), keepdims=True)
    theta = jnp.zeros_like(total)
    for _ in range(_NEWTON_ITERS_Y):  # unrolled: see _project_simplex_masked
        r = jnp.sum(jnp.maximum(y - theta, 0.0), axis=(1, 3), keepdims=True)
        k = jnp.sum(y > theta, axis=(1, 3), keepdims=True)
        theta = theta + jnp.maximum(r - cap, 0.0) / jnp.maximum(k, 1)
    shrunk = jnp.maximum(y - theta, 0.0)
    # scale out any Newton residue: keeps sum <= cap exactly, so the dual
    # value G(y) stays a certified lower bound
    ssum = jnp.sum(shrunk, axis=(1, 3), keepdims=True)
    shrunk = shrunk * (cap / jnp.maximum(ssum, cap))
    return jnp.where(total <= cap, y, shrunk)


# --- congestion operator, three interchangeable forms ----------------------

def _make_operators(w_all, start, end, Tp: int, operator: str):
    """fwd_all: (B, n, m) -> (B, T', m, D); adj_all: its exact adjoint.

    All layouts are chosen so the scan body is transpose-free: the dual
    iterate lives as (B, T', m, D), weights as (B, n, m, D), and the two
    activity layouts are materialized once outside the scan.  Each form
    applies the whole batch in O(1) XLA ops — at sweep sizes per-op
    dispatch dominates, so the batch must live *inside* single ops for
    batching to pay off.
    """
    B, n, m, D = w_all.shape
    w_flat = w_all.reshape(B, n, m * D)

    if operator == "dense":
        t_ids = jnp.arange(Tp, dtype=jnp.int32)
        act_nt = ((start[:, :, None] <= t_ids[None, None, :])
                  & (t_ids[None, None, :] <= end[:, :, None])
                  ).astype(jnp.float32)  # (B, n, T')
        act_tn = act_nt.transpose(0, 2, 1)  # (B, T', n)

        def fwd_all(xv):
            xw = (xv[..., None] * w_all).reshape(B, n, m * D)
            return jnp.matmul(act_tn, xw).reshape(B, Tp, m, D)

        def adj_all(yv):
            z = jnp.matmul(act_nt, yv.reshape(B, Tp, m * D))
            return jnp.sum(z.reshape(B, n, m, D) * w_all, axis=3)
        return fwd_all, adj_all

    if operator == "cumsum":
        # O((n+T)D) difference-array form: scatter +xw at start, -xw past
        # end, prefix-sum over time; adjoint reads span sums off an
        # exclusive prefix-sum.  One batched scatter/gather per apply.
        def fwd_all(xv):
            xw = (xv[..., None] * w_all).reshape(B, n, m * D)

            def one(xw_b, s_b, e_b):
                delta = jnp.zeros((Tp + 1, m * D), xw_b.dtype)
                delta = delta.at[s_b].add(xw_b)
                delta = delta.at[e_b + 1].add(-xw_b)
                return jnp.cumsum(delta[:Tp], axis=0)

            return jax.vmap(one)(xw, start, end).reshape(B, Tp, m, D)

        def adj_all(yv):
            C = jnp.cumsum(yv.reshape(B, Tp, m * D), axis=1)
            Cx = jnp.concatenate([jnp.zeros_like(C[:, :1]), C], axis=1)

            def one(Cx_b, s_b, e_b):
                return Cx_b[e_b + 1] - Cx_b[s_b]  # (n, m*D) span sums

            span = jax.vmap(one)(Cx, start, end).reshape(B, n, m, D)
            return jnp.sum(span * w_all, axis=3)
        return fwd_all, adj_all

    if operator == "pallas":
        from repro.kernels import ops as kops

        # one (B*m)-group kernel launch per forward: group g = b*m + B
        start_g = jnp.repeat(start, m, axis=0)
        end_g = jnp.repeat(end, m, axis=0)
        w_g = w_all.transpose(0, 2, 1, 3).reshape(B * m, n, D)

        def fwd_all(xv):
            x_g = xv.transpose(0, 2, 1).reshape(B * m, n)
            cong = kops.congestion_many(start_g, end_g,
                                        w_g * x_g[:, :, None], Tp)
            return cong.reshape(B, m, Tp, D).transpose(0, 2, 1, 3)

        _, adj_cumsum = _make_operators(w_all, start, end, Tp, "cumsum")
        return fwd_all, adj_cumsum  # adjoint of the same linear map

    raise ValueError(f"unknown operator {operator!r}")


@functools.partial(jax.jit,
                   static_argnames=("iters", "Tp", "operator", "power_iters"))
def _pdhg_run_many(w_all, start, end, feas, cost, step_scale, iters: int,
                   Tp: int, operator: str = "cumsum", power_iters: int = 12):
    B, n, m, D = w_all.shape
    fwd_all, adj_all = _make_operators(w_all, start, end, Tp, operator)

    # ||A||_2 per instance: power iteration on A^T A from the (nonnegative,
    # deterministic, padding-invariant) feasibility pattern.
    v = feas.astype(jnp.float32)
    norm = jnp.ones((B,), jnp.float32)
    for _ in range(power_iters):
        v2 = adj_all(fwd_all(v))
        norm = jnp.sqrt(jnp.sum(v2 * v2, axis=(1, 2)))
        v = v2 / (norm[:, None, None] + 1e-30)
    op_norm = jnp.sqrt(norm)
    tau = (step_scale / (op_norm + 1e-30))[:, None, None]        # vs (B,n,m)
    sigma = tau[..., None]                                    # vs (B,T',m,D)
    cap = cost[:, None, :, None]                              # vs (B,T',m,D)

    x = feas.astype(jnp.float32)
    x = x / x.sum(axis=2, keepdims=True)
    y = jnp.zeros((B, Tp, m, D), jnp.float32)

    def step(carry, _):
        x, y, x_prev = carry
        x_bar = 2.0 * x - x_prev
        y_new = _project_capped_simplex_td(y + sigma * fwd_all(x_bar), cap)
        x_new = _project_simplex_masked(x - tau * adj_all(y_new), feas)
        return (x_new, y_new, x), None

    (x, y, _), _ = jax.lax.scan(step, (x, y, x), None, length=iters)

    cong = fwd_all(x)  # (B, T', m, D)
    primal = jnp.sum(cost * cong.max(axis=(1, 3)), axis=1)
    wty = adj_all(y)   # (B, n, m)
    wty = jnp.where(feas, wty, jnp.inf)
    dual = jnp.sum(wty.min(axis=2), axis=1)
    return x, primal, dual


# 'auto' picks the dense one-dot-per-application operator while the
# activity matrix fits comfortably in memory, else the O((n+T)D) form.
_DENSE_ACT_BUDGET = 64 * 1024 * 1024  # elements of (B, n, T')


def solve_lp_many(problems, iters: int = 2000, step_scale: float = 0.9,
                  operator: str = "auto") -> list[PDHGResult]:
    """One fused PDHG solve of the mapping LP for B instances.

    ``problems`` is a sequence of ``Problem``s or an already-packed
    ``ProblemBatch``.  Returns one ``PDHGResult`` per instance, sliced
    back to its own (n, m) shapes: primal upper bound, certified dual
    lower bound, and the argmax-rounded mapping for the placement phase.
    """
    batch = problems if isinstance(problems, ProblemBatch) \
        else pack_problems(problems)
    if operator == "auto":
        operator = ("dense" if batch.B * batch.n * batch.Tp
                    <= _DENSE_ACT_BUDGET else "cumsum")
    x, primal, dual = _pdhg_run_many(
        jnp.asarray(batch.weights(), jnp.float32),
        jnp.asarray(batch.start), jnp.asarray(batch.end),
        jnp.asarray(batch.feas),
        jnp.asarray(batch.cost, jnp.float32),
        jnp.float32(step_scale),
        iters=iters, Tp=batch.Tp, operator=operator,
    )
    x = np.asarray(x)
    primal = np.asarray(primal)
    dual = np.asarray(dual)
    results = []
    for b, t in enumerate(batch.problems):
        x_b = x[b, : t.n, : t.m]
        feas_b = batch.feas[b, : t.n, : t.m]
        mapping = np.where(feas_b, x_b, -1.0).argmax(axis=1)
        results.append(PDHGResult(
            x=x_b,
            objective=float(primal[b]),
            lower_bound=float(dual[b]),
            gap=float(primal[b] - dual[b]),
            iters=iters,
            mapping=mapping.astype(np.int64),
            x_max=x_b.max(axis=1),
        ))
    return results
