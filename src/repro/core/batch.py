"""Batched many-instance LP engine: pad-and-stack + one fused PDHG solve.

The paper's §VI protocol (and every fleet-scale sweep in the related
work) evaluates algorithms over *grids* of instances.  This module packs
B ``Problem`` instances into ragged-safe ``(B, ...)`` arrays and runs the
matrix-free PDHG mapping LP for all of them in a single compiled solve —
the whole iteration (congestion operator, adjoint, both projections) is
batched, so one compiled stepper advances every instance at once instead
of B sequential solves.

Two stopping regimes share the packed operator machinery:

  * ``tol=None`` — the legacy fixed-step, fixed-``iters`` vanilla
    Chambolle–Pock ``lax.scan`` (bit-stable; the golden tables pin it);
  * ``tol=<float>`` — the PDLP-style engine: per-instance adaptive
    primal/dual step sizes via the backtracking ratio test (step-size
    state carried per batch lane, so each instance adapts independently
    inside the one fused solve), average-iterate restarts triggered by a
    per-instance normalized duality-gap criterion, a vectorized
    convergence mask that freezes converged lanes (masked updates) while
    stragglers keep iterating, and an early-exit ``lax.while_loop``
    outer stepper that stops as soon as the whole batch is converged.
    ``solve_lp_many(..., init=prev_state)`` warm-starts from a previous
    solve's primal/dual iterates, and ``solve_lp_sweep`` chains that
    across a grid-adjacent sequence of sweep groups so each sweep point
    starts from its neighbor's solution.  Per-instance telemetry
    (iterations-to-tolerance, restarts, final KKT residuals) comes back
    in a ``SolveStats``.

Padding scheme (exact — padded coordinates never perturb real ones):

  * tasks      — zero demand, span [0, 0]: zero operator weight, zero
                 congestion, zero dual contribution;
  * node-types — unit capacity but *zero operator weight* and an
                 effectively-infinite price (``PAD_COST``), masked
                 infeasible for every task so ``x`` never selects them;
  * dimensions — zero demand over unit capacity: zero weight;
  * timeline   — slots past an instance's trimmed T' have no active
                 task, so congestion and the (zero-initialized) dual
                 iterate stay identically zero there.

Both simplex projections are padding-exact as well: appended ``-inf``/
zero entries never enter the sorted-threshold count, so the projected
real coordinates match the unbatched projection bit-for-bit up to float
reassociation.  ``solve_lp_pdhg`` is the B=1 special case of this engine,
so the per-instance and batched paths share one implementation.

The forward map can run through the batch-dim-aware Pallas congestion
kernel (``operator='pallas'``, grid over B; see kernels/congestion.py),
the dense mask-matmul form it implements (``'dense'``), or the O((n+T)D)
difference-array form (``'cumsum'``, the default).

The fleet-scale speed layer (all tol-mode only; the legacy path is
untouched):

  * ``scaling='ruiz'`` — iterated Ruiz equilibration of the packed
    operator.  Per-task column scales ``c`` and per-type row scales
    ``r`` turn ``w`` into ``w * r / c``; the change of variables is
    exact (task simplices carry mass ``c``, dual caps become
    ``cost / r``), so certified objectives are original-scale values
    and only the iteration trajectory changes.
  * ``omega=True`` — PDLP-style primal-weight balancing: per-lane
    ``omega`` splits the step into ``tau = eta / omega`` and
    ``sigma = eta * omega`` and is re-estimated at every restart from
    the primal/dual movement ratio of the closing epoch.
  * ``precision='mixed'`` (default) / ``'f64'`` — mixed precision runs
    the f32 iterate with an f64 KKT certificate and a final f64 polish
    pass (kept per lane only where it tightens the gap); 'f64' runs the
    whole iterate in f64.  Both trace under a scoped ``enable_x64``
    (the compiled placement stepper's discipline), so the process-wide
    precision default is untouched.
  * ``solve_lp_sweep(..., pipeline=True)`` — the warm-started sweep
    chain compiled into ONE ``lax.scan`` over groups (one device
    dispatch for the whole chain), optionally sharded over the batch
    dim across local devices via ``shard_map`` (``devices=``).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .lp_pdhg import PDHGResult, PDHGState, SolveStats
from .problem import (Problem, feasible_types, require_lowered,
                      trim_timeline)

__all__ = ["ProblemBatch", "pack_problems", "solve_lp_many",
           "solve_lp_sweep", "PAD_COST", "DEFAULT_TOL",
           "DEFAULT_CHECK_EVERY", "SCALINGS", "PRECISIONS",
           "CANONICAL_MARGIN", "dispatch_count"]

# Padded node-types carry this price: they never accrue congestion (their
# operator weight is zeroed), so they contribute exactly 0 to the primal,
# but any accidental use would be unmissable in the objective.
PAD_COST = 1e9

# Default normalized-duality-gap tolerance of the adaptive engine: a 0.5%
# certified relative gap.  Near-integrality (paper Fig 5) keeps the argmax
# mapping — and therefore the §VI protocol costs — stable at this gap, so
# tolerance-stopped solves place identically to converged ones.
DEFAULT_TOL = 5e-3

# Default convergence-check cadence of the tol-mode engine: iteration
# counts quantize to this interval, so telemetry consumers (the CI gate's
# quantum slack, test tolerances) must read it from here, not hardcode it.
DEFAULT_CHECK_EVERY = 25

# Valid sets of the tol-mode speed-layer knobs (SolverConfig validates
# against these, so the solver and the config never disagree).
SCALINGS = ("none", "ruiz")
PRECISIONS = ("f64", "mixed")

# Ruiz equilibration sweeps: inf-norm Ruiz converges geometrically, so a
# handful of sweeps lands within a few percent of doubly-balanced.
_RUIZ_ITERS = 8

# Primal-weight clip: omega is dimensionless (1 = the classic symmetric
# tau = sigma = eta split), so an absolute band keeps a degenerate lane's
# movement ratio from running the split to extremes.
_OMEGA_CLIP = 1e2

# Final f64 polish pass of precision='mixed': a few plain PDHG steps at
# the adapted step size, computed in f64 from the f32 solution, kept per
# lane only where they tighten the certified gap.
_POLISH_ITERS = 10

# Canonical-rounding margin: a type whose relaxed mass is within this of
# the per-task max is treated as epsilon-optimal-equivalent, and the
# winner among those is picked by problem data (cheapest cost, then
# lowest index) instead of by trajectory-dependent float noise.  Must sit
# well above the cross-trajectory iterate noise at DEFAULT_TOL (~1e-2 on
# degenerate ties) and well below real argmax gaps (near-integrality,
# paper Fig 5: contested tasks split ~0.5 vs ~0.99 for settled ones).
CANONICAL_MARGIN = 0.05

# Host-side count of compiled-solver invocations (the pipelined sweep's
# "exactly ONE dispatch" claim is measured, not asserted by construction:
# benchmarks snapshot this counter around the call).
_DISPATCH_COUNT = 0


def dispatch_count() -> int:
    """Number of compiled LP-solver entry-point invocations so far in
    this process (legacy, tol, and pipeline steppers all count 1 per
    host-level call)."""
    return _DISPATCH_COUNT


def _count_dispatch() -> None:
    global _DISPATCH_COUNT
    _DISPATCH_COUNT += 1


@dataclasses.dataclass(frozen=True)
class ProblemBatch:
    """B timeline-trimmed instances padded to common (n, m, D, T') shapes.

    problems: the trimmed per-instance ``Problem``s (for unpacking).
    dem:   (B, n, D) float64, padded tasks/dims zero.
    start: (B, n) int32, padded tasks [0, 0].
    end:   (B, n) int32.
    cap:   (B, m, D) float64, padded types/dims one.
    cost:  (B, m) float64, padded types ``PAD_COST``.
    feas:  (B, n, m) bool — per-instance feasible pairs; padded tasks may
           use any *real* type (zero demand fits everywhere), padded
           types are feasible for no task.
    task_mask: (B, n) bool; type_mask: (B, m) bool.
    Tp: common (max) trimmed timeline length.
    """

    problems: tuple[Problem, ...]
    dem: np.ndarray
    start: np.ndarray
    end: np.ndarray
    cap: np.ndarray
    cost: np.ndarray
    feas: np.ndarray
    task_mask: np.ndarray
    type_mask: np.ndarray
    Tp: int

    @property
    def B(self) -> int:
        return self.dem.shape[0]

    @property
    def n(self) -> int:
        return self.dem.shape[1]

    @property
    def m(self) -> int:
        return self.cap.shape[1]

    @property
    def D(self) -> int:
        return self.dem.shape[2]

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """The common padded (n, m, D, T') every instance was packed to
        — the ``pad_to`` that reproduces this batch's layout (what the
        engine's shard dispatch passes so shards share one compile)."""
        return (self.n, self.m, self.D, self.Tp)

    def weights(self) -> np.ndarray:
        """(B, n, m, D) operator weights dem/cap, zeroed on padding."""
        w = self.dem[:, :, None, :] / self.cap[:, None, :, :]
        return w * self.type_mask[:, None, :, None]


def pack_problems(problems, pad_to=None,
                  assume_trimmed: bool = False) -> ProblemBatch:
    """Trim each instance's timeline, then pad-and-stack the batch.

    ``pad_to=(n, m, D, Tp)`` sets *minimum* padded dims — warm-started
    sweeps pack every group to one common shape so all groups share one
    compiled solve and states align lane-for-lane without re-padding.
    ``assume_trimmed`` skips the (idempotent) per-instance trim for
    callers that already hold trimmed instances — e.g. the FleetEngine,
    which trims once up front to plan its shape buckets.
    """
    problems = list(problems)
    if not problems:
        raise ValueError("pack_problems needs at least one instance")
    trimmed = []
    for p in problems:
        if p.n == 0:
            raise ValueError("cannot batch an empty instance")
        require_lowered(p, "pack_problems")
        trimmed.append(p if assume_trimmed else trim_timeline(p)[0])
    n = max(t.n for t in trimmed)
    m = max(t.m for t in trimmed)
    D = max(t.D for t in trimmed)
    Tp = max(t.T for t in trimmed)
    if pad_to is not None:
        n, m, D, Tp = (max(n, pad_to[0]), max(m, pad_to[1]),
                       max(D, pad_to[2]), max(Tp, pad_to[3]))
    B = len(trimmed)

    dem = np.zeros((B, n, D))
    start = np.zeros((B, n), np.int32)
    end = np.zeros((B, n), np.int32)
    cap = np.ones((B, m, D))
    cost = np.full((B, m), PAD_COST)
    feas = np.zeros((B, n, m), bool)
    task_mask = np.zeros((B, n), bool)
    type_mask = np.zeros((B, m), bool)
    for b, t in enumerate(trimmed):
        dem[b, : t.n, : t.D] = t.dem
        start[b, : t.n] = t.start
        end[b, : t.n] = t.end
        cap[b, : t.m, : t.D] = t.node_types.cap
        cost[b, : t.m] = t.node_types.cost
        feas[b, : t.n, : t.m] = feasible_types(t)
        feas[b, t.n :, : t.m] = True  # zero-demand pad tasks fit anywhere
        task_mask[b, : t.n] = True
        type_mask[b, : t.m] = True
    return ProblemBatch(
        problems=tuple(trimmed), dem=dem, start=start, end=end, cap=cap,
        cost=cost, feas=feas, task_mask=task_mask, type_mask=type_mask,
        Tp=Tp,
    )


# --- projections -----------------------------------------------------------
# Water-filling thresholds found by Newton's method on the piecewise-linear
# residual instead of a sort: XLA's sort lowers to an element-serial
# comparator loop on CPU, which would put a batch-size-independent floor
# under every PDHG iteration, while Newton is pure element-wise arithmetic
# that vectorizes across everything the engine stacks.  Starting left of
# the root, the iteration is monotone; with <= m breakpoints it is *exact*
# for the task simplex after m steps.

_NEWTON_ITERS_Y = 12


def _project_simplex_masked(v, mask, mass=None):
    """Project rows (last axis) of v onto the simplex over mask==True.

    ``mass`` (broadcastable to v's row index, e.g. (B, n)) generalizes
    the target row sum from 1 to a per-row scaled simplex — the Ruiz-
    scaled primal feasible set, where task u's row carries mass c_u.
    The Newton start ``rowmax - mass`` is still left of the root, so
    the iteration stays monotone and exact in m+1 steps.  ``mass=None``
    keeps the legacy unit-simplex arithmetic bit-identical.
    """
    neg = jnp.finfo(v.dtype).min
    s = None if mass is None else mass[..., None]
    theta = (jnp.where(mask, v, neg).max(axis=-1, keepdims=True)
             - (1.0 if s is None else s))
    # unrolled so XLA fuses the whole chain into a handful of kernels
    # (a fori_loop would re-dispatch ~6 tiny ops per Newton step)
    for _ in range(v.shape[-1] + 1):  # piecewise-linear: exact in m+1 steps
        r = jnp.sum(jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0),
                    axis=-1, keepdims=True)
        k = jnp.sum(jnp.where(mask, (v > theta), False), axis=-1,
                    keepdims=True)
        theta = theta + (r - (1.0 if s is None else s)) / jnp.maximum(k, 1)
    out = jnp.where(mask, jnp.maximum(v - theta, 0.0), 0.0)
    if s is None:
        return out / (out.sum(axis=-1, keepdims=True) + 1e-30)
    return out * (s / (out.sum(axis=-1, keepdims=True) + 1e-30))


def _project_capped_simplex_td(y, cap):
    """Project y (B, T', m, D) onto {y >= 0, sum_{t,d} y <= cap} per (b, m).

    cap: (B, 1, m, 1).  Axis-aware so the dual iterate never needs a
    transpose inside the scan.
    """
    y = jnp.maximum(y, 0.0)
    total = jnp.sum(y, axis=(1, 3), keepdims=True)
    theta = jnp.zeros_like(total)
    for _ in range(_NEWTON_ITERS_Y):  # unrolled: see _project_simplex_masked
        r = jnp.sum(jnp.maximum(y - theta, 0.0), axis=(1, 3), keepdims=True)
        k = jnp.sum(y > theta, axis=(1, 3), keepdims=True)
        theta = theta + jnp.maximum(r - cap, 0.0) / jnp.maximum(k, 1)
    shrunk = jnp.maximum(y - theta, 0.0)
    # scale out any Newton residue: keeps sum <= cap exactly, so the dual
    # value G(y) stays a certified lower bound
    ssum = jnp.sum(shrunk, axis=(1, 3), keepdims=True)
    shrunk = shrunk * (cap / jnp.maximum(ssum, cap))
    return jnp.where(total <= cap, y, shrunk)


# --- congestion operator, three interchangeable forms ----------------------

def _make_operators(w_all, start, end, Tp: int, operator: str):
    """fwd_all: (B, n, m) -> (B, T', m, D); adj_all: its exact adjoint.

    All layouts are chosen so the scan body is transpose-free: the dual
    iterate lives as (B, T', m, D), weights as (B, n, m, D), and the two
    activity layouts are materialized once outside the scan.  Each form
    applies the whole batch in O(1) XLA ops — at sweep sizes per-op
    dispatch dominates, so the batch must live *inside* single ops for
    batching to pay off.
    """
    B, n, m, D = w_all.shape
    w_flat = w_all.reshape(B, n, m * D)

    if operator == "dense":
        t_ids = jnp.arange(Tp, dtype=jnp.int32)
        act_nt = ((start[:, :, None] <= t_ids[None, None, :])
                  & (t_ids[None, None, :] <= end[:, :, None])
                  ).astype(w_all.dtype)  # (B, n, T')
        act_tn = act_nt.transpose(0, 2, 1)  # (B, T', n)

        def fwd_all(xv):
            xw = (xv[..., None] * w_all).reshape(B, n, m * D)
            return jnp.matmul(act_tn, xw).reshape(B, Tp, m, D)

        def adj_all(yv):
            z = jnp.matmul(act_nt, yv.reshape(B, Tp, m * D))
            return jnp.sum(z.reshape(B, n, m, D) * w_all, axis=3)
        return fwd_all, adj_all

    if operator == "cumsum":
        # O((n+T)D) difference-array form: scatter +xw at start, -xw past
        # end, prefix-sum over time; adjoint reads span sums off an
        # exclusive prefix-sum.  One batched scatter/gather per apply.
        def fwd_all(xv):
            xw = (xv[..., None] * w_all).reshape(B, n, m * D)

            def one(xw_b, s_b, e_b):
                delta = jnp.zeros((Tp + 1, m * D), xw_b.dtype)
                delta = delta.at[s_b].add(xw_b)
                delta = delta.at[e_b + 1].add(-xw_b)
                return jnp.cumsum(delta[:Tp], axis=0)

            return jax.vmap(one)(xw, start, end).reshape(B, Tp, m, D)

        def adj_all(yv):
            C = jnp.cumsum(yv.reshape(B, Tp, m * D), axis=1)
            Cx = jnp.concatenate([jnp.zeros_like(C[:, :1]), C], axis=1)

            def one(Cx_b, s_b, e_b):
                return Cx_b[e_b + 1] - Cx_b[s_b]  # (n, m*D) span sums

            span = jax.vmap(one)(Cx, start, end).reshape(B, n, m, D)
            return jnp.sum(span * w_all, axis=3)
        return fwd_all, adj_all

    if operator == "pallas":
        from repro.kernels import ops as kops

        # one (B*m)-group kernel launch per forward: group g = b*m + B
        start_g = jnp.repeat(start, m, axis=0)
        end_g = jnp.repeat(end, m, axis=0)
        w_g = w_all.transpose(0, 2, 1, 3).reshape(B * m, n, D)

        def fwd_all(xv):
            x_g = xv.transpose(0, 2, 1).reshape(B * m, n)
            cong = kops.congestion_many(start_g, end_g,
                                        w_g * x_g[:, :, None], Tp)
            return cong.reshape(B, m, Tp, D).transpose(0, 2, 1, 3)

        _, adj_cumsum = _make_operators(w_all, start, end, Tp, "cumsum")
        return fwd_all, adj_cumsum  # adjoint of the same linear map

    raise ValueError(f"unknown operator {operator!r}")


def _power_op_norm(fwd_all, adj_all, feas, power_iters: int):
    """||A||_2 per instance: power iteration on A^T A from the
    (nonnegative, deterministic, padding-invariant) feasibility pattern."""
    v = feas.astype(jnp.float32)
    norm = jnp.ones((feas.shape[0],), jnp.float32)
    for _ in range(power_iters):
        v2 = adj_all(fwd_all(v))
        norm = jnp.sqrt(jnp.sum(v2 * v2, axis=(1, 2)))
        v = v2 / (norm[:, None, None] + 1e-30)
    return jnp.sqrt(norm)


def _ruiz_scalings(w_all, iters: int = _RUIZ_ITERS):
    """Iterated Ruiz equilibration of the packed operator core.

    Returns per-task column scales ``c`` (B, n) and per-type row scales
    ``r`` (B, m) such that ``w * r / c`` has near-unit inf-norms along
    both scalable partitions.  Time slots share one row scale (activity
    is 0/1, so it never changes a row's inf-norm) and demand dimensions
    share their (b, n, m) entry's scale (the dual cap couples (t, d) per
    type, so a per-d scale would break the capped-simplex projection).
    Padded tasks/types have all-zero weight rows; their norms clamp to 1
    so their scales stay exactly 1.
    """
    B, n, m, D = w_all.shape
    c = jnp.ones((B, n), w_all.dtype)
    r = jnp.ones((B, m), w_all.dtype)
    for _ in range(iters):
        ws = w_all * (r[:, None, :, None] / c[:, :, None, None])
        col = jnp.max(ws, axis=(2, 3))  # (B, n) inf-norm over (m, d)
        row = jnp.max(ws, axis=(1, 3))  # (B, m) inf-norm over (n, d)
        c = c * jnp.sqrt(jnp.where(col > 0, col, 1.0))
        r = r / jnp.sqrt(jnp.where(row > 0, row, 1.0))
    return c, r


def _objectives(Ax, y, adj_all, cost, feas, mass=None, dt=None):
    """(primal, dual, normalized gap) per lane, from a cached forward
    apply.  The normalized gap is the KKT-residual proxy: both iterates
    are kept exactly feasible by their projections, so the duality gap is
    the full KKT error.

    Under Ruiz scaling ``cost`` is the scaled caps ``cost / r`` and
    ``mass`` the per-task simplex masses ``c``; the products below then
    cancel the scales, so both bounds are original-scale values.
    ``dt`` computes the certificate in a wider dtype than the iterate
    (the mixed-precision f64 certificate) — inputs are cast up, and the
    operator closures propagate the wider dtype through the adjoint.
    """
    if dt is not None:
        Ax, y, cost = Ax.astype(dt), y.astype(dt), cost.astype(dt)
    primal = jnp.sum(cost * Ax.max(axis=(1, 3)), axis=1)
    wty = jnp.where(feas, adj_all(y), jnp.inf)
    mins = wty.min(axis=2)
    if mass is not None:
        mins = mass.astype(mins.dtype) * mins
    dual = jnp.sum(mins, axis=1)
    rel = (primal - dual) / (1.0 + jnp.abs(primal) + jnp.abs(dual))
    return primal, dual, rel


# --- legacy fixed-step engine (tol=None; golden-table bit-stable) ----------

@functools.partial(jax.jit,
                   static_argnames=("iters", "Tp", "operator", "power_iters"))
def _pdhg_run_many(w_all, start, end, feas, cost, step_scale, iters: int,
                   Tp: int, operator: str = "cumsum", power_iters: int = 12,
                   x0=None, y0=None):
    B, n, m, D = w_all.shape
    fwd_all, adj_all = _make_operators(w_all, start, end, Tp, operator)

    op_norm = _power_op_norm(fwd_all, adj_all, feas, power_iters)
    tau = (step_scale / (op_norm + 1e-30))[:, None, None]        # vs (B,n,m)
    sigma = tau[..., None]                                    # vs (B,T',m,D)
    cap = cost[:, None, :, None]                              # vs (B,T',m,D)

    if x0 is None:
        x = feas.astype(jnp.float32)
        x = x / x.sum(axis=2, keepdims=True)
    else:
        x = _project_simplex_masked(x0, feas)
    if y0 is None:
        y = jnp.zeros((B, Tp, m, D), jnp.float32)
    else:
        y = _project_capped_simplex_td(y0, cap)

    def step(carry, _):
        x, y, x_prev = carry
        x_bar = 2.0 * x - x_prev
        y_new = _project_capped_simplex_td(y + sigma * fwd_all(x_bar), cap)
        x_new = _project_simplex_masked(x - tau * adj_all(y_new), feas)
        return (x_new, y_new, x), None

    (x, y, _), _ = jax.lax.scan(step, (x, y, x), None, length=iters)

    primal, dual, rel_gap = _objectives(fwd_all(x), y, adj_all, cost, feas)
    return x, y, primal, dual, rel_gap


# --- adaptive restarted engine (tol mode; PDLP-style) ----------------------
# Restart sufficient-decay factor: restart an epoch once the best of
# {current, average} iterate improves the normalized gap to below
# _RESTART_BETA x the gap at the last restart.
_RESTART_BETA = 0.5
# Adaptive step-size clip around the power-iteration baseline: the ratio
# test drives eta, these only stop a degenerate lane (zero interaction
# many checks in a row) from running eta to inf/0.
_ETA_CLIP = 1e4


class _TolCarry(NamedTuple):
    x: jnp.ndarray        # (B, n, m) primal iterate (scaled coordinates)
    x_prev: jnp.ndarray   # momentum partner
    Ax: jnp.ndarray       # (B, T', m, D) cached forward apply of x
    Ax_prev: jnp.ndarray
    y: jnp.ndarray        # (B, T', m, D) dual iterate (scaled coordinates)
    eta: jnp.ndarray      # (B,) per-lane step size (geometric mean)
    omega: jnp.ndarray    # (B,) primal weight: tau=eta/omega, sigma=eta*omega
    k: jnp.ndarray        # scalar: outer attempted-iteration count
    iters_b: jnp.ndarray  # (B,) per-lane iterations-to-tolerance
    conv: jnp.ndarray     # (B,) converged mask — frozen lanes
    restarts_b: jnp.ndarray  # (B,)
    gap_b: jnp.ndarray    # (B,) latest normalized gap per lane
    last_gap: jnp.ndarray  # (B,) gap at last restart (criterion anchor)
    sum_x: jnp.ndarray    # epoch average accumulators (restart mode)
    sum_y: jnp.ndarray
    sum_Ax: jnp.ndarray
    elen: jnp.ndarray     # (B,) epoch length
    dxs: jnp.ndarray      # (B,) epoch primal path length (omega estimator)
    dys: jnp.ndarray      # (B,) epoch dual path length


def _tol_core(w_all, start, end, feas, cost, step_scale, tol,
              max_iters: int, check_every: int, Tp: int, operator: str,
              adaptive: bool, restart: bool, power_iters: int,
              scaling: str, precision: str, omega_on: bool,
              x0=None, y0=None, eta_init=None, omega_init=None,
              use_init=None):
    """Adaptive restarted PDHG with per-lane tolerance stopping.

    One fused stepper for the whole batch: ``check_every`` inner PDHG
    iterations (adaptive per-lane step sizes via the PDLP backtracking
    ratio test — a rejected attempt keeps the iterate and shrinks that
    lane's step below the ratio bound, so backtracking unrolls across
    the loop instead of nesting one), then a convergence/restart check,
    inside an early-exit ``lax.while_loop`` that runs until every lane's
    normalized duality gap is <= tol (or ``max_iters``).  Converged
    lanes freeze via masked updates but keep riding along until the
    whole batch is done — that is the batched analogue of PDLP's
    per-problem termination.

    The speed-layer statics: ``scaling='ruiz'`` solves in Ruiz-scaled
    coordinates (warm inits are scaled on the way in, iterates unscaled
    on the way out — callers only ever see original coordinates);
    ``precision`` picks the iterate dtype (``'mixed'`` = f32 iterate,
    f64 certificate + final polish; ``'f64'`` = f64 throughout — both
    need the caller's ``enable_x64`` scope); ``omega_on`` enables the
    primal-weight split.  ``use_init`` is a *traced* bool selecting the
    warm arrays over the default init — the sweep pipeline's scan body
    passes it so cold group 0 and warm groups 1.. share one trace.

    This function is deliberately un-jitted: ``_pdhg_run_many_tol``
    wraps it for the one-batch entry point and ``_pipeline_fn`` scans it
    over sweep groups inside one jit.
    """
    B, n, m, D = w_all.shape
    if operator == "pallas" and precision == "f64":
        operator = "cumsum"  # the kernel is f32; cumsum is the same map
    it_dt = jnp.float64 if precision == "f64" else jnp.float32
    cert_dt = jnp.float64
    w_all = w_all.astype(it_dt)
    cost = cost.astype(it_dt)

    if scaling == "ruiz":
        c_sc, r_sc = _ruiz_scalings(w_all)
        ws_all = w_all * (r_sc[:, None, :, None] / c_sc[:, :, None, None])
        cost_s = cost / r_sc   # scaled dual caps (padded types stay huge)
        mass = c_sc            # scaled primal simplex masses
    else:
        ws_all, cost_s, mass = w_all, cost, None

    fwd_all, adj_all = _make_operators(ws_all, start, end, Tp, operator)
    op_norm = _power_op_norm(fwd_all, adj_all, feas,
                             power_iters).astype(it_dt)
    eta0 = step_scale / (op_norm + 1e-30)                     # (B,)
    cap = cost_s[:, None, :, None]

    x_def = feas.astype(it_dt)
    x_def = x_def / x_def.sum(axis=2, keepdims=True)
    if mass is not None:
        x_def = x_def * mass[:, :, None]
    if x0 is None:
        x = x_def
    else:
        x_w = x0.astype(it_dt)
        if mass is not None:
            x_w = x_w * mass[:, :, None]
        x_w = _project_simplex_masked(x_w, feas, mass)
        x = x_w if use_init is None else jnp.where(use_init, x_w, x_def)
    if y0 is None:
        y = jnp.zeros((B, Tp, m, D), it_dt)
    else:
        y_w = y0.astype(it_dt)
        if scaling == "ruiz":
            y_w = y_w / r_sc[:, None, :, None]
        y_w = _project_capped_simplex_td(y_w, cap)
        y = (y_w if use_init is None
             else jnp.where(use_init, y_w, jnp.zeros_like(y_w)))
    Ax = fwd_all(x)

    eta_start = eta0
    if eta_init is not None:
        eta_w = jnp.clip(eta_init.astype(it_dt), eta0 / _ETA_CLIP,
                         eta0 * _ETA_CLIP)
        eta_start = (eta_w if use_init is None
                     else jnp.where(use_init, eta_w, eta0))
    ones_b = jnp.ones((B,), it_dt)
    omega_start = ones_b
    if omega_on and omega_init is not None:
        om_w = jnp.clip(omega_init.astype(it_dt), 1.0 / _OMEGA_CLIP,
                        _OMEGA_CLIP)
        omega_start = (om_w if use_init is None
                       else jnp.where(use_init, om_w, ones_b))

    def inner(_, c: _TolCarry) -> _TolCarry:
        active = ~c.conv
        if omega_on:
            sig = (c.eta * c.omega)[:, None, None, None]
            tau = (c.eta / c.omega)[:, None, None]
        else:
            sig = c.eta[:, None, None, None]
            tau = c.eta[:, None, None]
        # candidate step; fwd(2x - x_prev) folded through linearity onto
        # the cached applies, so each attempt costs one fwd + one adj
        y_c = _project_capped_simplex_td(
            c.y + sig * (2.0 * c.Ax - c.Ax_prev), cap)
        x_c = _project_simplex_masked(c.x - tau * adj_all(y_c), feas,
                                      mass)
        Ax_c = fwd_all(x_c)
        dx = x_c - c.x
        dy = y_c - c.y
        dxsq = jnp.sum(dx * dx, axis=(1, 2))
        dysq = jnp.sum(dy * dy, axis=(1, 2, 3))
        if adaptive:
            if omega_on:
                # ratio-test movement in the omega-weighted norm — the
                # norm the primal-dual step is a proximal step in
                move = 0.5 * (c.omega * dxsq + dysq / c.omega)
            else:
                move = 0.5 * (dxsq + dysq)
            inter = jnp.abs(jnp.sum(dy * (Ax_c - c.Ax), axis=(1, 2, 3)))
            eta_bar = jnp.where(inter > 1e-20,
                                move / jnp.maximum(inter, 1e-20), jnp.inf)
            accept = c.eta <= eta_bar
            # kk starts at 2 so the decay factor is never exactly 0 (a
            # k=0 reject would zero eta for good); a lane with no
            # interaction (eta_bar = inf, e.g. x pinned by single-type
            # feasibility) must fall through to the growth term, not
            # evaluate inf * factor (whose 0-factor case is NaN)
            kk = (c.k + 2).astype(jnp.float32)
            shrink = jnp.where(jnp.isfinite(eta_bar),
                               eta_bar * (1.0 - kk ** -0.3), jnp.inf)
            eta_next = jnp.minimum(shrink, c.eta * (1.0 + kk ** -0.6))
            eta_next = jnp.clip(eta_next, eta0 / _ETA_CLIP, eta0 * _ETA_CLIP)
        else:
            accept = jnp.ones((B,), bool)
            eta_next = c.eta
        upd = active & accept
        u3 = upd[:, None, None]
        u4 = upd[:, None, None, None]
        new = c._replace(
            x=jnp.where(u3, x_c, c.x),
            x_prev=jnp.where(u3, c.x, c.x_prev),
            Ax=jnp.where(u4, Ax_c, c.Ax),
            Ax_prev=jnp.where(u4, c.Ax, c.Ax_prev),
            y=jnp.where(u4, y_c, c.y),
            eta=jnp.where(active, eta_next, c.eta),
            k=c.k + 1,
            iters_b=c.iters_b + active.astype(jnp.int32),
        )
        if omega_on:
            new = new._replace(
                dxs=c.dxs + jnp.where(upd, jnp.sqrt(dxsq), 0.0),
                dys=c.dys + jnp.where(upd, jnp.sqrt(dysq), 0.0),
            )
        if restart:
            new = new._replace(
                sum_x=c.sum_x + jnp.where(u3, x_c, 0.0),
                sum_y=c.sum_y + jnp.where(u4, y_c, 0.0),
                sum_Ax=c.sum_Ax + jnp.where(u4, Ax_c, 0.0),
                elen=c.elen + upd.astype(c.elen.dtype),
            )
        return new

    def body(c: _TolCarry) -> _TolCarry:
        # never overshoot the cap: the final chunk shrinks to the
        # remaining budget (traced bound -> dynamic fori length)
        c = jax.lax.fori_loop(0, jnp.minimum(check_every, max_iters - c.k),
                              inner, c)
        _, _, gap_cur = _objectives(c.Ax, c.y, adj_all, cost_s, feas,
                                    mass=mass, dt=cert_dt)
        if restart:
            den = jnp.maximum(c.elen, 1.0)
            x_avg = c.sum_x / den[:, None, None]
            y_avg = c.sum_y / den[:, None, None, None]
            Ax_avg = c.sum_Ax / den[:, None, None, None]
            _, _, gap_avg = _objectives(Ax_avg, y_avg, adj_all, cost_s,
                                        feas, mass=mass, dt=cert_dt)
            gap_avg = jnp.where(c.elen > 0, gap_avg, jnp.inf)
            use_avg = gap_avg < gap_cur
            cand = jnp.minimum(gap_avg, gap_cur)
            do_r = (~c.conv) & ((cand <= _RESTART_BETA * c.last_gap)
                                | (cand <= tol))
            a3 = (do_r & use_avg)[:, None, None]
            a4 = (do_r & use_avg)[:, None, None, None]
            x = jnp.where(a3, x_avg, c.x)
            y = jnp.where(a4, y_avg, c.y)
            Ax = jnp.where(a4, Ax_avg, c.Ax)
            r3 = do_r[:, None, None]
            r4 = do_r[:, None, None, None]
            c = c._replace(
                x=x, y=y, Ax=Ax,
                # restarts reset momentum and the epoch average
                x_prev=jnp.where(r3, x, c.x_prev),
                Ax_prev=jnp.where(r4, Ax, c.Ax_prev),
                restarts_b=c.restarts_b + do_r.astype(jnp.int32),
                last_gap=jnp.where(do_r, cand, c.last_gap),
                sum_x=jnp.where(r3, 0.0, c.sum_x),
                sum_y=jnp.where(r4, 0.0, c.sum_y),
                sum_Ax=jnp.where(r4, 0.0, c.sum_Ax),
                elen=jnp.where(do_r, 0.0, c.elen),
            )
            if omega_on:
                # PDLP primal-weight update at the restart boundary:
                # log-space smoothing (theta = 0.5) toward the closing
                # epoch's dual/primal path-length ratio.  Only lanes
                # that actually moved in both spaces update.
                ratio = jnp.sqrt(c.dys / jnp.maximum(c.dxs, 1e-30))
                om_new = jnp.clip(jnp.sqrt(c.omega * ratio),
                                  1.0 / _OMEGA_CLIP, _OMEGA_CLIP)
                ok = do_r & (c.dxs > 0) & (c.dys > 0)
                c = c._replace(
                    omega=jnp.where(ok, om_new, c.omega),
                    dxs=jnp.where(do_r, 0.0, c.dxs),
                    dys=jnp.where(do_r, 0.0, c.dys),
                )
            gap_new = jnp.where(do_r, cand, gap_cur)
        else:
            gap_new = gap_cur
        gap_b = jnp.where(c.conv, c.gap_b, gap_new)
        return c._replace(gap_b=gap_b, conv=c.conv | (gap_b <= tol))

    def cond(c: _TolCarry):
        return jnp.logical_and(~jnp.all(c.conv), c.k < max_iters)

    zeros_b = jnp.zeros((B,), it_dt)
    c = _TolCarry(
        x=x, x_prev=x, Ax=Ax, Ax_prev=Ax, y=y,
        eta=eta_start, omega=omega_start, k=jnp.int32(0),
        iters_b=jnp.zeros((B,), jnp.int32),
        conv=jnp.zeros((B,), bool),
        restarts_b=jnp.zeros((B,), jnp.int32),
        gap_b=jnp.full((B,), jnp.inf, cert_dt),
        # normalized gap starts < 1 (dual of y=0 is 0), so 1.0 anchors
        # the first sufficient-decay restart check
        last_gap=jnp.ones((B,), cert_dt),
        sum_x=jnp.zeros_like(x), sum_y=jnp.zeros_like(y),
        sum_Ax=jnp.zeros_like(Ax), elen=zeros_b,
        dxs=zeros_b, dys=zeros_b,
    )
    c = jax.lax.while_loop(cond, body, c)

    if precision == "mixed":
        # f64 certificate with f64 *weights* (the in-loop checks only
        # widen the accumulation), then a short plain-PDHG polish at the
        # adapted per-lane step split, kept per lane only where it
        # tightens the certified gap — kkt can only improve.
        pol_op = "cumsum" if operator == "pallas" else operator
        fwd64, adj64 = _make_operators(ws_all.astype(cert_dt), start, end,
                                       Tp, pol_op)
        x_fin = c.x.astype(cert_dt)
        y_fin = c.y.astype(cert_dt)
        primal, dual, rel_gap = _objectives(fwd64(x_fin), y_fin, adj64,
                                            cost_s, feas, mass=mass,
                                            dt=cert_dt)
        cap64 = cap.astype(cert_dt)
        mass64 = None if mass is None else mass.astype(cert_dt)
        if omega_on:
            sig_p = (c.eta * c.omega).astype(cert_dt)[:, None, None, None]
            tau_p = (c.eta / c.omega).astype(cert_dt)[:, None, None]
        else:
            sig_p = c.eta.astype(cert_dt)[:, None, None, None]
            tau_p = c.eta.astype(cert_dt)[:, None, None]

        def pstep(carry, _):
            xp, yp, xpr = carry
            y_n = _project_capped_simplex_td(
                yp + sig_p * fwd64(2.0 * xp - xpr), cap64)
            x_n = _project_simplex_masked(xp - tau_p * adj64(y_n), feas,
                                          mass64)
            return (x_n, y_n, xp), None

        (x_p, y_p, _), _ = jax.lax.scan(pstep, (x_fin, y_fin, x_fin),
                                        None, length=_POLISH_ITERS)
        p_p, d_p, r_p = _objectives(fwd64(x_p), y_p, adj64, cost_s, feas,
                                    mass=mass, dt=cert_dt)
        better = r_p < rel_gap
        x_fin = jnp.where(better[:, None, None], x_p, x_fin)
        y_fin = jnp.where(better[:, None, None, None], y_p, y_fin)
        primal = jnp.where(better, p_p, primal)
        dual = jnp.where(better, d_p, dual)
        rel_gap = jnp.where(better, r_p, rel_gap)
    else:
        x_fin, y_fin = c.x, c.y
        primal, dual, rel_gap = _objectives(c.Ax, c.y, adj_all, cost_s,
                                            feas, mass=mass, dt=cert_dt)

    if scaling == "ruiz":
        # back to original coordinates — callers never see the scales
        x_fin = x_fin / c_sc[:, :, None]
        y_fin = y_fin * r_sc[:, None, :, None]
    return (x_fin, y_fin, primal, dual, rel_gap, c.iters_b, c.restarts_b,
            c.conv, c.eta, c.omega)


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "check_every", "Tp",
                                    "operator", "adaptive", "restart",
                                    "power_iters", "scaling", "precision",
                                    "omega_on"))
def _pdhg_run_many_tol(w_all, start, end, feas, cost, step_scale, tol,
                       max_iters: int, check_every: int, Tp: int,
                       operator: str = "cumsum", adaptive: bool = True,
                       restart: bool = True, power_iters: int = 12,
                       scaling: str = "none", precision: str = "mixed",
                       omega_on: bool = True,
                       x0=None, y0=None, eta_init=None, omega_init=None):
    """One-batch jitted entry point over ``_tol_core`` (see there)."""
    return _tol_core(w_all, start, end, feas, cost, step_scale, tol,
                     max_iters, check_every, Tp, operator, adaptive,
                     restart, power_iters, scaling, precision, omega_on,
                     x0=x0, y0=y0, eta_init=eta_init,
                     omega_init=omega_init)


# 'auto' picks the dense one-dot-per-application operator while the
# activity matrix fits comfortably in memory, else the O((n+T)D) form.
_DENSE_ACT_BUDGET = 64 * 1024 * 1024  # elements of (B, n, T')


def _align_state(state: PDHGState, batch: ProblemBatch):
    """Crop / zero-pad a previous solve's iterates to this batch's padded
    shape.  Lane b warm-starts lane b; the projections inside the engine
    re-feasibilize whatever lands outside the new feasible sets (a fresh
    task row starts uniform over its feasible types, a fresh time slot's
    dual starts at zero)."""
    if state.B != batch.B:
        raise ValueError(
            f"warm start needs matching batch sizes, got state B={state.B} "
            f"vs batch B={batch.B}")
    x0 = np.zeros((batch.B, batch.n, batch.m), np.float32)
    n_c = min(state.x.shape[1], batch.n)
    m_c = min(state.x.shape[2], batch.m)
    x0[:, :n_c, :m_c] = state.x[:, :n_c, :m_c]
    y0 = np.zeros((batch.B, batch.Tp, batch.m, batch.D), np.float32)
    T_c = min(state.y.shape[1], batch.Tp)
    D_c = min(state.y.shape[3], batch.D)
    y0[:, :T_c, :m_c, :D_c] = state.y[:, :T_c, :m_c, :D_c]
    return x0, y0, state.eta, state.omega


def _canonical_mapping(x_b, feas_b, cost_m):
    """Degeneracy-insensitive rounding of an epsilon-optimal LP vertex.

    A tolerance-stopped iterate resolves a degenerate tie (two types at
    identical cost-per-congestion) by trajectory noise: the raw argmax
    of a 0.5/0.5 split flips between runs (warm vs cold, scaled vs not)
    even though every winner prices identically.  Canonical rounding
    treats every feasible type within ``CANONICAL_MARGIN`` of the row
    max as epsilon-optimal-equivalent and picks the winner by problem
    data — cheapest cost, then lowest index — so any two solves that
    agree to tolerance round to the *same* mapping.  The argmax winner
    is always a candidate, so the pick never falls outside the support.
    """
    masked = np.where(feas_b, x_b, -np.inf)
    rowmax = masked.max(axis=1, keepdims=True)
    cand = feas_b & (masked >= rowmax - CANONICAL_MARGIN)
    pick = np.where(cand, cost_m[None, :], np.inf).argmin(axis=1)
    return pick.astype(np.int64)


def solve_lp_many(problems, iters: int = 2000, step_scale: float = 0.9,
                  operator: str = "auto", tol: float | None = None,
                  adaptive: bool = True, restart: bool = True,
                  check_every: int = DEFAULT_CHECK_EVERY, init: PDHGState | None = None,
                  full_output: bool = False, scaling: str = "ruiz",
                  precision: str = "mixed", omega: bool = True):
    """One fused PDHG solve of the mapping LP for B instances.

    ``problems`` is a sequence of ``Problem``s or an already-packed
    ``ProblemBatch``.  Returns one ``PDHGResult`` per instance, sliced
    back to its own (n, m) shapes: primal upper bound, certified dual
    lower bound, and the rounded mapping for the placement phase.

    ``tol=None`` runs the legacy fixed-step loop for exactly ``iters``
    iterations (bit-stable; ``scaling``/``precision``/``omega`` are
    tol-mode knobs and are ignored here).  ``tol=<float>`` switches to
    the adaptive restarted engine: per-lane PDLP-style step sizes
    (``adaptive``), average-iterate restarts (``restart``), early exit
    once every lane's normalized duality gap is <= tol — ``iters``
    becomes the cap, convergence is checked every ``check_every``
    iterations — plus the speed layer: Ruiz equilibration
    (``scaling='ruiz'``), primal-weight balancing (``omega=True``), and
    mixed-precision f32-iterate/f64-certificate solves with a final f64
    polish (``precision='mixed'``; ``'f64'`` solves in f64 throughout).
    Tol-mode mappings use degeneracy-insensitive canonical rounding
    (``_canonical_mapping``), so epsilon-optimal solves agree
    per-instance, not just in aggregate.

    ``init`` warm-starts from a previous solve's ``PDHGState`` (shapes
    are re-aligned; lane b seeds lane b).  ``full_output=True`` returns
    ``(results, SolveStats)`` — per-instance telemetry plus the final
    state for warm-starting the next solve.
    """
    if scaling not in SCALINGS:
        raise ValueError(
            f"scaling must be one of {SCALINGS}, got {scaling!r}")
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}")
    batch = problems if isinstance(problems, ProblemBatch) \
        else pack_problems(problems)
    if operator == "auto":
        operator = ("dense" if batch.B * batch.n * batch.Tp
                    <= _DENSE_ACT_BUDGET else "cumsum")
    if tol is None:
        x0 = y0 = None
        if init is not None:
            x0, y0, _, _ = _align_state(init, batch)
            x0, y0 = jnp.asarray(x0), jnp.asarray(y0)
        args = (jnp.asarray(batch.weights(), jnp.float32),
                jnp.asarray(batch.start), jnp.asarray(batch.end),
                jnp.asarray(batch.feas),
                jnp.asarray(batch.cost, jnp.float32),
                jnp.float32(step_scale))
        _count_dispatch()
        x, y, primal, dual, rel_gap = _pdhg_run_many(
            *args, iters=iters, Tp=batch.Tp, operator=operator,
            x0=x0, y0=y0)
        iters_b = np.full(batch.B, iters, np.int64)
        restarts_b = np.zeros(batch.B, np.int64)
        conv = np.ones(batch.B, bool)
        eta_np = omega_np = None
        x, y = np.asarray(x), np.asarray(y)
        primal, dual, rel_gap = (np.asarray(primal), np.asarray(dual),
                                 np.asarray(rel_gap))
    else:
        from jax.experimental import enable_x64

        # the whole tol-mode call — array creation included — lives in
        # a scoped x64 context (place_step.py's discipline): f64 arrays
        # built outside it would silently downcast, and the jit cache
        # keys on the x64 flag so this never collides with f32 traces
        with enable_x64():
            x0 = y0 = eta_init = omega_init = None
            if init is not None:
                x0, y0, eta_a, omega_a = _align_state(init, batch)
                x0, y0 = jnp.asarray(x0), jnp.asarray(y0)
                if eta_a is not None:
                    eta_init = jnp.asarray(eta_a, jnp.float32)
                if omega_a is not None:
                    omega_init = jnp.asarray(omega_a, jnp.float32)
            w_dt = jnp.float64 if precision == "f64" else jnp.float32
            args = (jnp.asarray(batch.weights(), w_dt),
                    jnp.asarray(batch.start), jnp.asarray(batch.end),
                    jnp.asarray(batch.feas),
                    jnp.asarray(batch.cost, w_dt),
                    jnp.float32(step_scale))
            _count_dispatch()
            (x, y, primal, dual, rel_gap, iters_b, restarts_b,
             conv, eta_out, omega_out) = _pdhg_run_many_tol(
                *args, jnp.float32(tol), max_iters=iters,
                check_every=check_every, Tp=batch.Tp, operator=operator,
                adaptive=adaptive, restart=restart, scaling=scaling,
                precision=precision, omega_on=omega, x0=x0, y0=y0,
                eta_init=eta_init, omega_init=omega_init)
            iters_b = np.asarray(iters_b, np.int64)
            restarts_b = np.asarray(restarts_b, np.int64)
            conv = np.asarray(conv)
            eta_np = np.asarray(eta_out, np.float32)
            omega_np = np.asarray(omega_out, np.float32) if omega else None
            x, y = np.asarray(x), np.asarray(y)
            primal, dual, rel_gap = (np.asarray(primal), np.asarray(dual),
                                     np.asarray(rel_gap))
    results = []
    for b, t in enumerate(batch.problems):
        x_b = x[b, : t.n, : t.m]
        feas_b = batch.feas[b, : t.n, : t.m]
        if tol is None:
            mapping = np.where(feas_b, x_b, -1.0).argmax(axis=1)
            mapping = mapping.astype(np.int64)
        else:
            mapping = _canonical_mapping(x_b, feas_b,
                                         np.asarray(t.node_types.cost))
        results.append(PDHGResult(
            x=x_b,
            objective=float(primal[b]),
            lower_bound=float(dual[b]),
            gap=float(primal[b] - dual[b]),
            iters=int(iters_b[b]),
            mapping=mapping,
            x_max=x_b.max(axis=1),
            restarts=int(restarts_b[b]),
            kkt=float(rel_gap[b]),
            converged=bool(conv[b]),
        ))
    if not full_output:
        return results
    stats = SolveStats(
        iterations=iters_b, restarts=restarts_b, kkt=rel_gap,
        converged=conv, tol=tol,
        state=PDHGState(x=np.asarray(x, np.float32),
                        y=np.asarray(y, np.float32), eta=eta_np,
                        omega=omega_np),
    )
    return results, stats


@functools.lru_cache(maxsize=None)
def _pipeline_fn(max_iters: int, check_every: int, Tp: int, operator: str,
                 adaptive: bool, restart: bool, scaling: str,
                 precision: str, omega_on: bool, n_devices):
    """Build (once per static config) the jitted whole-sweep stepper:
    one ``lax.scan`` over sweep groups whose body is the tol-mode core,
    warm-starting each group from its predecessor's final iterates —
    ONE compiled dispatch for the entire chain.  ``n_devices`` wraps
    the scan in a ``shard_map`` over the batch dim so a multi-chip host
    solves disjoint lanes data-parallel (each shard's early-exit
    ``while_loop`` stops independently)."""

    def run(W, S, E, F, C, step_scale, tol):
        G, B, n, m, D = W.shape
        it_dt = jnp.float64 if precision == "f64" else jnp.float32

        def body(carry, inp):
            x, y, eta, om, has = carry
            w, s, e, f, cst = inp
            (x_o, y_o, primal, dual, rel, it_b, rs_b, conv, eta_o,
             om_o) = _tol_core(
                w, s, e, f, cst, step_scale, tol, max_iters, check_every,
                Tp, operator, adaptive, restart, 12, scaling, precision,
                omega_on, x0=x, y0=y, eta_init=eta, omega_init=om,
                use_init=has)
            # states cross group boundaries in ORIGINAL coordinates —
            # each group re-scales by its own Ruiz factors on entry
            carry = (x_o.astype(it_dt), y_o.astype(it_dt),
                     eta_o.astype(it_dt), om_o.astype(it_dt),
                     jnp.bool_(True))
            outs = (x_o.astype(jnp.float32), primal, dual, rel, it_b,
                    rs_b, conv, eta_o.astype(jnp.float32),
                    om_o.astype(jnp.float32))
            return carry, outs

        carry0 = (jnp.zeros((B, n, m), it_dt),
                  jnp.zeros((B, Tp, m, D), it_dt),
                  jnp.zeros((B,), it_dt), jnp.ones((B,), it_dt),
                  jnp.bool_(False))
        carry, outs = jax.lax.scan(body, carry0, (W, S, E, F, C))
        return outs + (carry[1].astype(jnp.float32),)

    if n_devices is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:n_devices]), ("lanes",))
        gb = P(None, "lanes")  # (G, B, ...) stacked group arrays
        run = shard_map(run, mesh=mesh,
                        in_specs=(gb, gb, gb, gb, gb, P(), P()),
                        out_specs=(gb,) * 9 + (P("lanes"),),
                        check_rep=False)
    return jax.jit(run)


def _sweep_pipeline(groups, pad_to, tol, iters, step_scale, operator,
                    adaptive, restart, check_every, scaling, precision,
                    omega, devices):
    """The compiled sweep chain: pack every group to one common shape,
    stack them on a leading group axis, and run the whole warm-started
    chain as one device dispatch (``_pipeline_fn``)."""
    sizes = {len(g) for g in groups}
    if len(sizes) != 1:
        raise ValueError(
            f"pipeline=True needs equal group sizes (states warm-start "
            f"lane-for-lane), got sizes {sorted(sizes)}")
    batches = [pack_problems(g, pad_to=pad_to) for g in groups]
    b0 = batches[0]
    if operator == "auto":
        operator = ("dense" if b0.B * b0.n * b0.Tp <= _DENSE_ACT_BUDGET
                    else "cumsum")
    n_devices = None
    if devices is not None and devices > 1:
        if b0.B % devices != 0:
            raise ValueError(
                f"pipeline sharding needs devices to divide the group "
                f"size, got B={b0.B}, devices={devices}")
        if devices > len(jax.devices()):
            raise ValueError(
                f"devices={devices} exceeds the {len(jax.devices())} "
                f"local device(s)")
        n_devices = devices
    from jax.experimental import enable_x64

    with enable_x64():
        w_dt = jnp.float64 if precision == "f64" else jnp.float32
        W = jnp.asarray(np.stack([bt.weights() for bt in batches]), w_dt)
        S = jnp.asarray(np.stack([bt.start for bt in batches]))
        E = jnp.asarray(np.stack([bt.end for bt in batches]))
        F = jnp.asarray(np.stack([bt.feas for bt in batches]))
        C = jnp.asarray(np.stack([bt.cost for bt in batches]), w_dt)
        fn = _pipeline_fn(iters, check_every, b0.Tp, operator, adaptive,
                          restart, scaling, precision, omega, n_devices)
        _count_dispatch()
        out = fn(W, S, E, F, C, jnp.float32(step_scale), jnp.float32(tol))
        (xs, primals, duals, rels, iters_g, restarts_g, convs, etas,
         omegas, y_last) = [np.asarray(o) for o in out]
    results: list[PDHGResult] = []
    stats: list[SolveStats] = []
    for g, batch in enumerate(batches):
        for b, t in enumerate(batch.problems):
            x_b = xs[g][b, : t.n, : t.m]
            feas_b = batch.feas[b, : t.n, : t.m]
            results.append(PDHGResult(
                x=x_b,
                objective=float(primals[g][b]),
                lower_bound=float(duals[g][b]),
                gap=float(primals[g][b] - duals[g][b]),
                iters=int(iters_g[g][b]),
                mapping=_canonical_mapping(x_b, feas_b,
                                           np.asarray(t.node_types.cost)),
                x_max=x_b.max(axis=1),
                restarts=int(restarts_g[g][b]),
                kkt=float(rels[g][b]),
                converged=bool(convs[g][b]),
            ))
        # only the final group's state is materialized (it is the only
        # dual iterate the scan carries out); earlier groups' telemetry
        # is complete but their state is None
        state = None
        if g == len(batches) - 1:
            state = PDHGState(x=xs[g], y=y_last, eta=etas[g],
                              omega=omegas[g] if omega else None)
        stats.append(SolveStats(
            iterations=iters_g[g].astype(np.int64),
            restarts=restarts_g[g].astype(np.int64),
            kkt=rels[g], converged=convs[g], tol=tol, state=state))
    return results, stats


def _sweep_impl(groups, tol: float = DEFAULT_TOL, iters: int = 4000,
                step_scale: float = 0.9, operator: str = "auto",
                adaptive: bool = True, restart: bool = True,
                check_every: int = DEFAULT_CHECK_EVERY,
                align_shapes: bool = True, scaling: str = "ruiz",
                precision: str = "mixed", omega: bool = True,
                pipeline: bool = False, devices: int | None = None):
    """Warm-started fleet sweep: solve a grid-adjacent sequence of
    instance groups, seeding each group's primal/dual iterates from its
    predecessor's solution.

    ``groups[g]`` holds one sweep point's instances (e.g. the seed
    replicas of one grid cell), ordered so consecutive groups are
    neighbors on the sweep grid — exactly the row-major, seed-innermost
    order ``workload.sweep_specs`` emits.  Neighboring LP instances
    differ by one perturbed axis, so the previous optimum is deep inside
    the new problem's basin and the adaptive engine converges in a
    fraction of a cold start's iterations (the sweep analogue of Eva's
    incremental re-provisioning).

    With ``align_shapes`` every group is packed to one common padded
    shape, so the whole sweep reuses a single compiled solve and states
    carry over without re-alignment.  A group whose size differs from
    its predecessor's cold-starts (states match lane-for-lane only).

    ``pipeline=True`` compiles the whole chain into ONE ``lax.scan``
    dispatch (requires aligned shapes and equal group sizes; see
    ``_sweep_pipeline``); ``devices`` additionally shards the batch dim
    across that many local devices via ``shard_map``.

    Returns ``(results, stats)``: the flat per-instance ``PDHGResult``
    list (group order preserved) and one ``SolveStats`` per group.
    """
    groups = [list(g) for g in groups]
    if not groups or any(not g for g in groups):
        raise ValueError("solve_lp_sweep needs non-empty groups")
    pad_to = None
    if align_shapes:
        trimmed = [trim_timeline(p)[0] for g in groups for p in g]
        pad_to = (max(t.n for t in trimmed), max(t.m for t in trimmed),
                  max(t.D for t in trimmed), max(t.T for t in trimmed))
    if pipeline:
        if not align_shapes:
            raise ValueError(
                "pipeline=True requires align_shapes=True (every group "
                "must share one padded shape)")
        return _sweep_pipeline(
            groups, pad_to, tol=tol, iters=iters, step_scale=step_scale,
            operator=operator, adaptive=adaptive, restart=restart,
            check_every=check_every, scaling=scaling, precision=precision,
            omega=omega, devices=devices)
    results: list[PDHGResult] = []
    stats: list[SolveStats] = []
    state: PDHGState | None = None
    for g in groups:
        batch = pack_problems(g, pad_to=pad_to)
        if state is not None and state.B != batch.B:
            state = None
        res, st = solve_lp_many(
            batch, iters=iters, step_scale=step_scale, operator=operator,
            tol=tol, adaptive=adaptive, restart=restart,
            check_every=check_every, init=state, full_output=True,
            scaling=scaling, precision=precision, omega=omega)
        results.extend(res)
        stats.append(st)
        state = st.state
    return results, stats


def solve_lp_sweep(groups, tol: float = DEFAULT_TOL, iters: int = 4000,
                   step_scale: float = 0.9, operator: str = "auto",
                   adaptive: bool = True, restart: bool = True,
                   check_every: int = DEFAULT_CHECK_EVERY,
                   align_shapes: bool = True, scaling: str = "ruiz",
                   precision: str = "mixed", omega: bool = True,
                   pipeline: bool = False, devices: int | None = None):
    """Deprecated: drive sweeps through the typed configs instead —
    ``FleetEngine(solver=SolverConfig(tol=...), sweep=SweepConfig(
    warm_start=k, pipeline=...)).solve(...)``.  This shim forwards to
    the same implementation (``_sweep_impl``), so results are
    bit-identical; it only adds the warning."""
    warnings.warn(
        "solve_lp_sweep is deprecated; use FleetEngine(solver="
        "SolverConfig(tol=...), sweep=SweepConfig(warm_start=..., "
        "pipeline=...)).solve(...) — results are bit-identical",
        DeprecationWarning, stacklevel=2)
    return _sweep_impl(groups, tol=tol, iters=iters,
                       step_scale=step_scale, operator=operator,
                       adaptive=adaptive, restart=restart,
                       check_every=check_every, align_shapes=align_shapes,
                       scaling=scaling, precision=precision, omega=omega,
                       pipeline=pipeline, devices=devices)
