"""Greedy placement engine (paper §III phase 2 and §V-D cross-fill).

The engine packs tasks into node replicas of a single node-type, maintaining
each open node's remaining capacity over the (trimmed) timeline.  Two
fitting policies (paper §III):

  * ``first``      — among feasible nodes, the earliest purchased.
  * ``similarity`` — among feasible nodes, the one whose capacity-normalized
                     remaining capacity is most *cosine-similar* to the
                     task's capacity-normalized demand over its span
                     (the dot-product/best-fit strategy of [25], [12]).

The per-task scoring pass is the algorithm's hot loop
(O(n * |S| * D * T) total); ``backend='kernel'`` routes it through the
Pallas fit kernel (repro.kernels), ``backend='numpy'`` uses the plain
vectorized host path.  Both produce identical placements.
"""

from __future__ import annotations

import numpy as np

from .problem import Problem, require_lowered
from .solution import EPS, Solution
from . import penalty as penalty_mod

__all__ = ["TypePool", "two_phase", "FIT_POLICIES"]

FIT_POLICIES = ("first", "similarity")


class TypePool:
    """Open nodes of one node-type, with remaining capacity over (T, D)."""

    def __init__(self, cap_vec: np.ndarray, T: int, backend: str = "numpy"):
        self.cap_vec = np.asarray(cap_vec, dtype=np.float64)  # (D,)
        self.T = T
        self.D = len(self.cap_vec)
        self._rem = np.empty((4, T, self.D))
        self.count = 0
        self.global_ids: list[int] = []
        self.backend = backend

    @property
    def rem(self) -> np.ndarray:
        return self._rem[: self.count]

    def open_node(self, global_id: int) -> int:
        if self.count == len(self._rem):
            grown = np.empty((2 * len(self._rem), self.T, self.D))
            grown[: self.count] = self._rem[: self.count]
            self._rem = grown
        self._rem[self.count] = self.cap_vec
        self.global_ids.append(global_id)
        self.count += 1
        return self.count - 1

    def find_fit(self, dem: np.ndarray, s: int, e: int, fit: str) -> int | None:
        """Local index of the chosen feasible node, or None."""
        if self.count == 0:
            return None
        if self.backend == "kernel":
            from repro.kernels import ops as kops

            feas, score = kops.fit_scores(
                self.rem, dem, s, e, self.cap_vec, scored=(fit == "similarity")
            )
            feas = np.asarray(feas)
            score = np.asarray(score)
        else:
            rem_slice = self.rem[:, s : e + 1, :]
            feas = (rem_slice >= dem[None, None, :] - EPS).all(axis=(1, 2))
            if fit == "similarity":
                dem_n = dem / self.cap_vec  # (D,)
                rem_n = rem_slice / self.cap_vec[None, None, :]
                dot = np.einsum("ntd,d->n", rem_n, dem_n)
                # cosine: demand vector is constant across the span
                span = e - s + 1
                dem_norm = np.linalg.norm(dem_n) * np.sqrt(span)
                rem_norm = np.sqrt(np.einsum("ntd,ntd->n", rem_n, rem_n))
                score = dot / (dem_norm * rem_norm + 1e-30)
            else:
                score = None
        if not feas.any():
            return None
        if fit == "first":
            return int(np.argmax(feas))  # lowest index == earliest purchased
        # quantize before the argmax: digits beyond the 9th are float
        # reassociation noise (einsum kernels differ by layout), and
        # rounding makes the first-max tie-break identical across the
        # numpy / Pallas / batched-lockstep scoring paths
        masked = np.where(feas, np.round(score, 9), -np.inf)
        return int(np.argmax(masked))

    def place(self, local_idx: int, dem: np.ndarray, s: int, e: int) -> None:
        self._rem[local_idx, s : e + 1, :] -= dem


def _sort_by_start(problem: Problem, tasks: np.ndarray) -> np.ndarray:
    order = np.lexsort((tasks, problem.start[tasks]))
    return tasks[order]


def two_phase(
    problem: Problem,
    mapping: np.ndarray,
    fit: str = "first",
    filling: bool = False,
    backend: str = "numpy",
    meta: dict | None = None,
) -> Solution:
    """Run the placement phase for a given task->node-type ``mapping``.

    ``filling=False`` reproduces Fig. 3's placement (each node-type packed
    independently, tasks in increasing start order, purchase on miss).

    ``filling=True`` reproduces Fig. 6: node-types processed in decreasing
    sum_d cap(B,d)/cost(B); after packing a type's own (still unplaced)
    tasks, the remaining tasks of *later* types piggy-back into this type's
    leftover holes in increasing h_avg(u|B) order (fill only — no purchase).

    Constrained instances must be lowered first (``require_lowered``);
    lowered virtual dimensions place through the same feasibility
    checks as real resources.
    """
    require_lowered(problem, "two_phase")
    if fit not in FIT_POLICIES:
        raise ValueError(f"fit must be one of {FIT_POLICIES}")
    nt = problem.node_types
    n = problem.n

    if filling:
        type_order = np.argsort(-nt.capacity_per_cost(), kind="stable")
    else:
        type_order = np.arange(nt.m)

    assign = np.full(n, -1, dtype=np.int64)
    node_types_purchased: list[int] = []
    pools = {
        B: TypePool(nt.cap[B], problem.T, backend=backend) for B in range(nt.m)
    }
    h_avg = penalty_mod.relative_demand(problem, "avg") if filling else None
    placed = np.zeros(n, dtype=bool)

    def _place_task(u: int, B: int, allow_purchase: bool, fit_policy: str) -> bool:
        pool = pools[B]
        dem, s, e = problem.dem[u], problem.start[u], problem.end[u]
        local = pool.find_fit(dem, s, e, fit_policy)
        if local is None:
            if not allow_purchase:
                return False
            if (dem > pool.cap_vec + EPS).any():
                raise RuntimeError(
                    f"mapping assigned task {u} to node-type {B} it cannot fit"
                )
            gid = len(node_types_purchased)
            node_types_purchased.append(B)
            local = pool.open_node(gid)
        pool.place(local, dem, s, e)
        assign[u] = pool.global_ids[local]
        placed[u] = True
        return True

    for B in type_order:
        own = np.flatnonzero((mapping == int(B)) & ~placed)
        for u in _sort_by_start(problem, own):
            _place_task(int(u), int(B), allow_purchase=True, fit_policy=fit)
        if filling:
            remaining = np.flatnonzero(~placed)
            # increasing space they would occupy in a B-type node
            remaining = remaining[np.argsort(h_avg[remaining, B], kind="stable")]
            for u in remaining:
                # fill-only: never purchase during cross-fill; Fig. 6 places
                # piggy-backers in the earliest-purchased feasible node
                _place_task(int(u), int(B), allow_purchase=False, fit_policy="first")

    assert placed.all(), "two_phase must place every task"
    return Solution(
        node_type=np.asarray(node_types_purchased, dtype=np.int64),
        assign=assign,
        meta=dict(meta or {}, fit=fit, filling=filling),
    )
