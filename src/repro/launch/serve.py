"""Batched serving driver: prefill a batch of prompts, then decode.

    python -m repro.launch.serve --arch gemma2-9b --preset smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.launch.train import pick_config
from repro.models import decode_step, init_params, prefill


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--preset", choices=["smoke", "100m", "full"],
                    default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = pick_config(args.arch, args.preset)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_seq:
        batch["vision"] = jax.random.normal(
            key, (B, cfg.vision_seq, cfg.d_model))
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None, :], (3, B, S))

    prefill_j = jax.jit(lambda p, b: prefill(p, cfg, b, max_len=max_len))
    decode_j = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))

    t0 = time.perf_counter()
    logits, state = prefill_j(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: batch={B} len={S}  {t_prefill:.2f}s "
          f"({B*S/t_prefill:.0f} tok/s)")

    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, state = decode_j(params, state, tokens)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tokens = jax.random.categorical(
                sub, logits / args.temperature).astype(jnp.int32)
        else:
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_dec = time.perf_counter() - t0
    steps = args.gen - 1
    print(f"decode: {steps} steps  {t_dec:.2f}s "
          f"({B*steps/max(t_dec,1e-9):.0f} tok/s, "
          f"{t_dec/max(steps,1)*1000:.0f} ms/step)")
    out = jnp.stack(generated, axis=1)
    print("generated token ids (first row):", out[0].tolist())
    return out


if __name__ == "__main__":
    run()
