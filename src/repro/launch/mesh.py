"""Production mesh definition.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS *before* any jax
initialization; smoke tests see the real single CPU device).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e-256-like); 2 pods = 512 chips when
    ``multi_pod``.  Axes: data (FSDP/batch), model (TP/EP), pod (pure DP,
    gradient sync over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 mesh over the real local device — used by smoke tests and the
    CPU end-to-end examples."""
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
