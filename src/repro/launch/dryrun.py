import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh, with NO real allocation
(ShapeDtypeStruct stand-ins), and extract the roofline inputs:

  * compiled.memory_analysis()  — bytes/device: proves the cell fits
  * compiled.cost_analysis()    — HLO FLOPs + bytes for §Roofline
  * collective bytes            — parsed from the compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute result sizes)

Usage:
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k \
        --mesh pod --out results/dryrun
    python -m repro.launch.dryrun --all   # every eligible cell, both meshes
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cell_eligible, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import ModelConfig, init_decode_state, init_params
from repro.sharding import (
    batch_specs,
    decode_state_specs,
    param_specs,
)
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.train_step import make_serve_steps

# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable,
# never allocated)
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_shapes(cfg: ModelConfig, seq: int, batch: int, mode: str) -> dict:
    if mode == "decode":
        return {"tokens": _sds((batch,), jnp.int32)}
    b = {
        "tokens": _sds((batch, seq), jnp.int32),
        "labels": _sds((batch, seq), jnp.int32),
    }
    if mode == "prefill":
        del b["labels"]
    if cfg.encoder_layers:
        b["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                           jnp.float32)
    if cfg.vision_seq:
        b["vision"] = _sds((batch, cfg.vision_seq, cfg.d_model),
                           jnp.float32)
        b["mrope_positions"] = _sds((3, batch, seq), jnp.int32)
    return b


def input_specs(arch: str, shape_name: str, mode: str | None = None):
    """(cfg, params_shapes, state_shapes, batch_shapes) for one cell —
    all ShapeDtypeStructs via eval_shape; nothing is allocated."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mode = mode or shape.mode
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    batch = batch_shapes(cfg, shape.seq_len, shape.global_batch, mode)
    if mode == "train":
        tc = TrainConfig()
        state = jax.eval_shape(lambda p: init_train_state(p, tc), params)
    elif mode == "decode":
        state = jax.eval_shape(
            lambda p: init_decode_state(p, cfg, shape.global_batch,
                                        shape.seq_len), params)
    else:
        state = None
    return cfg, params, state, batch


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_ARRAY_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|"
                       r"u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES.get(dt.split("{")[0], 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op, by kind.

    Convention: a collective 'moves' its result size (all-gather output,
    all-reduce full tensor); this is the standard bytes-on-wire proxy for
    ring algorithms to within the (k-1)/k factor.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        eq = ls.split(" = ", 1)
        if len(eq) != 2:
            continue
        rhs = eq[1]
        opm = re.match(r"^(\([^)]*\)|\S+)\s+([a-z0-9-]+)", rhs)
        if not opm:
            continue
        typ, op = opm.groups()
        base = op.rstrip(".0123456789")
        if base.endswith("-start"):
            base = base[: -len("-start")]
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(typ)
            out["count"] += 1
    return out


# --------------------------------------------------------------------------
# one cell
# --------------------------------------------------------------------------


def _sharded(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             train_cfg: TrainConfig | None = None,
             hints: bool = True) -> dict:
    from repro.sharding.ctx import use_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg, params_sh, state_sh, batch_sh = input_specs(arch, shape_name)
    pspecs = _sharded(mesh, param_specs(params_sh, cfg, mesh))
    bspecs = _sharded(mesh, batch_specs(batch_sh, cfg, mesh))
    n_dev = mesh.size
    t0 = time.perf_counter()

    with mesh, use_mesh(mesh if hints else None):
        if shape.mode == "train":
            tc = train_cfg or TrainConfig()
            step = make_train_step(cfg, tc)
            # optimizer state shares the param specs; scalars replicate
            sspecs = {
                "opt": {
                    "m": pspecs, "v": pspecs,
                    "step": NamedSharding(mesh, P()),
                },
            }
            if tc.compress_grads:
                sspecs["err"] = pspecs
            lowered = jax.jit(
                step,
                in_shardings=(pspecs, sspecs, bspecs),
                out_shardings=(pspecs, sspecs, None),
                donate_argnums=(0, 1),
            ).lower(params_sh, jax.eval_shape(
                lambda p: init_train_state(p, tc), params_sh), batch_sh)
        elif shape.mode == "decode":
            _pre, decode_fn = make_serve_steps(cfg, shape.seq_len)
            cspecs = _sharded(mesh, decode_state_specs(state_sh, cfg, mesh))
            lowered = jax.jit(
                decode_fn,
                in_shardings=(pspecs, cspecs, bspecs["tokens"]),
                out_shardings=(None, cspecs),
                donate_argnums=(1,),
            ).lower(params_sh, state_sh, batch_sh["tokens"])
        else:  # prefill
            prefill_fn, _dec = make_serve_steps(cfg, shape.seq_len)
            state_out = jax.eval_shape(
                lambda p, b: prefill_fn(p, b), params_sh, batch_sh)[1]
            cspecs = _sharded(mesh,
                              decode_state_specs(state_out, cfg, mesh))
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(pspecs, bspecs),
                out_shardings=(None, cspecs),
            ).lower(params_sh, batch_sh)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # trip-count-aware accounting (xla's cost_analysis counts while bodies
    # once; hlo_cost multiplies by known_trip_count — see hlo_cost.py)
    from repro.launch import hlo_cost as hc
    acc = hc.analyze(hlo)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "mode": shape.mode,
        "sharding_hints": hints,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # per-device totals, trip-count-aware
        "flops": acc.flops,
        "traffic_bytes": acc.traffic_bytes,
        "collective_bytes": acc.collective_bytes,
        "collective_count": acc.collective_count,
        # raw xla numbers for reference (bodies counted once)
        "xla_flops": float(cost.get("flops", -1)) if cost else -1,
        "xla_bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else -1,
        "xla_collective_bytes_once": coll,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-hints", action="store_true",
                    help="disable sharding hints (paper-faithful baseline)")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for arch, shape_name, ok, _why in cells(include_skipped=False):
            todo.append((arch, shape_name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        ok, why = cell_eligible(get_config(args.arch), SHAPES[args.shape])
        if not ok:
            print(f"SKIP {args.arch} x {args.shape}: {why}")
            return 0
        todo.append((args.arch, args.shape))

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape_name in todo:
        for multi_pod in meshes:
            tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
            try:
                res = run_cell(arch, shape_name, multi_pod,
                               hints=not args.no_hints)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                print(f"OK   {tag}: compile={res['compile_s']}s "
                      f"flops/dev={res['flops']:.3e} "
                      f"coll/dev={sum(res['collective_bytes'].values()):.3e}B",
                      flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
