"""End-to-end training driver.

    python -m repro.launch.train --arch qwen2.5-3b --preset smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

Presets:
  smoke  — the arch's reduced config (seconds/step on CPU)
  100m   — a ~100M-param dense config (the end-to-end example target)
  full   — the assigned config (requires a real TPU fleet; on CPU this is
           only useful with --dry-run)

The loop is the fault-tolerant one (checkpoint/restart, straggler
detection); run it twice with the same --ckpt-dir and it resumes.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, smoke_config
from repro.models import ModelConfig, init_params
from repro.train import (
    AdamWConfig,
    DataConfig,
    TrainConfig,
    init_train_state,
    make_batch,
    make_train_step,
)
from repro.train.fault import FaultInjector, LoopConfig, train_loop

__all__ = ["model_100m", "run"]


def model_100m() -> ModelConfig:
    """~100M params: 10L x d640 x ff2560, 50k vocab."""
    return ModelConfig(
        name="dense-100m", family="dense", num_layers=10, d_model=640,
        num_heads=10, num_kv_heads=5, head_dim=64, d_ff=2560,
        vocab_size=50_000, dtype="float32",
    )


def pick_config(arch: str, preset: str) -> ModelConfig:
    if preset == "smoke":
        return smoke_config(arch)
    if preset == "100m":
        return model_100m()
    return get_config(arch)


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--preset", choices=["smoke", "100m", "full"],
                    default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a fault at this step (restart demo)")
    args = ap.parse_args(argv)

    cfg = pick_config(args.arch, args.preset)
    print(f"config: {cfg.name}  params~{cfg.param_count()/1e6:.1f}M")
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=20),
        remat=True, microbatch=args.microbatch,
        loss_chunk=min(256, args.seq),
        compress_grads=args.compress_grads)
    dc = DataConfig(batch=args.batch, seq_len=args.seq)

    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tc)
    step_fn = jax.jit(make_train_step(cfg, tc))

    lc = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every)
    injector = FaultInjector((args.crash_at,) if args.crash_at else ())

    losses = []

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)

    t0 = time.perf_counter()
    params, state, hist = train_loop(
        step_fn, params, state, lambda s: make_batch(cfg, dc, s), lc,
        injector=injector, on_metrics=on_metrics)
    wall = time.perf_counter() - t0
    n = len(hist["loss"])
    print(f"done: {n} steps in {wall:.1f}s "
          f"({wall/max(n,1):.2f}s/step); "
          f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"stragglers={sum(hist['straggler'])} "
          f"resumed_from={hist['start_step']}")
    return params, state, hist


if __name__ == "__main__":
    run()
