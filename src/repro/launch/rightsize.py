"""Fleet rightsizing CLI — the paper's technique as the framework's
capacity-planning layer.

    python -m repro.launch.rightsize [--dryrun-dir results/dryrun] \
        [--algo lp-map-f] [--compare] [--fleet N]

Builds the TL-Rightsizing instance from the job schedule (demands measured
from dry-run artifacts when present), purchases a minimum-cost fleet of
TPU slices, and prints the plan.  --compare runs all four paper algorithms
plus the timeline-agnostic lower bound (§VI-F).  --fleet N evaluates N
demand-scaled what-if scenarios (0.5x .. 1.5x utilization) through ONE
``FleetEngine`` session — the paper's protocol as a provisioning
*service* answering a batch of capacity questions in one fused solve —
and prints the $/day frontier per scenario.
"""

from __future__ import annotations

import argparse
import collections
import dataclasses

import numpy as np

from repro.core import (
    evaluate,
    no_timeline_lowerbound,
    rightsize,
    trim_timeline,
)
from repro.workload.jobs import DEFAULT_SCHEDULE, fleet_problem


def run_fleet(problem, n_scenarios: int,
              placement: str = "batched") -> None:
    """Evaluate demand-scaled scenario variants in one FleetEngine
    session: every scenario's mapping LP solves in one fused batch and
    every greedy placement advances in lockstep (``placement=
    'compiled'`` routes it through the on-device stepper).  Doubles as
    the docs' read-the-telemetry walkthrough (docs/benchmarks.md): the
    per-phase timings and the placement-stepper telemetry printed here
    come straight from ``FleetResult.timings``."""
    from repro.core import (FleetEngine, PlacementConfig, SolverConfig,
                            SweepConfig)

    cap_max = problem.node_types.cap.max(axis=0)
    factors = np.linspace(0.5, 1.5, n_scenarios)
    # clamp per-task demand to the largest SKU so every scenario stays
    # placeable (a job can never need more than one full slice here)
    scenarios = [dataclasses.replace(
        problem, dem=np.minimum(problem.dem * f, cap_max))
        for f in factors]
    engine = FleetEngine(
        solver=SolverConfig(iters=1500),
        placement=PlacementConfig(engine=placement),
        sweep=SweepConfig(max_buckets=4),
        algos=("penalty-map-f", "lp-map-f"),
    )
    result = engine.evaluate(scenarios)
    t = result.timings
    print(f"== fleet scenarios ({n_scenarios} demand scalings, one "
          f"FleetEngine session) ==")
    print(f"   pack {t['pack_s']:.2f}s + lp {t['lp_s']:.1f}s + "
          f"placement {t['place_s']:.1f}s over "
          f"{result.plan.n_buckets} shape bucket(s)")
    tel = t["placement"]
    line = (f"   placement engine: {tel['engine']} "
            f"({tel['calls']} stepper calls")
    if "wave_s_total" in tel:
        line += (f", {tel['waves']} phase waves, "
                 f"{tel['wave_s_total']:.2f}s in waves")
    if tel.get("engine") == "compiled":
        line += (f", {tel['dispatches']} device dispatches, "
                 f"{tel['fallbacks']} fallbacks, "
                 f"modes {'/'.join(tel['modes'])}")
    print(line + ")\n")
    print(f"{'demand x':>9s} {'penalty-map-f $/day':>20s} "
          f"{'lp-map-f $/day':>15s} {'x LB':>6s}")
    for f, e in zip(factors, result.entries):
        cost = e["costs"]["lp-map-f"]
        print(f"{f:9.2f} {e['costs']['penalty-map-f']*24:20,.2f} "
              f"{cost*24:15,.2f} {e['normalized']['lp-map-f']:6.3f}")


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--algo", default="lp-map-f")
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="evaluate N demand-scaled scenarios through one "
                         "FleetEngine session instead of a single plan")
    ap.add_argument("--placement",
                    choices=["batched", "compiled", "loop"],
                    default="batched",
                    help="placement engine of the --fleet session "
                         "(identical placements; 'compiled' shows the "
                         "on-device stepper telemetry)")
    args = ap.parse_args(argv)

    problem, tasks = fleet_problem(DEFAULT_SCHEDULE, args.dryrun_dir)
    measured = sum(1 for t in tasks if t["source"] == "dryrun")
    print(f"jobs -> {problem.n} tasks ({measured} demand vectors measured "
          f"from dry-run artifacts), {problem.m} slice SKUs, T=24h\n")

    if args.fleet:
        run_fleet(problem, args.fleet, placement=args.placement)
        return None

    trimmed, _ = trim_timeline(problem)
    if args.compare:
        res = evaluate(trimmed)
        lb = res["lb"]
        print(f"{'algorithm':16s} {'$/day':>10s} {'x LB':>7s}")
        for algo, cost in res["costs"].items():
            print(f"{algo:16s} {cost*24:10.2f} {cost/lb:7.3f}")
        flat = no_timeline_lowerbound(trimmed)
        print(f"\nLP lower bound: ${lb*24:.2f}/day")
        print(f"timeline-agnostic LB (always-on): ${flat*24:.2f}/day "
              f"({flat/lb:.2f}x — the §VI-F gap)")

    sol = rightsize(trimmed, args.algo)
    cost = sol.cost(trimmed)
    print(f"\n== fleet plan ({args.algo}) — ${cost*24:,.2f}/day ==")
    per_type = sol.nodes_per_type(trimmed)
    for b, count in enumerate(per_type):
        if count:
            print(f"  {count} x {trimmed.node_types.names[b]} "
                  f"(${trimmed.node_types.cost[b]*24:,.2f}/day each)")
    print("\nplacement:")
    by_node = collections.defaultdict(list)
    for u, node in enumerate(sol.assign):
        by_node[int(node)].append(tasks[u])
    for node in sorted(by_node):
        b = sol.node_type[node]
        names = ", ".join(
            f"{t['name']}[{t['start']:02d}-{t['end']:02d}h]"
            for t in by_node[node])
        print(f"  node{node} ({trimmed.node_types.names[b]}): {names}")
    return sol


if __name__ == "__main__":
    run()
