"""Fleet rightsizing CLI — the paper's technique as the framework's
capacity-planning layer.

    python -m repro.launch.rightsize [--dryrun-dir results/dryrun] \
        [--algo lp-map-f] [--compare]

Builds the TL-Rightsizing instance from the job schedule (demands measured
from dry-run artifacts when present), purchases a minimum-cost fleet of
TPU slices, and prints the plan.  --compare runs all four paper algorithms
plus the timeline-agnostic lower bound (§VI-F).
"""

from __future__ import annotations

import argparse
import collections


from repro.core import (
    evaluate,
    no_timeline_lowerbound,
    rightsize,
    trim_timeline,
)
from repro.workload.jobs import DEFAULT_SCHEDULE, fleet_problem


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--algo", default="lp-map-f")
    ap.add_argument("--compare", action="store_true")
    args = ap.parse_args(argv)

    problem, tasks = fleet_problem(DEFAULT_SCHEDULE, args.dryrun_dir)
    measured = sum(1 for t in tasks if t["source"] == "dryrun")
    print(f"jobs -> {problem.n} tasks ({measured} demand vectors measured "
          f"from dry-run artifacts), {problem.m} slice SKUs, T=24h\n")

    trimmed, _ = trim_timeline(problem)
    if args.compare:
        res = evaluate(trimmed)
        lb = res["lb"]
        print(f"{'algorithm':16s} {'$/day':>10s} {'x LB':>7s}")
        for algo, cost in res["costs"].items():
            print(f"{algo:16s} {cost*24:10.2f} {cost/lb:7.3f}")
        flat = no_timeline_lowerbound(trimmed)
        print(f"\nLP lower bound: ${lb*24:.2f}/day")
        print(f"timeline-agnostic LB (always-on): ${flat*24:.2f}/day "
              f"({flat/lb:.2f}x — the §VI-F gap)")

    sol = rightsize(trimmed, args.algo)
    cost = sol.cost(trimmed)
    print(f"\n== fleet plan ({args.algo}) — ${cost*24:,.2f}/day ==")
    per_type = sol.nodes_per_type(trimmed)
    for b, count in enumerate(per_type):
        if count:
            print(f"  {count} x {trimmed.node_types.names[b]} "
                  f"(${trimmed.node_types.cost[b]*24:,.2f}/day each)")
    print("\nplacement:")
    by_node = collections.defaultdict(list)
    for u, node in enumerate(sol.assign):
        by_node[int(node)].append(tasks[u])
    for node in sorted(by_node):
        b = sol.node_type[node]
        names = ", ".join(
            f"{t['name']}[{t['start']:02d}-{t['end']:02d}h]"
            for t in by_node[node])
        print(f"  node{node} ({trimmed.node_types.names[b]}): {names}")
    return sol


if __name__ == "__main__":
    run()
