"""Fleet rightsizing CLI — the paper's technique as the framework's
capacity-planning layer, as four subcommands over one config surface:

    python -m repro.launch.rightsize plan    [--algo lp-map-f]
                                             [--scenarios K --cvar-alpha A]
    python -m repro.launch.rightsize compare
    python -m repro.launch.rightsize fleet   [-n 8] [--placement compiled]
    python -m repro.launch.rightsize serve   [--trace gct] [--requests 200]

``plan`` purchases a minimum-cost fleet for the LM-job schedule and
prints the placement (with ``--scenarios K`` it continues into the
stochastic layer: K-scenario fan-out, one batched dispatch, CVaR
frontier — docs/stochastic.md); ``compare`` runs all four paper
algorithms plus
the timeline-agnostic lower bound (§VI-F); ``fleet`` evaluates N
demand-scaled what-if scenarios through ONE ``FleetEngine`` session;
``serve`` replays an arrival trace through the long-lived
``RightsizingService`` (docs/service.md) and prints its sustained
requests/sec + re-plan latency report.

Every subcommand builds its engine through the shared
``configs_from_flags()`` helper — the solver/placement/sweep flags are
spelled once, map one-to-one onto the typed configs, and each
subcommand only overrides the *defaults* (e.g. ``serve`` defaults to a
tolerance-stopped solver because warm starts need early exit).
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json

import numpy as np

from repro.core import (
    FleetEngine,
    PlacementConfig,
    SolverConfig,
    SweepConfig,
    no_timeline_lowerbound,
    rightsize,
    trim_timeline,
)
from repro.workload.jobs import DEFAULT_SCHEDULE, fleet_problem


def configs_from_flags(args) -> dict:
    """Map the shared CLI flags onto the typed-config family — the ONE
    place flag spellings meet config fields.  Returns kwargs for
    ``FleetEngine(**configs_from_flags(args))`` (minus ``algos``, which
    each subcommand picks)."""
    return {
        "solver": SolverConfig(tol=args.lp_tol, iters=args.lp_iters,
                               operator=args.operator,
                               scaling=args.scaling,
                               precision=args.precision,
                               omega=not args.no_omega),
        "placement": PlacementConfig(engine=args.placement,
                                     backend=args.backend),
        "sweep": SweepConfig(max_buckets=args.buckets,
                             shard_size=args.shard_size,
                             warm_start=args.warm_start,
                             pipeline=args.pipeline,
                             devices=args.devices),
    }


def stochastic_from_flags(args):
    """Map the ``plan`` subcommand's stochastic flags onto a
    ``StochasticConfig`` (the CVaR selection knobs; the forecast
    channels ride separately on ``--load-sigma``/``--burst-prob``).
    Lives next to ``configs_from_flags`` for the same reason: one
    place where flag spellings meet config fields."""
    from repro.stochastic import StochasticConfig

    return StochasticConfig(
        scenarios=args.scenarios,
        seed=args.seed,
        cvar_alpha=args.cvar_alpha,
        cvar_lambda=args.cvar_lambda,
        recfg_weight=args.recfg_cost,
        algo=args.algo,
    )


def _shared_flags() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(add_help=False)
    p.add_argument("--dryrun-dir", default="results/dryrun")
    p.add_argument("--lp-tol", type=float, default=None,
                   help="tolerance-stopped LP solve "
                        "(SolverConfig.tol; default: fixed iterations)")
    p.add_argument("--lp-iters", type=int, default=2000,
                   help="LP iteration count / cap (SolverConfig.iters)")
    p.add_argument("--operator", default="auto",
                   choices=["auto", "dense", "cumsum", "pallas"],
                   help="congestion-operator form (SolverConfig.operator)")
    p.add_argument("--placement", default="batched",
                   choices=["batched", "compiled", "loop"],
                   help="placement engine (PlacementConfig.engine)")
    p.add_argument("--backend", default="numpy",
                   choices=["numpy", "kernel"],
                   help="placement scoring backend "
                        "(PlacementConfig.backend)")
    p.add_argument("--buckets", type=int, default=1,
                   help="max shape buckets (SweepConfig.max_buckets)")
    p.add_argument("--shard-size", type=int, default=None,
                   help="LP dispatch shard size (SweepConfig.shard_size)")
    p.add_argument("--warm-start", type=int, default=None,
                   help="warm-started sweep group size "
                        "(SweepConfig.warm_start)")
    p.add_argument("--scaling", default="ruiz",
                   choices=["none", "ruiz"],
                   help="operator equilibration (SolverConfig.scaling; "
                        "tol mode only)")
    p.add_argument("--precision", default="mixed",
                   choices=["f64", "mixed"],
                   help="solve precision: f32 iterate + f64 certificate/"
                        "polish, or full f64 (SolverConfig.precision)")
    p.add_argument("--no-omega", action="store_true",
                   help="disable primal-weight balancing "
                        "(SolverConfig.omega)")
    p.add_argument("--pipeline", action="store_true",
                   help="compile the warm-started sweep chain into one "
                        "lax.scan dispatch (SweepConfig.pipeline; "
                        "requires --warm-start)")
    p.add_argument("--devices", type=int, default=None,
                   help="shard the pipelined sweep's batch dim across "
                        "this many local devices (SweepConfig.devices)")
    return p


def _load_problem(args):
    problem, tasks = fleet_problem(DEFAULT_SCHEDULE, args.dryrun_dir)
    measured = sum(1 for t in tasks if t["source"] == "dryrun")
    print(f"jobs -> {problem.n} tasks ({measured} demand vectors measured "
          f"from dry-run artifacts), {problem.m} slice SKUs, T=24h\n")
    return problem, tasks


def _plan_stochastic(args, problem, current):
    """``plan --scenarios K``: fan the job fleet's point forecast into
    K scenarios (one batched dispatch) and print the CVaR frontier,
    the chosen robust fleet, and the expected-cost-only comparison.
    ``current`` (the deterministic point plan) anchors the Eva-style
    ``--recfg-cost`` reconfiguration term."""
    from repro.stochastic import DemandForecast, plan_stochastic

    forecast = DemandForecast(base=problem,
                              load_sigma=args.load_sigma,
                              burst_prob=args.burst_prob)
    engine = FleetEngine(**configs_from_flags(args), algos=(args.algo,))
    res = plan_stochastic(forecast, stochastic_from_flags(args),
                          engine=engine, current_fleet=current)
    print(f"== stochastic plan ({res.K} scenarios, {res.lp_dispatches} "
          f"LP dispatch(es), alpha={args.cvar_alpha}, "
          f"lambda={args.cvar_lambda}) ==")
    names = problem.node_types.names
    fmt = lambda F: ", ".join(  # noqa: E731
        f"{c} x {names[b]}" for b, c in enumerate(F) if c) or "(empty)"
    print(f"  robust fleet:   {fmt(res.fleet)} "
          f"(${res.fleet_cost*24:,.2f}/day, worst-scenario overload "
          f"${res.worst_overload*24:,.2f}/day)")
    print(f"  expected-only:  {fmt(res.expected_fleet)} "
          f"(${res.expected_fleet_cost*24:,.2f}/day, worst-scenario "
          f"overload ${res.expected_overload.max()*24:,.2f}/day)")
    print(f"\n{'alpha':>6s} {'lambda':>7s} {'$/day':>10s} "
          f"{'cvar ov':>9s} {'worst ov':>9s}  fleet")
    for row in res.frontier:
        print(f"{row['alpha']:6.2f} {row['lambda']:7.2f} "
              f"{row['fleet_cost']*24:10,.2f} "
              f"{row['cvar_overload']*24:9,.2f} "
              f"{row['worst_overload']*24:9,.2f}  {row['fleet']}")
    return res


def cmd_plan(args):
    """One fleet plan with one algorithm; the mapping LP runs through
    the flag-configured engine (``rightsize`` consumes its result).
    With ``--scenarios K`` the point plan becomes the *current* fleet
    and planning continues stochastically (forecast fan-out + CVaR
    selection, docs/stochastic.md)."""
    problem, tasks = _load_problem(args)
    trimmed, _ = trim_timeline(problem)
    lp_result = None
    if args.algo.startswith("lp-map"):
        engine = FleetEngine(**configs_from_flags(args),
                             algos=(args.algo,))
        (lp_result,), _ = engine.solve([trimmed])
    sol = rightsize(trimmed, args.algo, lp_result=lp_result)
    cost = sol.cost(trimmed)
    print(f"== fleet plan ({args.algo}) — ${cost*24:,.2f}/day ==")
    per_type = sol.nodes_per_type(trimmed)
    for b, count in enumerate(per_type):
        if count:
            print(f"  {count} x {trimmed.node_types.names[b]} "
                  f"(${trimmed.node_types.cost[b]*24:,.2f}/day each)")
    print("\nplacement:")
    by_node = collections.defaultdict(list)
    for u, node in enumerate(sol.assign):
        by_node[int(node)].append(tasks[u])
    for node in sorted(by_node):
        b = sol.node_type[node]
        names = ", ".join(
            f"{t['name']}[{t['start']:02d}-{t['end']:02d}h]"
            for t in by_node[node])
        print(f"  node{node} ({trimmed.node_types.names[b]}): {names}")
    if args.scenarios:
        print()
        return _plan_stochastic(args, problem, per_type)
    return sol


def cmd_compare(args):
    """All four paper algorithms on the job fleet, via ONE B=1
    ``FleetEngine`` session (the LP lower bound is the solver's
    certified dual bound)."""
    problem, _ = _load_problem(args)
    trimmed, _ = trim_timeline(problem)
    engine = FleetEngine(**configs_from_flags(args))
    result = engine.evaluate([trimmed])
    entry = result.entries[0]
    lb = entry["lb"]
    print(f"{'algorithm':16s} {'$/day':>10s} {'x LB':>7s}")
    for algo, cost in entry["costs"].items():
        print(f"{algo:16s} {cost*24:10.2f} {cost/lb:7.3f}")
    flat = no_timeline_lowerbound(trimmed)
    print(f"\nLP lower bound: ${lb*24:.2f}/day")
    print(f"timeline-agnostic LB (always-on): ${flat*24:.2f}/day "
          f"({flat/lb:.2f}x — the §VI-F gap)")
    return entry


def cmd_fleet(args):
    """N demand-scaled what-if scenarios in one FleetEngine session:
    every scenario's mapping LP solves in one fused batch and every
    greedy placement advances in lockstep.  Doubles as the docs'
    read-the-telemetry walkthrough (docs/benchmarks.md)."""
    problem, _ = _load_problem(args)
    cap_max = problem.node_types.cap.max(axis=0)
    factors = np.linspace(0.5, 1.5, args.scenarios)
    # clamp per-task demand to the largest SKU so every scenario stays
    # placeable (a job can never need more than one full slice here)
    scenarios = [dataclasses.replace(
        problem, dem=np.minimum(problem.dem * f, cap_max))
        for f in factors]
    engine = FleetEngine(**configs_from_flags(args),
                         algos=("penalty-map-f", "lp-map-f"))
    result = engine.evaluate(scenarios)
    t = result.timings
    print(f"== fleet scenarios ({args.scenarios} demand scalings, one "
          f"FleetEngine session) ==")
    print(f"   pack {t['pack_s']:.2f}s + lp {t['lp_s']:.1f}s + "
          f"placement {t['place_s']:.1f}s over "
          f"{result.plan.n_buckets} shape bucket(s)")
    tel = t["placement"]
    line = (f"   placement engine: {tel['engine']} "
            f"({tel['calls']} stepper calls")
    if "wave_s_total" in tel:
        line += (f", {tel['waves']} phase waves, "
                 f"{tel['wave_s_total']:.2f}s in waves")
    if tel.get("engine") == "compiled":
        line += (f", {tel['dispatches']} device dispatches, "
                 f"{tel['fallbacks']} fallbacks, "
                 f"modes {'/'.join(tel['modes'])}")
    print(line + ")\n")
    print(f"{'demand x':>9s} {'penalty-map-f $/day':>20s} "
          f"{'lp-map-f $/day':>15s} {'x LB':>6s}")
    for f, e in zip(factors, result.entries):
        cost = e["costs"]["lp-map-f"]
        print(f"{f:9.2f} {e['costs']['penalty-map-f']*24:20,.2f} "
              f"{cost*24:15,.2f} {e['normalized']['lp-map-f']:6.3f}")
    return result


def cmd_serve(args):
    """Replay an arrival trace through a ``RightsizingService`` and
    print the serving report (requests/sec, p50/p99 re-plan latency,
    warm-vs-cold iteration medians, decision-loop events).

    ``--restore DIR`` resumes a checkpointed service (warm lanes,
    adopted plans, and the pending queue carry over) before the replay;
    ``--checkpoint DIR`` snapshots the service after it drains, so a
    later invocation can pick up where this one stopped."""
    from repro.serve import (RightsizingService, ServiceConfig,
                             TraceSpec, gct_trace, jobs_trace, replay)

    engine = FleetEngine(**configs_from_flags(args), algos=("lp-map-f",))
    config = ServiceConfig(
        max_requests_per_tick=args.max_requests_per_tick)
    if args.restore:
        service = RightsizingService.restore(args.restore,
                                             engine=engine, config=config)
        print(f"restored service from {args.restore}: "
              f"{len(service.fleets)} fleet(s), "
              f"{service.queue.pending} queued request(s)")
    else:
        service = RightsizingService(engine=engine, config=config)
    spec = TraceSpec(fleets=args.fleets, requests=args.requests,
                     seed=args.seed)
    if args.trace == "gct":
        trace = gct_trace(spec)
    else:
        trace = jobs_trace(dataclasses.replace(spec, n0=0),
                           dryrun_dir=args.dryrun_dir)
    print(f"replaying {len(trace)} requests over {args.fleets} "
          f"{args.trace} fleets ({args.push_per_tick}/tick pressure)\n")
    report = replay(service, trace, push_per_tick=args.push_per_tick)
    print(json.dumps(report, indent=2))
    if args.checkpoint:
        service.snapshot(args.checkpoint)
        print(f"# service checkpointed -> {args.checkpoint}")
    return report


def run(argv=None):
    shared = _shared_flags()
    ap = argparse.ArgumentParser(prog="repro.launch.rightsize")
    sub = ap.add_subparsers(dest="command")

    p = sub.add_parser("plan", parents=[shared],
                       help="purchase one fleet plan and print it")
    p.add_argument("--algo", default="lp-map-f")
    p.add_argument("--scenarios", type=int, default=0, metavar="K",
                   help="also plan stochastically: fan the forecast "
                        "into K scenarios (one batched dispatch) and "
                        "print the CVaR frontier (0 = off)")
    p.add_argument("--seed", type=int, default=0,
                   help="scenario fan-out seed")
    p.add_argument("--cvar-alpha", type=float, default=0.9,
                   help="CVaR tail level (StochasticConfig.cvar_alpha)")
    p.add_argument("--cvar-lambda", type=float, default=1.0,
                   help="CVaR term weight (StochasticConfig.cvar_lambda)")
    p.add_argument("--recfg-cost", type=float, default=0.0,
                   help="Eva-style reconfiguration weight against the "
                        "point plan (StochasticConfig.recfg_weight)")
    p.add_argument("--load-sigma", type=float, default=0.15,
                   help="forecast scenario-wide load sigma "
                        "(DemandForecast.load_sigma)")
    p.add_argument("--burst-prob", type=float, default=0.05,
                   help="forecast per-task burst probability "
                        "(DemandForecast.burst_prob)")
    p.set_defaults(func=cmd_plan)

    p = sub.add_parser("compare", parents=[shared],
                       help="all four paper algorithms + §VI-F bounds")
    p.set_defaults(func=cmd_compare)

    p = sub.add_parser("fleet", parents=[shared],
                       help="N demand-scaled scenarios, one session")
    p.add_argument("-n", "--scenarios", type=int, default=8)
    p.set_defaults(func=cmd_fleet, lp_iters=1500, buckets=4)

    p = sub.add_parser("serve", parents=[shared],
                       help="replay an arrival trace through the "
                            "RightsizingService")
    p.add_argument("--trace", choices=["gct", "jobs"], default="gct")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--fleets", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--push-per-tick", type=int, default=8)
    p.add_argument("--max-requests-per-tick", type=int, default=32)
    p.add_argument("--checkpoint", default=None, metavar="DIR",
                   help="snapshot the drained service to DIR")
    p.add_argument("--restore", default=None, metavar="DIR",
                   help="resume from a snapshot in DIR before replaying")
    p.set_defaults(func=cmd_serve, lp_tol=5e-3, lp_iters=4000)

    args = ap.parse_args(argv)
    if args.command is None:
        args = ap.parse_args(["plan"] + (argv or []))
    return args.func(args)


if __name__ == "__main__":
    run()
