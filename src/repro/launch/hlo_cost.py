"""Trip-count-aware HLO cost accounting.

``compiled.cost_analysis()`` counts every computation ONCE — a ``lax.scan``
over 36 layers contributes its body a single time, under-counting FLOPs by
orders of magnitude (verified empirically; the while op carries
``backend_config={"known_trip_count":{"n":...}}``).  This module re-derives
roofline inputs by parsing the optimized HLO text:

  * per-computation totals (dot FLOPs from result x contracting dims;
    collective result bytes by kind; memory-traffic proxy = operand +
    result bytes of compute/data-movement ops),
  * a call graph (while bodies x known trip count, conditionals/calls x 1),
  * entry totals via weighted DFS.

Validated against analytic FLOP counts in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import collections
import dataclasses
import re

__all__ = ["analyze", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "f16": 2, "bf16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# opcodes whose operands+result we count as memory traffic.  Fusions are
# counted at their *boundary* (operands + result) — their internals are
# excluded (flops inside are still counted); raw elementwise ops are
# excluded since they would fuse on a real backend.
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "copy", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "sort", "concatenate",
    "pad", "reverse", "transpose", "reduce", "slice",
} | set(_COLLECTIVES)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    traffic: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float))
    coll_count: int = 0
    # (callee, multiplier, fused) — fused edges propagate flops but NOT
    # traffic (the caller's fusion op already counted the boundary bytes)
    calls: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloCost:
    flops: float
    traffic_bytes: float
    collective_bytes: dict
    collective_count: int

    def to_dict(self):
        return {
            "flops": self.flops,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_count": self.collective_count,
        }


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z0-9\-]+)\((.*)$"
)
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[\"\':{ ]+n[\"\': ]+(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")


def analyze(hlo_text: str) -> HloCost:
    comps: dict[str, _Comp] = {}
    entry_name = None
    cur: _Comp | None = None
    symbols: dict[str, str] = {}

    comment_re = re.compile(r"/\*.*?\*/")
    for raw in hlo_text.splitlines():
        # strip /*index=N*/ comments: their '=' breaks tuple-type matching
        line = comment_re.sub("", raw).rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{"):
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr:
                cur = _Comp(hdr.group(1))
                comps[cur.name] = cur
                symbols = {}
                if line.lstrip().startswith("ENTRY"):
                    entry_name = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, typ, op, rest = m.groups()
        symbols[name] = typ
        base = op.rstrip(".0123456789")
        if base.endswith("-start"):
            base = base[: -len("-start")]
        if base == "dot":
            # operands may carry inline types ("dot(f32[8,16]{1,0} %x, ...")
            # whose commas break naive splitting: take the first %-symbol
            om = re.search(r"%([\w.\-]+)", rest)
            lhs = om.group(1) if om else rest.split(",")[0].strip()
            lhs_type = symbols.get(lhs, "")
            cm = _CONTRACT_RE.search(line)
            contract = 1
            if cm and lhs_type:
                dims_m = _ARRAY_RE.search(lhs_type)
                if dims_m:
                    lhs_dims = [int(d) for d in dims_m.group(2).split(",")
                                if d]
                    for idx in cm.group(1).split(","):
                        if idx:
                            contract *= lhs_dims[int(idx)]
            cur.flops += 2.0 * _shape_elems(typ) * contract
        elif base == "convolution":
            # rough: 2 * out_elems * (in_channels * kernel_spatial)  — we
            # have no convs in these models; keep a conservative count
            cur.flops += 2.0 * _shape_elems(typ)
        if base in _COLLECTIVES:
            b = _shape_bytes(typ)
            cur.coll[base] += b
            cur.coll_count += 1
        if base in _TRAFFIC_OPS:
            opnds = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
            cur.traffic += _shape_bytes(typ) + sum(
                _shape_bytes(symbols.get(o, "")) for o in opnds)
        # call edges
        if base in ("while",):
            tm = _TRIP_RE.search(line)
            trips = int(tm.group(1)) if tm else 1
            for callee in _CALLED_RE.findall(line):
                cur.calls.append((callee, trips, False))
        elif base in ("conditional",):
            bm = _COND_BRANCH_RE.search(line)
            if bm:
                for callee in bm.group(1).split(","):
                    cur.calls.append((callee.strip().lstrip("%"), 1, False))
        elif base in ("call", "map"):
            for callee in _CALLED_RE.findall(line):
                cur.calls.append((callee, 1, False))
        elif base in ("fusion", "reduce", "scatter", "sort",
                      "reduce-window", "select-and-scatter", "custom-call",
                      "all-reduce", "reduce-scatter"):
            for callee in _CALLED_RE.findall(line):
                cur.calls.append((callee, 1, True))

    if entry_name is None:
        raise ValueError("no ENTRY computation found in HLO text")

    memo: dict[str, tuple] = {}

    def total(name: str) -> tuple:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, {}, 0)
        memo[name] = (0.0, 0.0, {}, 0)  # cycle guard
        fl, tr = comp.flops, comp.traffic
        coll = collections.defaultdict(float, comp.coll)
        cnt = comp.coll_count
        for callee, mult, fused in comp.calls:
            cf, ct, cc, cn = total(callee)
            fl += mult * cf
            if not fused:  # fusion internals: boundary already counted
                tr += mult * ct
            for k, v in cc.items():
                coll[k] += mult * v
            cnt += mult * cn
        memo[name] = (fl, tr, dict(coll), cnt)
        return memo[name]

    fl, tr, coll, cnt = total(entry_name)
    full = {k: coll.get(k, 0.0) for k in _COLLECTIVES}
    return HloCost(flops=fl, traffic_bytes=tr, collective_bytes=full,
                   collective_count=int(cnt))
