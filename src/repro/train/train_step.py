"""Train / serve step factories.

``make_train_step`` builds a jit-able step:
  * remat (activation checkpointing) policy through the model's scan
  * optional microbatch gradient accumulation (lax.scan over microbatches)
  * optional int8 error-feedback gradient compression (simulating the
    compressed cross-pod all-reduce's numerics; the explicit collective
    variant lives in compression.compressed_psum)
  * AdamW with clipping + warmup

``make_serve_steps`` builds (prefill_fn, decode_fn).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, loss_fn, prefill
from . import compression
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainConfig", "make_train_step", "init_train_state",
           "make_serve_steps"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: bool = True
    microbatch: int = 1          # gradient-accumulation factor
    loss_chunk: int = 512
    compress_grads: bool = False  # int8 error-feedback compression


def init_train_state(params, tc: TrainConfig) -> dict:
    state = {"opt": adamw_init(params, tc.optimizer)}
    if tc.compress_grads:
        state["err"] = compression.init_error_state(params)
    return state


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns step(params, state, batch) -> (params, state, metrics)."""

    def loss_of(params, batch):
        return loss_fn(params, cfg, batch, remat=tc.remat,
                       loss_chunk=tc.loss_chunk)

    def grads_of(params, batch):
        if tc.microbatch <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads

        # microbatch accumulation: split the batch leading axis and scan
        def reshape_mb(x):
            B = x.shape[0]
            assert B % tc.microbatch == 0, (B, tc.microbatch)
            return x.reshape(tc.microbatch, B // tc.microbatch,
                             *x.shape[1:])

        # mrope positions carry batch on axis 1
        mb_batch = {
            k: (v.transpose(1, 0, 2, 3) if k == "mrope_positions" else v)
            for k, v in batch.items()}
        mb_batch = jax.tree.map(reshape_mb, mb_batch)
        mb_batch = {
            k: (v.transpose(0, 2, 1, 3) if k == "mrope_positions" else v)
            for k, v in mb_batch.items()}

        def mb_step(carry, mb):
            loss_acc, grads_acc = carry
            (loss, _m), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            mb_step, (jnp.zeros(()), zero_grads), mb_batch)
        inv = 1.0 / tc.microbatch
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss_sum * inv
        return loss, {"xent": loss}, grads

    def step(params, state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if tc.compress_grads:
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = tdef.flatten_up_to(state["err"])
            pairs = [compression.compress_decompress(g, e)
                     for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([p[0] for p in pairs])
            err = tdef.unflatten([p[1] for p in pairs])
        params, opt, opt_metrics = adamw_update(
            params, grads, state["opt"], tc.optimizer)
        new_state = {"opt": opt}
        if tc.compress_grads:
            new_state["err"] = err
        return params, new_state, {"loss": loss, **metrics, **opt_metrics}

    return step


def make_serve_steps(cfg: ModelConfig, max_len: int):
    """Returns (prefill_fn(params, batch), decode_fn(params, state, tokens))."""

    def prefill_fn(params, batch):
        return prefill(params, cfg, batch, max_len=max_len)

    def decode_fn(params, state, tokens):
        return decode_step(params, cfg, state, tokens)

    return prefill_fn, decode_fn
