"""Data pipeline: deterministic synthetic LM token streams.

Deterministic per-(step, host) batches make restart-exactness testable:
after a crash + restore at step k, the pipeline regenerates exactly the
batch the failed run would have seen.  In multi-host deployment each
process generates only its addressable shard (``host_id``/``num_hosts``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models import ModelConfig

__all__ = ["DataConfig", "SyntheticLMData", "make_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    seed: int = 1234


class SyntheticLMData:
    """Zipfian token stream with enough structure for loss to fall:
    each sequence is a repeating random n-gram pattern with noise, so a
    model can learn local statistics quickly (used by the end-to-end
    example to show a real learning curve)."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig,
                 host_id: int = 0, num_hosts: int = 1):
        assert dc.batch % num_hosts == 0
        self.cfg = cfg
        self.dc = dc
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = dc.batch // num_hosts

    def batch_at(self, step: int) -> dict:
        return make_batch(self.cfg, self.dc, step, self.host_id,
                          self.num_hosts)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int,
               host_id: int = 0, num_hosts: int = 1) -> dict:
    local_batch = dc.batch // num_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, host_id]))
    B, S, V = local_batch, dc.seq_len, cfg.vocab_size
    period = 16
    # motifs draw from a small head vocabulary so the marginal is
    # learnable quickly (Zipf-like head), on top of the induction pattern
    motif = rng.integers(0, min(V, 1024), size=(B, period))
    reps = -(-S // period) + 1
    stream = np.tile(motif, (1, reps))[:, : S + 1]
    noise = rng.random((B, S + 1)) < 0.1
    stream = np.where(noise, rng.integers(0, V, size=(B, S + 1)), stream)
    tokens = stream[:, :S].astype(np.int32)
    labels = stream[:, 1:].astype(np.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.encoder_layers:
        batch["frames"] = rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.vision_seq:
        batch["vision"] = rng.standard_normal(
            (B, cfg.vision_seq, cfg.d_model)).astype(np.float32)
        batch["mrope_positions"] = np.broadcast_to(
            np.arange(S, dtype=np.int32)[None, None, :], (3, B, S)).copy()
    return batch
