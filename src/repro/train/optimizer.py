"""AdamW with global-norm clipping — self-contained pytree optimizer.

Optimizer state dtype is configurable (fp32 default; bf16 halves the
HBM footprint of m/v for the 1T-param config — see EXPERIMENTS §Dry-run).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    state_dtype: str = "float32"   # 'float32' | 'bfloat16'


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.zeros((), jnp.float32))
    return jnp.sqrt(sq)


def adamw_init(params, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gn, "lr": lr}
