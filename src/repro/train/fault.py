"""Fault tolerance: the resilient training loop.

* **Checkpoint/restart**: every step is deterministic given (params, step)
  — the data pipeline regenerates batch ``k`` from the step index, so
  restoring the latest checkpoint resumes the exact trajectory.
* **Straggler mitigation**: a watchdog times each step against a rolling
  deadline (median of recent steps x ``straggler_factor``); overruns are
  counted and surfaced so the cluster layer can re-dispatch (here: logged
  + injected-delay tested).  On a real fleet the per-step barrier makes
  the slowest host the step time, which is exactly what the TL-Rightsizing
  planner's per-job demand margins absorb.
* **Elastic rescale**: checkpoints are mesh-agnostic (host numpy), so a
  restore may target a different mesh/sharding (checkpoint.restore with
  new shardings) — tested by reshaping a 1-device "mesh" logical layout.
* **Crash injection**: ``FaultInjector`` raises at configured steps to
  exercise the restart path in tests.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax

from . import checkpoint as ckpt_mod

__all__ = ["LoopConfig", "FaultInjector", "train_loop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 10
    keep: int = 3
    straggler_factor: float = 3.0
    min_history: int = 5


class FaultInjector:
    """Deterministically crash at given global steps (once each)."""

    def __init__(self, crash_at: tuple[int, ...] = ()):
        self.crash_at = set(crash_at)

    def maybe_crash(self, step: int):
        if step in self.crash_at:
            self.crash_at.discard(step)
            raise RuntimeError(f"injected fault at step {step}")


def train_loop(
    step_fn: Callable,
    params,
    state,
    batch_at: Callable[[int], Any],
    lc: LoopConfig,
    injector: FaultInjector | None = None,
    on_metrics: Callable[[int, dict], None] | None = None,
):
    """Run (or resume) training to ``lc.total_steps``.

    Returns (params, state, history) where history records per-step wall
    time, loss, straggler flags and restart events.
    """
    ckpt = ckpt_mod.Checkpointer(lc.ckpt_dir, keep=lc.keep)
    history: dict[str, list] = {"loss": [], "wall_s": [], "straggler": [],
                                "restarts": 0, "start_step": 0}

    # resume from the latest checkpoint if one exists
    start = ckpt_mod.latest_step(lc.ckpt_dir)
    step0 = 0
    if start is not None:
        (params, state), _ = ckpt_mod.restore(
            lc.ckpt_dir, (params, state), step=start)
        step0 = start
        history["start_step"] = step0

    times: list[float] = []
    step = step0
    while step < lc.total_steps:
        t0 = time.perf_counter()  # includes data fetch: stalls straggle too
        batch = batch_at(step)
        if injector is not None:
            injector.maybe_crash(step)
        params, state, metrics = step_fn(params, state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        straggle = False
        if len(times) >= lc.min_history:
            deadline = statistics.median(times[-20:]) * lc.straggler_factor
            straggle = dt > deadline
        times.append(dt)
        history["loss"].append(float(metrics["loss"]))
        history["wall_s"].append(dt)
        history["straggler"].append(straggle)
        if on_metrics:
            on_metrics(step, metrics)
        step += 1
        if step % lc.ckpt_every == 0 or step == lc.total_steps:
            ckpt.save_async((params, state), step)
    ckpt.close()
    return params, state, history


def run_with_restarts(make_loop_args, lc: LoopConfig,
                      injector: FaultInjector, max_restarts: int = 5):
    """Driver that supervises train_loop across injected crashes: on
    failure, reconstructs fresh (params, state) and re-enters the loop,
    which resumes from the last checkpoint."""
    restarts = 0
    last_history = None
    while True:
        step_fn, params, state, batch_at = make_loop_args()
        try:
            params, state, history = train_loop(
                step_fn, params, state, batch_at, lc, injector=injector)
            if last_history is not None:
                history["restarts"] = restarts
            return params, state, history
        except RuntimeError as e:
            if "injected fault" not in str(e) or restarts >= max_restarts:
                raise
            restarts += 1
            last_history = True
