"""Checkpointing: atomic step-tagged saves, async commit, keep-k GC,
restore-to-any-mesh (elastic rescale).

Format: one zstd-compressed msgpack file per checkpoint holding flattened
(path -> raw ndarray bytes + dtype + shape) entries.  Restoring onto a
*different* mesh is supported by loading to host numpy and re-placing with
the target sharding (``restore(..., shardings=...)``) — this is the
elastic-rescale path exercised by the fault-tolerance tests.
"""

from __future__ import annotations

import concurrent.futures as cf
import os
import re

import jax
import jax.numpy as jnp
import numpy as np

try:  # optional serialization deps — the 'train' extra in pyproject.toml
    import msgpack
    import zstandard as zstd
except ImportError:  # gate at use, not import, so repro.train stays loadable
    msgpack = None
    zstd = None

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

_STEP_RE = re.compile(r"^step_(\d+)\.ckpt$")


def _require_serialization():
    if msgpack is None or zstd is None:
        raise ImportError(
            "checkpointing needs msgpack + zstandard: "
            "pip install -e '.[train]'"
        )


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = {
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def _unflatten_into(tree, flat: dict):
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new_leaves = []
    for path, leaf in leaves_paths:
        key = "/".join(str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        ent = flat[key]
        arr = np.frombuffer(ent["data"], dtype=np.dtype(ent["dtype"]))
        arr = arr.reshape(ent["shape"])
        new_leaves.append(arr)
    return treedef.unflatten(new_leaves)


def save(path: str, tree, step: int) -> str:
    """Atomic save: write tmp, fsync, rename."""
    _require_serialization()
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"step_{step}.ckpt")
    tmp = fname + ".tmp"
    payload = msgpack.packb({"step": step, "tree": _flatten(tree)})
    with open(tmp, "wb") as f:
        f.write(zstd.ZstdCompressor(level=3).compress(payload))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, fname)
    return fname


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := _STEP_RE.match(f))]
    return max(steps) if steps else None


def restore(path: str, like_tree, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``.  ``shardings`` (an
    optional matching pytree of Sharding/None) re-places leaves onto a
    possibly different mesh — the elastic-rescale path."""
    _require_serialization()
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    fname = os.path.join(path, f"step_{step}.ckpt")
    with open(fname, "rb") as f:
        payload = msgpack.unpackb(zstd.ZstdDecompressor().decompress(
            f.read()))
    host_tree = _unflatten_into(like_tree, payload["tree"])
    if shardings is None:
        placed = jax.tree.map(jnp.asarray, host_tree)
    else:
        placed = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None
            else jnp.asarray(a),
            host_tree, shardings)
    return placed, payload["step"]


class Checkpointer:
    """Async checkpointer: snapshot on the caller thread (cheap host
    copies), commit (compress + write) on a worker thread; keeps the
    newest ``keep`` files."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    def save_async(self, tree, step: int):
        self.wait()  # one in flight at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = self._pool.submit(self._commit, host, step)

    def _commit(self, host_tree, step: int):
        save(self.path, host_tree, step)
        self._gc()
        return step

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for f in os.listdir(self.path)
            if (m := _STEP_RE.match(f)))
        for s in steps[: -self.keep]:
            os.remove(os.path.join(self.path, f"step_{s}.ckpt"))

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self):
        self.wait()
        self._pool.shutdown()
