"""Gradient compression for cross-pod synchronization (beyond-paper
distributed-optimization trick).

Cross-pod (DCN) bandwidth is the scarcest link in a multi-pod mesh, so the
pod-axis gradient all-reduce is the natural place to compress.  We use
int8 block quantization with **error feedback**: the quantization residual
is carried to the next step, so compression error accumulates to zero
instead of biasing the update (Karimireddy et al., 2019).

Two entry points:
  * ``compress_decompress`` — the numerics, usable inside any jit'd step
    (simulates the compressed collective's end-to-end effect: 4x fewer
    bytes on the wire).
  * ``compressed_psum`` — the explicit collective for a shard_map'd step:
    quantize -> psum(int32 accumulate) -> dequantize.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress",
           "compressed_psum", "init_error_state"]

_BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % _BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize_int8(g):
    """Per-block symmetric int8 quantization: returns (q, scales, n)."""
    flat, n = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q, scale, n, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return deq.reshape(shape)


def compress_decompress(g, err):
    """Error-feedback round trip: returns (g_hat, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale, n = quantize_int8(corrected)
    g_hat = dequantize_int8(q, scale, n, g.shape)
    return g_hat.astype(g.dtype), corrected - g_hat


def compressed_psum(g, err, axis_name: str):
    """Quantized all-reduce over ``axis_name`` with error feedback.

    Inside shard_map: each participant quantizes its (error-corrected)
    local gradient to int8 and all-gathers the int8 payload + per-block
    fp32 scales (wire volume ~= 1 byte/element vs 4 for an fp32
    all-reduce); the weighted sum ``sum_i q_i * s_i`` is then exact local
    arithmetic.  With a small axis (pods), this is both cheaper on the
    wire and bit-exact in reconstruction."""
    corrected = g.astype(jnp.float32) + err
    q, scale, n = quantize_int8(corrected)
    local = dequantize_int8(q, scale, n, g.shape)
    new_err = corrected - local
    q_all = jax.lax.all_gather(q, axis_name)          # (P, blocks, B) int8
    s_all = jax.lax.all_gather(scale, axis_name)      # (P, blocks, 1) fp32
    summed = jnp.einsum("pbk,pbo->bk", q_all.astype(jnp.float32), s_all)
    deq = summed.reshape(-1)[:n].reshape(g.shape)
    return deq.astype(g.dtype), new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
