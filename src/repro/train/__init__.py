"""Training/serving substrate: optimizer, step factories, data,
checkpointing, fault tolerance, gradient compression."""

from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm
from .train_step import (
    TrainConfig,
    init_train_state,
    make_serve_steps,
    make_train_step,
)
from .data import DataConfig, SyntheticLMData, make_batch
from . import checkpoint, compression, fault

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "global_norm",
    "TrainConfig", "init_train_state", "make_serve_steps",
    "make_train_step", "DataConfig", "SyntheticLMData", "make_batch",
    "checkpoint", "compression", "fault",
]
