"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth every kernel test compares against
(``assert_allclose`` over shape/dtype sweeps).

Like the kernels, every oracle is generic over the trailing feature
dimensions (D/K): lowered virtual constraint columns from
``repro.core.constraints`` (exclusivity, anti-affinity) are ordinary
capacity dimensions here and need no special casing.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["congestion_ref", "congestion_many_ref", "fit_scores_ref",
           "fit_scores_many_ref"]


def congestion_ref(start, end, w, T: int):
    """out[t, k] = sum_u [start_u <= t <= end_u] * w[u, k].

    start, end: (n,) int32 inclusive slots; w: (n, K) float; out: (T, K).
    The interval-congestion operator — used by the LP constraint evaluation,
    the Lemma-1 lower bound and the PDHG solver's linear operator.
    """
    t = jnp.arange(T, dtype=jnp.int32)
    mask = (start[None, :] <= t[:, None]) & (t[:, None] <= end[None, :])
    return mask.astype(w.dtype) @ w


def congestion_many_ref(start, end, w, T: int):
    """out[g, t, k] = sum_u [start_gu <= t <= end_gu] * w[g, u, k].

    start, end: (G, n) int32; w: (G, n, K); out: (G, T, K) — the batched
    interval-congestion operator behind the many-instance LP engine.
    """
    t = jnp.arange(T, dtype=jnp.int32)
    mask = (start[:, None, :] <= t[None, :, None]) \
        & (t[None, :, None] <= end[:, None, :])  # (G, T, n)
    return jnp.einsum("gtn,gnk->gtk", mask.astype(w.dtype), w)


def fit_scores_ref(rem, dem, mask, inv_cap):
    """Placement fit scoring over all open nodes at once.

    rem:     (N, T, D) remaining capacity per node.
    dem:     (D,)      task demand.
    mask:    (T,)      1.0 inside the task's span, 0.0 outside.
    inv_cap: (D,)      1 / cap of this node-type.

    Returns (feas_margin, dot, rem_norm2):
      feas_margin: (N,) min over span,d of rem - dem  (feasible iff >= -eps)
      dot:         (N,) sum over span,d of (rem/cap) * (dem/cap)
      rem_norm2:   (N,) sum over span,d of (rem/cap)^2
    """
    dtype = rem.dtype
    big = jnp.asarray(jnp.finfo(dtype).max, dtype)
    margin = rem - dem[None, None, :]
    masked_margin = jnp.where(mask[None, :, None] > 0, margin, big)
    feas_margin = masked_margin.min(axis=(1, 2))
    rem_n = rem * inv_cap[None, None, :]
    dem_n = dem * inv_cap
    dot = jnp.einsum("ntd,d,t->n", rem_n, dem_n, mask)
    rem_norm2 = jnp.einsum("ntd,ntd,t->n", rem_n, rem_n, mask)
    return feas_margin, dot, rem_norm2


def fit_scores_many_ref(rem, dem, mask, inv_cap):
    """Batched placement fit scoring — one task per instance, all open
    nodes of all B instances at once (the lockstep ``place_many`` hot
    loop).

    rem:     (B, N, T, D) remaining capacity per (instance, node).
    dem:     (B, D)       the current task's demand, per instance.
    mask:    (B, T)       1.0 inside that instance's task span.
    inv_cap: (B, D)       1 / cap of the targeted node-type; 0 on padded
                          dims (which then contribute nothing to
                          dot / rem_norm2).

    Returns (feas_margin, dot, rem_norm2), each (B, N) — the batched
    analogue of ``fit_scores_ref``; padded nodes/slots are the caller's
    responsibility (mask slots via ``mask``, nodes at selection time).
    """
    dtype = rem.dtype
    big = jnp.asarray(jnp.finfo(dtype).max, dtype)
    margin = rem - dem[:, None, None, :]
    masked_margin = jnp.where(mask[:, None, :, None] > 0, margin, big)
    feas_margin = masked_margin.min(axis=(2, 3))
    rem_n = rem * inv_cap[:, None, None, :]
    dem_n = dem * inv_cap
    dot = jnp.einsum("bntd,bd,bt->bn", rem_n, dem_n, mask)
    rem_norm2 = jnp.einsum("bntd,bntd,bt->bn", rem_n, rem_n, mask)
    return feas_margin, dot, rem_norm2
