"""Pallas TPU kernel: interval-congestion matmul.

Computes ``out[t, k] = sum_u [start_u <= t <= end_u] * w[u, k]`` — the core
operator behind the paper's congestion quantities (Lemma 1 lower bound, LP
congestion constraints, and the PDHG LP solver's A / A^T applications).

TPU adaptation (vs. the paper's per-slot Python loops): the task-active
interval mask ``A[t, u]`` is never materialized in HBM; each (Tt, nb) tile
is generated *inside VMEM* from the ``start``/``end`` vectors with
``broadcasted_iota``, then contracted against the demand tile on the MXU.
Block sizes keep the working set (Tt*nb mask + nb*Kb weights + Tt*Kb acc)
within VMEM and 128-aligned for the MXU.

Grid: (G, T/Tt, K/Kb, n/nb) with the instance axis outermost (one grid
group per batched instance; G=1 for the single-instance wrapper) and the
task axis innermost so each output tile stays resident while the task
dimension streams through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["congestion_pallas", "congestion_many_pallas"]

# 128-aligned MXU tiles; fp32 working set = (128*512 + 512*128 + 128*128)*4
# ~= 580 KiB << 16 MiB VMEM, leaving headroom for double buffering.
BLOCK_T = 128
BLOCK_N = 512
BLOCK_K = 128


def congestion_pallas(
    start: jax.Array,
    end: jax.Array,
    w: jax.Array,
    T: int,
    block_t: int = BLOCK_T,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """(T, K) congestion from (n,) int32 start/end and (n, K) weights —
    the G=1 case of ``congestion_many_pallas`` (one tiling/padding
    implementation to maintain)."""
    return congestion_many_pallas(
        start[None], end[None], w[None], T,
        block_t=block_t, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )[0]


def _congestion_many_kernel(start_ref, end_ref, w_ref, out_ref, *, block_t):
    ti = pl.program_id(1)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # (Tt, nb) active mask generated in-register from the interval bounds
    t0 = ti * block_t
    t_ids = t0 + jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)
    start = start_ref[...].reshape(1, -1)  # (1, nb)
    end = end_ref[...].reshape(1, -1)
    mask = (start <= t_ids) & (t_ids <= end)
    acc = jnp.dot(
        mask.astype(w_ref.dtype), w_ref[0],
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc.astype(out_ref.dtype)[None]


@functools.partial(
    jax.jit, static_argnames=("T", "block_t", "block_n", "block_k", "interpret")
)
def congestion_many_pallas(
    start: jax.Array,
    end: jax.Array,
    w: jax.Array,
    T: int,
    block_t: int = BLOCK_T,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """(G, T, K) congestion for a batch of G independent instances.

    start, end: (G, n) int32; w: (G, n, K).  The instance axis becomes the
    outermost grid dimension, so each instance's output tile streams its own
    task dimension exactly like the single-instance kernel; padding follows
    the same never-active / zero-weight scheme and is exact.
    """
    G, n, K = w.shape
    dtype = w.dtype
    n_p = max(pl.cdiv(n, block_n) * block_n, block_n)
    K_p = max(pl.cdiv(K, block_k) * block_k, block_k)
    T_p = max(pl.cdiv(T, block_t) * block_t, block_t)
    start_p = jnp.full((G, n_p), 1, jnp.int32).at[:, :n].set(
        start.astype(jnp.int32))
    end_p = jnp.full((G, n_p), 0, jnp.int32).at[:, :n].set(
        end.astype(jnp.int32))
    w_p = jnp.zeros((G, n_p, K_p), dtype).at[:, :n, :K].set(w)

    grid = (G, T_p // block_t, K_p // block_k, n_p // block_n)
    out = pl.pallas_call(
        functools.partial(_congestion_many_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda g, i, j, k: (g, k)),
            pl.BlockSpec((1, block_n), lambda g, i, j, k: (g, k)),
            pl.BlockSpec((1, block_n, block_k), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_t, block_k), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, T_p, K_p), dtype),
        interpret=interpret,
    )(start_p, end_p, w_p)
    return out[:, :T, :K]
