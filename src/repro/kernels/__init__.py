"""Pallas TPU kernels for the paper's compute hot-spots.

* ``congestion``  — interval-congestion matmul (LP constraints, Lemma-1
                    bound, PDHG operator).
* ``fit_scores``  — placement feasibility + similarity scoring over all
                    open nodes (the O(n·|S|·D·T) placement hot loop).

``ops`` holds the jit'd wrappers (interpret=True off-TPU); ``ref`` the
pure-jnp oracles the tests sweep against.
"""

from . import ops, ref
from .ops import congestion, fit_scores

__all__ = ["ops", "ref", "congestion", "fit_scores"]
