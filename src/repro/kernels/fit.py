"""Pallas TPU kernel: placement fit-scoring.

The placement phase's hot loop (paper §III "Time Complexity":
O(n * |S| * D * T) dominates) asks, for one task against *all* open nodes of
a node-type: is the node feasible over the task's span, and how similar is
its remaining capacity to the demand (similarity-fit)?  This kernel fuses
the three reductions in one pass over the (N, T, D) remaining-capacity
tensor:

    feas_margin[n] = min_{t in span, d} rem[n,t,d] - dem[d]
    dot[n]         = sum_{t in span, d} (rem/cap)[n,t,d] * (dem/cap)[d]
    rem_norm2[n]   = sum_{t in span, d} (rem/cap)[n,t,d]^2

Layout: rem is passed transposed as (T, D, N) so nodes ride the 128-lane
axis and timeslots the 8-sublane axis; D is a small static inner loop.
Grid: (N/Nb, T/Tb) with the T axis innermost, accumulating into the (Nb,)
outputs while they stay VMEM-resident.

The kernel is generic over D, which is the constraint contract: the
lowering in ``repro.core.constraints`` appends virtual unit-capacity
dimensions (a shared exclusivity column with a δ=1e-6 sliver demand for
non-exclusive rows, one column per anti-affinity group) and they ride
the same feasibility-margin reduction as real resources.  The margins
involved (0 vs δ−EPS ≈ 9e-7, accumulations of δ) sit far above f32
resolution at these O(1) magnitudes, so the f32 kernel path stays
bit-consistent with the f64 numpy path on the feasibility *decision*.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fit_scores_pallas", "fit_scores_many_pallas"]

BLOCK_N = 128
BLOCK_T = 256

_BIG = 3.0e38  # < fp32 max; neutral for the min-reduction


def _fit_kernel(rem_ref, dem_ref, mask_ref, invcap_ref, feas_ref, dot_ref,
                norm_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        feas_ref[...] = jnp.full_like(feas_ref, _BIG)
        dot_ref[...] = jnp.zeros_like(dot_ref)
        norm_ref[...] = jnp.zeros_like(norm_ref)

    mask = mask_ref[...].reshape(-1, 1)  # (Tb, 1) in {0, 1}
    D = rem_ref.shape[1]
    feas = feas_ref[...]
    dot = dot_ref[...]
    norm = norm_ref[...]
    for d in range(D):  # D is small and static: unrolled VPU loop
        rem_d = rem_ref[:, d, :]  # (Tb, Nb)
        dem_d = dem_ref[0, d]
        inv_d = invcap_ref[0, d]
        margin = jnp.where(mask > 0, rem_d - dem_d, _BIG)
        feas = jnp.minimum(feas, margin.min(axis=0))
        rem_n = rem_d * inv_d * mask
        dot = dot + (dem_d * inv_d) * rem_n.sum(axis=0)
        norm = norm + (rem_n * rem_n).sum(axis=0)
    feas_ref[...] = feas
    dot_ref[...] = dot
    norm_ref[...] = norm


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_t", "interpret")
)
def fit_scores_pallas(
    rem_tdn: jax.Array,   # (T, D, N) remaining capacity, node-minor
    dem: jax.Array,       # (D,)
    mask: jax.Array,      # (T,) float, 1 inside the span
    inv_cap: jax.Array,   # (D,)
    block_n: int = BLOCK_N,
    block_t: int = BLOCK_T,
    interpret: bool = False,
):
    """Returns (feas_margin, dot, rem_norm2), each (N,) float32.

    Padding is exact: padded slots get mask=0 (neutral for all three
    reductions), padded nodes are sliced away.
    """
    T, D, N = rem_tdn.shape
    dtype = jnp.float32
    N_p = max(pl.cdiv(N, block_n) * block_n, block_n)
    T_p = max(pl.cdiv(T, block_t) * block_t, block_t)
    rem_p = jnp.zeros((T_p, D, N_p), dtype).at[:T, :, :N].set(
        rem_tdn.astype(dtype))
    mask_p = jnp.zeros((T_p,), dtype).at[:T].set(mask.astype(dtype))
    dem_2d = dem.astype(dtype).reshape(1, D)
    inv_2d = inv_cap.astype(dtype).reshape(1, D)

    grid = (N_p // block_n, T_p // block_t)
    out_shape = [jax.ShapeDtypeStruct((N_p,), dtype)] * 3
    out_spec = pl.BlockSpec((block_n,), lambda i, t: (i,))
    feas, dot, norm = pl.pallas_call(
        _fit_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, D, block_n), lambda i, t: (t, 0, i)),
            pl.BlockSpec((1, D), lambda i, t: (0, 0)),
            pl.BlockSpec((block_t,), lambda i, t: (t,)),
            pl.BlockSpec((1, D), lambda i, t: (0, 0)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(rem_p, dem_2d, mask_p, inv_2d)
    return feas[:N], dot[:N], norm[:N]


def _fit_many_kernel(rem_ref, dem_ref, mask_ref, invcap_ref, feas_ref,
                     dot_ref, norm_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        feas_ref[...] = jnp.full_like(feas_ref, _BIG)
        dot_ref[...] = jnp.zeros_like(dot_ref)
        norm_ref[...] = jnp.zeros_like(norm_ref)

    mask = mask_ref[0].reshape(-1, 1)  # (Tb, 1) in {0, 1}
    D = rem_ref.shape[2]
    feas = feas_ref[0]
    dot = dot_ref[0]
    norm = norm_ref[0]
    for d in range(D):  # D is small and static: unrolled VPU loop
        rem_d = rem_ref[0, :, d, :]  # (Tb, Nb)
        dem_d = dem_ref[0, d]
        inv_d = invcap_ref[0, d]
        margin = jnp.where(mask > 0, rem_d - dem_d, _BIG)
        feas = jnp.minimum(feas, margin.min(axis=0))
        rem_n = rem_d * inv_d * mask
        dot = dot + (dem_d * inv_d) * rem_n.sum(axis=0)
        norm = norm + (rem_n * rem_n).sum(axis=0)
    feas_ref[0] = feas
    dot_ref[0] = dot
    norm_ref[0] = norm


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_t", "interpret")
)
def fit_scores_many_pallas(
    rem_btdn: jax.Array,  # (B, T, D, N) remaining capacity, node-minor
    dem: jax.Array,       # (B, D) per-instance task demand
    mask: jax.Array,      # (B, T) float, 1 inside each instance's span
    inv_cap: jax.Array,   # (B, D) per-instance 1/cap; 0 on padded dims
    block_n: int = BLOCK_N,
    block_t: int = BLOCK_T,
    interpret: bool = False,
):
    """Batched fit scoring: grid over B with the single-instance tiling.

    Returns (feas_margin, dot, rem_norm2), each (B, N) float32 — one
    lockstep ``place_many`` step scores the pending task of every
    instance against all its open nodes in this one call.  Padding is
    exact exactly as in ``fit_scores_pallas``: padded slots carry mask=0
    (neutral for all three reductions), padded nodes are sliced away by
    the host, padded dims carry ``inv_cap=0`` (and zero demand), so they
    only add a neutral ``rem - 0 >= 0`` term to the min-reduction.
    """
    B, T, D, N = rem_btdn.shape
    dtype = jnp.float32
    N_p = max(pl.cdiv(N, block_n) * block_n, block_n)
    T_p = max(pl.cdiv(T, block_t) * block_t, block_t)
    rem_p = jnp.zeros((B, T_p, D, N_p), dtype).at[:, :T, :, :N].set(
        rem_btdn.astype(dtype))
    mask_p = jnp.zeros((B, T_p), dtype).at[:, :T].set(mask.astype(dtype))
    dem_2d = dem.astype(dtype).reshape(B, D)
    inv_2d = inv_cap.astype(dtype).reshape(B, D)

    grid = (B, N_p // block_n, T_p // block_t)
    out_shape = [jax.ShapeDtypeStruct((B, N_p), dtype)] * 3
    out_spec = pl.BlockSpec((1, block_n), lambda b, i, t: (b, i))
    feas, dot, norm = pl.pallas_call(
        _fit_many_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, D, block_n),
                         lambda b, i, t: (b, t, 0, i)),
            pl.BlockSpec((1, D), lambda b, i, t: (b, 0)),
            pl.BlockSpec((1, block_t), lambda b, i, t: (b, t)),
            pl.BlockSpec((1, D), lambda b, i, t: (b, 0)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(rem_p, dem_2d, mask_p, inv_2d)
    return feas[:, :N], dot[:, :N], norm[:, :N]
