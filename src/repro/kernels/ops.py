"""Jit'd public wrappers around the Pallas kernels.

On CPU hosts (this container) the kernels run under ``interpret=True``,
which executes the kernel body in Python for correctness; on TPU the same
code lowers to Mosaic.  ``ref.py`` holds the pure-jnp oracles used by the
test sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import congestion as _congestion
from . import fit as _fit
from . import ref

__all__ = ["on_tpu", "congestion", "congestion_many", "fit_scores",
           "fit_scores_many", "fit_scores_step"]

_EPS = 1e-7


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def congestion(start, end, w, T: int, use_ref: bool = False):
    """(T, K) interval congestion; Pallas kernel unless ``use_ref``."""
    start = jnp.asarray(start, jnp.int32)
    end = jnp.asarray(end, jnp.int32)
    w = jnp.asarray(w, jnp.float32)
    if use_ref:
        return ref.congestion_ref(start, end, w, T)
    return _congestion.congestion_pallas(
        start, end, w, T, interpret=not on_tpu()
    )


def congestion_many(start, end, w, T: int, use_ref: bool = False):
    """(G, T, K) batched interval congestion; Pallas kernel unless
    ``use_ref``.  start/end: (G, n); w: (G, n, K)."""
    start = jnp.asarray(start, jnp.int32)
    end = jnp.asarray(end, jnp.int32)
    w = jnp.asarray(w, jnp.float32)
    if use_ref:
        return ref.congestion_many_ref(start, end, w, T)
    return _congestion.congestion_many_pallas(
        start, end, w, T, interpret=not on_tpu()
    )


def fit_scores(rem, dem, s: int, e: int, cap, scored: bool = False,
               use_ref: bool = False):
    """Host-facing fit API for the placement engine.

    rem: (N, T, D) remaining capacities of the open nodes.
    dem: (D,) demand; [s, e] the task's span; cap: (D,) type capacity.

    Returns (feasible (N,) bool, score (N,) float) where score is the cosine
    similarity of capacity-normalized demand vs. remaining capacity over the
    span (only computed when ``scored``).
    """
    rem = np.asarray(rem)
    N, T, D = rem.shape
    dem_j = jnp.asarray(dem, jnp.float32)
    inv_cap = 1.0 / jnp.asarray(cap, jnp.float32)
    mask = jnp.zeros(T, jnp.float32).at[s : e + 1].set(1.0)
    if use_ref:
        feas_m, dot, norm2 = ref.fit_scores_ref(
            jnp.asarray(rem, jnp.float32), dem_j, mask, inv_cap
        )
    else:
        rem_tdn = jnp.asarray(np.ascontiguousarray(rem.transpose(1, 2, 0)),
                              jnp.float32)
        feas_m, dot, norm2 = _fit.fit_scores_pallas(
            rem_tdn, dem_j, mask, inv_cap, interpret=not on_tpu()
        )
    feas = np.asarray(feas_m) >= -_EPS
    if not scored:
        return feas, np.zeros(N, np.float32)
    span = e - s + 1
    dem_n = np.asarray(dem) / np.asarray(cap)
    dem_norm = float(np.linalg.norm(dem_n)) * np.sqrt(span)
    cos = np.asarray(dot) / (dem_norm * np.sqrt(np.asarray(norm2)) + 1e-30)
    return feas, cos


def fit_scores_many(rem, dem, s, e, inv_cap, scored: bool = False,
                    use_ref: bool = False):
    """Host-facing batched fit API for the lockstep placement engine.

    rem:     (B, N, T, D) open-node remaining capacities, all instances.
    dem:     (B, D) the pending task's demand per instance.
    s, e:    (B,) int inclusive span bounds per instance.
    inv_cap: (B, D) 1/cap of each instance's targeted node-type, with 0
             on padded dimensions (so they contribute nothing to the
             similarity reductions).

    Returns (feasible (B, N) bool, score (B, N) float) — the batched
    analogue of ``fit_scores``; padded/foreign nodes are masked by the
    caller at selection time.
    """
    rem = np.asarray(rem)
    B, N, T, D = rem.shape
    s = np.asarray(s, np.int64)
    e = np.asarray(e, np.int64)
    dem_j = jnp.asarray(dem, jnp.float32)
    inv_j = jnp.asarray(inv_cap, jnp.float32)
    t_ids = np.arange(T)
    mask = ((s[:, None] <= t_ids[None, :])
            & (t_ids[None, :] <= e[:, None])).astype(np.float32)
    if use_ref:
        feas_m, dot, norm2 = ref.fit_scores_many_ref(
            jnp.asarray(rem, jnp.float32), dem_j, jnp.asarray(mask), inv_j
        )
    else:
        rem_btdn = jnp.asarray(
            np.ascontiguousarray(rem.transpose(0, 2, 3, 1)), jnp.float32)
        feas_m, dot, norm2 = _fit.fit_scores_many_pallas(
            rem_btdn, dem_j, jnp.asarray(mask), inv_j,
            interpret=not on_tpu()
        )
    feas = np.asarray(feas_m) >= -_EPS
    if not scored:
        return feas, np.zeros((B, N), np.float32)
    span = (e - s + 1).astype(np.float64)
    dem_n = np.asarray(dem) * np.asarray(inv_cap)
    dem_norm = np.linalg.norm(dem_n, axis=1) * np.sqrt(span)  # (B,)
    cos = np.asarray(dot) / (
        dem_norm[:, None] * np.sqrt(np.asarray(norm2)) + 1e-30)
    return feas, cos


def fit_scores_step(rem, dem, span, capx, dem_norm, scored: bool = False,
                    quantum=None, eps: float = _EPS):
    """In-loop callable form of ``fit_scores_many`` for compiled steppers.

    Unlike the host-facing wrappers above, this is a pure-jnp function
    meant to be *traced* — it takes and returns ``jnp`` arrays, does no
    host conversion or padding, and is safe inside ``lax.while_loop`` /
    ``lax.scan`` bodies (the compiled lockstep placement stepper,
    ``repro.core.place_step``, calls it once per placement step).

    All slot-carrying operands arrive flattened to one contiguous
    reduction axis K = T*D (slot k = t*D + d), the same layout trick
    the numpy engine uses for its feasibility scan: the similarity dot
    then lowers to a batched mat-vec over a contiguous axis instead of
    a 4-D einsum with a tiny trailing dimension, which CPU/TPU backends
    vectorize an order of magnitude better.

    rem:      (B, N, K) open-node remaining capacity.
    dem:      (B, K) the pending task's demand, tiled over timeslots.
    span:     (B, K) bool, True inside each instance's task span.
    capx:     (B, K) node-type capacity tiled over slots, +inf on
              padded dims, so ``rem / capx`` is exact on real dims and
              0 on padded ones.
    dem_norm: (B,) the precomputed per-task demand norm of the
              similarity denominator.
    quantum:  similarity tie-break quantization as a *runtime* scalar
              (1e9 for the engines' shared 9-decimal rounding).  Passing
              it as an operand keeps XLA from folding the division into
              a multiply-by-reciprocal, which is not bit-equal to the
              host engines' ``np.round(score, 9)``.

    Returns ``(feas, score)``, both (B, N): feasibility is the same
    elementwise float comparison the host engines evaluate
    (``not any(rem < dem - eps)`` over the span), and ``score`` is the
    quantized cosine similarity (zeros when ``scored`` is False).  In a
    float64 trace (``jax.experimental.enable_x64``) every elementwise
    term is bit-identical to the numpy engines; the reduction sums may
    differ in the last ulp, which the shared quantization collapses.
    """
    thr = dem - eps
    viol = ((rem < thr[:, None, :]) & span[:, None, :]).any(axis=2)
    feas = ~viol
    if not scored:
        return feas, jnp.zeros(feas.shape, rem.dtype)
    span_f = span.astype(rem.dtype)
    rem_n = rem / capx[:, None, :]
    q = (dem / capx) * span_f                 # exact: dem_n * {0, 1}
    dot = jnp.einsum("bnk,bk->bn", rem_n, q)  # batched mat-vec
    rm = rem_n * span_f[:, None, :]
    norm2 = (rm * rm).sum(axis=2)
    score = dot / (dem_norm[:, None] * jnp.sqrt(norm2) + 1e-30)
    if quantum is not None:
        score = jnp.rint(score * quantum) / quantum
    return feas, score
