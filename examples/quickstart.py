"""Quickstart: TL-Rightsizing in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import NodeTypes, Problem, evaluate, rightsize, verify, \
    trim_timeline

# --- a Figure-1-style instance: time-sharing saves money -----------------
nt = NodeTypes(cap=np.array([[4.0, 8.0], [2.0, 2.0]]),
               cost=np.array([10.0, 6.0]),
               names=("big", "small"))
problem = Problem(
    dem=np.array([[2.0, 3.0],    # task A: 2 cpu, 3 GB, hours 0-1
                  [2.0, 4.0],    # task B: hours 2-3 (disjoint from A!)
                  [1.0, 2.0]]),  # task C: hours 0-3
    start=np.array([0, 2, 0]),
    end=np.array([1, 3, 3]),
    node_types=nt,
    T=4,
)

sol = rightsize(problem, "lp-map-f")
verify(*trim_timeline(problem)[:1], sol)
print(f"time-aware cluster: ${sol.cost(problem):.0f} "
      f"({sol.num_nodes} node) — A and B time-share one big node")

# --- the paper's evaluation protocol on a synthetic instance -------------
from repro.workload import SyntheticSpec, synthetic_instance

p = synthetic_instance(SyntheticSpec(n=400, m=8, D=5, seed=0))
res = evaluate(p)
print("\nnormalized costs (cost / LP lower bound), n=400 synthetic:")
for algo, norm in res["normalized"].items():
    print(f"  {algo:15s} {norm:.3f}")
