"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with fault-tolerant checkpointing.

    PYTHONPATH=src python examples/train_lm.py                  # ~100M
    PYTHONPATH=src python examples/train_lm.py --quick          # tiny/CI

Re-run with the same --ckpt-dir to resume; --crash-at N demonstrates the
restart path.
"""

import sys

from repro.launch.train import run

if __name__ == "__main__":
    args = sys.argv[1:]
    if "--quick" in args:
        args.remove("--quick")
        args = ["--arch", "qwen2.5-3b", "--preset", "smoke",
                "--steps", "60", "--seq", "64"] + args
    else:
        args = ["--preset", "100m", "--steps", "300", "--batch", "4",
                "--seq", "256", "--ckpt-every", "50"] + args
    run(args)
