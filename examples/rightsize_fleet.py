"""Rightsize a TPU fleet for a day of LM jobs — the paper's algorithm
planning capacity for the very jobs this framework trains/serves.

Job demands are measured from the multi-pod dry-run artifacts
(results/dryrun/*.json) when present; run

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod

first for fully-measured demands, then:

    PYTHONPATH=src python examples/rightsize_fleet.py

For the fleet-scale what-if frontier — N demand-scaled scenarios
evaluated through ONE ``FleetEngine`` session (one fused batched LP
solve + lockstep placements, the typed-config API from
``repro.core.engine``):

    PYTHONPATH=src python examples/rightsize_fleet.py fleet -n 8

The fleet banner prints the session's per-phase timings and the
placement-stepper telemetry from ``FleetResult.timings`` (which
engine placed, how many phase waves / device dispatches, fallbacks) —
the "read the telemetry" walkthrough referenced by
docs/benchmarks.md.  Pass ``--placement compiled`` to route the
greedy phase through the compiled on-device stepper (placements are
identical either way):

    PYTHONPATH=src python examples/rightsize_fleet.py fleet -n 8 \
        --placement compiled

Every subcommand of the ``repro.launch.rightsize`` CLI works here too
(``plan``, ``compare``, ``fleet``, ``serve``); bare invocation runs
``compare`` followed by a ``plan``.
"""

import sys

from repro.launch.rightsize import run

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv or argv[0].startswith("-"):
        run(["compare"] + argv)
        argv = ["plan"] + argv
    run(argv)
