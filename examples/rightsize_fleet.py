"""Rightsize a TPU fleet for a day of LM jobs — the paper's algorithm
planning capacity for the very jobs this framework trains/serves.

Job demands are measured from the multi-pod dry-run artifacts
(results/dryrun/*.json) when present; run

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod

first for fully-measured demands, then:

    PYTHONPATH=src python examples/rightsize_fleet.py
"""

import sys

from repro.launch.rightsize import run

if __name__ == "__main__":
    run(["--compare"] + sys.argv[1:])
