"""Batched serving example: prefill a prompt batch, decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-9b
"""

import sys

from repro.launch.serve import run

if __name__ == "__main__":
    run(sys.argv[1:])
