"""One benchmark per paper table/figure (§VI).

Every function returns a list of row dicts; ``run.py`` prints them as CSV
and writes JSON under results/paper/.  The ``scale`` knob trades fidelity
for wall time: 'paper' replicates the paper's sizes (n=1000, 5 seeds);
'quick' shrinks n and seeds for CI.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import evaluate, solve_lp, trim_timeline, rightsize, \
    no_timeline_lowerbound
from repro.workload import SyntheticSpec, gct_like_instance, \
    synthetic_instance

ALGOS = ("penalty-map", "penalty-map-f", "lp-map", "lp-map-f")


def _scale_params(scale: str):
    if scale == "quick":
        return {"n": 200, "n_sweep": (100, 200, 400), "seeds": 2,
                "m": 6, "gct_n": 300, "max_slots": 200}
    if scale == "default":
        # paper-shaped but sized for a single CPU core (~20 min total)
        return {"n": 500, "n_sweep": (500, 1000), "seeds": 2,
                "m": 10, "gct_n": 500, "max_slots": 300}
    return {"n": 1000, "n_sweep": (500, 1000, 1500, 2000), "seeds": 5,
            "m": 10, "gct_n": 1000, "max_slots": 400}


def _avg_eval(mk_problem, seeds: int, max_slots=None) -> dict:
    sums = {a: 0.0 for a in ALGOS}
    lb = 0.0
    wall = {a: 0.0 for a in ALGOS}
    for s in range(seeds):
        p = mk_problem(s)
        t, _ = trim_timeline(p)
        from repro.core.lp_map import solve_lp as _slp
        lp_result = _slp(t, max_slots=max_slots)
        for a in ALGOS:
            sol = rightsize(t, a, lp_result=lp_result)
            sums[a] += sol.cost(t) / max(lp_result.objective, 1e-9)
            wall[a] += sol.meta["wall_s"]
        lb += lp_result.objective
    out = {a: sums[a] / seeds for a in ALGOS}
    out["lb"] = lb / seeds
    out["wall_s"] = {a: wall[a] / seeds for a in ALGOS}
    return out


# ---------------------------------------------------------------- Fig 7a
def fig7a(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for D in (2, 5, 7):
        res = _avg_eval(
            lambda s, D=D: synthetic_instance(SyntheticSpec(
                n=sp["n"], m=sp["m"], D=D, seed=s)),
            sp["seeds"])
        rows.append({"figure": "7a", "D": D,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 7b
def fig7b(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for m in (5, 10, 15):
        res = _avg_eval(
            lambda s, m=m: synthetic_instance(SyntheticSpec(
                n=sp["n"], m=m, D=5, seed=s)),
            sp["seeds"])
        rows.append({"figure": "7b", "m": m,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 7c
def fig7c(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for hi in (0.05, 0.1, 0.2):
        res = _avg_eval(
            lambda s, hi=hi: synthetic_instance(SyntheticSpec(
                n=sp["n"], m=sp["m"], D=5, demand=(0.01, hi), seed=s)),
            sp["seeds"])
        rows.append({"figure": "7c", "demand_hi": hi,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 8a
def fig8a(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for n in sp["n_sweep"]:
        res = _avg_eval(
            lambda s, n=n: gct_like_instance(n=n, m=sp["m"], seed=s),
            sp["seeds"], max_slots=sp["max_slots"])
        rows.append({"figure": "8a", "n": n,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 8b
def fig8b(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for m in (4, 7, 10, 13):
        res = _avg_eval(
            lambda s, m=m: gct_like_instance(n=sp["gct_n"], m=m, seed=s),
            sp["seeds"], max_slots=sp["max_slots"])
        rows.append({"figure": "8b", "m": m,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 9
def fig9(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for e in (0.33, 1.0, 2.0, 3.0):
        res = _avg_eval(
            lambda s, e=e: synthetic_instance(SyntheticSpec(
                n=sp["n"], m=sp["m"], D=5, cost_model="heterogeneous",
                e=e, seed=s)),
            sp["seeds"])
        rows.append({"figure": "9", "e": e,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 10
def fig10(scale="paper"):
    sp = _scale_params(scale)
    rows = []
    for m in (4, 7, 10, 13):
        res = _avg_eval(
            lambda s, m=m: gct_like_instance(
                n=sp["gct_n"], m=m, seed=s, cost_model="gce"),
            sp["seeds"], max_slots=sp["max_slots"])
        rows.append({"figure": "10", "m": m,
                     **{a: round(res[a], 4) for a in ALGOS}})
    return rows


# ---------------------------------------------------------------- Fig 11
def fig11(scale="paper"):
    """PenaltyMap-F vs LP-map-F across the GCT scenarios."""
    sp = _scale_params(scale)
    rows = []
    scenarios = [("hom", dict(cost_model="homogeneous")),
                 ("gce", dict(cost_model="gce"))]
    for tag, kw in scenarios:
        for m in (4, 10, 13):
            res = _avg_eval(
                lambda s, m=m, kw=kw: gct_like_instance(
                    n=sp["gct_n"], m=m, seed=s, **kw),
                sp["seeds"], max_slots=sp["max_slots"])
            rows.append({
                "figure": "11", "scenario": f"{tag}-m{m}",
                "penalty-map-f": round(res["penalty-map-f"], 4),
                "lp-map-f": round(res["lp-map-f"], 4),
                "gain_pct": round(100 * (res["penalty-map-f"]
                                         - res["lp-map-f"])
                                  / max(res["lp-map-f"], 1e-9), 2),
            })
    return rows


# ------------------------------------------------------------ §VI-E time
def runtime(scale="paper"):
    """Paper: PenaltyMap ~1s; LP solve ~15min (CBC) at n=2000, m=13;
    mapping+placement ~1s.  We report HiGHS numbers."""
    n = {"paper": 2000, "default": 1000}.get(scale, 400)
    g = gct_like_instance(n=n, m=13, seed=0)
    t, _ = trim_timeline(g)
    rows = []
    t0 = time.perf_counter()
    sol = rightsize(t, "penalty-map")
    rows.append({"figure": "runtime", "algo": "penalty-map",
                 "seconds": round(time.perf_counter() - t0, 3)})
    t0 = time.perf_counter()
    lp = solve_lp(t)
    t_lp = time.perf_counter() - t0
    rows.append({"figure": "runtime", "algo": "lp-solve(HiGHS)",
                 "seconds": round(t_lp, 3)})
    t0 = time.perf_counter()
    sol = rightsize(t, "lp-map-f", lp_result=lp)
    rows.append({"figure": "runtime", "algo": "lp-map-f (post-LP)",
                 "seconds": round(time.perf_counter() - t0, 3)})
    return rows


# ------------------------------------------------------------ §VI-F
def no_timeline(scale="paper"):
    """Timeline-aware LP-map-F cost vs the timeline-agnostic lower bound:
    the paper reports ~2x average."""
    sp = _scale_params(scale)
    factors = []
    for s in range(sp["seeds"]):
        g = gct_like_instance(n=sp["gct_n"], m=10, seed=s)
        t, _ = trim_timeline(g)
        sol = rightsize(t, "lp-map-f")
        flat_lb = no_timeline_lowerbound(t)
        factors.append(flat_lb / sol.cost(t))
    return [{"figure": "no_timeline",
             "agnostic_lb_over_aware_cost": round(float(np.mean(factors)), 3),
             "min": round(float(np.min(factors)), 3),
             "max": round(float(np.max(factors)), 3)}]


# ------------------------------------------------------------ Fig 5
def near_integrality(scale="paper"):
    sp = _scale_params(scale)
    p = synthetic_instance(SyntheticSpec(n=500 if scale == "paper" else 150,
                                         m=10, D=5, seed=0))
    t, _ = trim_timeline(p)
    res = solve_lp(t)
    xm = res.x_max
    return [{"figure": "5(near-integrality)",
             "frac_xmax_ge_0.99": round(float((xm >= 0.99).mean()), 4),
             "frac_xmax_ge_0.9": round(float((xm >= 0.9).mean()), 4),
             "frac_xmax_ge_0.6": round(float((xm >= 0.6).mean()), 4),
             "median_xmax": round(float(np.median(xm)), 4)}]


# ---------------------------------------------------- beyond-paper tables
def scaling_beyond(scale="default"):
    """HiGHS (exact) vs JAX PDHG (matrix-free, O(n+T)/iter) as n grows —
    the accelerator-native solve path's quality/latency trade."""
    from repro.core import solve_lp_pdhg

    ns = {"quick": (200, 400), "default": (500, 1000, 2000),
          "paper": (500, 1000, 2000, 4000)}[scale]
    rows = []
    for n in ns:
        g = gct_like_instance(n=n, m=10, seed=0)
        t, _ = trim_timeline(g)
        t0 = time.perf_counter()
        exact = solve_lp(t, max_slots=400)
        t_hi = time.perf_counter() - t0
        t0 = time.perf_counter()
        pd = solve_lp_pdhg(t, iters=1500)
        t_pd = time.perf_counter() - t0
        rows.append({
            "figure": "scaling(beyond)", "n": n,
            "highs_s": round(t_hi, 2), "pdhg_s": round(t_pd, 2),
            "highs_obj": round(exact.objective, 3),
            "pdhg_primal": round(pd.objective, 3),
            "pdhg_dual_lb": round(pd.lower_bound, 3),
            "pdhg_gap_pct": round(100 * pd.gap
                                  / max(pd.objective, 1e-9), 2),
        })
    return rows


def local_search_beyond(scale="default"):
    """Node-elimination post-pass on LP-map-F (the consistent beyond-paper
    cost reduction)."""
    sp = _scale_params(scale)
    rows = []
    for seed in range(sp["seeds"]):
        g = gct_like_instance(n=sp["gct_n"], m=10, seed=seed)
        t, _ = trim_timeline(g)
        from repro.core.lp_map import solve_lp as _slp

        lp_result = _slp(t, max_slots=sp["max_slots"])
        base = rightsize(t, "lp-map-f", lp_result=lp_result)
        ls = rightsize(t, "lp-map-f+ls", lp_result=lp_result)
        lb = lp_result.objective
        rows.append({
            "figure": "local_search(beyond)", "seed": seed,
            "lp-map-f": round(base.cost(t) / lb, 4),
            "lp-map-f+ls": round(ls.cost(t) / lb, 4),
            "gain_pct": round(
                100 * (1 - ls.cost(t) / base.cost(t)), 2),
        })
    return rows


ALL_TABLES = {
    "fig7a": fig7a, "fig7b": fig7b, "fig7c": fig7c,
    "fig8a": fig8a, "fig8b": fig8b, "fig9": fig9, "fig10": fig10,
    "fig11": fig11, "runtime": runtime, "no_timeline": no_timeline,
    "near_integrality": near_integrality,
    "scaling_beyond": scaling_beyond,
    "local_search_beyond": local_search_beyond,
}
