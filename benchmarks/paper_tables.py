"""One benchmark per paper table/figure (§VI).

Every function returns a list of row dicts; ``run.py`` prints them as CSV
and writes JSON under results/paper/.  The ``scale`` knob trades fidelity
for wall time: 'paper' replicates the paper's sizes (n=1000, 5 seeds);
'quick' shrinks n and seeds for CI.

Each sweep table runs its whole instance grid through ONE configured
``repro.core.FleetEngine`` session (``lp='pdhg'``): one warm-started
batched LP chain plus, with ``placement='batched'`` (default), ONE
lockstep greedy placement per protocol combo.  Pass ``lp='highs'`` for
the paper's original per-instance exact-LP loop and ``placement='loop'``
for the per-instance placement loop (identical placements either way);
``buckets`` routes the ``fleet_sweep`` table's bucketing section through
the shape-bucket packing planner.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (FleetEngine, PlacementConfig, SolverConfig,
                        SweepConfig, evaluate_many, no_timeline_lowerbound,
                        rightsize, solve_lp, trim_timeline)
from repro.workload import SyntheticSpec, gct_like_instance, \
    sweep_specs, synthetic_batch, synthetic_instance

ALGOS = ("penalty-map", "penalty-map-f", "lp-map", "lp-map-f")


def _scale_params(scale: str, lp_tol=None, lp_max_iters=None):
    """Per-scale knobs.  ``lp_iters`` is the legacy fixed iteration count
    (still used by the fixed-step timing comparisons); the LP phase of
    every sweep table now stops on ``lp_tol`` (normalized duality gap)
    with ``lp_max_iters`` as the worst-case cap — both overridable from
    ``run.py --lp-tol / --lp-max-iters``."""
    from repro.core.batch import DEFAULT_TOL

    if scale == "quick":
        sp = {"n": 200, "n_sweep": (100, 200, 400), "seeds": 2,
              "m": 6, "gct_n": 300, "max_slots": 200, "lp_iters": 1000,
              "lp_tol": DEFAULT_TOL, "lp_max_iters": 4000}
    elif scale == "default":
        # paper-shaped but sized for a single CPU core (~20 min total)
        sp = {"n": 500, "n_sweep": (500, 1000), "seeds": 2,
              "m": 10, "gct_n": 500, "max_slots": 300, "lp_iters": 1500,
              "lp_tol": DEFAULT_TOL, "lp_max_iters": 6000}
    else:
        sp = {"n": 1000, "n_sweep": (500, 1000, 1500, 2000), "seeds": 5,
              "m": 10, "gct_n": 1000, "max_slots": 400, "lp_iters": 2000,
              "lp_tol": DEFAULT_TOL, "lp_max_iters": 8000}
    if lp_tol is not None:
        sp["lp_tol"] = lp_tol
    if lp_max_iters is not None:
        sp["lp_max_iters"] = lp_max_iters
    return sp


def _highs_entry(p, max_slots):
    """Per-instance exact-LP protocol entry (the paper's original loop),
    with the Lemma-sound ``max_slots`` constraint subsampling at GCT
    scale."""
    from repro.core.lp_map import solve_lp as _slp

    t, _ = trim_timeline(p)
    lp_result = _slp(t, max_slots=max_slots)
    lb = lp_result.objective
    entry = {"lb": lb, "costs": {}, "normalized": {}, "wall_s": {}}
    for a in ALGOS:
        sol = rightsize(t, a, lp_result=lp_result)
        cost = sol.cost(t)
        entry["costs"][a] = cost
        entry["normalized"][a] = cost / max(lb, 1e-9)
        entry["wall_s"][a] = sol.meta["wall_s"]
    return entry


def _sweep_eval(groups, sp, lp="pdhg", max_slots=None,
                placement="batched"):
    """Run the §VI protocol over a whole sweep grid.

    ``groups[g]`` holds one sweep point's seed-replicated instances, in
    grid-adjacent (``sweep_specs``) order.  With ``lp='pdhg'`` the grid
    runs through a ``FleetEngine`` session: the LP phase runs the
    adaptive restarted engine to ``sp['lp_tol']`` as a warm-started
    chain over the sweep — each group seeds from its neighbor's
    primal/dual solution — and (with ``placement='batched'``) ONE
    lockstep placement per protocol combo; ``lp='highs'`` reproduces
    the per-instance exact-LP loop (``max_slots`` caps its constraint
    rows at GCT scale).  Returns one seed-averaged dict per group with
    the normalized cost per algorithm, 'lb', and per-algo 'wall_s'.
    """
    flat = [p for g in groups for p in g]
    if lp == "pdhg":
        sizes = {len(g) for g in groups}
        warm = sizes.pop() if len(sizes) == 1 and len(groups) > 1 else None
        engine = FleetEngine(
            solver=SolverConfig(tol=sp["lp_tol"],
                                iters=sp["lp_max_iters"]),
            placement=PlacementConfig(engine=placement),
            sweep=SweepConfig(warm_start=warm),
            algos=ALGOS)
        entries = engine.evaluate(flat).entries
    else:
        entries = [_highs_entry(p, max_slots) for p in flat]
    rows, i = [], 0
    for g in groups:
        part = entries[i : i + len(g)]
        i += len(g)
        row = {a: float(np.mean([e["normalized"][a] for e in part]))
               for a in ALGOS}
        row["lb"] = float(np.mean([e["lb"] for e in part]))
        row["wall_s"] = {a: float(np.mean([e["wall_s"][a] for e in part]))
                         for a in ALGOS}
        rows.append(row)
    return rows


def _spec_table(figure, axis_name, axis_vals, base, sp, lp,
                spec_axis=None, placement="batched"):
    """Sweep one SyntheticSpec axis: one batched LP for the whole table."""
    specs = sweep_specs(base, seeds=sp["seeds"],
                        **{spec_axis or axis_name: axis_vals})
    problems = synthetic_batch(specs)
    k = sp["seeds"]
    groups = [problems[i * k : (i + 1) * k] for i in range(len(axis_vals))]
    res = _sweep_eval(groups, sp, lp=lp, placement=placement)
    return [{"figure": figure, axis_name: v,
             **{a: round(r[a], 4) for a in ALGOS}}
            for v, r in zip(axis_vals, res)]


def _gct_table(figure, axis_name, axis_vals, mk, sp, lp,
               placement="batched"):
    """Sweep a GCT-emulation axis: one batched LP for the whole table."""
    groups = [[mk(v, s) for s in range(sp["seeds"])] for v in axis_vals]
    res = _sweep_eval(groups, sp, lp=lp, max_slots=sp["max_slots"],
                      placement=placement)
    return [{"figure": figure, axis_name: v,
             **{a: round(r[a], 4) for a in ALGOS}}
            for v, r in zip(axis_vals, res)]


# ---------------------------------------------------------------- Fig 7a
def fig7a(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    return _spec_table("7a", "D", (2, 5, 7),
                       SyntheticSpec(n=sp["n"], m=sp["m"]), sp, lp,
                       placement=placement)


# ---------------------------------------------------------------- Fig 7b
def fig7b(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    return _spec_table("7b", "m", (5, 10, 15),
                       SyntheticSpec(n=sp["n"], D=5), sp, lp,
                       placement=placement)


# ---------------------------------------------------------------- Fig 7c
def fig7c(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    rows = _spec_table("7c", "demand_hi", ((0.01, 0.05), (0.01, 0.1),
                                           (0.01, 0.2)),
                       SyntheticSpec(n=sp["n"], m=sp["m"], D=5), sp, lp,
                       spec_axis="demand", placement=placement)
    for row in rows:
        row["demand_hi"] = row["demand_hi"][1]
    return rows


# ---------------------------------------------------------------- Fig 8a
def fig8a(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    return _gct_table(
        "8a", "n", sp["n_sweep"],
        lambda n, s: gct_like_instance(n=n, m=sp["m"], seed=s), sp, lp,
        placement=placement)


# ---------------------------------------------------------------- Fig 8b
def fig8b(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    return _gct_table(
        "8b", "m", (4, 7, 10, 13),
        lambda m, s: gct_like_instance(n=sp["gct_n"], m=m, seed=s), sp, lp,
        placement=placement)


# ---------------------------------------------------------------- Fig 9
def fig9(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    return _spec_table("9", "e", (0.33, 1.0, 2.0, 3.0),
                       SyntheticSpec(n=sp["n"], m=sp["m"], D=5,
                                     cost_model="heterogeneous"), sp, lp,
                       placement=placement)


# ---------------------------------------------------------------- Fig 10
def fig10(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    return _gct_table(
        "10", "m", (4, 7, 10, 13),
        lambda m, s: gct_like_instance(n=sp["gct_n"], m=m, seed=s,
                                       cost_model="gce"), sp, lp,
        placement=placement)


# ---------------------------------------------------------------- Fig 11
def fig11(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    """PenaltyMap-F vs LP-map-F across the GCT scenarios."""
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    scenarios = [("hom", dict(cost_model="homogeneous")),
                 ("gce", dict(cost_model="gce"))]
    points = [(tag, m, kw) for tag, kw in scenarios for m in (4, 10, 13)]
    groups = [[gct_like_instance(n=sp["gct_n"], m=m, seed=s, **kw)
               for s in range(sp["seeds"])] for _, m, kw in points]
    res = _sweep_eval(groups, sp, lp=lp, max_slots=sp["max_slots"],
                      placement=placement)
    return [{
        "figure": "11", "scenario": f"{tag}-m{m}",
        "penalty-map-f": round(r["penalty-map-f"], 4),
        "lp-map-f": round(r["lp-map-f"], 4),
        "gain_pct": round(100 * (r["penalty-map-f"] - r["lp-map-f"])
                          / max(r["lp-map-f"], 1e-9), 2),
    } for (tag, m, _), r in zip(points, res)]


# ------------------------------------------------------------ §VI-E time
def runtime(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    """Paper: PenaltyMap ~1s; LP solve ~15min (CBC) at n=2000, m=13;
    mapping+placement ~1s.  We report HiGHS numbers."""
    n = {"paper": 2000, "default": 1000}.get(scale, 400)
    g = gct_like_instance(n=n, m=13, seed=0)
    t, _ = trim_timeline(g)
    rows = []
    t0 = time.perf_counter()
    sol = rightsize(t, "penalty-map")
    rows.append({"figure": "runtime", "algo": "penalty-map",
                 "seconds": round(time.perf_counter() - t0, 3)})
    t0 = time.perf_counter()
    lp = solve_lp(t)
    t_lp = time.perf_counter() - t0
    rows.append({"figure": "runtime", "algo": "lp-solve(HiGHS)",
                 "seconds": round(t_lp, 3)})
    t0 = time.perf_counter()
    sol = rightsize(t, "lp-map-f", lp_result=lp)
    rows.append({"figure": "runtime", "algo": "lp-map-f (post-LP)",
                 "seconds": round(time.perf_counter() - t0, 3)})
    return rows


# ------------------------------------------------------------ §VI-F
def no_timeline(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    """Timeline-aware LP-map-F cost vs the timeline-agnostic lower bound:
    the paper reports ~2x average."""
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    factors = []
    for s in range(sp["seeds"]):
        g = gct_like_instance(n=sp["gct_n"], m=10, seed=s)
        t, _ = trim_timeline(g)
        sol = rightsize(t, "lp-map-f")
        flat_lb = no_timeline_lowerbound(t)
        factors.append(flat_lb / sol.cost(t))
    return [{"figure": "no_timeline",
             "agnostic_lb_over_aware_cost": round(float(np.mean(factors)), 3),
             "min": round(float(np.min(factors)), 3),
             "max": round(float(np.max(factors)), 3)}]


# ------------------------------------------------------------ Fig 5
def near_integrality(scale="paper", lp="pdhg", placement="batched",
          lp_tol=None, lp_max_iters=None):
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    p = synthetic_instance(SyntheticSpec(n=500 if scale == "paper" else 150,
                                         m=10, D=5, seed=0))
    t, _ = trim_timeline(p)
    res = solve_lp(t)
    xm = res.x_max
    return [{"figure": "5(near-integrality)",
             "frac_xmax_ge_0.99": round(float((xm >= 0.99).mean()), 4),
             "frac_xmax_ge_0.9": round(float((xm >= 0.9).mean()), 4),
             "frac_xmax_ge_0.6": round(float((xm >= 0.6).mean()), 4),
             "median_xmax": round(float(np.median(xm)), 4)}]


# ---------------------------------------------------- beyond-paper tables
def scaling_beyond(scale="default", lp="pdhg", placement="batched",
                   lp_tol=None, lp_max_iters=None):
    """HiGHS (exact) vs JAX PDHG (matrix-free, O(n+T)/iter) as n grows —
    the accelerator-native solve path's quality/latency trade."""
    from repro.core import solve_lp_pdhg

    ns = {"quick": (200, 400), "default": (500, 1000, 2000),
          "paper": (500, 1000, 2000, 4000)}[scale]
    rows = []
    for n in ns:
        g = gct_like_instance(n=n, m=10, seed=0)
        t, _ = trim_timeline(g)
        t0 = time.perf_counter()
        exact = solve_lp(t, max_slots=400)
        t_hi = time.perf_counter() - t0
        t0 = time.perf_counter()
        pd = solve_lp_pdhg(t, iters=1500)
        t_pd = time.perf_counter() - t0
        rows.append({
            "figure": "scaling(beyond)", "n": n,
            "highs_s": round(t_hi, 2), "pdhg_s": round(t_pd, 2),
            "highs_obj": round(exact.objective, 3),
            "pdhg_primal": round(pd.objective, 3),
            "pdhg_dual_lb": round(pd.lower_bound, 3),
            "pdhg_gap_pct": round(100 * pd.gap
                                  / max(pd.objective, 1e-9), 2),
        })
    return rows


def local_search_beyond(scale="default", lp="pdhg", placement="batched",
                   lp_tol=None, lp_max_iters=None):
    """Node-elimination post-pass on LP-map-F (the consistent beyond-paper
    cost reduction)."""
    sp = _scale_params(scale, lp_tol, lp_max_iters)
    rows = []
    for seed in range(sp["seeds"]):
        g = gct_like_instance(n=sp["gct_n"], m=10, seed=seed)
        t, _ = trim_timeline(g)
        from repro.core.lp_map import solve_lp as _slp

        lp_result = _slp(t, max_slots=sp["max_slots"])
        base = rightsize(t, "lp-map-f", lp_result=lp_result)
        ls = rightsize(t, "lp-map-f+ls", lp_result=lp_result)
        lb = lp_result.objective
        rows.append({
            "figure": "local_search(beyond)", "seed": seed,
            "lp-map-f": round(base.cost(t) / lb, 4),
            "lp-map-f+ls": round(ls.cost(t) / lb, 4),
            "gain_pct": round(
                100 * (1 - ls.cost(t) / base.cost(t)), 2),
        })
    return rows


def fleet_sweep(scale="default", lp="pdhg", placement="batched",
                   lp_tol=None, lp_max_iters=None, buckets=None,
                   scenarios=None):
    """The batched engine's headline: LP + placement phases of a ragged
    Table-I-style sweep grid.  The LP phase runs as one fused padded
    solve vs the per-instance loop (which pays a fresh JIT compile per
    distinct instance shape); the placement phase then consumes the
    batched mappings through the lockstep ``place_many`` engine, the
    compiled on-device stepper (``placement='compiled'``; cold and warm
    wall-clock plus device-dispatch telemetry), and the per-instance
    ``two_phase`` loop, timing all four {fit} x {filling} protocol
    combos (placements must be identical three ways).

    The shape-bucketing section runs the same grid through a
    ``FleetEngine`` with the bucket planner enabled (``--buckets``, or
    a per-scale default): the ragged grid is split into a few shape
    buckets instead of one worst-case padded shape, costs must match
    the single-bucket path exactly, and the table reports the bucket
    count, padded-cell waste fraction before/after bucketing, and
    per-bucket compile+solve seconds.

    The solver-telemetry section then runs the same grid through the
    tolerance-stopped engine three ways — fixed-step vanilla, adaptive+
    restarted (cold), and adaptive+restarted warm-started along the
    sweep — and reports iterations-to-tolerance, restarts, and final KKT
    residuals (the ``_solver_stats`` blob ``run.py`` writes as
    ``solver_stats.json``, which the CI convergence gate diffs against
    ``results/golden/solver_stats.json``).

    The robustness section runs the fixed golden burst grid through
    the stochastic layer (``benchmarks.stochastic_smoke``: K-scenario
    fan-out, one batched dispatch, CVaR selection) and reports the
    robust-vs-expected fleet columns; the blob rides under the
    ``stochastic`` key of ``solver_stats.json`` for the
    ``check_stochastic`` gate (``scenarios`` = K, default the golden
    K).

    The constraint section replays the ``check_constraints`` smoke
    grid (deadlines, affinity, anti-affinity, exclusivity, malleable
    widths): every plan must be clean under the independent
    feasibility oracle and the three placement engines identical on
    the lowered instances; the blob rides under the ``constraints``
    key of ``solver_stats.json``."""
    import jax

    from repro.core import (pack_problems, place_many, solve_lp_many,
                            solve_lp_pdhg, two_phase, FIT_POLICIES)
    from repro.core.batch import DEFAULT_CHECK_EVERY, dispatch_count
    from repro.core.engine import _placement_telemetry
    from repro.core.lp_pdhg import merge_stats

    sp = _scale_params(scale, lp_tol, lp_max_iters)
    shapes = {"quick": 8, "default": 12, "paper": 16}.get(scale, 12)
    # seed-replicated like the paper's sweeps: many instances per shape
    # (that is the fleet shape both batched phases amortize over)
    seeds = {"quick": 4, "default": 6, "paper": 8}.get(scale, 4)
    base_n = {"quick": 40, "default": 80, "paper": 160}.get(scale, 80)
    specs = [SyntheticSpec(n=base_n + 15 * i, m=sp["m"], D=5,
                           T=12 + 2 * i, seed=s)
             for i in range(shapes) for s in range(seeds)]
    problems = [trim_timeline(p)[0] for p in synthetic_batch(specs)]
    iters = sp["lp_iters"]

    jax.clear_caches()
    t0 = time.perf_counter()
    batched = solve_lp_many(problems, iters=iters)
    t_batch = time.perf_counter() - t0
    jax.clear_caches()
    t0 = time.perf_counter()
    looped = [solve_lp_pdhg(p, iters=iters) for p in problems]
    t_loop = time.perf_counter() - t0
    agree = all(np.array_equal(a.mapping, b.mapping)
                for a, b in zip(batched, looped))

    # placement phase on the batched mappings: lockstep vs per-instance
    batch = pack_problems(problems)
    maps = [r.mapping for r in batched]
    combos = [(fit, filling) for fit in FIT_POLICIES
              for filling in (False, True)]
    t0 = time.perf_counter()
    placed_b = [place_many(batch, maps, fit=fit, filling=filling)
                for fit, filling in combos]
    t_place_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    placed_l = [[two_phase(t, mp, fit=fit, filling=filling)
                 for t, mp in zip(batch.problems, maps)]
                for fit, filling in combos]
    t_place_l = time.perf_counter() - t0
    place_agree = all(
        np.array_equal(a.assign, b.assign)
        and np.array_equal(a.node_type, b.node_type)
        for many, loop in zip(placed_b, placed_l)
        for a, b in zip(many, loop))

    # compiled on-device stepper: cold (compiles included), then warm,
    # with per-call stepper telemetry (dispatch counts, modes)
    tels: list[dict] = []
    placed_c = []
    t0 = time.perf_counter()
    for fit, filling in combos:
        tel: dict = {}
        placed_c.append(place_many(batch, maps, fit=fit,
                                   filling=filling,
                                   placement="compiled",
                                   telemetry=tel))
        tels.append(tel)
    t_place_c_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for fit, filling in combos:
        place_many(batch, maps, fit=fit, filling=filling,
                   placement="compiled")
    t_place_c = time.perf_counter() - t0
    compiled_agree = all(
        np.array_equal(a.assign, b.assign)
        and np.array_equal(a.node_type, b.node_type)
        for many, comp in zip(placed_b, placed_c)
        for a, b in zip(many, comp))
    # same aggregation FleetResult.timings["placement"] carries
    stepper = _placement_telemetry("compiled", tels)

    # --- shape-bucketed packing: FleetEngine vs single-bucket --------
    # the ragged grid padded to ONE worst-case shape wastes most of its
    # padded cells; the engine's bucket planner splits it into a few
    # shape buckets (cost model: padded cells + per-bucket compile) and
    # must reproduce the single-bucket protocol costs exactly
    n_buckets = buckets or {"quick": 4, "default": 4, "paper": 6}.get(
        scale, 4)
    bucket_algos = ("lp-map", "lp-map-f")
    engine = FleetEngine(solver=SolverConfig(iters=iters),
                         sweep=SweepConfig(max_buckets=n_buckets),
                         algos=bucket_algos)
    jax.clear_caches()
    fres = engine.evaluate(problems)
    plan = fres.plan
    single = evaluate_many(problems, algos=bucket_algos, lp_iters=iters)
    bucket_costs_identical = all(
        a["costs"] == b["costs"] for a, b in zip(single, fres.entries))
    bucketing = {
        **plan.summary(),
        "bucket_lp_s": [round(t, 3) for t in fres.timings["bucket_lp_s"]],
        "bucket_place_s": [round(t, 3)
                           for t in fres.timings["bucket_place_s"]],
        "costs_identical": bool(bucket_costs_identical),
    }

    # --- solver telemetry: vanilla vs adaptive vs warm-started sweep ---
    tol, cap = sp["lp_tol"], sp["lp_max_iters"]
    res_van, st_van = solve_lp_many(problems, iters=cap, tol=tol,
                                    adaptive=False, restart=False,
                                    full_output=True)
    _, st_ada = solve_lp_many(problems, iters=cap, tol=tol,
                              full_output=True)
    # warm-started sweep chain through the typed-config surface
    # (grid-adjacent groups of `seeds`; solve_lp_sweep is a deprecated
    # shim over exactly this), sequentially and as the compiled
    # one-dispatch pipeline
    warm_engine = FleetEngine(solver=SolverConfig(tol=tol, iters=cap),
                              sweep=SweepConfig(warm_start=seeds))
    res_warm, stats_warm = warm_engine.solve(problems)
    d0 = dispatch_count()
    res_pipe, stats_pipe = warm_engine.with_overrides(
        pipeline=True).solve(problems)
    pipe_dispatches = dispatch_count() - d0
    van, ada = st_van.summary(), merge_stats([st_ada])
    warm = merge_stats(stats_warm)
    pipe = merge_stats(stats_pipe)

    # protocol-cost parity at tol: the lp-map-f entry (best fit policy,
    # with filling) from the vanilla vs the warm-started mappings,
    # computed through the lockstep batched placement engine.  Both
    # solves are epsilon-optimal, so their certified LP objectives agree
    # within the provable tol slack; the *rounded* protocol cost of a
    # degenerate instance can land on a different epsilon-optimal vertex
    # either way, so per-instance drift is two-sided rounding noise and
    # parity is pinned in aggregate (total drift) instead.
    def _proto_costs(results):
        per_fit = [place_many(batch, [r.mapping for r in results],
                              fit=f, filling=True) for f in FIT_POLICIES]
        return [min(sols[b].cost(t) for sols in per_fit)
                for b, t in enumerate(batch.problems)]

    cost_v = _proto_costs(res_van)
    cost_w = _proto_costs(res_warm)
    drift_pct = 100.0 * (sum(cost_w) - sum(cost_v)) / sum(cost_v)
    drift_max_pct = 100.0 * max(
        abs(w - v) / v for v, w in zip(cost_v, cost_w))
    slack_ok = all(
        abs(a.objective - b.objective)
        <= tol * (2.0 + a.objective + a.lower_bound
                  + b.objective + b.lower_bound)
        for a, b in zip(res_van, res_warm))

    # pipeline-vs-sequential identity: the compiled chain runs the same
    # group solves inside one lax.scan, so the rounded mappings (hence
    # protocol costs) must match the sequential chain exactly
    cost_p = _proto_costs(res_pipe)
    pipeline_stats = {
        "groups": shapes, "group_size": seeds,
        "dispatches": int(pipe_dispatches),
        "sequential_dispatches": shapes,
        "total_iters": pipe["total_iters"],
        "converged_frac": pipe["converged_frac"],
        "costs_identical": bool(cost_p == cost_w),
    }

    # --- ruiz+omega advantage on an ill-conditioned gate grid --------
    # heterogeneous costs plus a wide capacity range make w = dem/cap
    # span orders of magnitude across node types — the conditioning
    # regime Ruiz equilibration targets.  Fixed grid at every scale
    # (the CI gate pins the reduction, so it must not move with
    # --scale).
    gate_tol, gate_cap = 1e-3, 20000
    gate_specs = [SyntheticSpec(n=60, m=8, D=5, T=16, seed=s,
                                cost_model="heterogeneous",
                                capacity=(0.1, 8.0))
                  for s in range(12)]
    gate_problems = [trim_timeline(p)[0]
                     for p in synthetic_batch(gate_specs)]
    gate_batch = pack_problems(gate_problems)
    res_gb, st_gb = solve_lp_many(gate_batch, iters=gate_cap,
                                  tol=gate_tol, scaling="none",
                                  omega=False, full_output=True)
    res_gr, st_gr = solve_lp_many(gate_batch, iters=gate_cap,
                                  tol=gate_tol, full_output=True)

    def _gate_costs(results):
        per_fit = [place_many(gate_batch, [r.mapping for r in results],
                              fit=f, filling=True)
                   for f in FIT_POLICIES]
        return [min(sols[b].cost(t) for sols in per_fit)
                for b, t in enumerate(gate_batch.problems)]

    gcost_b, gcost_r = _gate_costs(res_gb), _gate_costs(res_gr)
    med_b = float(np.median(st_gb.iterations))
    med_r = float(np.median(st_gr.iterations))
    scaling_stats = {
        "grid": {"B": len(gate_specs), "n": 60, "m": 8,
                 "cost_model": "heterogeneous", "capacity": [0.1, 8.0]},
        "tol": gate_tol,
        "baseline_median_iters": med_b,
        "ruiz_median_iters": med_r,
        "baseline_total_iters": int(st_gb.iterations.sum()),
        "ruiz_total_iters": int(st_gr.iterations.sum()),
        "median_iter_reduction": round(1.0 - med_r / med_b, 4),
        "converged_frac": float(np.mean(st_gr.converged)),
        "cost_drift_max_pct": round(100.0 * max(
            abs(r - b) / b for b, r in zip(gcost_b, gcost_r)), 2),
    }

    # --- stochastic robustness on the golden burst grid --------------
    # the fixed K-scenario fan-out + CVaR selection smoke
    # (benchmarks.stochastic_smoke): like the ruiz gate grid above, the
    # forecast is pinned at every --scale because check_stochastic
    # diffs the frontier against results/golden/stochastic.json; only
    # K moves (benchmarks.run --scenarios)
    from benchmarks.stochastic_smoke import stochastic_smoke

    stochastic_stats = stochastic_smoke(scenarios)

    # --- constraint planning on the fixed smoke grid -----------------
    # the check_constraints gate grid (deadlines, affinity merges,
    # anti-affinity, exclusivity, widths): plans must be oracle-clean
    # and the three placement engines identical under lowering
    from benchmarks.check_constraints import (_smoke_grid,
                                              check_engine_agreement,
                                              check_oracle_smoke)

    cgrid = _smoke_grid()
    constraint_stats = {
        "instances": len(cgrid),
        "active": int(sum(not low.identity for _, low in cgrid)),
        "oracle_violations": len(check_oracle_smoke()),
        "engines_identical": not check_engine_agreement(),
    }

    solver_stats = {
        "grid": {"B": len(problems), "shapes": shapes, "seeds": seeds,
                 "scale": scale},
        "tol": tol, "max_iters": cap,
        # iteration counts quantize to the convergence-check interval;
        # the regression gate grants one quantum of slack on top of the
        # fractional budget
        "check_every": DEFAULT_CHECK_EVERY,
        "bucketing": bucketing,
        "placement_stepper": {
            "lockstep_s": round(t_place_b, 3),
            "compiled_cold_s": round(t_place_c_cold, 3),
            "compiled_s": round(t_place_c, 3),
            "identical": bool(compiled_agree),
            **stepper,
        },
        "vanilla": van, "adaptive": ada, "warm": warm,
        "iter_reduction_vs_vanilla": round(
            van["total_iters"] / max(warm["total_iters"], 1), 2),
        "lp_obj_within_slack": bool(slack_ok),
        "cost_drift_pct": round(drift_pct, 3),
        "cost_drift_max_pct": round(drift_max_pct, 2),
        "scaling": scaling_stats,
        "pipeline": pipeline_stats,
        "stochastic": stochastic_stats,
        "constraints": constraint_stats,
    }
    return [{
        "figure": "fleet_sweep(beyond)", "B": len(problems),
        "distinct_shapes": shapes,
        "batched_s": round(t_batch, 2), "looped_s": round(t_loop, 2),
        "speedup": round(t_loop / max(t_batch, 1e-9), 1),
        "mappings_identical": agree,
        "placement_batched_s": round(t_place_b, 2),
        "placement_looped_s": round(t_place_l, 2),
        "placement_speedup": round(
            t_place_l / max(t_place_b, 1e-9), 1),
        "placements_identical": place_agree,
        # compiled on-device stepper (place_step): cold includes the
        # XLA compiles, warm is the steady-state per-sweep cost; the
        # speedup column is vs the per-instance loop (the per-step
        # host-dispatch baseline both batched engines eliminate) —
        # vs the numpy lockstep engine, CPU hosts sit near parity
        # (XLA's elementwise kernels are ~2x slower than numpy's;
        # the dispatch-elimination win shows on TPU)
        "placement_compiled_cold_s": round(t_place_c_cold, 2),
        "placement_compiled_s": round(t_place_c, 2),
        "compiled_speedup_vs_loop": round(
            t_place_l / max(t_place_c, 1e-9), 1),
        "compiled_vs_lockstep": round(
            t_place_b / max(t_place_c, 1e-9), 2),
        "placements_identical_compiled": compiled_agree,
        "compiled_dispatches": stepper["dispatches"],
        "compiled_fallbacks": stepper["fallbacks"],
        "compiled_modes": stepper["modes"],
        # shape-bucketed packing (FleetEngine planner) vs the one
        # worst-case padded shape: bucket count, padded-cell waste
        # fraction before/after, per-bucket cold compile+solve seconds
        "buckets": plan.n_buckets,
        "bucket_sizes": [b.B for b in plan.buckets],
        "waste_frac_single": round(plan.waste_single, 4),
        "waste_frac_bucketed": round(plan.waste_packed, 4),
        "waste_reduction_pct": round(100 * plan.waste_reduction, 1),
        "bucket_lp_s": bucketing["bucket_lp_s"],
        "bucket_place_s": bucketing["bucket_place_s"],
        "bucket_costs_identical": bucket_costs_identical,
        # convergence telemetry (iterations are deterministic, unlike
        # the wall-clock columns — these are what the CI gate pins)
        "lp_tol": tol,
        "vanilla_total_iters": van["total_iters"],
        "adaptive_total_iters": ada["total_iters"],
        "warm_total_iters": warm["total_iters"],
        "iter_reduction_vs_vanilla": solver_stats[
            "iter_reduction_vs_vanilla"],
        "warm_median_iters": warm["median_iters"],
        "warm_total_restarts": warm["total_restarts"],
        "warm_max_kkt": round(warm["max_kkt"], 6),
        "warm_converged_frac": warm["converged_frac"],
        "lp_obj_within_slack": bool(slack_ok),
        "cost_drift_pct": round(drift_pct, 3),
        "cost_drift_max_pct": round(drift_max_pct, 2),
        # the PR 8 speed layer: ruiz+omega advantage on the
        # ill-conditioned gate grid, one-dispatch compiled sweep chain
        "ruiz_median_iter_reduction_pct": round(
            100 * scaling_stats["median_iter_reduction"], 1),
        "pipeline_dispatches": pipeline_stats["dispatches"],
        "pipeline_costs_identical": pipeline_stats["costs_identical"],
        # stochastic robustness (repro.stochastic on the golden burst
        # grid): the CVaR-selected fleet vs expected-cost-only
        # selection, all K scenarios in one batched dispatch
        "stochastic_k": stochastic_stats["K"],
        "stochastic_dispatches": stochastic_stats["lp_dispatches"],
        "robust_fleet_cost": stochastic_stats["fleet_cost"],
        "robust_worst_overload": stochastic_stats["worst_overload"],
        "expected_fleet_cost": stochastic_stats["expected_fleet_cost"],
        "expected_worst_overload": stochastic_stats[
            "expected_fleet_worst_overload"],
        # constraint planning (repro.core.constraints + checker) on
        # the check_constraints smoke grid
        "constrained_instances": constraint_stats["active"],
        "constraint_oracle_violations":
            constraint_stats["oracle_violations"],
        "constraint_engines_identical":
            constraint_stats["engines_identical"],
        "_solver_stats": solver_stats,
    }]


ALL_TABLES = {
    "fig7a": fig7a, "fig7b": fig7b, "fig7c": fig7c,
    "fig8a": fig8a, "fig8b": fig8b, "fig9": fig9, "fig10": fig10,
    "fig11": fig11, "runtime": runtime, "no_timeline": no_timeline,
    "near_integrality": near_integrality,
    "scaling_beyond": scaling_beyond,
    "local_search_beyond": local_search_beyond,
    "fleet_sweep": fleet_sweep,
}
