"""The stochastic-rightsizing smoke: one fixed golden burst grid.

``stochastic_smoke`` fans a pinned GCT-like forecast into K scenarios,
runs the full ``plan_stochastic`` path (ONE batched LP dispatch +
lockstep placement + CVaR selection), and returns the deterministic
summary blob ``benchmarks.run`` merges into ``solver_stats.json``
under the ``stochastic`` key — the blob ``benchmarks.check_stochastic``
gates against ``results/golden/stochastic.json``.

Like the ruiz/pipeline gate grids in ``paper_tables.fleet_sweep``, the
forecast and selection parameters here are FIXED at every ``--scale``:
the CI gate pins the frontier numbers, so the grid must not move when
the surrounding benchmark scales down.  Only K is a parameter
(``benchmarks.run --scenarios``), and the committed golden was
generated at ``GOLDEN_K`` — a run at any other K still satisfies the
structural invariants but skips the frontier diff.

The burst channel is deliberately hot (``burst_prob=0.15`` with a
Pareto-1.6 tail): heavy-tailed spikes are the regime where the
CVaR-selected fleet strictly dominates expected-cost-only selection on
worst-scenario overload — the separation the gate asserts.
"""

from __future__ import annotations

# the golden burst grid: every field pinned, independent of --scale
GOLDEN_FORECAST = {
    "n": 120, "m": 6, "seed": 0, "cost_model": "gce", "e": 1.0,
    "load_sigma": 0.15, "diurnal_amp": 0.10,
    "burst_prob": 0.15, "burst_alpha": 1.6, "burst_cap": 8.0,
}
GOLDEN_SELECT = {
    "seed": 0, "cvar_alpha": 0.9, "cvar_lambda": 2.0,
    "overload_premium": 3.0, "recfg_weight": 0.0, "quantiles": 9,
    "algo": "lp-map-f",
}
GOLDEN_K = 64


def stochastic_smoke(scenarios: int | None = None) -> dict:
    """Run the golden burst grid at K=``scenarios`` (default
    ``GOLDEN_K``) and return the summary blob plus provenance."""
    from repro.stochastic import (StochasticConfig, gct_forecast,
                                  plan_stochastic)

    K = scenarios if scenarios is not None else GOLDEN_K
    forecast = gct_forecast(**GOLDEN_FORECAST)
    config = StochasticConfig(scenarios=K, **GOLDEN_SELECT)
    res = plan_stochastic(forecast, config)
    blob = res.summary()
    blob["forecast"] = dict(GOLDEN_FORECAST)
    blob["golden_k"] = GOLDEN_K
    blob["timings"] = {k: round(v, 3) for k, v in res.timings.items()}
    return blob


if __name__ == "__main__":
    import json

    print(json.dumps(stochastic_smoke(), indent=1))
