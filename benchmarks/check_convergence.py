"""Convergence-regression gate for the adaptive PDHG engine.

    python -m benchmarks.check_convergence results/ci/solver_stats.json \
        results/golden/solver_stats.json [--max-iter-regression 0.25]

Compares the current smoke-sweep solver telemetry (written by
``benchmarks.run --only fleet_sweep``) against the committed baseline:

  * median iterations-to-tolerance of the warm-started production path
    must not regress by more than ``--max-iter-regression`` (default
    25%, per-PR noise floor for the deterministic iteration counts);
  * final KKT residuals must stay within tolerance (every lane
    converged) and the median must not double vs the baseline;
  * the warm-started path must keep its >=2x total-iteration reduction
    over fixed-step vanilla PDHG;
  * protocol-cost parity with vanilla must hold: certified LP
    objectives within the provable tol slack on every instance, and
    total protocol cost within ``--max-cost-drift`` percent; the
    degeneracy-insensitive canonical rounding also bounds the PER-
    INSTANCE drift (``--max-cost-drift-instance``, default 15%) —
    residual drift beyond that means a truly degenerate LP landed on a
    different optimal face, not rounding noise;
  * the ruiz+omega speed layer must keep its advantage: median
    iterations-to-tolerance on the ill-conditioned heterogeneous gate
    grid reduced by at least ``--min-scaling-advantage`` (default 25%)
    vs the unscaled adaptive baseline, at full convergence and near-
    exact per-instance protocol-cost parity;
  * the compiled sweep pipeline must report exactly ONE dispatch for
    the whole warm chain, with protocol costs identical to the
    sequential chain.

The speed-layer gates are skipped when the stats predate PR 8 (no
``scaling``/``pipeline`` sections), so older baselines stay readable.

Exit code 0 on pass, 1 on regression — wired as a CI step right after
the benchmark smoke run.  Regenerate the baseline intentionally with:
``python -m benchmarks.run --scale quick --only fleet_sweep --out
results/golden_tmp && cp results/golden_tmp/solver_stats.json
results/golden/solver_stats.json``.
"""

from __future__ import annotations

import argparse
import json
import sys


def check(cur: dict, base: dict, max_iter_regression: float,
          max_kkt_factor: float, min_reduction: float,
          max_cost_drift: float = 2.0,
          max_cost_drift_instance: float = 15.0,
          min_scaling_advantage: float = 0.25) -> list[str]:
    """Returns the list of regression messages (empty == gate passes)."""
    errs = []
    cw, bw = cur["warm"], base["warm"]
    # iteration counts quantize to the convergence-check interval, so a
    # fractional budget alone would flag a single one-quantum shift
    # (e.g. median 75 -> 100 is +33%): grant one quantum of slack on
    # the per-instance median, and apply the fractional budget to the
    # finer-grained total as well
    quantum = cur.get("check_every", 0)
    limit = bw["median_iters"] * (1.0 + max_iter_regression) + quantum
    if cw["median_iters"] > limit:
        errs.append(
            f"median iterations-to-tolerance regressed: "
            f"{cw['median_iters']} > {limit:.1f} "
            f"(baseline {bw['median_iters']} +{max_iter_regression:.0%} "
            f"+ {quantum} check-interval slack)")
    t_limit = bw["total_iters"] * (1.0 + max_iter_regression) + quantum
    if cw["total_iters"] > t_limit:
        errs.append(
            f"total iterations-to-tolerance regressed: "
            f"{cw['total_iters']} > {t_limit:.0f} "
            f"(baseline {bw['total_iters']} +{max_iter_regression:.0%})")
    if cw["converged_frac"] < base["warm"]["converged_frac"]:
        errs.append(
            f"converged fraction dropped: {cw['converged_frac']:.3f} < "
            f"baseline {bw['converged_frac']:.3f}")
    if cw["max_kkt"] > cur["tol"]:
        errs.append(
            f"final KKT residual above tolerance: max_kkt "
            f"{cw['max_kkt']:.2e} > tol {cur['tol']:.2e}")
    if cw["median_kkt"] > bw["median_kkt"] * max_kkt_factor:
        errs.append(
            f"median KKT residual regressed: {cw['median_kkt']:.2e} > "
            f"{max_kkt_factor}x baseline {bw['median_kkt']:.2e}")
    if cur["iter_reduction_vs_vanilla"] < min_reduction:
        errs.append(
            f"warm-started sweep lost its iteration advantage: "
            f"{cur['iter_reduction_vs_vanilla']}x < {min_reduction}x "
            f"over fixed-step vanilla")
    if not cur.get("lp_obj_within_slack", False):
        errs.append("certified LP objectives drifted beyond the "
                    "provable tolerance slack vs fixed-step vanilla")
    if abs(cur["cost_drift_pct"]) > max_cost_drift:
        errs.append(
            f"total protocol cost drifted {cur['cost_drift_pct']:+.3f}% "
            f"vs vanilla (budget +/-{max_cost_drift}%)")
    drift_max = cur.get("cost_drift_max_pct")
    if drift_max is not None and drift_max > max_cost_drift_instance:
        errs.append(
            f"per-instance protocol cost drifted {drift_max:.2f}% vs "
            f"vanilla (budget {max_cost_drift_instance}%; the canonical "
            f"rounding should absorb epsilon-optimal vertex ties)")

    # --- PR 8 speed-layer gates (absent in pre-PR 8 stats) -----------
    scal = cur.get("scaling")
    if scal is not None:
        red = scal["median_iter_reduction"]
        if red < min_scaling_advantage:
            errs.append(
                f"ruiz+omega lost its iteration advantage on the "
                f"heterogeneous gate grid: median reduction {red:.1%} < "
                f"{min_scaling_advantage:.0%} (baseline median "
                f"{scal['baseline_median_iters']}, ruiz median "
                f"{scal['ruiz_median_iters']})")
        if scal["converged_frac"] < 1.0:
            errs.append(
                f"ruiz+omega gate grid not fully converged: "
                f"{scal['converged_frac']:.3f} < 1.0")
        if scal["cost_drift_max_pct"] > max_cost_drift_instance:
            errs.append(
                f"ruiz+omega protocol cost drifted "
                f"{scal['cost_drift_max_pct']:.2f}% per-instance on the "
                f"gate grid (budget {max_cost_drift_instance}%)")
    pipe = cur.get("pipeline")
    if pipe is not None:
        if pipe["dispatches"] != 1:
            errs.append(
                f"pipelined sweep dispatched {pipe['dispatches']} "
                f"compiled solves for the whole warm chain (must be "
                f"exactly 1; sequential chain takes "
                f"{pipe['sequential_dispatches']})")
        if not pipe["costs_identical"]:
            errs.append(
                "pipelined sweep protocol costs diverged from the "
                "sequential warm chain (must be identical)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="solver_stats.json from this run")
    ap.add_argument("baseline", help="committed baseline solver_stats.json")
    ap.add_argument("--max-iter-regression", type=float, default=0.25,
                    help="allowed fractional increase of median "
                         "iterations-to-tolerance (default 0.25)")
    ap.add_argument("--max-kkt-factor", type=float, default=2.0,
                    help="allowed factor on the median final KKT "
                         "residual (default 2.0)")
    ap.add_argument("--min-reduction", type=float, default=2.0,
                    help="required total-iteration reduction of the "
                         "warm-started sweep vs vanilla (default 2.0)")
    ap.add_argument("--max-cost-drift", type=float, default=2.0,
                    help="allowed total protocol-cost drift vs vanilla, "
                         "in percent (default 2.0; two-sided -- the canonical rounding's cheapest-vertex rule makes tol mode slightly cheaper than vanilla)")
    ap.add_argument("--max-cost-drift-instance", type=float, default=15.0,
                    help="allowed per-instance protocol-cost drift, in "
                         "percent (default 15.0)")
    ap.add_argument("--min-scaling-advantage", type=float, default=0.25,
                    help="required fractional median-iteration reduction "
                         "of ruiz+omega on the heterogeneous gate grid "
                         "(default 0.25)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    errs = check(cur, base, args.max_iter_regression, args.max_kkt_factor,
                 args.min_reduction, args.max_cost_drift,
                 args.max_cost_drift_instance, args.min_scaling_advantage)
    print(f"convergence gate: current warm median_iters="
          f"{cur['warm']['median_iters']} (baseline "
          f"{base['warm']['median_iters']}), reduction vs vanilla="
          f"{cur['iter_reduction_vs_vanilla']}x, max_kkt="
          f"{cur['warm']['max_kkt']:.2e}, tol={cur['tol']:.0e}, "
          f"cost drift={cur['cost_drift_pct']:+.3f}%")
    if "scaling" in cur:
        s, p = cur["scaling"], cur["pipeline"]
        print(f"speed layer: ruiz+omega median iter reduction="
              f"{s['median_iter_reduction']:.1%} (gate grid median "
              f"{s['baseline_median_iters']:.0f} -> "
              f"{s['ruiz_median_iters']:.0f}), pipeline dispatches="
              f"{p['dispatches']} for {p['groups']} groups, "
              f"costs identical={p['costs_identical']}")
    if errs:
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print("convergence gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
