"""Stochastic-rightsizing regression gate.

    python -m benchmarks.check_stochastic results/ci/solver_stats.json \
        results/golden/stochastic.json

Reads the ``stochastic`` blob that ``benchmarks.run`` (via the
``fleet_sweep`` robustness section / ``benchmarks.stochastic_smoke``)
merges into ``solver_stats.json`` and holds the stochastic layer's
contracts on the fixed golden burst grid:

  * one-dispatch invariant: all K scenarios share ONE trimmed shape by
    the fan-out's construction, so the batched solve must issue at
    most one compiled LP dispatch per bucket (``lp_dispatches <=
    buckets``);
  * every scenario lane converged to tolerance;
  * robust-cost bracket: the CVaR-selected fleet costs at least the
    per-scenario mean protocol cost (buying for a distribution is
    never cheaper than the average scenario's own plan on this grid)
    and at most the elementwise-max fleet (the zero-overload upper
    bracket in the candidate menu);
  * tail-risk separation: the CVaR-selected fleet's worst-scenario
    overload is STRICTLY lower than the expected-cost-only fleet's —
    the whole point of carrying the CVaR term through selection on a
    heavy-tailed burst grid;
  * determinism vs the committed golden (only when the run used the
    golden K): same forecast + seed => the same frontier, fleet by
    fleet and number by number (scenario fan-out, LP rounding, and
    selection are all deterministic; numeric fields get ``--tol``
    relative slack for cross-platform rounding).

Exit code 0 on pass, 1 on regression — wired as a CI step right after
the service gate.  Regenerate the baseline intentionally with

    python -m benchmarks.stochastic_smoke > results/golden/stochastic.json
"""

from __future__ import annotations

import argparse
import json
import sys

# frontier/summary fields pinned against the golden blob (beyond the
# structural invariants, which hold at any K)
_PINNED = ("fleet", "fleet_cost", "expected_fleet",
           "expected_fleet_cost", "mean_scenario_cost",
           "worst_scenario_cost", "max_fleet_cost", "mean_overload",
           "cvar_overload", "worst_overload",
           "expected_fleet_worst_overload")


def _close(a, b, tol: float) -> bool:
    if isinstance(a, list) or isinstance(b, list):
        return (isinstance(a, list) and isinstance(b, list)
                and len(a) == len(b)
                and all(_close(x, y, tol) for x, y in zip(a, b)))
    if isinstance(a, float) or isinstance(b, float):
        return abs(float(a) - float(b)) <= tol * max(
            1.0, abs(float(a)), abs(float(b)))
    return a == b


def check(cur: dict, base: dict | None, tol: float = 1e-6) -> list[str]:
    """Returns the list of regression messages (empty == gate passes)."""
    errs = []
    if cur["lp_dispatches"] > cur["buckets"]:
        errs.append(
            f"one-dispatch invariant broken: {cur['lp_dispatches']} LP "
            f"dispatch(es) for {cur['buckets']} bucket(s) — all K "
            f"scenarios share one trimmed shape, so the batched solve "
            f"must coalesce them")
    if cur["converged_frac"] < 1.0:
        errs.append(
            f"unconverged scenario lanes: converged_frac == "
            f"{cur['converged_frac']:.4f} < 1.0")
    if cur["fleet_cost"] < cur["mean_scenario_cost"] - tol:
        errs.append(
            f"robust fleet cost {cur['fleet_cost']} fell below the "
            f"mean per-scenario cost {cur['mean_scenario_cost']} — "
            f"selection is under-buying the distribution")
    if cur["fleet_cost"] > cur["max_fleet_cost"] + tol:
        errs.append(
            f"robust fleet cost {cur['fleet_cost']} exceeds the "
            f"elementwise-max fleet {cur['max_fleet_cost']} — the "
            f"zero-overload candidate should have won instead")
    if not cur["worst_overload"] < cur["expected_fleet_worst_overload"]:
        errs.append(
            f"tail-risk separation lost: CVaR-selected worst overload "
            f"{cur['worst_overload']} is not strictly below the "
            f"expected-cost-only fleet's "
            f"{cur['expected_fleet_worst_overload']} on the golden "
            f"burst grid")
    if base is None:
        return errs
    if cur["K"] != base["K"]:
        errs.append(
            f"# frontier diff skipped: run used K={cur['K']}, golden "
            f"is K={base['K']} (structural invariants still checked)")
        return errs
    for key in _PINNED:
        if not _close(cur[key], base[key], tol):
            errs.append(
                f"golden drift: {key} == {cur[key]!r} != committed "
                f"{base[key]!r} (same forecast + seed must reproduce "
                f"the frontier exactly; regenerate the golden only "
                f"for intentional changes)")
    if len(cur["frontier"]) != len(base["frontier"]):
        errs.append(
            f"frontier changed shape: {len(cur['frontier'])} rows vs "
            f"golden {len(base['frontier'])}")
    else:
        for i, (c, b) in enumerate(zip(cur["frontier"],
                                       base["frontier"])):
            for key in sorted(set(c) | set(b)):
                if not _close(c.get(key), b.get(key), tol):
                    errs.append(
                        f"golden drift: frontier[{i}].{key} == "
                        f"{c.get(key)!r} != committed {b.get(key)!r}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="solver_stats.json from this run")
    ap.add_argument("baseline",
                    help="committed results/golden/stochastic.json")
    ap.add_argument("--tol", type=float, default=1e-6,
                    help="relative tolerance on numeric golden fields "
                         "(default 1e-6 — cross-platform rounding "
                         "only; the pipeline is deterministic)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f).get("stochastic")
    if cur is None:
        print("FAIL: no 'stochastic' key in current solver_stats.json "
              "— run benchmarks.run --only fleet_sweep (the robustness "
              "section writes it)", file=sys.stderr)
        return 1
    with open(args.baseline) as f:
        base = json.load(f)

    errs = [e for e in check(cur, base, args.tol)
            if not e.startswith("#")]
    print(f"stochastic gate: K={cur['K']}, {cur['lp_dispatches']} LP "
          f"dispatch(es) / {cur['buckets']} bucket(s), robust fleet "
          f"{cur['fleet']} (cost {cur['fleet_cost']}, worst overload "
          f"{cur['worst_overload']}) vs expected-only "
          f"{cur['expected_fleet']} (cost {cur['expected_fleet_cost']}, "
          f"worst overload {cur['expected_fleet_worst_overload']})")
    if errs:
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print("stochastic gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
