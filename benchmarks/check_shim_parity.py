"""Shim-parity gate: legacy ``evaluate_many`` vs the ``FleetEngine`` path.

    python -m benchmarks.check_shim_parity [--buckets 4] [--lp-iters 300]

Runs the CI smoke grid (a small ragged sweep, every protocol algorithm)
through both public surfaces —

  * the legacy kwarg shim ``evaluate_many(...)`` (single-bucket packing,
    the path the committed golden tables pin), and
  * an explicitly configured ``FleetEngine`` with the shape-bucket
    packing planner enabled (``SweepConfig(max_buckets=...)``),

and fails (exit 1) on ANY protocol-cost mismatch.  This is the
engine-redesign analogue of the golden-table gate: the typed-config
session API, the bucket planner, and the bucket merge must reproduce the
legacy numbers exactly — bucketed packing is a layout optimization, not
a numerical one.  Lower bounds ride on fp32 XLA reductions whose
reassociation may shift with the padded shape, so they are compared at a
tight relative tolerance instead of bitwise.

Wired into the CI fast tier right after the tier-1 tests.
"""

from __future__ import annotations

import argparse
import sys


# tolerance for the fp32 LP lower bounds (costs must match EXACTLY)
LB_REL = 1e-5


def run_grid(buckets: int, lp_iters: int):
    """(legacy entries, FleetResult) for the smoke grid."""
    from repro.core import (FleetEngine, SolverConfig, SweepConfig,
                            evaluate_many)
    from repro.workload import SyntheticSpec, synthetic_batch

    specs = [SyntheticSpec(n=36 + 8 * i, m=4, D=3, T=10 + 2 * i, seed=s)
             for i in range(6) for s in range(2)]
    problems = synthetic_batch(specs)
    legacy = evaluate_many(problems, lp_iters=lp_iters)
    engine = FleetEngine(solver=SolverConfig(iters=lp_iters),
                         sweep=SweepConfig(max_buckets=buckets))
    return legacy, engine.evaluate(problems)


def compare(legacy, result) -> list[str]:
    """Returns mismatch messages (empty == gate passes)."""
    errs = []
    if len(legacy) != len(result.entries):
        return [f"entry count mismatch: legacy {len(legacy)} vs engine "
                f"{len(result.entries)}"]
    for i, (a, b) in enumerate(zip(legacy, result.entries)):
        if set(a["costs"]) != set(b["costs"]):
            errs.append(f"instance {i}: algo sets differ")
            continue
        for algo, cost in a["costs"].items():
            if b["costs"][algo] != cost:
                errs.append(
                    f"instance {i} algo {algo}: legacy cost {cost!r} != "
                    f"engine cost {b['costs'][algo]!r}")
        if abs(b["lb"] - a["lb"]) > LB_REL * max(abs(a["lb"]), 1e-12):
            errs.append(
                f"instance {i}: lower bound drifted beyond rel {LB_REL}: "
                f"legacy {a['lb']!r} vs engine {b['lb']!r}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--buckets", type=int, default=4,
                    help="max shape buckets of the engine path "
                         "(default 4)")
    ap.add_argument("--lp-iters", type=int, default=300,
                    help="fixed LP iteration count of both paths "
                         "(default 300)")
    args = ap.parse_args(argv)

    legacy, result = run_grid(args.buckets, args.lp_iters)
    plan = result.plan
    print(f"shim parity: B={len(legacy)} grid, engine packed "
          f"{plan.n_buckets} bucket(s) {[b.B for b in plan.buckets]}, "
          f"padded-cell waste {plan.waste_single:.1%} -> "
          f"{plan.waste_packed:.1%} "
          f"({plan.waste_reduction:.1%} of waste eliminated)")
    errs = compare(legacy, result)
    if errs:
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print("shim parity: PASS (all protocol costs identical)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
