"""Roofline analysis from the dry-run artifacts (§Roofline).

Hardware model (TPU v5e-like, per chip):
    peak   = 197 TFLOP/s bf16
    HBM bw = 819 GB/s
    ICI    = ~50 GB/s/link

Terms (seconds, per step, for the whole partitioned program):
    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes / collective_bytes come from the trip-count-aware
HLO accounting (hlo_cost.py) over the compiled partitioned module —
already per-device totals; multiplying by chips gives program totals, and
the per-device time is the roofline term directly.

MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE) for train;
2 * N * D for inference (forward only).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link

__all__ = ["roofline_row", "load_all", "table"]


def roofline_row(rec: dict) -> dict:
    """rec: one dry-run JSON record (per-device flops/traffic/collectives)."""
    flops_dev = rec["flops"]
    bytes_dev = rec["traffic_bytes"]
    coll_dev = sum(rec["collective_bytes"].values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    # tokens processed per step: full sequences for train/prefill, one
    # token per sequence for decode
    tokens = rec["global_batch"] * (rec["seq_len"]
                                    if rec["mode"] in ("train", "prefill")
                                    else 1)
    n_params = (rec["active_param_count"]
                if rec["active_param_count"] else rec["param_count"])
    mult = 6 if rec["mode"] == "train" else 2
    model_flops = mult * n_params * tokens
    hlo_total = flops_dev * rec["devices"]
    useful = model_flops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model FLOPs per second achievable if the
    # dominant term were the only cost, vs chips at peak
    step_time = max(terms.values())
    mfu_bound = model_flops / (step_time * rec["devices"] * PEAK_FLOPS) \
        if step_time > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec["mode"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "roofline_fraction": mfu_bound,
    }


def load_all(dirname: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(dirname: str = "results/dryrun", mesh: str = "16x16"):
    out = []
    for rec in load_all(dirname):
        if rec["mesh"] != mesh:
            continue
        row = roofline_row(rec)
        row["next_lever"] = next_lever(row)
        out.append(row)
    return out


def next_lever(r: dict) -> str:
    """One sentence: what would move the dominant term down (§Roofline)."""
    arch, shape, b = r["arch"], r["shape"], r["bottleneck"]
    if b == "collective":
        if "moe" in arch or "kimi" in arch or "olmoe" in arch:
            return ("reduce-scatter MoE combine (exchange only owned "
                    "tokens) instead of dense psum")
        if r["mode"] == "decode":
            return ("batch decode steps / widen per-step work so state "
                    "psums amortize; overlap collectives with compute")
        return "overlap gradient all-reduce with backward (bucketed async)"
    if b == "memory":
        if arch.startswith("rwkv") and r["mode"] == "train":
            return ("chunked WKV recurrence (64-step parallel chunks) cuts "
                    "state read/write traffic ~chunk-fold")
        if r["mode"] == "train":
            return ("microbatching + selective remat policy to cut live "
                    "activation traffic; causal block-skip in streaming "
                    "attention")
        if r["mode"] == "prefill":
            return ("larger KV chunks + causal block-skip halve score "
                    "traffic; fuse softmax normalizer updates")
        return "quantized (int8) KV cache halves cache read traffic"
    return ("higher per-chip utilization: fuse small ops, raise "
            "arithmetic intensity (bigger microbatch)")


def fmt_table(rows) -> str:
    hdr = (f"{'arch':20s} {'shape':12s} {'bottleneck':11s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
           f"{'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"{r['arch']:20s} {r['shape']:12s} {r['bottleneck']:11s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
            f"{r['t_collective_s']:10.4f} {r['useful_ratio']:7.3f} "
            f"{r['roofline_fraction']:9.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(fmt_table(table(mesh=mesh)))
