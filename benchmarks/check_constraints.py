"""Constraint-planning CI gate.

    python -m benchmarks.check_constraints

Holds the three contracts of the constraint layer
(``repro.core.constraints`` + ``repro.core.checker``, see
docs/constraints.md):

  * **oracle-clean smoke grid** — a fixed grid of synthetic instances
    with active constraint sets (deadlines, affinity merges,
    anti-affinity spreads, exclusive tasks, malleable widths) solved
    end-to-end by ``rightsize`` produces plans with ZERO violations
    from the independent brute-force checker;
  * **vacuous parity vs the committed golden** — attaching
    ``TaskConstraints.vacuous`` to the golden ``evaluate_many`` grid
    must reproduce ``results/golden/evaluate_many.json`` within the
    golden tolerance: the identity fast path may not perturb a single
    protocol number;
  * **engine agreement under active constraints** — the looped
    ``two_phase``, the numpy lockstep ``place_many``, and the
    compiled stepper place the LOWERED instances bit-identically.

Exit code 0 on pass, 1 on violation — wired as a CI step right after
the convergence gate.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

import numpy as np

from repro.core import (
    TaskConstraints,
    check_plan,
    evaluate_many,
    expand_solution,
    lower_constraints,
    pack_problems,
    penalty_map,
    place_many,
    rightsize,
    trim_timeline,
    two_phase,
)
from repro.workload import (
    SyntheticSpec,
    sweep_specs,
    synthetic_batch,
    synthetic_instance,
)

GOLDEN = pathlib.Path(__file__).resolve().parent.parent \
    / "results" / "golden" / "evaluate_many.json"

# same tolerance as tests/test_golden.py: the LP-side numbers ride on
# fp32 XLA reductions; real regressions move costs by whole node prices
REL = 1e-5


def _smoke_grid():
    """Deterministic constrained instances: strongest candidate set
    first, weakened when lowering rejects it (mirrors the property
    suite's generator); the exclusive-only fallback always lowers."""
    out = []
    for seed in range(6):
        rng = np.random.default_rng(seed)
        p = synthetic_instance(SyntheticSpec(
            n=18 + 4 * seed, m=3, D=2, T=12, seed=seed))
        pool = list(rng.permutation(p.n))
        dl = {u: int(rng.integers(int(p.end[u]), p.T))
              for u in (int(pool.pop()) for _ in range(2))}
        wide = int(pool.pop())
        candidates = [
            dict(deadlines=dl, affinity={"aff": [int(pool[0]),
                                                 int(pool[1])]},
                 anti_affinity={"anti": [int(pool[2]), int(pool[3])]},
                 exclusive=[int(pool[4])], widths={wide: (3, 0.2)}),
            dict(deadlines=dl,
                 anti_affinity={"anti": [int(pool[2]), int(pool[3])]},
                 exclusive=[int(pool[4])]),
            dict(exclusive=[0]),
        ]
        for cand in candidates:
            c = TaskConstraints.from_groups(p.n, **cand)
            q = dataclasses.replace(p, constraints=c)
            try:
                low = lower_constraints(q)
            except ValueError:
                continue
            out.append((q, low))
            break
    return out


def check_oracle_smoke() -> list[str]:
    errs, active = [], 0
    for q, low in _smoke_grid():
        active += not low.identity
        violations = check_plan(q, rightsize(q))
        for v in violations:
            errs.append(f"seed grid instance n={q.n}: {v}")
    if active < 4:
        errs.append(
            f"smoke grid degenerated: only {active} instances carry "
            f"active constraints — the gate is not exercising lowering")
    return errs


def check_vacuous_parity(golden: dict) -> list[str]:
    errs = []
    specs = sweep_specs(SyntheticSpec(n=60, m=4, D=3, T=16), seeds=2,
                        n=(40, 60, 80))
    problems = [dataclasses.replace(
        p, constraints=TaskConstraints.vacuous(p.n))
        for p in synthetic_batch(specs)]
    entries = evaluate_many(problems, lp_iters=golden["lp_iters"])
    if len(entries) != len(golden["entries"]):
        return [f"grid size drifted: {len(entries)} entries vs "
                f"{len(golden['entries'])} in the golden"]
    for i, (got, ref) in enumerate(zip(entries, golden["entries"])):
        for algo, cost in ref["costs"].items():
            g = got["costs"][algo]
            if abs(g - cost) > REL * max(1.0, abs(cost)):
                errs.append(
                    f"vacuous constraints perturbed entry {i} "
                    f"{algo}: {g} vs golden {cost} — the identity "
                    f"fast path must leave the pipeline untouched")
    return errs


def check_engine_agreement() -> list[str]:
    errs = []
    for q, low in _smoke_grid():
        t, _ = trim_timeline(low.lowered)
        mp = penalty_map(t, "avg")
        want = two_phase(t, mp)
        batch = pack_problems([t], assume_trimmed=True)
        for placement in ("lockstep", "compiled"):
            got = place_many(batch, [mp], placement=placement)[0]
            if not (np.array_equal(got.node_type, want.node_type)
                    and np.array_equal(got.assign, want.assign)):
                errs.append(
                    f"{placement} engine diverged from two_phase on a "
                    f"constrained instance (n={q.n})")
        for v in check_plan(q, expand_solution(low, want)):
            errs.append(f"expanded two_phase plan (n={q.n}): {v}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--golden", type=pathlib.Path, default=GOLDEN,
                    help="golden evaluate_many snapshot to diff against")
    args = ap.parse_args(argv)
    golden = json.loads(args.golden.read_text())

    errs = []
    for name, fn in (("oracle smoke grid", check_oracle_smoke),
                     ("vacuous golden parity",
                      lambda: check_vacuous_parity(golden)),
                     ("engine agreement", check_engine_agreement)):
        found = fn()
        errs.extend(found)
        print(("FAIL" if found else "ok  ") + f" {name}")
    if errs:
        print(f"\nconstraints gate: {len(errs)} violation(s)")
        for e in errs:
            print(f"  - {e}")
        return 1
    print("constraints gate: all green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
