"""Benchmark driver: one function per paper table/figure.

    python -m benchmarks.run [--scale quick|paper] [--only fig8a,...]
                             [--lp pdhg|highs]
                             [--placement batched|loop]
                             [--out results/paper]

Prints ``table,key=value,...`` CSV rows; writes JSON per table.  With the
default ``--lp pdhg`` every sweep table funnels its whole instance grid
through ONE batched LP solve (repro.core.batch), and with the default
``--placement batched`` the greedy placement phase runs as one lockstep
``place_many`` per protocol combo (repro.core.place_batch); ``--lp
highs`` / ``--placement loop`` restore the paper's per-instance loops
(placements and costs are identical).  Roofline rows (from dry-run
artifacts, if present) are appended at the end.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None) -> None:
    from benchmarks.paper_tables import ALL_TABLES

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["quick", "default", "paper"],
                    default="default")
    ap.add_argument("--lp", choices=["pdhg", "highs"], default="pdhg",
                    help="LP backend: batched PDHG sweep engine (one "
                         "solve per table) or per-instance exact HiGHS")
    ap.add_argument("--placement", choices=["batched", "loop"],
                    default="batched",
                    help="greedy placement phase: lockstep batched "
                         "engine (place_many) or the per-instance "
                         "two_phase loop (identical placements)")
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/paper")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(ALL_TABLES)
        if unknown:
            ap.error(f"unknown table(s) {sorted(unknown)}; "
                     f"choose from {sorted(ALL_TABLES)}")
    for name, fn in ALL_TABLES.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        rows = fn(scale=args.scale, lp=args.lp, placement=args.placement)
        dt = time.perf_counter() - t0
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=1)
        for row in rows:
            cells = ",".join(f"{k}={v}" for k, v in row.items())
            print(f"{name},{cells}")
        print(f"{name},_wall_s={dt:.1f}", flush=True)

    # roofline table from dry-run artifacts when available
    try:
        from benchmarks.roofline import fmt_table, table

        rows = table(args.dryrun_dir, mesh="16x16")
        if rows:
            print("\n# Roofline (16x16, from dry-run artifacts)")
            print(fmt_table(rows))
            with open(os.path.join(args.out, "roofline.json"), "w") as f:
                json.dump(rows, f, indent=1)
    except Exception as e:  # dry-run not yet produced
        print(f"# roofline skipped: {e}")


if __name__ == "__main__":
    main()
