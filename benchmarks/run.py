"""Benchmark driver: one function per paper table/figure.

    python -m benchmarks.run [--scale quick|paper] [--only fig8a,...]
                             [--lp pdhg|highs]
                             [--placement batched|loop]
                             [--lp-tol 5e-3] [--lp-max-iters 4000]
                             [--buckets 4] [--scenarios 64]
                             [--out results/paper]

Prints ``table,key=value,...`` CSV rows; writes JSON per table.  With the
default ``--lp pdhg`` every sweep table funnels its whole instance grid
through the adaptive restarted batched PDHG engine (repro.core.batch),
stopped at the ``--lp-tol`` normalized duality gap (``--lp-max-iters``
caps the worst case) and warm-started between grid-adjacent sweep
points; ``--placement batched`` (default) runs the greedy placement
phase as one lockstep ``place_many`` per protocol combo
(repro.core.place_batch).  ``--lp highs`` / ``--placement loop`` restore
the paper's per-instance loops (placements and costs are identical).

The ``fleet_sweep`` table additionally emits shape-bucketing telemetry
(bucket count, padded-cell waste fraction before/after the FleetEngine
packing planner, per-bucket compile+solve seconds; ``--buckets`` caps
the planner) and solver convergence telemetry (iterations-to-tolerance,
restarts, final KKT residuals for vanilla vs adaptive vs warm-started
solves), written next to the timing output as
``<out>/solver_stats.json`` — the file the CI convergence-
regression gate (benchmarks/check_convergence.py) diffs against
``results/golden/solver_stats.json``.  Roofline rows (from dry-run
artifacts, if present) are appended at the end.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import time


def main(argv=None) -> None:
    from benchmarks.paper_tables import ALL_TABLES

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["quick", "default", "paper"],
                    default="default")
    ap.add_argument("--lp", choices=["pdhg", "highs"], default="pdhg",
                    help="LP backend: batched PDHG sweep engine (one "
                         "solve per table) or per-instance exact HiGHS")
    ap.add_argument("--placement", choices=["batched", "compiled", "loop"],
                    default="batched",
                    help="greedy placement phase: numpy lockstep engine "
                         "(place_many), the compiled on-device stepper "
                         "(place_step), or the per-instance two_phase "
                         "loop (identical placements all three ways)")
    ap.add_argument("--lp-tol", type=float, default=None,
                    help="normalized-duality-gap stopping tolerance of "
                         "the PDHG LP phase (default: the scale's "
                         "built-in tolerance, repro.core.batch."
                         "DEFAULT_TOL)")
    ap.add_argument("--lp-max-iters", type=int, default=None,
                    help="worst-case PDHG iteration cap under --lp-tol "
                         "(default: per-scale)")
    ap.add_argument("--buckets", type=int, default=None,
                    help="max shape buckets of the FleetEngine packing "
                         "planner in the fleet_sweep bucketing section "
                         "(default: per-scale); 1 forces legacy "
                         "single-bucket packing")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="K of the stochastic robustness section in "
                         "fleet_sweep (benchmarks.stochastic_smoke's "
                         "golden burst grid; default: the committed "
                         "golden K) — the blob lands under the "
                         "'stochastic' key of <out>/solver_stats.json "
                         "for benchmarks.check_stochastic")
    ap.add_argument("--serve-trace", action="store_true",
                    help="also replay the serving-loop smoke trace "
                         "(benchmarks.serve_smoke: paired warm/cold "
                         "RightsizingService replays) and merge its "
                         "requests/sec + p99 telemetry under the "
                         "'serve' key of <out>/solver_stats.json")
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/paper")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args(argv)

    if args.buckets is not None and args.buckets < 1:
        ap.error(f"--buckets must be >= 1, got {args.buckets}")
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(ALL_TABLES)
        if unknown:
            ap.error(f"unknown table(s) {sorted(unknown)}; "
                     f"choose from {sorted(ALL_TABLES)}")
    for name, fn in ALL_TABLES.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        kwargs = {}
        if "buckets" in inspect.signature(fn).parameters:
            kwargs["buckets"] = args.buckets
        if "scenarios" in inspect.signature(fn).parameters:
            kwargs["scenarios"] = args.scenarios
        rows = fn(scale=args.scale, lp=args.lp, placement=args.placement,
                  lp_tol=args.lp_tol, lp_max_iters=args.lp_max_iters,
                  **kwargs)
        dt = time.perf_counter() - t0
        # solver telemetry rides on the row as a private blob: write it
        # as its own artifact next to the timing output
        stats = [row.pop("_solver_stats") for row in rows
                 if "_solver_stats" in row]
        if stats:
            path = os.path.join(args.out, "solver_stats.json")
            with open(path, "w") as f:
                json.dump(stats[0] if len(stats) == 1 else stats, f,
                          indent=1)
            print(f"# solver telemetry -> {path}")
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=1)
        for row in rows:
            cells = ",".join(f"{k}={v}" for k, v in row.items())
            print(f"{name},{cells}")
        print(f"{name},_wall_s={dt:.1f}", flush=True)

    if args.serve_trace:
        from benchmarks.serve_smoke import serve_smoke

        t0 = time.perf_counter()
        blob = serve_smoke(scale=args.scale)
        path = os.path.join(args.out, "solver_stats.json")
        stats = {}
        if os.path.exists(path):
            with open(path) as f:
                stats = json.load(f)
        stats["serve"] = blob
        with open(path, "w") as f:
            json.dump(stats, f, indent=1)
        print(f"# serve telemetry -> {path} ('serve' key)")
        print(f"serve_trace,requests={blob['requests']},"
              f"ticks={blob['ticks']},"
              f"requests_per_s={blob['requests_per_s']},"
              f"p99_replan_s={blob['p99_replan_s']},"
              f"dispatches_per_tick={blob['dispatches_per_tick']}")
        print(f"serve_trace,_wall_s={time.perf_counter() - t0:.1f}",
              flush=True)

    # roofline table from dry-run artifacts when available
    try:
        from benchmarks.roofline import fmt_table, table

        rows = table(args.dryrun_dir, mesh="16x16")
        if rows:
            print("\n# Roofline (16x16, from dry-run artifacts)")
            print(fmt_table(rows))
            with open(os.path.join(args.out, "roofline.json"), "w") as f:
                json.dump(rows, f, indent=1)
    except Exception as e:  # dry-run not yet produced
        print(f"# roofline skipped: {e}")


if __name__ == "__main__":
    main()
