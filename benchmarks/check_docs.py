"""Docs health gate (CI fast tier).

    PYTHONPATH=src python -m benchmarks.check_docs [--skip-snippets]

Three checks, any failure exits nonzero:

  1. **Doctests** — runs ``doctest.testmod`` over the audited public
     surface (``FleetEngine`` + the typed configs, the ``evaluate`` /
     ``evaluate_many`` shims, ``place_many``, the kernel wrappers), so
     every usage example in those docstrings stays runnable.
  2. **README snippets** — extracts the fenced ```python blocks from
     README.md and executes them top to bottom in one namespace; the
     quickstarts must keep working as written.
  3. **Intra-repo links** — scans ``docs/*.md`` and README.md for
     markdown links; every relative link must resolve to an existing
     file, and every ``#anchor`` (same-file or cross-file) must match
     a heading in its target (GitHub slug rules: lowercase, punctuation
     stripped, spaces to hyphens).

Docs are part of the product surface: a broken example or a dangling
link is a CI failure, not a docs chore.
"""

from __future__ import annotations

import argparse
import doctest
import importlib
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# The audited public surface: modules whose docstring examples the
# docs/ suite leans on.  Modules without doctests pass trivially.
AUDITED_MODULES = (
    "repro.core.engine",
    "repro.core.api",
    "repro.core.place_batch",
    "repro.core.place_step",
    "repro.core.batch",
    "repro.core.constraints",
    "repro.core.checker",
    "repro.kernels.ops",
    "repro.serve.config",
    "repro.serve.queue",
    "repro.serve.scale",
    "repro.serve.service",
    "repro.serve.snapshot",
    "repro.serve.faults",
    "repro.stochastic.forecast",
    "repro.stochastic.scenarios",
    "repro.stochastic.select",
)

SNIPPET_FILES = ("README.md",)
LINK_FILES = ("README.md", "docs")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def run_doctests() -> int:
    failures = 0
    flags = doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE
    for name in AUDITED_MODULES:
        mod = importlib.import_module(name)
        result = doctest.testmod(mod, optionflags=flags, verbose=False)
        label = f"doctest {name}: {result.attempted} examples"
        if result.failed:
            print(f"FAIL {label}, {result.failed} failed")
            failures += result.failed
        else:
            print(f"ok   {label}")
    return failures


def run_snippets() -> int:
    failures = 0
    for rel in SNIPPET_FILES:
        text = (REPO / rel).read_text()
        ns: dict = {}
        for i, block in enumerate(FENCE_RE.findall(text)):
            try:
                exec(compile(block, f"{rel}[python block {i}]", "exec"),
                     ns)
                print(f"ok   snippet {rel}[{i}] "
                      f"({len(block.splitlines())} lines)")
            except Exception as exc:  # noqa: BLE001 - report and gate
                print(f"FAIL snippet {rel}[{i}]: {exc!r}")
                failures += 1
    return failures


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of one markdown heading."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    text = re.sub(r"[^a-z0-9 _\-]", "", text)
    return text.replace(" ", "-")


def _anchors(path: pathlib.Path) -> set[str]:
    return {_slug(h) for h in HEADING_RE.findall(path.read_text())}


def check_links() -> int:
    files: list[pathlib.Path] = []
    for rel in LINK_FILES:
        p = REPO / rel
        files.extend(sorted(p.glob("*.md")) if p.is_dir() else [p])
    failures = 0
    for md in files:
        rel_md = md.relative_to(REPO)
        n_checked = 0
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = md if not path_part \
                else (md.parent / path_part).resolve()
            if not dest.exists():
                print(f"FAIL link {rel_md}: {target} "
                      f"(missing file {path_part})")
                failures += 1
                continue
            if anchor and dest.suffix == ".md" \
                    and anchor not in _anchors(dest):
                print(f"FAIL link {rel_md}: {target} "
                      f"(no heading for #{anchor})")
                failures += 1
                continue
            n_checked += 1
        print(f"ok   links {rel_md}: {n_checked} intra-repo links")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-snippets", action="store_true",
                    help="skip executing the README python blocks "
                         "(doctests and links still run)")
    args = ap.parse_args(argv)
    failures = run_doctests()
    if not args.skip_snippets:
        failures += run_snippets()
    failures += check_links()
    if failures:
        print(f"docs check: {failures} failure(s)")
        return 1
    print("docs check: all green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
